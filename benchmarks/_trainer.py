"""Shared trainer for accuracy-recovery benchmarks (Tables 1-3).

Trains a small GPT on the deterministic synthetic Markov corpus with a
given QSDP policy and returns the loss curve.  Runs on the trivial (1,1)
mesh: with FSDP size 1 the all-gathers are local but the quantize ->
dequantize of every transmitted tensor still applies, so the *accuracy*
effect of wire quantization is exactly reproduced at any device count
(bytes are accounted analytically elsewhere).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core.qsdp import MeshSpec, QSDPConfig
from repro.data import SyntheticLM, make_batch
from repro.models.config import ModelConfig
from repro.models.transformer import Model
from repro.optim import AdamWConfig, cosine_schedule, make_adamw
from repro.train.step import init_train_state, make_jitted_train_step

BENCH_MODEL = ModelConfig(
    name="gpt-bench", arch_type="dense", n_layers=2, d_model=192,
    vocab_size=512, n_heads=6, n_kv_heads=6, head_dim=32, d_ff=384,
    rope_theta=10_000.0,
)


@dataclasses.dataclass
class RunResult:
    tag: str
    losses: list  # [(step, loss)]
    final_loss: float
    ppl: float


def train_run(qsdp: QSDPConfig, steps: int = 200, batch: int = 8, seq: int = 128,
              lr: float = 2e-3, seed: int = 0, tag: str = "", model_cfg=None,
              eval_last: int = 5) -> RunResult:
    cfg = model_cfg or BENCH_MODEL
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    ms = MeshSpec(axes=("data", "model"), shape=(1, 1))
    model = Model(cfg, ms, qsdp)
    opt = make_adamw(AdamWConfig(lr=lr, schedule=cosine_schedule(lr, 20, steps)))
    state = init_train_state(model, opt, jax.random.PRNGKey(seed))
    data = SyntheticLM(vocab_size=cfg.vocab_size, seq_len=seq, global_batch=batch,
                       seed=seed, branching=4)
    step = make_jitted_train_step(model, opt, mesh, n_micro=1)
    losses = []
    with mesh:
        for i in range(steps):
            b = make_batch(data, i, mesh, ms.fsdp_axes)
            state, m = step(state, b, jax.random.fold_in(jax.random.PRNGKey(seed + 1), i))
            if i % 10 == 0 or i >= steps - eval_last:
                losses.append((i, float(m["loss"])))
    tail = [l for _, l in losses[-eval_last:]]
    final = sum(tail) / len(tail)
    return RunResult(tag=tag, losses=losses, final_loss=final,
                     ppl=float(jnp.exp(jnp.asarray(final))))


def qsdp_wg(w: int | None, g: int | None, **kw) -> QSDPConfig:
    """w/g = bits or None for full precision; min_quant_size small so the
    bench model's tensors are actually quantized."""
    return QSDPConfig(
        quantize_weights=w is not None, quantize_grads=g is not None,
        weight_bits=w or 8, grad_bits=g or 8, min_quant_size=256, **kw,
    )
