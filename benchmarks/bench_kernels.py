"""Kernel micro-benchmarks: jnp reference vs Pallas (interpret / compiled)
for the QSDP hot-path ops, per (bits, bucket_size).

  PYTHONPATH=src python -m benchmarks.bench_kernels [--n 4194304] \
      [--bits 2 4 8] [--buckets 512 1024] [--reps 20] [--out results/bench]

For each configuration it times

  * quantize   (fused quantize→pack on the Pallas side),
  * dequantize (fused unpack→dequantize on the Pallas side),
  * rowquant_matmul vs dense matmul of the dequantized weight (decode path),

and reports per-op wall ms plus the wire bytes the codes occupy (vs the
f32 bytes they replace).  On CPU the Pallas numbers are *interpret mode* —
a correctness path, not a speed path — and are labeled as such; on TPU the
compiled kernels are benchmarked (and interpret is skipped unless
--interpret is passed).
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp

from repro.core.quant import QuantConfig, dequantize, quantize, wire_bytes
from repro.kernels import ops, ref


def _timeit(fn, reps: int) -> float:
    fn()  # compile / warm up
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn()
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps * 1e3  # ms


def bench_quant(n: int, bits: int, bucket: int, mode: str, reps: int,
                backends: list[str]) -> dict:
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (n,))
    row = dict(op="quant_roundtrip", n=n, bits=bits, bucket=bucket, mode=mode,
               wire_bytes=wire_bytes(n, QuantConfig(bits=bits, bucket_size=bucket,
                                                    mode=mode)),
               f32_bytes=4 * n)
    for b in backends:
        cfg = QuantConfig(bits=bits, bucket_size=bucket, mode=mode, backend=b)
        qfn = jax.jit(lambda x: quantize(x, cfg, jax.random.PRNGKey(1)).codes)
        q = quantize(x, cfg, jax.random.PRNGKey(1))
        dfn = jax.jit(lambda q: dequantize(q))
        row[f"quantize_ms_{b}"] = _timeit(lambda: qfn(x), reps)
        row[f"dequantize_ms_{b}"] = _timeit(lambda: dfn(q), reps)
    return row


def bench_matmul(m: int, k: int, n: int, reps: int, backends: list[str]) -> dict:
    w = jax.random.normal(jax.random.PRNGKey(2), (k, n)) * 0.05
    x = jax.random.normal(jax.random.PRNGKey(3), (m, k))
    codes, scale, zero = ref.quantize_rowwise_ref(w, 255)
    row = dict(op="rowquant_matmul", m=m, k=k, n=n,
               code_bytes=k * n, f32_bytes=4 * k * n)
    dense = jax.jit(lambda x, w: x @ w)
    row["dense_matmul_ms"] = _timeit(lambda: dense(x, w), reps)
    jref = jax.jit(ref.rowquant_matmul_ref)
    row["rowquant_ms_jnp"] = _timeit(lambda: jref(x, codes, scale, zero), reps)
    if "pallas" in backends:
        row["rowquant_ms_pallas"] = _timeit(
            lambda: ops.rowquant_matmul(x, codes, scale, zero), reps)
    return row


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=1 << 22)
    ap.add_argument("--bits", type=int, nargs="+", default=[2, 4, 8])
    ap.add_argument("--buckets", type=int, nargs="+", default=[512, 1024])
    ap.add_argument("--modes", type=str, nargs="+", default=["shift", "stochastic"])
    ap.add_argument("--reps", type=int, default=10)
    ap.add_argument("--matmul", type=int, nargs=3, default=[256, 2048, 2048],
                    metavar=("M", "K", "N"))
    ap.add_argument("--interpret", action="store_true",
                    help="benchmark the Pallas interpret path even on TPU")
    ap.add_argument("--skip-pallas", action="store_true")
    ap.add_argument("--out", type=str, default=None)
    args = ap.parse_args(argv)

    on_tpu = jax.default_backend() == "tpu"
    pallas_label = "compiled" if on_tpu else "interpret (CPU correctness path)"
    backends = ["jnp"] + ([] if args.skip_pallas else ["pallas"])
    print(f"backend={jax.default_backend()}  pallas={pallas_label}")

    rows = []
    hdr = (f"| {'bits':>4} | {'bucket':>6} | {'mode':>10} | {'wire':>10} "
           f"| {'q jnp ms':>9} | {'q pallas':>9} | {'dq jnp':>9} | {'dq pallas':>9} |")
    print(hdr)
    print("|" + "-" * (len(hdr) - 2) + "|")
    for bits in args.bits:
        for bucket in args.buckets:
            for mode in args.modes:
                r = bench_quant(args.n, bits, bucket, mode, args.reps, backends)
                rows.append(r)
                print(f"| {bits:4d} | {bucket:6d} | {mode:>10} "
                      f"| {r['wire_bytes']:>10d} "
                      f"| {r.get('quantize_ms_jnp', 0):9.2f} "
                      f"| {r.get('quantize_ms_pallas', float('nan')):9.2f} "
                      f"| {r.get('dequantize_ms_jnp', 0):9.2f} "
                      f"| {r.get('dequantize_ms_pallas', float('nan')):9.2f} |")

    m, k, n = args.matmul
    r = bench_matmul(m, k, n, args.reps, backends)
    rows.append(r)
    print(f"rowquant_matmul ({m}x{k}x{n}): dense {r['dense_matmul_ms']:.2f}ms, "
          f"jnp-dequant {r['rowquant_ms_jnp']:.2f}ms, "
          f"pallas {r.get('rowquant_ms_pallas', float('nan')):.2f}ms "
          f"(weight bytes {r['code_bytes']:,} vs f32 {r['f32_bytes']:,})")

    if args.out:
        os.makedirs(args.out, exist_ok=True)
        path = os.path.join(args.out, "bench_kernels.jsonl")
        with open(path, "w") as f:
            for r in rows:
                f.write(json.dumps(r) + "\n")
        print(f"wrote {path}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
