"""Versioned schema for the BENCH_step.json / BENCH_serve.json artifacts.

The two bench writers upload their JSON as CI artifacts so the perf
trajectory accumulates across commits; downstream tooling (and humans
diffing artifacts between runs) depends on the column set staying stable.
This module pins that contract: a hand-rolled validator (no jsonschema
dependency) that the writers run before ``json.dump`` and the tier-1 tests
exercise on both synthetic documents and the checked-in artifacts.

Versioning: documents carry a top-level ``schema_version``.  A document
without one is a legacy artifact written before this module existed and is
treated as version 1; a document with a *different* version fails loudly so
a column rename is forced to bump the constant here and update this spec.

Field specs map column name -> type token:
  num   int or float (bools rejected)
  int   integral (bools rejected)
  bool  real bool
  str   string
  dict  mapping
  list  any list
  numlist  list of num
Extra columns are always allowed — the schema pins the floor, not the
ceiling.
"""

BENCH_SCHEMA_VERSION = 1


class BenchSchemaError(ValueError):
    """A bench document is missing required columns or has wrong types."""


def _is_num(v):
    return isinstance(v, (int, float)) and not isinstance(v, bool)


_CHECKS = {
    "num": _is_num,
    "int": lambda v: isinstance(v, int) and not isinstance(v, bool),
    "bool": lambda v: isinstance(v, bool),
    "str": lambda v: isinstance(v, str),
    "dict": lambda v: isinstance(v, dict),
    "list": lambda v: isinstance(v, list),
    "numlist": lambda v: isinstance(v, list) and all(_is_num(x) for x in v),
}

STEP_CONFIG = {
    "n_layers": "int", "d_model": "int", "d_ff": "int", "seq": "int",
    "batch": "int", "micro": "int", "mesh": "str", "steps": "int",
    "smoke": "bool",
}
STEP_VARIANT = {
    "train_state_bytes": "int", "train_state_bytes_per_device": "int",
    "ckpt_payload_bytes": "int", "compile_s": "num",
    "step_ms_median": "num", "step_ms_all": "numlist", "loss_final": "num",
    "layer_gather_launches_analytic": "int",
    "wire_bytes_analytic_per_step": "dict", "hlo_collective_bytes": "num",
    "hlo_collective_launches": "dict", "hlo_launches_by_dtype": "dict",
}
STEP_SUMMARY = {
    "ag_launch_reduction": "num", "wire_bytes_ratio_co_vs_per_tensor": "num",
    "autoplan_vs_qsdp_step_ratio": "num",
    "autoplan_vs_coalesced_step_ratio": "num",
}

SERVE_CONFIG = {
    "n_layers": "int", "d_model": "int", "d_ff": "int", "mesh": "str",
    "slots": "int", "requests": "int", "smoke": "bool",
}
SERVE_VARIANT = {
    "compile_s": "num", "wall_s": "num", "tokens": "int",
    "tokens_per_s": "num", "decode_steps": "int", "step_ms_mean": "num",
    "latency_s_p50": "num", "latency_s_p95": "num", "ttft_s_p95": "num",
    "mean_occupancy": "num", "slots": "int", "launches_per_token": "num",
    "gather_bytes_per_decode_step": "num", "prefill_traces": "int",
    "prefill_launches": "int",
}
SERVE_SUMMARY = {
    "gather_bytes_ratio_qsdp_vs_baseline": "num",
    "tokens_equal_across_variants": "bool",
}


def _check_fields(obj, spec, where, errors):
    if not isinstance(obj, dict):
        errors.append(f"{where}: expected object, got {type(obj).__name__}")
        return
    for field, token in spec.items():
        if field not in obj:
            errors.append(f"{where}: missing required column '{field}'")
        elif not _CHECKS[token](obj[field]):
            errors.append(
                f"{where}.{field}: expected {token}, "
                f"got {type(obj[field]).__name__} ({obj[field]!r:.40})")


def _validate(doc, kind, config_spec, variant_spec, summary_spec):
    errors = []
    if not isinstance(doc, dict):
        raise BenchSchemaError(f"{kind}: document is not a JSON object")
    version = doc.get("schema_version", BENCH_SCHEMA_VERSION)
    if version != BENCH_SCHEMA_VERSION:
        errors.append(f"{kind}: schema_version {version} != "
                      f"{BENCH_SCHEMA_VERSION} understood by this validator")
    _check_fields(doc.get("config"), config_spec, f"{kind}.config", errors)
    variants = doc.get("variants")
    if not isinstance(variants, dict) or not variants:
        errors.append(f"{kind}.variants: expected non-empty object")
    else:
        for name, row in variants.items():
            _check_fields(row, variant_spec, f"{kind}.variants[{name}]",
                          errors)
    _check_fields(doc.get("summary"), summary_spec, f"{kind}.summary", errors)
    if errors:
        raise BenchSchemaError("\n".join(errors))


def validate_bench_step(doc):
    """Validate a BENCH_step.json document; raises BenchSchemaError."""
    _validate(doc, "BENCH_step", STEP_CONFIG, STEP_VARIANT, STEP_SUMMARY)


def validate_bench_serve(doc):
    """Validate a BENCH_serve.json document; raises BenchSchemaError."""
    _validate(doc, "BENCH_serve", SERVE_CONFIG, SERVE_VARIANT, SERVE_SUMMARY)


def stamp(doc):
    """Stamp the current schema_version onto a document (returns it)."""
    doc["schema_version"] = BENCH_SCHEMA_VERSION
    return doc
