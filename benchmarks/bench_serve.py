"""Benchmark: continuous-batching serving under a synthetic arrival trace.

The serving analogue of bench_step.py.  On an emulated (2 data x 4 model)
8-device CPU mesh, a fixed pool of decode slots drains a DETERMINISTIC
synthetic request trace — seeded Poisson arrival gaps, mixed prompt and
generation lengths — through serve.ContinuousScheduler, for each wire
policy:

  baseline-fsdp        f32 weight gathers every decode step
  qsdp                 W8 quantized gathers (paper Section 5 wire format)
  qsdp-rowquant-wire   W8 gathers consumed in wire-code form by the fused
                       rowquant matmul (dense-MLP weights never dequantized
                       to HBM)

Decode is FSDP-style — every step re-gathers the sharded weights — so step
latency is collective-bound and the gather wire bytes per decode step are
the headline column: QSDP ships ~bits/32 of the baseline's bytes for the
same trace, slots, and per-request token counts.  (Baseline decodes f32
weights while the quantized variants decode quantized ones, so their
greedy TOKENS may differ; qsdp and qsdp-rowquant-wire consume the same
quantized weights and are asserted token-identical.)

Per variant this reports
  * tokens/s over the timed replay (compile excluded via a warmup drain
    that covers every distinct prompt length in the trace),
  * per-request latency (submit -> last token) p50/p95, in decode steps
    and in wall seconds,
  * mean slot occupancy of the pool,
  * analytic per-decode-step weight-gather wire bytes per device,

and writes everything to BENCH_serve.json (uploaded as a CI artifact next
to BENCH_step.json).

Run:  PYTHONPATH=src python benchmarks/bench_serve.py --smoke
"""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
# ^ MUST precede every other import: jax locks the device count on first init.

import argparse
import json
import time

import jax
import numpy as np

from repro.core.qsdp import QSDPConfig
from repro.models.config import ModelConfig
from repro.serve import ContinuousScheduler, Request, build_serve_setup


def variants():
    return {
        "baseline-fsdp": dict(qsdp=QSDPConfig.baseline(), rowquant=False),
        "qsdp": dict(qsdp=QSDPConfig(min_quant_size=256), rowquant=False),
        "qsdp-rowquant-wire": dict(qsdp=QSDPConfig(min_quant_size=256),
                                   rowquant=True),
    }


def make_trace(rng, n_requests, arrival_rate, prompt_lens, gen_lens, vocab):
    """Deterministic synthetic load: (arrival_step, Request) pairs.  Arrival
    gaps are Poisson (exponential inter-arrival, rounded to decode steps);
    prompt/gen lengths cycle through mixed buckets."""
    trace = []
    step = 0
    for i in range(n_requests):
        step += int(rng.exponential(1.0 / arrival_rate))
        plen = int(rng.choice(prompt_lens))
        gen = int(rng.choice(gen_lens))
        trace.append((step, Request(
            rid=f"req{i:03d}", prompt=rng.integers(0, vocab, size=plen).tolist(),
            max_new_tokens=gen, seed=i)))
    return trace


def replay(sched, trace, max_steps=100_000):
    """Drive the scheduler through the arrival trace: requests are submitted
    when the scheduler's decode-step clock (relative to replay start)
    reaches their arrival step; an idle pool fast-forwards to the next
    arrival."""
    pending = list(trace)
    start = sched.step_count
    skipped = 0  # idle steps fast-forwarded on the virtual arrival clock
    t0 = time.perf_counter()
    steps = 0
    while pending or sched.queue or sched.n_active():
        clock = sched.step_count - start + skipped
        while pending and pending[0][0] <= clock:
            sched.submit(pending.pop(0)[1])
        if pending and not (sched.queue or sched.n_active()):
            # idle server: fast-forward the virtual clock to the next
            # arrival (later arrivals keep their relative gaps)
            skipped += pending[0][0] - clock
            continue
        sched.step()
        steps += 1
        assert steps < max_steps, "trace replay did not converge"
    return time.perf_counter() - t0


def bench_variant(name, qsdp, rowquant, mcfg, trace, slots):
    prompt_lens = sorted({len(r.prompt) for _, r in trace})
    gen0 = trace[0][1].max_new_tokens
    setup = build_serve_setup(
        mcfg, data_par=2, model_par=4, qsdp=qsdp, batch=slots,
        prompt_len=max(prompt_lens),
        gen=max(r.max_new_tokens for _, r in trace), rowquant_mlp=rowquant)
    sched = ContinuousScheduler(setup.model, setup.mesh, setup.spec,
                                setup.params,
                                gather_key=jax.random.PRNGKey(42))

    # warmup: compile decode + one prefill per distinct prompt length
    t0 = time.perf_counter()
    for j, plen in enumerate(prompt_lens):
        sched.submit(Request(rid=f"warm{j}", prompt=list(range(1, plen + 1)),
                             max_new_tokens=min(gen0, 2), seed=0))
    sched.run()
    compile_s = time.perf_counter() - t0

    # timed replay (snapshot counters so warmup is excluded)
    base = sched.stats()
    wall_s = replay(sched, trace)
    st = sched.stats()
    done = {r.rid: sched.finished[r.rid] for _, r in trace}
    lat_steps = [c.finish_step - c.submit_step for c in done.values()]
    lat_s = [c.finish_time - c.submit_time for c in done.values()]
    tokens = st["tokens_generated"] - base["tokens_generated"]
    steps = st["decode_steps"] - base["decode_steps"]
    occ = ((st["mean_occupancy"] * st["decode_steps"]
            - base["mean_occupancy"] * base["decode_steps"]) / max(steps, 1))
    return {
        "compile_s": round(compile_s, 1),
        "wall_s": round(wall_s, 2),
        "tokens": int(tokens),
        "tokens_per_s": round(tokens / wall_s, 2),
        "decode_steps": int(steps),
        "step_ms_mean": round(1e3 * wall_s / max(steps, 1), 2),
        "latency_steps_p50": float(np.percentile(lat_steps, 50)),
        "latency_steps_p95": float(np.percentile(lat_steps, 95)),
        "latency_s_p50": round(float(np.percentile(lat_s, 50)), 3),
        "latency_s_p95": round(float(np.percentile(lat_s, 95)), 3),
        "mean_occupancy": round(occ, 2),
        "slots": slots,
        "gather_bytes_per_decode_step": int(setup.decode_gather_bytes()),
    }, {rid: c.tokens.tolist() for rid, c in done.items()}


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny config for CI (fast compile, short trace)")
    ap.add_argument("--requests", type=int, default=None)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--arrival-rate", type=float, default=1.5,
                    help="mean arrivals per decode step")
    ap.add_argument("--out", default="BENCH_serve.json")
    args = ap.parse_args(argv)

    if args.smoke:
        dims = dict(n_layers=2, d_model=128, d_ff=256)
        n_requests = args.requests or 8
        prompt_lens, gen_lens = (8, 12), (3, 4, 6)
    else:
        dims = dict(n_layers=4, d_model=256, d_ff=512)
        n_requests = args.requests or 24
        prompt_lens, gen_lens = (16, 32, 48), (8, 16, 24)

    mcfg = ModelConfig(name="bench-serve", arch_type="dense",
                       n_layers=dims["n_layers"], d_model=dims["d_model"],
                       vocab_size=512, n_heads=8, n_kv_heads=4,
                       head_dim=dims["d_model"] // 8, d_ff=dims["d_ff"])
    rng = np.random.default_rng(0)
    trace = make_trace(rng, n_requests, args.arrival_rate, prompt_lens,
                       gen_lens, mcfg.vocab_size)

    out = {"config": {**dims, "mesh": "2x4", "slots": args.slots,
                      "requests": n_requests, "arrival_rate": args.arrival_rate,
                      "prompt_lens": list(prompt_lens),
                      "gen_lens": list(gen_lens), "smoke": bool(args.smoke)},
           "variants": {}}
    outputs = {}
    for name, v in variants().items():
        r, toks = bench_variant(name, v["qsdp"], v["rowquant"], mcfg,
                                trace, args.slots)
        out["variants"][name] = r
        outputs[name] = toks
        print(f"{name:20s} {r['tokens_per_s']:8.1f} tok/s  "
              f"step {r['step_ms_mean']:7.1f}ms  "
              f"lat p50/p95 {r['latency_steps_p50']:.0f}/"
              f"{r['latency_steps_p95']:.0f} steps  "
              f"occ {r['mean_occupancy']:.2f}/{r['slots']}  "
              f"gather {r['gather_bytes_per_decode_step'] / 2**20:.2f} MiB/step")

    # equal-tokens guarantee: every variant decoded the same trace greedily;
    # the quantized variants may *sample different tokens* than f32 baseline
    # (different weights), but qsdp vs qsdp-rowquant-wire consume the SAME
    # quantized weights and must agree token-for-token.
    assert outputs["qsdp"] == outputs["qsdp-rowquant-wire"], \
        "rowquant-wire decode diverged from the dense-dequant qsdp decode"
    b = out["variants"]["baseline-fsdp"]["gather_bytes_per_decode_step"]
    q = out["variants"]["qsdp"]["gather_bytes_per_decode_step"]
    rq = out["variants"]["qsdp-rowquant-wire"]["gather_bytes_per_decode_step"]
    assert q < b and rq < b, (q, rq, b)
    out["summary"] = {
        "gather_bytes_ratio_qsdp_vs_baseline": q / b,
        "gather_bytes_ratio_rowquant_vs_baseline": rq / b,
        "rowquant_matches_qsdp_tokens": True,
        "tokens_equal_across_variants": all(
            sum(len(t) for t in v.values())
            == sum(len(t) for t in outputs["qsdp"].values())
            for v in outputs.values()),
    }
    print(f"qsdp ships {out['summary']['gather_bytes_ratio_qsdp_vs_baseline']:.3f}x "
          f"the baseline gather bytes per decode step at equal tokens")

    with open(args.out, "w") as f:
        json.dump(out, f, indent=1)
    print(f"wrote {args.out}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
