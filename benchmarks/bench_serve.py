"""Benchmark: continuous-batching serving under a synthetic arrival trace.

The serving analogue of bench_step.py.  On an emulated (2 data x 4 model)
8-device CPU mesh, a fixed pool of decode slots drains a DETERMINISTIC
synthetic request trace — seeded Poisson arrival gaps, mixed prompt and
generation lengths — through serve.ContinuousScheduler, for each wire
policy:

  baseline-fsdp        f32 weight gathers every decode step
  qsdp                 W8 quantized gathers (paper Section 5 wire format)
  qsdp-rowquant-wire   W8 gathers consumed in wire-code form by the fused
                       rowquant matmul (dense-MLP weights never dequantized
                       to HBM)
  qsdp-spec            self-speculative decode: a 4-bit rowquant
                       re-quantization of the SAME weights drafts 4
                       tokens/slot/step, the serving-precision model
                       verifies them in one pooled launch — committed
                       tokens are asserted bit-equal to the qsdp row,
                       with accepted_per_launch > 1 and
                       launches_per_token < 1 as CI tripwires

Decode is FSDP-style — every step re-gathers the sharded weights — so step
latency is collective-bound and the gather wire bytes per decode step are
the headline column: QSDP ships ~bits/32 of the baseline's bytes for the
same trace, slots, and per-request token counts.  (Baseline decodes f32
weights while the quantized variants decode quantized ones, so their
greedy TOKENS may differ; qsdp and qsdp-rowquant-wire consume the same
quantized weights and are asserted token-identical.)

A second LONG-PROMPT trace (many distinct prompt lengths, prompts several
times the chunk size) replays through the qsdp wire policy under both
admission paths:

  qsdp-longprompt      blocking whole-prompt admission (one jit retrace
                       per distinct prompt length; every admission stalls
                       live decode slots for the full prompt)
  qsdp-chunked         chunked, length-bucketed prefill (--prefill-chunk):
                       at most one chunk rides each scheduler step, jit
                       cache bounded at n_buckets traces

and the run ASSERTS the bounded-retrace guarantee (a regression back to
per-length retraces fails CI), the chunked slot-isolation invariant
(every chunked request's greedy tokens bit-match its solo batch-of-1 run
with the SAME chunk decomposition, generate(prefill_chunk=C,
fold_step_keys=False) — chunked and whole-prompt prefill are distinct
float paths, so each admission path is held to ITS solo reference), and
the bounded per-launch stall (max_prefill_launch_tokens <= the padded
chunk, vs the full prompt under blocking).

Per variant this reports
  * tokens/s over the timed replay (compile excluded via a warmup drain
    that covers every distinct prompt length / chunk bucket in the trace),
  * per-request latency (submit -> last token) p50/p95, in decode steps
    and in wall seconds, plus p95 time-to-first-token,
  * mean slot occupancy of the pool,
  * analytic per-decode-step weight-gather wire bytes per device,
  * prefill trace/launch counts and the per-launch stall bound,

and writes everything to BENCH_serve.json (uploaded as a CI artifact next
to BENCH_step.json).

Run:  PYTHONPATH=src python benchmarks/bench_serve.py --smoke
"""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
# ^ MUST precede every other import: jax locks the device count on first init.

import argparse
import json
import time

try:
    from . import bench_schema
except ImportError:  # run as a script: sys.path[0] is benchmarks/
    import bench_schema

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.core.qsdp import QSDPConfig
from repro.models.config import ModelConfig
from repro.serve import ContinuousScheduler, Request, build_serve_setup


def _round_floats(obj, ndigits=4):
    """Round every float in a JSON tree to `ndigits` decimals so the
    emitted artifact is stable to read and diff (no
    4.6499999999999995-style repr noise from ratio arithmetic)."""
    if isinstance(obj, float):
        return round(obj, ndigits)
    if isinstance(obj, dict):
        return {k: _round_floats(v, ndigits) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        return [_round_floats(v, ndigits) for v in obj]
    return obj


def variants():
    return {
        "baseline-fsdp": dict(qsdp=QSDPConfig.baseline(), rowquant=False),
        "qsdp": dict(qsdp=QSDPConfig(min_quant_size=256), rowquant=False),
        "qsdp-rowquant-wire": dict(qsdp=QSDPConfig(min_quant_size=256),
                                   rowquant=True),
    }


def make_trace(rng, n_requests, arrival_rate, prompt_lens, gen_lens, vocab,
               cycle_lens=False):
    """Deterministic synthetic load: (arrival_step, Request) pairs.  Arrival
    gaps are Poisson (exponential inter-arrival, rounded to decode steps);
    prompt/gen lengths cycle through mixed buckets.  cycle_lens=True walks
    prompt_lens round-robin instead of sampling, guaranteeing every
    distinct length appears (the long-prompt retrace assertions need
    that)."""
    trace = []
    step = 0
    for i in range(n_requests):
        step += int(rng.exponential(1.0 / arrival_rate))
        plen = int(prompt_lens[i % len(prompt_lens)] if cycle_lens
                   else rng.choice(prompt_lens))
        gen = int(rng.choice(gen_lens))
        trace.append((step, Request(
            rid=f"req{i:03d}", prompt=rng.integers(0, vocab, size=plen).tolist(),
            max_new_tokens=gen, seed=i)))
    return trace


def make_prefix_trace(rng, n_requests, arrival_rate, sys_len, tail_lens,
                      gen_lens, vocab):
    """Repeated-system-prompt load: every request's prompt = one fixed
    `sys_len`-token system prefix + a distinct random tail — the dominant
    real traffic shape, and the one the paged pool's prefix sharing is
    for."""
    system = rng.integers(0, vocab, size=sys_len).tolist()
    trace = []
    step = 0
    for i in range(n_requests):
        step += int(rng.exponential(1.0 / arrival_rate))
        tail = rng.integers(0, vocab,
                            size=int(tail_lens[i % len(tail_lens)])).tolist()
        trace.append((step, Request(
            rid=f"sys{i:03d}", prompt=system + tail,
            max_new_tokens=int(rng.choice(gen_lens)), seed=i)))
    return trace


def replay(sched, trace, max_steps=100_000):
    """Drive the scheduler through the arrival trace: requests are submitted
    when the scheduler's decode-step clock (relative to replay start)
    reaches their arrival step; an idle pool fast-forwards to the next
    arrival."""
    pending = list(trace)
    start = sched.step_count
    skipped = 0  # idle steps fast-forwarded on the virtual arrival clock
    t0 = time.perf_counter()
    steps = 0
    while pending or sched.queue or sched.n_active():
        clock = sched.step_count - start + skipped
        while pending and pending[0][0] <= clock:
            sched.submit(pending.pop(0)[1])
        if pending and not (sched.queue or sched.n_active()):
            # idle server: fast-forward the virtual clock to the next
            # arrival (later arrivals keep their relative gaps)
            skipped += pending[0][0] - clock
            continue
        sched.step()
        steps += 1
        assert steps < max_steps, "trace replay did not converge"
    return time.perf_counter() - t0


def bench_variant(name, qsdp, rowquant, mcfg, trace, slots,
                  prefill_chunk=0, prefill_buckets=4, kv_block_size=0,
                  kv_quant_bits=0, kv_quant_horizon=0, kv_prefix_share=True,
                  draft_bits=0, draft_depth=0):
    prompt_lens = sorted({len(r.prompt) for _, r in trace})
    gen0 = trace[0][1].max_new_tokens
    setup = build_serve_setup(
        mcfg, data_par=2, model_par=4, qsdp=qsdp, batch=slots,
        prompt_len=max(prompt_lens),
        gen=max(r.max_new_tokens for _, r in trace), rowquant_mlp=rowquant,
        kv_block_size=kv_block_size,
        draft_bits=draft_bits, draft_depth=draft_depth)
    sched = ContinuousScheduler(setup.model, setup.mesh, setup.spec,
                                setup.params,
                                gather_key=jax.random.PRNGKey(42),
                                prefill_chunk=prefill_chunk,
                                prefill_buckets=prefill_buckets,
                                kv_quant_bits=kv_quant_bits,
                                kv_quant_horizon=kv_quant_horizon,
                                kv_prefix_share=kv_prefix_share)

    # warmup: compile decode + one prefill per distinct prompt length
    # (blocking) / per chunk bucket (chunked: one prompt of each bucket
    # length, run one at a time so every bucket's launch compiles before
    # the timed replay); speculative variants warm at full generation
    # length so the deeper draft/verify launch shapes compile too
    warm_gen = gen0 if setup.spec.speculative else min(gen0, 2)
    t0 = time.perf_counter()
    if prefill_chunk:
        for j, blen in enumerate(sched.buckets):
            sched.submit(Request(rid=f"warm{j}",
                                 prompt=list(range(1, blen + 1)),
                                 max_new_tokens=warm_gen, seed=0))
            sched.run()
    else:
        for j, plen in enumerate(prompt_lens):
            sched.submit(Request(rid=f"warm{j}",
                                 prompt=list(range(1, plen + 1)),
                                 max_new_tokens=warm_gen, seed=0))
        sched.run()
    compile_s = time.perf_counter() - t0

    # timed replay (snapshot counters so warmup is excluded)
    base = sched.stats()
    wall_s = replay(sched, trace)
    st = sched.stats()
    done = {r.rid: sched.finished[r.rid] for _, r in trace}
    lat_steps = [c.finish_step - c.submit_step for c in done.values()]
    lat_s = [c.finish_time - c.submit_time for c in done.values()]
    ttft_s = [c.first_token_time - c.submit_time for c in done.values()]
    tokens = st["tokens_generated"] - base["tokens_generated"]
    steps = st["decode_steps"] - base["decode_steps"]
    occ = ((st["lane_steps"] - base["lane_steps"]) / max(steps, 1))
    # launch accounting over the timed replay only (warmup deltas out),
    # normalized per lane so it is batch-composition independent: 1.0 =
    # one serving-precision lane-step per decoded token (non-speculative
    # decode by construction), < 1.0 = speculation committing > 1
    dec_tokens = max(1, tokens - (st["prefills"] - base["prefills"]))
    lpt = (st["lane_steps"] - base["lane_steps"]) / dec_tokens
    spec_ls = st["spec_lane_steps"] - base["spec_lane_steps"]
    apl = ((st["spec_tokens"] - base["spec_tokens"]) / spec_ls
           if spec_ls else 0.0)
    draft_oh = (st["draft_lane_steps"] - base["draft_lane_steps"]) / dec_tokens
    return {
        "compile_s": round(compile_s, 1),
        "wall_s": round(wall_s, 2),
        "tokens": int(tokens),
        "tokens_per_s": round(tokens / wall_s, 2),
        "decode_steps": int(steps),
        "step_ms_mean": round(1e3 * wall_s / max(steps, 1), 2),
        "latency_steps_p50": float(np.percentile(lat_steps, 50)),
        "latency_steps_p95": float(np.percentile(lat_steps, 95)),
        "latency_s_p50": round(float(np.percentile(lat_s, 50)), 3),
        "latency_s_p95": round(float(np.percentile(lat_s, 95)), 3),
        "ttft_s_p95": round(float(np.percentile(ttft_s, 95)), 3),
        "mean_occupancy": round(occ, 2),
        "slots": slots,
        "launches_per_token": round(lpt, 4),
        "accepted_per_launch": round(apl, 4),
        "draft_overhead": round(draft_oh, 4),
        "draft_launches": int(st["draft_launches"] - base["draft_launches"]),
        "verify_launches": int(st["verify_launches"]
                               - base["verify_launches"]),
        "gather_bytes_per_decode_step": int(setup.decode_gather_bytes()),
        "prefill_chunk": prefill_chunk,
        "prefill_traces": int(st["prefill_traces"]),
        "prefill_launches": int((st["prefill_chunks"] or st["prefills"])
                                - (base["prefill_chunks"] or base["prefills"])),
        "max_prefill_launch_tokens": int(st["max_prefill_launch_tokens"]),
        # paged-pool columns (0 / 0.0 under ring serving)
        "blocks_in_use": int(st.get("blocks_in_use", 0)),
        "blocks_cached": int(st.get("blocks_cached", 0)),
        "prefix_hit_rate": round(float(st.get("prefix_hit_rate", 0.0)), 3),
        "effective_capacity": float(st.get("effective_capacity", 0.0)),
        "cold_blocks": int(st.get("cold_blocks", 0)),
        "cold_bytes": int(st.get("cold_bytes", 0)),
        "hot_block_bytes": int(st.get("hot_block_bytes", 0)),
        "cold_compression": round(float(st.get("cold_compression", 1.0)), 2),
        "cow_forks": int(st.get("cow_forks", 0)),
        "demotions": int(st.get("demotions", 0)),
        "rehydrations": int(st.get("rehydrations", 0)),
    }, {rid: c.tokens.tolist() for rid, c in done.items()}, sched


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny config for CI (fast compile, short trace)")
    ap.add_argument("--requests", type=int, default=None)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--arrival-rate", type=float, default=1.5,
                    help="mean arrivals per decode step")
    ap.add_argument("--prefill-chunk", type=int, default=8,
                    help="chunk size for the qsdp-chunked long-prompt row")
    ap.add_argument("--prefill-buckets", type=int, default=4)
    ap.add_argument("--out", default="BENCH_serve.json")
    args = ap.parse_args(argv)

    if args.smoke:
        dims = dict(n_layers=2, d_model=128, d_ff=256)
        n_requests = args.requests or 8
        prompt_lens, gen_lens = (8, 12), (3, 4, 6)
        # long-prompt trace: >= 8 distinct lengths, prompts several chunks
        # long — the retrace + head-of-line-blocking regime
        long_lens, long_n = tuple(range(9, 17)), 8
        # repeated-system-prompt trace (paged prefix sharing)
        sys_len, tail_lens, sys_n = 16, (3, 5, 7, 9, 11, 13), 8
    else:
        dims = dict(n_layers=4, d_model=256, d_ff=512)
        n_requests = args.requests or 24
        prompt_lens, gen_lens = (16, 32, 48), (8, 16, 24)
        long_lens, long_n = tuple(range(33, 64, 3)), 16
        sys_len, tail_lens, sys_n = 32, tuple(range(5, 40, 5)), 12
    kv_bs = 8  # paged block size (divides sys_len and the chunk size)

    mcfg = ModelConfig(name="bench-serve", arch_type="dense",
                       n_layers=dims["n_layers"], d_model=dims["d_model"],
                       vocab_size=512, n_heads=8, n_kv_heads=4,
                       head_dim=dims["d_model"] // 8, d_ff=dims["d_ff"])
    rng = np.random.default_rng(0)
    trace = make_trace(rng, n_requests, args.arrival_rate, prompt_lens,
                       gen_lens, mcfg.vocab_size)

    out = {"config": {**dims, "mesh": "2x4", "slots": args.slots,
                      "requests": n_requests, "arrival_rate": args.arrival_rate,
                      "prompt_lens": list(prompt_lens),
                      "gen_lens": list(gen_lens),
                      "long_prompt_lens": list(long_lens),
                      "prefill_chunk": args.prefill_chunk,
                      "prefill_buckets": args.prefill_buckets,
                      "kv_block_size": kv_bs, "sys_prompt_len": sys_len,
                      "smoke": bool(args.smoke)},
           "variants": {}}
    outputs = {}

    def show(name, r):
        spec = (f"  acc/launch {r['accepted_per_launch']:.2f}  "
                f"draft-oh {r['draft_overhead']:.2f}"
                if r["verify_launches"] else "")
        print(f"{name:20s} {r['tokens_per_s']:8.1f} tok/s  "
              f"step {r['step_ms_mean']:7.1f}ms  "
              f"lat p50/p95 {r['latency_steps_p50']:.0f}/"
              f"{r['latency_steps_p95']:.0f} steps  "
              f"ttft p95 {r['ttft_s_p95']:.3f}s  "
              f"occ {r['mean_occupancy']:.2f}/{r['slots']}  "
              f"l/tok {r['launches_per_token']:.2f}  "
              f"pf {r['prefill_traces']} traces/"
              f"{r['max_prefill_launch_tokens']} tok-stall  "
              f"gather {r['gather_bytes_per_decode_step'] / 2**20:.2f} "
              f"MiB/step{spec}")

    for name, v in variants().items():
        r, toks, _ = bench_variant(name, v["qsdp"], v["rowquant"], mcfg,
                                   trace, args.slots)
        out["variants"][name] = r
        outputs[name] = toks
        show(name, r)

    # self-speculative decoding over the SAME trace and qsdp wire policy:
    # the 4-bit rowquant re-quantization of the serving weights drafts 4
    # tokens per slot per step, the serving-precision model verifies them
    # in one pooled launch.  CI tripwires: committed tokens bit-equal the
    # non-speculative qsdp row (speculation is a pure launch-count
    # optimization), > 1 token committed per verify launch, and < 1
    # serving-precision lane-step per decoded token.
    r, toks, _ = bench_variant("qsdp-spec", QSDPConfig(min_quant_size=256),
                               False, mcfg, trace, args.slots,
                               draft_bits=4, draft_depth=4)
    out["variants"]["qsdp-spec"] = r
    outputs["qsdp-spec"] = toks
    show("qsdp-spec", r)
    assert outputs["qsdp-spec"] == outputs["qsdp"], \
        "speculative decode changed a request's committed tokens"
    assert r["accepted_per_launch"] > 1, r["accepted_per_launch"]
    assert r["launches_per_token"] < 1, r["launches_per_token"]

    # long-prompt trace: blocking vs chunked admission over the SAME qsdp
    # wire policy (chunked is the fix for per-length retraces + prefill
    # head-of-line blocking, so this is where its columns mean something)
    long_trace = make_trace(np.random.default_rng(1), long_n,
                            args.arrival_rate, long_lens, gen_lens,
                            mcfg.vocab_size, cycle_lens=True)
    for name, chunk in (("qsdp-longprompt", 0),
                        ("qsdp-chunked", args.prefill_chunk)):
        r, toks, _ = bench_variant(name, QSDPConfig(min_quant_size=256), False,
                                   mcfg, long_trace, args.slots,
                                   prefill_chunk=chunk,
                                   prefill_buckets=args.prefill_buckets)
        out["variants"][name] = r
        outputs[name] = toks
        show(name, r)

    # equal-tokens guarantee: every variant decoded the same trace greedily;
    # the quantized variants may *sample different tokens* than f32 baseline
    # (different weights), but qsdp vs qsdp-rowquant-wire consume the SAME
    # quantized weights and must agree token-for-token.
    assert outputs["qsdp"] == outputs["qsdp-rowquant-wire"], \
        "rowquant-wire decode diverged from the dense-dequant qsdp decode"
    b = out["variants"]["baseline-fsdp"]["gather_bytes_per_decode_step"]
    q = out["variants"]["qsdp"]["gather_bytes_per_decode_step"]
    rq = out["variants"]["qsdp-rowquant-wire"]["gather_bytes_per_decode_step"]
    assert q < b and rq < b, (q, rq, b)

    # chunked-admission contract on the long-prompt trace (CI tripwires):
    # slot isolation — every chunked request's greedy tokens bit-match its
    # solo batch-of-1 run with the SAME chunk decomposition; jit cache
    # bounded by the bucket count even though the trace has len(long_lens)
    # distinct prompt lengths (blocking compiles one trace per length — a
    # regression back to that fails here); and a live slot never stalls
    # behind more than one padded chunk of prefill.
    blk = out["variants"]["qsdp-longprompt"]
    chk = out["variants"]["qsdp-chunked"]
    solo_setup = build_serve_setup(
        mcfg, data_par=2, model_par=4, qsdp=QSDPConfig(min_quant_size=256),
        batch=1, prompt_len=max(long_lens),
        gen=max(r.max_new_tokens for _, r in long_trace),
        batch_sharded=False)
    for _, req in long_trace:
        ref = np.asarray(jax.device_get(solo_setup.engine.generate(
            solo_setup.params,
            {"tokens": jnp.asarray(np.asarray(req.prompt, np.int32)[None])},
            {"tokens": P(None)}, n_tokens=req.max_new_tokens,
            key=jax.random.PRNGKey(42), fold_step_keys=False,
            prefill_chunk=args.prefill_chunk,
            prefill_buckets=args.prefill_buckets)))[0].tolist()
        assert outputs["qsdp-chunked"][req.rid] == ref, \
            f"chunked {req.rid} diverged from its solo chunked run"
    assert chk["prefill_traces"] <= args.prefill_buckets, \
        (chk["prefill_traces"], args.prefill_buckets)
    assert blk["prefill_traces"] == len(long_lens), blk["prefill_traces"]
    if args.prefill_buckets < len(long_lens):
        # the headline guarantee — fewer compiled prefill shapes than
        # distinct prompt lengths (vacuous if the CLI raised the bucket
        # count past the trace's length diversity)
        assert chk["prefill_traces"] < blk["prefill_traces"], (chk, blk)
    chunk_top = min(args.prefill_chunk, solo_setup.spec.cache_len)
    assert chk["max_prefill_launch_tokens"] <= chunk_top, (chk, chunk_top)
    if chunk_top < max(long_lens):
        # a live slot stalls behind at most one padded chunk, strictly less
        # than the blocking path's full-prompt launches (vacuous if the CLI
        # chunk covers the longest prompt)
        assert (chk["max_prefill_launch_tokens"]
                < blk["max_prefill_launch_tokens"]), (chk, blk)

    # paged KV pool on a repeated-system-prompt trace: sharing OFF vs ON
    # over the SAME paged float path (block indirection preserves every
    # value, so the A/B isolates the prefix cache), then the quantized cold
    # tier on top.  CI tripwires: sharing engages (hit rate > 0, fewer
    # prefill launches at identical tokens) and the cold tier re-encodes
    # idle prefix blocks at ~4x fewer resident bytes, tokens unchanged.
    sys_trace = make_prefix_trace(np.random.default_rng(2), sys_n,
                                  args.arrival_rate / 3, sys_len, tail_lens,
                                  gen_lens, mcfg.vocab_size)
    paged_rows = {}
    for name, share, qbits in (("qsdp-paged-noshare", False, 0),
                               ("qsdp-paged", True, 0),
                               ("qsdp-paged-cold", True, 4)):
        r, toks, sched = bench_variant(
            name, QSDPConfig(min_quant_size=256), False, mcfg, sys_trace,
            args.slots, prefill_chunk=args.prefill_chunk,
            prefill_buckets=args.prefill_buckets, kv_block_size=kv_bs,
            kv_prefix_share=share, kv_quant_bits=qbits,
            kv_quant_horizon=16 if qbits else 0)
        out["variants"][name] = r
        outputs[name] = toks
        paged_rows[name] = (r, sched)
        show(name, r)
    nosh = out["variants"]["qsdp-paged-noshare"]
    shr = out["variants"]["qsdp-paged"]
    assert outputs["qsdp-paged"] == outputs["qsdp-paged-noshare"], \
        "prefix sharing changed a request's tokens"
    assert shr["prefix_hit_rate"] > 0, shr
    assert shr["prefill_launches"] < nosh["prefill_launches"], (shr, nosh)
    assert outputs["qsdp-paged-cold"] == outputs["qsdp-paged"], \
        "the quantized cold tier changed a request's tokens"

    # cold-tier capacity: the replay itself never demotes (the horizon
    # outlasts any mid-replay idle gap, which is why the token equality
    # above is exact).  Now idle the retired system blocks past the horizon
    # with a filler request, demote them into wire codes (~4x fewer
    # resident bytes), then resubmit the system prompt twice: the first hit
    # rehydrates from the cold store (rehydrations > 0); the second reads
    # the same rehydrated block hot and must reproduce the first's tokens
    # bit-for-bit — a demoted prefix serves DETERMINISTIC streams (the
    # codec is lossy 4-bit QDQ, so the rehydrated stream is its own
    # reference, not the full-precision row's).
    sched_cold = paged_rows["qsdp-paged-cold"][1]
    sched_cold.submit(Request(rid="cold-filler", prompt=[7, 8, 9],
                              max_new_tokens=24, seed=0))
    sched_cold.run()
    st_cold = sched_cold.stats()
    assert st_cold["demotions"] > 0, st_cold
    assert st_cold["cold_blocks"] > 0, st_cold
    hot_resident = st_cold["hot_block_bytes"] * st_cold["cold_blocks"]
    cold_ratio = hot_resident / max(st_cold["cold_bytes"], 1)
    assert cold_ratio > 3.0, (hot_resident, st_cold["cold_bytes"])
    req0 = sys_trace[0][1]
    redo = []
    for rid in ("cold-re", "cold-re2"):
        sched_cold.submit(Request(rid=rid, prompt=req0.prompt,
                                  max_new_tokens=req0.max_new_tokens,
                                  seed=req0.seed))
        redo.append(sched_cold.run()[rid].tokens.tolist())
    st_cold = sched_cold.stats()
    assert st_cold["rehydrations"] > 0, st_cold
    assert redo[0] == redo[1], \
        "rehydrated prefix served two identical requests different tokens"
    sched_cold.pool.check_invariants()
    out["variants"]["qsdp-paged-cold"].update(
        cold_blocks=int(st_cold["cold_blocks"]),
        cold_bytes=int(st_cold["cold_bytes"]),
        demotions=int(st_cold["demotions"]),
        rehydrations=int(st_cold["rehydrations"]),
        cold_compression=round(cold_ratio, 2))

    out["summary"] = {
        "gather_bytes_ratio_qsdp_vs_baseline": q / b,
        "gather_bytes_ratio_rowquant_vs_baseline": rq / b,
        "rowquant_matches_qsdp_tokens": True,
        "tokens_equal_across_variants": all(
            sum(len(t) for t in v.values())
            == sum(len(t) for t in outputs["qsdp"].values())
            for v in (outputs[k] for k in variants())),
        "chunked_matches_solo_chunked_tokens": True,
        "chunked_prefill_traces": chk["prefill_traces"],
        "blocking_prefill_traces": blk["prefill_traces"],
        "chunked_max_prefill_launch_tokens": chk["max_prefill_launch_tokens"],
        "blocking_max_prefill_launch_tokens": blk["max_prefill_launch_tokens"],
        "ttft_p95_ratio_chunked_vs_blocking": (
            round(chk["ttft_s_p95"] / max(blk["ttft_s_p95"], 1e-9), 3)),
        "paged_matches_noshare_tokens": True,
        "paged_prefix_hit_rate": shr["prefix_hit_rate"],
        "paged_prefill_launches": shr["prefill_launches"],
        "noshare_prefill_launches": nosh["prefill_launches"],
        "cold_matches_paged_tokens": True,
        "cold_compression": round(cold_ratio, 2),
        "cold_blocks": int(st_cold["cold_blocks"]),
        "spec_matches_qsdp_tokens": True,
        "spec_accepted_per_launch": out["variants"]["qsdp-spec"][
            "accepted_per_launch"],
        "spec_launches_per_token": out["variants"]["qsdp-spec"][
            "launches_per_token"],
        "spec_draft_overhead": out["variants"]["qsdp-spec"]["draft_overhead"],
    }
    print(f"qsdp ships {out['summary']['gather_bytes_ratio_qsdp_vs_baseline']:.3f}x "
          f"the baseline gather bytes per decode step at equal tokens")
    print(f"chunked prefill: {chk['prefill_traces']} traces vs "
          f"{blk['prefill_traces']} blocking for {len(long_lens)} distinct "
          f"prompt lengths; per-launch stall {chk['max_prefill_launch_tokens']}"
          f" vs {blk['max_prefill_launch_tokens']} tokens; "
          f"ttft p95 {chk['ttft_s_p95']:.3f}s vs {blk['ttft_s_p95']:.3f}s")
    print(f"paged pool: prefix hit rate {shr['prefix_hit_rate']:.2f}, "
          f"{shr['prefill_launches']} prefill launches vs "
          f"{nosh['prefill_launches']} unshared at identical tokens; cold "
          f"tier holds {st_cold['cold_blocks']} blocks at "
          f"{cold_ratio:.1f}x fewer resident bytes")
    sp = out["variants"]["qsdp-spec"]
    print(f"speculative: {sp['accepted_per_launch']:.2f} tokens/verify "
          f"launch, {sp['launches_per_token']:.2f} launches/token "
          f"(draft overhead {sp['draft_overhead']:.2f}) at tokens bit-equal "
          f"to non-speculative qsdp")

    doc = _round_floats(bench_schema.stamp(out))
    bench_schema.validate_bench_serve(doc)
    with open(args.out, "w") as f:
        json.dump(doc, f, indent=1)
    print(f"wrote {args.out}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
