"""Benchmark: end-to-end train-step cost of the wire formats.

Compares, on an emulated (4 data x 2 model) 8-device CPU mesh (Pallas
kernels in interpret mode — the *structure* of the compiled program is what
matters here, the absolute ms are CPU numbers):

  baseline-fsdp            fp32 weights / bf16 grads, per-tensor launches
  qsdp                     W8G8, per-tensor launches (3 per quantized tensor)
  qsdp-coalesced           W8G8, ONE u8 launch per layer gather / RS
  qsdp-coalesced-prefetch  + double-buffered layer prefetch pipeline
  qsdp-autoplan            W8G8 under the repro.tune cost-model policy:
                           coalesce only layers whose gathered wire buffer
                           stays under coalesce_max_bytes — on this mesh
                           that falls back to per-tensor everywhere (the
                           coalesced small-scale regression fix)

For each variant this measures
  * per-step wall ms (median over --steps timed steps after a warmup),
  * HLO collective-launch counts (trip-count-aware, per kind and per
    operand dtype, via roofline.hlo_analyzer),
  * HLO collective wire bytes + the engine's analytic per-step wire bytes,
  * the analytic per-layer gather launch count (3 x n_params -> 1),
  * train-state bytes (total + per-device) and checkpoint payload bytes,
    so BENCH_step.json tracks the quantized-state memory win,

and writes everything to BENCH_step.json (uploaded as a CI artifact by the
workflow, so the perf trajectory accumulates across commits).

``--quantized-state`` adds the qsdp-quantized-state row: the coalesced
schedule with the train state resting in packed wire-code form
(QuantizedParam masters + 8-bit Adam moments, ckpt format v2).

Run:  PYTHONPATH=src python benchmarks/bench_step.py --smoke --quantized-state
"""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
# ^ MUST precede every other import: jax locks the device count on first init.

import argparse
import dataclasses
import json
import tempfile
import time

try:
    from . import bench_schema
except ImportError:  # run as a script: sys.path[0] is benchmarks/
    import bench_schema

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.qsdp import MeshSpec, QSDPConfig, layer_gather_launches, step_comm_bytes
from repro.tune.cost_model import CPU_SMOKE, plan_layer_policies
from repro.data import SyntheticLM
from repro.models.config import ModelConfig
from repro.models.transformer import Model
from repro.optim import AdamWConfig, make_adamw
from repro.roofline.hlo_analyzer import analyze_hlo
from repro.train.checkpoint import checkpoint_payload_bytes, save_checkpoint
from repro.train.step import (init_train_state, make_jitted_train_step,
                              quantize_train_state)


def _round_floats(obj, ndigits=4):
    """Round every float in the output tree (stable artifact diffs)."""
    if isinstance(obj, float):
        return round(obj, ndigits)
    if isinstance(obj, dict):
        return {k: _round_floats(v, ndigits) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        return [_round_floats(v, ndigits) for v in obj]
    return obj


def autoplan_config(mcfg, ms) -> tuple[QSDPConfig, int]:
    """The deployment-plan policy for this bench's mesh: per-layer coalesce
    decisions from the repro.tune cost model (cpu-smoke preset), expressed
    as the coalesce_max_bytes threshold.  On the tiny CPU mesh every layer
    buffer exceeds the crossover, so the policy falls back to per-tensor
    gathers — the coalesced small-scale regression fix, bit-exact by
    construction (both paths draw identical per-tensor quantization keys)."""
    probe = Model(mcfg, ms,
                  QSDPConfig(coalesce=True, min_quant_size=256)).engine
    _, thresh = plan_layer_policies(probe, CPU_SMOKE)
    return QSDPConfig(coalesce=True, coalesce_max_bytes=thresh), thresh


def variants(mcfg, ms, quantized_state=False):
    v = {
        "baseline-fsdp": QSDPConfig.baseline(),
        "qsdp": QSDPConfig(coalesce=False),
        "qsdp-coalesced": QSDPConfig(coalesce=True),
        "qsdp-coalesced-prefetch": QSDPConfig(coalesce=True, prefetch=True),
        "qsdp-autoplan": autoplan_config(mcfg, ms)[0],
    }
    if quantized_state:
        # train state rests as packed wire codes: QuantizedParam masters
        # + 8-bit Adam moments (checkpoint format v2)
        v["qsdp-quantized-state"] = QSDPConfig(coalesce=True)
    return v


def state_and_ckpt_bytes(state, n_devices):
    """Exact train-state bytes (device arrays) + checkpoint payload bytes."""
    total = sum(l.nbytes for l in jax.tree.leaves(state))
    with tempfile.TemporaryDirectory() as td:
        save_checkpoint(td, state)
        ckpt = sum(checkpoint_payload_bytes(td).values())
    return {"train_state_bytes": int(total),
            "train_state_bytes_per_device": int(total) // n_devices,
            "ckpt_payload_bytes": int(ckpt)}


def bench_variant(name, qcfg, mcfg, mesh, ms, batch, n_micro, steps):
    qcfg = dataclasses.replace(qcfg, min_quant_size=256)
    quantized_state = name == "qsdp-quantized-state"
    model = Model(mcfg, ms, qcfg)
    opt = make_adamw(AdamWConfig(lr=1e-3,
                                 moment_bits=8 if quantized_state else None))
    state = init_train_state(model, opt, jax.random.PRNGKey(0))
    if quantized_state:
        state = quantize_train_state(state, model, jax.random.PRNGKey(1))
    step = make_jitted_train_step(model, opt, mesh, n_micro=n_micro,
                                  quantized_state=quantized_state)

    key = jax.random.PRNGKey(7)
    with mesh:
        t0 = time.perf_counter()
        lowered = step.lower(state, batch, key)
        compiled = lowered.compile()
        compile_s = time.perf_counter() - t0
        hlo = analyze_hlo(compiled.as_text())

        state, metrics = step(state, batch, key)  # warmup (donated state)
        float(metrics["loss"])
        times = []
        for i in range(steps):
            t0 = time.perf_counter()
            state, metrics = step(state, batch, jax.random.fold_in(key, i))
            float(metrics["loss"])  # forces completion
            times.append(1e3 * (time.perf_counter() - t0))

    layer_names = [n for n in model.specs if n.startswith("layers/")]
    comm = step_comm_bytes(model.engine, gathers_per_param=2 * n_micro,
                           reduces_per_param=n_micro)
    counts = hlo["collectives"]["counts"]
    mem = state_and_ckpt_bytes(state, len(mesh.devices.flat))
    return {
        **mem,
        "compile_s": float(compile_s),
        "step_ms_median": float(np.median(times)),
        "step_ms_all": [float(t) for t in times],
        "loss_final": float(metrics["loss"]),
        "layer_gather_launches_analytic": layer_gather_launches(
            model.engine, layer_names),
        "wire_bytes_analytic_per_step": comm,
        "hlo_collective_bytes": hlo["collectives"]["total"],
        "hlo_collective_launches": counts,
        "hlo_launches_by_dtype": hlo["collectives"]["counts_by_dtype"],
    }


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny config for CI (fast compile, 3 timed steps)")
    ap.add_argument("--quantized-state", action="store_true",
                    help="add the qsdp-quantized-state row (packed masters "
                         "+ 8-bit moments)")
    ap.add_argument("--steps", type=int, default=None)
    ap.add_argument("--out", default="BENCH_step.json")
    args = ap.parse_args(argv)

    if args.smoke:
        dims = dict(n_layers=2, d_model=128, d_ff=256, seq=32, batch=8, micro=1)
        steps = args.steps or 3
    else:
        dims = dict(n_layers=4, d_model=256, d_ff=512, seq=64, batch=8, micro=2)
        steps = args.steps or 10

    mesh = jax.make_mesh((4, 2), ("data", "model"))
    ms = MeshSpec(axes=("data", "model"), shape=(4, 2))
    mcfg = ModelConfig(name="bench", arch_type="dense", n_layers=dims["n_layers"],
                       d_model=dims["d_model"], vocab_size=512, n_heads=8,
                       n_kv_heads=4, head_dim=dims["d_model"] // 8,
                       d_ff=dims["d_ff"])
    data = SyntheticLM(vocab_size=512, seq_len=dims["seq"],
                       global_batch=dims["batch"], seed=1)
    tokens, labels = data.sample(0)
    batch = {"tokens": tokens, "labels": labels}

    out = {"config": {**dims, "mesh": "4x2", "steps": steps,
                      "smoke": bool(args.smoke),
                      "autoplan_coalesce_max_bytes":
                          autoplan_config(mcfg, ms)[1]},
           "variants": {}}
    for name, qcfg in variants(mcfg, ms, args.quantized_state).items():
        r = bench_variant(name, qcfg, mcfg, mesh, ms, batch, dims["micro"], steps)
        out["variants"][name] = r
        c = r["hlo_collective_launches"]
        print(f"{name:24s} step {r['step_ms_median']:8.1f}ms  "
              f"launches/layer-gather {r['layer_gather_launches_analytic']:2d}  "
              f"HLO ag={c['all-gather']} a2a={c['all-to-all']} "
              f"rs={c['reduce-scatter']} ar={c['all-reduce']}  "
              f"wire {r['wire_bytes_analytic_per_step']['total'] / 2**20:.2f}MB  "
              f"state {r['train_state_bytes'] / 2**20:.2f}MB "
              f"ckpt {r['ckpt_payload_bytes'] / 2**20:.2f}MB")

    base = out["variants"]["qsdp"]
    co = out["variants"]["qsdp-coalesced"]
    ap_row = out["variants"]["qsdp-autoplan"]
    out["summary"] = {
        "ag_launch_reduction": (base["hlo_collective_launches"]["all-gather"]
                                / max(co["hlo_collective_launches"]["all-gather"], 1)),
        "wire_bytes_ratio_co_vs_per_tensor": (
            co["wire_bytes_analytic_per_step"]["total"]
            / base["wire_bytes_analytic_per_step"]["total"]),
        "autoplan_vs_qsdp_step_ratio": (ap_row["step_ms_median"]
                                        / base["step_ms_median"]),
        "autoplan_vs_coalesced_step_ratio": (ap_row["step_ms_median"]
                                             / co["step_ms_median"]),
    }
    print(f"autoplan: {out['summary']['autoplan_vs_qsdp_step_ratio']:.3f}x "
          f"plain qsdp, {out['summary']['autoplan_vs_coalesced_step_ratio']:.3f}x "
          f"always-coalesced (threshold "
          f"{out['config']['autoplan_coalesce_max_bytes']} B)")
    if "qsdp-quantized-state" in out["variants"]:
        qs = out["variants"]["qsdp-quantized-state"]
        out["summary"]["state_bytes_ratio_qstate_vs_f32"] = (
            qs["train_state_bytes"] / co["train_state_bytes"])
        out["summary"]["ckpt_bytes_ratio_qstate_vs_f32"] = (
            qs["ckpt_payload_bytes"] / co["ckpt_payload_bytes"])
        print(f"quantized state: {out['summary']['state_bytes_ratio_qstate_vs_f32']:.3f}x "
              f"train-state bytes, {out['summary']['ckpt_bytes_ratio_qstate_vs_f32']:.3f}x "
              f"checkpoint bytes vs f32")
    print(f"coalescing: {out['summary']['ag_launch_reduction']:.1f}x fewer "
          f"all-gather launches at {out['summary']['wire_bytes_ratio_co_vs_per_tensor']:.3f}x "
          f"the wire bytes")

    doc = _round_floats(bench_schema.stamp(out))
    bench_schema.validate_bench_step(doc)
    with open(args.out, "w") as f:
        json.dump(doc, f, indent=1)
    print(f"wrote {args.out}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
