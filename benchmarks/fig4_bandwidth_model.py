"""Benchmark: Figure 4 / Table 5 / Figure 6 — step time vs inter-node
bandwidth, with and without QSDP.

This container cannot measure multi-node wall time, so this is the
*analytic* communication model over the exact wire-byte accounting of the
engine (core.qsdp.step_comm_bytes — the same byte counts observed in the
compiled dry-run HLO):

  step(bw) = t_compute + wire_bytes_per_gpu / bw_per_gpu

Part A reproduces the paper's setup: GPT-{125M,350M,1.3B}, 4 nodes x 8
V100s (pure FSDP, no TP), weights fp32 / grads fp16 baseline vs QSDP
W8G8/W4G4; bandwidths 10/50/100 Gbps.  t_compute is calibrated from the
paper's own no-communication step time for the 1.3B model (~13.2s, Table 5
ideal-scaling line) scaled by model FLOPs.

Validated claims:
  * baseline step time grows sharply as bandwidth drops (bw bottleneck);
  * QSDP W8G8 step time is ~constant across 10-100 Gbps (Fig 4);
  * end-to-end speedup at 10 Gbps is ~2x for the 1.3B model (paper: 2.2x);
  * weight compression matters more than gradient compression (Table 5).

Part B applies the same model to this repo's TPU meshes using the
multi-pod dry-run's parsed collective bytes (results/dryrun_*.jsonl),
sweeping the pod-to-pod (DCN) bandwidth.
"""
from __future__ import annotations

import argparse
import json
import os

from repro import configs
from repro.core.qsdp import MeshSpec, QSDPConfig, QSDPEngine, step_comm_bytes
from repro.models.transformer import Model


def paper_cluster_bytes(arch: str, qsdp: QSDPConfig) -> int:
    """Per-GPU wire bytes of one step on the paper's 32-GPU pure-FSDP
    cluster (grad accumulation 4 => 4x weight gathers per optimizer step
    ... the paper's App. B observes ~5 weight transmissions per gradient
    exchange; we model the FSDP schedule: 2 AG per microbatch fwd+bwd, 1 RS
    per microbatch)."""
    ms = MeshSpec(axes=("data", "model"), shape=(32, 1))
    model = Model(configs.get_config(arch), ms, qsdp)
    n_micro = 4
    b = step_comm_bytes(model.engine, gathers_per_param=2 * n_micro,
                        reduces_per_param=n_micro)
    return b["total"]


POLICIES = {
    "baseline (W:fp32 G:fp16)": QSDPConfig.baseline(),
    "QSDP W8G8": QSDPConfig(),
    "QSDP W4G4": QSDPConfig(weight_bits=4, grad_bits=4),
    "QSDP W8 G:fp16": QSDPConfig(quantize_grads=False),
    "QSDP G8 W:fp32": QSDPConfig(quantize_weights=False),
    # bf16 per-bucket (scale, zero) metadata on the wire: shaves the
    # metadata half of the overhead (meta_wire_dtype knob; wire-byte
    # accounting picks it up via QuantConfig.meta_bytes)
    "QSDP W8G8 bf16-meta": QSDPConfig(meta_wire_dtype="bfloat16"),
    # 4-bit codes amplify the relative metadata cost -> bf16 meta helps more
    "QSDP W4G4 bf16-meta": QSDPConfig(weight_bits=4, grad_bits=4,
                                      meta_wire_dtype="bfloat16"),
}

# paper-calibrated compute seconds per optimizer step (V100 cluster)
T_COMPUTE = {"gpt-125m": 1.6, "gpt-350m": 4.2, "gpt-1.3b": 13.2}
BWS_GBPS = (10, 50, 100)


def main(argv=None, out_dir="results/bench"):
    ap = argparse.ArgumentParser()
    ap.add_argument("--dryrun-json", default="results/dryrun_qsdp.jsonl")
    args = ap.parse_args(argv)
    os.makedirs(out_dir, exist_ok=True)

    out = {"paper_cluster": {}, "tpu_pods": {}}
    print("# Part A: paper cluster (4x8 V100, pure FSDP), step seconds")
    speedup_13b_10g = None
    for arch in ("gpt-125m", "gpt-350m", "gpt-1.3b"):
        rows = {}
        for tag, pol in POLICIES.items():
            byts = paper_cluster_bytes(arch, pol)
            times = {}
            for bw in BWS_GBPS:
                bw_gpu = bw * 1e9 / 8 / 8  # node bw shared by 8 GPUs, bits->bytes
                times[bw] = T_COMPUTE[arch] + byts / bw_gpu
            rows[tag] = dict(wire_mb=byts / 2**20, **{f"t{bw}": times[bw] for bw in BWS_GBPS})
        out["paper_cluster"][arch] = rows
        print(f"\n{arch}: per-GPU wire MB + step time @10/50/100 Gbps")
        for tag, r in rows.items():
            print(f"  {tag:24s} {r['wire_mb']:9.1f}MB  "
                  + "  ".join(f"{r[f't{bw}']:7.2f}s" for bw in BWS_GBPS))
        if arch == "gpt-1.3b":
            speedup_13b_10g = rows["baseline (W:fp32 G:fp16)"]["t10"] / rows["QSDP W8G8"]["t10"]
            q = rows["QSDP W8G8"]
            flat = q["t10"] / q["t100"]
            print(f"  -> 1.3B @10Gbps speedup QSDP vs baseline: {speedup_13b_10g:.2f}x "
                  f"(paper: 2.2x); QSDP t10/t100 = {flat:.3f} (paper: ~1.0)")

    # weight-vs-grad compression dominance (Table 5 shape)
    b13 = out["paper_cluster"]["gpt-1.3b"]
    w_only = b13["QSDP W8 G:fp16"]["t10"]
    g_only = b13["QSDP G8 W:fp32"]["t10"]
    print(f"\nweight-compression-only t@10G = {w_only:.2f}s < "
          f"grad-compression-only {g_only:.2f}s: "
          f"{'PASS' if w_only < g_only else 'FAIL'} (Table 5 / App. B)")

    # ---- Part B: TPU pods from the dry-run ----
    if os.path.exists(args.dryrun_json):
        import collections
        base_f = args.dryrun_json.replace("qsdp", "baseline")
        rows = []
        for f in (args.dryrun_json, base_f):
            if os.path.exists(f):
                with open(f) as fh:
                    rows += [json.loads(l) for l in fh]
        sel = [r for r in rows if r.get("ok") and r["mesh"] == "2x16x16"
               and r["shape"] == "train_4k"]
        print("\n# Part B: 2-pod mesh, DCN bandwidth sweep (train_4k)")
        print(f"{'arch':22s} {'policy':14s} " +
              " ".join(f"t@{g}GB/s" for g in (12, 50, 200)))
        for r in sorted(sel, key=lambda r: (r['arch'], r['tag'])):
            coll_b = r["collective_bytes"]
            times = {g: max(r["t_compute"], r["t_memory"]) + coll_b / (g * 1e9)
                     for g in (12, 50, 200)}
            out["tpu_pods"][f"{r['arch']}/{r['tag']}"] = times
            print(f"{r['arch']:22s} {r['tag']:14s} " +
                  " ".join(f"{times[g]:8.2f}s" for g in (12, 50, 200)))

    with open(os.path.join(out_dir, "fig4_bandwidth_model.json"), "w") as f:
        json.dump(out, f, indent=1)
    ok = speedup_13b_10g is not None and speedup_13b_10g > 1.8 and w_only < g_only
    print("fig4 trends:", "PASS" if ok else "FAIL")
    return 0 if ok else 1


if __name__ == "__main__":
    raise SystemExit(main())
