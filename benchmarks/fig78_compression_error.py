"""Benchmark: Figures 7/8 — compression error (relative L2) of uniform vs
learned quantization levels tracked OVER TRAINING.

The paper learns levels once after warmup and shows (i) learned error stays
below uniform for the whole run and (ii) both curves drift together, so one
learning pass suffices.  We track an attention projection and the LM head
(embedding) of the bench GPT at 4-bit weights.
"""
from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp

from repro.core.levels import (
    LevelsConfig, compression_error, dequantize_levels,
    learn_levels_for_tensor, quantize_levels, uniform_levels,
)
from repro.core.qsdp import MeshSpec
from repro.data import SyntheticLM, make_batch
from repro.models.transformer import Model
from repro.optim import AdamWConfig, cosine_schedule, make_adamw
from repro.train.step import init_train_state, make_jitted_train_step
from ._trainer import BENCH_MODEL, qsdp_wg

BITS = 4
TRACK = ["layers/wq", "embed"]


def main(argv=None, out_dir="results/bench"):
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=160)
    ap.add_argument("--every", type=int, default=20)
    ap.add_argument("--warmup", type=int, default=40)
    args = ap.parse_args(argv)
    os.makedirs(out_dir, exist_ok=True)

    mesh = jax.make_mesh((1, 1), ("data", "model"))
    ms = MeshSpec(axes=("data", "model"), shape=(1, 1))
    model = Model(BENCH_MODEL, ms, qsdp_wg(8, 8))
    opt = make_adamw(AdamWConfig(lr=1e-3, schedule=cosine_schedule(1e-3, 20, args.steps)))
    state = init_train_state(model, opt, jax.random.PRNGKey(0))
    data = SyntheticLM(vocab_size=BENCH_MODEL.vocab_size, seq_len=128,
                       global_batch=16, seed=0)
    step = make_jitted_train_step(model, opt, mesh, n_micro=1)

    levels = {k: None for k in TRACK}  # learned at warmup, then frozen
    curves = {k: [] for k in TRACK}

    def measure(i, params):
        for k in TRACK:
            w = params[k].reshape(-1)
            if levels[k] is None and i >= args.warmup:
                levels[k] = learn_levels_for_tensor(w, LevelsConfig(bits=BITS, epochs=2))
            qu = quantize_levels(w, uniform_levels(BITS))
            eu = float(compression_error(w, dequantize_levels(qu, uniform_levels(BITS))))
            if levels[k] is not None:
                ql = quantize_levels(w, levels[k])
                el = float(compression_error(w, dequantize_levels(ql, levels[k])))
            else:
                el = None
            curves[k].append(dict(step=i, uniform=eu, learned=el))

    with mesh:
        for i in range(args.steps):
            if i % args.every == 0:
                measure(i, state.params)
            b = make_batch(data, i, mesh, ms.fsdp_axes)
            state, m = step(state, b, jax.random.fold_in(jax.random.PRNGKey(1), i))
        measure(args.steps, state.params)

    print(f"# Figures 7/8: relative L2 compression error at {BITS}-bit weights")
    ok = True
    for k in TRACK:
        print(f"\n{k}:")
        for c in curves[k]:
            l = "     -" if c["learned"] is None else f"{c['learned']:.4f}"
            print(f"  step {c['step']:4d}  uniform={c['uniform']:.4f}  learned={l}")
        post = [c for c in curves[k] if c["learned"] is not None]
        wins = sum(c["learned"] < c["uniform"] for c in post)
        print(f"  learned < uniform at {wins}/{len(post)} checkpoints after warmup")
        ok &= wins >= 0.7 * len(post)

    with open(os.path.join(out_dir, "fig78_compression_error.json"), "w") as f:
        json.dump(curves, f, indent=1)
    print("fig78:", "PASS" if ok else "FAIL")
    return 0 if ok else 1


if __name__ == "__main__":
    raise SystemExit(main())
