"""Render the roofline tables (deliverable g) from the dry-run sweeps.

Reads results/dryrun_qsdp.jsonl + results/dryrun_baseline.jsonl and emits
a markdown report: per (arch x shape x mesh) the three roofline terms, the
dominant bottleneck, MODEL_FLOPS/HLO_FLOPs, and the QSDP-vs-baseline
collective-byte reduction.
"""
from __future__ import annotations

import argparse
import json
import os


def load(path):
    if not os.path.exists(path):
        return []
    with open(path) as f:
        return [json.loads(l) for l in f]


def fmt_s(t):
    return f"{t*1e3:10.1f}ms"


def main(argv=None, out_dir="results/bench"):
    ap = argparse.ArgumentParser()
    ap.add_argument("--qsdp", default="results/dryrun_qsdp.jsonl")
    ap.add_argument("--baseline", default="results/dryrun_baseline.jsonl")
    ap.add_argument("--out", default=None)
    args = ap.parse_args(argv)
    os.makedirs(out_dir, exist_ok=True)
    out_path = args.out or os.path.join(out_dir, "roofline_report.md")

    qs = {(r["arch"], r["shape"], r["mesh"]): r for r in load(args.qsdp) if r.get("ok")}
    bs = {(r["arch"], r["shape"], r["mesh"]): r for r in load(args.baseline) if r.get("ok")}

    lines = ["# Roofline report (TPU v5e: 197 TF/s bf16, 819 GB/s HBM, 50 GB/s ICI)",
             "",
             "Terms are per-device seconds for ONE step, derived from the",
             "compiled dry-run HLO (trip-count-aware analyzer).  `useful` =",
             "MODEL_FLOPS / HLO_FLOPs per device.  `coll x` = baseline-FSDP /",
             "QSDP collective bytes (the paper's wire compression).", ""]
    hdr = (f"| {'arch':22s} | {'shape':11s} | {'mesh':8s} | {'T_compute':>11s} "
           f"| {'T_mem_min':>11s} | {'T_mem_max':>11s} | {'T_coll':>11s} | {'bound':10s} | {'useful':>6s} "
           f"| {'coll x':>6s} | {'HBM fit':>8s} |")
    lines.append(hdr)
    lines.append("|" + "-" * (len(hdr) - 2) + "|")
    n_pairs = 0
    for key in sorted(qs):
        r = qs[key]
        b = bs.get(key)
        ratio = (b["collective_bytes"] / max(r["collective_bytes"], 1)) if b else None
        temp = (r.get("memory") or {}).get("temp")
        fit = "n/a" if temp is None else f"{temp/2**30:6.1f}GB"
        tmn = fmt_s(r.get("t_memory_min", r["t_memory"]))
        rtxt = f"{ratio:6.2f}" if ratio else "  n/a "
        lines.append(
            f"| {key[0]:22s} | {key[1]:11s} | {key[2]:8s} | {fmt_s(r['t_compute'])} "
            f"| {tmn} | {fmt_s(r['t_memory'])} | {fmt_s(r['t_collective'])} "
            f"| {r['bottleneck']:10s} | {r['useful_flops_ratio']:6.3f} "
            f"| {rtxt} | {fit:>8s} |")
        n_pairs += 1

    # summary block
    from collections import Counter
    bns = Counter(r["bottleneck"] for r in qs.values())
    ratios = [bs[k]["collective_bytes"] / max(qs[k]["collective_bytes"], 1)
              for k in qs if k in bs]
    lines += ["", f"- pairs: {n_pairs} (expect 40 per mesh x 2 meshes = 80)",
              f"- bottleneck census: {dict(bns)}",
              f"- QSDP collective-byte reduction vs baseline FSDP: "
              f"min {min(ratios):.2f}x / median {sorted(ratios)[len(ratios)//2]:.2f}x / "
              f"max {max(ratios):.2f}x" if ratios else "- no baseline comparison"]
    text = "\n".join(lines)
    with open(out_path, "w") as f:
        f.write(text + "\n")
    print(text)
    ok = n_pairs >= 80
    print("\nroofline_report:", "PASS" if ok else f"INCOMPLETE ({n_pairs}/80)")
    return 0 if ok else 1


if __name__ == "__main__":
    raise SystemExit(main())
