"""Benchmark driver: one benchmark per paper table/figure.

  PYTHONPATH=src python -m benchmarks.run [--full]

| benchmark                    | paper artifact               |
|------------------------------|------------------------------|
| theory_convergence           | Theorem 2 / Corollary 3      |
| table1_recovery              | Table 1 (W8G8 recovery)      |
| table2_bits_grid             | Table 2 (W x G bit grid)     |
| table3_learned_levels        | Tables 3/6 (learned levels)  |
| fig4_bandwidth_model         | Figure 4 / Table 5 / Fig 6   |
| fig78_compression_error      | Figures 7/8                  |
| roofline_report              | deliverable (g)              |
"""
from __future__ import annotations

import argparse
import sys
import time


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true", help="longer training runs")
    ap.add_argument("--only", type=str, default=None)
    args = ap.parse_args(argv)

    from . import (fig4_bandwidth_model, fig78_compression_error,
                   roofline_report, table1_recovery, table2_bits_grid,
                   table3_learned_levels, theory_convergence)

    steps = "400" if args.full else None
    suite = [
        ("theory_convergence", theory_convergence.main, []),
        ("table1_recovery", table1_recovery.main,
         ["--steps", steps or "240"]),
        ("table2_bits_grid", table2_bits_grid.main,
         (["--steps", steps or "160"] + (["--full"] if args.full else []))),
        ("table3_learned_levels", table3_learned_levels.main,
         ["--steps", steps or "160"]),
        ("fig78_compression_error", fig78_compression_error.main,
         ["--steps", steps or "160"]),
        ("fig4_bandwidth_model", fig4_bandwidth_model.main, []),
        ("roofline_report", roofline_report.main, []),
    ]
    failures = []
    for name, fn, argv_i in suite:
        if args.only and args.only != name:
            continue
        print("\n" + "=" * 72)
        print(f"== benchmark: {name}")
        print("=" * 72, flush=True)
        t0 = time.time()
        try:
            rc = fn(argv_i)
        except SystemExit as e:  # argparse in sub-benchmarks
            rc = int(e.code or 0)
        except Exception as e:
            import traceback
            traceback.print_exc()
            rc = 1
        print(f"== {name}: {'OK' if rc == 0 else 'FAIL'} ({time.time()-t0:.0f}s)")
        if rc != 0:
            failures.append(name)

    print("\n" + "=" * 72)
    if failures:
        print("FAILED:", ", ".join(failures))
    else:
        print("ALL BENCHMARKS OK")
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
