"""Benchmark: Table 1 — accuracy recovery of QSDP W8G8 vs the FSDP baseline.

The paper trains GPT-{125M,350M,1.3B} on C4 and shows QSDP's final
perplexity matches the baseline (35.81 vs 35.58 etc.).  Offline we train
the bench GPT on the synthetic Markov corpus and require the W8G8 final
loss to be within a small band of the baseline, and FAR below the
no-learning floor (ln V).  Also reproduces the paper's remark that naive
unbucketed round-to-nearest quantization is clearly worse.
"""
from __future__ import annotations

import argparse
import json
import os

import numpy as np

from ._trainer import BENCH_MODEL, qsdp_wg, train_run
from repro.core.qsdp import QSDPConfig


def main(argv=None, out_dir="results/bench"):
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    args = ap.parse_args(argv)
    os.makedirs(out_dir, exist_ok=True)

    runs = {
        "baseline-fsdp": QSDPConfig.baseline(),
        "qsdp-w8g8": qsdp_wg(8, 8),
        "qsdp-w8g8-rtn-nobucket": qsdp_wg(8, 8, weight_mode="nearest",
                                          grad_mode="nearest", bucket_size=65536),
    }
    results = {}
    for tag, cfg in runs.items():
        r = train_run(cfg, steps=args.steps, tag=tag)
        results[tag] = r
        print(f"{tag:26s} final_loss={r.final_loss:.4f} ppl={r.ppl:.2f}")

    base = results["baseline-fsdp"].final_loss
    q = results["qsdp-w8g8"].final_loss
    floor = np.log(BENCH_MODEL.vocab_size)
    recovered = abs(q - base) <= 0.08 * base
    learned = q < 0.75 * floor
    print(f"\nrecovery: |{q:.4f} - {base:.4f}| <= 8% of baseline: "
          f"{'PASS' if recovered else 'FAIL'}; learned (vs ln V = {floor:.2f}): "
          f"{'PASS' if learned else 'FAIL'}")

    with open(os.path.join(out_dir, "table1_recovery.json"), "w") as f:
        json.dump({t: dict(final_loss=r.final_loss, ppl=r.ppl, losses=r.losses)
                   for t, r in results.items()}, f, indent=1)
    return 0 if (recovered and learned) else 1


if __name__ == "__main__":
    raise SystemExit(main())
