"""Benchmark: Table 2 — final loss across the (weight bits x grad bits)
grid.  The paper's shape: quality degrades as bits shrink, weight bits
matter more than gradient bits (W4 rows are worst)."""
from __future__ import annotations

import argparse
import json
import os

from ._trainer import qsdp_wg, train_run


def main(argv=None, out_dir="results/bench"):
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=250)
    ap.add_argument("--full", action="store_true", help="3x3 grid (else 2x2)")
    args = ap.parse_args(argv)
    os.makedirs(out_dir, exist_ok=True)

    bits = (8, 6, 4) if args.full else (8, 4)
    grid = {}
    for w in bits:
        for g in bits:
            r = train_run(qsdp_wg(w, g), steps=args.steps, tag=f"w{w}g{g}")
            grid[f"w{w}g{g}"] = r.final_loss
            print(f"W{w}G{g}: final_loss={r.final_loss:.4f}")

    print("\n# Table 2 shape (rows = weight bits, cols = grad bits)")
    print("      " + "  ".join(f"G{g:>6}" for g in bits))
    for w in bits:
        print(f"W{w}: " + "  ".join(f"{grid[f'w{w}g{g}']:7.4f}" for g in bits))

    # the paper's ordering: lowest weight bits is the worst row
    worst_w = bits[-1]
    best_w = bits[0]
    ordering = all(grid[f"w{worst_w}g{g}"] >= grid[f"w{best_w}g{g}"] - 0.02
                   for g in bits)
    print("weight-bits-dominate ordering:", "PASS" if ordering else "FAIL")
    with open(os.path.join(out_dir, "table2_bits_grid.json"), "w") as f:
        json.dump(grid, f, indent=1)
    return 0 if ordering else 1


if __name__ == "__main__":
    raise SystemExit(main())
