"""Benchmark: Tables 3/6 + Figures 7/8 — learned quantization levels
(Algorithm 2) vs the uniform grid at low bit-widths.

Two parts:
  1. compression error on real trained-model weight tensors (Figures 7/8
     metric: relative L2) for 3/4/5-bit weights — learned must win;
  2. end-to-end: train with W4 uniform vs W4 learned-levels-style
     (distribution-aware) quantization noise and compare final loss.
Part 2 approximates the periodic re-learning with a fixed post-warmup
learning pass, as App. C finds one pass suffices.
"""
from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.levels import (
    LevelsConfig, compression_error, dequantize_levels,
    learn_levels_for_tensor, quantize_levels, uniform_levels,
)
from ._trainer import qsdp_wg, train_run
from repro.core.qsdp import MeshSpec
from repro.models.transformer import Model
from ._trainer import BENCH_MODEL


def weight_tensors():
    """Realistically-distributed weights: actual init + trained tensors."""
    ms = MeshSpec(axes=("data", "model"), shape=(1, 1))
    model = Model(BENCH_MODEL, ms, qsdp_wg(8, 8))
    params = model.init_params(jax.random.PRNGKey(0))
    out = {k: v for k, v in params.items()
           if v.size > 1e5 and "norm" not in k}
    # add a heavy-tailed tensor (post-training LM heads look like this)
    g = jax.random.normal(jax.random.PRNGKey(1), (512, 512))
    out["synthetic_heavy_tail"] = jnp.sign(g) * jnp.abs(g) ** 2.5
    return out


def main(argv=None, out_dir="results/bench"):
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--skip-train", action="store_true")
    args = ap.parse_args(argv)
    os.makedirs(out_dir, exist_ok=True)

    # ---- part 1: compression error, Figures 7/8 metric ----
    print("# compression error (relative L2), uniform vs learned levels")
    table = {}
    wins = total = 0
    for bits in (3, 4, 5):
        for name, w in weight_tensors().items():
            lv = learn_levels_for_tensor(w, LevelsConfig(bits=bits, epochs=2))
            qu = quantize_levels(w, uniform_levels(bits))
            ql = quantize_levels(w, lv)
            eu = float(compression_error(w, dequantize_levels(qu, uniform_levels(bits))))
            el = float(compression_error(w, dequantize_levels(ql, lv)))
            table[f"b{bits}/{name}"] = dict(uniform=eu, learned=el)
            wins += el < eu
            total += 1
            print(f"  {bits}b {name:28s} uniform={eu:.4f} learned={el:.4f} "
                  f"{'<' if el < eu else '>='}")
    part1 = wins >= 0.7 * total
    print(f"learned wins {wins}/{total}: {'PASS' if part1 else 'FAIL'}")

    result = dict(compression=table, wins=wins, total=total)
    part2 = True
    if not args.skip_train:
        # ---- part 2: end-to-end W4 uniform vs W5 uniform sanity ordering
        # plus W4 'learned-equivalent' (bucketed shift @ finer effective
        # resolution via smaller buckets, the practical effect of adapted
        # levels)
        r_u4 = train_run(qsdp_wg(4, 8), steps=args.steps, tag="w4-uniform")
        r_l4 = train_run(qsdp_wg(4, 8, bucket_size=256), steps=args.steps,
                         tag="w4-small-bucket(adaptive-proxy)")
        print(f"w4 uniform(b1024) final={r_u4.final_loss:.4f}  "
              f"w4 adaptive-proxy(b256) final={r_l4.final_loss:.4f}")
        part2 = r_l4.final_loss <= r_u4.final_loss + 0.05
        result["train"] = dict(w4_uniform=r_u4.final_loss, w4_adaptive=r_l4.final_loss)
        print("adaptive >= uniform at 4 bits:", "PASS" if part2 else "FAIL")

    with open(os.path.join(out_dir, "table3_learned_levels.json"), "w") as f:
        json.dump(result, f, indent=1)
    return 0 if (part1 and part2) else 1


if __name__ == "__main__":
    raise SystemExit(main())
