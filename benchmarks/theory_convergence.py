"""Benchmark: Theorem 2 / Corollary 3 convergence (the paper's analytical
core, Section 4).

Produces the convergence table: quantized SGD with the random-shift weight
quantizer converges to the lattice-optimum band; naive round-to-nearest on
the coarse grid stalls; adding an unbiased gradient quantizer (Corollary 3)
preserves convergence.
"""
from __future__ import annotations

import json
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.theory import make_quadratic, run_qsgd, theorem2_params


def main(argv=None, out_dir="results/bench"):
    os.makedirs(out_dir, exist_ok=True)
    key = jax.random.PRNGKey(0)
    rows = []
    for kappa in (2.0, 4.0, 8.0):
        obj = make_quadratic(key, n=64, kappa=kappa)
        delta_star, eps = 0.5, 1e-3
        params = theorem2_params(obj.alpha, obj.beta, delta_star, eps, 0.0,
                                 f0_gap=float(obj.f(jnp.zeros(64))))
        bench = obj.lattice_opt_value(delta_star, jax.random.PRNGKey(7))

        def avg_final(weight_q, grad_q_delta=None, delta=None, n_seeds=8):
            import dataclasses
            p = params if delta is None else dataclasses.replace(params, delta=delta)
            fs = [float(obj.f(run_qsgd(obj, jnp.zeros(64), p, jax.random.PRNGKey(s),
                                       weight_q=weight_q, grad_q_delta=grad_q_delta)[0]))
                  for s in range(n_seeds)]
            return float(np.mean(fs))

        f_shift = avg_final("shift")
        f_none = avg_final("none")
        f_rtn_coarse = avg_final("nearest", delta=delta_star)
        f_shift_coarse = avg_final("shift", delta=delta_star)
        f_gq = avg_final("shift", grad_q_delta=0.05)
        rows.append(dict(
            kappa=kappa, T=params.T, eta=params.eta, delta=params.delta,
            lattice_opt=bench, shift=f_shift, unquantized=f_none,
            rtn_coarse=f_rtn_coarse, shift_coarse=f_shift_coarse,
            shift_gradquant=f_gq,
            theorem_holds=bool(f_shift <= bench + eps + 1e-6),
            gq_holds=bool(f_gq <= bench + eps + 1e-6),
        ))

    with open(os.path.join(out_dir, "theory_convergence.json"), "w") as f:
        json.dump(rows, f, indent=1)
    print("\n# Theorem 2 convergence (f(x_T), avg of 8 seeds; target = lattice_opt + 1e-3)")
    hdr = f"{'kappa':>6} {'T':>5} {'lattice_opt':>12} {'QSGD(shift)':>12} {'+gradQ':>10} {'RTN@d*':>10} {'shift@d*':>10} {'ok':>4}"
    print(hdr)
    for r in rows:
        print(f"{r['kappa']:6.1f} {r['T']:5d} {r['lattice_opt']:12.5f} "
              f"{r['shift']:12.5f} {r['shift_gradquant']:10.5f} "
              f"{r['rtn_coarse']:10.5f} {r['shift_coarse']:10.5f} "
              f"{'Y' if r['theorem_holds'] and r['gq_holds'] else 'N':>4}")
    ok = all(r["theorem_holds"] and r["gq_holds"] for r in rows)
    print("theorem2:", "PASS" if ok else "FAIL")
    return 0 if ok else 1


if __name__ == "__main__":
    raise SystemExit(main())
