"""Learned quantization levels (paper Section 5.2, Algorithm 2) end to end:
learn a 4-bit codebook for each large tensor of a model, compare the
compression error against the uniform grid, and show the wire format.

  PYTHONPATH=src python examples/learned_levels.py
"""
import jax
import jax.numpy as jnp

from repro import configs
from repro.core.levels import (LevelsConfig, compression_error,
                               dequantize_levels, learn_levels_for_tensor,
                               quantize_levels, uniform_levels)
from repro.core.qsdp import MeshSpec, QSDPConfig
from repro.models.transformer import Model


def main():
    ms = MeshSpec(axes=("data", "model"), shape=(1, 1))
    model = Model(configs.get_smoke("yi-6b"), ms, QSDPConfig())
    params = model.init_params(jax.random.PRNGKey(0))
    cfg = LevelsConfig(bits=4, bucket_size=1024, epochs=2, min_params=10_000)

    print(f"# 4-bit learned vs uniform quantization ({model.cfg.name})")
    for name, w in params.items():
        if w.size < cfg.min_params:
            continue  # paper App. C: small layers stay uniform
        levels = learn_levels_for_tensor(w, cfg)
        qu = quantize_levels(w, uniform_levels(cfg.bits))
        ql = quantize_levels(w, levels)
        eu = float(compression_error(w, dequantize_levels(qu, uniform_levels(cfg.bits))))
        el = float(compression_error(w, dequantize_levels(ql, levels)))
        print(f"{name:24s} n={w.size:9d}  uniform={eu:.4f}  learned={el:.4f}  "
              f"({'better' if el < eu else 'no gain'})")
        if name == "embed":
            print(f"  learned levels: {[round(float(x), 3) for x in levels]}")
            print(f"  wire: codes {ql.codes.shape} u8 (packed {cfg.bits}-bit) "
                  f"+ {ql.scale.shape[0]} bucket scales = {ql.wire_bytes/2**10:.1f} KiB "
                  f"vs {w.size*4/2**10:.1f} KiB fp32")


if __name__ == "__main__":
    main()
