"""Quickstart: the QSDP public API in ~60 lines.

Builds a small GPT, shards it over an emulated (2 data x 4 model) mesh,
runs a few quantized-communication training steps, then generates tokens.

  XLA_FLAGS=--xla_force_host_platform_device_count=8 \
  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import configs
from repro.core.qsdp import MeshSpec, QSDPConfig
from repro.data import SyntheticLM, make_batch
from repro.models.decode import DecodeSpec
from repro.models.transformer import Model
from repro.optim import AdamWConfig, make_adamw
from repro.serve import ServeEngine
from repro.train.step import init_train_state, make_jitted_train_step


def main():
    # 1. mesh: ("data", "model") — FSDP over data, tensor-parallel over model
    dp, tp = (2, 4) if len(jax.devices()) >= 8 else (1, 1)
    mesh = jax.make_mesh((dp, tp), ("data", "model"))
    ms = MeshSpec(axes=("data", "model"), shape=(dp, tp))

    # 2. the paper's technique, as config: quantize everything FSDP transmits
    qsdp = QSDPConfig(weight_bits=8, grad_bits=8, bucket_size=1024,
                      min_quant_size=256)

    # 3. any architecture from the registry (10 assigned + GPT family)
    cfg = configs.get_smoke("gpt-125m")
    model = Model(cfg, ms, qsdp)

    # 4. train a few steps on the synthetic corpus
    opt = make_adamw(AdamWConfig(lr=1e-3))
    state = init_train_state(model, opt, jax.random.PRNGKey(0))
    data = SyntheticLM(vocab_size=cfg.vocab_size, seq_len=128, global_batch=8)
    step = make_jitted_train_step(model, opt, mesh, n_micro=2)
    with mesh:
        for i in range(10):
            batch = make_batch(data, i, mesh, ms.fsdp_axes)
            state, m = step(state, batch, jax.random.fold_in(jax.random.PRNGKey(1), i))
            print(f"step {i}: loss={float(m['loss']):.4f}")

    # 5. serve: greedy generation with quantized weight gathers
    spec = DecodeSpec(cache_len=64 + (-64) % tp, batch_global=8,
                      batch_sharded=8 % ms.fsdp_size == 0)
    eng = ServeEngine(model, mesh, spec)
    prompt, _ = data.sample(99, batch=8, seq=32)
    with mesh:
        out = eng.generate(state.params, {"tokens": prompt},
                           {"tokens": P(ms.fsdp_axes if spec.batch_sharded else None)},
                           n_tokens=8)
    print("generated:", out[0].tolist())


if __name__ == "__main__":
    main()
