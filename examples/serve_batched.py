"""Serve a model with batched requests: prefill a batch of prompts, decode
greedily, report per-step token throughput and the quantized weight-gather
bytes each decode step ships.

  XLA_FLAGS=--xla_force_host_platform_device_count=8 \
  PYTHONPATH=src python examples/serve_batched.py --arch olmoe-1b-7b
"""
import argparse
import time

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import configs
from repro.core.qsdp import MeshSpec, QSDPConfig, step_comm_bytes
from repro.data import SyntheticLM
from repro.models.decode import DecodeSpec
from repro.models.transformer import Model
from repro.serve import ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="olmoe-1b-7b")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--baseline", action="store_true")
    args = ap.parse_args()

    dp, tp = (2, 4) if len(jax.devices()) >= 8 else (1, 1)
    mesh = jax.make_mesh((dp, tp), ("data", "model"))
    ms = MeshSpec(axes=("data", "model"), shape=(dp, tp))
    cfg = configs.get_smoke(args.arch)
    qsdp = QSDPConfig.baseline() if args.baseline else QSDPConfig(min_quant_size=1024)
    model = Model(cfg, ms, qsdp)
    params = model.init_params(jax.random.PRNGKey(0))

    # per-decode-step wire bytes: ONE quantized gather per parameter
    comm = step_comm_bytes(model.engine, gathers_per_param=1, reduces_per_param=0)
    print(f"# {cfg.name} ({'baseline' if args.baseline else 'QSDP W8'}): "
          f"decode-step weight gathers = {comm['weight_gather']/2**20:.2f} MiB/device")

    ring = args.prompt_len + args.gen
    ring += (-ring) % tp
    spec = DecodeSpec(cache_len=0 if cfg.arch_type == "ssm" else ring,
                      batch_global=args.batch,
                      batch_sharded=args.batch % ms.fsdp_size == 0,
                      enc_len=max(args.prompt_len // cfg.enc_frames_ratio, tp)
                      if cfg.arch_type == "audio" else 0)
    eng = ServeEngine(model, mesh, spec)

    data = SyntheticLM(vocab_size=cfg.vocab_size, seq_len=args.prompt_len,
                       global_batch=args.batch)
    tokens, _ = data.sample(0)
    bax = ms.fsdp_axes if spec.batch_sharded else None
    prompt, pspecs = {"tokens": tokens}, {"tokens": P(bax)}
    if cfg.arch_type == "vlm":
        b, s = tokens.shape
        prompt.update(vision_embeds=jnp.zeros((b, s, cfg.d_model), jnp.bfloat16),
                      vision_mask=jnp.zeros((b, s), bool),
                      positions=jnp.broadcast_to(jnp.arange(s), (3, b, s)))
        pspecs.update(vision_embeds=P(bax), vision_mask=P(bax), positions=P(None, bax))
    if cfg.arch_type == "audio":
        prompt["audio_embeds"] = 0.1 * jax.random.normal(
            jax.random.PRNGKey(1), (args.batch, spec.enc_len, cfg.d_model), jnp.bfloat16)
        pspecs["audio_embeds"] = P(bax)

    with mesh:
        t0 = time.time()
        out = eng.generate(params, prompt, pspecs, n_tokens=args.gen)
        out.block_until_ready()
        t_total = time.time() - t0
        # steady-state decode rate (re-run decode only)
        dec = eng.decode_step()
        cache = eng.init_cache()
        nxt = out[:, -1]
        t1 = time.time()
        for i in range(8):
            nxt, cache = dec(params, cache, nxt,
                             jnp.asarray(args.prompt_len + i, jnp.int32),
                             jax.random.PRNGKey(i))
        nxt.block_until_ready()
        rate = 8 * args.batch / (time.time() - t1)
    print(f"generated {args.batch}x{args.gen} tokens in {t_total:.2f}s "
          f"(incl. compile); steady decode ~{rate:.1f} tok/s")
    print("sample:", out[0, :16].tolist())


if __name__ == "__main__":
    main()
