"""Serve a model with batched requests: prefill a batch of prompts, decode
greedily, report per-step token throughput and the quantized weight-gather
bytes each decode step ships.  Engine setup is the shared
repro.serve.build_serve_setup — the launcher, this example, and
benchmarks/bench_serve.py all build the exact same stack, and the
continuous mode (--continuous) builds its scheduler through the same
serve.common.make_scheduler as the launcher (flag-for-flag parity).

  XLA_FLAGS=--xla_force_host_platform_device_count=8 \
  PYTHONPATH=src python examples/serve_batched.py --arch olmoe-1b-7b

Continuous batching with self-speculative decoding (a 4-bit draft of the
SAME weights proposes 4 tokens/slot/step, the serving-precision model
verifies them in one launch; committed tokens are bit-identical to
non-speculative decode):

  PYTHONPATH=src python examples/serve_batched.py --arch gpt-125m \
      --continuous --prefill-chunk 16 --draft-bits 4 --draft-depth 4
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.qsdp import QSDPConfig
from repro.data import SyntheticLM
from repro.serve import (Request, build_serve_setup, make_prompt_batch,
                         make_scheduler)


def parse_args():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="olmoe-1b-7b")
    ap.add_argument("--smoke", action="store_true", default=True,
                    help="smoke-sized config (default for the example)")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--data-par", type=int, default=0,
                    help="0 = auto: (2, 4) when 8+ devices, else (1, 1)")
    ap.add_argument("--model-par", type=int, default=0)
    ap.add_argument("--baseline", action="store_true")
    ap.add_argument("--wbits", type=int, default=8)
    ap.add_argument("--seed", type=int, default=0)
    # continuous-batching knobs (same set as repro.launch.serve)
    ap.add_argument("--continuous", action="store_true",
                    help="serve a request queue through the "
                         "continuous-batching scheduler")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--top-k", type=int, default=0)
    ap.add_argument("--prefill-chunk", type=int, default=16,
                    help="chunked prefill size (also the chunk size when "
                         "--kv-block-size is set in one-shot mode)")
    ap.add_argument("--prefill-buckets", type=int, default=4)
    ap.add_argument("--prefill-interleave", type=int, default=1)
    # paged KV pool knobs
    ap.add_argument("--kv-block-size", type=int, default=0,
                    help="paged KV pool block size (0 = per-slot ring); "
                         "paged serving prefills in chunks")
    ap.add_argument("--kv-pool-blocks", type=int, default=0)
    ap.add_argument("--kv-quant-bits", type=int, default=0)
    ap.add_argument("--kv-quant-horizon", type=int, default=64)
    # self-speculative decoding knobs
    ap.add_argument("--draft-bits", type=int, default=0,
                    help="bit width of the self-speculative draft forward "
                         "(0 = off; 2-4 typical)")
    ap.add_argument("--draft-depth", type=int, default=0,
                    help="draft up to this many tokens per slot per step "
                         "(<= 1 = off; requires --continuous)")
    return ap.parse_args()


def run_continuous(setup, args):
    rng = np.random.default_rng(args.seed)
    sched = make_scheduler(
        setup, gather_key=jax.random.PRNGKey(args.seed),
        prefill_chunk=args.prefill_chunk,
        prefill_buckets=args.prefill_buckets,
        prefill_interleave=args.prefill_interleave,
        kv_quant_bits=args.kv_quant_bits if args.kv_block_size else 0,
        kv_quant_horizon=args.kv_quant_horizon)
    for i in range(args.requests):
        plen = int(rng.integers(max(args.prompt_len // 2, 1),
                                args.prompt_len + 1))
        gen = int(rng.integers(max(args.gen // 2, 1), args.gen + 1))
        sched.submit(Request(
            rid=f"req{i}",
            prompt=rng.integers(0, setup.cfg.vocab_size, size=plen).tolist(),
            max_new_tokens=gen, temperature=args.temperature,
            top_k=args.top_k, seed=args.seed + i))
    t0 = time.time()
    done = sched.run()
    dt = time.time() - t0
    st = sched.stats()
    print(f"# {setup.cfg.name} continuous: {len(done)} requests, "
          f"{st['tokens_generated']} tokens in {dt:.2f}s "
          f"({st['tokens_generated'] / dt:.1f} tok/s incl. compile), "
          f"occupancy {st['mean_occupancy']:.2f}/{st['slots']}")
    if setup.spec.speculative:
        print(f"# speculative: draft {setup.spec.draft_bits}-bit x depth "
              f"{setup.spec.draft_depth} -> accepted/launch "
              f"{st['accepted_per_launch']:.2f}, launches/token "
              f"{st['launches_per_token']:.2f}")
    first = done[sorted(done)[0]]
    print("sample:", first.tokens.tolist())


def run_batch(setup, args):
    cfg, eng, params = setup.cfg, setup.engine, setup.params
    data = SyntheticLM(vocab_size=cfg.vocab_size, seq_len=args.prompt_len,
                       global_batch=args.batch, seed=args.seed)
    tokens, _ = data.sample(0)
    prompt, pspecs = make_prompt_batch(cfg, setup.spec, setup.ms, tokens)

    kw, bt = {}, ()
    if setup.spec.paged:
        # paged pool: chunked prefill + fixed gather key; the solo path
        # lays each lane out on the identity block table
        kw = dict(prefill_chunk=args.prefill_chunk, fold_step_keys=False)
        bps = setup.spec.blocks_per_slot
        bt = (jnp.arange(args.batch * bps,
                         dtype=jnp.int32).reshape(args.batch, bps),)
    with setup.mesh:
        t0 = time.time()
        out = eng.generate(params, prompt, pspecs, n_tokens=args.gen, **kw)
        out.block_until_ready()
        t_total = time.time() - t0
        # steady-state decode rate (re-run decode only)
        dec = eng.decode_step()
        cache = eng.init_cache()
        nxt = out[:, -1]
        key0 = jax.random.PRNGKey(0)
        t1 = time.time()
        for i in range(8):
            pos = jnp.full((args.batch,), args.prompt_len + i, jnp.int32)
            k = key0 if setup.spec.paged else jax.random.PRNGKey(i)
            nxt, cache = dec(params, cache, nxt, pos, *bt, k)
        nxt.block_until_ready()
        rate = 8 * args.batch / (time.time() - t1)
    print(f"generated {args.batch}x{args.gen} tokens in {t_total:.2f}s "
          f"(incl. compile); steady decode ~{rate:.1f} tok/s")
    print("sample:", out[0, :16].tolist())


def main():
    args = parse_args()
    if args.data_par and args.model_par:
        dp, tp = args.data_par, args.model_par
    else:
        dp, tp = (2, 4) if len(jax.devices()) >= 8 else (1, 1)
    qsdp = (QSDPConfig.baseline() if args.baseline
            else QSDPConfig(weight_bits=args.wbits, min_quant_size=1024))
    if (args.draft_bits > 0) != (args.draft_depth > 1):
        raise SystemExit("speculative decode needs BOTH --draft-bits >= 2 "
                         "and --draft-depth >= 2")
    if args.draft_depth > 1 and not args.continuous:
        raise SystemExit("--draft-depth requires --continuous")
    setup = build_serve_setup(
        args.arch, data_par=dp, model_par=tp, smoke=args.smoke, qsdp=qsdp,
        batch=args.batch, prompt_len=args.prompt_len, gen=args.gen,
        seed=args.seed,
        sampling=args.continuous and (args.temperature > 0 or args.top_k > 1),
        kv_block_size=args.kv_block_size,
        kv_pool_blocks=args.kv_pool_blocks,
        draft_bits=args.draft_bits, draft_depth=args.draft_depth)

    # per-decode-step wire bytes: ONE quantized gather per parameter
    print(f"# {setup.cfg.name} "
          f"({'baseline' if args.baseline else f'QSDP W{args.wbits}'}): "
          f"decode-step weight gathers = "
          f"{setup.decode_gather_bytes() / 2**20:.2f} MiB/device")
    if args.continuous:
        run_continuous(setup, args)
    else:
        run_batch(setup, args)


if __name__ == "__main__":
    main()
