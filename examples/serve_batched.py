"""Serve a model with batched requests: prefill a batch of prompts, decode
greedily, report per-step token throughput and the quantized weight-gather
bytes each decode step ships.  Engine setup is the shared
repro.serve.build_serve_setup — the launcher, this example, and
benchmarks/bench_serve.py all build the exact same stack.

  XLA_FLAGS=--xla_force_host_platform_device_count=8 \
  PYTHONPATH=src python examples/serve_batched.py --arch olmoe-1b-7b
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.core.qsdp import QSDPConfig
from repro.data import SyntheticLM
from repro.serve import build_serve_setup, make_prompt_batch


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="olmoe-1b-7b")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--baseline", action="store_true")
    ap.add_argument("--kv-block-size", type=int, default=0,
                    help="paged KV pool block size (0 = per-slot ring); "
                         "paged serving prefills in chunks")
    ap.add_argument("--prefill-chunk", type=int, default=16,
                    help="chunk size when --kv-block-size is set")
    args = ap.parse_args()

    dp, tp = (2, 4) if len(jax.devices()) >= 8 else (1, 1)
    qsdp = (QSDPConfig.baseline() if args.baseline
            else QSDPConfig(min_quant_size=1024))
    setup = build_serve_setup(args.arch, data_par=dp, model_par=tp, smoke=True,
                              qsdp=qsdp, batch=args.batch,
                              prompt_len=args.prompt_len, gen=args.gen,
                              kv_block_size=args.kv_block_size)
    cfg, eng, params = setup.cfg, setup.engine, setup.params

    # per-decode-step wire bytes: ONE quantized gather per parameter
    print(f"# {cfg.name} ({'baseline' if args.baseline else 'QSDP W8'}): "
          f"decode-step weight gathers = "
          f"{setup.decode_gather_bytes() / 2**20:.2f} MiB/device")

    data = SyntheticLM(vocab_size=cfg.vocab_size, seq_len=args.prompt_len,
                       global_batch=args.batch)
    tokens, _ = data.sample(0)
    prompt, pspecs = make_prompt_batch(cfg, setup.spec, setup.ms, tokens)

    kw, bt = {}, ()
    if setup.spec.paged:
        # paged pool: chunked prefill + fixed gather key; the solo path
        # lays each lane out on the identity block table
        kw = dict(prefill_chunk=args.prefill_chunk, fold_step_keys=False)
        bps = setup.spec.blocks_per_slot
        bt = (jnp.arange(args.batch * bps,
                         dtype=jnp.int32).reshape(args.batch, bps),)
    with setup.mesh:
        t0 = time.time()
        out = eng.generate(params, prompt, pspecs, n_tokens=args.gen, **kw)
        out.block_until_ready()
        t_total = time.time() - t0
        # steady-state decode rate (re-run decode only)
        dec = eng.decode_step()
        cache = eng.init_cache()
        nxt = out[:, -1]
        key0 = jax.random.PRNGKey(0)
        t1 = time.time()
        for i in range(8):
            pos = jnp.full((args.batch,), args.prompt_len + i, jnp.int32)
            k = key0 if setup.spec.paged else jax.random.PRNGKey(i)
            nxt, cache = dec(params, cache, nxt, pos, *bt, k)
        nxt.block_until_ready()
        rate = 8 * args.batch / (time.time() - t1)
    print(f"generated {args.batch}x{args.gen} tokens in {t_total:.2f}s "
          f"(incl. compile); steady decode ~{rate:.1f} tok/s")
    print("sample:", out[0, :16].tolist())


if __name__ == "__main__":
    main()
