"""End-to-end driver: pre-train a ~100M-parameter GPT with QSDP for a few
hundred steps on the synthetic corpus, logging loss + communication savings.

Default is a laptop-scale run (reduced width, 300 steps) that finishes on
CPU; pass --full-width for the real gpt-125m geometry (slow on CPU, the
same config the dry-run lowers for the production mesh).

  XLA_FLAGS=--xla_force_host_platform_device_count=8 \
  PYTHONPATH=src python examples/train_gpt_qsdp.py --steps 300
"""
import argparse
import time

import jax

from repro import configs
from repro.core.qsdp import MeshSpec, QSDPConfig, step_comm_bytes
from repro.data import SyntheticLM, make_batch
from repro.models.config import ModelConfig
from repro.models.transformer import Model
from repro.optim import AdamWConfig, cosine_schedule, make_adamw
from repro.train.checkpoint import save_checkpoint
from repro.train.step import init_train_state, make_jitted_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--n-micro", type=int, default=4)  # paper: 4 accumulations
    ap.add_argument("--baseline", action="store_true")
    ap.add_argument("--full-width", action="store_true")
    ap.add_argument("--ckpt", type=str, default=None)
    args = ap.parse_args()

    dp, tp = (2, 4) if len(jax.devices()) >= 8 else (1, 1)
    mesh = jax.make_mesh((dp, tp), ("data", "model"))
    ms = MeshSpec(axes=("data", "model"), shape=(dp, tp))

    if args.full_width:
        cfg = configs.get_config("gpt-125m")
    else:  # ~8M params: same depth-ish shape, CPU-trainable
        cfg = ModelConfig(name="gpt-mini", arch_type="dense", n_layers=4,
                          d_model=384, vocab_size=8192, n_heads=8, n_kv_heads=8,
                          head_dim=48, d_ff=1024, rope_theta=10_000.0)

    qsdp = QSDPConfig.baseline() if args.baseline else QSDPConfig(min_quant_size=1024)
    model = Model(cfg, ms, qsdp)
    comm = step_comm_bytes(model.engine, gathers_per_param=2 * args.n_micro,
                           reduces_per_param=args.n_micro)
    print(f"# {cfg.name}: {cfg.n_params()/1e6:.1f}M params, "
          f"{'baseline FSDP' if args.baseline else 'QSDP W8G8'}; "
          f"per-device comm/step = {comm['total']/2**20:.1f} MiB "
          f"(weights {comm['weight_gather']/2**20:.1f} + grads {comm['grad_reduce']/2**20:.1f})")

    opt = make_adamw(AdamWConfig(lr=6e-4, schedule=cosine_schedule(6e-4, 20, args.steps)))
    state = init_train_state(model, opt, jax.random.PRNGKey(0))
    data = SyntheticLM(vocab_size=cfg.vocab_size, seq_len=args.seq,
                       global_batch=args.batch)
    step = make_jitted_train_step(model, opt, mesh, n_micro=args.n_micro)
    t0 = time.time()
    with mesh:
        for i in range(args.steps):
            batch = make_batch(data, i, mesh, ms.fsdp_axes)
            state, m = step(state, batch, jax.random.fold_in(jax.random.PRNGKey(1), i))
            if i % 20 == 0 or i == args.steps - 1:
                print(f"step {i:4d} loss {float(m['loss']):7.4f} "
                      f"gnorm {float(m['grad_norm']):7.3f} ({time.time()-t0:6.1f}s)")
    if args.ckpt:
        save_checkpoint(args.ckpt, state, meta=dict(arch=cfg.name, steps=args.steps))
        print("checkpoint ->", args.ckpt)


if __name__ == "__main__":
    main()
