"""Shared boilerplate for the scripts/check_*.py subprocess suites.

Every check script runs as a fresh subprocess (tests/test_distributed.py
`_run`) so it can emulate a multi-device host.  The shared contract:

  * ``force_host_devices(n)`` must run BEFORE anything imports jax —
    XLA reads the flag at backend init.  This module therefore imports
    nothing heavier than os/sys at module scope.
  * ``check(name, ok, info)`` prints one "PASS name"/"FAIL name" line per
    assertion (the test harness greps stdout for "FAIL ").
  * ``finish()`` prints the "ALL-OK" sentinel and exits non-zero when any
    check failed.
  * ``mesh_and_spec(shape, axes)`` builds the jax Mesh + MeshSpec pair
    every engine-level check needs.
"""
import os
import sys

FAIL = []


def force_host_devices(n: int = 8) -> None:
    os.environ["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n}"


def check(name: str, ok, info="") -> bool:
    ok = bool(ok)
    print(("PASS " if ok else "FAIL ") + name, info)
    if not ok:
        FAIL.append(name)
    return ok


def finish() -> None:
    print("ALL-OK" if not FAIL else f"FAILED: {FAIL}")
    sys.exit(0 if not FAIL else 1)


def mesh_and_spec(shape, axes=("data", "model")):
    import jax

    from repro.core.qsdp import MeshSpec

    return (jax.make_mesh(tuple(shape), tuple(axes)),
            MeshSpec(axes=tuple(axes), shape=tuple(shape)))
