"""Coalesced-wire-format correctness + HLO launch-count regression checks
(run under 8 emulated devices).  Invoked by tests/test_distributed.py.

Validates:
  1. collective level, (8,) mesh: all_gather_coalesced / reduce_scatter_
     coalesced are BIT-EXACT vs. the per-tensor quantized collectives for
     bits {2,3,4,8} x all 3 rounding modes x both backends (same keys,
     same wire bytes, one launch), incl. mixed quantized+fp layouts.
  2. hierarchical variants on a (2,2,2) pod mesh: bit-exact vs. per-tensor.
  3. meta_wire_dtype="bfloat16": coalesced == per-tensor bit-exact, and
     close (~2^-8) to the f32-metadata decode.
  4. engine level, (2,4) mesh: loss and grads of a dense model with
     coalesce=True match coalesce=False — quantized-param grads bit-exact,
     fp (filtered) grads within bf16-wire tolerance.
  5. prefetch=True (double-buffered pipeline): loss and ALL grads bit-exact
     vs. the non-pipelined coalesced schedule.
  6. HLO regression (the acceptance criterion): per-layer marginal
     all-gather launch count of the compiled forward is 3*n_quant + n_fp
     per-tensor and exactly 1 (u8) coalesced — measured via
     roofline.hlo_analyzer counts on two stack depths.

Exit code 0 + 'ALL-OK' on success.
"""
from _mesh_common import FAIL, check, finish, force_host_devices

force_host_devices(8)
import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map
from repro.core import collectives as coll
from repro.core.qsdp import MeshSpec, QSDPConfig
from repro.core.quant import QuantConfig
from repro.models.config import ModelConfig
from repro.models.transformer import Model
from repro.roofline.hlo_analyzer import analyze_hlo

# ---------------------------------------------------------------------------
# 1. collective-level bit-exactness, (8,) mesh
# ---------------------------------------------------------------------------
mesh8 = jax.make_mesh((8,), ("data",))
N = 2048  # per-device shard elements (not a bucket multiple for bits=3 path)


def ag_both(cfg):
    @partial(shard_map, mesh=mesh8, in_specs=(P("data"), P("data"), P()),
             out_specs=P("data"), check_vma=False)
    def f(xs, ys, key):
        x, y = xs.reshape(-1), ys.reshape(-1)
        ref_x = coll.all_gather_quantized(x, ("data",), cfg, key[0])
        ref_y = coll.all_gather_fp(y, ("data",))
        layout = coll.WireLayout((coll.WireSegment(x.shape[0], cfg),
                                  coll.WireSegment(y.shape[0], None, "float32")))
        co_x, co_y = coll.all_gather_coalesced(
            [x, y], ("data",), layout, [key[0], None],
            [jnp.float32, jnp.float32])
        return jnp.stack([jnp.concatenate([ref_x, ref_y]),
                          jnp.concatenate([co_x, co_y])])[None]

    x = jax.random.normal(jax.random.PRNGKey(0), (8, N))
    y = jax.random.normal(jax.random.PRNGKey(1), (8, 160))
    out = jax.jit(f)(x, y, jax.random.PRNGKey(2)[None])
    return out[0]


for bits in (2, 3, 4, 8):
    for mode in ("shift", "stochastic", "nearest"):
        for backend in ("jnp", "pallas"):
            cfg = QuantConfig(bits=bits, bucket_size=256, mode=mode, backend=backend)
            r = ag_both(cfg)
            check(f"ag-coalesced-bitexact-b{bits}-{mode}-{backend}",
                  bool(jnp.all(r[0] == r[1])),
                  f"maxdiff={float(jnp.max(jnp.abs(r[0] - r[1]))):.2e}")


def rs_both(cfg):
    from repro.core.quant import Quantized, dequantize, quantize, wire_pack, wire_unpack

    @partial(shard_map, mesh=mesh8, in_specs=(P("data"), P("data"), P()),
             out_specs=P("data"), check_vma=False)
    def f(gs, hs, key):
        g, h = gs.reshape(-1), hs.reshape(-1)
        p, n = 8, g.shape[0]
        ref_g = coll.reduce_scatter_quantized(g, ("data",), cfg, key[0])
        layout = coll.WireLayout((coll.WireSegment(n // p, cfg),
                                  coll.WireSegment(h.shape[0] // p, None, "bfloat16")))
        co_g, co_h = coll.reduce_scatter_coalesced([g, h], ("data",), layout,
                                                   [key[0], None])
        # fp reference: ship bf16 chunks, sum in f32 (the coalesced contract)
        ref_h = jnp.sum(
            jax.vmap(lambda c: coll.fp_unpack(coll.fp_pack(c, "bfloat16"),
                                              h.shape[0] // p, "bfloat16"))(
                jax.lax.all_to_all(h.reshape(p, -1), ("data",), 0, 0, tiled=True)),
            axis=0)
        # per-chunk DECODE bit-exactness: per-tensor collectives vs the wire
        # round-trip, same exchanged bytes, before any reduction
        q = jax.vmap(lambda c, k: quantize(c, cfg, k))(
            g.reshape(p, n // p), jax.random.split(key[0], p))
        codes = jax.lax.all_to_all(q.codes, ("data",), 0, 0, tiled=True)
        scale = jax.lax.all_to_all(q.scale, ("data",), 0, 0, tiled=True)
        zero = jax.lax.all_to_all(q.zero, ("data",), 0, 0, tiled=True)
        deq_ref = jax.vmap(lambda c, s, z: dequantize(
            Quantized(c, s, z, (n // p,), n // p, cfg)))(codes, scale, zero)
        rbuf = jax.lax.all_to_all(jax.vmap(wire_pack)(q), ("data",), 0, 0, tiled=True)
        deq_co = jax.vmap(lambda b: dequantize(wire_unpack(b, n // p, cfg)))(rbuf)
        decode_diff = jnp.max(jnp.abs(deq_ref - deq_co)) * jnp.ones_like(ref_g)
        return jnp.stack([jnp.concatenate([ref_g, ref_h]),
                          jnp.concatenate([co_g, co_h]),
                          jnp.concatenate([decode_diff, jnp.zeros_like(ref_h)])])[None]

    g = jax.random.normal(jax.random.PRNGKey(3), (8, N * 8))
    h = jax.random.normal(jax.random.PRNGKey(4), (8, 512))
    out = jax.jit(f)(g, h, jax.random.PRNGKey(5)[None])
    return out


for bits in (2, 4, 8):
    for mode in ("stochastic", "nearest"):
        cfg = QuantConfig(bits=bits, bucket_size=256, mode=mode)
        out = rs_both(cfg)
        check(f"rs-coalesced-decode-bitexact-b{bits}-{mode}",
              float(jnp.max(out[:, 2])) == 0.0,
              f"decode maxdiff={float(jnp.max(out[:, 2])):.2e}")
        # the summed RS result may differ by float reassociation only (XLA
        # fuses decode->sum differently across the two lowerings): ~1 ulp
        # at the summand scale, NOT a wire/decode discrepancy
        sum_diff = float(jnp.max(jnp.abs(out[:, 0] - out[:, 1])))
        check(f"rs-coalesced-sum-b{bits}-{mode}", sum_diff < 1e-5,
              f"maxdiff={sum_diff:.2e}")

# ---------------------------------------------------------------------------
# 2. hierarchical coalesced == per-tensor hierarchical, (2,2,2) mesh
# ---------------------------------------------------------------------------
mesh_pod = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
cfgh = QuantConfig(bits=8, bucket_size=256, mode="shift")


@partial(shard_map, mesh=mesh_pod, in_specs=(P(("data", "pod")), P()),
         out_specs=P(("data", "pod")), check_vma=False)
def hier_both(xs, key):
    x = xs.reshape(-1)
    ref = coll.all_gather_hierarchical(x, "pod", ("data",), cfgh, key[0])
    layout = coll.WireLayout((coll.WireSegment(x.shape[0], cfgh),))
    (co,) = coll.all_gather_coalesced([x], ("data", "pod"), layout, [key[0]],
                                      [jnp.float32], pod_axis="pod")
    rs_ref = coll.reduce_scatter_hierarchical(x, "pod", ("data",), cfgh, key[0])
    l1 = coll.WireLayout((coll.WireSegment(x.shape[0] // 2, cfgh),))
    l2 = coll.WireLayout((coll.WireSegment(x.shape[0] // 4, cfgh),))
    (rs_co,) = coll.reduce_scatter_coalesced_hierarchical(
        [x], "pod", ("data",), l1, l2, [key[0]])
    pad = jnp.zeros(ref.shape[0] - rs_ref.shape[0], jnp.float32)
    return jnp.stack([ref, co, jnp.concatenate([rs_ref, pad]),
                      jnp.concatenate([rs_co, pad])])[None]


xh = jax.random.normal(jax.random.PRNGKey(6), (4, 512))
out = jax.jit(hier_both)(xh, jax.random.PRNGKey(7)[None])
check("hier-ag-coalesced-bitexact", bool(jnp.all(out[:, 0] == out[:, 1])))
check("hier-rs-coalesced-bitexact", bool(jnp.all(out[:, 2] == out[:, 3])))

# ---------------------------------------------------------------------------
# 3. bf16 metadata wire
# ---------------------------------------------------------------------------
cfg16 = QuantConfig(bits=8, bucket_size=256, mode="shift", meta_dtype="bfloat16")
r16 = ag_both(cfg16)
check("ag-coalesced-bitexact-bf16meta", bool(jnp.all(r16[0] == r16[1])))
cfg32 = dataclasses.replace(cfg16, meta_dtype="float32")
r32 = ag_both(cfg32)
rel = float(jnp.max(jnp.abs(r16[0] - r32[0])) / (jnp.max(jnp.abs(r32[0])) + 1e-9))
check("bf16meta-close-to-f32meta", 0 < rel < 0.02, f"rel={rel:.2e}")
b16 = coll.gather_wire_bytes(N, 8, cfg16)
b32 = coll.gather_wire_bytes(N, 8, cfg32)
check("bf16meta-fewer-wire-bytes", b16 == b32 - 7 * 2 * 2 * (N // 256),
      f"{b16} vs {b32}")

# ---------------------------------------------------------------------------
# 4-5. engine level: coalesce / prefetch vs per-tensor, (2,4) mesh
# ---------------------------------------------------------------------------
mesh24 = jax.make_mesh((2, 4), ("data", "model"))
ms = MeshSpec(axes=("data", "model"), shape=(2, 4))
mcfg = ModelConfig(name="t", arch_type="dense", n_layers=3, d_model=128,
                   vocab_size=256, n_heads=8, n_kv_heads=4, head_dim=16, d_ff=256)


def loss_and_grads(qcfg):
    model = Model(mcfg, ms, qcfg)
    params = model.init_params(jax.random.PRNGKey(20))

    @partial(shard_map, mesh=mesh24,
             in_specs=(model.param_pspecs(), {"tokens": P(("data",)), "labels": P(("data",))}, P()),
             out_specs=(P(), model.param_pspecs()), check_vma=False)
    def f(p, b, k):
        loss, g = jax.value_and_grad(model.loss_fn)(p, b, k)
        return jax.lax.pmean(loss, ("data", "model")), g

    tokens = jax.random.randint(jax.random.PRNGKey(21), (4, 16), 0, 256)
    batch = {"tokens": tokens, "labels": tokens}
    loss, g = jax.jit(f)(params, batch, jax.random.PRNGKey(22))
    return model, float(loss), jax.device_get(g)


q_base = QSDPConfig(min_quant_size=256, coalesce=False)
q_co = dataclasses.replace(q_base, coalesce=True)
q_pf = dataclasses.replace(q_base, coalesce=True, prefetch=True)

model, l0, g0 = loss_and_grads(q_base)
_, l1, g1 = loss_and_grads(q_co)
_, l2, g2 = loss_and_grads(q_pf)

check("engine-coalesce-loss-bitexact", l0 == l1, f"{l0} vs {l1}")
check("engine-prefetch-loss-bitexact", l1 == l2, f"{l1} vs {l2}")

worst_fp, ok_q = 0.0, True
for k in g0:
    spec = model.specs[k]
    if model.engine._is_grad_quantized(spec):
        ok_q &= bool((np.asarray(g0[k]) == np.asarray(g1[k])).all())
    else:
        d = float(np.max(np.abs(np.asarray(g0[k]) - np.asarray(g1[k]))))
        s = float(np.max(np.abs(np.asarray(g0[k]))) + 1e-9)
        worst_fp = max(worst_fp, d / s)
check("engine-coalesce-quantgrads-bitexact", ok_q)
check("engine-coalesce-fpgrads-close", worst_fp < 2e-2, f"rel={worst_fp:.2e}")

ok_pf = all(bool((np.asarray(g1[k]) == np.asarray(g2[k])).all()) for k in g1)
check("engine-prefetch-grads-bitexact", ok_pf)

# ---------------------------------------------------------------------------
# 6. HLO launch-count regression: 3*n_quant + n_fp -> 1 per layer gather
# ---------------------------------------------------------------------------


def fwd_ag_counts(qcfg, n_layers):
    c = dataclasses.replace(mcfg, n_layers=n_layers)
    model = Model(c, ms, qcfg)
    params = model.init_params(jax.random.PRNGKey(30))

    @partial(shard_map, mesh=mesh24,
             in_specs=(model.param_pspecs(), {"tokens": P(("data",)), "labels": P(("data",))}, P()),
             out_specs=P(), check_vma=False)
    def f(p, b, k):
        return jax.lax.pmean(model.loss_fn(p, b, k), ("data", "model"))

    tokens = jnp.zeros((4, 16), jnp.int32)
    batch = {"tokens": tokens, "labels": tokens}
    hlo = jax.jit(f).lower(params, batch, jax.random.PRNGKey(31)).compile().as_text()
    r = analyze_hlo(hlo)
    return r["collectives"]["counts"], r["collectives"]["counts_by_dtype"]


# layer params: 7 quantized (wq wk wv wo w_gate w_up w_down) + 2 fp norms
c2, _ = fwd_ag_counts(q_base, 2)
c4, _ = fwd_ag_counts(q_base, 4)
marg_base = (c4["all-gather"] - c2["all-gather"]) / 2
check("hlo-per-tensor-marginal-23", marg_base == 3 * 7 + 2,
      f"marginal={marg_base}")

c2, d2 = fwd_ag_counts(q_co, 2)
c4, d4 = fwd_ag_counts(q_co, 4)
marg_co = (c4["all-gather"] - c2["all-gather"]) / 2
marg_u8 = (d4.get("all-gather:u8", 0) - d2.get("all-gather:u8", 0)) / 2
check("hlo-coalesced-marginal-1", marg_co == 1, f"marginal={marg_co}")
check("hlo-coalesced-marginal-is-u8", marg_u8 == 1, f"marginal={marg_u8}")

c2, d2 = fwd_ag_counts(q_pf, 2)
c4, d4 = fwd_ag_counts(q_pf, 4)
marg_pf = (d4.get("all-gather:u8", 0) - d2.get("all-gather:u8", 0)) / 2
check("hlo-prefetch-marginal-1", marg_pf == 1, f"marginal={marg_pf}")

finish()
