"""Multi-device numerical correctness checks (run under 8 emulated devices).

Validates on a (2, 4) mesh:
  1. quantized all-gather ~= fp all-gather (within quantization error)
  2. quantized reduce-scatter ~= fp psum_scatter
  3. hierarchical variants match flat variants' semantics (3-axis mesh)
  4. QSDP engine gather reconstructs from_rest exactly (fp path)
  5. TP gradients: QSDP dense model grads == single-device fp replica grads
  6. decode == prefill consistency (fp path, greedy tokens identical)

Exit code 0 + 'ALL-OK' on success.  Invoked by tests/test_distributed.py.
"""
from _mesh_common import FAIL, check, finish, force_host_devices

force_host_devices(8)
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map

from repro.core import collectives as coll
from repro.core.qsdp import (MeshSpec, ParamSpec, QSDPConfig, QSDPEngine,
                             from_rest, to_rest)
from repro.core.quant import QuantConfig
from repro.models.config import ModelConfig
from repro.models.transformer import Model
from repro.models.decode import DecodeSpec
from repro.serve.engine import ServeEngine

# ---------------------------------------------------------------------------
# 1-2: quantized collectives numerics (1-axis)
# ---------------------------------------------------------------------------
mesh8 = jax.make_mesh((8,), ("data",))
cfgq = QuantConfig(bits=8, bucket_size=256, mode="shift")
x = jax.random.normal(jax.random.PRNGKey(0), (8, 1024))


@partial(shard_map, mesh=mesh8, in_specs=(P("data"), P()), out_specs=P("data"),
         check_vma=False)
def ag_pair(xs, key):
    flat = xs.reshape(-1)
    q = coll.all_gather_quantized(flat, ("data",), cfgq, key[0])
    f = coll.all_gather_fp(flat, ("data",))
    return jnp.stack([q, f])[None]


out = jax.jit(ag_pair)(x, jax.random.PRNGKey(1)[None])
q, f = out[0, 0], out[0, 1]
err = float(jnp.max(jnp.abs(q - f)))
scale_bound = float((jnp.max(x) - jnp.min(x)) / 255) * 1.5
check("quantized-all-gather", err <= scale_bound, f"err={err:.5f}")
# every rank got identical full tensors
allq = jax.device_get(out)
check("all-gather-full-recovery", np.allclose(np.asarray(f).reshape(8, 1024), np.asarray(x), atol=scale_bound))


@partial(shard_map, mesh=mesh8, in_specs=(P("data"), P()), out_specs=P("data"),
         check_vma=False)
def rs_pair(xs, key):
    g = xs.reshape(-1)
    q = coll.reduce_scatter_quantized(g, ("data",), cfgq, key[0])
    f = coll.reduce_scatter_fp(g, ("data",))
    return jnp.stack([q, f])[None]


g_in = jax.random.normal(jax.random.PRNGKey(2), (8, 2048))
out = jax.jit(rs_pair)(g_in, jax.random.PRNGKey(3)[None])
qrs = out[:, 0].reshape(-1)
frs = out[:, 1].reshape(-1)
# tolerance: 8 summands each with bucket quant error
tol = 8 * float(jnp.max(jnp.abs(g_in)) * 2 / 255)
check("quantized-reduce-scatter", float(jnp.max(jnp.abs(qrs - frs))) <= tol,
      f"err={float(jnp.max(jnp.abs(qrs - frs))):.5f} tol={tol:.5f}")
np.testing.assert_allclose(np.asarray(frs), np.asarray(g_in).reshape(8, 8, 256).sum(0).reshape(-1), rtol=1e-5)
check("fp-reduce-scatter-exact", True)

# ---------------------------------------------------------------------------
# 3: hierarchical == flat (2x2x2 mesh: pod x data x model)
# ---------------------------------------------------------------------------
mesh_pod = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))


@partial(shard_map, mesh=mesh_pod, in_specs=(P(("data", "pod")), P()),
         out_specs=P(("data", "pod")), check_vma=False)
def hier_ag(xs, key):
    flat = xs.reshape(-1)
    h = coll.all_gather_hierarchical(flat, "pod", ("data",), cfgq, key[0])
    fl = coll.all_gather_quantized(flat, ("data", "pod"), cfgq, key[0])
    f = coll.all_gather_fp(flat, ("data", "pod"))
    return jnp.stack([h, fl, f])[None]


xh = jax.random.normal(jax.random.PRNGKey(4), (4, 512))
out = jax.jit(hier_ag)(xh, jax.random.PRNGKey(5)[None])
h, fl, f = out[0, 0], out[0, 1], out[0, 2]
sb = float((jnp.max(xh) - jnp.min(xh)) / 255) * 1.5
check("hierarchical-ag-order", float(jnp.max(jnp.abs(h - f))) <= sb,
      f"err={float(jnp.max(jnp.abs(h - f))):.5f}")
check("flat-ag-order", float(jnp.max(jnp.abs(fl - f))) <= sb)


@partial(shard_map, mesh=mesh_pod, in_specs=(P(("data", "pod")), P()),
         out_specs=P(("data", "pod")), check_vma=False)
def hier_rs(xs, key):
    g = xs.reshape(-1)
    h = coll.reduce_scatter_hierarchical(g, "pod", ("data",), cfgq, key[0])
    f = coll.reduce_scatter_fp(g, ("data", "pod"))
    return jnp.stack([h, f])[None]


gh = jax.random.normal(jax.random.PRNGKey(6), (4, 1024))
out = jax.jit(hier_rs)(gh, jax.random.PRNGKey(7)[None])
tol = 5 * float(jnp.max(jnp.abs(gh)) * 2 / 255)
check("hierarchical-rs", float(jnp.max(jnp.abs(out[:, 0] - out[:, 1]))) <= tol,
      f"err={float(jnp.max(jnp.abs(out[:, 0] - out[:, 1]))):.5f}")

# ---------------------------------------------------------------------------
# 4: engine gather (fp path) reconstructs exactly
# ---------------------------------------------------------------------------
mesh24 = jax.make_mesh((2, 4), ("data", "model"))
ms = MeshSpec(axes=("data", "model"), shape=(2, 4))
spec = ParamSpec((16, 8), tp_axis=1)
full = jnp.arange(16 * 8, dtype=jnp.float32).reshape(16, 8)
rest = to_rest(full, spec, ms)
eng = QSDPEngine(ms, QSDPConfig.baseline(), {"w": spec})


@partial(shard_map, mesh=mesh24,
         in_specs=(spec.rest_pspec(ms), P()), out_specs=P(None, "model"),
         check_vma=False)
def gather_w(w, key):
    return eng.gather("w", w, key[0]).astype(jnp.float32)


out = jax.jit(gather_w)(rest, jax.random.PRNGKey(8)[None])
check("engine-gather-exact", bool(jnp.all(out == full)),
      f"maxdiff={float(jnp.max(jnp.abs(out - full)))}")

# ---------------------------------------------------------------------------
# 5: distributed fp grads == single-device replica grads
# ---------------------------------------------------------------------------
import dataclasses

import dataclasses as _dc  # noqa: E402

mcfg = ModelConfig(name="t", arch_type="dense", n_layers=2, d_model=64,
                   vocab_size=256, n_heads=4, n_kv_heads=2, head_dim=16, d_ff=128)
# capacity_factor high enough that NO token is dropped on either mesh —
# capacity semantics differ with per-rank token counts, so drop-free routing
# is required for an exact single-device comparison.
moecfg = ModelConfig(name="tm", arch_type="moe", n_layers=2, d_model=64,
                     vocab_size=256, n_heads=4, n_kv_heads=4, head_dim=16,
                     n_experts=8, moe_top_k=2, moe_d_ff=64,
                     moe_capacity_factor=16.0,
                     # aux uses per-token-shard statistics by design (standard
                     # EP) -> not single-device comparable; exclude it here
                     moe_aux_coef=0.0)
qs_fp = dataclasses.replace(QSDPConfig.baseline(), compute_dtype="float32",
                            grad_wire_dtype="float32")
ms11 = MeshSpec(axes=("data", "model"), shape=(1, 1))
mesh11 = jax.make_mesh((1, 1), ("data", "model"))

def grads_of(model, mesh, params, batch, bspec):
    @partial(shard_map, mesh=mesh,
             in_specs=(model.param_pspecs(), {"tokens": bspec, "labels": bspec}, P()),
             out_specs=(P(), model.param_pspecs()), check_vma=False)
    def f(p, b, k):
        loss, g = jax.value_and_grad(model.loss_fn)(p, b, k)
        return jax.lax.pmean(loss, ("data", "model")), g
    return jax.jit(f)(params, batch, jax.random.PRNGKey(11))


for cfg_i, tolv in ((mcfg, 5e-3), (moecfg, 5e-3)):
    model_d = Model(cfg_i, ms, qs_fp)
    model_s = Model(cfg_i, ms11, qs_fp)
    params_s = model_s.init_params(jax.random.PRNGKey(9))
    params_logical = {k: from_rest(v, model_s.specs[k], ms11) for k, v in params_s.items()}
    params_d = {k: to_rest(v, model_d.specs[k], ms) for k, v in params_logical.items()}
    tokens = jax.random.randint(jax.random.PRNGKey(10), (4, 16), 0, 256)
    batch = {"tokens": tokens, "labels": tokens}
    loss_d, g_d = grads_of(model_d, mesh24, params_d, batch, P(("data",)))
    loss_s, g_s = grads_of(model_s, mesh11, params_s, batch, P(("data",)))
    check(f"tp-loss-match-{cfg_i.arch_type}",
          abs(float(loss_d) - float(loss_s)) < 2e-4,
          f"{float(loss_d):.6f} vs {float(loss_s):.6f}")
    worst, worst_k = 0.0, None
    for k in g_s:
        gd_logical = np.asarray(jax.device_get(from_rest(g_d[k], model_d.specs[k], ms)))
        gs_logical = np.asarray(jax.device_get(from_rest(g_s[k], model_s.specs[k], ms11)))
        rel = float(np.max(np.abs(gd_logical - gs_logical)) /
                    (np.max(np.abs(gs_logical)) + 1e-9))
        if rel > worst:
            worst, worst_k = rel, k
    check(f"tp-grads-match-{cfg_i.arch_type}", worst < tolv,
          f"worst rel err={worst:.2e} at {worst_k}")

# ---------------------------------------------------------------------------
# 6: decode == re-prefill greedy consistency (fp path)
# ---------------------------------------------------------------------------
for arch_kw in (dict(arch_type="dense", n_layers=2, d_model=64, vocab_size=256,
                     n_heads=4, n_kv_heads=2, head_dim=16, d_ff=128),
                dict(arch_type="ssm", n_layers=2, d_model=64, vocab_size=256,
                     ssm_state=16, ssm_head_dim=16, ssm_chunk=8)):
    c = ModelConfig(name="t2", **arch_kw)
    m = Model(c, ms, qs_fp)
    p = m.init_params(jax.random.PRNGKey(12))
    S, B, gen = 16, 4, 5
    ring = S + gen + (-(S + gen)) % 4
    sp = DecodeSpec(cache_len=0 if c.arch_type == "ssm" else ring,
                    batch_global=B, batch_sharded=True)
    eng2 = ServeEngine(m, mesh24, sp)
    prompt = {"tokens": jax.random.randint(jax.random.PRNGKey(13), (B, S), 0, 256)}
    toks_dec = jax.device_get(eng2.generate(p, prompt, {"tokens": P(("data",))}, n_tokens=gen))

    # reference: re-prefill with the growing teacher-forced sequence
    seq = np.asarray(prompt["tokens"])
    ref = []
    for i in range(gen):
        sp_i = DecodeSpec(cache_len=0 if c.arch_type == "ssm" else ring,
                          batch_global=B, batch_sharded=True)
        eng_i = ServeEngine(m, mesh24, sp_i)
        nxt, _ = eng_i.prefill_step({"tokens": P(("data",))})(
            p, {"tokens": jnp.asarray(seq)}, jax.random.PRNGKey(0))
        nxt = jax.device_get(nxt)
        ref.append(nxt)
        seq = np.concatenate([seq, nxt[:, None]], axis=1)
    ref = np.stack(ref, axis=1)
    check(f"decode-prefill-consistency-{c.arch_type}",
          bool((toks_dec == ref).all()),
          f"dec={toks_dec[0].tolist()} ref={ref[0].tolist()}")

finish()
