"""Quantized-domain train-state checks that need a multi-device mesh (run
under 8 emulated CPU devices; invoked by tests/test_distributed.py).

Validates:
  1. (2,4) mesh, 10 steps: loss + dequantized params + Adam moments of
     `quantized_state=True` are BIT-EXACT vs the f32 `quantize_master=True`
     QDQ path started from the same quantization-grid initial state (the
     acceptance criterion; the (1,1) case runs in-process in
     tests/test_quantized_state.py).
  2. checkpoint format v2 resharding: an f32 state saved on (1,1) loads on
     (2,4) — and back — with bit-identical logical params/moments/step.
  3. a QUANTIZED state saved on (1,1) loads on (2,4) (dequantize=True) with
     bit-identical decoded values, and byte-identical wire on the same
     layout; reverse direction likewise.

Exit code 0 + 'ALL-OK' on success.
"""
from _mesh_common import check, finish, force_host_devices

force_host_devices(8)

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.qsdp import MeshSpec, QSDPConfig, from_rest
from repro.core.quant import QuantizedParam
from repro.models.config import ModelConfig
from repro.models.transformer import Model
from repro.optim import AdamWConfig, make_adamw
from repro.train.checkpoint import load_checkpoint, save_checkpoint
from repro.train.step import (
    dequantize_train_state,
    init_train_state,
    make_jitted_train_step,
    quantize_train_state,
    state_pspecs,
)

MCFG = ModelConfig(name="t", arch_type="dense", n_layers=2, d_model=64,
                   vocab_size=128, n_heads=4, n_kv_heads=4, head_dim=16,
                   d_ff=128)


def build(mesh_shape):
    mesh = jax.make_mesh(mesh_shape, ("data", "model"))
    ms = MeshSpec(axes=("data", "model"), shape=mesh_shape)
    model = Model(MCFG, ms, QSDPConfig(min_quant_size=256))
    return mesh, ms, model


def batch_for(model):
    tokens = jax.random.randint(jax.random.PRNGKey(3), (8, 32), 0,
                                MCFG.vocab_size)
    return {"tokens": tokens, "labels": tokens}


# ---------------------------------------------------------------------------
# 1. (2,4) bit-exactness over 10 steps
# ---------------------------------------------------------------------------

mesh, ms, model = build((2, 4))
opt = make_adamw(AdamWConfig(lr=1e-3))
s0 = init_train_state(model, opt, jax.random.PRNGKey(0))
qs0 = quantize_train_state(s0, model, jax.random.PRNGKey(9))
fs0 = dequantize_train_state(qs0)
batch = batch_for(model)

step_q = make_jitted_train_step(model, opt, mesh, quantized_state=True,
                                donate=False)
step_f = make_jitted_train_step(model, opt, mesh, quantize_master=True,
                                donate=False)
sq, sf = qs0, fs0
losses_equal = True
with mesh:
    for i in range(10):
        k = jax.random.fold_in(jax.random.PRNGKey(7), i)
        sq, mq = step_q(sq, batch, k)
        sf, mf = step_f(sf, batch, k)
        losses_equal &= float(mq["loss"]) == float(mf["loss"])
check("qstate-2x4-loss-bitexact-10steps", losses_equal)
dq = dequantize_train_state(sq)
ok = all(bool(jnp.all(dq.params[k] == sf.params[k])) for k in sf.params)
check("qstate-2x4-params-bitexact", ok)
ok = all(bool(jnp.all(dq.opt.mu[k] == sf.opt.mu[k]))
         and bool(jnp.all(dq.opt.nu[k] == sf.opt.nu[k])) for k in sf.opt.mu)
check("qstate-2x4-moments-bitexact", ok)
n_wire = sum(isinstance(v, QuantizedParam) for v in sq.params.values())
check("qstate-2x4-has-wire-leaves", n_wire > 0, f"n={n_wire}")


# ---------------------------------------------------------------------------
# 2. checkpoint v2 resharding, f32 state: (1,1) <-> (2,4) bit-identical
# ---------------------------------------------------------------------------


def logical(state, model):
    out = {}
    for k, v in state.params.items():
        out[k] = np.asarray(from_rest(v, model.specs[k], model.ms))
    return out


def logical_tree(tree, model):
    return {k: np.asarray(from_rest(v, model.specs[k], model.ms))
            for k, v in tree.items()}


import tempfile

mesh11_, ms11, model11 = build((1, 1))
mesh24, ms24, model24 = build((2, 4))

opt11 = make_adamw(AdamWConfig(lr=1e-3))
state11 = init_train_state(model11, opt11, jax.random.PRNGKey(4))
state24 = init_train_state(model24, make_adamw(AdamWConfig(lr=1e-3)),
                           jax.random.PRNGKey(4))

with tempfile.TemporaryDirectory() as td:
    save_checkpoint(td, state11)
    loaded24 = load_checkpoint(td, mesh24, state_pspecs(model24), model=model24)
l_src = logical(state11, model11)
l_dst = logical(loaded24, model24)
ok = all(np.array_equal(l_src[k], l_dst[k]) for k in l_src)
check("ckpt-reshard-f32-1x1-to-2x4-params", ok)
mu_src = logical_tree(state11.opt.mu, model11)
mu_dst = logical_tree(loaded24.opt.mu, model24)
ok = (all(np.array_equal(mu_src[k], mu_dst[k]) for k in mu_src)
      and int(loaded24.opt.step) == int(state11.opt.step))
check("ckpt-reshard-f32-1x1-to-2x4-opt", ok)

with tempfile.TemporaryDirectory() as td:
    save_checkpoint(td, state24)
    loaded11 = load_checkpoint(td, mesh11_, state_pspecs(model11), model=model11)
l_src = logical(state24, model24)
l_dst = logical(loaded11, model11)
ok = all(np.array_equal(l_src[k], l_dst[k]) for k in l_src)
check("ckpt-reshard-f32-2x4-to-1x1-params", ok)


# ---------------------------------------------------------------------------
# 3. quantized state across meshes
# ---------------------------------------------------------------------------

q11 = quantize_train_state(state11, model11, jax.random.PRNGKey(5))

# same layout: wire bytes survive the checkpoint untouched
with tempfile.TemporaryDirectory() as td:
    save_checkpoint(td, q11)
    rq11 = load_checkpoint(td, mesh11_,
                           state_pspecs(model11, quantized_state=True),
                           model=model11)
ok = all(
    (np.array_equal(np.asarray(v.wire), np.asarray(rq11.params[k].wire))
     if isinstance(v, QuantizedParam)
     else np.array_equal(np.asarray(v), np.asarray(rq11.params[k])))
    for k, v in q11.params.items())
check("ckpt-qstate-same-layout-byte-identical", ok)

# cross layout: decoded values are bit-identical (decode is deterministic)
with tempfile.TemporaryDirectory() as td:
    save_checkpoint(td, q11)
    try:
        load_checkpoint(td, mesh24, state_pspecs(model24), model=model24)
        check("ckpt-qstate-cross-layout-requires-dequantize", False)
    except ValueError:
        check("ckpt-qstate-cross-layout-requires-dequantize", True)
    rq24 = load_checkpoint(td, mesh24, state_pspecs(model24), model=model24,
                           dequantize=True)
ref = logical(dequantize_train_state(q11), model11)
got = logical(rq24, model24)
ok = all(np.array_equal(ref[k], got[k]) for k in ref)
check("ckpt-qstate-1x1-to-2x4-decoded-bitexact", ok)

# reverse: quantize on (2,4), read back on (1,1)
q24 = quantize_train_state(state24, model24, jax.random.PRNGKey(5))
with tempfile.TemporaryDirectory() as td:
    save_checkpoint(td, q24)
    rq11b = load_checkpoint(td, mesh11_, state_pspecs(model11), model=model11,
                            dequantize=True)
ref = logical(dequantize_train_state(q24), model24)
got = logical(rq11b, model11)
ok = all(np.array_equal(ref[k], got[k]) for k in ref)
check("ckpt-qstate-2x4-to-1x1-decoded-bitexact", ok)


finish()
