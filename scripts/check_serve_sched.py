"""8-device continuous-batching scheduler checks (run via test_distributed).

On the (2 data x 4 model) emulated mesh: the slot-isolation invariant —
greedy request tokens bit-identical interleaved (batch-sharded slot pool,
slot splice across the sharded batch axis) vs solo batch-of-1 — plus
sampled-request reproducibility, for the dense and moe families with
quantized weight gathers; and one CHUNKED-prefill case (the KV ring is
sequence-sharded over the 4-way model axis, so per-chunk ring writes and
the chunk_attend psum cross shard boundaries only an 8-device run
exercises).
"""
from _mesh_common import check, finish, force_host_devices, mesh_and_spec

force_host_devices(8)

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402
from jax.sharding import PartitionSpec as P  # noqa: E402

from repro.core.qsdp import MeshSpec, QSDPConfig  # noqa: E402
from repro.models.config import ModelConfig  # noqa: E402
from repro.models.decode import DecodeSpec  # noqa: E402
from repro.models.transformer import Model  # noqa: E402
from repro.serve import (ContinuousScheduler, Request,  # noqa: E402
                         ServeEngine, make_sample_params)

mesh, ms = mesh_and_spec((2, 4))
GATHER_KEY = jax.random.PRNGKey(7)
RING = 32  # multiple of model_par=4
VOCAB = 256

for arch_kw in (dict(arch_type="dense", n_layers=2, d_model=64,
                     vocab_size=VOCAB, n_heads=4, n_kv_heads=2, head_dim=16,
                     d_ff=128),
                dict(arch_type="moe", n_layers=2, d_model=64,
                     vocab_size=VOCAB, n_heads=4, n_kv_heads=2, head_dim=16,
                     d_ff=128, n_experts=4, moe_top_k=2)):
    cfg = ModelConfig(name="sched8", **arch_kw)
    m = Model(cfg, ms, QSDPConfig(min_quant_size=256))
    params = m.init_params(jax.random.PRNGKey(0))
    fam = cfg.arch_type

    # batch-SHARDED slot pool: 4 slots over the 2-way data axis — the slot
    # splice crosses shard boundaries, which only an 8-device run exercises
    spec = DecodeSpec(cache_len=RING, batch_global=4, batch_sharded=True,
                      sampling=True)
    sched = ContinuousScheduler(m, mesh, spec, params, gather_key=GATHER_KEY)

    rng = np.random.default_rng(1)
    reqs = [Request(rid=f"r{i}",
                    prompt=rng.integers(0, VOCAB, size=int(pl)).tolist(),
                    max_new_tokens=int(g), temperature=t, top_k=k, seed=i)
            for i, (pl, g, t, k) in enumerate(
                [(4, 5, 0.0, 0), (8, 3, 0.0, 0), (6, 6, 1.1, 4),
                 (4, 4, 0.0, 0), (8, 5, 0.8, 0), (6, 2, 0.0, 0)])]
    for r in reqs:
        sched.submit(r)
    done = sched.run()

    solo = ServeEngine(m, mesh, DecodeSpec(cache_len=RING, batch_global=1,
                                           batch_sharded=False, sampling=True))
    worst = ""
    ok = True
    for r in reqs:
        sample = make_sample_params(r.temperature, r.top_k, r.seed)
        ref = np.asarray(jax.device_get(solo.generate(
            params, {"tokens": jnp.asarray(np.asarray(r.prompt, np.int32)[None])},
            {"tokens": P(None)}, n_tokens=r.max_new_tokens, key=GATHER_KEY,
            sample=sample, fold_step_keys=False)))[0]
        if not np.array_equal(done[r.rid].tokens, ref):
            ok = False
            worst = f"{r.rid}: got={done[r.rid].tokens.tolist()} ref={ref.tolist()}"
    check(f"sched-interleaved-vs-solo-{fam}", ok, worst)

    # reproducibility: a second scheduler instance replays identically
    sched2 = ContinuousScheduler(m, mesh, spec, params, gather_key=GATHER_KEY)
    for r in reqs:
        sched2.submit(Request(rid=r.rid, prompt=r.prompt,
                              max_new_tokens=r.max_new_tokens,
                              temperature=r.temperature, top_k=r.top_k,
                              seed=r.seed))
    done2 = sched2.run()
    check(f"sched-replay-identical-{fam}",
          all(np.array_equal(done[r.rid].tokens, done2[r.rid].tokens)
              for r in reqs))

    if fam == "dense":
        # chunked admission over the batch-sharded pool: per-chunk ring
        # writes at per-slot offsets, multi-chunk prompts, mixed lengths —
        # greedy tokens must bit-match the solo batch-of-1 run with the
        # SAME chunk decomposition (generate(prefill_chunk=4)), with the
        # jit cache bounded by the bucket count
        sched3 = ContinuousScheduler(m, mesh, spec, params,
                                     gather_key=GATHER_KEY,
                                     prefill_chunk=4, prefill_buckets=3)
        reqs3 = [Request(rid=f"ck{i}",
                         prompt=rng.integers(0, VOCAB, size=int(pl)).tolist(),
                         max_new_tokens=int(g))
                 for i, (pl, g) in enumerate(
                     [(9, 4), (3, 3), (13, 5), (6, 2), (11, 4)])]
        for r in reqs3:
            sched3.submit(r)
        done3 = sched3.run()
        worst = ""
        ok = True
        for r in reqs3:
            ref = np.asarray(jax.device_get(solo.generate(
                params,
                {"tokens": jnp.asarray(np.asarray(r.prompt, np.int32)[None])},
                {"tokens": P(None)}, n_tokens=r.max_new_tokens,
                key=GATHER_KEY, fold_step_keys=False, prefill_chunk=4)))[0]
            if not np.array_equal(done3[r.rid].tokens, ref):
                ok = False
                worst = (f"{r.rid}: got={done3[r.rid].tokens.tolist()} "
                         f"ref={ref.tolist()}")
        check("sched-chunked-vs-solo-dense", ok, worst)
        check("sched-chunked-traces-bounded",
              sched3.stats()["prefill_traces"] <= 3
              and len(sched3.engine._chunk_steps) <= 3,
              str(sched3.stats()["prefill_traces"]))

        # bucket > s_loc regime: a padded chunk spans more global ring
        # slots than one rank holds, so local ring indices alias across
        # owners — the masked drop-scatter must stay collision-free
        # (regression: duplicate scatter targets made tokens depend on the
        # bucket a chunk was padded into)
        sched4 = ContinuousScheduler(m, mesh, spec, params,
                                     gather_key=GATHER_KEY,
                                     prefill_chunk=16, prefill_buckets=2)
        reqs4 = [Request(rid=f"bk{i}",
                         prompt=rng.integers(0, VOCAB, size=int(pl)).tolist(),
                         max_new_tokens=3)
                 for i, pl in enumerate((13, 9, 17))]
        for r in reqs4:
            sched4.submit(r)
        done4 = sched4.run()
        ok = all(
            np.array_equal(
                done4[r.rid].tokens,
                np.asarray(jax.device_get(solo.generate(
                    params,
                    {"tokens": jnp.asarray(np.asarray(r.prompt, np.int32)[None])},
                    {"tokens": P(None)}, n_tokens=r.max_new_tokens,
                    key=GATHER_KEY, fold_step_keys=False, prefill_chunk=16,
                    prefill_buckets=2)))[0])
            for r in reqs4)
        check("sched-chunked-bucket-gt-sloc", ok)

        # paged KV block pool: every block is sequence-sharded over the
        # 4-way model axis, so block-table gathers and the drop-scatter
        # writes cross shard boundaries on every step.  Greedy + sampled
        # tokens must bit-match the solo batch-of-1 paged run (identity
        # block table, same chunk decomposition), and prefix sharing on a
        # repeated system prompt must engage without changing ANY token
        # (share vs no-share is the same paged float path).
        pspec = DecodeSpec(cache_len=RING, batch_global=4,
                           batch_sharded=False, sampling=True,
                           kv_block_size=8)
        solo_p = ServeEngine(m, mesh, DecodeSpec(
            cache_len=RING, batch_global=1, batch_sharded=False,
            sampling=True, kv_block_size=8))
        system = rng.integers(0, VOCAB, size=8).tolist()
        reqs5 = [Request(rid=f"pg{i}",
                         prompt=system
                         + rng.integers(0, VOCAB, size=tail).tolist(),
                         max_new_tokens=int(g), temperature=t, top_k=k,
                         seed=100 + i)
                 for i, (tail, g, t, k) in enumerate(
                     [(3, 4, 0.0, 0), (5, 3, 0.9, 4), (7, 5, 0.0, 0),
                      (2, 3, 0.0, 0), (9, 4, 1.2, 0), (4, 2, 0.0, 0)])]
        outs, hits = {}, 0
        for share in (True, False):
            s5 = ContinuousScheduler(m, mesh, pspec, params,
                                     gather_key=GATHER_KEY,
                                     prefill_chunk=8, prefill_buckets=3,
                                     kv_prefix_share=share)
            for r in reqs5:
                s5.submit(Request(rid=r.rid, prompt=r.prompt,
                                  max_new_tokens=r.max_new_tokens,
                                  temperature=r.temperature, top_k=r.top_k,
                                  seed=r.seed))
            outs[share] = s5.run()
            s5.pool.check_invariants()
            if share:
                hits = s5.stats()["prefix_hits"]
        worst = ""
        ok = True
        for r in reqs5:
            sample = make_sample_params(r.temperature, r.top_k, r.seed)
            ref = np.asarray(jax.device_get(solo_p.generate(
                params,
                {"tokens": jnp.asarray(np.asarray(r.prompt, np.int32)[None])},
                {"tokens": P(None)}, n_tokens=r.max_new_tokens,
                key=GATHER_KEY, sample=sample, fold_step_keys=False,
                prefill_chunk=8, prefill_buckets=3)))[0]
            if not np.array_equal(outs[True][r.rid].tokens, ref):
                ok = False
                worst = (f"{r.rid}: got={outs[True][r.rid].tokens.tolist()} "
                         f"ref={ref.tolist()}")
        check("sched-paged-vs-solo-dense", ok, worst)
        check("sched-paged-share-invariant",
              all(np.array_equal(outs[True][r.rid].tokens,
                                 outs[False][r.rid].tokens)
                  for r in reqs5) and hits > 0, f"prefix_hits={hits}")

        # self-speculative decode on the 8-device mesh: the 4-bit draft
        # forward and the pooled multi-token verify both run sharded (the
        # verify's per-token ring writes and chunk psum cross the 4-way
        # model axis).  Mixed max_new_tokens + staggered retirement give
        # heterogeneous per-slot draft depths (n_spec mixes 1..draft_depth
        # in one launch); committed tokens must bit-match the
        # NON-speculative solo reference on the ring AND paged paths, and
        # speculation must actually engage (verify launches, > 0 committed
        # speculative tokens).
        rng6 = np.random.default_rng(6)
        reqs6 = [Request(rid=f"sp{i}",
                         prompt=rng6.integers(0, VOCAB, size=int(pl)).tolist(),
                         max_new_tokens=int(g), temperature=t, top_k=k,
                         seed=200 + i)
                 for i, (pl, g, t, k) in enumerate(
                     [(4, 6, 0.0, 0), (8, 2, 0.0, 0), (6, 5, 0.9, 4),
                      (5, 1, 0.0, 0), (7, 4, 0.0, 0), (6, 3, 1.1, 0)])]
        for mode, dspec, ref_eng, ref_kw in (
                ("ring",
                 DecodeSpec(cache_len=RING, batch_global=4,
                            batch_sharded=True, sampling=True,
                            draft_bits=4, draft_depth=3),
                 solo, {}),
                ("paged",
                 DecodeSpec(cache_len=RING, batch_global=4,
                            batch_sharded=False, sampling=True,
                            kv_block_size=8, draft_bits=4, draft_depth=3),
                 solo_p, dict(prefill_chunk=8, prefill_buckets=3))):
            s6 = ContinuousScheduler(m, mesh, dspec, params,
                                     gather_key=GATHER_KEY, **ref_kw)
            for r in reqs6:
                s6.submit(Request(rid=r.rid, prompt=r.prompt,
                                  max_new_tokens=r.max_new_tokens,
                                  temperature=r.temperature, top_k=r.top_k,
                                  seed=r.seed))
            done6 = s6.run()
            st6 = s6.stats()
            worst = ""
            ok = True
            for r in reqs6:
                sample = make_sample_params(r.temperature, r.top_k, r.seed)
                ref = np.asarray(jax.device_get(ref_eng.generate(
                    params,
                    {"tokens": jnp.asarray(
                        np.asarray(r.prompt, np.int32)[None])},
                    {"tokens": P(None)}, n_tokens=r.max_new_tokens,
                    key=GATHER_KEY, sample=sample, fold_step_keys=False,
                    **ref_kw)))[0]
                if not np.array_equal(done6[r.rid].tokens, ref):
                    ok = False
                    worst = (f"{r.rid}: got={done6[r.rid].tokens.tolist()} "
                             f"ref={ref.tolist()}")
            check(f"sched-speculative-vs-solo-{mode}", ok, worst)
            check(f"sched-speculative-engaged-{mode}",
                  st6["verify_launches"] > 0 and st6["spec_tokens"] > 0
                  and st6["accepted_per_launch"] > 0,
                  f"acc/launch={st6['accepted_per_launch']:.2f} "
                  f"l/tok={st6['launches_per_token']:.2f}")

finish()
