"""Cost-model conformance on REAL multi-device meshes (8 emulated CPU
devices).  Invoked by tests/test_distributed.py.

``repro.tune.cost_model.predict_hlo_gather_counts`` claims to predict the
all-gather launch count the compiled HLO shows for one gather of a layer
group.  The deployment-plan autotuner ranks candidates with it, so pin the
prediction against ``roofline.hlo_analyzer`` counts of actually-compiled
programs:

  1. (2,4) mesh, full forward: the per-layer MARGINAL all-gather count
     (stack 4 vs stack 2) equals the prediction for per-tensor (23),
     coalesced (1), and both threshold policies (veto -> 23, accept -> 1).
  2. (2,4) mesh, mixed per-layer policy: a threshold between the embed
     buffer and the layers buffer coalesces the small group while the big
     one falls back to per-tensor — single-gather compiles show 1 vs 23.
  3. (2,2,2) pod mesh, hierarchical engine gathers: per-tensor quantized
     = 3 launches per level (6), coalesced = 1 per level (2).

Exit code 0 + 'ALL-OK' on success.
"""
from _mesh_common import check, finish, force_host_devices, mesh_and_spec

force_host_devices(8)

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map
from repro.core.qsdp import MeshSpec, QSDPConfig
from repro.models.config import ModelConfig
from repro.models.transformer import Model
from repro.roofline.hlo_analyzer import analyze_hlo
from repro.tune.cost_model import layer_groups, predict_hlo_gather_counts

mesh24, ms24 = mesh_and_spec((2, 4))
mcfg = ModelConfig(name="t", arch_type="dense", n_layers=2, d_model=128,
                   vocab_size=256, n_heads=8, n_kv_heads=4, head_dim=16,
                   d_ff=256)


def fwd_ag_counts(qcfg, n_layers):
    """All-gather count of the compiled forward at a given stack depth."""
    c = dataclasses.replace(mcfg, n_layers=n_layers)
    model = Model(c, ms24, qcfg)
    params = model.init_params(jax.random.PRNGKey(30))

    @partial(shard_map, mesh=mesh24,
             in_specs=(model.param_pspecs(),
                       {"tokens": P(("data",)), "labels": P(("data",))}, P()),
             out_specs=P(), check_vma=False)
    def f(p, b, k):
        return jax.lax.pmean(model.loss_fn(p, b, k), ("data", "model"))

    tokens = jnp.zeros((4, 16), jnp.int32)
    batch = {"tokens": tokens, "labels": tokens}
    hlo = jax.jit(f).lower(params, batch,
                           jax.random.PRNGKey(31)).compile().as_text()
    return analyze_hlo(hlo)["collectives"]["counts"]["all-gather"]


def marginal(qcfg):
    return (fwd_ag_counts(qcfg, 4) - fwd_ag_counts(qcfg, 2)) / 2


def single_gather_counts(model, name, qkey=40):
    """All-gather count of ONE compiled engine.gather of `name`."""
    params = model.init_params(jax.random.PRNGKey(qkey))
    pspec = model.param_pspecs()[name]
    eng = model.engine

    @partial(shard_map, mesh=jax.make_mesh(model.ms.shape, model.ms.axes),
             in_specs=(pspec, P()), out_specs=P(), check_vma=False)
    def f(w, k):
        full = eng.gather(name, w, k[0])
        return jax.lax.psum(jnp.sum(full.astype(jnp.float32)), model.ms.axes)

    hlo = jax.jit(f).lower(params[name],
                           jax.random.PRNGKey(41)[None]).compile().as_text()
    return analyze_hlo(hlo)["collectives"]["counts"]["all-gather"]


# ---------------------------------------------------------------------------
# 1. (2,4) forward marginals vs predictions
# ---------------------------------------------------------------------------

probe = Model(mcfg, ms24, QSDPConfig(min_quant_size=256, coalesce=True)).engine
layer_names = [n for n in sorted(probe.specs) if n.startswith("layers/")]
buf_layers = probe.layer_wire_bytes(tuple(layer_names))
buf_embed = probe.layer_wire_bytes(("embed",))
assert buf_embed < buf_layers, (buf_embed, buf_layers)

for tag, qkw, forced in (
    ("per-tensor", dict(coalesce=False), False),
    ("coalesced", dict(coalesce=True), True),
    ("threshold-veto", dict(coalesce=True, coalesce_max_bytes=0), None),
    ("threshold-accept",
     dict(coalesce=True, coalesce_max_bytes=buf_layers), None),
):
    qcfg = QSDPConfig(min_quant_size=256, **qkw)
    eng = Model(mcfg, ms24, qcfg).engine
    pred = predict_hlo_gather_counts(eng, layer_names, coalesced=forced)
    got = marginal(qcfg)
    check(f"fwd-marginal-{tag}", got == pred, f"hlo={got} predicted={pred}")

# ---------------------------------------------------------------------------
# 2. (2,4) mixed per-layer policy under one threshold
# ---------------------------------------------------------------------------

mid = (buf_embed + buf_layers) // 2
q_mid = QSDPConfig(min_quant_size=256, coalesce=True, coalesce_max_bytes=mid)
m_mid = Model(mcfg, ms24, q_mid)
check("policy-embed-coalesces", m_mid.engine.layer_coalesced(("embed",)))
check("policy-layers-fall-back",
      not m_mid.engine.layer_coalesced(tuple(layer_names)))
got_embed = single_gather_counts(m_mid, "embed")
pred_embed = predict_hlo_gather_counts(m_mid.engine, ["embed"])
check("single-gather-embed-coalesced", got_embed == pred_embed == 1,
      f"hlo={got_embed} predicted={pred_embed}")
got_marg = marginal(q_mid)
pred_marg = predict_hlo_gather_counts(m_mid.engine, layer_names)
check("fwd-marginal-mixed-policy", got_marg == pred_marg == 23,
      f"hlo={got_marg} predicted={pred_marg}")
# and the small fp singleton is invisible either way (1 launch)
got_fn = single_gather_counts(m_mid, "final_norm")
pred_fn = predict_hlo_gather_counts(m_mid.engine, ["final_norm"])
check("single-gather-final-norm", got_fn == pred_fn == 1,
      f"hlo={got_fn} predicted={pred_fn}")

# ---------------------------------------------------------------------------
# 3. (2,2,2) pod mesh: hierarchical gathers
# ---------------------------------------------------------------------------

ms_pod = MeshSpec(axes=("pod", "data", "model"), shape=(2, 2, 2))
for tag, qkw, pred_want in (
    ("hier-per-tensor", dict(coalesce=False, hierarchical=True), 6),
    ("hier-coalesced", dict(coalesce=True, hierarchical=True), 2),
):
    model = Model(mcfg, ms_pod, QSDPConfig(min_quant_size=256, **qkw))
    pred = predict_hlo_gather_counts(model.engine, ["embed"])
    got = single_gather_counts(model, "embed")
    check(f"single-gather-{tag}", got == pred == pred_want,
          f"hlo={got} predicted={pred} want={pred_want}")

# sanity: the groups the autotuner iterates exist and cover the model
groups = {g for g, _, _ in layer_groups(probe)}
check("layer-groups-cover", {"layers", "embed", "final_norm"} <= groups,
      str(sorted(groups)))

finish()
