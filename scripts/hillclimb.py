"""§Perf hillclimb driver: run one (arch, shape, mesh) pair under a named
set of optimization knobs and print the roofline-term deltas vs baseline.

  PYTHONPATH=src python scripts/hillclimb.py --arch qwen2_vl_72b \
      --shape train_4k --variant attn_bf16 [--multi-pod]

Variants compose QSDPConfig/engine knobs; results append to
results/hillclimb.jsonl for the EXPERIMENTS.md §Perf log.
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
import argparse
import json

import dataclasses

from repro.core.qsdp import QSDPConfig
from repro.launch.dryrun import run_one

VARIANTS = {
    # paper-faithful QSDP baseline
    "baseline": dict(),
    # P1: bf16 attention matmul operands (f32 accumulation)
    "attn_bf16": dict(attn_bf16=True),
    # P1b: + remat policy saving dot outputs (less backward recompute)
    "attn_bf16+dots": dict(attn_bf16=True, remat_policy="dots"),
    "dots": dict(remat_policy="dots"),
    # P2: serving-grade weight compression (4-bit gathers)
    "w4": dict(weight_bits=4),
    "w4g8": dict(weight_bits=4, grad_bits=8),
    "w4g4": dict(weight_bits=4, grad_bits=4),
    # bigger buckets: fewer scale/zero vectors on the wire
    "bucket4096": dict(bucket_size=4096),
    "w4_bucket4096": dict(weight_bits=4, bucket_size=4096),
    # hierarchical 2-level collectives (multi-pod only)
    "hierarchical": dict(hierarchical=True),
    "attn_bf16+w4": dict(attn_bf16=True, weight_bits=4),
    "bf16_wire_grads": dict(quantize_grads=False),  # fp path comparison
    # dequantize gathered weights straight to bf16 (no f32 intermediate)
    "deq_bf16": dict(dequant_to_compute=True),
    "deq_bf16+w4": dict(dequant_to_compute=True, weight_bits=4),
    "deq_bf16+attn_bf16": dict(dequant_to_compute=True, attn_bf16=True),
    "deq_bf16+attn_bf16+dots": dict(dequant_to_compute=True, attn_bf16=True,
                                    remat_policy="dots"),
    "deq_bf16+w4_bucket4096": dict(dequant_to_compute=True, weight_bits=4,
                                   bucket_size=4096),
    "deq_bf16+hier": dict(dequant_to_compute=True, hierarchical=True),
    "all_in": dict(dequant_to_compute=True, attn_bf16=True,
                   remat_policy="dots", grad_bits=4),
    "rng16": dict(rand_bits=16),
    "attn_bf16+rng16": dict(attn_bf16=True, rand_bits=16),
    "w4g4+rng16+bucket4096": dict(weight_bits=4, grad_bits=4, rand_bits=16,
                                  bucket_size=4096),
    "best_train": dict(attn_bf16=True, rand_bits=16, dequant_to_compute=True),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--variant", required=True, choices=sorted(VARIANTS))
    ap.add_argument("--n-micro", type=int, default=None)
    ap.add_argument("--out", default="results/hillclimb.jsonl")
    args = ap.parse_args()

    qsdp = QSDPConfig(**VARIANTS[args.variant])
    r = run_one(args.arch, args.shape, multi_pod=args.multi_pod, qsdp=qsdp,
                n_micro=args.n_micro)
    r["variant"] = args.variant
    r["n_micro"] = args.n_micro
    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    with open(args.out, "a") as f:
        f.write(json.dumps(r) + "\n")
    print(f"\nvariant={args.variant}: Tc={r['t_compute']*1e3:.1f}ms "
          f"Tm=[{r['t_memory_min']*1e3:.1f},{r['t_memory']*1e3:.1f}]ms "
          f"Tx={r['t_collective']*1e3:.1f}ms bound={r['bottleneck']} "
          f"useful={r['useful_flops_ratio']:.3f}")


if __name__ == "__main__":
    main()
