"""Resumable dry-run sweep: all (arch x shape x mesh) pairs -> JSONL.

Each record is appended as soon as its pair compiles, so the sweep can be
killed/restarted; pairs already present are skipped.

  PYTHONPATH=src python scripts/run_dryrun_sweep.py [--out results/dryrun.jsonl]
      [--meshes 16x16 2x16x16] [--archs ...] [--shapes ...] [--qsdp|--baseline]
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
import argparse
import gc
import json
import sys
import traceback

import jax


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="results/dryrun.jsonl")
    ap.add_argument("--archs", nargs="*", default=None)
    ap.add_argument("--shapes", nargs="*", default=None)
    ap.add_argument("--meshes", nargs="*", default=["16x16", "2x16x16"])
    ap.add_argument("--baseline", action="store_true")
    ap.add_argument("--tag", default=None)
    args = ap.parse_args()

    from repro import configs
    from repro.core.qsdp import QSDPConfig
    from repro.launch.dryrun import run_one
    from repro.models.config import SHAPES

    qsdp = QSDPConfig.baseline() if args.baseline else QSDPConfig()
    tag = args.tag or ("fsdp-baseline" if args.baseline else "qsdp-w8g8")

    archs = args.archs or configs.ASSIGNED
    shapes = args.shapes or list(SHAPES)

    done = set()
    if os.path.exists(args.out):
        with open(args.out) as f:
            for line in f:
                try:
                    r = json.loads(line)
                    done.add((r.get("tag"), r["arch"], r["shape"], r["mesh"]))
                except Exception:
                    pass
    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)

    # cheap-first ordering: fewer layers x smaller d_model first
    def cost(a):
        c = configs.get_config(a)
        return c.n_layers * c.d_model * max(c.d_model, 1)

    pairs = [(a, s, m) for a in sorted(archs, key=cost) for s in shapes
             for m in args.meshes]
    for arch, shape, mesh_name in pairs:
        key = (tag, arch, shape, mesh_name)
        if key in done:
            continue
        mp = mesh_name == "2x16x16"
        print(f"== {tag} {arch} x {shape} x {mesh_name}", flush=True)
        try:
            r = run_one(arch, shape, multi_pod=mp, qsdp=qsdp,
                        hlo_dir=os.path.join(os.path.dirname(args.out) or ".", "hlo"),
                        tag=tag)
        except Exception as e:
            traceback.print_exc()
            r = dict(arch=arch, shape=shape, mesh=mesh_name, ok=False, error=str(e))
        r["tag"] = tag
        with open(args.out, "a") as f:
            f.write(json.dumps(r) + "\n")
        jax.clear_caches()
        gc.collect()
    print("sweep complete")


if __name__ == "__main__":
    main()
