"""Quick sanity: forward+backward one microbatch for each arch family on an
8-device CPU mesh. Run: PYTHONPATH=src XLA_FLAGS=--xla_force_host_platform_device_count=8 python scripts/sanity_families.py"""
import jax, jax.numpy as jnp
from functools import partial
from jax.sharding import PartitionSpec as P
from repro.compat import shard_map
from repro.core.qsdp import MeshSpec, QSDPConfig
from repro.models.config import ModelConfig
from repro.models.transformer import Model

mesh = jax.make_mesh((2, 4), ("data", "model"))
ms = MeshSpec(axes=("data", "model"), shape=(2, 4))
qcfg = QSDPConfig(min_quant_size=256)

FAMS = {
    "dense": dict(arch_type="dense", n_layers=2, d_model=128, vocab_size=512,
                  n_heads=8, n_kv_heads=4, head_dim=16, d_ff=256),
    "dense_bias": dict(arch_type="dense", n_layers=2, d_model=128, vocab_size=512,
                       n_heads=8, n_kv_heads=8, head_dim=16, d_ff=256, qkv_bias=True),
    "moe": dict(arch_type="moe", n_layers=2, d_model=128, vocab_size=512,
                n_heads=8, n_kv_heads=4, head_dim=16, n_experts=4, moe_top_k=2, moe_d_ff=128),
    "ssm": dict(arch_type="ssm", n_layers=2, d_model=128, vocab_size=512,
                ssm_state=16, ssm_head_dim=16, ssm_chunk=16),
    "hybrid": dict(arch_type="hybrid", n_layers=3, d_model=128, vocab_size=512,
                   n_heads=8, n_kv_heads=8, head_dim=16, d_ff=256,
                   ssm_state=16, ssm_head_dim=16, ssm_chunk=16, hybrid_attn_every=2),
    "vlm": dict(arch_type="vlm", n_layers=2, d_model=128, vocab_size=512,
                n_heads=8, n_kv_heads=4, head_dim=16, d_ff=256, rope_mode="mrope",
                mrope_sections=(4, 2, 2)),
    "audio": dict(arch_type="audio", n_layers=2, n_enc_layers=2, d_model=128, vocab_size=512,
                  n_heads=8, n_kv_heads=8, head_dim=16, d_ff=256, tie_embeddings=False),
}

B, S = 4, 32
for name, kw in FAMS.items():
    cfg = ModelConfig(name=name, **kw)
    m = Model(cfg, ms, qcfg)
    params = m.init_params(jax.random.PRNGKey(0))
    pspecs = m.param_pspecs()
    batch = {"tokens": jnp.ones((B, S), jnp.int32), "labels": jnp.ones((B, S), jnp.int32)}
    bspecs = {"tokens": P(("data",)), "labels": P(("data",))}
    if kw["arch_type"] == "vlm":
        batch["vision_embeds"] = jnp.zeros((B, S, 128), jnp.float32)
        batch["vision_mask"] = jnp.zeros((B, S), bool)
        batch["positions"] = jnp.broadcast_to(jnp.arange(S), (3, B, S))
        bspecs["vision_embeds"] = P(("data",)); bspecs["vision_mask"] = P(("data",))
        bspecs["positions"] = P(None, ("data",))
    if kw["arch_type"] == "audio":
        batch["audio_embeds"] = jnp.zeros((B, S // 2, 128), jnp.float32)
        bspecs["audio_embeds"] = P(("data",))

    @partial(shard_map, mesh=mesh, in_specs=(pspecs, bspecs, P()), out_specs=P(), check_vma=False)
    def step(params, batch, key):
        loss, grads = jax.value_and_grad(m.loss_fn)(params, batch, key[0])
        gnorm = jnp.sqrt(sum(jnp.sum(g.astype(jnp.float32) ** 2) for g in jax.tree.leaves(grads)))
        return jax.lax.pmean(loss, ("data", "model")), jax.lax.pmax(gnorm, ("data", "model"))

    with mesh:
        loss, gnorm = jax.jit(step)(params, batch, jax.random.PRNGKey(1)[None])
    ok = bool(jnp.isfinite(loss)) and bool(jnp.isfinite(gnorm))
    print(f"{name:12s} loss={float(loss):.4f} gnorm={float(gnorm):.4f} {'OK' if ok else 'FAIL'}")
