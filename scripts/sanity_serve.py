"""Sanity: train step (grad accum + AdamW) and prefill->decode for each family.
Run: PYTHONPATH=src XLA_FLAGS=--xla_force_host_platform_device_count=8 python scripts/sanity_serve.py
"""
import jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.core.qsdp import MeshSpec, QSDPConfig
from repro.models.config import ModelConfig
from repro.models.transformer import Model
from repro.models.decode import DecodeModel, DecodeSpec
from repro.serve.engine import ServeEngine
from repro.optim import AdamWConfig, make_adamw
from repro.train.step import init_train_state, make_jitted_train_step
from repro.data import SyntheticLM

mesh = jax.make_mesh((2, 4), ("data", "model"))
ms = MeshSpec(axes=("data", "model"), shape=(2, 4))
qcfg = QSDPConfig(min_quant_size=256)

FAMS = {
    "dense": dict(arch_type="dense", n_layers=2, d_model=128, vocab_size=512,
                  n_heads=8, n_kv_heads=4, head_dim=16, d_ff=256),
    "moe": dict(arch_type="moe", n_layers=2, d_model=128, vocab_size=512,
                n_heads=8, n_kv_heads=16, head_dim=16, n_experts=4, moe_top_k=2, moe_d_ff=128),
    "ssm": dict(arch_type="ssm", n_layers=2, d_model=128, vocab_size=512,
                ssm_state=16, ssm_head_dim=16, ssm_chunk=16),
    "hybrid": dict(arch_type="hybrid", n_layers=3, d_model=128, vocab_size=512,
                   n_heads=8, n_kv_heads=8, head_dim=16, d_ff=256,
                   ssm_state=16, ssm_head_dim=16, ssm_chunk=16, hybrid_attn_every=2),
    "vlm": dict(arch_type="vlm", n_layers=2, d_model=128, vocab_size=512,
                n_heads=8, n_kv_heads=4, head_dim=16, d_ff=256, rope_mode="mrope",
                mrope_sections=(4, 2, 2)),
    "audio": dict(arch_type="audio", n_layers=2, n_enc_layers=2, d_model=128, vocab_size=512,
                  n_heads=8, n_kv_heads=8, head_dim=16, d_ff=256, tie_embeddings=False),
}

B, S = 8, 32
for name, kw in FAMS.items():
    cfg = ModelConfig(name=name, **kw)
    m = Model(cfg, ms, qcfg)
    opt = make_adamw(AdamWConfig(lr=1e-3))
    state = init_train_state(m, opt, jax.random.PRNGKey(0))

    data = SyntheticLM(vocab_size=512, seq_len=S, global_batch=B, seed=1)
    tokens, labels = data.sample(0)
    batch = {"tokens": tokens, "labels": labels}
    bspecs = {"tokens": P(("data",)), "labels": P(("data",))}
    if kw["arch_type"] == "vlm":
        batch["vision_embeds"] = jnp.zeros((B, S, 128), jnp.float32)
        batch["vision_mask"] = jnp.zeros((B, S), bool)
        batch["positions"] = jnp.broadcast_to(jnp.arange(S), (3, B, S))
        bspecs.update(vision_embeds=P(("data",)), vision_mask=P(("data",)),
                      positions=P(None, ("data",)))
    if kw["arch_type"] == "audio":
        batch["audio_embeds"] = 0.1 * jax.random.normal(jax.random.PRNGKey(9), (B, 16, 128))
        bspecs["audio_embeds"] = P(("data",))

    step = make_jitted_train_step(m, opt, mesh, n_micro=2, batch_pspec=bspecs)
    with mesh:
        l0 = None
        for i in range(3):
            state, metrics = step(state, batch, jax.random.fold_in(jax.random.PRNGKey(7), i))
            if l0 is None:
                l0 = float(metrics["loss"])
        l1 = float(metrics["loss"])
    print(f"{name:8s} train: loss {l0:.4f} -> {l1:.4f}  gnorm {float(metrics['grad_norm']):.3f}")

    # ---- serve: prefill + 4 decode steps ----
    spec = DecodeSpec(cache_len=0 if kw["arch_type"] == "ssm" else S,
                      batch_global=B, batch_sharded=True,
                      enc_len=16 if kw["arch_type"] == "audio" else 0)
    eng = ServeEngine(m, mesh, spec)
    prompt = dict(batch)
    prompt.pop("labels")
    ps = dict(bspecs); ps.pop("labels")
    with mesh:
        toks = eng.generate(state.params, prompt, ps, n_tokens=4)
    ok = bool(jnp.all((toks >= 0) & (toks < 512)))
    print(f"{name:8s} serve: tokens shape {toks.shape} ok={ok} sample={toks[0].tolist()}")
