"""Static analysis (`qlint`): trace-time invariant auditor.

Four passes, none of which executes a training step:

  lint        AST conventions over src/ (host syncs in the scheduler loop,
              literal PRNGKeys in library code, kernel-dispatch bypasses)
  key         static enumeration of every quantization-key derivation over
              the full param trees of all configs/ families; (key, tensor)
              uniqueness + FNV hash-collision detection
  jaxpr       trace the jitted train step / decode_fn / prefill_chunk_fn /
              verify_step to ClosedJaxprs and walk them for redundant
              quantize->dequantize->quantize round-trips, u8 wire buffers
              widened before a collective, and nondeterminism-hazard
              primitives on bit-identity-guarded paths
  collective  compile the forward for a mesh/DeploymentPlan and diff
              hlo_analyzer collective counts + wire bytes against
              tune.cost_model.predict_hlo_gather_counts

Run: ``PYTHONPATH=src python -m repro.analysis.qlint --all``.  Findings
carry stable rule IDs; ``qlint_baseline.json`` suppresses the justified
ones so CI gates on "no new findings".  Keep this module import-light —
the CLI must be able to set XLA_FLAGS before anything pulls in jax.
"""

from .findings import Finding, RULES, load_baseline, partition_findings

__all__ = ["Finding", "RULES", "load_baseline", "partition_findings"]
