"""Collective audit: compiled HLO vs the cost model's promises (QC301-303).

Generalizes the PR 2 "23 -> 1" HLO launch assertion into a reusable
checker: compile the forward at two stack depths on the requested mesh,
take the per-layer MARGINAL collective counts / wire bytes from
`roofline.hlo_analyzer`, and diff them against
`tune.cost_model.predict_hlo_gather_counts` plus the engine's analytic
wire-byte budget.  On a (1,1) mesh every collective degenerates to group
size 1 and is compiled away, so both sides must read zero — any surviving
launch is itself a finding.  With a DeploymentPlan the engine is built
from the plan's qsdp section and the plan's recorded per-group policies
are cross-checked against that engine (drift = a stale plan).

The count/byte differs are pure functions of analyzer output, so seeded
regression tests drive them with hand-written HLO text.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

from .findings import Finding

# marginal-byte slack: XLA may pad buffers to tile boundaries
WIRE_SLACK_FRAC = 0.10
WIRE_SLACK_BYTES = 4096


def diff_gather_counts(marginal_counts: dict, predicted: int,
                       tag: str) -> list[Finding]:
    """QC301 on any divergence between measured marginal collective counts
    and the cost model's prediction.  `marginal_counts` is the per-layer
    marginal of ``analyze_hlo(...)['collectives']['counts']``."""
    out = []
    got = marginal_counts.get("all-gather", 0)
    if got != predicted:
        out.append(Finding(
            "QC301", f"{tag}::all-gather",
            f"compiled marginal all-gather count {got} != cost-model "
            f"prediction {predicted}"))
    for kind, n in sorted(marginal_counts.items()):
        if kind not in ("all-gather", "reduce-scatter", "all-reduce") and n:
            out.append(Finding(
                "QC301", f"{tag}::unexpected::{kind}",
                f"{n} unexplained '{kind}' launch(es) in the forward "
                f"marginal (only gathers belong on this path)"))
    return out


def diff_wire_bytes(marginal_wire: float, budget: float,
                    tag: str) -> list[Finding]:
    """QC302 when marginal on-the-wire bytes exceed the analytic budget."""
    limit = budget * (1 + WIRE_SLACK_FRAC) + WIRE_SLACK_BYTES
    if marginal_wire > limit:
        return [Finding(
            "QC302", f"{tag}::all-gather-bytes",
            f"compiled marginal all-gather wire bytes {marginal_wire:.0f} "
            f"exceed analytic budget {budget:.0f} (+slack {limit:.0f})")]
    return []


def check_plan_drift(plan, engine, tag: str) -> list[Finding]:
    """QC303: the plan's recorded per-group policy/bytes must match the
    engine its own qsdp section builds."""
    out = []
    for lp in plan.layers:
        names = tuple(sorted(n for n in engine.specs
                             if n.startswith(f"{lp.group}/")))
        if not names:
            if lp.group in engine.specs:
                names = (lp.group,)
            else:
                out.append(Finding(
                    "QC303", f"{tag}::{lp.group}::missing",
                    f"plan records group '{lp.group}' absent from the "
                    f"engine's spec tree"))
                continue
        got_co = engine.layer_coalesced(names)
        got_bytes = engine.layer_wire_bytes(names)
        if got_co != lp.coalesce:
            out.append(Finding(
                "QC303", f"{tag}::{lp.group}::coalesce",
                f"plan says coalesce={lp.coalesce} for '{lp.group}' but the "
                f"engine built from the plan decides {got_co}"))
        if got_bytes != lp.wire_buffer_bytes:
            out.append(Finding(
                "QC303", f"{tag}::{lp.group}::wire-bytes",
                f"plan records {lp.wire_buffer_bytes} wire bytes for "
                f"'{lp.group}', engine computes {got_bytes}"))
    return out


# ---------------------------------------------------------------------------
# Compilation harness
# ---------------------------------------------------------------------------


def _fwd_collectives(mcfg, ms, qcfg, n_layers: int, mesh) -> dict:
    """Collectives section of the compiled forward at a stack depth."""
    from functools import partial

    import jax
    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as P

    from ..compat import shard_map
    from ..models.transformer import Model
    from ..roofline.hlo_analyzer import analyze_hlo

    c = dataclasses.replace(mcfg, n_layers=n_layers)
    model = Model(c, ms, qcfg)
    params = model.init_params(jax.random.PRNGKey(30))

    @partial(shard_map, mesh=mesh,
             in_specs=(model.param_pspecs(),
                       {"tokens": P(ms.fsdp_axes), "labels": P(ms.fsdp_axes)},
                       P()),
             out_specs=P(), check_vma=False)
    def f(p, b, k):
        return jax.lax.pmean(model.loss_fn(p, b, k), ms.axes)

    b = max(2, ms.fsdp_size)
    tokens = jnp.zeros((b, 16), jnp.int32)
    batch = {"tokens": tokens, "labels": tokens}
    hlo = jax.jit(f).lower(params, batch,
                           jax.random.PRNGKey(31)).compile().as_text()
    return analyze_hlo(hlo)["collectives"]


def audit(arch: str = "gpt-125m", mesh_shape=(1, 1),
          plan_path: Optional[str] = None, smoke: bool = True,
          report: Optional[dict] = None) -> list[Finding]:
    import jax

    from .. import configs
    from ..core.qsdp import MeshSpec, QSDPConfig
    from ..models.transformer import Model
    from ..tune.cost_model import predict_hlo_gather_counts

    ms = MeshSpec(axes=("data", "model"), shape=tuple(mesh_shape))
    mesh = jax.make_mesh(ms.shape, ms.axes)
    mcfg = configs.get_smoke(arch) if smoke else configs.get_config(arch)
    tag = f"{mcfg.name}@{ms.shape[0]}x{ms.shape[1]}"

    findings: list[Finding] = []
    if plan_path:
        from ..tune.plan import DeploymentPlan
        plan = DeploymentPlan.load(plan_path)
        plan.validate_mesh(ms.axes, ms.shape)
        qcfg = plan.to_qsdp_config(QSDPConfig(min_quant_size=256))
        engine = Model(mcfg, ms, qcfg).engine
        findings.extend(check_plan_drift(plan, engine, tag))
    else:
        qcfg = QSDPConfig(min_quant_size=256, coalesce=True)
        engine = Model(mcfg, ms, qcfg).engine

    layer_names = sorted(n for n in engine.specs if n.startswith("layers/"))
    predicted = predict_hlo_gather_counts(engine, layer_names)

    lo, hi = 2, 4
    c_lo = _fwd_collectives(mcfg, ms, qcfg, lo, mesh)
    c_hi = _fwd_collectives(mcfg, ms, qcfg, hi, mesh)
    marg_counts = {
        k: (c_hi["counts"].get(k, 0) - c_lo["counts"].get(k, 0)) / (hi - lo)
        for k in set(c_hi["counts"]) | set(c_lo["counts"])
    }
    marg_wire = (c_hi.get("all-gather", 0) - c_lo.get("all-gather", 0)) \
        / (hi - lo)

    findings.extend(diff_gather_counts(marg_counts, predicted, tag))
    # analytic budget: the gathered wire buffer crosses the ring once
    # -> B * (p-1)/p bytes on the wire per gather
    p = ms.fsdp_size
    buf = engine.layer_wire_bytes(tuple(layer_names))
    budget = buf * (p - 1) / p if p > 1 else 0.0
    findings.extend(diff_wire_bytes(marg_wire, budget, tag))

    if report is not None:
        report[tag] = {
            "predicted_marginal_all_gather": predicted,
            "marginal_counts": {k: v for k, v in sorted(marg_counts.items())
                                if v},
            "marginal_all_gather_wire_bytes": marg_wire,
            "analytic_wire_budget_bytes": budget,
            "layer_wire_buffer_bytes": buf,
        }
    return findings


def run(arch: str = "gpt-125m", mesh_shape=(1, 1),
        plan_path: Optional[str] = None,
        report: Optional[dict] = None) -> list[Finding]:
    return audit(arch, mesh_shape, plan_path, report=report)
