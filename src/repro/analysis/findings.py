"""Finding / rule / baseline plumbing shared by every qlint pass.

A finding's identity is ``(rule, site)`` — `site` is a stable fingerprint
that deliberately excludes line numbers (those shift on every edit), so a
baseline entry keeps suppressing the same finding across refactors.  The
checked-in ``qlint_baseline.json`` maps identities to one-line
justifications; CI fails on any finding without one.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Optional

BASELINE_VERSION = 1
REPORT_VERSION = 1

# Stable rule catalog.  IDs are append-only: never renumber, never reuse.
RULES = {
    # jaxpr audit
    "QJ101": "redundant quantize->dequantize->quantize round-trip (dequantized "
             "values re-quantized with no intervening compute)",
    "QJ102": "u8 wire buffer widened (convert_element_type u8->float) before "
             "a collective — bytes on the wire silently multiply",
    "QJ103": "nondeterminism-hazard primitive inside a bit-identity-guarded "
             "path (decode/prefill/verify must replay exactly)",
    # key audit
    "QK201": "quantization key collision: one derived key feeds two tensors "
             "(correlates shift-mode rounding noise, breaking unbiasedness)",
    "QK202": "FNV-1a name-hash collision in _h/_stable_hash key folds",
    "QK203": "reserved fold-salt overlap (microbatch/layer index range "
             "intersects a reserved salt or group offset)",
    # collective audit
    "QC301": "compiled collective launch count diverges from "
             "tune.cost_model.predict_hlo_gather_counts",
    "QC302": "compiled collective wire bytes exceed the analytic budget",
    "QC303": "DeploymentPlan drift: plan's recorded per-group policy/bytes "
             "disagree with the engine the plan builds",
    # source lint
    "QS401": "host sync (.item()/device_get/block_until_ready) inside "
             "ContinuousScheduler's per-step loop",
    "QS402": "jax.random.PRNGKey(<literal>) in library code (seeds belong to "
             "callers / launchers)",
    "QS403": "direct call into kernels/ bypassing the core.quant backend "
             "switch (import kernels.ops dispatchers instead)",
}


@dataclasses.dataclass(frozen=True)
class Finding:
    rule: str               # key into RULES
    site: str               # stable fingerprint, no line numbers
    message: str            # human-readable detail
    path: str = ""          # best-effort location (diagnostic only)
    line: int = 0           # best-effort location (diagnostic only)

    def ident(self) -> tuple[str, str]:
        return (self.rule, self.site)

    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["rule_doc"] = RULES.get(self.rule, "?")
        return d

    def __str__(self) -> str:
        loc = f" [{self.path}:{self.line}]" if self.path else ""
        return f"{self.rule} {self.site}{loc}: {self.message}"


def load_baseline(path: Optional[str]) -> dict[tuple[str, str], str]:
    """{(rule, site): justification}.  Missing file == empty baseline."""
    if not path:
        return {}
    try:
        with open(path) as f:
            doc = json.load(f)
    except FileNotFoundError:
        return {}
    if doc.get("version") != BASELINE_VERSION:
        raise ValueError(
            f"baseline version {doc.get('version')!r} != {BASELINE_VERSION} "
            f"— regenerate with qlint --update-baseline")
    out = {}
    for s in doc.get("suppressions", []):
        out[(s["rule"], s["site"])] = s.get("justify", "")
    return out


def save_baseline(path: str, findings: list[Finding],
                  old: Optional[dict[tuple[str, str], str]] = None) -> None:
    """Write every current finding as a suppression, keeping existing
    justifications; new entries get a TODO placeholder to be hand-edited."""
    old = old or {}
    sup = [
        {"rule": f.rule, "site": f.site,
         "justify": old.get(f.ident(), "TODO: justify or fix")}
        for f in sorted(set(findings), key=lambda f: f.ident())
    ]
    with open(path, "w") as f:
        json.dump({"version": BASELINE_VERSION, "suppressions": sup},
                  f, indent=1, sort_keys=False)
        f.write("\n")


def partition_findings(findings: list[Finding],
                       baseline: dict[tuple[str, str], str]):
    """-> (new, suppressed, unused_suppression_idents)."""
    new, suppressed = [], []
    seen = set()
    for f in findings:
        seen.add(f.ident())
        (suppressed if f.ident() in baseline else new).append(f)
    unused = sorted(k for k in baseline if k not in seen)
    return new, suppressed, unused


def make_report(per_pass: dict[str, list[Finding]],
                baseline: dict[tuple[str, str], str],
                meta: Optional[dict] = None) -> dict:
    """JSON-able audit report (the CI artifact)."""
    all_f = [f for fs in per_pass.values() for f in fs]
    new, suppressed, unused = partition_findings(all_f, baseline)
    return {
        "version": REPORT_VERSION,
        "meta": meta or {},
        "rules": RULES,
        "passes": {
            name: [f.to_dict() for f in fs] for name, fs in per_pass.items()
        },
        "new": [f.to_dict() for f in new],
        "suppressed": [
            {**f.to_dict(), "justify": baseline[f.ident()]} for f in suppressed
        ],
        "unused_suppressions": [list(k) for k in unused],
        "ok": not new,
    }
