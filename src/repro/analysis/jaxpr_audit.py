"""Jaxpr audit: walk traced programs for wire-format hazards (QJ101-103).

The audited programs are the jitted train step (f32 and quantized-state)
and the serve-side `decode_fn` / `prefill_chunk_fn` / `verify_fn` — traced
with `jax.make_jaxpr` on a (1,1) mesh, so nothing is compiled or executed.
Detection leans on two structural facts:

  * the quantizer/dequantizer entry points are jit-wrapped
    (`core.quant._quantize_jnp` / `_dequantize_jnp`,
    `kernels.ops.quantize_packed` / `dequantize_packed`), so inside any
    traced program they appear as `pjit` equations with stable names;
  * wire pack/unpack moves bytes with layout ops only (reshape / slice /
    concatenate / bitcast), so "no intervening compute" is checkable as
    reachability through a transparent-op whitelist.

Rules:
  QJ101  a dequantizer's output reaches a quantizer's input through
         transparent ops only — a redundant re-quantization round-trip
         (the SDP4Bit failure mode: extra noise draw + an extra bias term,
         invisible to shape checks)
  QJ102  a `convert_element_type` from u8 to a float dtype whose result
         reaches a collective operand through transparent ops — the wire
         was silently widened 2-4x
  QJ103  nondeterminism-hazard primitives inside programs guarded by the
         bit-identity serve invariant (decode/prefill/verify must replay
         exactly on every rank/run)
"""
from __future__ import annotations

from typing import Iterable, Optional

from .findings import Finding

QUANTIZER_NAMES = ("_quantize_jnp", "quantize_packed", "quantize_buckets")
DEQUANTIZER_NAMES = ("_dequantize_jnp", "dequantize_packed",
                     "dequantize_buckets")

# pure data-movement: values pass through unchanged (bits may be re-laid-out
# or reinterpreted, never combined)
TRANSPARENT_PRIMS = {
    "reshape", "transpose", "broadcast_in_dim", "squeeze", "expand_dims",
    "slice", "dynamic_slice", "concatenate", "rev", "pad", "copy",
    "convert_element_type", "bitcast_convert_type", "gather",
    "dynamic_update_slice",
}

COLLECTIVE_PRIMS = {
    "all_gather", "psum_scatter", "all_to_all", "ppermute", "psum",
    "reduce_scatter",
}

# primitives whose device-to-device / run-to-run determinism is not
# guaranteed on every backend (float atomics, legacy stateful RNG)
HAZARD_PRIMS = {"rng_uniform"}
HAZARD_FLOAT_PRIMS = {"scatter-add", "scatter_add", "scatter-mul",
                      "scatter_mul"}


def _subjaxprs(params: dict):
    import jax.core as jcore
    ClosedJaxpr = jcore.ClosedJaxpr
    Jaxpr = jcore.Jaxpr
    for v in params.values():
        if isinstance(v, ClosedJaxpr):
            yield v.jaxpr
        elif isinstance(v, Jaxpr):
            yield v
        elif isinstance(v, (list, tuple)):
            for x in v:
                if isinstance(x, ClosedJaxpr):
                    yield x.jaxpr
                elif isinstance(x, Jaxpr):
                    yield x


def iter_jaxprs(jaxpr):
    """Yield `jaxpr` and every nested sub-jaxpr (pjit / scan / while /
    cond / custom_vjp / shard_map bodies), depth-first."""
    yield jaxpr
    for eqn in jaxpr.eqns:
        for sub in _subjaxprs(eqn.params):
            yield from iter_jaxprs(sub)


def _eqn_callee(eqn) -> str:
    """The function name a call-like equation wraps ('' otherwise)."""
    name = eqn.params.get("name")
    if isinstance(name, str):
        return name
    for k in ("jaxpr", "call_jaxpr", "fun_jaxpr"):
        sub = eqn.params.get(k)
        if sub is not None and hasattr(sub, "jaxpr"):
            return getattr(sub.jaxpr, "name", "") or ""
    return ""


def _match(name: str, catalog: tuple) -> bool:
    return any(c in name for c in catalog)


def _level_findings(jaxpr, tag: str) -> list[Finding]:
    """Run all three detectors on ONE jaxpr level (dataflow within a level;
    iter_jaxprs visits every level of the program)."""
    out = []
    producer = {}  # var -> eqn
    for eqn in jaxpr.eqns:
        for v in eqn.outvars:
            producer[id(v)] = eqn

    def _reaches_back(var, want: str, seen) -> Optional[str]:
        """Walk producers through transparent ops; return the matched
        callee name if `var` derives from a `want`-class call."""
        if id(var) in seen:
            return None
        seen.add(id(var))
        eqn = producer.get(id(var))
        if eqn is None:
            return None
        callee = _eqn_callee(eqn)
        if want == "dequantize" and _match(callee, DEQUANTIZER_NAMES):
            return callee
        prim = eqn.primitive.name
        if prim in TRANSPARENT_PRIMS or (prim == "pjit" and not callee):
            for iv in eqn.invars:
                if hasattr(iv, "aval"):
                    hit = _reaches_back(iv, want, seen)
                    if hit:
                        return hit
        return None

    # forward reachability through transparent ops, for QJ102
    consumers: dict[int, list] = {}
    for eqn in jaxpr.eqns:
        for iv in eqn.invars:
            if hasattr(iv, "aval"):
                consumers.setdefault(id(iv), []).append(eqn)

    def _reaches_collective(var, seen) -> Optional[str]:
        if id(var) in seen:
            return None
        seen.add(id(var))
        for eqn in consumers.get(id(var), ()):
            prim = eqn.primitive.name
            if prim in COLLECTIVE_PRIMS:
                return prim
            if prim in TRANSPARENT_PRIMS:
                for ov in eqn.outvars:
                    hit = _reaches_collective(ov, seen)
                    if hit:
                        return hit
        return None

    for eqn in jaxpr.eqns:
        callee = _eqn_callee(eqn)
        # QJ101: quantizer fed (transparently) by a dequantizer
        if _match(callee, QUANTIZER_NAMES):
            for iv in eqn.invars:
                if not hasattr(iv, "aval"):
                    continue
                hit = _reaches_back(iv, "dequantize", set())
                if hit:
                    out.append(Finding(
                        "QJ101", f"{tag}::{hit}->{callee}",
                        f"'{callee}' consumes '{hit}' output with no "
                        f"intervening compute — redundant QDQ round-trip"))
                    break
        # QJ102: u8 -> float widen that reaches a collective
        if eqn.primitive.name == "convert_element_type":
            src = eqn.invars[0].aval
            dst = eqn.outvars[0].aval
            if (str(src.dtype) == "uint8"
                    and str(dst.dtype) in ("float32", "bfloat16", "float16")):
                coll = _reaches_collective(eqn.outvars[0], set())
                if coll:
                    out.append(Finding(
                        "QJ102",
                        f"{tag}::u8->{dst.dtype}->{coll}",
                        f"u8 wire buffer widened to {dst.dtype} before "
                        f"'{coll}' — wire bytes multiplied"))
    return out


def hazard_findings(jaxpr, tag: str) -> list[Finding]:
    out = []
    for sub in iter_jaxprs(jaxpr):
        for eqn in sub.eqns:
            prim = eqn.primitive.name
            if prim in HAZARD_PRIMS:
                out.append(Finding(
                    "QJ103", f"{tag}::{prim}",
                    f"nondeterminism-hazard primitive '{prim}' inside a "
                    f"bit-identity-guarded program"))
            elif prim in HAZARD_FLOAT_PRIMS:
                if any(hasattr(ov, "aval") and "float" in str(ov.aval.dtype)
                       for ov in eqn.outvars):
                    out.append(Finding(
                        "QJ103", f"{tag}::{prim}:float",
                        f"float '{prim}' (atomic-ordering hazard on GPU "
                        f"backends) inside a bit-identity-guarded program"))
    return out


def audit_jaxpr(closed, tag: str, bit_identity: bool = False) -> list[Finding]:
    """All jaxpr-level detectors over one traced program."""
    jaxpr = closed.jaxpr if hasattr(closed, "jaxpr") else closed
    out = []
    seen_sites = set()
    for sub in iter_jaxprs(jaxpr):
        for f in _level_findings(sub, tag):
            if f.site not in seen_sites:
                seen_sites.add(f.site)
                out.append(f)
    if bit_identity:
        for f in hazard_findings(jaxpr, tag):
            if f.site not in seen_sites:
                seen_sites.add(f.site)
                out.append(f)
    return out


# ---------------------------------------------------------------------------
# Program construction (trace-only, (1,1) mesh)
# ---------------------------------------------------------------------------


def trace_train_step(arch: str = "gpt-125m", quantized_state: bool = False,
                     n_micro: int = 1):
    import jax
    import jax.numpy as jnp

    from .. import configs
    from ..core.qsdp import MeshSpec, QSDPConfig
    from ..models.transformer import Model
    from ..optim import AdamWConfig, make_adamw
    from ..train.step import (init_train_state, make_jitted_train_step,
                              quantize_train_state)

    cfg = configs.get_smoke(arch)
    ms = MeshSpec(axes=("data", "model"), shape=(1, 1))
    mesh = jax.make_mesh(ms.shape, ms.axes)
    model = Model(cfg, ms, QSDPConfig(min_quant_size=256, coalesce=True))
    opt = make_adamw(AdamWConfig(lr=1e-3))
    key = jax.random.PRNGKey(0)
    state = init_train_state(model, opt, key)
    if quantized_state:
        state = quantize_train_state(state, model, key)
    step = make_jitted_train_step(model, opt, mesh, n_micro=n_micro,
                                  donate=False,
                                  quantized_state=quantized_state)
    tokens = jnp.zeros((4, 16), jnp.int32)
    batch = {"tokens": tokens, "labels": tokens}
    return jax.make_jaxpr(step)(state, batch, key)


def trace_serve_programs(arch: str = "gpt-125m"):
    """{tag: ClosedJaxpr} for decode / chunked-prefill / verify on (1,1)."""
    import jax
    import jax.numpy as jnp

    from ..serve.common import build_serve_setup
    from ..serve.engine import prepare_wire_params

    setup = build_serve_setup(arch, data_par=1, model_par=1, smoke=True,
                              batch=2, prompt_len=8, gen=4,
                              draft_bits=4, draft_depth=2)
    eng = setup.engine
    params = prepare_wire_params(setup.model, setup.params)
    cache = eng.init_cache()
    b = setup.spec.batch_global
    key = jax.random.PRNGKey(0)
    toks = jnp.zeros((b,), jnp.int32)
    pos = jnp.zeros((b,), jnp.int32)
    out = {}
    out["decode_fn"] = jax.make_jaxpr(eng.decode_step())(
        params, cache, toks, pos, key)
    bucket = 8
    out["prefill_chunk_fn"] = jax.make_jaxpr(eng.prefill_chunk_step(bucket))(
        params, cache, jnp.zeros((b, bucket), jnp.int32), pos,
        jnp.full((b,), bucket, jnp.int32), key)
    k = max(1, setup.spec.draft_depth)
    out["verify_fn"] = jax.make_jaxpr(eng.verify_step(k))(
        params, cache, jnp.zeros((b, k), jnp.int32), pos,
        jnp.full((b,), k, jnp.int32), key)
    return out


def run(arch: str = "gpt-125m") -> list[Finding]:
    findings = []
    for qs in (False, True):
        tag = f"train-step[{'qstate' if qs else 'f32'}]"
        findings.extend(audit_jaxpr(
            trace_train_step(arch, quantized_state=qs), tag))
    for tag, closed in trace_serve_programs(arch).items():
        findings.extend(audit_jaxpr(closed, tag, bit_identity=True))
    return findings
