"""Key audit: static (key, tensor) uniqueness over all configs/ families.

Shift-mode rounding (paper Def. 1) draws its random shift from the
per-tensor PRNG key, so the unbiased-quantizer assumption behind QSDP's
convergence (PAPER.md Thm. 2) requires every derived key to feed exactly
one tensor.  All derivations in this repo are `fold_in` chains; two chains
collide exactly when they share a parent scope and fold the same constant.
This pass re-derives the full fold catalog — without tracing anything —
from the live hash functions (`train.step._h`, `core.qsdp._stable_hash`)
and the spec trees of every architecture family, then checks:

  QK201  one (scope, fold constant) pair claimed by two different tensors
         (e.g. a layer-scan index colliding with a group-offset constant)
  QK202  same, where both claims are FNV-1a name hashes — a hash collision
  QK203  reserved-salt overlap: the microbatch index range or a layer /
         group index range reaching a reserved salt (0x3A57E9 master,
         0x5D grad RS, 1000/2000/5000 group offsets)

Fold catalog (kept in sync with the call sites it names):
  train/step.py    step key -> fold_in(i) per microbatch; fold_in(0x3A57E9)
                   then fold_in(_h(name)) for the master re-quantization
  core/qsdp.py     gather keys fold _stable_hash(full name) (per-tensor) /
                   _stable_hash(short name) (gather_layer); grad
                   reduce-scatter folds 0x5D from the tensor key
  models/*.py      scan layers fold idx; hybrid groups fold 1000+gidx /
                   2000 (tail) / 5000+gidx (decode sampling); the shared
                   block's gather_layer folds short-name hashes from the
                   SAME group key the layer scan folds its indices from
  serve/engine.py  generate() folds the decode-step index from the launch
                   key (same scope family as prefill's direct use)
"""
from __future__ import annotations

import dataclasses
from typing import Iterable

from .findings import Finding

MASTER_SALT = 0x3A57E9
GRAD_SALT = 0x5D
GROUP_OFFSET = 1000
TAIL_OFFSET = 2000
ENC_OFFSET = 3000
SAMPLE_OFFSET = 5000
RESERVED = {
    MASTER_SALT: "master-requant salt (train/step.py)",
    GRAD_SALT: "grad reduce-scatter salt (core/qsdp.py)",
}


@dataclasses.dataclass(frozen=True)
class KeyUse:
    """One fold_in edge: from key scope `scope`, fold `const`, yielding the
    key for `tensor` (a param name, or a sub-scope like 'layer[3]')."""
    scope: str
    const: int
    tensor: str
    site: str        # source location family (documentation, not identity)
    from_hash: bool  # const came from a name hash (QK202 vs QK201)


def check_key_uses(uses: Iterable[KeyUse]) -> list[Finding]:
    """Collision + reserved-salt checks over a fold catalog."""
    out = []
    claimed: dict[tuple[str, int], KeyUse] = {}
    for u in uses:
        prev = claimed.get((u.scope, u.const))
        if prev is None:
            claimed[(u.scope, u.const)] = u
            continue
        if prev.tensor == u.tensor:
            continue  # same tensor re-derived identically (e.g. fwd + bwd)
        rule = "QK202" if (u.from_hash and prev.from_hash) else "QK201"
        out.append(Finding(
            rule, f"{u.scope}::0x{u.const:X}::{prev.tensor}<->{u.tensor}",
            f"key fold_in({u.const:#x}) in scope '{u.scope}' feeds both "
            f"'{prev.tensor}' ({prev.site}) and '{u.tensor}' ({u.site})"))
        # QK203: index ranges must stay clear of reserved salts
        if not (u.from_hash and prev.from_hash):
            for cand in (prev, u):
                if not cand.from_hash and cand.const in RESERVED:
                    out.append(Finding(
                        "QK203", f"{u.scope}::0x{cand.const:X}::reserved",
                        f"scope '{u.scope}' folds reserved constant "
                        f"{cand.const:#x} ({RESERVED[cand.const]})"))
    return out


def _hash_fns():
    from ..core.qsdp import _stable_hash
    from ..train.step import _h
    return _h, _stable_hash


def enumerate_key_uses(model, n_micro: int = 2,
                       serve_steps: int = 2) -> list[KeyUse]:
    """The fold catalog for one Model (train + serve schedules)."""
    from ..train.step import master_eligible
    from ..tune.cost_model import layer_groups

    _h, _stable_hash = _hash_fns()
    cfg, eng = model.cfg, model.engine
    uses: list[KeyUse] = []
    arch = cfg.name

    groups = layer_groups(eng)
    stacked = {g: (ns, stack) for g, ns, stack in groups if stack > 1}
    singles = [g for g, ns, stack in groups if stack <= 1]

    # -- step key scope: microbatch folds + master salt ---------------------
    step = f"{arch}/step"
    for i in range(n_micro):
        uses.append(KeyUse(step, i, f"micro[{i}]", "train/step.py", False))
    uses.append(KeyUse(step, MASTER_SALT, "master-requant",
                       "train/step.py", False))

    # -- master scope: _h(name) per master-eligible param -------------------
    master = f"{arch}/master"
    for name in sorted(eng.specs):
        if master_eligible(model, name):
            uses.append(KeyUse(master, _h(name), name,
                               "train/step.py qmaster", True))

    # -- loss scope (one per microbatch; identical catalog, so model once) --
    # serve prefill/decode launches reuse exactly this layout from the
    # launch key, so the same scope also covers decode_fn/prefill_fn.
    loss = f"{arch}/loss"
    for name in singles:
        uses.append(KeyUse(loss, _stable_hash(name), name,
                           "core/qsdp.py engine.gather", True))
    every = getattr(cfg, "hybrid_attn_every", 0) or 0
    if cfg.arch_type == "hybrid" and every:
        n_groups, rem = divmod(cfg.n_layers, every)
        for g in range(n_groups):
            uses.append(KeyUse(loss, GROUP_OFFSET + g, f"layer-group[{g}]",
                               "models hybrid stack", False))
            uses.append(KeyUse(loss, SAMPLE_OFFSET + g, f"sample-group[{g}]",
                               "models/decode.py hybrid sampling", False))
        if rem:
            uses.append(KeyUse(loss, TAIL_OFFSET, "layer-tail",
                               "models hybrid tail", False))
        # group scope: scan indices AND the shared block's short-name
        # hashes fold from the SAME gkey
        gscope = f"{arch}/layer-group"
        for i in range(every):
            uses.append(KeyUse(gscope, i, f"layer[{i}]",
                               "models _scan_layers", False))
        for name in sorted(eng.specs):
            if name.startswith("shared/"):
                short = name.split("/", 1)[1]
                uses.append(KeyUse(gscope, _stable_hash(short), name,
                                   "models _shared_block gather_layer", True))
    elif cfg.arch_type == "audio":
        # encoder stack scans under fold_in(key, ENC_OFFSET); the decoder
        # folds its indices straight from the loss key (see
        # Model._loss_encdec — enc/dec share short names, so a shared
        # parent scope would collide)
        uses.append(KeyUse(loss, ENC_OFFSET, "enc-stack",
                           "models _loss_encdec", False))
        escope = f"{arch}/enc-stack"
        for g, (ns, stack) in sorted(stacked.items()):
            scope, label = (escope, g) if g == "enc" else (loss, g)
            for i in range(stack):
                uses.append(KeyUse(scope, i, f"{label}[{i}]",
                                   "models _scan_layers", False))
    else:
        for g, (ns, stack) in sorted(stacked.items()):
            for i in range(stack):
                uses.append(KeyUse(loss, i, f"{g}[{i}]",
                                   "models _scan_layers", False))

    # -- layer scope: short-name hashes inside gather_layer -----------------
    for g, (ns, stack) in sorted(stacked.items()):
        lscope = f"{arch}/layer:{g}"
        for name in ns:
            short = name.split("/", 1)[1]
            uses.append(KeyUse(lscope, _stable_hash(short), name,
                               "core/qsdp.py _layer_keys", True))

    # -- tensor scope: the grad RS fold is the only child of a tensor key ---
    # (nothing else folds from it; enumerate to keep the catalog honest)
    tensor = f"{arch}/tensor"
    uses.append(KeyUse(tensor, GRAD_SALT, "grad-rs",
                       "core/qsdp.py backward", False))

    # -- serve launch scope: generate() folds decode-step indices -----------
    serve = f"{arch}/serve-launch"
    for i in range(serve_steps):
        uses.append(KeyUse(serve, i, f"decode-step[{i}]",
                           "serve/engine.py generate", False))
    return uses


def range_guards(model, n_micro: int = 2) -> list[Finding]:
    """QK203 range checks that don't show up as direct collisions in the
    (finite) catalog: index ranges growing into reserved constants."""
    out = []
    cfg = model.cfg
    arch = cfg.name
    checks = [
        ("microbatch index", n_micro, (MASTER_SALT,)),
        ("layer index", cfg.n_layers,
         (GROUP_OFFSET, TAIL_OFFSET, ENC_OFFSET, SAMPLE_OFFSET,
          MASTER_SALT)),
    ]
    every = getattr(cfg, "hybrid_attn_every", 0) or 0
    if cfg.arch_type == "hybrid" and every:
        n_groups = cfg.n_layers // every
        checks.append(("hybrid group index", GROUP_OFFSET + n_groups,
                       (TAIL_OFFSET, SAMPLE_OFFSET)))
    for what, top, salts in checks:
        for s in salts:
            if top > s:
                out.append(Finding(
                    "QK203", f"{arch}::{what.replace(' ', '-')}::0x{s:X}",
                    f"{what} range [0, {top}) of '{arch}' reaches reserved "
                    f"constant {s:#x}"))
    return out


def run(archs=None, smoke: bool = False, n_micro: int = 2) -> list[Finding]:
    """Audit every (or the given) configs/ family on a (1,1) mesh spec.
    Defaults to the FULL (non-smoke) configs — spec construction is
    metadata-only, so the real layer counts cost nothing to enumerate."""
    from .. import configs
    from ..core.qsdp import MeshSpec, QSDPConfig
    from ..models.transformer import Model

    names = list(archs) if archs else configs.list_archs()
    ms = MeshSpec(axes=("data", "model"), shape=(1, 1))
    findings: list[Finding] = []
    for arch in names:
        cfg = configs.get_smoke(arch) if smoke else configs.get_config(arch)
        model = Model(cfg, ms, QSDPConfig())
        findings.extend(check_key_uses(enumerate_key_uses(model, n_micro)))
        findings.extend(range_guards(model, n_micro))
    return findings
