"""qlint CLI — run the static-analysis passes and gate on the baseline.

    PYTHONPATH=src python -m repro.analysis.qlint --all \\
        [--arch gpt-125m] [--mesh 1,1] [--plan PLAN.json] \\
        [--baseline qlint_baseline.json] [--report QLINT_REPORT.json]

Exit codes: 0 = no non-baselined findings, 1 = new findings (printed and
written to the JSON report), 2 = a pass crashed.  ``--update-baseline``
rewrites the baseline from the current findings (new entries get a TODO
justification to hand-edit — suppressions are code-reviewed, not
generated).
"""
from __future__ import annotations

import argparse
import json
import os
import sys
from pathlib import Path

PASSES = ("lint", "key", "jaxpr", "collective")


def parse_args(argv=None):
    ap = argparse.ArgumentParser(prog="repro.analysis.qlint",
                                 description=__doc__)
    ap.add_argument("--all", action="store_true",
                    help="run every pass (same as --passes "
                         + ",".join(PASSES) + ")")
    ap.add_argument("--passes", default="",
                    help="comma-separated subset of: " + ",".join(PASSES))
    ap.add_argument("--arch", default="gpt-125m",
                    help="config family the traced/compiled passes use")
    ap.add_argument("--mesh", default="1,1",
                    help="data,model mesh for the collective audit")
    ap.add_argument("--plan", default=None,
                    help="DeploymentPlan JSON the collective audit checks")
    ap.add_argument("--root", default=None,
                    help="source tree for the lint pass (default: src/repro)")
    ap.add_argument("--baseline", default=None,
                    help="suppression file (default: qlint_baseline.json "
                         "next to the repo's src/)")
    ap.add_argument("--report", default=None,
                    help="write the JSON audit report here")
    ap.add_argument("--update-baseline", action="store_true",
                    help="rewrite the baseline from current findings")
    return ap.parse_args(argv)


def _default_baseline() -> str:
    # src/repro/analysis/qlint.py -> repo root
    return str(Path(__file__).resolve().parents[3] / "qlint_baseline.json")


def main(argv=None) -> int:
    args = parse_args(argv)
    names = [p.strip() for p in args.passes.split(",") if p.strip()]
    if args.all or not names:
        names = list(PASSES)
    bad = set(names) - set(PASSES)
    if bad:
        print(f"unknown passes: {sorted(bad)}", file=sys.stderr)
        return 2

    mesh_shape = tuple(int(x) for x in args.mesh.split(","))
    ndev = 1
    for x in mesh_shape:
        ndev *= x
    if ndev > 1 and "XLA_FLAGS" not in os.environ:
        # must land before anything imports jax
        os.environ["XLA_FLAGS"] = \
            f"--xla_force_host_platform_device_count={ndev}"

    from .findings import (load_baseline, make_report, partition_findings,
                           save_baseline)

    baseline_path = args.baseline or _default_baseline()
    baseline = load_baseline(baseline_path)

    per_pass = {}
    extra = {}
    crashed = False
    for name in names:
        try:
            if name == "lint":
                from . import source_lint
                per_pass[name] = source_lint.run(args.root)
            elif name == "key":
                from . import key_audit
                per_pass[name] = key_audit.run()
            elif name == "jaxpr":
                from . import jaxpr_audit
                per_pass[name] = jaxpr_audit.run(args.arch)
            elif name == "collective":
                from . import collective_audit
                detail = {}
                per_pass[name] = collective_audit.run(
                    args.arch, mesh_shape, args.plan, report=detail)
                extra["collective"] = detail
        except Exception as e:  # a crashed pass must fail CI loudly
            crashed = True
            per_pass[name] = []
            extra.setdefault("crashes", {})[name] = f"{type(e).__name__}: {e}"
            print(f"[qlint] pass '{name}' crashed: {type(e).__name__}: {e}",
                  file=sys.stderr)

    all_findings = [f for fs in per_pass.values() for f in fs]
    new, suppressed, unused = partition_findings(all_findings, baseline)

    if args.update_baseline:
        save_baseline(baseline_path, all_findings, baseline)
        print(f"[qlint] wrote {len(set(all_findings))} suppression(s) to "
              f"{baseline_path}")

    report = make_report(per_pass, baseline,
                         meta={"arch": args.arch, "mesh": list(mesh_shape),
                               "plan": args.plan, "passes": names, **extra})
    if args.report:
        with open(args.report, "w") as f:
            json.dump(report, f, indent=1)
            f.write("\n")

    for f in new:
        print(f"[qlint] NEW {f}")
    for k in unused:
        print(f"[qlint] warning: unused suppression {k[0]} {k[1]}")
    print(f"[qlint] passes={','.join(names)} findings={len(all_findings)} "
          f"new={len(new)} suppressed={len(suppressed)}")
    if crashed:
        return 2
    return 1 if new else 0


if __name__ == "__main__":
    raise SystemExit(main())
