"""Source lint: AST conventions over src/ (rules QS401-QS403).

QS401  host syncs inside ``ContinuousScheduler``'s per-step loop.  The
       scheduler's contract (serve/scheduler.py) is ONE batched host sync
       per launch; any `.item()`, `jax.device_get(...)` or
       `.block_until_ready()` added to its methods is either that one
       deliberate sync (baseline it, with the justification) or a
       per-token/per-lane sync regression (fix it).
QS402  ``jax.random.PRNGKey(<int literal>)`` in library code.  Seeds are
       caller-owned: literal keys silently correlate quantization noise
       between components that should be independent.
QS403  imports that reach past ``kernels.ops`` (the backend dispatcher)
       into kernel implementation modules from outside ``kernels/`` —
       bypassing the jnp/pallas switch `core.quant` owns.

Pure stdlib; runs on any tree (tests point it at seeded temp dirs).
"""
from __future__ import annotations

import ast
from pathlib import Path

from .findings import Finding

HOST_SYNC_ATTRS = ("item", "block_until_ready")
SCHEDULER_CLASS = "ContinuousScheduler"
# methods outside the admit/launch/step loop (no device work by contract)
SCHEDULER_EXEMPT = ("__init__",)
KERNEL_PKG = "kernels"
KERNEL_PUBLIC = ("ops",)  # the dispatch surface; everything else is private


def _attr_chain(node: ast.AST) -> str:
    parts = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
    return ".".join(reversed(parts))


def _host_sync_pattern(call: ast.Call) -> str:
    """Name the host-sync pattern a Call matches, or ''. """
    chain = _attr_chain(call.func)
    leaf = chain.rsplit(".", 1)[-1]
    if leaf in HOST_SYNC_ATTRS and isinstance(call.func, ast.Attribute):
        return leaf
    if chain in ("jax.device_get", "device_get"):
        return "device_get"
    return ""


def _prngkey_literal(call: ast.Call) -> bool:
    chain = _attr_chain(call.func)
    if not chain.endswith("PRNGKey"):
        return False
    return bool(call.args) and isinstance(call.args[0], ast.Constant) \
        and isinstance(call.args[0].value, int)


def _kernel_import_violation(node: ast.AST) -> str:
    """Return the offending module path for an import that reaches past the
    kernels dispatch surface, or ''. """
    if isinstance(node, ast.ImportFrom) and node.module:
        mod = node.module
        parts = mod.split(".")
        if KERNEL_PKG in parts:
            sub = parts[parts.index(KERNEL_PKG) + 1:]
            if sub and sub[0] not in KERNEL_PUBLIC:
                return mod
            if not sub:  # from ..kernels import X — X must be public
                bad = [a.name for a in node.names
                       if a.name not in KERNEL_PUBLIC]
                if bad:
                    return f"{mod} import {','.join(bad)}"
    if isinstance(node, ast.Import):
        for a in node.names:
            parts = a.name.split(".")
            if KERNEL_PKG in parts:
                sub = parts[parts.index(KERNEL_PKG) + 1:]
                if sub and sub[0] not in KERNEL_PUBLIC:
                    return a.name
    return ""


def _lint_module(tree: ast.Module, rel: str) -> list[Finding]:
    out = []
    counts: dict[str, int] = {}

    def _site(base: str) -> str:
        # occurrence counter keeps identical patterns in one scope distinct
        n = counts.get(base, 0)
        counts[base] = n + 1
        return base if n == 0 else f"{base}#{n}"

    in_kernels = f"/{KERNEL_PKG}/" in f"/{rel}"
    for node in ast.walk(tree):
        # QS403 — anywhere outside kernels/ itself
        if not in_kernels:
            bad = _kernel_import_violation(node)
            if bad:
                out.append(Finding(
                    "QS403", _site(f"{rel}::import::{bad}"),
                    f"import reaches past kernels.{'/'.join(KERNEL_PUBLIC)} "
                    f"dispatch surface: {bad}", rel, node.lineno))
        # QS402 — module-wide
        if isinstance(node, ast.Call) and _prngkey_literal(node):
            val = node.args[0].value
            out.append(Finding(
                "QS402", _site(f"{rel}::PRNGKey({val})"),
                f"literal jax.random.PRNGKey({val}) in library code",
                rel, node.lineno))
        # QS401 — scheduler class methods only
        if isinstance(node, ast.ClassDef) and node.name == SCHEDULER_CLASS:
            for meth in node.body:
                if not isinstance(meth, (ast.FunctionDef,
                                         ast.AsyncFunctionDef)):
                    continue
                if meth.name in SCHEDULER_EXEMPT:
                    continue
                for sub in ast.walk(meth):
                    if isinstance(sub, ast.Call):
                        pat = _host_sync_pattern(sub)
                        if pat:
                            out.append(Finding(
                                "QS401",
                                _site(f"{rel}::{SCHEDULER_CLASS}."
                                      f"{meth.name}::{pat}"),
                                f"host sync `{pat}` in scheduler loop "
                                f"method {meth.name}", rel, sub.lineno))
    return out


def lint_source(root) -> list[Finding]:
    """Lint every .py under `root` (normally src/repro)."""
    root = Path(root)
    out = []
    for path in sorted(root.rglob("*.py")):
        rel = path.relative_to(root).as_posix()
        # the analyzer's own trace harness builds programs under synthetic
        # keys by construction — nothing it traces is ever executed
        if rel.startswith("analysis/"):
            continue
        try:
            tree = ast.parse(path.read_text(), filename=str(path))
        except SyntaxError as e:
            out.append(Finding("QS403", f"{rel}::parse-error",
                               f"unparseable source: {e}", rel, e.lineno or 0))
            continue
        out.extend(_lint_module(tree, rel))
    return out


def run(root=None) -> list[Finding]:
    if root is None:
        root = Path(__file__).resolve().parents[1]  # src/repro
    return lint_source(root)
