"""Version compatibility shims.

`shard_map` moved twice across jax releases:

  * jax < 0.4.x:    ``jax.experimental.shard_map.shard_map`` with the
                    replication-check kwarg spelled ``check_rep``;
  * newer jax:      top-level ``jax.shard_map`` with the kwarg renamed to
                    ``check_vma``.

Everything in this repo imports :func:`shard_map` from here and uses the
*new* spelling (``check_vma``); the shim translates for old jax.
"""
from __future__ import annotations

import jax
from jax import lax

if hasattr(jax, "shard_map"):  # jax >= 0.6: the new API, passthrough
    shard_map = jax.shard_map
else:
    from jax.experimental.shard_map import shard_map as _shard_map

    def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = True, **kw):
        """New-style ``jax.shard_map`` signature on old jax (``check_vma`` is
        forwarded as ``check_rep``)."""
        return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                          check_rep=check_vma, **kw)


if hasattr(lax, "axis_size"):
    axis_size = lax.axis_size
else:

    def axis_size(axis_name) -> int:
        """``lax.axis_size`` for old jax: ``psum(1, axis)`` of a Python int is
        constant-folded to the static axis size inside shard_map/pmap."""
        return lax.psum(1, axis_name)
