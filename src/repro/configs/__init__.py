"""Architecture registry: the 10 assigned architectures + the paper's own
GPT-2 family (125M / 350M / 1.3B), each with a reduced smoke variant.

Every module defines CONFIG (the exact assigned config, source cited) and
smoke() (2 layers, d_model <= 512, <= 4 experts) for CPU tests.
"""
from __future__ import annotations

import importlib

from ..models.config import ModelConfig, ShapeConfig, SHAPES  # noqa: F401

ARCHS = [
    "qwen2_5_3b",
    "yi_6b",
    "seamless_m4t_large_v2",
    "qwen1_5_32b",
    "olmoe_1b_7b",
    "yi_34b",
    "zamba2_7b",
    "qwen2_vl_72b",
    "qwen3_moe_235b_a22b",
    "mamba2_370m",
    # paper's own models
    "gpt_125m",
    "gpt_350m",
    "gpt_1_3b",
]

ASSIGNED = ARCHS[:10]

_ALIAS = {a.replace("_", "-"): a for a in ARCHS}
_ALIAS.update({"qwen2.5-3b": "qwen2_5_3b", "qwen1.5-32b": "qwen1_5_32b",
               "olmoe-1b-7b": "olmoe_1b_7b", "qwen3-moe-235b-a22b": "qwen3_moe_235b_a22b",
               "gpt-1.3b": "gpt_1_3b"})


def _mod(name: str):
    key = _ALIAS.get(name, name).replace("-", "_").replace(".", "_")
    return importlib.import_module(f".{key}", __package__)


def get_config(name: str) -> ModelConfig:
    return _mod(name).CONFIG


def get_smoke(name: str) -> ModelConfig:
    return _mod(name).smoke()


def list_archs() -> list[str]:
    return list(ARCHS)
