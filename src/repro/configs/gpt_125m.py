"""gpt-125m — the paper's smallest GPT-2 pretraining target (Table 1).
Implemented on this repo's decoder substrate (RMSNorm/SwiGLU/RoPE); the
QSDP claims being validated concern communication + quantization, which are
block-agnostic (DESIGN.md §1)."""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="gpt-125m",
    arch_type="dense",
    n_layers=12,
    d_model=768,
    vocab_size=50_304,
    n_heads=12,
    n_kv_heads=12,
    head_dim=64,
    d_ff=3072,
    rope_theta=10_000.0,
    source="Radford et al. 2018; Mos [2022] MosaicML LLM examples",
)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="gpt-125m-smoke", arch_type="dense", n_layers=2, d_model=256,
        vocab_size=1024, n_heads=8, n_kv_heads=8, head_dim=32, d_ff=512,
        rope_theta=10_000.0, source=CONFIG.source,
    )
