"""gpt-1.3b — the paper's largest GPT pretraining target (Table 1, Figure 3:
2.2x end-to-end speedup at 10 Gbps)."""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="gpt-1.3b",
    arch_type="dense",
    n_layers=24,
    d_model=2048,
    vocab_size=50_304,
    n_heads=32,
    n_kv_heads=32,
    head_dim=64,
    d_ff=8192,
    rope_theta=10_000.0,
    source="Radford et al. 2018; Mos [2022] MosaicML LLM examples",
)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="gpt-1.3b-smoke", arch_type="dense", n_layers=2, d_model=256,
        vocab_size=1024, n_heads=8, n_kv_heads=8, head_dim=32, d_ff=512,
        rope_theta=10_000.0, source=CONFIG.source,
    )
