"""gpt-350m — the paper's mid GPT pretraining target (Table 1)."""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="gpt-350m",
    arch_type="dense",
    n_layers=24,
    d_model=1024,
    vocab_size=50_304,
    n_heads=16,
    n_kv_heads=16,
    head_dim=64,
    d_ff=4096,
    rope_theta=10_000.0,
    source="Radford et al. 2018; Mos [2022] MosaicML LLM examples",
)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="gpt-350m-smoke", arch_type="dense", n_layers=2, d_model=256,
        vocab_size=1024, n_heads=8, n_kv_heads=8, head_dim=32, d_ff=512,
        rope_theta=10_000.0, source=CONFIG.source,
    )
