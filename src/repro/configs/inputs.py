"""input_specs(): ShapeDtypeStruct stand-ins for every model input of every
(architecture x input-shape) pair — weak-type-correct, shardable, zero
allocation.  This is what the multi-pod dry-run lowers against.

Shape kinds:
  train    -> train_step inputs  (tokens, labels [, modality stubs])
  prefill  -> prefill_fn inputs  (tokens [, modality stubs])
  decode   -> decode_fn inputs   (cache, tokens (B,), pos (B,) per-slot)

Modality stubs (the one allowed carve-out):
  vlm   -> vision_embeds (B, S, d) bf16 patch embeddings + vision_mask +
           M-RoPE positions (3, B, S)
  audio -> audio_embeds (B, S_enc, d) bf16 frame embeddings
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..models.config import ModelConfig, ShapeConfig
from ..models.decode import DecodeModel, make_decode_spec
from ..models.transformer import Model


def _token_batch(cfg: ModelConfig, b: int, s: int, batch_axes, with_labels: bool):
    structs = {"tokens": jax.ShapeDtypeStruct((b, s), jnp.int32)}
    specs = {"tokens": P(batch_axes)}
    if with_labels:
        structs["labels"] = jax.ShapeDtypeStruct((b, s), jnp.int32)
        specs["labels"] = P(batch_axes)
    if cfg.arch_type == "vlm":
        structs["vision_embeds"] = jax.ShapeDtypeStruct((b, s, cfg.d_model), jnp.bfloat16)
        structs["vision_mask"] = jax.ShapeDtypeStruct((b, s), jnp.bool_)
        structs["positions"] = jax.ShapeDtypeStruct((3, b, s), jnp.int32)
        specs["vision_embeds"] = P(batch_axes)
        specs["vision_mask"] = P(batch_axes)
        specs["positions"] = P(None, batch_axes)
    if cfg.arch_type == "audio":
        s_enc = max(s // cfg.enc_frames_ratio, 1)
        structs["audio_embeds"] = jax.ShapeDtypeStruct((b, s_enc, cfg.d_model), jnp.bfloat16)
        specs["audio_embeds"] = P(batch_axes)
    return structs, specs


def input_specs(model: Model, shape: ShapeConfig):
    """Returns (kind, arg_structs, arg_pspecs) where args are the non-param
    positional inputs of the step to be lowered:

      train:   (batch, key)
      prefill: (batch, key)
      decode:  (cache, tokens, pos, key)
    """
    ms = model.ms
    cfg = model.cfg
    fsdp = ms.fsdp_size
    key_struct = jax.ShapeDtypeStruct((2,), jnp.uint32)

    if shape.kind == "train":
        assert shape.global_batch % fsdp == 0, (shape.global_batch, fsdp)
        structs, specs = _token_batch(cfg, shape.global_batch, shape.seq_len,
                                      ms.fsdp_axes, with_labels=True)
        return "train", (structs, key_struct), (specs, P())

    dspec = make_decode_spec(model, shape)
    bax = ms.fsdp_axes if dspec.batch_sharded else None

    if shape.kind == "prefill":
        structs, specs = _token_batch(cfg, shape.global_batch, shape.seq_len,
                                      bax, with_labels=False)
        if cfg.arch_type == "audio":
            # decode-time cross-KV is capped; prefill uses the capped length
            s_enc = dspec.enc_len or max(shape.seq_len // cfg.enc_frames_ratio, 1)
            structs["audio_embeds"] = jax.ShapeDtypeStruct(
                (shape.global_batch, s_enc, cfg.d_model), jnp.bfloat16)
        return "prefill", (structs, key_struct), (specs, P())

    # decode
    dm = DecodeModel(model, dspec)
    cache_structs, cache_specs = dm.cache_struct()
    tok = jax.ShapeDtypeStruct((shape.global_batch,), jnp.int32)
    # per-slot positions: every batch slot decodes at its own sequence
    # position (continuous batching)
    pos = jax.ShapeDtypeStruct((shape.global_batch,), jnp.int32)
    return "decode", (cache_structs, tok, pos, key_struct), (cache_specs, P(bax), P(bax), P())
