"""mamba2-370m — attention-free SSD (state-space duality) decoder.
[arXiv:2405.21060]"""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-370m",
    arch_type="ssm",
    n_layers=48,
    d_model=1024,
    vocab_size=50_280,
    d_ff=0,
    ssm_state=128,
    ssm_expand=2,
    ssm_head_dim=64,
    ssm_chunk=256,
    long_context="native",
    source="arXiv:2405.21060",
)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="mamba2-smoke", arch_type="ssm", n_layers=2, d_model=256,
        vocab_size=1024, ssm_state=16, ssm_head_dim=32, ssm_chunk=32,
        long_context="native", source=CONFIG.source,
    )
