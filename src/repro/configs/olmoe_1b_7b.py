"""olmoe-1b-7b — 64-expert top-8 MoE decoder. [arXiv:2409.02060]"""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="olmoe-1b-7b",
    arch_type="moe",
    n_layers=16,
    d_model=2048,
    vocab_size=50_304,
    n_heads=16,
    n_kv_heads=16,
    head_dim=128,
    n_experts=64,
    moe_top_k=8,
    moe_d_ff=1024,
    long_context="sliding_window",
    source="arXiv:2409.02060",
)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="olmoe-smoke", arch_type="moe", n_layers=2, d_model=256,
        vocab_size=1024, n_heads=8, n_kv_heads=8, head_dim=32,
        n_experts=4, moe_top_k=2, moe_d_ff=128, source=CONFIG.source,
    )
