"""qwen1.5-32b — dense GQA decoder with QKV bias (40 heads: padded to 48 on
the 16-way model axis, padded heads hard-masked). [hf:Qwen/Qwen1.5-0.5B]"""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen1.5-32b",
    arch_type="dense",
    n_layers=64,
    d_model=5120,
    vocab_size=152_064,
    n_heads=40,
    n_kv_heads=40,
    head_dim=128,
    qkv_bias=True,
    d_ff=27_392,
    rope_theta=1_000_000.0,
    long_context="sliding_window",
    source="hf:Qwen/Qwen1.5-0.5B",
)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="qwen1.5-32b-smoke", arch_type="dense", n_layers=2, d_model=320,
        vocab_size=1024, n_heads=10, n_kv_heads=10, head_dim=32, qkv_bias=True,
        d_ff=512, source=CONFIG.source,
    )
