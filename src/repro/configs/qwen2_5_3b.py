"""qwen2.5-3b — dense GQA decoder with QKV bias.
[hf:Qwen/Qwen2.5-0.5B family card; 3B: 36L d_model=2048 16H kv=2 d_ff=11008]
"""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2.5-3b",
    arch_type="dense",
    n_layers=36,
    d_model=2048,
    vocab_size=151_936,
    n_heads=16,
    n_kv_heads=2,
    head_dim=128,
    qkv_bias=True,
    d_ff=11_008,
    rope_theta=1_000_000.0,
    long_context="sliding_window",
    source="hf:Qwen/Qwen2.5-0.5B",
)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="qwen2.5-3b-smoke", arch_type="dense", n_layers=2, d_model=256,
        vocab_size=1024, n_heads=8, n_kv_heads=2, head_dim=32, qkv_bias=True,
        d_ff=512, source=CONFIG.source,
    )
