"""qwen2-vl-72b — VLM language backbone with M-RoPE and dynamic-resolution
vision input. [arXiv:2409.12191]  The ViT tower is stubbed per the
assignment carve-out: input_specs provides patch embeddings + a vision mask.
"""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-72b",
    arch_type="vlm",
    n_layers=80,
    d_model=8192,
    vocab_size=152_064,
    n_heads=64,
    n_kv_heads=8,
    head_dim=128,
    qkv_bias=True,
    d_ff=29_568,
    rope_mode="mrope",
    mrope_sections=(16, 24, 24),
    rope_theta=1_000_000.0,
    long_context="sliding_window",
    source="arXiv:2409.12191",
)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="qwen2-vl-smoke", arch_type="vlm", n_layers=2, d_model=256,
        vocab_size=1024, n_heads=8, n_kv_heads=2, head_dim=32, qkv_bias=True,
        d_ff=512, rope_mode="mrope", mrope_sections=(8, 4, 4),
        source=CONFIG.source,
    )
