"""qwen3-moe-235b-a22b — 128-expert top-8 MoE decoder.
[hf:Qwen/Qwen3-30B-A3B family card]"""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-moe-235b-a22b",
    arch_type="moe",
    n_layers=94,
    d_model=4096,
    vocab_size=151_936,
    n_heads=64,
    n_kv_heads=4,
    head_dim=128,
    n_experts=128,
    moe_top_k=8,
    moe_d_ff=1536,
    rope_theta=1_000_000.0,
    long_context="sliding_window",
    source="hf:Qwen/Qwen3-30B-A3B",
)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="qwen3-moe-smoke", arch_type="moe", n_layers=2, d_model=256,
        vocab_size=1024, n_heads=8, n_kv_heads=2, head_dim=32,
        n_experts=4, moe_top_k=2, moe_d_ff=128, source=CONFIG.source,
    )
