"""seamless-m4t-large-v2 — audio encoder-decoder transformer backbone.
[arXiv:2308.11596]  The mel/conformer audio frontend is stubbed per the
assignment carve-out: input_specs provides precomputed frame embeddings.
"""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-large-v2",
    arch_type="audio",
    n_layers=24,       # decoder
    n_enc_layers=24,   # speech encoder backbone
    d_model=1024,
    vocab_size=256_206,
    n_heads=16,
    n_kv_heads=16,
    head_dim=64,
    d_ff=8192,
    enc_frames_ratio=2,
    tie_embeddings=False,
    long_context="sliding_window",
    source="arXiv:2308.11596",
)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="seamless-m4t-smoke", arch_type="audio", n_layers=2, n_enc_layers=2,
        d_model=256, vocab_size=1024, n_heads=8, n_kv_heads=8, head_dim=32,
        d_ff=512, tie_embeddings=False, source=CONFIG.source,
    )
