"""yi-34b — llama-architecture dense GQA decoder (56 heads: padded to 64 on
the 16-way model axis). [arXiv:2403.04652]"""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="yi-34b",
    arch_type="dense",
    n_layers=60,
    d_model=7168,
    vocab_size=64_000,
    n_heads=56,
    n_kv_heads=8,
    head_dim=128,
    d_ff=20_480,
    rope_theta=5_000_000.0,
    long_context="sliding_window",
    source="arXiv:2403.04652",
)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="yi-34b-smoke", arch_type="dense", n_layers=2, d_model=448,
        vocab_size=1024, n_heads=14, n_kv_heads=2, head_dim=32, d_ff=512,
        source=CONFIG.source,
    )
