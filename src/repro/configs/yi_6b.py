"""yi-6b — llama-architecture dense GQA decoder. [arXiv:2403.04652]"""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="yi-6b",
    arch_type="dense",
    n_layers=32,
    d_model=4096,
    vocab_size=64_000,
    n_heads=32,
    n_kv_heads=4,
    head_dim=128,
    d_ff=11_008,
    rope_theta=5_000_000.0,
    long_context="sliding_window",
    source="arXiv:2403.04652",
)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="yi-6b-smoke", arch_type="dense", n_layers=2, d_model=256,
        vocab_size=1024, n_heads=8, n_kv_heads=4, head_dim=32, d_ff=512,
        source=CONFIG.source,
    )
