"""zamba2-7b — hybrid Mamba2 backbone with a shared attention+MLP block
invoked every 6 Mamba blocks (the shared block's params are FSDP-sharded
once and re-gathered, quantized, at every invocation). [arXiv:2411.15242]"""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-7b",
    arch_type="hybrid",
    n_layers=81,
    d_model=3584,
    vocab_size=32_000,
    n_heads=32,
    n_kv_heads=32,
    head_dim=112,
    d_ff=14_336,
    ssm_state=64,
    ssm_expand=2,
    ssm_head_dim=64,
    ssm_chunk=256,
    hybrid_attn_every=6,
    # long-context policy applies to the *shared attention block* only (the
    # Mamba2 state is O(1) natively); its KV ring uses the sliding window.
    long_context="sliding_window",
    source="arXiv:2411.15242",
)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="zamba2-smoke", arch_type="hybrid", n_layers=3, d_model=256,
        vocab_size=1024, n_heads=8, n_kv_heads=8, head_dim=32, d_ff=512,
        ssm_state=16, ssm_head_dim=32, ssm_chunk=32, hybrid_attn_every=2,
        long_context="native", source=CONFIG.source,
    )
