"""QSDP core: quantizers, quantized collectives, the FSDP engine, theory."""
from . import collectives, levels, quant, qsdp, theory  # noqa: F401
from .qsdp import MeshSpec, ParamSpec, QSDPConfig, QSDPEngine  # noqa: F401
from .quant import QuantConfig, Quantized, dequantize, quantize  # noqa: F401
