"""Quantized collectives — the TPU-native equivalents of CGX's quantized
NCCL AllGather / ReduceScatter (paper Section 5).

All functions here are *per-device* code: they must be called inside
``jax.shard_map``.  Axis names refer to mesh axes of the enclosing
shard_map.

Design notes (see DESIGN.md §2):

* **Quantized all-gather** ships int8-packed codes + per-bucket (scale, zero)
  metadata (f32, or bf16 under ``QuantConfig.meta_dtype="bfloat16"``).  The
  receiving side dequantizes after the gather, so the wire carries
  ``~ bits/32`` of the fp32 volume.  Appears in compiled HLO as
  ``all-gather`` of ``u8[...]`` operands — this is what the roofline parser
  counts.

* **Coalesced wire format** (the per-*launch* optimization): the per-tensor
  collectives above still cost 3 launches per tensor (codes, scale, zero) —
  a transformer layer with 7 quantized params is 21+ all-gather launches.
  The ``*_coalesced`` variants serialize every tensor of a layer — packed
  codes + metadata for quantized params, bitcast fp payloads for filtered
  ones — into ONE contiguous u8 buffer (``core.quant.wire_pack``) and issue
  ONE collective per layer, with bit-exact decode on the receiving side
  (same per-tensor quantization keys, same bytes on the wire, just one
  launch).  ``WireLayout`` is the static description of that buffer.

* **Quantized reduce-scatter** cannot use a ring reduce-scatter (codes from
  different peers have different scales and cannot be summed in transit).
  The TPU-native formulation is a single ``all_to_all`` of quantized chunks
  followed by a local dequant-sum: identical wire volume to a ring RS
  (``(P-1)/P * N * bits/8`` per device) and one collective instead of P-1
  steps.  This mirrors how CGX implements it over NCCL P2P.  The coalesced
  variant ships all of a layer's per-destination chunk rows in one
  ``(P, layer_bytes)`` u8 all_to_all.

* **Hierarchical variants** split the FSDP axes (pod, data): reduce-scatter
  over the fast in-pod axis first, so only ``1/data`` of the volume crosses
  the slow pod boundary — the paper's hierarchical inter-node collectives.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
from jax import lax

from ..compat import axis_size
from .quant import (
    QuantConfig,
    Quantized,
    dequantize,
    fp_pack,
    fp_segment_bytes,
    fp_unpack,
    quantize,
    quantized_shapes,
    wire_pack,
    wire_segment_bytes,
    wire_unpack,
)

AxisNames = tuple[str, ...]


def _axis_size(axes: AxisNames) -> int:
    s = 1
    for a in axes:
        s *= axis_size(a)  # static int (see compat.axis_size)
    return s


# ---------------------------------------------------------------------------
# Full-precision fallbacks (filtered params / baseline FSDP)
# ---------------------------------------------------------------------------


def all_gather_fp(x: jax.Array, axes: AxisNames, dtype=None) -> jax.Array:
    """Plain all-gather, optionally casting the wire dtype (baseline FSDP
    ships weights fp32, i.e. dtype=None; bf16 wire is a cheap ablation)."""
    if dtype is not None and x.dtype != dtype:
        y = lax.all_gather(x.astype(dtype), axes, tiled=True)
        return y.astype(x.dtype)
    return lax.all_gather(x, axes, tiled=True)


def reduce_scatter_fp(x: jax.Array, axes: AxisNames, dtype=None) -> jax.Array:
    """Plain reduce-scatter (sum) over flattened leading dim."""
    if dtype is not None and x.dtype != dtype:
        return lax.psum_scatter(x.astype(dtype), axes, tiled=True).astype(x.dtype)
    return lax.psum_scatter(x, axes, tiled=True)


# ---------------------------------------------------------------------------
# Quantized all-gather
# ---------------------------------------------------------------------------


def _decode_shards(
    codes: jax.Array,
    scale: jax.Array,
    zero: jax.Array,
    p: int,
    n_local: int,
    cfg: QuantConfig,
    dtype,
) -> jax.Array:
    """Decode P concatenated per-shard code blocks, respecting the fact that
    each source shard was padded to a bucket multiple *independently*."""
    nb_local = codes.shape[0] // p
    chunks = codes.reshape(p, nb_local, codes.shape[-1])
    out = jax.vmap(
        lambda c, s, z: dequantize(Quantized(c, s, z, (n_local,), n_local, cfg))
    )(chunks, scale.reshape(p, nb_local), zero.reshape(p, nb_local))
    return out.reshape(-1).astype(dtype)


def all_gather_quantized(
    x: jax.Array, axes: AxisNames, cfg: QuantConfig, key: jax.Array,
    out_dtype=None,
) -> jax.Array:
    """Gather a flat per-device shard into the full (flat) tensor, shipping
    quantized codes.  x: (n_local,) f32/bf16 -> (P * n_local,) out_dtype
    (default x.dtype).  Decoding straight to bf16 halves the materialized
    weight bytes with zero information loss (codes are <=8 bits) — §Perf."""
    q = quantize(x, cfg, key)
    md = cfg.meta_jnp_dtype
    codes = lax.all_gather(q.codes, axes, tiled=True)  # (P*nb, bsz/cpb) u8
    scale = lax.all_gather(q.scale.astype(md), axes, tiled=True)  # (P*nb,)
    zero = lax.all_gather(q.zero.astype(md), axes, tiled=True)
    p = _axis_size(axes)
    return _decode_shards(codes, scale.astype(jnp.float32),
                          zero.astype(jnp.float32), p, x.shape[0], cfg,
                          out_dtype or x.dtype)


def all_gather_hierarchical(
    x: jax.Array, pod_axis: str, inner_axes: AxisNames, cfg: QuantConfig,
    key: jax.Array, out_dtype=None,
) -> jax.Array:
    """Two-level gather: cross-pod first (moves only the local shard over the
    slow links), then in-pod.  Because the engine orders its flat FSDP axes
    data-major (`fsdp_axes = ("data", "pod")`), gathering over "pod" first and
    then "data" reproduces exactly the flat element order."""
    q = quantize(x, cfg, key)
    md = cfg.meta_jnp_dtype
    codes = lax.all_gather(q.codes, pod_axis, tiled=True)
    scale = lax.all_gather(q.scale.astype(md), pod_axis, tiled=True)
    zero = lax.all_gather(q.zero.astype(md), pod_axis, tiled=True)
    codes = lax.all_gather(codes, inner_axes, tiled=True)
    scale = lax.all_gather(scale, inner_axes, tiled=True)
    zero = lax.all_gather(zero, inner_axes, tiled=True)
    p = axis_size(pod_axis) * _axis_size(inner_axes)
    return _decode_shards(codes, scale.astype(jnp.float32),
                          zero.astype(jnp.float32), p, x.shape[0], cfg,
                          out_dtype or x.dtype)


# ---------------------------------------------------------------------------
# Quantized reduce-scatter (sum) via all_to_all + local dequant-sum
# ---------------------------------------------------------------------------


def reduce_scatter_quantized(
    g: jax.Array, axes: AxisNames, cfg: QuantConfig, key: jax.Array
) -> jax.Array:
    """Sum `g` across `axes`, leaving each device its own 1/P chunk.

    g: (n,) per-device full (unreduced) tensor with n % P == 0.
    Returns (n/P,) f32 — the summed chunk owned by this device.
    """
    p = _axis_size(axes)
    n = g.shape[0]
    assert n % p == 0, (n, p)
    chunks = g.reshape(p, n // p)
    q = jax.vmap(lambda c, k: quantize(c, cfg, k))(
        chunks, jax.random.split(key, p)
    )
    md = cfg.meta_jnp_dtype
    # Each row i goes to device i of the logical axis; we receive P rows.
    codes = lax.all_to_all(q.codes, axes, split_axis=0, concat_axis=0, tiled=True)
    scale = lax.all_to_all(q.scale.astype(md), axes, split_axis=0, concat_axis=0, tiled=True)
    zero = lax.all_to_all(q.zero.astype(md), axes, split_axis=0, concat_axis=0, tiled=True)
    deq = jax.vmap(
        lambda c, s, z: dequantize(
            Quantized(c, s, z, (n // p,), n // p, cfg)
        )
    )(codes, scale.astype(jnp.float32), zero.astype(jnp.float32))
    return jnp.sum(deq, axis=0)


def reduce_scatter_hierarchical(
    g: jax.Array, pod_axis: str, inner_axes: AxisNames, cfg: QuantConfig, key: jax.Array
) -> jax.Array:
    """Two-level quantized reduce-scatter: RS over the in-pod axes first
    (full volume stays on fast links), then RS of the 1/inner-sized partial
    across pods — only ``n/inner`` bytes cross the pod boundary."""
    k1, k2 = jax.random.split(key)
    partial_sum = reduce_scatter_quantized(g, inner_axes, cfg, k1)
    return reduce_scatter_quantized(partial_sum, (pod_axis,), cfg, k2)


# ---------------------------------------------------------------------------
# Coalesced wire collectives: one launch per layer.
#
# ``WireLayout`` statically describes the concatenation of every tensor of a
# layer into one u8 buffer (see the module docstring).  ``encode_wire`` /
# ``gather_wire`` / ``decode_gathered_wire`` are split so the QSDP engine can
# issue the collective for layer i+1 while layer i computes (the
# double-buffered prefetch pipeline) and decode the carried buffer one scan
# step later.
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class WireSegment:
    """Static layout of one tensor inside a coalesced wire buffer.

    n:        flat element count contributed per device (shard or chunk)
    cfg:      quantization config, or None for a raw fp payload
    fp_dtype: wire dtype of the fp payload when cfg is None
    """

    n: int
    cfg: Optional[QuantConfig]
    fp_dtype: str = "float32"

    @property
    def nbytes(self) -> int:
        if self.cfg is None:
            return fp_segment_bytes(self.n, self.fp_dtype)
        return wire_segment_bytes(self.n, self.cfg)


@dataclasses.dataclass(frozen=True)
class WireLayout:
    """Static layout of a whole coalesced buffer (ordered segments)."""

    segments: tuple[WireSegment, ...]

    @property
    def nbytes(self) -> int:
        return sum(s.nbytes for s in self.segments)

    def offsets(self) -> list[int]:
        out, off = [], 0
        for s in self.segments:
            out.append(off)
            off += s.nbytes
        return out


def encode_wire(xs: Sequence[jax.Array], layout: WireLayout,
                keys: Sequence[Optional[jax.Array]]) -> jax.Array:
    """Quantize + serialize every tensor into one (layout.nbytes,) u8 buffer.
    Quantized segments draw randomness from their own per-tensor key, so the
    bytes are identical to what the per-tensor collectives would ship."""
    parts = []
    for x, seg, key in zip(xs, layout.segments, keys):
        flat = x.reshape(-1)
        if seg.cfg is None:
            parts.append(fp_pack(flat, seg.fp_dtype))
        else:
            parts.append(wire_pack(quantize(flat, seg.cfg, key)))
    return jnp.concatenate(parts)


def gather_wire(buf: jax.Array, axes: AxisNames,
                pod_axis: Optional[str] = None) -> jax.Array:
    """All-gather a coalesced buffer: (B,) u8 -> (P*B,) u8 in shard order.
    With `pod_axis`, gathers cross-pod first (hierarchical two-level form —
    same peer ordering as the per-tensor hierarchical gather)."""
    if pod_axis is not None:
        buf = lax.all_gather(buf, pod_axis, tiled=True)
        inner = tuple(a for a in axes if a != pod_axis)
        return lax.all_gather(buf, inner, tiled=True)
    return lax.all_gather(buf, axes, tiled=True)


def _decode_segments(rows: jax.Array, layout: WireLayout) -> list[jax.Array]:
    """(P, layout.nbytes) u8 rows -> per-segment (P, seg.n) f32 decodes
    (shared by the gather decode and the reduce-scatter dequant-sum)."""
    outs, off = [], 0
    for seg in layout.segments:
        sb = rows[:, off:off + seg.nbytes]
        off += seg.nbytes
        if seg.cfg is None:
            outs.append(jax.vmap(lambda b: fp_unpack(b, seg.n, seg.fp_dtype))(sb))
        else:
            outs.append(jax.vmap(
                lambda b: dequantize(wire_unpack(b, seg.n, seg.cfg))
            )(sb))
    return outs


def decode_gathered_wire(gbuf: jax.Array, layout: WireLayout, p: int,
                         out_dtypes: Sequence) -> list[jax.Array]:
    """Decode a gathered (P * layout.nbytes,) buffer back into full flat
    tensors [(P * seg.n,) in out_dtype], respecting per-shard padding."""
    rows = gbuf.reshape(p, layout.nbytes)
    return [vals.reshape(-1).astype(dt)
            for vals, dt in zip(_decode_segments(rows, layout), out_dtypes)]


def all_gather_coalesced(
    xs: Sequence[jax.Array], axes: AxisNames, layout: WireLayout,
    keys: Sequence[Optional[jax.Array]], out_dtypes: Sequence,
    pod_axis: Optional[str] = None,
) -> list[jax.Array]:
    """One-launch layer gather: encode -> 1 all-gather -> decode."""
    buf = encode_wire(xs, layout, keys)
    gbuf = gather_wire(buf, axes, pod_axis=pod_axis)
    p = _axis_size(axes)
    return decode_gathered_wire(gbuf, layout, p, out_dtypes)


def reduce_scatter_coalesced(
    gs: Sequence[jax.Array], axes: AxisNames, layout: WireLayout,
    keys: Sequence[Optional[jax.Array]],
) -> list[jax.Array]:
    """One-launch layer reduce-scatter (sum): each tensor's P destination
    chunks are quantized (or bitcast, for fp segments) into per-destination
    byte rows; all tensors' rows ride ONE (P, layout.nbytes) u8 all_to_all,
    then each destination dequant-sums its P received chunks.

    layout.segments[i].n must equal gs[i].size // P.  Quantized segments are
    bit-identical on the wire to `reduce_scatter_quantized` with the same
    key; fp segments ship grad_wire_dtype bytes but are summed in f32 after
    the exchange (the ring psum_scatter reduces in the wire dtype instead —
    the coalesced form is at least as accurate)."""
    p = _axis_size(axes)
    rows = []
    for g, seg, key in zip(gs, layout.segments, keys):
        chunks = g.reshape(p, seg.n)
        if seg.cfg is None:
            rows.append(jax.vmap(lambda c: fp_pack(c, seg.fp_dtype))(chunks))
        else:
            q = jax.vmap(lambda c, k: quantize(c, seg.cfg, k))(
                chunks, jax.random.split(key, p))
            rows.append(jax.vmap(wire_pack)(q))
    buf = jnp.concatenate(rows, axis=1)  # (P, layout.nbytes)
    rbuf = lax.all_to_all(buf, axes, split_axis=0, concat_axis=0, tiled=True)
    return [jnp.sum(deq, axis=0) for deq in _decode_segments(rbuf, layout)]


def reduce_scatter_coalesced_hierarchical(
    gs: Sequence[jax.Array], pod_axis: str, inner_axes: AxisNames,
    inner_layout: WireLayout, pod_layout: WireLayout,
    keys: Sequence[Optional[jax.Array]],
) -> list[jax.Array]:
    """Two-level coalesced RS: full volume over the fast in-pod axes, then
    the 1/inner-sized partial across pods (one launch per level per layer).
    Per-tensor keys are split exactly like `reduce_scatter_hierarchical`."""
    k1 = [None if k is None else jax.random.split(k)[0] for k in keys]
    k2 = [None if k is None else jax.random.split(k)[1] for k in keys]
    partial_sums = reduce_scatter_coalesced(gs, inner_axes, inner_layout, k1)
    return reduce_scatter_coalesced(partial_sums, (pod_axis,), pod_layout, k2)


# ---------------------------------------------------------------------------
# Wire-byte accounting (used by the analytic communication model)
# ---------------------------------------------------------------------------


def gather_wire_bytes(n_local: int, p: int, cfg: QuantConfig | None, fp_bytes: int = 4) -> int:
    """Per-device bytes moved by one all-gather of an n_local-element shard
    (ring: receive (P-1) shards).  Identical for the per-tensor and the
    coalesced wire format — coalescing changes launches, not bytes."""
    if cfg is None:
        return (p - 1) * n_local * fp_bytes
    s = quantized_shapes(n_local, cfg)
    per_shard = s["codes"][0] * s["codes"][1] + 2 * cfg.meta_bytes * s["scale"][0]
    return (p - 1) * per_shard


def reduce_scatter_wire_bytes(n: int, p: int, cfg: QuantConfig | None, fp_bytes: int = 4) -> int:
    """Per-device bytes moved by one reduce-scatter of an n-element tensor."""
    if cfg is None:
        return (p - 1) * (n // p) * fp_bytes
    s = quantized_shapes(n // p, cfg)
    per_chunk = s["codes"][0] * s["codes"][1] + 2 * cfg.meta_bytes * s["scale"][0]
    return (p - 1) * per_chunk
