"""Quantized collectives — the TPU-native equivalents of CGX's quantized
NCCL AllGather / ReduceScatter (paper Section 5).

All functions here are *per-device* code: they must be called inside
``jax.shard_map``.  Axis names refer to mesh axes of the enclosing
shard_map.

Design notes (see DESIGN.md §2):

* **Quantized all-gather** ships int8-packed codes + per-bucket (scale, zero)
  f32 metadata.  The receiving side dequantizes after the gather, so the wire
  carries ``~ bits/32`` of the fp32 volume.  Appears in compiled HLO as
  ``all-gather`` of ``u8[...]`` operands — this is what the roofline parser
  counts.

* **Quantized reduce-scatter** cannot use a ring reduce-scatter (codes from
  different peers have different scales and cannot be summed in transit).
  The TPU-native formulation is a single ``all_to_all`` of quantized chunks
  followed by a local dequant-sum: identical wire volume to a ring RS
  (``(P-1)/P * N * bits/8`` per device) and one collective instead of P-1
  steps.  This mirrors how CGX implements it over NCCL P2P.

* **Hierarchical variants** split the FSDP axes (pod, data): reduce-scatter
  over the fast in-pod axis first, so only ``1/data`` of the volume crosses
  the slow pod boundary — the paper's hierarchical inter-node collectives.
"""
from __future__ import annotations

from functools import partial
from typing import Sequence

import jax
import jax.numpy as jnp
from jax import lax

from ..compat import axis_size
from .quant import QuantConfig, Quantized, dequantize, quantize, quantized_shapes

AxisNames = tuple[str, ...]


def _axis_size(axes: AxisNames) -> int:
    s = 1
    for a in axes:
        s *= axis_size(a)  # static int (see compat.axis_size)
    return s


# ---------------------------------------------------------------------------
# Full-precision fallbacks (filtered params / baseline FSDP)
# ---------------------------------------------------------------------------


def all_gather_fp(x: jax.Array, axes: AxisNames, dtype=None) -> jax.Array:
    """Plain all-gather, optionally casting the wire dtype (baseline FSDP
    ships weights fp32, i.e. dtype=None; bf16 wire is a cheap ablation)."""
    if dtype is not None and x.dtype != dtype:
        y = lax.all_gather(x.astype(dtype), axes, tiled=True)
        return y.astype(x.dtype)
    return lax.all_gather(x, axes, tiled=True)


def reduce_scatter_fp(x: jax.Array, axes: AxisNames, dtype=None) -> jax.Array:
    """Plain reduce-scatter (sum) over flattened leading dim."""
    if dtype is not None and x.dtype != dtype:
        return lax.psum_scatter(x.astype(dtype), axes, tiled=True).astype(x.dtype)
    return lax.psum_scatter(x, axes, tiled=True)


# ---------------------------------------------------------------------------
# Quantized all-gather
# ---------------------------------------------------------------------------


def _decode_shards(
    codes: jax.Array,
    scale: jax.Array,
    zero: jax.Array,
    p: int,
    n_local: int,
    cfg: QuantConfig,
    dtype,
) -> jax.Array:
    """Decode P concatenated per-shard code blocks, respecting the fact that
    each source shard was padded to a bucket multiple *independently*."""
    nb_local = codes.shape[0] // p
    chunks = codes.reshape(p, nb_local, codes.shape[-1])
    out = jax.vmap(
        lambda c, s, z: dequantize(Quantized(c, s, z, (n_local,), n_local, cfg))
    )(chunks, scale.reshape(p, nb_local), zero.reshape(p, nb_local))
    return out.reshape(-1).astype(dtype)


def all_gather_quantized(
    x: jax.Array, axes: AxisNames, cfg: QuantConfig, key: jax.Array,
    out_dtype=None,
) -> jax.Array:
    """Gather a flat per-device shard into the full (flat) tensor, shipping
    quantized codes.  x: (n_local,) f32/bf16 -> (P * n_local,) out_dtype
    (default x.dtype).  Decoding straight to bf16 halves the materialized
    weight bytes with zero information loss (codes are <=8 bits) — §Perf."""
    q = quantize(x, cfg, key)
    codes = lax.all_gather(q.codes, axes, tiled=True)  # (P*nb, bsz/cpb) u8
    scale = lax.all_gather(q.scale, axes, tiled=True)  # (P*nb,) f32
    zero = lax.all_gather(q.zero, axes, tiled=True)
    p = _axis_size(axes)
    return _decode_shards(codes, scale, zero, p, x.shape[0], cfg,
                          out_dtype or x.dtype)


def all_gather_hierarchical(
    x: jax.Array, pod_axis: str, inner_axes: AxisNames, cfg: QuantConfig,
    key: jax.Array, out_dtype=None,
) -> jax.Array:
    """Two-level gather: cross-pod first (moves only the local shard over the
    slow links), then in-pod.  Because the engine orders its flat FSDP axes
    data-major (`fsdp_axes = ("data", "pod")`), gathering over "pod" first and
    then "data" reproduces exactly the flat element order."""
    q = quantize(x, cfg, key)
    codes = lax.all_gather(q.codes, pod_axis, tiled=True)
    scale = lax.all_gather(q.scale, pod_axis, tiled=True)
    zero = lax.all_gather(q.zero, pod_axis, tiled=True)
    codes = lax.all_gather(codes, inner_axes, tiled=True)
    scale = lax.all_gather(scale, inner_axes, tiled=True)
    zero = lax.all_gather(zero, inner_axes, tiled=True)
    p = axis_size(pod_axis) * _axis_size(inner_axes)
    return _decode_shards(codes, scale, zero, p, x.shape[0], cfg,
                          out_dtype or x.dtype)


# ---------------------------------------------------------------------------
# Quantized reduce-scatter (sum) via all_to_all + local dequant-sum
# ---------------------------------------------------------------------------


def reduce_scatter_quantized(
    g: jax.Array, axes: AxisNames, cfg: QuantConfig, key: jax.Array
) -> jax.Array:
    """Sum `g` across `axes`, leaving each device its own 1/P chunk.

    g: (n,) per-device full (unreduced) tensor with n % P == 0.
    Returns (n/P,) f32 — the summed chunk owned by this device.
    """
    p = _axis_size(axes)
    n = g.shape[0]
    assert n % p == 0, (n, p)
    chunks = g.reshape(p, n // p)
    q = jax.vmap(lambda c, k: quantize(c, cfg, k))(
        chunks, jax.random.split(key, p)
    )
    # Each row i goes to device i of the logical axis; we receive P rows.
    codes = lax.all_to_all(q.codes, axes, split_axis=0, concat_axis=0, tiled=True)
    scale = lax.all_to_all(q.scale, axes, split_axis=0, concat_axis=0, tiled=True)
    zero = lax.all_to_all(q.zero, axes, split_axis=0, concat_axis=0, tiled=True)
    deq = jax.vmap(
        lambda c, s, z: dequantize(
            Quantized(c, s, z, (n // p,), n // p, cfg)
        )
    )(codes, scale, zero)
    return jnp.sum(deq, axis=0)


def reduce_scatter_hierarchical(
    g: jax.Array, pod_axis: str, inner_axes: AxisNames, cfg: QuantConfig, key: jax.Array
) -> jax.Array:
    """Two-level quantized reduce-scatter: RS over the in-pod axes first
    (full volume stays on fast links), then RS of the 1/inner-sized partial
    across pods — only ``n/inner`` bytes cross the pod boundary."""
    k1, k2 = jax.random.split(key)
    partial_sum = reduce_scatter_quantized(g, inner_axes, cfg, k1)
    return reduce_scatter_quantized(partial_sum, (pod_axis,), cfg, k2)


# ---------------------------------------------------------------------------
# Wire-byte accounting (used by the analytic communication model)
# ---------------------------------------------------------------------------


def gather_wire_bytes(n_local: int, p: int, cfg: QuantConfig | None, fp_bytes: int = 4) -> int:
    """Per-device bytes moved by one all-gather of an n_local-element shard
    (ring: receive (P-1) shards)."""
    if cfg is None:
        return (p - 1) * n_local * fp_bytes
    s = quantized_shapes(n_local, cfg)
    per_shard = s["codes"][0] * s["codes"][1] + 8 * s["scale"][0]
    return (p - 1) * per_shard


def reduce_scatter_wire_bytes(n: int, p: int, cfg: QuantConfig | None, fp_bytes: int = 4) -> int:
    """Per-device bytes moved by one reduce-scatter of an n-element tensor."""
    if cfg is None:
        return (p - 1) * (n // p) * fp_bytes
    s = quantized_shapes(n // p, cfg)
    per_chunk = s["codes"][0] * s["codes"][1] + 8 * s["scale"][0]
    return (p - 1) * per_chunk
