"""Learned quantization levels (paper Section 5.2, Algorithm 2).

The paper's optional optimization: instead of the uniform grid, the
locations of the ``2^b`` quantization levels are optimized with a fast
SGD-style pass over the (bucket-normalized) values:

    for each value v_i:
        q_j = find_closest(v_i, Q)
        q_j = q_j - lr * (q_j - v_i)

We implement the exact per-value sequential rule (for small inputs / tests)
and a vectorized minibatch variant (paper: batch 1024, lr 0.01) that applies
the accumulated per-level update once per batch — the estimator the paper's
implementation uses in practice.  Levels are learned per-layer after a
warmup period and then frozen (App. C shows one learning pass suffices).

Non-uniform encode/decode uses the same bucketed min-max normalization as
`core.quant`, so learned levels drop into the same wire format: codes are
indices into the level table, which is shipped once per (layer, refresh).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from .quant import QuantConfig, Quantized, _to_buckets, pack_codes, unpack_codes


def uniform_levels(bits: int) -> jax.Array:
    """Initial (uniform) level locations on the normalized [0, 1] range."""
    return jnp.linspace(0.0, 1.0, 1 << bits)


def _nearest_level(v: jax.Array, levels: jax.Array) -> jax.Array:
    """Index of the closest level for each value (levels need not be sorted
    during learning, so use argmin rather than searchsorted)."""
    return jnp.argmin(jnp.abs(v[..., None] - levels), axis=-1)


def learn_levels_minibatch(
    values: jax.Array,
    levels: jax.Array,
    lr: float = 0.01,
    batch_size: int = 1024,
) -> jax.Array:
    """One epoch of Algorithm 2 over `values` (already normalized to [0,1]).

    Vectorized: for each minibatch, every value pulls its closest level
    toward itself; per-level updates within a batch are averaged.  This is
    the standard mean-shift relaxation of the sequential rule.
    """
    n = values.shape[0]
    pad = (-n) % batch_size
    v = jnp.pad(values, (0, pad))
    valid = jnp.pad(jnp.ones((n,), jnp.float32), (0, pad))
    v = v.reshape(-1, batch_size)
    valid = valid.reshape(-1, batch_size)
    k = levels.shape[0]

    def body(lv, batch):
        vb, mb = batch
        idx = _nearest_level(vb, lv)
        one_hot = jax.nn.one_hot(idx, k, dtype=jnp.float32) * mb[:, None]
        cnt = jnp.sum(one_hot, axis=0)
        mean_v = jnp.sum(one_hot * vb[:, None], axis=0) / jnp.maximum(cnt, 1.0)
        # Applying the sequential rule to `cnt` values near `mean_v` moves the
        # level by (1 - (1-lr)^cnt) of the way toward their mean; use that
        # closed-form rate so one vectorized pass matches the paper's loop.
        rate = 1.0 - (1.0 - lr) ** cnt
        upd = jnp.where(cnt > 0, lv - rate * (lv - mean_v), lv)
        return upd, None

    levels, _ = jax.lax.scan(body, levels, (v, valid))
    return levels


def learn_levels_sequential(values: jax.Array, levels: jax.Array, lr: float = 0.01) -> jax.Array:
    """The literal per-value loop of Algorithm 2 (reference / tests)."""

    def body(lv, vi):
        j = _nearest_level(vi, lv)
        return lv.at[j].add(-lr * (lv[j] - vi)), None

    levels, _ = jax.lax.scan(body, levels, values)
    return levels


@dataclasses.dataclass(frozen=True)
class LevelsConfig:
    bits: int = 4
    bucket_size: int = 1024
    lr: float = 0.01
    batch_size: int = 1024
    epochs: int = 1
    min_params: int = 100_000  # layers smaller than this stay uniform (App. C)


def learn_levels_for_tensor(x: jax.Array, cfg: LevelsConfig) -> jax.Array:
    """Learn a level table for one tensor, after bucket-wise normalization
    (paper: 'Normalize values V bucket-wise')."""
    buckets, size = _to_buckets(x, cfg.bucket_size)
    lo = jnp.min(buckets, axis=1, keepdims=True)
    hi = jnp.max(buckets, axis=1, keepdims=True)
    v = ((buckets - lo) / jnp.maximum(hi - lo, 1e-12)).reshape(-1)[:size]
    levels = uniform_levels(cfg.bits)
    for _ in range(cfg.epochs):
        levels = learn_levels_minibatch(v, levels, cfg.lr, cfg.batch_size)
    return jnp.sort(levels)


# ---------------------------------------------------------------------------
# Non-uniform wire quantization with a level table.
# ---------------------------------------------------------------------------


def quantize_levels(
    x: jax.Array,
    levels: jax.Array,
    bucket_size: int = 1024,
    key: Optional[jax.Array] = None,
) -> Quantized:
    """Bucket-normalize then encode each value as the index of its nearest
    level (optionally stochastic between the two neighbours, keeping the
    estimator unbiased within the table's convex hull)."""
    bits = int(np.log2(levels.shape[0]))
    assert (1 << bits) == levels.shape[0], "level count must be a power of 2"
    cfg = QuantConfig(bits=bits, bucket_size=bucket_size, mode="nearest")
    buckets, size = _to_buckets(x, bucket_size)
    lo = jnp.min(buckets, axis=1, keepdims=True)
    hi = jnp.max(buckets, axis=1, keepdims=True)
    scale = jnp.maximum(hi - lo, 1e-12)
    v = (buckets - lo) / scale  # [0, 1]

    srt = jnp.sort(levels)
    # index of right neighbour in the sorted table
    hi_idx = jnp.clip(jnp.searchsorted(srt, v, side="right"), 1, srt.shape[0] - 1)
    lo_idx = hi_idx - 1
    l_lo, l_hi = srt[lo_idx], srt[hi_idx]
    frac = jnp.clip((v - l_lo) / jnp.maximum(l_hi - l_lo, 1e-12), 0.0, 1.0)
    if key is None:  # nearest level
        take_hi = frac > 0.5
    else:  # unbiased stochastic assignment between neighbours
        take_hi = jax.random.uniform(key, v.shape) < frac
    codes = jnp.where(take_hi, hi_idx, lo_idx).astype(jnp.uint8)
    return Quantized(
        codes=pack_codes(codes, bits),
        scale=scale[:, 0],
        zero=lo[:, 0],
        shape=tuple(x.shape),
        size=size,
        cfg=cfg,
    )


def dequantize_levels(q: Quantized, levels: jax.Array, dtype=jnp.float32) -> jax.Array:
    srt = jnp.sort(levels)
    codes = unpack_codes(q.codes, q.cfg.bits)
    v = srt[codes]
    x = v * q.scale[:, None] + q.zero[:, None]
    return x.reshape(-1)[: q.size].reshape(q.shape).astype(dtype)


def compression_error(x: jax.Array, xq: jax.Array) -> jax.Array:
    """Relative L2 compression error (paper Figures 7/8 metric)."""
    return jnp.linalg.norm((x - xq).reshape(-1)) / jnp.maximum(
        jnp.linalg.norm(x.reshape(-1)), 1e-12
    )
