"""The QSDP engine: fully-sharded data-parallel parameters with quantized
communication (the paper's contribution, as a composable JAX module).

Layout
------
Every logical parameter (logical shape ``spec.shape``, optionally a scanned
stack of ``spec.stack`` layers, optionally tensor-parallel along
``spec.tp_axis``) is stored *at rest* in the distributed layout

    (stack?, MODEL, FSDP, n_local)

where ``n_local = ceil(prod(tp_local_shape) / FSDP)`` (zero-padded).  The
shard_map in_spec for such a leaf is ``P(None?, "model", fsdp_axes, None)``,
i.e. each device holds a flat f32 1/FSDP-slice of its tensor-parallel shard
— exactly torch-FSDP's flat-parameter sharding, composed with Megatron TP.

Inside the step (per device), :meth:`QSDPEngine.gather` reconstructs the
TP-local tensor for one layer:

    forward :  quantize(local shard) -> all-gather(codes+scales) -> dequant
    backward:  quantize(grad chunks) -> all-to-all -> dequant-sum  (= quantized
               reduce-scatter), divided by the FSDP size (data-parallel mean),
               plus a psum over "model" for TP-replicated params.

wrapped in ``jax.custom_vjp`` so the paper's 2×AllGather + 1×ReduceScatter
per layer per step emerges naturally from ``jax.checkpoint``-rematerialized
scan-over-layers.

Coalesced wire format (``QSDPConfig.coalesce``, default on)
-----------------------------------------------------------
The per-tensor collectives cost 3 launches per quantized tensor (codes,
scale, zero) — ~23 all-gather launches per transformer layer per direction.
With ``coalesce=True`` every gather/reduce-scatter ships ONE contiguous u8
wire buffer (``core.collectives.WireLayout``): :meth:`QSDPEngine.gather`
coalesces a single tensor's three components, and
:meth:`QSDPEngine.gather_layer` coalesces *all* params of a layer dict —
quantized payloads and full-precision (filtered) ones alike — into one
collective per layer.  The bytes on the wire and the decoded values are
bit-identical to the per-tensor path (same per-tensor quantization keys);
only the launch count changes: 3 × n_params -> 1.

Double-buffered prefetch (``QSDPConfig.prefetch``, default off)
---------------------------------------------------------------
:meth:`QSDPEngine.gather_layer_start` issues the coalesced all-gather and
returns the *wire buffer* (u8); :meth:`QSDPEngine.gather_layer_finish`
decodes a previously gathered buffer and owns the backward reduce-scatter.
``models.transformer._scan_layers`` uses the pair to run a software
pipeline: the gather for layer i+1 is issued while layer i computes, the
(compact, ``~bits/32``-sized) wire buffer rides the scan carry, and the
rematerialized backward replays the same schedule — so the collective for
the next layer can overlap the current layer's compute in both directions.

Filtering (paper Section 5): normalization layers / biases / any tensor
smaller than ``min_quant_size`` travel in full precision, as do all tensors
when the engine is configured as the *baseline FSDP* (fp32 weights / bf16
gradients).
"""
from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import PartitionSpec as P

from ..compat import axis_size
from ..kernels.ops import RowQuantWeight
from . import collectives as coll
from .quant import (QuantConfig, QuantizedParam, dequantize, quantize,
                    unpack_codes, wire_unpack)

# ---------------------------------------------------------------------------
# Mesh description
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class MeshSpec:
    """Static view of the training mesh.

    axes/shape: as built by launch.mesh.make_production_mesh — either
    ("data", "model") or ("pod", "data", "model").
    """

    axes: tuple[str, ...]
    shape: tuple[int, ...]

    @property
    def multi_pod(self) -> bool:
        return "pod" in self.axes

    @property
    def fsdp_axes(self) -> tuple[str, ...]:
        # data-major ordering so hierarchical collectives (gather pod first,
        # then data) land in the same element order as the flat tuple form.
        return ("data", "pod") if self.multi_pod else ("data",)

    @property
    def model_axis(self) -> str:
        return "model"

    @property
    def fsdp_size(self) -> int:
        s = dict(zip(self.axes, self.shape))
        return s["data"] * (s.get("pod", 1))

    @property
    def model_size(self) -> int:
        return dict(zip(self.axes, self.shape))["model"]

    @property
    def batch_spec(self) -> P:
        return P(self.fsdp_axes)


# ---------------------------------------------------------------------------
# Parameter specification
# ---------------------------------------------------------------------------

InitKind = str  # "normal" | "zeros" | "ones" | "scaled_normal"


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    """One logical parameter of the model."""

    shape: tuple[int, ...]  # logical (TP-global) shape, without stack dim
    tp_axis: Optional[int] = None  # axis sharded over "model" (None = replicated)
    stack: Optional[int] = None  # scan-over-layers length
    init: InitKind = "normal"
    init_scale: float = 0.02
    quantize: bool = True  # False => always full-precision comm (norms/bias)
    # True for model-REPLICATED params whose outputs are consumed
    # rank-specifically (e.g. replicated KV projections, Mamba B/C): each
    # model rank's gradient is then only a partial sum and the engine psums
    # it over the model axis to keep the replicas consistent.
    grad_sync_model: bool = False

    def tp_local_shape(self, model_size: int) -> tuple[int, ...]:
        if self.tp_axis is None:
            return self.shape
        assert self.shape[self.tp_axis] % model_size == 0, (self.shape, self.tp_axis, model_size)
        s = list(self.shape)
        s[self.tp_axis] //= model_size
        return tuple(s)

    def n_logical_local(self, model_size: int) -> int:
        return int(np.prod(self.tp_local_shape(model_size)))

    def n_local(self, ms: MeshSpec) -> int:
        n = self.n_logical_local(ms.model_size)
        return -(-n // ms.fsdp_size)  # ceil

    def rest_shape(self, ms: MeshSpec) -> tuple[int, ...]:
        base = (ms.model_size, ms.fsdp_size, self.n_local(ms))
        return (self.stack, *base) if self.stack is not None else base

    def rest_pspec(self, ms: MeshSpec) -> P:
        base = ("model", ms.fsdp_axes, None)
        return P(None, *base) if self.stack is not None else P(*base)

    @property
    def logical_size(self) -> int:
        n = int(np.prod(self.shape))
        return n * (self.stack or 1)


def to_rest(full: jax.Array, spec: ParamSpec, ms: MeshSpec) -> jax.Array:
    """Logical layout -> distributed rest layout (host-side / init / ckpt)."""
    lead = 1 if spec.stack is not None else 0
    x = full
    if spec.tp_axis is not None:
        ax = spec.tp_axis + lead
        tp = ms.model_size
        s = list(x.shape)
        x = x.reshape(*s[:ax], tp, s[ax] // tp, *s[ax + 1 :])
        x = jnp.moveaxis(x, ax, lead)  # (stack?, model, ...tp_local...)
    else:
        x = jnp.expand_dims(x, lead)
        x = jnp.broadcast_to(x, (*x.shape[:lead], ms.model_size, *x.shape[lead + 1 :]))
    # flatten tp-local part, pad, split over fsdp
    batch_dims = x.shape[: lead + 1]
    flat = x.reshape(*batch_dims, -1)
    n = flat.shape[-1]
    n_local = -(-n // ms.fsdp_size)
    pad = n_local * ms.fsdp_size - n
    if pad:
        flat = jnp.pad(flat, [(0, 0)] * len(batch_dims) + [(0, pad)])
    return flat.reshape(*batch_dims, ms.fsdp_size, n_local)


def from_rest(rest: jax.Array, spec: ParamSpec, ms: MeshSpec) -> jax.Array:
    """Distributed rest layout -> logical layout (checkpoint export/eval)."""
    lead = 1 if spec.stack is not None else 0
    batch_dims = rest.shape[: lead + 1]
    flat = rest.reshape(*batch_dims, -1)
    n = int(np.prod(spec.tp_local_shape(ms.model_size)))
    flat = flat[..., :n]
    x = flat.reshape(*batch_dims, *spec.tp_local_shape(ms.model_size))
    if spec.tp_axis is None:
        return x[:, 0] if lead else x[0]
    ax = spec.tp_axis + lead
    x = jnp.moveaxis(x, lead, ax)  # (stack?, ..., model, tp_local_dim, ...)
    s = list(x.shape)
    out = x.reshape(*s[:ax], s[ax] * s[ax + 1], *s[ax + 2 :])
    return out


def init_param(key: jax.Array, spec: ParamSpec, ms: MeshSpec, dtype=jnp.float32) -> jax.Array:
    shape = ((spec.stack,) if spec.stack is not None else ()) + spec.shape
    if spec.init == "zeros":
        full = jnp.zeros(shape, dtype)
    elif spec.init == "ones":
        full = jnp.ones(shape, dtype)
    elif spec.init == "constant":
        full = jnp.full(shape, spec.init_scale, dtype)
    elif spec.init == "normal":
        full = jax.random.normal(key, shape, dtype) * spec.init_scale
    elif spec.init == "scaled_normal":  # 1/sqrt(fan_in) init
        fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
        full = jax.random.normal(key, shape, dtype) * (spec.init_scale / math.sqrt(fan_in))
    else:
        raise ValueError(spec.init)
    return to_rest(full, spec, ms)


# ---------------------------------------------------------------------------
# Engine configuration
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class QSDPConfig:
    """Communication policy.  The paper's QSDP default is W8G8 bucket=1024;
    `baseline()` reproduces the paper's FSDP baseline (fp32 weights / half-
    precision gradients)."""

    quantize_weights: bool = True
    quantize_grads: bool = True
    weight_bits: int = 8
    grad_bits: int = 8
    bucket_size: int = 1024
    weight_mode: str = "shift"  # Definition 1
    grad_mode: str = "stochastic"  # Definition 12
    min_quant_size: int = 2048  # smaller tensors go full precision
    weight_wire_dtype: str = "float32"  # fp path wire dtype for weights
    grad_wire_dtype: str = "bfloat16"  # fp path wire dtype for grads (paper: fp16)
    hierarchical: bool = False  # 2-level collectives over (pod, data)
    compute_dtype: str = "bfloat16"
    # activation-checkpoint policy for the scan-over-layers:
    #   "full" — recompute everything in backward (min memory),
    #   "dots" — save matmul outputs (jax.checkpoint_policies
    #            .dots_with_no_batch_dims_saveable): ~25% less recompute
    #            FLOPs for ~1 extra activation set per layer (§Perf).
    remat_policy: str = "full"
    # §Perf knob: bf16 attention matmul operands w/ f32 accumulation
    attn_bf16: bool = False
    # §Perf knob: dequantize gathered weights straight to the compute dtype
    # (bf16), skipping the f32 intermediate — halves materialized weight
    # bytes with zero information loss (codes are <=8 bits).
    dequant_to_compute: bool = False
    # §Perf knob: u16 stochastic-rounding thresholds (4x less RNG traffic)
    rand_bits: int = 32
    # §Perf knob: coalesced wire format — serialize codes + (scale, zero)
    # metadata of every tensor of a gather (and of a whole layer dict via
    # gather_layer) into ONE contiguous u8 buffer, so each layer gather /
    # reduce-scatter is ONE collective launch instead of 3 x n_params.
    # Bit-exact vs. the per-tensor collectives (same keys, same wire bytes).
    coalesce: bool = True
    # Per-layer byte threshold on the coalesced path (None = coalesce every
    # layer when coalesce=True).  Coalescing trades 3*n_params-1 launch
    # overheads for extra serialization passes over ONE gathered buffer of
    # P * layout.nbytes bytes (segment concat, f32<->u8 bitcasts, vmap'd
    # per-shard decode) — a win only while that buffer is small relative to
    # the launch overhead it saves.  On the tiny smoke CPU mesh the
    # serialization side dominates (qsdp-coalesced 370 ms vs plain qsdp
    # 204 ms median), so the deployment-plan autotuner (repro.tune) sets
    # this threshold from its cost model: layers whose per-device gathered
    # wire buffer exceeds it fall back to per-tensor gathers.  Because the
    # two paths are bit-identical (same per-tensor quantization keys), the
    # policy can flip per layer without changing a single gradient bit.
    coalesce_max_bytes: Optional[int] = None
    # §Perf knob: double-buffered layer prefetch — the scan-over-layers
    # issues the coalesced gather for layer i+1 while layer i computes,
    # carrying the u8 wire buffer through the scan carry (forward AND the
    # rematerialized backward).  Requires coalesce=True.  Costs one extra
    # (discarded) gather per stack traversal and one wire buffer of
    # residency per live layer.
    prefetch: bool = False
    # on-wire dtype of the per-bucket (scale, zero) quantization metadata:
    # "float32" (exact) or "bfloat16" (halves metadata bytes; perturbs the
    # decode affine by ~2^-8 relative).  Accounted by gather_wire_bytes /
    # reduce_scatter_wire_bytes and the Fig-4 bandwidth model.
    meta_wire_dtype: str = "float32"

    @classmethod
    def baseline(cls) -> "QSDPConfig":
        """The paper's FSDP baseline: fp32 weights / bf16 grads, per-tensor
        collectives (no wire coalescing — torch-FSDP launches per leaf)."""
        return cls(quantize_weights=False, quantize_grads=False, coalesce=False)

    @classmethod
    def w8g8(cls, **kw) -> "QSDPConfig":
        return cls(**kw)

    def wcfg(self) -> QuantConfig:
        return QuantConfig(bits=self.weight_bits, bucket_size=self.bucket_size,
                           mode=self.weight_mode, rand_bits=self.rand_bits,
                           meta_dtype=self.meta_wire_dtype)

    def gcfg(self) -> QuantConfig:
        return QuantConfig(bits=self.grad_bits, bucket_size=self.bucket_size,
                           mode=self.grad_mode, rand_bits=self.rand_bits,
                           meta_dtype=self.meta_wire_dtype)


@dataclasses.dataclass(frozen=True)
class _GatherStatic:
    """Hashable static payload for the custom_vjp gather."""

    fsdp_axes: tuple[str, ...]
    model_axis: str
    grad_sync_model: bool
    wcfg: Optional[QuantConfig]  # None => full-precision weight path
    gcfg: Optional[QuantConfig]  # None => full-precision grad path
    weight_wire_dtype: str
    grad_wire_dtype: str
    hierarchical: bool
    gather_out_dtype: Optional[str] = None  # None => shard dtype (f32)

    @property
    def pod_axis(self) -> Optional[str]:
        return "pod" if "pod" in self.fsdp_axes else None

    @property
    def inner_axes(self) -> tuple[str, ...]:
        return tuple(a for a in self.fsdp_axes if a != "pod")


# ---------------------------------------------------------------------------
# The gather primitive (per-device; used inside shard_map)
# ---------------------------------------------------------------------------


def _gather_fwd_impl(flat: jax.Array, key: jax.Array, st: _GatherStatic) -> jax.Array:
    out_dt = getattr(jnp, st.gather_out_dtype) if st.gather_out_dtype else None
    if st.wcfg is None:
        return coll.all_gather_fp(flat, st.fsdp_axes, getattr(jnp, st.weight_wire_dtype))
    if st.hierarchical and st.pod_axis is not None:
        return coll.all_gather_hierarchical(flat, st.pod_axis, st.inner_axes,
                                            st.wcfg, key, out_dtype=out_dt)
    return coll.all_gather_quantized(flat, st.fsdp_axes, st.wcfg, key,
                                     out_dtype=out_dt)


def _grad_rs_impl(ct: jax.Array, key: jax.Array, st: _GatherStatic) -> jax.Array:
    # Gradient semantics (see core/tp.py docstring): the loss function returns
    # the per-device local-batch mean with no collectives on the loss path;
    # the cotangent arriving here is d(local loss)/d(full weight).  The
    # reduce-scatter sums over the FSDP group and we divide by its size, so
    # the result is exactly d(global-batch-mean loss)/d(shard).  Model-axis
    # sums for TP-replicated params are owned by tp_copy's backward; the
    # cotangent here is already identical across model ranks.
    p = 1
    for a in st.fsdp_axes:
        p *= axis_size(a)
    if st.gcfg is None:
        g = coll.reduce_scatter_fp(ct, st.fsdp_axes, getattr(jnp, st.grad_wire_dtype))
    elif st.hierarchical and st.pod_axis is not None:
        g = coll.reduce_scatter_hierarchical(ct, st.pod_axis, st.inner_axes, st.gcfg, key)
    else:
        g = coll.reduce_scatter_quantized(ct, st.fsdp_axes, st.gcfg, key)
    g = g.astype(jnp.float32) / p
    if st.grad_sync_model:
        g = lax.psum(g, st.model_axis)
    return g


@partial(jax.custom_vjp, nondiff_argnums=(2,))
def qsdp_gather(flat: jax.Array, key: jax.Array, st: _GatherStatic) -> jax.Array:
    """(n_local,) f32 shard -> (FSDP * n_local,) full flat tensor."""
    return _gather_fwd_impl(flat, key, st)


def _qsdp_gather_fwd(flat, key, st):
    return _gather_fwd_impl(flat, key, st), key


def _qsdp_gather_bwd(st, key, ct):
    bkey = jax.random.fold_in(key, 0x5D)
    d_flat = _grad_rs_impl(ct.astype(jnp.float32), bkey, st)
    return d_flat, jnp.zeros_like(key)


qsdp_gather.defvjp(_qsdp_gather_fwd, _qsdp_gather_bwd)


# ---------------------------------------------------------------------------
# Coalesced layer gather: ONE collective for all params of a layer dict.
#
# Three entry points (all over a tuple of flat shards, ordered by st.names):
#
#   qsdp_gather_layer(shards, key, st)          fused encode+gather+decode
#   qsdp_gather_layer_start(shards, key, st)    encode + all-gather -> u8 wire
#   qsdp_gather_layer_finish(shards, wire, key, st)   decode a carried wire
#
# start/finish split the op across scan iterations for the prefetch
# pipeline: `start` has no custom VJP (its u8 output is non-differentiable,
# so AD never touches the launch), while `finish` owns the whole backward —
# its cotangent is reduce-scattered (coalesced, one launch) back to the
# shards, exactly like the fused form.  The `shards` argument of `finish` is
# unused in the primal (the wire already holds their quantized bytes); it
# exists to give the VJP a differentiable path back to the parameters.
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class _LayerStatic:
    """Hashable static payload for the coalesced layer gather."""

    names: tuple[str, ...]  # full param names (buffer segment order)
    n_locals: tuple[int, ...]  # per-device shard sizes
    quant: tuple[bool, ...]  # weight path quantized per param
    gquant: tuple[bool, ...]  # grad path quantized per param
    gsync: tuple[bool, ...]  # psum grads over the model axis per param
    fsdp_axes: tuple[str, ...]
    model_axis: str
    wcfg: Optional[QuantConfig]
    gcfg: Optional[QuantConfig]
    weight_wire_dtype: str
    grad_wire_dtype: str
    hierarchical: bool
    gather_out_dtype: Optional[str] = None

    @property
    def pod_axis(self) -> Optional[str]:
        return "pod" if "pod" in self.fsdp_axes else None

    @property
    def inner_axes(self) -> tuple[str, ...]:
        return tuple(a for a in self.fsdp_axes if a != "pod")

    def fsdp_size(self) -> int:
        p = 1
        for a in self.fsdp_axes:
            p *= axis_size(a)
        return p

    def gather_layout(self) -> coll.WireLayout:
        return coll.WireLayout(tuple(
            coll.WireSegment(n, self.wcfg if q else None, self.weight_wire_dtype)
            for n, q in zip(self.n_locals, self.quant)
        ))

    def rs_layout(self, chunk_div: int) -> coll.WireLayout:
        """Layout of the grad RS rows when each tensor's full size
        (p * n_local) is split into chunk_div chunks (one per destination
        of the level being reduced)."""
        p = self.fsdp_size()
        return coll.WireLayout(tuple(
            coll.WireSegment(n * p // chunk_div,
                             self.gcfg if q else None, self.grad_wire_dtype)
            for n, q in zip(self.n_locals, self.gquant)
        ))


def _layer_keys(key: jax.Array, st: _LayerStatic) -> list:
    """Per-param gather keys — the same fold the per-tensor path applies, so
    coalesced and per-tensor quantization draw identical randomness."""
    return [jax.random.fold_in(key, _stable_hash(n)) for n in st.names]


def _layer_encode_gather(shards, key: jax.Array, st: _LayerStatic) -> jax.Array:
    keys = _layer_keys(key, st)
    buf = coll.encode_wire([s.reshape(-1) for s in shards],
                           st.gather_layout(), keys)
    pod = st.pod_axis if st.hierarchical else None
    return coll.gather_wire(buf, st.fsdp_axes, pod_axis=pod)


def _layer_decode(wire: jax.Array, st: _LayerStatic):
    out_dt = getattr(jnp, st.gather_out_dtype) if st.gather_out_dtype else jnp.float32
    dts = [out_dt if q else jnp.float32 for q in st.quant]
    return tuple(coll.decode_gathered_wire(
        wire, st.gather_layout(), st.fsdp_size(), dts))


def _layer_grad_rs(cts, key: jax.Array, st: _LayerStatic):
    p = st.fsdp_size()
    keys = [jax.random.fold_in(k, 0x5D) for k in _layer_keys(key, st)]
    if st.hierarchical and st.pod_axis is not None:
        p_inner = 1
        for a in st.inner_axes:
            p_inner *= axis_size(a)
        outs = coll.reduce_scatter_coalesced_hierarchical(
            cts, st.pod_axis, st.inner_axes,
            st.rs_layout(p_inner), st.rs_layout(p), keys)
    else:
        outs = coll.reduce_scatter_coalesced(cts, st.fsdp_axes,
                                             st.rs_layout(p), keys)
    res = []
    for g, sync in zip(outs, st.gsync):
        g = g.astype(jnp.float32) / p
        if sync:
            g = lax.psum(g, st.model_axis)
        res.append(g)
    return tuple(res)


@partial(jax.custom_vjp, nondiff_argnums=(2,))
def qsdp_gather_layer(shards: tuple, key: jax.Array, st: _LayerStatic) -> tuple:
    """Tuple of (n_local,) shards -> tuple of (P * n_local,) full flats,
    via one coalesced all-gather (backward: one coalesced reduce-scatter)."""
    return _layer_decode(_layer_encode_gather(shards, key, st), st)


def _qsdp_gather_layer_fwd(shards, key, st):
    return _layer_decode(_layer_encode_gather(shards, key, st), st), key


def _qsdp_gather_layer_bwd(st, key, cts):
    d = _layer_grad_rs([c.astype(jnp.float32) for c in cts], key, st)
    return d, jnp.zeros_like(key)


qsdp_gather_layer.defvjp(_qsdp_gather_layer_fwd, _qsdp_gather_layer_bwd)


def qsdp_gather_layer_start(shards: tuple, key: jax.Array, st: _LayerStatic) -> jax.Array:
    """Issue the coalesced all-gather; returns the (P * nbytes,) u8 wire
    buffer (prefetch pipeline: call one scan step ahead of the compute)."""
    return _layer_encode_gather(shards, key, st)


@partial(jax.custom_vjp, nondiff_argnums=(3,))
def qsdp_gather_layer_finish(shards: tuple, wire: jax.Array, key: jax.Array,
                             st: _LayerStatic) -> tuple:
    """Decode a wire buffer gathered by :func:`qsdp_gather_layer_start`.
    The primal ignores `shards` (their bytes are already in `wire`); the
    backward reduce-scatters the cotangents to them."""
    return _layer_decode(wire, st)


def _qsdp_gather_layer_finish_fwd(shards, wire, key, st):
    return _layer_decode(wire, st), key


def _qsdp_gather_layer_finish_bwd(st, key, cts):
    d = _layer_grad_rs([c.astype(jnp.float32) for c in cts], key, st)
    wire_len = st.fsdp_size() * st.gather_layout().nbytes
    return d, jnp.zeros((wire_len,), jnp.uint8), jnp.zeros_like(key)


qsdp_gather_layer_finish.defvjp(_qsdp_gather_layer_finish_fwd,
                                _qsdp_gather_layer_finish_bwd)


# ---------------------------------------------------------------------------
# Engine
# ---------------------------------------------------------------------------


class QSDPEngine:
    """Binds a MeshSpec + QSDPConfig + parameter specs into gather callables
    usable inside the shard_mapped step."""

    def __init__(self, ms: MeshSpec, cfg: QSDPConfig, specs: dict[str, ParamSpec]):
        self.ms = ms
        self.cfg = cfg
        self.specs = specs
        self.compute_dtype = getattr(jnp, cfg.compute_dtype)

    # -- static policy ------------------------------------------------------

    def _is_quantized(self, spec: ParamSpec) -> bool:
        return (
            spec.quantize
            and self.cfg.quantize_weights
            and spec.n_logical_local(self.ms.model_size) >= self.cfg.min_quant_size
        )

    def _is_grad_quantized(self, spec: ParamSpec) -> bool:
        return (
            spec.quantize
            and self.cfg.quantize_grads
            and spec.n_logical_local(self.ms.model_size) >= self.cfg.min_quant_size
        )

    def layer_wire_bytes(self, names: tuple[str, ...]) -> int:
        """Per-device bytes of the GATHERED coalesced wire buffer for one
        gather of `names` (= fsdp_size * encoded layout bytes) — the
        quantity the coalesce threshold compares against, and what the
        serialization term of the tune cost model scales with."""
        st = self._layer_static(tuple(names))
        return self.ms.fsdp_size * st.gather_layout().nbytes

    def layer_coalesced(self, names: tuple[str, ...]) -> bool:
        """Per-layer coalesce policy: ship these params as ONE wire buffer
        iff ``cfg.coalesce`` and the gathered buffer stays under
        ``cfg.coalesce_max_bytes`` (None = no threshold).  Purely static —
        decided from ParamSpecs at trace time, never from array values."""
        if not self.cfg.coalesce:
            return False
        if self.cfg.coalesce_max_bytes is None:
            return True
        return self.layer_wire_bytes(names) <= self.cfg.coalesce_max_bytes

    def _layer_static(self, names: tuple[str, ...]) -> _LayerStatic:
        specs = [self.specs[n] for n in names]
        return _LayerStatic(
            names=names,
            n_locals=tuple(s.n_local(self.ms) for s in specs),
            quant=tuple(self._is_quantized(s) for s in specs),
            gquant=tuple(self._is_grad_quantized(s) for s in specs),
            gsync=tuple(s.grad_sync_model for s in specs),
            fsdp_axes=self.ms.fsdp_axes,
            model_axis=self.ms.model_axis,
            wcfg=self.cfg.wcfg() if self.cfg.quantize_weights else None,
            gcfg=self.cfg.gcfg() if self.cfg.quantize_grads else None,
            weight_wire_dtype=self.cfg.weight_wire_dtype,
            grad_wire_dtype=self.cfg.grad_wire_dtype,
            hierarchical=self.cfg.hierarchical,
            gather_out_dtype=(self.cfg.compute_dtype
                              if getattr(self.cfg, "dequant_to_compute", False)
                              else None),
        )

    def _static_for(self, spec: ParamSpec) -> _GatherStatic:
        quant = self._is_quantized(spec)
        grad_quant = self._is_grad_quantized(spec)
        return _GatherStatic(
            fsdp_axes=self.ms.fsdp_axes,
            model_axis=self.ms.model_axis,
            grad_sync_model=spec.grad_sync_model,
            wcfg=self.cfg.wcfg() if quant else None,
            gcfg=self.cfg.gcfg() if grad_quant else None,
            weight_wire_dtype=self.cfg.weight_wire_dtype,
            grad_wire_dtype=self.cfg.grad_wire_dtype,
            hierarchical=self.cfg.hierarchical,
            gather_out_dtype=(self.cfg.compute_dtype
                              if getattr(self.cfg, "dequant_to_compute", False)
                              else None),
        )

    # -- per-device ops (inside shard_map) -----------------------------------

    def _reshape_full(self, name: str, full: jax.Array) -> jax.Array:
        spec = self.specs[name]
        n = spec.n_logical_local(self.ms.model_size)
        w = full[:n].reshape(spec.tp_local_shape(self.ms.model_size))
        return w.astype(self.compute_dtype)

    def _gather_per_tensor(self, name: str, flat: jax.Array,
                           key: jax.Array) -> jax.Array:
        """Forced per-tensor gather: 3 collectives (codes/scale/zero) for a
        quantized param, 1 for an fp payload — never re-coalesced."""
        spec = self.specs[name]
        key = jax.random.fold_in(key, _stable_hash(name))
        full = qsdp_gather(flat, key, self._static_for(spec))
        return self._reshape_full(name, full)

    def gather(self, name: str, local: jax.Array, key: jax.Array) -> jax.Array:
        """Materialize the TP-local tensor for parameter `name` from its
        per-device flat shard (shape (..., 1, 1, n_local) or (n_local,)).
        Under ``cfg.coalesce`` the tensor's codes + metadata ride one
        collective (single-segment wire buffer) instead of three."""
        flat = local.reshape(-1)
        if self.layer_coalesced((name,)):
            full = qsdp_gather_layer((flat,), key, self._layer_static((name,)))[0]
            return self._reshape_full(name, full)
        return self._gather_per_tensor(name, flat, key)

    def gather_layer(self, prefix: str, leaves: dict[str, jax.Array],
                     key: jax.Array) -> dict[str, jax.Array]:
        """Gather every parameter of one layer-dict — ONE collective for the
        whole layer under ``cfg.coalesce``, per-param otherwise.  The
        fallback is genuinely per-tensor (3 launches per quantized param):
        re-checking the byte threshold tensor-by-tensor would single-segment
        re-coalesce every small tensor, which the cost model prices as a
        loss (it saves 2 launches but adds the wire serialize/decode passes
        that caused the small-scale regression in the first place)."""
        if not leaves:
            return {}
        if not self.layer_coalesced(tuple(f"{prefix}{k}" for k in sorted(leaves))):
            return {k: self._gather_per_tensor(f"{prefix}{k}", v.reshape(-1), key)
                    for k, v in leaves.items()}
        names, st, shards = self._layer_args(prefix, leaves)
        fulls = qsdp_gather_layer(shards, key, st)
        return {k: self._reshape_full(f"{prefix}{k}", f)
                for k, f in zip(names, fulls)}

    def gather_layer_start(self, prefix: str, leaves: dict[str, jax.Array],
                           key: jax.Array) -> jax.Array:
        """Prefetch pipeline, step 1: issue the coalesced all-gather for a
        layer and return its u8 wire buffer (to be carried one scan step)."""
        _, st, shards = self._layer_args(prefix, leaves)
        return qsdp_gather_layer_start(shards, key, st)

    def gather_layer_finish(self, prefix: str, leaves: dict[str, jax.Array],
                            wire: jax.Array, key: jax.Array) -> dict[str, jax.Array]:
        """Prefetch pipeline, step 2: decode the carried wire buffer into the
        layer's TP-local tensors (backward: coalesced reduce-scatter)."""
        names, st, shards = self._layer_args(prefix, leaves)
        fulls = qsdp_gather_layer_finish(shards, wire, key, st)
        return {k: self._reshape_full(f"{prefix}{k}", f)
                for k, f in zip(names, fulls)}

    def _layer_args(self, prefix: str, leaves: dict[str, jax.Array]):
        names = tuple(sorted(leaves))
        st = self._layer_static(tuple(f"{prefix}{k}" for k in names))
        shards = tuple(leaves[k].reshape(-1) for k in names)
        return names, st, shards

    # -- code-form gather (serve/decode; no VJP — inference only) -------------

    def _rowquant_tiling_ok(self, spec: ParamSpec, cfg: QuantConfig) -> bool:
        """Do `cfg`'s buckets tile this weight's rows exactly?  2D (K, N)
        tp-local shape, a bit width whose packed codes unpack along bucket
        boundaries (bucket_size % codes_per_byte == 0 — always true for the
        packable widths 2/4/8, and sub-8-bit codes are unpacked to one byte
        per value after the gather), N a multiple of the bucket size, and an
        FSDP shard that is a whole number of buckets (no padding anywhere,
        so global bucket b covers flat elements [b*bsz, (b+1)*bsz) of the
        row-major weight).

        NB stacked (scan-over-layers) params are gathered one layer slice
        at a time, so shape/n here are already per-layer quantities."""
        shape = spec.tp_local_shape(self.ms.model_size)
        n = spec.n_logical_local(self.ms.model_size)
        p = self.ms.fsdp_size
        return (
            cfg.bucket_size % cfg.codes_per_byte == 0
            and not self.cfg.hierarchical
            and len(shape) == 2
            and shape[1] % cfg.bucket_size == 0
            and n % p == 0
            and (n // p) % cfg.bucket_size == 0
        )

    def _assemble_rowquant(self, spec: ParamSpec, cfg: QuantConfig,
                           q) -> RowQuantWeight:
        """All-gather a shard's (codes, scale, zero) over FSDP and reshape
        into the (K, N) / (K, n_seg) RowQuantWeight layout.  Sub-8-bit codes
        travel packed (the bits 2-8 wire format) and are unpacked to one
        byte per value after the gather — bucket boundaries survive packing
        (bucket_size % codes_per_byte == 0), so the unpacked bytes are the
        row-major codes the fused rowquant matmul consumes."""
        codes = lax.all_gather(q.codes, self.ms.fsdp_axes, tiled=True)
        scale = lax.all_gather(q.scale, self.ms.fsdp_axes, tiled=True)
        zero = lax.all_gather(q.zero, self.ms.fsdp_axes, tiled=True)
        if cfg.codes_per_byte > 1:
            codes = unpack_codes(codes, cfg.bits)
        k_dim, n_dim = spec.tp_local_shape(self.ms.model_size)
        n_seg = n_dim // cfg.bucket_size
        return RowQuantWeight(
            codes=codes.reshape(k_dim, n_dim),
            scale=scale.reshape(k_dim, n_seg),
            zero=zero.reshape(k_dim, n_seg),
        )

    def rowquant_eligible(self, name: str) -> bool:
        """A gathered weight can stay in code form through the matmul iff
        the engine quantizes it and the wire buckets tile its rows (see
        :meth:`_rowquant_tiling_ok`)."""
        spec = self.specs[name]
        return (self._is_quantized(spec)
                and self._rowquant_tiling_ok(spec, self.cfg.wcfg()))

    def gather_rowquant(self, name: str, local: jax.Array, key: jax.Array):
        """All-gather parameter `name` but return it as a
        :class:`RowQuantWeight` — the wire codes reshaped (K, N) with the
        per-bucket affine as (K, N/bucket) segments — instead of
        dequantizing to a dense matrix.  ``kernels.ops.rowquant_matmul``
        then consumes the codes directly, so the full-precision weight is
        never materialized in HBM (inference only: no custom VJP).

        Falls back to the dense :meth:`gather` when the layout conditions
        don't hold (see :meth:`rowquant_eligible`)."""
        if not self.rowquant_eligible(name):
            return self.gather(name, local, key)
        spec = self.specs[name]
        wcfg = self.cfg.wcfg()
        flat = local.reshape(-1)
        key = jax.random.fold_in(key, _stable_hash(name))
        return self._assemble_rowquant(spec, wcfg, quantize(flat, wcfg, key))

    def rowquant_wire_eligible(self, name: str, qp: QuantizedParam) -> bool:
        """Like :meth:`rowquant_eligible`, but for a parameter whose rest
        state already IS wire codes (quantized train state / checkpoint v2):
        the stored buckets must tile the weight's rows with no padding.
        Independent of the engine's comm policy — the codes exist whether or
        not this engine quantizes its own collectives."""
        return (qp.cfg.meta_dtype == "float32"
                and self._rowquant_tiling_ok(self.specs[name], qp.cfg))

    def gather_rowquant_wire(self, name: str, qp: QuantizedParam) -> RowQuantWeight:
        """All-gather a parameter stored as wire codes straight into a
        :class:`RowQuantWeight`: no quantize on the way out, no dequantize on
        the way in — the checkpoint/train-state bytes feed
        ``kernels.ops.rowquant_matmul`` directly (inference only).

        `qp` is the per-device view (wire (1, 1, nbytes), cell (n_local,));
        caller guarantees :meth:`rowquant_wire_eligible`."""
        q = wire_unpack(qp.wire.reshape(-1), qp.n, qp.cfg)
        return self._assemble_rowquant(self.specs[name], qp.cfg, q)

    def gather_wire_dequant(self, name: str, qp: QuantizedParam) -> jax.Array:
        """Dense fallback for a wire-form parameter that the rowquant matmul
        can't tile (attention projections, 3D expert stacks, odd buckets):
        all-gather the packed wire segments over FSDP and dequantize each
        shard's segment through the bits 2-8 kernels into the TP-local
        tensor.  Each shard's [codes | scale | zero] segment is
        self-contained (its own bucket padding included), so no alignment
        between shards is required — this works for ANY per-leaf bucket
        size (inference only: no VJP)."""
        buf = lax.all_gather(qp.wire.reshape(-1), self.ms.fsdp_axes,
                             tiled=True)
        segs = buf.reshape(self.ms.fsdp_size, -1)

        def dec(b):
            return dequantize(wire_unpack(b, qp.n, qp.cfg)).reshape(-1)

        full = (dec(segs[0]) if segs.shape[0] == 1
                else jax.vmap(dec)(segs).reshape(-1))
        return self._reshape_full(name, full)

    # -- host-side helpers ----------------------------------------------------

    def init_params(self, key: jax.Array) -> dict[str, jax.Array]:
        out = {}
        for i, (name, spec) in enumerate(sorted(self.specs.items())):
            out[name] = init_param(jax.random.fold_in(key, i), spec, self.ms)
        return out

    def in_specs(self) -> dict[str, P]:
        return {name: spec.rest_pspec(self.ms) for name, spec in self.specs.items()}

    def param_bytes_per_device(self) -> int:
        total = 0
        for spec in self.specs.values():
            total += int(np.prod(spec.rest_shape(self.ms))) // (self.ms.fsdp_size * self.ms.model_size)
        return total * 4


def _stable_hash(s: str) -> int:
    h = 2166136261
    for ch in s.encode():
        h = (h ^ ch) * 16777619 & 0xFFFFFFFF
    return h


# ---------------------------------------------------------------------------
# Communication accounting (per step, analytic; feeds the Fig-4 model)
# ---------------------------------------------------------------------------


def step_comm_bytes(
    engine: QSDPEngine, gathers_per_param: int = 2, reduces_per_param: int = 1
) -> dict[str, int]:
    """Per-device wire bytes of one optimizer step under the engine's policy
    (2 weight all-gathers + 1 gradient reduce-scatter per param by default,
    i.e. the FSDP schedule)."""
    ms, cfg = engine.ms, engine.cfg
    p = ms.fsdp_size
    wbytes = rbytes = 0
    for spec in engine.specs.values():
        reps = spec.stack or 1
        n_local_shard = spec.n_local(ms)
        n_full = n_local_shard * p
        wq = cfg.wcfg() if engine._is_quantized(spec) else None
        gq = (
            cfg.gcfg()
            if (spec.quantize and cfg.quantize_grads
                and spec.n_logical_local(ms.model_size) >= cfg.min_quant_size)
            else None
        )
        wfp = 4 if cfg.weight_wire_dtype == "float32" else 2
        gfp = 4 if cfg.grad_wire_dtype == "float32" else 2
        wbytes += reps * gathers_per_param * coll.gather_wire_bytes(n_local_shard, p, wq, wfp)
        rbytes += reps * reduces_per_param * coll.reduce_scatter_wire_bytes(n_full, p, gq, gfp)
    return dict(weight_gather=wbytes, grad_reduce=rbytes, total=wbytes + rbytes)


def layer_gather_launches(engine: QSDPEngine, names: list[str]) -> int:
    """Analytic collective-launch count of ONE gather of the given params
    (the quantity the coalesced wire format collapses): 3 per quantized
    tensor (codes, scale, zero) + 1 per full-precision tensor when
    per-tensor, 1 total when coalesced.  Hierarchical (two-level) gathers
    double the quantized / coalesced launches (pod + in-pod).  Respects the
    per-layer ``coalesce_max_bytes`` policy (engine.layer_coalesced)."""
    levels = 2 if engine.cfg.hierarchical and engine.ms.multi_pod else 1
    if engine.layer_coalesced(tuple(names)):
        return levels
    return sum(3 * levels if engine._is_quantized(engine.specs[n]) else 1
               for n in names)
