"""Quantizers from the QSDP paper (Markov et al., ICML 2023).

Two families live here:

1.  *Lattice quantizers* (`q_shift`, `q_coinflip`, `q_nearest`) — the exact
    operators analysed in the paper (Definitions 1 and 12).  They act on a
    fixed grid ``delta * Z^n (+ r 1)`` with no scaling or clipping, so the
    statements of Lemma 5 / Lemma 15 (unbiasedness, exact variance, sparsity)
    hold *exactly*.  These are used by ``core.theory`` and by the property
    tests.

2.  *Wire quantizers* (`quantize` / `dequantize`) — the practical bucketed
    min-max scheme of Section 5: a tensor is flattened, padded, split into
    equal buckets (default 1024), each bucket is scaled to ``[0, 2^b - 1]``
    with its own (zero, scale) pair and rounded with one of the three modes.
    The result is a :class:`Quantized` pytree whose ``codes`` are packed
    uint8 — this is exactly what QSDP puts on the wire, so collective byte
    counts in the roofline analysis are faithful.

Everything is jit/shard_map friendly.  The wire quantizers dispatch between
two bit-exact backends (see :func:`resolve_backend` in ``kernels.ops``):

  * ``"jnp"``    — the pure-jnp reference below (always available);
  * ``"pallas"`` — the fused quantize→pack / unpack→dequantize TPU kernels
    in ``kernels.quantize`` (interpret mode off-TPU), selected per call via
    ``backend=``, per config via ``QuantConfig.backend``, or globally via
    ``REPRO_QUANT_BACKEND`` / ``REPRO_PALLAS_INTERPRET``.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..kernels import ops as _kops

# ---------------------------------------------------------------------------
# Lattice quantizers (paper Definitions 1 and 12) — no scaling, no clipping.
# ---------------------------------------------------------------------------


def q_nearest(x: jax.Array, delta: float | jax.Array) -> jax.Array:
    """Deterministic round-to-nearest on ``delta * Z``.

    This is the *naive* scheme the paper shows to break convergence — kept as
    an ablation baseline.
    """
    return delta * jnp.round(x / delta)


def q_shift(x: jax.Array, delta: float | jax.Array, key: jax.Array) -> jax.Array:
    """Quantization by random shift (paper Definition 1).

    A *single* shift ``r ~ Unif[-delta/2, delta/2)`` is shared by every
    coordinate; each coordinate is rounded to the nearest point of
    ``delta * Z + r``.  Unbiased (Lemma 5), with the crucial cross-coordinate
    dependence that powers Lemma 4.
    """
    r = jax.random.uniform(key, (), minval=-0.5, maxval=0.5) * delta
    return delta * jnp.round((x - r) / delta) + r


def q_coinflip(x: jax.Array, delta: float | jax.Array, key: jax.Array) -> jax.Array:
    """Quantization by coin flip (paper Definition 12) — per-coordinate
    stochastic rounding onto ``delta * Z``.  Unbiased (Lemma 15); used for
    gradients (any unbiased estimator is admissible by Corollary 3).
    """
    lo = jnp.floor(x / delta)
    frac = x / delta - lo
    up = jax.random.uniform(key, x.shape) < frac
    return delta * (lo + up.astype(x.dtype))


# ---------------------------------------------------------------------------
# Wire format: bucketed min-max quantization with packed uint8 codes.
# ---------------------------------------------------------------------------

Mode = str  # "shift" | "stochastic" | "nearest"
_MODES = ("shift", "stochastic", "nearest")


@dataclasses.dataclass(frozen=True)
class QuantConfig:
    """Static configuration of the wire quantizer.

    bits:        code width (2..8).  Widths with 8 % bits == 0 are bit-packed
                 into uint8 so the on-wire byte count is exact; 3/5/6/7-bit
                 codes occupy one byte each on the (emulated) wire, and the
                 analytic communication model (wire_segment_bytes,
                 gather_wire_bytes, ...) accounts that same one byte per
                 code, so analytic bytes == actual wire-buffer bytes for
                 every width (pinned by tests/test_wire_accounting.py).
    bucket_size: independent scaling granularity (paper default 1024).
    mode:        rounding rule — "shift" (Def. 1, weights), "stochastic"
                 (Def. 12, gradients) or "nearest" (ablation).
    """

    bits: int = 8
    bucket_size: int = 1024
    mode: Mode = "shift"
    # stochastic-rounding threshold width: 32 = f32 uniforms (reference),
    # 16 = u16 raw bits compare — 4x less RNG traffic, bias <= 2^-16 (§Perf)
    rand_bits: int = 32
    # compute backend: "pallas" (fused kernels), "jnp" (reference), or
    # "auto" (kernels on TPU / under REPRO_PALLAS_INTERPRET, jnp otherwise).
    # Both backends emit identical wire bytes (tested bit-exact).
    backend: str = "auto"
    # on-wire dtype of the per-bucket (scale, zero) metadata: "float32"
    # (reference, exact) or "bfloat16" (halves metadata bytes; decode uses
    # the rounded affine, a ~2^-8 relative perturbation of scale/zero).
    meta_dtype: str = "float32"

    def __post_init__(self):
        assert 1 <= self.bits <= 8, self.bits
        assert self.mode in _MODES, self.mode
        assert self.rand_bits in (16, 32), self.rand_bits
        assert self.backend in ("auto", "jnp", "pallas"), self.backend
        assert self.meta_dtype in ("float32", "bfloat16"), self.meta_dtype

    @property
    def levels(self) -> int:
        return (1 << self.bits) - 1  # max code value

    @property
    def codes_per_byte(self) -> int:
        return 8 // self.bits if 8 % self.bits == 0 else 1

    @property
    def wire_bits(self) -> int:
        """Bits per value actually occupied in the packed uint8 stream."""
        return 8 // self.codes_per_byte

    @property
    def meta_bytes(self) -> int:
        """Bytes per scale (or zero) entry on the wire."""
        return 2 if self.meta_dtype == "bfloat16" else 4

    @property
    def meta_jnp_dtype(self):
        return jnp.bfloat16 if self.meta_dtype == "bfloat16" else jnp.float32


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class Quantized:
    """A quantized tensor as transmitted by QSDP.

    codes:  uint8, shape (n_buckets, bucket_size // codes_per_byte)
    scale:  f32, (n_buckets,) — bucket step size ((max-min)/levels)
    zero:   f32, (n_buckets,) — bucket offset (min, plus the random shift for
            mode="shift", so decode is branch-free across modes)
    meta (aux): original shape, original size (pre-padding), config
    """

    codes: jax.Array
    scale: jax.Array
    zero: jax.Array
    shape: tuple
    size: int
    cfg: QuantConfig

    def tree_flatten(self):
        return (self.codes, self.scale, self.zero), (self.shape, self.size, self.cfg)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children, *aux)

    @property
    def wire_bytes(self) -> int:
        """Exact bytes put on the wire (codes + per-bucket metadata)."""
        mb = self.cfg.meta_bytes
        return int(np.prod(self.codes.shape)) + mb * (self.scale.shape[0] + self.zero.shape[0])


# -- packing ----------------------------------------------------------------


def pack_codes(codes: jax.Array, bits: int) -> jax.Array:
    """Pack (..., n) uint8 codes of width `bits` into (..., n*bits/8) bytes
    when 8 % bits == 0; otherwise pass through (one code per byte)."""
    k = 8 // bits if 8 % bits == 0 else 1
    if k == 1:
        return codes
    *lead, n = codes.shape
    assert n % k == 0, (n, k)
    c = codes.reshape(*lead, n // k, k)
    shifts = jnp.arange(k, dtype=jnp.uint8) * bits
    return jnp.sum(c << shifts, axis=-1).astype(jnp.uint8)


def unpack_codes(packed: jax.Array, bits: int) -> jax.Array:
    """Inverse of :func:`pack_codes`."""
    k = 8 // bits if 8 % bits == 0 else 1
    if k == 1:
        return packed
    shifts = jnp.arange(k, dtype=jnp.uint8) * bits
    mask = jnp.uint8((1 << bits) - 1)
    c = (packed[..., None] >> shifts) & mask
    *lead, n, _ = c.shape
    return c.reshape(*lead, n * k)


# -- bucketing ---------------------------------------------------------------


def _to_buckets(x: jax.Array, bucket_size: int) -> tuple[jax.Array, int]:
    flat = x.reshape(-1).astype(jnp.float32)
    size = flat.shape[0]
    pad = (-size) % bucket_size
    if pad:
        flat = jnp.pad(flat, (0, pad))
    return flat.reshape(-1, bucket_size), size


# -- quantize / dequantize ---------------------------------------------------


def quantize(x: jax.Array, cfg: QuantConfig, key: Optional[jax.Array] = None,
             backend: Optional[str] = None) -> Quantized:
    """Bucketed min-max quantization (paper Section 5).

    Each bucket b is mapped through ``v = (x - min_b) / scale_b`` into
    ``[0, levels]`` and rounded according to ``cfg.mode``.  For
    ``mode="shift"`` one shift per bucket is drawn (the paper applies Def. 1
    at the granularity it scales at, i.e. the bucket).

    `backend` (default ``cfg.backend``) selects the fused Pallas
    quantize→pack kernel or the jnp reference below — both draw identical
    randomness from `key` and emit identical wire bytes.
    """
    if cfg.mode in ("shift", "stochastic") and key is None:
        raise ValueError(f"mode={cfg.mode!r} requires a PRNG key")
    buckets, size = _to_buckets(x, cfg.bucket_size)
    nb = buckets.shape[0]

    if _kops.resolve_backend(backend or cfg.backend) == "pallas":
        if cfg.mode == "stochastic":
            if cfg.rand_bits == 16:
                rand = jax.random.bits(key, buckets.shape, jnp.uint16).astype(jnp.float32)
                rand_scale = 65536.0
            else:
                rand = jax.random.uniform(key, buckets.shape)
                rand_scale = 1.0
        elif cfg.mode == "shift":
            rand = jax.random.uniform(key, (nb, 1), minval=-0.5, maxval=0.5)
            rand_scale = 1.0
        else:
            rand = jnp.zeros((nb, 1), jnp.float32)
            rand_scale = 1.0
        codes, scale, zero = _kops.quantize_packed(
            buckets, rand, cfg.levels, cfg.bits, cfg.mode, rand_scale
        )
        return Quantized(
            codes=codes,
            scale=scale[:, 0],
            zero=zero[:, 0],
            shape=tuple(x.shape),
            size=size,
            cfg=cfg,
        )

    codes, scale, zero = _quantize_jnp(buckets, key, cfg)
    return Quantized(
        codes=codes,
        scale=scale,
        zero=zero,
        shape=tuple(x.shape),
        size=size,
        cfg=cfg,
    )


@partial(jax.jit, static_argnames=("cfg",))
def _quantize_jnp(buckets: jax.Array, key: Optional[jax.Array], cfg: QuantConfig):
    """jnp reference core, jitted so the numerics (XLA's constant-division
    strength reduction, mul+add -> fma fusion) are identical whether the
    caller is eager or inside a larger jit — and therefore bit-identical to
    the (always-jitted) Pallas kernel wrappers in ``kernels.ops``."""
    nb = buckets.shape[0]
    lo = jnp.min(buckets, axis=1, keepdims=True)
    hi = jnp.max(buckets, axis=1, keepdims=True)
    # reciprocal multiply, NOT division: XLA strength-reduces division by a
    # constant to `* (1/c)` under jit but not in eager mode; the kernels use
    # the same explicit multiply.
    scale = jnp.maximum((hi - lo) * (1.0 / cfg.levels), 1e-12)
    v = (buckets - lo) / scale  # in [0, levels]

    if cfg.mode == "nearest":
        codes = jnp.round(v)
        zero = lo
    elif cfg.mode == "stochastic":
        f = jnp.floor(v)
        if cfg.rand_bits == 16:
            r = jax.random.bits(key, v.shape, jnp.uint16).astype(jnp.float32)
            up = r < (v - f) * 65536.0
        else:
            up = jax.random.uniform(key, v.shape) < (v - f)
        codes = f + up.astype(v.dtype)
        zero = lo
    else:  # shift — one r per bucket, shared across its coordinates
        r = jax.random.uniform(key, (nb, 1), minval=-0.5, maxval=0.5)
        codes = jnp.round(v - r)
        zero = lo + r * scale  # fold shift into the affine decode
    codes = jnp.clip(codes, 0, cfg.levels).astype(jnp.uint8)
    return pack_codes(codes, cfg.bits), scale[:, 0], zero[:, 0]


def dequantize(q: Quantized, dtype=jnp.float32,
               backend: Optional[str] = None) -> jax.Array:
    """Affine decode back to the original shape/dtype (backend-dispatched:
    fused Pallas unpack→dequantize kernel or the jnp reference)."""
    if _kops.resolve_backend(backend or q.cfg.backend) == "pallas":
        x = _kops.dequantize_packed(
            q.codes, q.scale[:, None], q.zero[:, None], q.cfg.bits, dtype
        )
    else:
        x = _dequantize_jnp(q.codes, q.scale, q.zero, q.cfg.bits, dtype)
    return x.reshape(-1)[: q.size].reshape(q.shape)


@partial(jax.jit, static_argnames=("bits", "dtype"))
def _dequantize_jnp(codes: jax.Array, scale: jax.Array, zero: jax.Array,
                    bits: int, dtype):
    """jnp decode core (jitted — see :func:`_quantize_jnp`)."""
    c = unpack_codes(codes, bits).astype(jnp.float32)
    return (c * scale[:, None] + zero[:, None]).astype(dtype)


def quantize_dequantize(x: jax.Array, cfg: QuantConfig, key: Optional[jax.Array] = None,
                        backend: Optional[str] = None) -> jax.Array:
    """Fake-quant helper (used in single-device simulation and tests)."""
    return dequantize(quantize(x, cfg, key, backend=backend), x.dtype, backend=backend)


# ---------------------------------------------------------------------------
# Flat wire layout helpers.
#
# Inside shard_map we prefer a fixed layout: a Quantized with known static
# shapes can be shipped through lax collectives leaf-by-leaf.  These helpers
# compute those static shapes so callers can pre-allocate / reason about
# bytes without tracing.
# ---------------------------------------------------------------------------


def quantized_shapes(n: int, cfg: QuantConfig) -> dict:
    """Static shapes of the wire representation of an n-element tensor."""
    nb = -(-n // cfg.bucket_size)
    return dict(
        codes=(nb, cfg.bucket_size // cfg.codes_per_byte),
        scale=(nb,),
        zero=(nb,),
    )


def wire_bytes(n: int, cfg: QuantConfig) -> int:
    s = quantized_shapes(n, cfg)
    return int(np.prod(s["codes"])) + 2 * cfg.meta_bytes * s["scale"][0]


# ---------------------------------------------------------------------------
# WireBuffer: serialize a Quantized (or a raw fp payload) into a single
# contiguous uint8 segment, so a whole layer's parameters can ride ONE
# collective instead of 3 x n_params (codes, scale, zero each).
#
# Segment layout of an n-element quantized tensor (all shapes static):
#
#     [ codes : nb * bucket/cpb bytes | scale : nb * mb | zero : nb * mb ]
#
# with mb = cfg.meta_bytes (4 for f32 metadata, 2 for bf16).  A raw fp
# segment is simply the bitcast of the tensor in its wire dtype.  Encode and
# decode are bit-exact inverses: unpacking a packed Quantized reproduces its
# codes/scale/zero fields bit-for-bit (scale/zero modulo the meta_dtype
# round-trip, which is the identity for float32).
# ---------------------------------------------------------------------------


def wire_segment_bytes(n: int, cfg: QuantConfig) -> int:
    """Static byte length of the wire segment of an n-element tensor."""
    return wire_bytes(n, cfg)


def fp_segment_bytes(n: int, dtype_str: str) -> int:
    return n * jnp.dtype(getattr(jnp, dtype_str)).itemsize


def _f2b(x: jax.Array) -> jax.Array:
    """(...,) float -> (..., itemsize) u8 bytes, flattened to 1-D."""
    return jax.lax.bitcast_convert_type(x, jnp.uint8).reshape(-1)


def wire_pack(q: Quantized) -> jax.Array:
    """Serialize a Quantized into its contiguous (wire_segment_bytes,) u8
    segment: packed codes, then scale bytes, then zero bytes."""
    md = q.cfg.meta_jnp_dtype
    return jnp.concatenate([
        q.codes.reshape(-1),
        _f2b(q.scale.astype(md)),
        _f2b(q.zero.astype(md)),
    ])


def wire_unpack(buf: jax.Array, n: int, cfg: QuantConfig,
                shape: Optional[tuple] = None) -> Quantized:
    """Inverse of :func:`wire_pack` for an n-element tensor (scale/zero are
    widened back to f32 so decode math is unchanged)."""
    s = quantized_shapes(n, cfg)
    nb = s["scale"][0]
    cb = int(np.prod(s["codes"]))
    mb = cfg.meta_bytes
    codes = buf[:cb].reshape(s["codes"])
    scale = jax.lax.bitcast_convert_type(
        buf[cb:cb + nb * mb].reshape(nb, mb), cfg.meta_jnp_dtype
    ).astype(jnp.float32)
    zero = jax.lax.bitcast_convert_type(
        buf[cb + nb * mb:cb + 2 * nb * mb].reshape(nb, mb), cfg.meta_jnp_dtype
    ).astype(jnp.float32)
    return Quantized(codes, scale, zero, shape or (n,), n, cfg)


def fp_pack(x: jax.Array, dtype_str: str) -> jax.Array:
    """Raw fp payload -> u8 segment (bitcast of the wire dtype — any fp
    dtype string the per-tensor wire-dtype knobs accept, e.g. float16)."""
    wd = getattr(jnp, dtype_str)
    return _f2b(x.reshape(-1).astype(wd))


def fp_unpack(buf: jax.Array, n: int, dtype_str: str) -> jax.Array:
    """Inverse of :func:`fp_pack` -> (n,) f32."""
    wd = getattr(jnp, dtype_str)
    isz = jnp.dtype(wd).itemsize
    return jax.lax.bitcast_convert_type(
        buf.reshape(n, isz), wd).astype(jnp.float32)


# ---------------------------------------------------------------------------
# QuantizedParam: a rest-layout train-state leaf kept in packed wire-code
# form (the paper's "maintain only quantized weights" — Theorem 2).
#
# A rest-layout f32 leaf has shape (stack?, MODEL, FSDP, n_local): each
# (model, fsdp) *cell* holds that device's flat shard, (stack?, n_local).
# A QuantizedParam stores, per cell, the :func:`wire_pack` serialization of
# the cell flattened in (stack, n_local) order — exactly the array the
# in-step master quantization (train/step.py, quantize_master=True) feeds
# to :func:`quantize` on that device — so dequantizing a QuantizedParam is
# bit-identical to the value the f32 QDQ path would have stored.
#
#     wire : u8 (*lead, nbytes)   lead = (MODEL, FSDP) host-side,
#                                 (1, 1) per-device inside shard_map,
#                                 (stack, MODEL, FSDP) after a stack split
#     nbytes = wire_segment_bytes(prod(cell_shape), cfg)
#
# The same pytree therefore shards with P("model", fsdp_axes, None) and
# flows through shard_map / jit / checkpointing like any other leaf, at
# ~bits/32 of the f32 bytes (+ per-bucket metadata).
# ---------------------------------------------------------------------------


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class QuantizedParam:
    """A parameter (or optimizer-moment) leaf stored as packed wire codes.

    wire:       uint8, (*lead, nbytes) — per-cell :func:`wire_pack` output.
    cell_shape: decoded shape per lead cell — (n_local,) for plain leaves,
                (stack, n_local) for scan-over-layers stacks (the stack dim
                is flattened *into* the cell so bucket boundaries match the
                in-step master quantization exactly).
    cfg:        the QuantConfig the codes were produced with.
    """

    wire: jax.Array
    cell_shape: tuple
    cfg: QuantConfig

    def tree_flatten(self):
        return (self.wire,), (self.cell_shape, self.cfg)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(children[0], *aux)

    @property
    def n(self) -> int:
        """Decoded f32 elements per cell."""
        return int(np.prod(self.cell_shape))

    @property
    def stacked(self) -> bool:
        return len(self.cell_shape) == 2


def qparam_encode(x: jax.Array, cfg: QuantConfig,
                  key: Optional[jax.Array] = None,
                  backend: Optional[str] = None) -> QuantizedParam:
    """Rest-layout f32 leaf (stack?, A, B, n_local) -> QuantizedParam.

    Every (A, B) cell is flattened in (stack, n_local) order and quantized
    with the SAME `key` — mirroring the in-step master quantization, where
    the step key is mesh-replicated and each device quantizes its own local
    view with it.  Works on host-global arrays (A, B) = (MODEL, FSDP) and on
    per-device views (A, B) = (1, 1) alike; the single-cell case runs the
    exact non-vmapped :func:`quantize` code path of the QDQ master."""
    if x.ndim == 4:
        cell_shape = (x.shape[0], x.shape[-1])
        xc = jnp.moveaxis(x, 0, 2)  # (A, B, stack, n_local)
    elif x.ndim == 3:
        cell_shape = (x.shape[-1],)
        xc = x
    else:
        raise ValueError(f"rest-layout leaf must be rank 3 or 4, got {x.shape}")
    lead = xc.shape[:2]
    n = int(np.prod(cell_shape))
    cells = xc.reshape(lead[0] * lead[1], n)

    def enc(v):
        return wire_pack(quantize(v, cfg, key, backend=backend))

    if cells.shape[0] == 1:
        wire = enc(cells[0])[None]
    else:
        wire = jax.vmap(enc)(cells)
    return QuantizedParam(wire.reshape(*lead, -1), cell_shape, cfg)


def qparam_decode(qp: QuantizedParam, dtype=jnp.float32,
                  backend: Optional[str] = None) -> jax.Array:
    """QuantizedParam -> rest-layout dense leaf.

    Output shape is (*lead, *cell) with a stacked cell's stack dim moved
    back to the front: (stack?, A, B, n_local) — the exact inverse of
    :func:`qparam_encode`'s layout.  Deterministic, so decoding on any host
    or device reproduces the QDQ master values bit-for-bit."""
    lead = qp.wire.shape[:-1]
    flat = qp.wire.reshape(-1, qp.wire.shape[-1])

    def dec(b):
        return dequantize(wire_unpack(b, qp.n, qp.cfg), dtype, backend=backend)

    if flat.shape[0] == 1:
        out = dec(flat[0]).reshape(*lead, *qp.cell_shape)
    else:
        out = jax.vmap(dec)(flat).reshape(*lead, *qp.cell_shape)
    if qp.stacked:
        out = jnp.moveaxis(out, -2, 0)
    return out


def qparam_wire_nbytes(cell_shape: tuple, cfg: QuantConfig) -> int:
    """Static per-cell wire length of a QuantizedParam."""
    return wire_segment_bytes(int(np.prod(cell_shape)), cfg)


def qparam_split_stack(qp: QuantizedParam) -> QuantizedParam:
    """Re-slice a stacked QuantizedParam into per-stack-slice wire segments:
    wire (*lead, nbytes) -> (stack, *lead, nbytes_slice), cell (n_local,).

    Requires bucket-aligned slices (n_local % bucket_size == 0) so every
    stack slice owns whole buckets; each output slice is then a valid wire
    segment of its own (codes | scale | zero) whose decode equals the
    corresponding rows of the full decode bit-for-bit.  This is what lets
    serve scan over the layers of a checkpointed stack while keeping the
    codes in wire form (see QSDPEngine.gather_rowquant_wire)."""
    assert qp.stacked, qp.cell_shape
    stack, n_local = qp.cell_shape
    cfg = qp.cfg
    assert n_local % cfg.bucket_size == 0, (n_local, cfg.bucket_size)
    nb_s = n_local // cfg.bucket_size
    cb_s = nb_s * (cfg.bucket_size // cfg.codes_per_byte)
    mb = cfg.meta_bytes
    lead = qp.wire.shape[:-1]
    cb = cb_s * stack
    sb = nb_s * mb * stack
    codes = qp.wire[..., :cb].reshape(*lead, stack, cb_s)
    scale = qp.wire[..., cb:cb + sb].reshape(*lead, stack, nb_s * mb)
    zero = qp.wire[..., cb + sb:].reshape(*lead, stack, nb_s * mb)
    wire = jnp.concatenate([codes, scale, zero], axis=-1)
    return QuantizedParam(jnp.moveaxis(wire, -2, 0), (n_local,), cfg)
