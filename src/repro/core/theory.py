"""Theorem 2 / Corollary 3 of the QSDP paper, executable.

The paper's analytical core is the iteration

    x_{t+1} = Q^w_delta( x_t - (eta / beta) * Q^g( g(x_t) ) )

for a beta-smooth, alpha-PL objective f, with Q^w the random-shift lattice
quantizer (Definition 1) and Q^g any unbiased gradient quantizer.  Theorem 2
fixes  delta = eta * delta_star / ceil(16 (beta/alpha)^2)  and proves linear
convergence (rate 1 - (3/4) eta alpha/beta per step, Lemma 10) to within
epsilon of the best point on the *coarser* lattice delta_star Z^n + r 1.

This module provides:
  * quadratic PL test objectives with known (alpha, beta) and known lattice
    optima, plus noisy-gradient oracles;
  * `theorem2_params` computing (eta, delta, T) exactly as in the theorem;
  * `run_qsgd` executing the iteration with selectable weight/gradient
    quantizers — used by tests and the theory benchmark to check both the
    convergence claim and its *failure* under naive round-to-nearest.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from .quant import q_coinflip, q_nearest, q_shift


@dataclasses.dataclass(frozen=True)
class Quadratic:
    """f(x) = 0.5 * sum_i h_i (x_i - c_i)^2  — beta = max h, alpha = min h.

    Strongly convex, hence alpha-PL; the minimizer over a shifted lattice is
    the coordinate-wise rounding of c, which makes the benchmark
    E f(x*_{r,delta_star}) computable in closed form.
    """

    h: jax.Array  # (n,) positive curvatures
    c: jax.Array  # (n,) optimum

    @property
    def alpha(self) -> float:
        return float(jnp.min(self.h))

    @property
    def beta(self) -> float:
        return float(jnp.max(self.h))

    def f(self, x: jax.Array) -> jax.Array:
        return 0.5 * jnp.sum(self.h * (x - self.c) ** 2)

    def grad(self, x: jax.Array) -> jax.Array:
        return self.h * (x - self.c)

    def noisy_grad(self, x: jax.Array, key: jax.Array, sigma: float) -> jax.Array:
        """Unbiased gradient oracle with E||g - grad||^2 = sigma^2."""
        n = x.shape[0]
        noise = jax.random.normal(key, (n,)) * (sigma / math.sqrt(n))
        return self.grad(x) + noise

    def lattice_opt_value(self, delta_star: float, key: jax.Array, n_shifts: int = 256) -> float:
        """Monte-Carlo estimate of E_r f(x*_{r,delta_star}): for a separable
        quadratic the best lattice point is round-to-nearest of c on each
        shifted grid."""
        rs = jax.random.uniform(key, (n_shifts,), minval=-0.5, maxval=0.5) * delta_star

        def one(r):
            xs = delta_star * jnp.round((self.c - r) / delta_star) + r
            return self.f(xs)

        return float(jnp.mean(jax.vmap(one)(rs)))


def make_quadratic(key: jax.Array, n: int = 64, kappa: float = 4.0,
                   c_scale: float = 8.0) -> Quadratic:
    """Random separable quadratic with condition number `kappa`.

    `c_scale` sets ||x0 - c|| relative to the coarse-lattice benchmark
    E f(x*_{r,delta_star}) (which depends only on h and delta_star, not c):
    starting from x0 = 0, the initial gap is ~c_scale^2 larger than the
    benchmark floor, so the linear transient of Theorem 2 spans enough
    iterations to *measure* the contraction rate before f(x_t) crosses the
    floor (with c_scale=1 the gap goes negative after ~2 steps and a rate
    fit is ill-posed)."""
    k1, k2 = jax.random.split(key)
    h = jnp.exp(jax.random.uniform(k1, (n,)) * math.log(kappa))  # in [1, kappa]
    c = jax.random.normal(k2, (n,)) * c_scale
    return Quadratic(h=h, c=c)


@dataclasses.dataclass(frozen=True)
class Theorem2Params:
    eta: float
    delta: float
    T: int
    lr: float  # eta / beta — the actual step size


def theorem2_params(
    alpha: float,
    beta: float,
    delta_star: float,
    eps: float,
    sigma: float,
    f0_gap: float,
    sigma_q: float = 0.0,
) -> Theorem2Params:
    """Exactly the parameter choices of Theorem 2 / Corollary 3."""
    var = sigma**2 + sigma_q**2
    eta = 1.0 if var == 0 else min(0.3 * eps * alpha / var, 1.0)
    delta = eta * delta_star / math.ceil(16.0 * (beta / alpha) ** 2)
    T = math.ceil(10.0 / eta * (beta / alpha) * math.log(max(f0_gap / eps, math.e)))
    return Theorem2Params(eta=eta, delta=delta, T=T, lr=eta / beta)


WeightQ = str  # "shift" | "nearest" | "coinflip" | "none"


def run_qsgd(
    obj: Quadratic,
    x0: jax.Array,
    params: Theorem2Params,
    key: jax.Array,
    sigma: float = 0.0,
    weight_q: WeightQ = "shift",
    grad_q_delta: Optional[float] = None,
    record_every: int = 1,
    x64: bool = True,
) -> tuple[jax.Array, jax.Array]:
    """Run the Theorem-2 iteration; returns (x_T, f-trajectory).

    By default the iteration runs in float64 (`jax.experimental.enable_x64`
    scoped to this call): the f(x_t) - f* gaps the rate tests fit span many
    orders of magnitude and bottom out at the f32 resolution of f after a
    handful of steps, which poisons any contraction-rate estimate."""
    if x64:
        with jax.experimental.enable_x64():
            return _run_qsgd_impl(obj, x0.astype(jnp.float64), params, key,
                                  sigma, weight_q, grad_q_delta, record_every)
    return _run_qsgd_impl(obj, x0, params, key, sigma, weight_q, grad_q_delta,
                          record_every)


def _run_qsgd_impl(obj, x0, params, key, sigma, weight_q, grad_q_delta,
                   record_every):

    def qw(x, k):
        if weight_q == "shift":
            return q_shift(x, params.delta, k)
        if weight_q == "nearest":
            return q_nearest(x, params.delta)
        if weight_q == "coinflip":
            return q_coinflip(x, params.delta, k)
        if weight_q == "none":
            return x
        raise ValueError(weight_q)

    def step(carry, _):
        x, k = carry
        k, kg, kq, kgq = jax.random.split(k, 4)
        g = obj.noisy_grad(x, kg, sigma) if sigma > 0 else obj.grad(x)
        if grad_q_delta is not None:  # Corollary 3: unbiased gradient quantizer
            g = q_coinflip(g, grad_q_delta, kgq)
        x = qw(x - params.lr * g, kq)
        return (x, k), obj.f(x)

    (xT, _), fs = jax.lax.scan(step, (x0, key), None, length=params.T)
    return xT, fs[::record_every]
