"""Tensor-parallel collective ops with hand-specified transposes.

Under ``shard_map(..., check_vma=False)`` JAX uses the legacy pmap transpose
rules (``transpose(psum) = psum``), which double-counts gradients whenever a
psum output is consumed by replicated compute.  As in Megatron's f/g
functions, we fix the semantics explicitly:

    tp_copy   : identity forward  /  psum over "model" backward
                (entry into a column-parallel region from replicated
                activations — the backward sums each rank's contribution)
    tp_reduce : psum over "model" forward  /  identity backward
                (exit from a row-parallel region — the output is replicated,
                so each rank backpropagates the same cotangent locally)

Composition rule for all model code in this repo:

  * every path from model-replicated activations into rank-specific
    (TP-sharded) compute goes through ``tp_copy``;
  * every rank-partial result that must become replicated goes through
    ``tp_reduce`` (including the log-sum-exp and label terms of the
    vocab-parallel cross-entropy);
  * gradient semantics inside the shard_mapped step: the loss function
    returns the *local* (per-device) mean loss with NO collectives on the
    loss path; the QSDP gather backward performs the cross-device sum
    (reduce-scatter / fsdp_size).

``lax.all_to_all`` and activation ``all_gather`` keep their builtin
transposes (verified exact: a2a transposes to the inverse a2a; all_gather to
psum_scatter, correct when the gathered value is consumed rank-specifically).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax import lax

from ..compat import axis_size

MODEL_AXIS = "model"


@partial(jax.custom_vjp, nondiff_argnums=(1,))
def tp_copy(x: jax.Array, axis: str = MODEL_AXIS) -> jax.Array:
    return x


def _tp_copy_fwd(x, axis):
    return x, None


def _tp_copy_bwd(axis, _, ct):
    return (lax.psum(ct, axis),)


tp_copy.defvjp(_tp_copy_fwd, _tp_copy_bwd)


@partial(jax.custom_vjp, nondiff_argnums=(1,))
def tp_reduce(x: jax.Array, axis: str = MODEL_AXIS) -> jax.Array:
    return lax.psum(x, axis)


def _tp_reduce_fwd(x, axis):
    return lax.psum(x, axis), None


def _tp_reduce_bwd(axis, _, ct):
    return (ct,)


tp_reduce.defvjp(_tp_reduce_fwd, _tp_reduce_bwd)


@partial(jax.custom_vjp, nondiff_argnums=(1, 2))
def tp_split_tokens(x: jax.Array, dim: int = 0, axis: str = MODEL_AXIS) -> jax.Array:
    """Replicated -> rank-sharded along `dim` (sequence/token parallelism).

    Forward: take this rank's 1/P chunk.  Backward: the full cotangent is
    assembled by all-gathering every rank's chunk-cotangent (each rank's
    compute path only touched its own chunk).
    """
    return _split(x, dim, axis)


def _split(x, dim, axis):
    p = axis_size(axis)
    r = lax.axis_index(axis)
    n = x.shape[dim] // p
    return lax.dynamic_slice_in_dim(x, r * n, n, axis=dim)


def _tp_split_fwd(x, dim, axis):
    return _split(x, dim, axis), None


def _tp_split_bwd(dim, axis, _, ct):
    y = lax.all_gather(ct, axis, tiled=False)
    y = jnp.moveaxis(y, 0, dim)
    s = list(ct.shape)
    s[dim] *= axis_size(axis)
    return (y.reshape(s),)


tp_split_tokens.defvjp(_tp_split_fwd, _tp_split_bwd)


@partial(jax.custom_vjp, nondiff_argnums=(1, 2))
def tp_merge_tokens(x: jax.Array, dim: int = 0, axis: str = MODEL_AXIS) -> jax.Array:
    """Rank-sharded along `dim` -> replicated (inverse of tp_split_tokens).

    Forward: all-gather the chunks.  Backward: every rank's consumer is a
    replica, so each rank keeps just its own chunk of the (identical)
    cotangent — NO cross-rank sum (contrast tp_all_gather, whose gathered
    value feeds rank-specific compute and therefore scatter-adds).
    """
    return _merge(x, dim, axis)


def _merge(x, dim, axis):
    y = lax.all_gather(x, axis, tiled=False)
    y = jnp.moveaxis(y, 0, dim)
    s = list(x.shape)
    s[dim] *= axis_size(axis)
    return y.reshape(s)


def _tp_merge_fwd(x, dim, axis):
    return _merge(x, dim, axis), None


def _tp_merge_bwd(dim, axis, _, ct):
    return (_split(ct, dim, axis),)


tp_merge_tokens.defvjp(_tp_merge_fwd, _tp_merge_bwd)


@partial(jax.custom_vjp, nondiff_argnums=(1, 2))
def tp_all_gather(x: jax.Array, dim: int, axis: str = MODEL_AXIS) -> jax.Array:
    """All-gather along tensor dim `dim` over the model axis, with the
    scatter-add transpose (correct when the gathered tensor is consumed
    rank-specifically, e.g. KV gathered while Q stays head-sharded)."""
    return _ag(x, dim, axis)


def _ag(x, dim, axis):
    y = lax.all_gather(x, axis, tiled=False)  # (P, ...) leading
    y = jnp.moveaxis(y, 0, dim)
    s = list(x.shape)
    s[dim] *= axis_size(axis)
    return y.reshape(s)


def _tp_ag_fwd(x, dim, axis):
    return _ag(x, dim, axis), None


def _tp_ag_bwd(dim, axis, _, ct):
    p = axis_size(axis)
    s = list(ct.shape)
    ct = ct.reshape(*s[:dim], p, s[dim] // p, *s[dim + 1 :])
    ct = jnp.moveaxis(ct, dim, 0)
    return (lax.psum_scatter(ct, axis, scatter_dimension=0, tiled=False),)


tp_all_gather.defvjp(_tp_ag_fwd, _tp_ag_bwd)
