from .synthetic import SyntheticLM, batch_pspecs, make_batch  # noqa: F401
