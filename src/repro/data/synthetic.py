"""Deterministic synthetic LM data pipeline.

The paper pre-trains GPT on C4; offline we need a corpus with *learnable
structure* so that loss curves are meaningful (a model that learns should
beat the unigram entropy floor).  We generate an order-1 Markov chain over
the vocabulary with a sparse, low-entropy transition table derived from a
fixed seed — the resulting stream has known cross-entropy floors:

    H(unigram)  -- what a bias-only model reaches
    H(bigram)   -- the Bayes floor a context model can reach

Every batch is a pure function of (seed, step), so runs are exactly
reproducible across restarts, process counts and shardings; each host
materializes only its addressable shard.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class SyntheticLM:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    branching: int = 8  # successors per token (lower = lower entropy)

    def _table(self) -> np.ndarray:
        """(V, branching) successor table, fixed by seed."""
        rng = np.random.default_rng(self.seed)
        return rng.integers(0, self.vocab_size, size=(self.vocab_size, self.branching))

    def bigram_entropy(self) -> float:
        """Bayes cross-entropy floor (nats/token) of the generating chain."""
        # successors are sampled uniformly among `branching` choices (with
        # possible duplicates); exact entropy computed per row then averaged
        # under the stationary (≈uniform) distribution.
        tab = self._table()
        ent = 0.0
        for row in tab[: min(1024, self.vocab_size)]:  # sample rows for speed
            _, counts = np.unique(row, return_counts=True)
            p = counts / counts.sum()
            ent += float(-(p * np.log(p)).sum())
        return ent / min(1024, self.vocab_size)

    # -- jax-side generation ---------------------------------------------------

    def sample(self, step: int, batch: int | None = None, seq: int | None = None):
        """Generate (tokens, labels) of shape (batch, seq) for `step`.

        tokens[t+1] ~ Uniform(table[tokens[t]]).  labels = next token.
        Jitted (cached per shape) — the scan would otherwise dispatch
        op-by-op and dominate step time.
        """
        b = batch or self.global_batch
        s = seq or self.seq_len
        tab = jnp.asarray(self._table())
        return _sample_jit(tab, self.seed, step, b, s, self.vocab_size, self.branching)


from functools import partial


@partial(jax.jit, static_argnums=(1, 3, 4, 5, 6))
def _sample_jit(tab, seed, step, b, s, vocab, branching):
    key = jax.random.fold_in(jax.random.PRNGKey(seed), step)
    k0, kc = jax.random.split(key)
    x0 = jax.random.randint(k0, (b,), 0, vocab)
    choices = jax.random.randint(kc, (b, s), 0, branching)

    def gen(tok, choice):
        nxt = tab[tok, choice]
        return nxt, nxt

    _, seq_toks = jax.lax.scan(gen, x0, choices.T)
    seq_toks = seq_toks.T  # (b, s)
    tokens = jnp.concatenate([x0[:, None], seq_toks[:, :-1]], axis=1)
    return tokens.astype(jnp.int32), seq_toks.astype(jnp.int32)


def batch_pspecs(batch_axes) -> dict:
    return {"tokens": P(batch_axes), "labels": P(batch_axes)}


def make_batch(data: SyntheticLM, step: int, mesh, batch_axes) -> dict:
    """Device-put one global batch with the training sharding."""
    tokens, labels = data.sample(step)
    sh = NamedSharding(mesh, P(batch_axes))
    return {
        "tokens": jax.device_put(tokens, sh),
        "labels": jax.device_put(labels, sh),
    }
