"""Fused int8-dequant matmul — the beyond-paper optimization the QSDP
conclusion points at ("whether the lower-precision weight representation can
also be exploited for faster runtimes").

After a quantized all-gather, the full layer weight exists on-device as u8
codes + per-row affine (scale, zero).  The baseline path dequantizes to a
full bf16/f32 matrix in HBM and then matmuls — paying the full-precision
weight bytes from HBM into VMEM *twice* (write then read).  This kernel
consumes the codes directly:

    y[m, n] = sum_k x[m, k] * (c[k, n] * s[k] + z[k])
            = (x * s^T) @ c     +     (x @ z) * 1^T
              ^^^^^^^^^^^^^          ^^^^^^^^^
              MXU int8->f32 dot      rank-1 correction (VPU)

so the weight traffic from HBM is 1 byte/element instead of 2-4, moving the
memory-roofline term down by ~2x for weight-dominated decode steps.

Tiling: grid (M/BM, N/BN, K/BK); x tile (BM, BK) and code tile (BK, BN) live
in VMEM; the accumulator is revisited across the K grid dimension (output
BlockSpec ignores k), with MXU-aligned tile sizes (multiples of 128 on the
minor dims, 8 on sublanes).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _dqmm_kernel(nk: int, x_ref, c_ref, s_ref, z_ref, o_ref):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[...].astype(jnp.float32)  # (BM, BK)
    c = c_ref[...].astype(jnp.float32)  # (BK, BN)
    s = s_ref[...].astype(jnp.float32)  # (BK, 1)
    z = z_ref[...].astype(jnp.float32)  # (BK, 1)
    xs = x * s[:, 0][None, :]  # scale folded into activations
    acc = jnp.dot(xs, c, preferred_element_type=jnp.float32)
    acc += jnp.sum(x * z[:, 0][None, :], axis=1, keepdims=True)  # rank-1 term
    o_ref[...] += acc


def rowquant_matmul_pallas(
    x: jax.Array,
    codes: jax.Array,
    scale: jax.Array,
    zero: jax.Array,
    *,
    block_m: int = 128,
    block_n: int = 256,
    block_k: int = 512,
    interpret: bool = True,
) -> jax.Array:
    """y = x @ dequant(W).

    x: (M, K) f32/bf16; codes: (K, N) u8; scale, zero: (K, n_seg) f32 with
    the affine constant over N-segments of size N / n_seg (n_seg == 1 is
    per-row affine).  Each n-tile must lie inside one segment (block_n
    divides the segment — arranged upstream in ops.py), so the kernel body
    always sees a (BK, 1) affine tile regardless of n_seg.
    Shapes must tile evenly (pad upstream in ops.py).
    """
    m, k = x.shape
    k2, n = codes.shape
    assert k == k2, (k, k2)
    n_seg = scale.shape[1]
    bm, bn, bk = min(block_m, m), min(block_n, n), min(block_k, k)
    assert m % bm == 0 and n % bn == 0 and k % bk == 0, (m, n, k, bm, bn, bk)
    seg_tiles = (n // bn) // n_seg  # n-tiles per affine segment
    assert seg_tiles * n_seg == n // bn, (n, bn, n_seg)
    grid = (m // bm, n // bn, k // bk)
    kern = functools.partial(_dqmm_kernel, grid[2])
    out = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((bk, 1), lambda i, j, kk: (kk, j // seg_tiles)),
            pl.BlockSpec((bk, 1), lambda i, j, kk: (kk, j // seg_tiles)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=interpret,
    )(x, codes, scale, zero)
    return out.astype(x.dtype)
