"""Jit'd public wrappers around the Pallas kernels.

`interpret` defaults to True off-TPU (this container is CPU-only; on real
TPU hardware pass interpret=False or set REPRO_PALLAS_INTERPRET=0).
"""
from __future__ import annotations

import os
from functools import partial

import jax
import jax.numpy as jnp

from . import ref
from .dequant_matmul import rowquant_matmul_pallas
from .quantize import ROWS_PER_TILE, dequantize_pallas, quantize_pallas


def _default_interpret() -> bool:
    env = os.environ.get("REPRO_PALLAS_INTERPRET")
    if env is not None:
        return env not in ("0", "false", "False")
    return jax.default_backend() != "tpu"


def _pad_rows(x: jax.Array, mult: int) -> tuple[jax.Array, int]:
    nb = x.shape[0]
    pad = (-nb) % mult
    if pad:
        x = jnp.pad(x, ((0, pad), (0, 0)))
    return x, nb


@partial(jax.jit, static_argnames=("levels", "stochastic", "interpret"))
def quantize_buckets(
    x: jax.Array,
    rand: jax.Array,
    levels: int = 255,
    stochastic: bool = True,
    interpret: bool | None = None,
):
    """Bucket-quantize a (nb, bucket) f32 array.  Returns (codes, scale, zero)
    with scale/zero shaped (nb, 1)."""
    interpret = _default_interpret() if interpret is None else interpret
    xp, nb = _pad_rows(x, ROWS_PER_TILE)
    rp, _ = _pad_rows(rand, ROWS_PER_TILE)
    codes, scale, zero = quantize_pallas(xp, rp, levels, stochastic, interpret=interpret)
    return codes[:nb], scale[:nb], zero[:nb]


@partial(jax.jit, static_argnames=("interpret",))
def dequantize_buckets(
    codes: jax.Array, scale: jax.Array, zero: jax.Array, interpret: bool | None = None
):
    interpret = _default_interpret() if interpret is None else interpret
    cp, nb = _pad_rows(codes, ROWS_PER_TILE)
    sp, _ = _pad_rows(scale, ROWS_PER_TILE)
    zp, _ = _pad_rows(zero, ROWS_PER_TILE)
    out = dequantize_pallas(cp, sp, zp, interpret=interpret)
    return out[:nb]


@partial(jax.jit, static_argnames=("block_m", "block_n", "block_k", "interpret"))
def rowquant_matmul(
    x: jax.Array,
    codes: jax.Array,
    scale: jax.Array,
    zero: jax.Array,
    block_m: int = 128,
    block_n: int = 256,
    block_k: int = 512,
    interpret: bool | None = None,
):
    """y = x @ dequant(W) consuming u8 codes directly (see dequant_matmul.py).

    Pads M/N/K up to tile multiples, so arbitrary shapes are accepted.
    """
    interpret = _default_interpret() if interpret is None else interpret
    m, k = x.shape
    _, n = codes.shape
    bm, bn, bk = min(block_m, m), min(block_n, n), min(block_k, k)
    pm, pn, pk = (-m) % bm, (-n) % bn, (-k) % bk
    xp = jnp.pad(x, ((0, pm), (0, pk)))
    cp = jnp.pad(codes, ((0, pk), (0, pn)))
    sp = jnp.pad(scale, ((0, pk), (0, 0)))
    zp = jnp.pad(zero, ((0, pk), (0, 0)))
    out = rowquant_matmul_pallas(
        xp, cp, sp, zp, block_m=bm, block_n=bn, block_k=bk, interpret=interpret
    )
    return out[:m, :n]


def quantize_weight_rowwise(w: jax.Array, bits: int = 8):
    """Host/one-time: per-K-row quantization producing the kernel layout."""
    return ref.quantize_rowwise_ref(w, (1 << bits) - 1)
