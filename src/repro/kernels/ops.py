"""Jit'd public wrappers around the Pallas kernels + backend dispatch.

Dispatch knobs
--------------
Two environment variables (plus per-call overrides) control how the QSDP
hot path runs:

  * ``REPRO_QUANT_BACKEND`` — ``"pallas" | "jnp" | "auto"`` (default
    ``auto``).  ``auto`` selects the Pallas kernels on TPU and whenever
    ``REPRO_PALLAS_INTERPRET`` is set truthy (interpret-mode testing on
    CPU), otherwise the pure-jnp reference in ``core.quant``.  The two
    backends are bit-exact (tested), so this is purely a performance knob.
  * ``REPRO_PALLAS_INTERPRET`` — force (``1``) or forbid (``0``) Pallas
    interpret mode.  Unset: interpret off-TPU, compiled on TPU.

``core.quant.quantize`` / ``dequantize`` call :func:`quantize_packed` /
:func:`dequantize_packed` here when the resolved backend is ``pallas``; the
wire layout (packed u8 codes + per-bucket f32 scale/zero) is identical in
both backends — see the module docstring of ``kernels.quantize`` for the
exact byte layout.
"""
from __future__ import annotations

import os
from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp

from . import ref
from .dequant_matmul import rowquant_matmul_pallas
from .quantize import (
    ROWS_PER_TILE,
    dequantize_pallas,
    quantize_pack_pallas,
    quantize_pallas,
    unpack_dequantize_pallas,
)


def _interpret_env() -> bool | None:
    """REPRO_PALLAS_INTERPRET as a tri-state: None when unset, else its
    truthiness ("0"/"false"/"False" are the falsy spellings)."""
    env = os.environ.get("REPRO_PALLAS_INTERPRET")
    if env is None:
        return None
    return env not in ("0", "false", "False")


def _default_interpret() -> bool:
    env = _interpret_env()
    if env is not None:
        return env
    return jax.default_backend() != "tpu"


def resolve_backend(backend: str | None = None) -> str:
    """Resolve a ``"pallas" | "jnp" | "auto" | None`` request to a concrete
    backend.  An explicit "pallas"/"jnp" wins; None or "auto" defers to
    ``REPRO_QUANT_BACKEND``, and a still-"auto" answer picks Pallas on TPU
    or when ``REPRO_PALLAS_INTERPRET`` forces interpret mode on, and the
    jnp reference otherwise."""
    b = backend or "auto"
    if b == "auto":
        b = os.environ.get("REPRO_QUANT_BACKEND", "auto")
    assert b in ("pallas", "jnp", "auto"), b
    if b != "auto":
        return b
    if jax.default_backend() == "tpu" or _interpret_env():
        return "pallas"
    return "jnp"


def _pad_rows(x: jax.Array, mult: int) -> tuple[jax.Array, int]:
    nb = x.shape[0]
    pad = (-nb) % mult
    if pad:
        x = jnp.pad(x, ((0, pad), (0, 0)))
    return x, nb


@partial(jax.jit, static_argnames=("levels", "stochastic", "interpret"))
def quantize_buckets(
    x: jax.Array,
    rand: jax.Array,
    levels: int = 255,
    stochastic: bool = True,
    interpret: bool | None = None,
):
    """Bucket-quantize a (nb, bucket) f32 array.  Returns (codes, scale, zero)
    with scale/zero shaped (nb, 1)."""
    interpret = _default_interpret() if interpret is None else interpret
    xp, nb = _pad_rows(x, ROWS_PER_TILE)
    rp, _ = _pad_rows(rand, ROWS_PER_TILE)
    codes, scale, zero = quantize_pallas(xp, rp, levels, stochastic, interpret=interpret)
    return codes[:nb], scale[:nb], zero[:nb]


@partial(jax.jit, static_argnames=("interpret",))
def dequantize_buckets(
    codes: jax.Array, scale: jax.Array, zero: jax.Array, interpret: bool | None = None
):
    interpret = _default_interpret() if interpret is None else interpret
    cp, nb = _pad_rows(codes, ROWS_PER_TILE)
    sp, _ = _pad_rows(scale, ROWS_PER_TILE)
    zp, _ = _pad_rows(zero, ROWS_PER_TILE)
    out = dequantize_pallas(cp, sp, zp, interpret=interpret)
    return out[:nb]


# ---------------------------------------------------------------------------
# Fused quantize->pack / unpack->dequantize (the core.quant hot path)
# ---------------------------------------------------------------------------


@partial(jax.jit, static_argnames=("levels", "bits", "mode", "rand_scale", "interpret"))
def quantize_packed(
    x: jax.Array,
    rand: jax.Array,
    levels: int,
    bits: int,
    mode: str = "nearest",
    rand_scale: float = 1.0,
    interpret: bool | None = None,
):
    """Fused bucketed quantize + bit-pack of a (nb, bucket) f32 array.

    Returns (packed codes u8 (nb, bucket*bits/8 — or one byte per code when
    8 % bits != 0), scale (nb, 1), zero (nb, 1)); the exact wire layout of
    ``core.quant.Quantized``.  `rand` is mode-dependent (see
    ``kernels.quantize.quantize_pack_pallas``)."""
    interpret = _default_interpret() if interpret is None else interpret
    xp, nb = _pad_rows(x, ROWS_PER_TILE)
    rp, _ = _pad_rows(rand, ROWS_PER_TILE)
    codes, scale, zero = quantize_pack_pallas(
        xp, rp, levels, bits, mode, rand_scale, interpret=interpret
    )
    return codes[:nb], scale[:nb], zero[:nb]


@partial(jax.jit, static_argnames=("bits", "dtype", "interpret"))
def dequantize_packed(
    codes: jax.Array,
    scale: jax.Array,
    zero: jax.Array,
    bits: int,
    dtype=jnp.float32,
    interpret: bool | None = None,
):
    """Fused bit-unpack + affine dequantize: (nb, bucket*bits/8) packed u8
    codes + (nb, 1) scale/zero -> (nb, bucket) values in `dtype`."""
    interpret = _default_interpret() if interpret is None else interpret
    cp, nb = _pad_rows(codes, ROWS_PER_TILE)
    sp, _ = _pad_rows(scale, ROWS_PER_TILE)
    zp, _ = _pad_rows(zero, ROWS_PER_TILE)
    out = unpack_dequantize_pallas(cp, sp, zp, bits, dtype, interpret=interpret)
    return out[:nb]


# ---------------------------------------------------------------------------
# Fused dequant-matmul (serve/decode path)
# ---------------------------------------------------------------------------


class RowQuantWeight(NamedTuple):
    """A (K, N) matmul weight kept in quantized code form.

    codes: (K, N) u8; scale/zero: (K, n_seg) f32 — the affine is per
    (K-row, N-segment) block with segment size N / n_seg.  n_seg == 1 is
    plain per-row quantization (the ``quantize_weight_rowwise`` layout);
    n_seg == N / bucket_size is the QSDP *wire* layout of a row-major
    weight whose rows are a multiple of the bucket size, which lets the
    serve path feed gathered wire codes straight into the matmul without
    ever materializing the dequantized weight (see QSDPEngine.gather_rowquant).
    """

    codes: jax.Array
    scale: jax.Array
    zero: jax.Array


@partial(jax.jit, static_argnames=("block_m", "block_n", "block_k", "interpret"))
def rowquant_matmul(
    x: jax.Array,
    codes: jax.Array,
    scale: jax.Array,
    zero: jax.Array,
    block_m: int = 128,
    block_n: int = 256,
    block_k: int = 512,
    interpret: bool | None = None,
):
    """y = x @ dequant(W) consuming u8 codes directly (see dequant_matmul.py).

    scale/zero: (K, 1) per-row affine, or (K, n_seg) segment affine with
    N % n_seg == 0 (block_n is clamped to divide the segment).  Pads M/K (and
    N for the per-row case) up to tile multiples, so arbitrary shapes are
    accepted.
    """
    interpret = _default_interpret() if interpret is None else interpret
    m, k = x.shape
    _, n = codes.shape
    n_seg = scale.shape[1]
    bm, bk = min(block_m, m), min(block_k, k)
    if n_seg == 1:
        bn = min(block_n, n)
    else:
        assert n % n_seg == 0, (n, n_seg)
        seg = n // n_seg
        bn = min(block_n, seg)
        while seg % bn:  # shrink to a divisor of the segment
            bn -= 1
        assert n % bn == 0
    pm, pn, pk = (-m) % bm, (-n) % bn, (-k) % bk
    assert n_seg == 1 or pn == 0, (n, bn, n_seg)
    xp = jnp.pad(x, ((0, pm), (0, pk)))
    cp = jnp.pad(codes, ((0, pk), (0, pn)))
    sp = jnp.pad(scale, ((0, pk), (0, 0)))
    zp = jnp.pad(zero, ((0, pk), (0, 0)))
    out = rowquant_matmul_pallas(
        xp, cp, sp, zp, block_m=bm, block_n=bn, block_k=bk, interpret=interpret
    )
    return out[:m, :n]


def rowquant_matmul_dispatch(x: jax.Array, w: RowQuantWeight,
                             backend: str | None = None) -> jax.Array:
    """Backend-dispatched y = x @ dequant(w) for 2D x."""
    if resolve_backend(backend) == "pallas":
        return rowquant_matmul(x, w.codes, w.scale, w.zero)
    return ref.rowquant_matmul_ref(x, w.codes, w.scale, w.zero)


def quantize_weight_rowwise(w: jax.Array, bits: int = 8):
    """Host/one-time: per-K-row quantization producing the kernel layout."""
    return ref.quantize_rowwise_ref(w, (1 << bits) - 1)
