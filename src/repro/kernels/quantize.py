"""Pallas TPU kernels for bucketed quantize / dequantize.

These are QSDP's compute hot-spots: every all-gather quantizes the local
shard and every receiver dequantizes P shards, at every layer, twice per
step (fwd + bwd re-gather) plus once for the gradient reduce-scatter.  On
GPU the paper implements these inside CGX as CUDA kernels; here they are
TPU-native Pallas kernels:

  * the bucket axis (1024 values) is the 128-lane minor dimension times 8
    sublanes, i.e. one bucket == one full (8, 128) f32 VREG tile — min/max
    reductions over a bucket are intra-tile and cheap on the VPU;
  * a block of ROWS_PER_TILE buckets is staged in VMEM per grid step;
  * randomness (stochastic-rounding uniforms, per-bucket random shifts)
    enters as a pre-generated array drawn from the SAME PRNG stream as the
    jnp reference in ``core.quant``, so the two backends are bit-exact.

Wire format (must match ``core.quant`` exactly — it is what goes on the
wire in the quantized collectives):

  codes  u8 (nb, bucket_size * bits / 8)   bit-packed when 8 % bits == 0:
         byte j of a bucket holds codes ``j*k .. j*k+k-1`` (k = 8/bits),
         code ``j*k+i`` in bits ``[i*bits, (i+1)*bits)`` — little-endian
         within the byte, identical to ``core.quant.pack_codes``;
  scale  f32 (nb, 1)   per-bucket step ((max - min) / levels);
  zero   f32 (nb, 1)   per-bucket affine offset (min, plus the folded-in
         random shift for mode="shift").

Two kernel families live here:

  1. ``quantize_pallas`` / ``dequantize_pallas`` — the original unpacked
     kernels (one u8 byte per code), kept for 3/5/6/7-bit widths and as
     the simplest-possible reference kernels.
  2. ``quantize_pack_pallas`` / ``unpack_dequantize_pallas`` — **fused**
     quantize→bit-pack and bit-unpack→dequantize: sub-8-bit codes never
     materialize as one-byte-per-code intermediates in HBM; the pack/unpack
     shifts run on the VPU over the VMEM-resident tile.  These implement
     all three rounding modes of the wire quantizer ("nearest",
     "stochastic", "shift") and are the kernels ``core.quant`` dispatches
     to (see the ``backend=`` / ``REPRO_QUANT_BACKEND`` /
     ``REPRO_PALLAS_INTERPRET`` knobs documented in ``kernels.ops``).

Validated in interpret mode on CPU against `ref.py` and ``core.quant``
(bit-exact for codes and packed wire bytes).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

ROWS_PER_TILE = 8


def _quantize_kernel(levels: int, stochastic: bool, x_ref, rand_ref, codes_ref, scale_ref, zero_ref):
    x = x_ref[...]  # (R, bucket) f32
    lo = jnp.min(x, axis=1, keepdims=True)
    hi = jnp.max(x, axis=1, keepdims=True)
    scale = jnp.maximum((hi - lo) * (1.0 / levels), 1e-12)
    v = (x - lo) / scale
    if stochastic:
        f = jnp.floor(v)
        codes = f + (rand_ref[...] < (v - f)).astype(v.dtype)
    else:
        codes = jnp.round(v)
    codes_ref[...] = jnp.clip(codes, 0, levels).astype(jnp.uint8)
    scale_ref[...] = scale
    zero_ref[...] = lo


def quantize_pallas(
    x: jax.Array,
    rand: jax.Array,
    levels: int,
    stochastic: bool,
    *,
    interpret: bool = True,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """x, rand: (nb, bucket) f32 with nb % ROWS_PER_TILE == 0 (pad upstream).

    Returns (codes u8 (nb, bucket), scale f32 (nb, 1), zero f32 (nb, 1)).
    """
    nb, bucket = x.shape
    assert nb % ROWS_PER_TILE == 0, nb
    grid = (nb // ROWS_PER_TILE,)
    kern = functools.partial(_quantize_kernel, levels, stochastic)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((ROWS_PER_TILE, bucket), lambda i: (i, 0)),
            pl.BlockSpec((ROWS_PER_TILE, bucket), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((ROWS_PER_TILE, bucket), lambda i: (i, 0)),
            pl.BlockSpec((ROWS_PER_TILE, 1), lambda i: (i, 0)),
            pl.BlockSpec((ROWS_PER_TILE, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nb, bucket), jnp.uint8),
            jax.ShapeDtypeStruct((nb, 1), jnp.float32),
            jax.ShapeDtypeStruct((nb, 1), jnp.float32),
        ],
        interpret=interpret,
    )(x, rand)


# ---------------------------------------------------------------------------
# Fused quantize -> bit-pack  (and bit-unpack -> dequantize below)
# ---------------------------------------------------------------------------

_MODES = ("nearest", "stochastic", "shift")


def _pack_k(bits: int) -> int:
    return 8 // bits if 8 % bits == 0 else 1


def _quantize_pack_kernel(levels, bits, mode, rand_scale,
                          x_ref, rand_ref, codes_ref, scale_ref, zero_ref):
    """One (R, bucket) tile: bucketed min-max quantize with the selected
    rounding mode, then bit-pack k = 8/bits codes per byte in-register.

    The arithmetic is kept expression-for-expression identical to the jnp
    reference path in ``core.quant.quantize`` so both backends produce the
    same wire bytes.
    """
    x = x_ref[...]  # (R, bucket) f32
    lo = jnp.min(x, axis=1, keepdims=True)
    hi = jnp.max(x, axis=1, keepdims=True)
    # `* (1/levels)` not `/ levels`: matches the jnp reference exactly in
    # both eager and jit (XLA rewrites constant divisions to reciprocal
    # multiplies under jit — see core.quant.quantize).
    scale = jnp.maximum((hi - lo) * (1.0 / levels), 1e-12)
    v = (x - lo) / scale
    if mode == "stochastic":
        f = jnp.floor(v)
        up = rand_ref[...] < (v - f) * rand_scale
        codes = f + up.astype(v.dtype)
        zero = lo
    elif mode == "shift":
        r = rand_ref[...]  # (R, 1) shared shift per bucket
        codes = jnp.round(v - r)
        zero = lo + r * scale  # fold the shift into the affine decode
    else:  # nearest
        codes = jnp.round(v)
        zero = lo
    codes = jnp.clip(codes, 0, levels).astype(jnp.uint8)
    k = _pack_k(bits)
    if k > 1:
        # strided-slice pack: byte j <- sum_i codes[:, j*k + i] << (i*bits).
        # Slices keep everything 2D / lane-major (no tiny minor reshape).
        packed = codes[:, 0::k]
        for i in range(1, k):
            packed = packed | (codes[:, i::k] << jnp.uint8(i * bits))
    else:
        packed = codes
    codes_ref[...] = packed
    scale_ref[...] = scale
    zero_ref[...] = zero


def quantize_pack_pallas(
    x: jax.Array,
    rand: jax.Array,
    levels: int,
    bits: int,
    mode: str = "nearest",
    rand_scale: float = 1.0,
    *,
    interpret: bool = True,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Fused quantize→pack.  x: (nb, bucket) f32, nb % ROWS_PER_TILE == 0.

    rand: per-mode randomness, drawn upstream from the same PRNG stream as
    the jnp reference —
      * mode="stochastic": (nb, bucket) thresholds; ``up = rand < frac *
        rand_scale`` (rand_scale=1 for f32 uniforms, 65536 for u16 raw bits);
      * mode="shift": (nb, 1) per-bucket shifts in [-0.5, 0.5);
      * mode="nearest": unused, pass (nb, 1) zeros.

    Returns (packed codes u8 (nb, bucket*bits/8), scale (nb, 1), zero (nb, 1)).
    """
    assert mode in _MODES, mode
    nb, bucket = x.shape
    assert nb % ROWS_PER_TILE == 0, nb
    k = _pack_k(bits)
    assert bucket % k == 0, (bucket, k)
    n_packed = bucket // k
    grid = (nb // ROWS_PER_TILE,)
    rand_cols = rand.shape[1]
    kern = functools.partial(_quantize_pack_kernel, levels, bits, mode, rand_scale)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((ROWS_PER_TILE, bucket), lambda i: (i, 0)),
            pl.BlockSpec((ROWS_PER_TILE, rand_cols), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((ROWS_PER_TILE, n_packed), lambda i: (i, 0)),
            pl.BlockSpec((ROWS_PER_TILE, 1), lambda i: (i, 0)),
            pl.BlockSpec((ROWS_PER_TILE, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nb, n_packed), jnp.uint8),
            jax.ShapeDtypeStruct((nb, 1), jnp.float32),
            jax.ShapeDtypeStruct((nb, 1), jnp.float32),
        ],
        interpret=interpret,
    )(x, rand)


def _unpack_dequantize_kernel(bits, out_dtype, codes_ref, scale_ref, zero_ref, out_ref):
    packed = codes_ref[...]  # (R, bucket*bits/8) u8
    k = _pack_k(bits)
    if k > 1:
        mask = jnp.uint8((1 << bits) - 1)
        r, nbytes = packed.shape
        # element j*k + i of a bucket lives in bits [i*bits, (i+1)*bits) of
        # byte j; stack along a new minor axis then flatten re-interleaves.
        parts = [(packed >> jnp.uint8(i * bits)) & mask for i in range(k)]
        codes = jnp.stack(parts, axis=-1).reshape(r, nbytes * k)
    else:
        codes = packed
    out_ref[...] = (codes.astype(jnp.float32) * scale_ref[...]
                    + zero_ref[...]).astype(out_dtype)


def unpack_dequantize_pallas(
    codes: jax.Array,
    scale: jax.Array,
    zero: jax.Array,
    bits: int,
    dtype=jnp.float32,
    *,
    interpret: bool = True,
) -> jax.Array:
    """Fused unpack→dequantize.  codes: (nb, bucket*bits/8) packed u8;
    scale/zero: (nb, 1) f32.  Returns (nb, bucket) values in `dtype`."""
    nb, n_packed = codes.shape
    assert nb % ROWS_PER_TILE == 0, nb
    k = _pack_k(bits)
    bucket = n_packed * k
    grid = (nb // ROWS_PER_TILE,)
    kern = functools.partial(_unpack_dequantize_kernel, bits, dtype)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((ROWS_PER_TILE, n_packed), lambda i: (i, 0)),
            pl.BlockSpec((ROWS_PER_TILE, 1), lambda i: (i, 0)),
            pl.BlockSpec((ROWS_PER_TILE, 1), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((ROWS_PER_TILE, bucket), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((nb, bucket), dtype),
        interpret=interpret,
    )(codes, scale, zero)


def _dequantize_kernel(out_dtype, codes_ref, scale_ref, zero_ref, out_ref):
    c = codes_ref[...].astype(jnp.float32)
    out_ref[...] = (c * scale_ref[...] + zero_ref[...]).astype(out_dtype)


def dequantize_pallas(
    codes: jax.Array,
    scale: jax.Array,
    zero: jax.Array,
    dtype=jnp.float32,
    *,
    interpret: bool = True,
) -> jax.Array:
    """(nb, bucket) u8 codes + (nb, 1) affine -> (nb, bucket) values."""
    nb, bucket = codes.shape
    assert nb % ROWS_PER_TILE == 0, nb
    grid = (nb // ROWS_PER_TILE,)
    kern = functools.partial(_dequantize_kernel, dtype)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((ROWS_PER_TILE, bucket), lambda i: (i, 0)),
            pl.BlockSpec((ROWS_PER_TILE, 1), lambda i: (i, 0)),
            pl.BlockSpec((ROWS_PER_TILE, 1), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((ROWS_PER_TILE, bucket), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((nb, bucket), dtype),
        interpret=interpret,
    )(codes, scale, zero)
