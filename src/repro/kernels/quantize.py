"""Pallas TPU kernels for bucketed quantize / dequantize.

These are QSDP's compute hot-spots: every all-gather quantizes the local
shard and every receiver dequantizes P shards, at every layer, twice per
step (fwd + bwd re-gather) plus once for the gradient reduce-scatter.  On
GPU the paper implements these inside CGX as CUDA kernels; here they are
TPU-native Pallas kernels:

  * the bucket axis (1024 values) is the 128-lane minor dimension times 8
    sublanes, i.e. one bucket == one full (8, 128) f32 VREG tile — min/max
    reductions over a bucket are intra-tile and cheap on the VPU;
  * a block of ROWS_PER_TILE buckets is staged in VMEM per grid step;
  * randomness for stochastic rounding enters as a pre-generated uniform
    array (same PRNG stream as the jnp reference, so tests are exact).

Validated in interpret mode on CPU against `ref.py` (bit-exact for codes).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

ROWS_PER_TILE = 8


def _quantize_kernel(levels: int, stochastic: bool, x_ref, rand_ref, codes_ref, scale_ref, zero_ref):
    x = x_ref[...]  # (R, bucket) f32
    lo = jnp.min(x, axis=1, keepdims=True)
    hi = jnp.max(x, axis=1, keepdims=True)
    scale = jnp.maximum((hi - lo) / levels, 1e-12)
    v = (x - lo) / scale
    if stochastic:
        f = jnp.floor(v)
        codes = f + (rand_ref[...] < (v - f)).astype(v.dtype)
    else:
        codes = jnp.round(v)
    codes_ref[...] = jnp.clip(codes, 0, levels).astype(jnp.uint8)
    scale_ref[...] = scale
    zero_ref[...] = lo


def quantize_pallas(
    x: jax.Array,
    rand: jax.Array,
    levels: int,
    stochastic: bool,
    *,
    interpret: bool = True,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """x, rand: (nb, bucket) f32 with nb % ROWS_PER_TILE == 0 (pad upstream).

    Returns (codes u8 (nb, bucket), scale f32 (nb, 1), zero f32 (nb, 1)).
    """
    nb, bucket = x.shape
    assert nb % ROWS_PER_TILE == 0, nb
    grid = (nb // ROWS_PER_TILE,)
    kern = functools.partial(_quantize_kernel, levels, stochastic)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((ROWS_PER_TILE, bucket), lambda i: (i, 0)),
            pl.BlockSpec((ROWS_PER_TILE, bucket), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((ROWS_PER_TILE, bucket), lambda i: (i, 0)),
            pl.BlockSpec((ROWS_PER_TILE, 1), lambda i: (i, 0)),
            pl.BlockSpec((ROWS_PER_TILE, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nb, bucket), jnp.uint8),
            jax.ShapeDtypeStruct((nb, 1), jnp.float32),
            jax.ShapeDtypeStruct((nb, 1), jnp.float32),
        ],
        interpret=interpret,
    )(x, rand)


def _dequantize_kernel(out_dtype, codes_ref, scale_ref, zero_ref, out_ref):
    c = codes_ref[...].astype(jnp.float32)
    out_ref[...] = (c * scale_ref[...] + zero_ref[...]).astype(out_dtype)


def dequantize_pallas(
    codes: jax.Array,
    scale: jax.Array,
    zero: jax.Array,
    dtype=jnp.float32,
    *,
    interpret: bool = True,
) -> jax.Array:
    """(nb, bucket) u8 codes + (nb, 1) affine -> (nb, bucket) values."""
    nb, bucket = codes.shape
    assert nb % ROWS_PER_TILE == 0, nb
    grid = (nb // ROWS_PER_TILE,)
    kern = functools.partial(_dequantize_kernel, dtype)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((ROWS_PER_TILE, bucket), lambda i: (i, 0)),
            pl.BlockSpec((ROWS_PER_TILE, 1), lambda i: (i, 0)),
            pl.BlockSpec((ROWS_PER_TILE, 1), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((ROWS_PER_TILE, bucket), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((nb, bucket), dtype),
        interpret=interpret,
    )(codes, scale, zero)
