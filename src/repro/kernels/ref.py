"""Pure-jnp oracles for the Pallas kernels.

Each function is the semantic ground truth a kernel must reproduce
(tests assert allclose against these across shape/dtype sweeps).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def quantize_ref(
    x: jax.Array, rand: jax.Array, levels: int, stochastic: bool
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Bucketed min-max quantization of a (nb, bucket) f32 array.

    rand: (nb, bucket) uniforms in [0, 1) used when `stochastic`.
    Returns (codes u8 (nb, bucket), scale f32 (nb, 1), zero f32 (nb, 1)).
    """
    lo = jnp.min(x, axis=1, keepdims=True)
    hi = jnp.max(x, axis=1, keepdims=True)
    scale = jnp.maximum((hi - lo) * (1.0 / levels), 1e-12)
    v = (x - lo) / scale
    if stochastic:
        f = jnp.floor(v)
        codes = f + (rand < (v - f)).astype(v.dtype)
    else:
        codes = jnp.round(v)
    codes = jnp.clip(codes, 0, levels).astype(jnp.uint8)
    return codes, scale, lo


def dequantize_ref(
    codes: jax.Array, scale: jax.Array, zero: jax.Array, dtype=jnp.float32
) -> jax.Array:
    """(nb, bucket) u8 codes + per-bucket affine -> values."""
    return (codes.astype(jnp.float32) * scale + zero).astype(dtype)


def rowquant_matmul_ref(
    x: jax.Array, codes: jax.Array, scale: jax.Array, zero: jax.Array
) -> jax.Array:
    """y = x @ dequant(W) with per-(K-row, N-segment) affine quantized W.

    x: (M, K) f32/bf16; codes: (K, N) u8; scale/zero: (K, n_seg) f32 with
    N % n_seg == 0 (n_seg == 1 is plain per-K-row affine).
    dequant(W)[k, n] = codes[k, n] * scale[k, n // (N/n_seg)] + zero[...].
    """
    n = codes.shape[1]
    n_seg = scale.shape[1]
    if n_seg > 1:
        scale = jnp.repeat(scale, n // n_seg, axis=1)
        zero = jnp.repeat(zero, n // n_seg, axis=1)
    w = codes.astype(jnp.float32) * scale + zero
    return (x.astype(jnp.float32) @ w).astype(x.dtype)


def quantize_rowwise_ref(w: jax.Array, levels: int) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Per-row (per input channel) min-max quantization of a (K, N) matrix —
    the layout consumed by the fused dequant-matmul kernel."""
    lo = jnp.min(w, axis=1, keepdims=True)
    hi = jnp.max(w, axis=1, keepdims=True)
    scale = jnp.maximum((hi - lo) * (1.0 / levels), 1e-12)
    codes = jnp.clip(jnp.round((w - lo) / scale), 0, levels).astype(jnp.uint8)
    return codes, scale, lo
