import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede every other import: jax locks the device count on first init.
"""Multi-pod dry-run: lower + compile every (architecture x input-shape)
step on the production mesh, prove it partitions, and extract the roofline
terms from the compiled artifact.

  python -m repro.launch.dryrun --arch yi-6b --shape train_4k [--multi-pod]
  python -m repro.launch.dryrun --all --out results/dryrun

Per pair this prints (and optionally JSON-dumps):
  * compiled.memory_analysis()  — proves the step fits per-device HBM
  * compiled.cost_analysis()    — per-device FLOPs / bytes
  * parsed collective wire bytes (roofline's collective term)
"""
import argparse
import json
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from .. import configs
from ..compat import shard_map
from ..configs.inputs import input_specs
from ..core.qsdp import QSDPConfig
from ..models.config import SHAPES
from ..models.decode import DecodeModel, make_decode_spec
from ..models.transformer import Model
from ..optim import AdamWConfig, make_adamw
from ..roofline import HW_V5E, collective_bytes_from_hlo, roofline
from ..train.step import build_train_step, state_pspecs
from .mesh import make_mesh_spec, make_production_mesh

# decode shapes are skipped for archs where they do not apply; none of the
# ten assigned archs skip anything (DESIGN.md §5): dense archs run long_500k
# via their sliding-window cache, SSM/hybrid natively.
DEFAULT_QSDP = dict(weight_bits=8, grad_bits=8, bucket_size=1024)


def build_step(arch: str, shape_name: str, multi_pod: bool, qsdp: QSDPConfig,
               n_micro: int | None = None):
    """Returns (fn, arg_structs) ready for jax.jit(fn).lower(*arg_structs)."""
    ms = make_mesh_spec(multi_pod=multi_pod)
    mesh = make_production_mesh(multi_pod=multi_pod)
    cfg = configs.get_config(arch)
    shape = SHAPES[shape_name]
    model = Model(cfg, ms, qsdp)
    kind, structs, specs = input_specs(model, shape)

    if kind == "train":
        if n_micro is None:
            n_micro = max(1, shape.global_batch // ms.fsdp_size)  # 1-row microbatches
        opt = make_adamw(AdamWConfig())
        step = build_train_step(model, opt, n_micro=n_micro)
        sspec = state_pspecs(model)
        params_struct = {
            name: jax.ShapeDtypeStruct(spec.rest_shape(ms), jnp.float32)
            for name, spec in model.specs.items()
        }
        from ..optim import OptState
        from ..train.step import TrainState
        state_struct = TrainState(
            params=params_struct,
            opt=OptState(step=jax.ShapeDtypeStruct((), jnp.int32),
                         mu=params_struct, nu=params_struct),
        )
        batch_struct, key_struct = structs
        batch_spec, key_spec = specs
        fn = shard_map(step, mesh=mesh,
                           in_specs=(sspec, batch_spec, key_spec),
                           out_specs=(sspec, {"loss": P(), "grad_norm": P(), "step": P()}),
                           check_vma=False)
        return fn, (state_struct, batch_struct, key_struct), mesh, model

    dspec = make_decode_spec(model, shape)
    dm = DecodeModel(model, dspec)
    pspecs = model.param_pspecs()
    params_struct = {
        name: jax.ShapeDtypeStruct(spec.rest_shape(ms), jnp.float32)
        for name, spec in model.specs.items()
    }
    bax = ms.fsdp_axes if dspec.batch_sharded else None

    if kind == "prefill":
        batch_struct, key_struct = structs
        batch_spec, key_spec = specs
        _, cache_specs = dm.cache_struct()
        fn = shard_map(dm.prefill_fn, mesh=mesh,
                           in_specs=(pspecs, batch_spec, key_spec),
                           out_specs=(P(bax), cache_specs),
                           check_vma=False)
        return fn, (params_struct, batch_struct, key_struct), mesh, model

    # decode
    cache_structs, tok, pos, key_struct = structs
    cache_specs, tok_spec, pos_spec, key_spec = specs
    fn = shard_map(dm.decode_fn, mesh=mesh,
                       in_specs=(pspecs, cache_specs, tok_spec, pos_spec, key_spec),
                       out_specs=(tok_spec, cache_specs),
                       check_vma=False)
    return fn, (params_struct, cache_structs, tok, pos, key_struct), mesh, model


def run_one(arch: str, shape_name: str, multi_pod: bool = False,
            qsdp: QSDPConfig | None = None, verbose: bool = True,
            n_micro: int | None = None, hlo_dir: str | None = None,
            tag: str = "") -> dict:
    qsdp = qsdp or QSDPConfig(**DEFAULT_QSDP)
    mesh_name = "2x16x16" if multi_pod else "16x16"
    n_chips = 512 if multi_pod else 256
    t0 = time.time()
    fn, arg_structs, mesh, model = build_step(arch, shape_name, multi_pod, qsdp,
                                              n_micro=n_micro)
    # donate the mutable state (TrainState / decode cache) so XLA may alias
    # buffers in place — matches how the real launchers jit these steps.
    donate = (0,) if SHAPES[shape_name].kind == "train" else (
        (1,) if SHAPES[shape_name].kind == "decode" else ())
    lowered = jax.jit(fn, donate_argnums=donate).lower(*arg_structs)
    t_lower = time.time() - t0
    compiled = lowered.compile()
    t_compile = time.time() - t0 - t_lower

    cost = compiled.cost_analysis() or {}
    try:
        mem = compiled.memory_analysis()
        peak = getattr(mem, "temp_size_in_bytes", None)
        arg_b = getattr(mem, "argument_size_in_bytes", None)
        out_b = getattr(mem, "output_size_in_bytes", None)
    except Exception:
        mem, peak, arg_b, out_b = None, None, None, None
    hlo = compiled.as_text()
    if hlo_dir:
        import gzip
        os.makedirs(hlo_dir, exist_ok=True)
        name = f"{tag + '_' if tag else ''}{arch}_{shape_name}_{mesh_name}.hlo.gz"
        with gzip.open(os.path.join(hlo_dir, name), "wt") as f:
            f.write(hlo)

    shape = SHAPES[shape_name]
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        mf = 6.0 * model.cfg.n_active_params() * tokens
    elif shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        mf = 2.0 * model.cfg.n_active_params() * tokens
    else:  # decode: one token per sequence
        tokens = shape.global_batch
        mf = 2.0 * model.cfg.n_active_params() * tokens

    rep = roofline(arch, shape_name, mesh_name, cost, hlo, n_chips, mf,
                   HW_V5E, peak_memory=peak)
    result = rep.to_dict()
    result.update(
        ok=True, t_lower_s=t_lower, t_compile_s=t_compile,
        memory=dict(temp=peak, args=arg_b, out=out_b),
        qsdp=dict(w=qsdp.weight_bits if qsdp.quantize_weights else "fp32",
                  g=qsdp.grad_bits if qsdp.quantize_grads else "bf16",
                  hierarchical=qsdp.hierarchical),
    )
    if verbose:
        print(rep.summary())
        print(f"  lower {t_lower:.1f}s compile {t_compile:.1f}s  "
              f"mem(temp)={_fmt(peak)} args={_fmt(arg_b)}  "
              f"coll={_fmt(result['collective_bytes'])} "
              f"({result['collectives']['counts']})")
    return result


def _fmt(b):
    if b is None:
        return "n/a"
    for u in ("B", "KB", "MB", "GB", "TB"):
        if b < 1024:
            return f"{b:.1f}{u}"
        b /= 1024
    return f"{b:.1f}PB"


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", type=str, default=None)
    ap.add_argument("--shape", type=str, default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--baseline-fsdp", action="store_true",
                    help="lower the unquantized FSDP baseline instead of QSDP")
    ap.add_argument("--hierarchical", action="store_true")
    ap.add_argument("--bits", type=int, default=8)
    ap.add_argument("--out", type=str, default=None)
    args = ap.parse_args()

    if args.baseline_fsdp:
        qsdp = QSDPConfig.baseline()
    else:
        qsdp = QSDPConfig(weight_bits=args.bits, grad_bits=args.bits,
                          hierarchical=args.hierarchical)

    archs = configs.ASSIGNED if args.all else [args.arch]
    shapes = list(SHAPES) if args.all or args.shape is None else [args.shape]
    meshes = [False, True] if (args.both_meshes or args.all) else [args.multi_pod]

    results = []
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                tag = f"{arch} x {shape} x {'2x16x16' if mp else '16x16'}"
                try:
                    results.append(run_one(arch, shape, mp, qsdp))
                except Exception as e:
                    print(f"FAIL {tag}: {e}")
                    traceback.print_exc()
                    results.append(dict(arch=arch, shape=shape,
                                        mesh="2x16x16" if mp else "16x16",
                                        ok=False, error=str(e)))
    if args.out:
        os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
        with open(args.out, "w") as f:
            json.dump(results, f, indent=1)
        print(f"wrote {args.out}")
    n_ok = sum(r.get("ok") for r in results)
    print(f"{n_ok}/{len(results)} pairs lowered+compiled OK")
    return 0 if n_ok == len(results) else 1


if __name__ == "__main__":
    raise SystemExit(main())
