"""Production mesh construction.

Target hardware: TPU v5e pods — 256 chips/pod (16x16), 2 pods for the
multi-pod dry-run.  Defined as functions (never module-level constants) so
importing this module never touches jax device state.
"""
from __future__ import annotations

import jax

from ..core.qsdp import MeshSpec


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh_spec(*, multi_pod: bool = False) -> MeshSpec:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return MeshSpec(axes=axes, shape=shape)


def make_small_mesh(data: int = 2, model: int = 4):
    """Test/CI mesh (requires xla_force_host_platform_device_count >= d*m)."""
    return jax.make_mesh((data, model), ("data", "model"))


def make_small_spec(data: int = 2, model: int = 4) -> MeshSpec:
    return MeshSpec(axes=("data", "model"), shape=(data, model))
