"""Serving launcher: batched greedy generation with QSDP weight gathers.

  XLA_FLAGS=--xla_force_host_platform_device_count=8 \
  PYTHONPATH=src python -m repro.launch.serve --arch gpt-125m --smoke \
      --batch 8 --prompt-len 32 --gen 16 --data-par 2 --model-par 4
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from .. import configs
from ..core.qsdp import MeshSpec, QSDPConfig
from ..data import SyntheticLM
from ..models.decode import DecodeSpec
from ..models.transformer import Model
from ..serve import ServeEngine


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gpt-125m")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--data-par", type=int, default=1)
    ap.add_argument("--model-par", type=int, default=1)
    ap.add_argument("--baseline", action="store_true")
    ap.add_argument("--wbits", type=int, default=8)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    mesh = jax.make_mesh((args.data_par, args.model_par), ("data", "model"))
    ms = MeshSpec(axes=("data", "model"), shape=(args.data_par, args.model_par))
    cfg = configs.get_smoke(args.arch) if args.smoke else configs.get_config(args.arch)
    qsdp = QSDPConfig.baseline() if args.baseline else QSDPConfig(weight_bits=args.wbits)
    model = Model(cfg, ms, qsdp)
    params = model.init_params(jax.random.PRNGKey(args.seed))

    ring = args.prompt_len + args.gen
    ring += (-ring) % args.model_par
    spec = DecodeSpec(
        cache_len=0 if cfg.arch_type == "ssm" else ring,
        batch_global=args.batch,
        batch_sharded=args.batch % ms.fsdp_size == 0,
        enc_len=max(args.prompt_len // cfg.enc_frames_ratio, args.model_par)
        if cfg.arch_type == "audio" else 0,
    )
    eng = ServeEngine(model, mesh, spec)

    data = SyntheticLM(vocab_size=cfg.vocab_size, seq_len=args.prompt_len,
                       global_batch=args.batch, seed=args.seed)
    tokens, _ = data.sample(0)
    bax = ms.fsdp_axes if spec.batch_sharded else None
    prompt = {"tokens": tokens}
    pspecs = {"tokens": P(bax)}
    if cfg.arch_type == "vlm":
        b, s = tokens.shape
        prompt["vision_embeds"] = jnp.zeros((b, s, cfg.d_model), jnp.bfloat16)
        prompt["vision_mask"] = jnp.zeros((b, s), bool)
        prompt["positions"] = jnp.broadcast_to(jnp.arange(s), (3, b, s))
        pspecs.update(vision_embeds=P(bax), vision_mask=P(bax), positions=P(None, bax))
    if cfg.arch_type == "audio":
        prompt["audio_embeds"] = 0.1 * jax.random.normal(
            jax.random.PRNGKey(1), (args.batch, spec.enc_len, cfg.d_model), jnp.bfloat16)
        pspecs["audio_embeds"] = P(bax)

    t0 = time.time()
    with mesh:
        out = eng.generate(params, prompt, pspecs, n_tokens=args.gen)
    out.block_until_ready()
    dt = time.time() - t0
    print(f"# {cfg.name} generated {args.batch}x{args.gen} tokens in {dt:.2f}s "
          f"({args.batch * args.gen / dt:.1f} tok/s incl. compile)")
    print("sample:", out[0].tolist())
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
