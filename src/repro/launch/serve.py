"""Serving launcher: batched generation with QSDP weight gathers.

One-shot batch mode (prefill one batch, decode to completion):

  XLA_FLAGS=--xla_force_host_platform_device_count=8 \\
  PYTHONPATH=src python -m repro.launch.serve --arch gpt-125m --smoke \\
      --batch 8 --prompt-len 32 --gen 16 --data-par 2 --model-par 4

Continuous-batching mode (--continuous): a request queue drained through
serve.ContinuousScheduler — a fixed pool of --batch decode slots, requests
admitted into freed slots mid-decode, per-request sampling:

  PYTHONPATH=src python -m repro.launch.serve --arch gpt-125m --smoke \\
      --continuous --batch 4 --requests 16 --gen 16 --temperature 0.8 --top-k 40
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from ..core.qsdp import QSDPConfig
from ..data import SyntheticLM
from ..serve import (Request, build_serve_setup, make_prompt_batch,
                     make_scheduler)


def _build_parser():
    ap = argparse.ArgumentParser()
    ap.add_argument("--plan", type=str, default=None,
                    help="DeploymentPlan JSON from repro.tune.autotune — "
                         "supplies the QSDP comm policy and the serve-knob "
                         "defaults (explicit flags still override knobs)")
    ap.add_argument("--arch", default="gpt-125m")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=8,
                    help="batch size (one-shot) / decode-slot pool size "
                         "(--continuous)")
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--data-par", type=int, default=1)
    ap.add_argument("--model-par", type=int, default=1)
    ap.add_argument("--baseline", action="store_true")
    ap.add_argument("--wbits", type=int, default=8)
    ap.add_argument("--seed", type=int, default=0)
    # continuous-batching flags
    ap.add_argument("--continuous", action="store_true",
                    help="serve a request queue through the "
                         "continuous-batching scheduler")
    ap.add_argument("--requests", type=int, default=16,
                    help="--continuous: number of queued requests")
    ap.add_argument("--temperature", type=float, default=0.0,
                    help="--continuous: per-request sampling temperature "
                         "(0 = greedy)")
    ap.add_argument("--top-k", type=int, default=0,
                    help="--continuous: per-request top-k (0 = full vocab)")
    ap.add_argument("--prefill-chunk", type=int, default=0,
                    help="--continuous: prefill at most this many prompt "
                         "tokens per scheduler step (0 = blocking "
                         "whole-prompt admission)")
    ap.add_argument("--prefill-buckets", type=int, default=4,
                    help="--continuous: chunk length buckets — bounds the "
                         "chunked-prefill jit cache at this many traces")
    ap.add_argument("--prefill-interleave", type=int, default=1,
                    help="--continuous: chunk launches per scheduler step "
                         "(fairness knob; 1 = maximally decode-fair)")
    # paged KV pool flags
    ap.add_argument("--kv-block-size", type=int, default=0,
                    help="paged KV pool block size in tokens (0 = per-slot "
                         "ring; > 0 requires --prefill-chunk and enables "
                         "prefix sharing)")
    ap.add_argument("--kv-pool-blocks", type=int, default=0,
                    help="physical KV blocks in the pool (0 = one full "
                         "logical window per slot)")
    ap.add_argument("--kv-quant-bits", type=int, default=0,
                    help="--continuous paged: re-encode idle cached prefix "
                         "blocks into the core.quant wire format at this "
                         "bit width (0 = cold tier off)")
    ap.add_argument("--kv-quant-horizon", type=int, default=64,
                    help="--continuous paged: idle scheduler steps before a "
                         "cached block demotes to the cold tier")
    # self-speculative decoding flags
    ap.add_argument("--draft-bits", type=int, default=0,
                    help="--continuous: bit width of the self-speculative "
                         "draft forward (0 = off; 2-4 typical) — the draft "
                         "re-quantizes the SAME weights, no second model")
    ap.add_argument("--draft-depth", type=int, default=0,
                    help="--continuous: draft up to this many tokens per "
                         "slot per step, batch-verified in one "
                         "serving-precision launch (<= 1 = off)")
    return ap


# plan serve-section field -> launcher flag dest
_PLAN_SERVE_DESTS = {
    "slots": "batch", "prefill_chunk": "prefill_chunk",
    "prefill_buckets": "prefill_buckets",
    "prefill_interleave": "prefill_interleave",
    "kv_block_size": "kv_block_size", "kv_pool_blocks": "kv_pool_blocks",
    "kv_quant_bits": "kv_quant_bits", "kv_quant_horizon": "kv_quant_horizon",
    "draft_bits": "draft_bits", "draft_depth": "draft_depth",
}


def validate_args(ap, args) -> None:
    """Reject inconsistent flag combos at parse time — failing here with a
    one-line reason beats failing deep inside tracing."""
    if not 2 <= args.wbits <= 8:
        ap.error(f"--wbits must be in 2..8 (got {args.wbits})")
    if args.draft_bits and not 2 <= args.draft_bits <= 8:
        ap.error(f"--draft-bits must be 0 (off) or in 2..8 (got "
                 f"{args.draft_bits}) — the draft re-quantizes the serving "
                 f"weights through the 2-8 bit wire kernels")
    if args.kv_quant_bits and not 2 <= args.kv_quant_bits <= 8:
        ap.error(f"--kv-quant-bits must be 0 (off) or in 2..8 "
                 f"(got {args.kv_quant_bits})")
    if args.prefill_buckets < 1:
        ap.error(f"--prefill-buckets must be >= 1 (got "
                 f"{args.prefill_buckets})")
    if min(args.prefill_chunk, args.kv_block_size, args.kv_pool_blocks,
           args.prefill_interleave - 1) < 0:
        ap.error("--prefill-chunk/--kv-block-size/--kv-pool-blocks must be "
                 ">= 0 and --prefill-interleave >= 1")
    if args.kv_block_size and not args.prefill_chunk:
        ap.error("--kv-block-size requires --prefill-chunk (paged serving "
                 "admits through chunked prefill)")
    if args.kv_quant_bits and not args.kv_block_size:
        ap.error("--kv-quant-bits requires --kv-block-size (the cold tier "
                 "demotes paged pool blocks)")
    if (args.draft_bits > 0) != (args.draft_depth > 1):
        ap.error("speculative decode needs BOTH --draft-bits >= 2 and "
                 "--draft-depth >= 2")
    if args.draft_depth > 1 and not args.continuous:
        ap.error("--draft-depth requires --continuous (speculation lives in "
                 "the scheduler's draft/verify phases)")
    if args.plan and args.baseline:
        ap.error("--plan pins the QSDP comm policy; don't combine it with "
                 "--baseline")


def parse_args(argv=None):
    ap = _build_parser()
    args = ap.parse_args(argv)
    args.plan_obj = None
    if args.plan:
        from ..tune.plan import DeploymentPlan
        try:
            plan = DeploymentPlan.load(args.plan)
        except (OSError, ValueError) as e:
            ap.error(f"--plan {args.plan}: {e}")
        # the plan's serve section provides the DEFAULTS; flags the user
        # typed still win (argparse re-parse with updated defaults)
        knobs = plan.serve_knobs()
        ap.set_defaults(**{_PLAN_SERVE_DESTS[k]: v for k, v in knobs.items()
                           if k in _PLAN_SERVE_DESTS})
        args = ap.parse_args(argv)
        args.plan_obj = plan
    validate_args(ap, args)
    return args


def run_continuous(setup, args) -> int:
    rng = np.random.default_rng(args.seed)
    sched = make_scheduler(
        setup, gather_key=jax.random.PRNGKey(args.seed),
        prefill_chunk=args.prefill_chunk,
        prefill_buckets=args.prefill_buckets,
        prefill_interleave=args.prefill_interleave,
        kv_quant_bits=args.kv_quant_bits if args.kv_block_size else 0,
        kv_quant_horizon=args.kv_quant_horizon)
    # mixed prompt/gen lengths, seeded: realistic heavy-traffic shape
    for i in range(args.requests):
        plen = int(rng.integers(max(args.prompt_len // 2, 1), args.prompt_len + 1))
        gen = int(rng.integers(max(args.gen // 2, 1), args.gen + 1))
        sched.submit(Request(
            rid=f"req{i}", prompt=rng.integers(0, setup.cfg.vocab_size,
                                               size=plen).tolist(),
            max_new_tokens=gen, temperature=args.temperature,
            top_k=args.top_k, seed=args.seed + i))
    t0 = time.time()
    done = sched.run()
    dt = time.time() - t0
    st = sched.stats()
    lat = [c.finish_step - c.submit_step for c in done.values()]
    ttft = [c.first_token_time - c.submit_time for c in done.values()]
    print(f"# {setup.cfg.name} continuous: {len(done)} requests, "
          f"{st['tokens_generated']} tokens in {dt:.2f}s "
          f"({st['tokens_generated'] / dt:.1f} tok/s incl. compile), "
          f"occupancy {st['mean_occupancy']:.2f}/{st['slots']}, "
          f"latency p50={np.percentile(lat, 50):.0f} "
          f"p95={np.percentile(lat, 95):.0f} steps, "
          f"ttft p95={np.percentile(ttft, 95):.3f}s")
    if args.prefill_chunk:
        print(f"# chunked prefill: chunk={args.prefill_chunk} "
              f"buckets={sched.buckets} -> {st['prefill_chunks']} chunk "
              f"launches, {st['prefill_traces']} compiled prefill shapes")
    if sched.pool is not None:
        print(f"# paged KV pool: {st['blocks_total']} blocks x "
              f"{setup.spec.kv_block_size} tok, prefix hit rate "
              f"{st['prefix_hit_rate']:.2f}, cow forks {st['cow_forks']}, "
              f"cold blocks {st['cold_blocks']} "
              f"(effective capacity {st['effective_capacity']:.0f} blocks)")
    if setup.spec.speculative:
        print(f"# speculative: draft {setup.spec.draft_bits}-bit x depth "
              f"{setup.spec.draft_depth} -> accepted/launch "
              f"{st['accepted_per_launch']:.2f}, launches/token "
              f"{st['launches_per_token']:.2f}, draft overhead "
              f"{st['draft_overhead']:.2f} draft lane-steps/token")
    print(f"# decode-step weight gathers = "
          f"{setup.decode_gather_bytes() / 2**20:.2f} MiB/device")
    first = done[sorted(done)[0]]
    print("sample:", first.tokens.tolist())
    return 0


def run_batch(setup, args) -> int:
    data = SyntheticLM(vocab_size=setup.cfg.vocab_size, seq_len=args.prompt_len,
                       global_batch=args.batch, seed=args.seed)
    tokens, _ = data.sample(0)
    prompt, pspecs = make_prompt_batch(setup.cfg, setup.spec, setup.ms, tokens)
    kw = {}
    if setup.spec.paged:
        # paged serving admits through chunked prefill, which serves a
        # FIXED quantized model (one gather key)
        kw = dict(prefill_chunk=args.prefill_chunk, fold_step_keys=False)
    t0 = time.time()
    with setup.mesh:
        out = setup.engine.generate(setup.params, prompt, pspecs,
                                    n_tokens=args.gen, **kw)
    out.block_until_ready()
    dt = time.time() - t0
    print(f"# {setup.cfg.name} generated {args.batch}x{args.gen} tokens in {dt:.2f}s "
          f"({args.batch * args.gen / dt:.1f} tok/s incl. compile)")
    print("sample:", out[0].tolist())
    return 0


def main(argv=None):
    args = parse_args(argv)
    if args.plan_obj is not None:
        try:
            args.plan_obj.validate_mesh(("data", "model"),
                                        (args.data_par, args.model_par))
            qsdp = args.plan_obj.to_qsdp_config(QSDPConfig())
        except ValueError as e:
            raise SystemExit(f"--plan {args.plan}: {e}")
    elif args.baseline:
        qsdp = QSDPConfig.baseline()
    else:
        qsdp = QSDPConfig(weight_bits=args.wbits)
    setup = build_serve_setup(
        args.arch, data_par=args.data_par, model_par=args.model_par,
        smoke=args.smoke, qsdp=qsdp, batch=args.batch,
        prompt_len=args.prompt_len, gen=args.gen, seed=args.seed,
        sampling=args.continuous and (args.temperature > 0 or args.top_k > 1),
        kv_block_size=args.kv_block_size,
        kv_pool_blocks=args.kv_pool_blocks,
        draft_bits=args.draft_bits, draft_depth=args.draft_depth)
    if args.continuous:
        return run_continuous(setup, args)
    return run_batch(setup, args)


if __name__ == "__main__":
    raise SystemExit(main())
