"""Training launcher (single-host; emulated multi-device CPU mesh or real
TPU slice — the same code path).

  XLA_FLAGS=--xla_force_host_platform_device_count=8 \
  PYTHONPATH=src python -m repro.launch.train --arch gpt-125m --smoke \
      --steps 100 --data-par 2 --model-par 4 --wbits 8 --gbits 8

Uses the deterministic synthetic Markov LM corpus (repro.data) so loss
curves are meaningful and exactly reproducible.
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from .. import configs
from ..core.qsdp import MeshSpec, QSDPConfig
from ..data import SyntheticLM, make_batch
from ..models.transformer import Model
from ..optim import AdamWConfig, cosine_schedule, make_adamw
from ..train.checkpoint import save_checkpoint
from ..train.step import (
    init_train_state,
    make_jitted_train_step,
    quantize_train_state,
)


def build_qsdp(args) -> QSDPConfig:
    if args.plan:
        from ..tune.plan import DeploymentPlan
        try:
            plan = DeploymentPlan.load(args.plan)
            plan.validate_mesh(("data", "model"),
                               (args.data_par, args.model_par))
            return plan.to_qsdp_config(QSDPConfig())
        except (OSError, ValueError) as e:
            raise SystemExit(f"--plan {args.plan}: {e}")
    if args.baseline:
        return QSDPConfig.baseline()
    return QSDPConfig(
        weight_bits=args.wbits, grad_bits=args.gbits,
        bucket_size=args.bucket, min_quant_size=args.min_quant_size,
        hierarchical=args.hierarchical,
        coalesce=args.coalesce, prefetch=args.prefetch,
        coalesce_max_bytes=args.coalesce_max_bytes,
    )


def validate_args(ap: argparse.ArgumentParser, args) -> None:
    """Reject inconsistent flag combos at parse time — tracing errors deep
    inside shard_map are unreadable; these are not."""
    if args.prefetch and not args.coalesce:
        ap.error("--prefetch requires coalescing (the prefetch pipeline "
                 "carries the coalesced u8 wire buffer through the scan); "
                 "drop --no-coalesce")
    for flag, v in (("--wbits", args.wbits), ("--gbits", args.gbits),
                    ("--master-bits", args.master_bits)):
        if not 2 <= v <= 8:
            ap.error(f"{flag} must be in 2..8 (got {v}) — the wire format "
                     f"packs 2-8 bit codes")
    if args.moment_bits is not None and not 2 <= args.moment_bits <= 8:
        ap.error(f"--moment-bits must be in 2..8 (got {args.moment_bits})")
    if args.bucket <= 0:
        ap.error(f"--bucket must be positive (got {args.bucket})")
    if args.coalesce_max_bytes is not None and args.coalesce_max_bytes < 0:
        ap.error("--coalesce-max-bytes must be >= 0 (0 = never coalesce)")
    if args.data_par < 1 or args.model_par < 1:
        ap.error("--data-par/--model-par must be >= 1")
    if args.quantize_master and args.quantized_state:
        ap.error("--quantize-master (QDQ f32 state) and --quantized-state "
                 "(wire-code state) are mutually exclusive")
    if args.plan and any([args.baseline, args.hierarchical,
                          args.coalesce_max_bytes is not None,
                          args.prefetch, not args.coalesce]):
        ap.error("--plan pins the comm policy; don't combine it with "
                 "--baseline/--hierarchical/--coalesce-max-bytes/--prefetch/"
                 "--no-coalesce")


def parse_args(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gpt-125m")
    ap.add_argument("--smoke", action="store_true", help="reduced config")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--warmup", type=int, default=10)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--n-micro", type=int, default=1)
    ap.add_argument("--lr", type=float, default=6e-4)
    ap.add_argument("--data-par", type=int, default=1)
    ap.add_argument("--model-par", type=int, default=1)
    ap.add_argument("--baseline", action="store_true", help="FSDP fp baseline")
    ap.add_argument("--wbits", type=int, default=8)
    ap.add_argument("--gbits", type=int, default=8)
    ap.add_argument("--bucket", type=int, default=1024)
    ap.add_argument("--min-quant-size", type=int, default=2048)
    ap.add_argument("--hierarchical", action="store_true")
    ap.add_argument("--coalesce", action=argparse.BooleanOptionalAction,
                    default=True,
                    help="coalesced wire format (one u8 collective per "
                         "layer gather)")
    ap.add_argument("--prefetch", action="store_true",
                    help="double-buffered layer prefetch (requires "
                         "coalescing)")
    ap.add_argument("--coalesce-max-bytes", type=int, default=None,
                    help="per-layer byte threshold: gathers whose coalesced "
                         "wire buffer exceeds this fall back to per-tensor "
                         "launches (None = always coalesce)")
    ap.add_argument("--plan", type=str, default=None,
                    help="DeploymentPlan JSON from repro.tune.autotune — "
                         "pins the whole comm policy instead of the "
                         "individual flags above")
    ap.add_argument("--quantize-master", action="store_true",
                    help="f32 state, QDQ-round-tripped through Q^w each step")
    ap.add_argument("--quantized-state", action="store_true",
                    help="theory-faithful quantized-domain state: master "
                         "weights rest as packed wire codes (QuantizedParam)")
    ap.add_argument("--master-bits", type=int, default=8)
    ap.add_argument("--moment-bits", type=int, default=None,
                    help="store Adam mu/nu as packed codes of this width")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--ckpt", type=str, default=None)
    ap.add_argument("--out-json", type=str, default=None)
    args = ap.parse_args(argv)
    validate_args(ap, args)
    return args


def main(argv=None):
    args = parse_args(argv)

    nd = args.data_par * args.model_par
    assert len(jax.devices()) >= nd, (len(jax.devices()), nd)
    mesh = jax.make_mesh((args.data_par, args.model_par), ("data", "model"))
    ms = MeshSpec(axes=("data", "model"), shape=(args.data_par, args.model_par))

    cfg = configs.get_smoke(args.arch) if args.smoke else configs.get_config(args.arch)
    qsdp = build_qsdp(args)
    model = Model(cfg, ms, qsdp)

    sched = cosine_schedule(args.lr, args.warmup, args.steps)
    opt = make_adamw(AdamWConfig(lr=args.lr, schedule=sched,
                                 moment_bits=args.moment_bits))
    state = init_train_state(model, opt, jax.random.PRNGKey(args.seed))
    if args.quantized_state:
        state = quantize_train_state(
            state, model, jax.random.PRNGKey(args.seed + 2),
            master_bits=args.master_bits)

    data = SyntheticLM(vocab_size=cfg.vocab_size, seq_len=args.seq,
                       global_batch=args.batch, seed=args.seed)
    step = make_jitted_train_step(model, opt, mesh, n_micro=args.n_micro,
                                  quantize_master=args.quantize_master,
                                  master_bits=args.master_bits,
                                  quantized_state=args.quantized_state)

    if args.plan:
        tag = (f"QSDP plan W{qsdp.weight_bits}G{qsdp.grad_bits} "
               f"coalesce<={qsdp.coalesce_max_bytes}B"
               if qsdp.coalesce_max_bytes is not None
               else f"QSDP plan W{qsdp.weight_bits}G{qsdp.grad_bits}")
    else:
        tag = "baseline-FSDP" if args.baseline else f"QSDP W{args.wbits}G{args.gbits}"
    if args.quantized_state:
        tag += f" qstate{args.master_bits}" + (
            f"m{args.moment_bits}" if args.moment_bits else "")
    print(f"# {cfg.name} [{tag}] mesh=({args.data_par},{args.model_par}) "
          f"batch={args.batch} seq={args.seq} params~{cfg.n_params()/1e6:.1f}M "
          f"bigram-floor={data.bigram_entropy():.3f} nats")
    log = []
    t0 = time.time()
    with mesh:
        for i in range(args.steps):
            batch = make_batch(data, i, mesh, ms.fsdp_axes)
            state, m = step(state, batch, jax.random.fold_in(jax.random.PRNGKey(args.seed + 1), i))
            if i % args.log_every == 0 or i == args.steps - 1:
                loss = float(m["loss"])
                log.append(dict(step=i, loss=loss, gnorm=float(m["grad_norm"]),
                                t=time.time() - t0))
                print(f"step {i:5d} loss {loss:7.4f} gnorm {log[-1]['gnorm']:8.3f} "
                      f"({log[-1]['t']:6.1f}s)")
    if args.ckpt:
        save_checkpoint(args.ckpt, state, meta=dict(arch=cfg.name, steps=args.steps))
        print(f"checkpoint -> {args.ckpt}")
    if args.out_json:
        with open(args.out_json, "w") as f:
            json.dump(dict(arch=cfg.name, tag=tag, log=log), f, indent=1)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
