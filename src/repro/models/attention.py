"""Attention: GQA with tensor parallelism, flash (chunked) attention for
train/prefill, and sequence-sharded flash-decode for serving.

TP layout
---------
Query heads are padded to a multiple of the model-axis size (`n_heads_padded`;
e.g. qwen1.5-32b 40->48, yi-34b 56->64) and sharded contiguously; padded heads
are hard-masked to zero so they never contribute (their params receive zero
gradient, preserving the logical architecture exactly — see DESIGN.md §5).
KV heads are TP-sharded when `n_kv % tp == 0` ("tp" mode), otherwise the
KV projections are model-replicated ("replicated" mode) — the Megatron
convention for GQA ratios that do not divide.

Decode
------
The KV cache is sharded over the *model* axis along the sequence dim
(uniform across all GQA ratios).  Each rank computes partial attention of
all (gathered) query heads against its sequence chunk and the partials are
combined with a log-sum-exp psum — flash-decoding.  Sliding windows are
ring-buffered; slot validity is computed arithmetically from the step index
so no position book-keeping tensors are needed.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax

from ..core.tp import tp_copy, tp_reduce
from .layers import apply_rope

MODEL_AXIS = "model"


@dataclasses.dataclass(frozen=True)
class AttnConfig:
    n_heads: int  # logical query heads
    n_kv: int
    head_dim: int
    tp: int  # model-axis size
    causal: bool = True
    sliding_window: int = 0  # 0 = full
    q_chunk: int = 1024
    kv_chunk: int = 1024
    # feed the score/AV matmuls bf16 operands with f32 accumulation (MXU
    # native) instead of f32 operands — §Perf hillclimb knob; the baseline
    # stays f32 to match the unoptimized reference numerics.
    mxu_bf16: bool = False

    @property
    def n_heads_padded(self) -> int:
        return -(-self.n_heads // self.tp) * self.tp

    @property
    def heads_local(self) -> int:
        return self.n_heads_padded // self.tp

    @property
    def kv_mode(self) -> str:
        # TP the KV projections only when shards stay contiguous head blocks:
        # that requires no query-head padding and an integral per-rank group.
        ok = (
            self.n_kv % self.tp == 0
            and self.n_heads_padded == self.n_heads
            and self.n_heads % self.n_kv == 0
        )
        return "tp" if ok else "replicated"

    @property
    def kv_local(self) -> int:
        return self.n_kv // self.tp if self.kv_mode == "tp" else self.n_kv

    @property
    def group(self) -> int:
        # logical GQA group (query heads per kv head); padded query heads are
        # masked so their (clipped) kv index is irrelevant.
        return max(self.n_heads // self.n_kv, 1)


def _local_head_mask(cfg: AttnConfig) -> jax.Array:
    """(heads_local,) 1.0 for real heads, 0.0 for padding (per rank)."""
    rank = lax.axis_index(MODEL_AXIS)
    gidx = rank * cfg.heads_local + jnp.arange(cfg.heads_local)
    return (gidx < cfg.n_heads).astype(jnp.float32)


def _expand_kv_local(k: jax.Array, cfg: AttnConfig) -> jax.Array:
    """Map per-rank KV heads onto per-rank (local) query heads.

    k: (..., kv_local, hd) -> (..., heads_local, hd)
    """
    if cfg.kv_mode == "tp":
        # contiguous blocks: local q head j -> local kv head j // (group)
        reps = cfg.heads_local // max(cfg.kv_local, 1)
        if reps <= 0:  # more kv shards than q heads per rank cannot happen when mode == tp
            raise AssertionError((cfg.heads_local, cfg.kv_local))
        return jnp.repeat(k, reps, axis=-2)
    # replicated: index kv by global q head
    rank = lax.axis_index(MODEL_AXIS)
    gidx = rank * cfg.heads_local + jnp.arange(cfg.heads_local)
    kv_idx = jnp.clip(gidx // cfg.group, 0, cfg.n_kv - 1)
    return jnp.take(k, kv_idx, axis=-2)


# ---------------------------------------------------------------------------
# Flash (chunked) attention — train & prefill
# ---------------------------------------------------------------------------


def flash_attention(
    q: jax.Array,  # (B, Sq, H, D)
    k: jax.Array,  # (B, Skv, H, D)  (already head-aligned with q)
    v: jax.Array,
    q_pos: jax.Array,  # (Sq,) global positions
    kv_pos: jax.Array,  # (Skv,)
    causal: bool,
    window: int = 0,
    q_chunk: int = 1024,
    kv_chunk: int = 1024,
    mxu_bf16: bool = False,
) -> jax.Array:
    """Memory-bounded attention: outer scan over q chunks (rematerialized),
    inner scan over kv chunks with running (max, sumexp, out)."""
    b, sq, h, d = q.shape
    skv = k.shape[1]
    cq = min(q_chunk, sq)
    ckv = min(kv_chunk, skv)
    assert sq % cq == 0 and skv % ckv == 0, (sq, cq, skv, ckv)
    scale = 1.0 / math.sqrt(d)
    nq, nk = sq // cq, skv // ckv

    qc = q.reshape(b, nq, cq, h, d).transpose(1, 0, 2, 3, 4)
    qp = q_pos.reshape(nq, cq)
    kc = k.reshape(b, nk, ckv, h, d).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(b, nk, ckv, h, d).transpose(1, 0, 2, 3, 4)
    kp = kv_pos.reshape(nk, ckv)

    def q_block(carry, qblk):
        qi, qpi = qblk  # (B, Cq, H, D), (Cq,)

        def kv_block(st, kblk):
            m, l, o = st
            ki, vi, kpi = kblk
            if mxu_bf16:
                # MXU-native: bf16 operands, f32 accumulation — halves
                # score/probability operand traffic (perf hillclimb P1-1)
                s = jnp.einsum("bqhd,bkhd->bhqk", qi, ki,
                               preferred_element_type=jnp.float32)
            else:
                s = jnp.einsum("bqhd,bkhd->bhqk", qi.astype(jnp.float32),
                               ki.astype(jnp.float32))
            s = s * scale
            msk = jnp.ones((cq, ckv), bool)
            if causal:
                msk &= qpi[:, None] >= kpi[None, :]
            if window:
                msk &= kpi[None, :] > qpi[:, None] - window
            s = jnp.where(msk[None, None], s, -jnp.inf)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            # guard fully-masked rows
            m_safe = jnp.where(jnp.isinf(m_new), 0.0, m_new)
            p = jnp.exp(s - m_safe[..., None])
            p = jnp.where(msk[None, None], p, 0.0)
            corr = jnp.exp(jnp.where(jnp.isinf(m), 0.0, m) - m_safe) * (~jnp.isinf(m))
            l_new = l * corr + jnp.sum(p, axis=-1)
            if mxu_bf16:
                pv = jnp.einsum("bhqk,bkhd->bhqd", p.astype(qi.dtype), vi,
                                preferred_element_type=jnp.float32)
            else:
                pv = jnp.einsum("bhqk,bkhd->bhqd", p, vi.astype(jnp.float32))
            o_new = o * corr[..., None] + pv
            return (m_new, l_new, o_new), None

        m0 = jnp.full((b, h, cq), -jnp.inf, jnp.float32)
        l0 = jnp.zeros((b, h, cq), jnp.float32)
        o0 = jnp.zeros((b, h, cq, d), jnp.float32)
        (m, l, o), _ = lax.scan(jax.checkpoint(kv_block), (m0, l0, o0), (kc, vc, kp))
        out = o / jnp.maximum(l, 1e-30)[..., None]
        return carry, out.transpose(0, 2, 1, 3)  # (B, Cq, H, D)

    _, outs = lax.scan(jax.checkpoint(q_block), (), (qc, qp))
    return outs.transpose(1, 0, 2, 3, 4).reshape(b, sq, h, d).astype(q.dtype)


# ---------------------------------------------------------------------------
# Self-attention block (train / prefill)
# ---------------------------------------------------------------------------


def self_attention(
    x: jax.Array,  # (B, S, d) replicated over model
    w: dict,  # gathered TP-local weights: wq,wk,wv,wo (+ optional bq,bk,bv)
    cfg: AttnConfig,
    cos: jax.Array,
    sin: jax.Array,
    positions: jax.Array,  # (S,) int32
    cache_slice: bool = False,
):
    """Returns (out (B,S,d), (k_full, v_full) if cache_slice else None).

    k_full/v_full: (B, S, n_kv, hd) un-expanded KV (for prefill cache build),
    rope-applied.
    """
    b, s, _ = x.shape
    hd = cfg.head_dim
    xi = tp_copy(x)
    q = (xi @ w["wq"]) if "bq" not in w else (xi @ w["wq"] + w["bq"].astype(x.dtype))
    q = q.reshape(b, s, cfg.heads_local, hd)
    k = (xi @ w["wk"]) if "bk" not in w else (xi @ w["wk"] + w["bk"].astype(x.dtype))
    v = (xi @ w["wv"]) if "bv" not in w else (xi @ w["wv"] + w["bv"].astype(x.dtype))
    k = k.reshape(b, s, cfg.kv_local, hd)
    v = v.reshape(b, s, cfg.kv_local, hd)

    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)

    ke = _expand_kv_local(k, cfg)
    ve = _expand_kv_local(v, cfg)
    o = flash_attention(
        q, ke, ve, positions, positions, cfg.causal, cfg.sliding_window,
        cfg.q_chunk, cfg.kv_chunk, cfg.mxu_bf16,
    )
    o = o * _local_head_mask(cfg)[None, None, :, None].astype(o.dtype)
    out = tp_reduce(o.reshape(b, s, cfg.heads_local * hd) @ w["wo"])
    if not cache_slice:
        return out, None
    # full-KV view for the prefill cache (gather over model in "tp" mode)
    if cfg.kv_mode == "tp":
        k_full = lax.all_gather(k, MODEL_AXIS, axis=2, tiled=True)
        v_full = lax.all_gather(v, MODEL_AXIS, axis=2, tiled=True)
    else:
        k_full, v_full = k, v
    return out, (k_full, v_full)


def cross_attention(
    x: jax.Array,  # (B, S, d)
    memory: jax.Array,  # (B, S_enc, d) encoder output
    w: dict,  # wq,wk,wv,wo (+biases)
    cfg: AttnConfig,
):
    """Encoder-decoder cross attention (no positional rotation, full mask)."""
    b, s, _ = x.shape
    hd = cfg.head_dim
    xi = tp_copy(x)
    mi = tp_copy(memory)
    q = (xi @ w["wq"]).reshape(b, s, cfg.heads_local, hd)
    k = (mi @ w["wk"]).reshape(b, memory.shape[1], cfg.kv_local, hd)
    v = (mi @ w["wv"]).reshape(b, memory.shape[1], cfg.kv_local, hd)
    ke = _expand_kv_local(k, cfg)
    ve = _expand_kv_local(v, cfg)
    s_pos = jnp.arange(s)
    m_pos = jnp.arange(memory.shape[1])
    o = flash_attention(q, ke, ve, s_pos, m_pos, causal=False, window=0,
                        q_chunk=cfg.q_chunk, kv_chunk=cfg.kv_chunk,
                        mxu_bf16=cfg.mxu_bf16)
    o = o * _local_head_mask(cfg)[None, None, :, None].astype(o.dtype)
    return tp_reduce(o.reshape(b, s, cfg.heads_local * hd) @ w["wo"])


# ---------------------------------------------------------------------------
# Flash-decode over a sequence-sharded KV cache
# ---------------------------------------------------------------------------


def decode_new_kv(x: jax.Array, w: dict, cfg: AttnConfig, cos, sin):
    """Project this token's q (all padded heads, gathered) and full-head
    k1/v1 on every rank.  Returns (q_all (B,Hp,hd), k1, v1 (B,n_kv,hd)).

    cos/sin may be (hd//2,) — one shared position — or (B, hd//2) per-slot
    rotations (continuous batching, where every batch slot sits at its own
    sequence position)."""
    b, _ = x.shape
    hd = cfg.head_dim
    q = (x @ w["wq"]) if "bq" not in w else (x @ w["wq"] + w["bq"].astype(x.dtype))
    q = q.reshape(b, cfg.heads_local, hd)
    k1 = (x @ w["wk"]) if "bk" not in w else (x @ w["wk"] + w["bk"].astype(x.dtype))
    v1 = (x @ w["wv"]) if "bv" not in w else (x @ w["wv"] + w["bv"].astype(x.dtype))
    k1 = k1.reshape(b, cfg.kv_local, hd)
    v1 = v1.reshape(b, cfg.kv_local, hd)
    cb = cos[None] if cos.ndim == 1 else cos[:, None]
    sb = sin[None] if sin.ndim == 1 else sin[:, None]
    q = apply_rope(q[:, None], cb, sb)[:, 0]
    k1 = apply_rope(k1[:, None], cb, sb)[:, 0]
    q_all = lax.all_gather(q, MODEL_AXIS, axis=1, tiled=True)  # (B, Hp, hd)
    if cfg.kv_mode == "tp":
        k1 = lax.all_gather(k1, MODEL_AXIS, axis=1, tiled=True)
        v1 = lax.all_gather(v1, MODEL_AXIS, axis=1, tiled=True)
    return q_all, k1, v1


def chunk_new_kv(x: jax.Array, w: dict, cfg: AttnConfig, cos, sin):
    """Multi-token variant of :func:`decode_new_kv` for chunked prefill.

    x: (B, Lq, d) — one prompt chunk per batch slot.  cos/sin are
    (B, Lq, hd//2) per-slot-per-token rotations (each slot's chunk starts
    at its own offset).  Returns (q_all (B, Lq, Hp, hd),
    k1/v1 (B, Lq, n_kv, hd)) — full (padded) query heads gathered, KV
    un-expanded, exactly the shapes the ring cache stores."""
    b, lq, _ = x.shape
    hd = cfg.head_dim
    q = (x @ w["wq"]) if "bq" not in w else (x @ w["wq"] + w["bq"].astype(x.dtype))
    q = q.reshape(b, lq, cfg.heads_local, hd)
    k1 = (x @ w["wk"]) if "bk" not in w else (x @ w["wk"] + w["bk"].astype(x.dtype))
    v1 = (x @ w["wv"]) if "bv" not in w else (x @ w["wv"] + w["bv"].astype(x.dtype))
    k1 = k1.reshape(b, lq, cfg.kv_local, hd)
    v1 = v1.reshape(b, lq, cfg.kv_local, hd)
    q = apply_rope(q, cos, sin)
    k1 = apply_rope(k1, cos, sin)
    q_all = lax.all_gather(q, MODEL_AXIS, axis=2, tiled=True)  # (B, Lq, Hp, hd)
    if cfg.kv_mode == "tp":
        k1 = lax.all_gather(k1, MODEL_AXIS, axis=2, tiled=True)
        v1 = lax.all_gather(v1, MODEL_AXIS, axis=2, tiled=True)
    return q_all, k1, v1


def chunk_attend(
    q_all: jax.Array,  # (B, Lq, Hp, hd) — all (padded) query heads
    k_cache: jax.Array,  # (B, S_loc, n_kv, hd) — this rank's seq chunk,
    v_cache: jax.Array,  # the chunk's own KV already written
    cfg: AttnConfig,
    q_pos: jax.Array,  # (B, Lq) per-slot-per-token query positions
    window: int,
    block_tables: jax.Array = None,  # (B, n_log) paged mode
    block_size: int = 0,
):
    """Multi-query flash-decode over the seq-sharded ring cache — the
    chunked-prefill analogue of :func:`decode_attend`.  Each query token
    attends every ring slot whose held position is causally visible
    (p_s >= 0 and p_s <= its own position); the per-rank partials combine
    with the same log-sum-exp psum.  Padded chunk tokens (beyond a slot's
    valid chunk length) compute garbage that the caller never reads —
    their KV is never written, so nothing they produce can reach a valid
    token.  With ``block_tables`` the caches are the (R, S_row, ...) paged
    pool and are first gathered into each slot's logical view (see
    :func:`paged_gather_kv`) — the math below then runs unchanged, which
    is what makes tokens independent of physical block placement.
    Returns (B, Lq, Hp, hd) f32 (padded heads zero)."""
    b, lq, hp, hd = q_all.shape
    rank = lax.axis_index(MODEL_AXIS)
    if block_tables is not None:
        bl_loc = block_size // cfg.tp
        k_cache = paged_gather_kv(k_cache, block_tables, bl_loc)
        v_cache = paged_gather_kv(v_cache, block_tables, bl_loc)
        s_glob = paged_s_glob(window, block_size, bl_loc)
    else:
        s_loc = k_cache.shape[1]
        s_glob = rank * s_loc + jnp.arange(s_loc)
    qr, k_cache, v_cache = _kv_major_q(q_all, k_cache, v_cache, cfg)

    # slot validity per query token: slot s holds p_s = q - ((q - s) mod W)
    p_s = q_pos[..., None] - jnp.mod(q_pos[..., None] - s_glob, window)
    valid = (p_s >= 0) & slot_valid_mask(q_pos)[..., None]  # (B, Lq, S_loc)

    scale = 1.0 / math.sqrt(hd)
    s_ij = jnp.einsum("blkgd,bskd->blkgs", qr, k_cache.astype(qr.dtype),
                      preferred_element_type=jnp.float32) * scale
    s_ij = jnp.where(valid[:, :, None, None, :], s_ij, -jnp.inf)
    m = lax.pmax(jnp.max(s_ij, axis=-1), MODEL_AXIS)  # (B, Lq, K, G)
    m_safe = jnp.where(jnp.isinf(m), 0.0, m)
    p = jnp.exp(s_ij - m_safe[..., None])
    p = jnp.where(valid[:, :, None, None, :], p, 0.0)
    l = lax.psum(jnp.sum(p, axis=-1), MODEL_AXIS)
    o = lax.psum(
        jnp.einsum("blkgs,bskd->blkgd", p.astype(q_all.dtype),
                   v_cache.astype(q_all.dtype),
                   preferred_element_type=jnp.float32),
        MODEL_AXIS)
    o = o / jnp.maximum(l, 1e-30)[..., None]
    o = o.reshape(b, lq, cfg.n_heads, hd)
    if hp > cfg.n_heads:  # padded heads contribute zero
        o = jnp.pad(o, ((0, 0), (0, 0), (0, hp - cfg.n_heads), (0, 0)))
    return o


def ring_slot(pos: jax.Array, window: int, s_loc: int):
    """Ring-buffer addressing: (local slot index, is_mine flag).

    Elementwise, so ``pos`` may be a scalar (whole batch at one position)
    or a (B,) vector of per-slot positions (continuous batching)."""
    rank = lax.axis_index(MODEL_AXIS)
    slot = jnp.mod(pos, window)
    owner = slot // s_loc
    return slot - owner * s_loc, owner == rank


def slot_valid_mask(pos: jax.Array) -> jax.Array:
    """THE dead-lane test: ``pos >= 0``.

    ``pos = -1`` is the sentinel for a lane that must be inert — retired,
    never filled, or mid-chunked-prefill.  Every consumer of the sentinel
    (the KV write mask in ``DecodeModel._write_token_kv``, the attend
    validity in :func:`decode_attend` / :func:`chunk_attend`, and the
    sampling clamp that keeps dead rows on the draw-free greedy path) goes
    through this one helper so a new cache layout — e.g. the paged block
    pool — cannot re-introduce a stale-lane write by re-deriving the test
    locally and getting an edge wrong."""
    return jnp.asarray(pos) >= 0


# ---------------------------------------------------------------------------
# Paged block-pool addressing (vLLM-style; see serve/kv_pool.py)
# ---------------------------------------------------------------------------
#
# The pool cache keeps the ring tensors' exact shape — (R, S_row, n_kv, hd)
# per layer per rank, S_row the per-rank row length — but reinterprets each
# row as `S_row // block_loc` physical blocks of block_loc tokens
# (block_loc = block_size // tp: every block is sequence-sharded across all
# model ranks, so ANY table permutation stays rank-local).  A slot's logical
# ring of `window` positions maps through its block table
# bt[j] -> physical block id, with logical position p living at logical
# block (p % window) // block_size, within-block offset p % block_size,
# owner rank (offset // block_loc).
#
# Because attend first GATHERS the slot's blocks into logical order, the
# attention math downstream is literally the ring math on the gathered view
# — outputs are bit-identical for every physical placement of the table's
# blocks by construction (a gather changes no values).


def paged_gather_kv(cache: jax.Array, block_tables: jax.Array,
                    block_loc: int) -> jax.Array:
    """(R, S_row, n_kv, hd) pool -> (B, n_log * block_loc, n_kv, hd)
    per-slot logical view through bt (B, n_log) physical block ids.
    Unallocated table entries (< 0) clamp to block 0 — garbage the caller's
    validity mask must exclude (it does: they can only cover positions
    beyond the slot's write head)."""
    r, s_row, nk, hd = cache.shape
    bpr = s_row // block_loc
    pool = cache.reshape(r * bpr, block_loc, nk, hd)
    b, n_log = block_tables.shape
    view = pool[jnp.clip(block_tables, 0, r * bpr - 1)]
    return view.reshape(b, n_log * block_loc, nk, hd)


def paged_s_glob(window: int, block_size: int, block_loc: int) -> jax.Array:
    """Global ring offsets held by this rank's slice of the gathered
    logical view (the paged analogue of ``rank * s_loc + arange(s_loc)``):
    gathered index i sits in logical block i // block_loc at within-block
    offset rank * block_loc + i % block_loc."""
    rank = lax.axis_index(MODEL_AXIS)
    i = jnp.arange((window // block_size) * block_loc)
    return (i // block_loc) * block_size + rank * block_loc + i % block_loc


def paged_slot(pos: jax.Array, window: int, block_size: int, block_loc: int,
               block_tables: jax.Array):
    """Paged write addressing: (pool row, per-rank row seq index, is_mine).

    pos is (B,) or (B, Lq) global positions; block_tables (B, n_log).
    is_mine is False for positions another rank's block slice holds —
    combined with the caller's validity mask and a drop-mode scatter this
    is the paged analogue of :func:`ring_slot`."""
    rank = lax.axis_index(MODEL_AXIS)
    lp = jnp.mod(pos, window)
    j = lp // block_size
    o = lp % block_size
    flat_j = j.reshape(j.shape[0], -1)
    phys = jnp.take_along_axis(block_tables, flat_j, axis=1).reshape(j.shape)
    bpr = window // block_size  # pool rows are ring-length: blocks per row
    row = phys // bpr
    seq = (phys % bpr) * block_loc + o % block_loc
    return row, seq, (o // block_loc) == rank


def _kv_major_q(q_all: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                cfg: AttnConfig):
    """Arrange the real query heads kv-major for the batched decode einsums.

    q_all is (..., Hp, hd) — the leading dims pass through unchanged
    (decode: (B,); chunked prefill: (B, Lq)).  Regular GQA
    (n_heads == n_kv * group) reshapes q to (..., n_kv, group, hd) and
    attends the un-expanded cache directly (no group-x cache copy —
    §Perf P2-2).  Irregular ratios (e.g. n_kv > n_heads, where the reshape
    is impossible) gather each query head's kv head from the cache instead
    and run the same einsums with a per-head group of 1."""
    *lead, _, hd = q_all.shape
    if cfg.n_heads == cfg.n_kv * cfg.group:
        return (q_all[..., : cfg.n_heads, :].reshape(
                    *lead, cfg.n_kv, cfg.group, hd),
                k_cache, v_cache)
    kv_idx = jnp.clip(jnp.arange(cfg.n_heads) // cfg.group, 0, cfg.n_kv - 1)
    return (q_all[..., : cfg.n_heads, :].reshape(*lead, cfg.n_heads, 1, hd),
            jnp.take(k_cache, kv_idx, axis=2),
            jnp.take(v_cache, kv_idx, axis=2))


def decode_attend(
    q_all: jax.Array,  # (B, Hp, hd) — all (padded) query heads
    k_cache: jax.Array,  # (B, S_loc, n_kv, hd) — this rank's seq chunk,
    v_cache: jax.Array,  # current token's KV already written
    cfg: AttnConfig,
    pos: jax.Array,
    window: int,
    block_tables: jax.Array = None,  # (B, n_log) paged mode
    block_size: int = 0,
):
    """Flash-decode over the seq-sharded ring cache WITHOUT materializing a
    GQA-expanded KV copy: real query heads are reshaped kv-major (see
    :func:`_kv_major_q`, which also handles irregular GQA ratios like
    n_kv > n_heads) and the score/AV einsums batch over the kv-head axis
    directly against the un-expanded cache — this removed a group-x
    cache-sized copy per layer (§Perf P2-2).  bf16 operands, f32
    accumulation.  Returns (B, Hp, hd) f32 (padded heads zero).

    ``pos`` may be a scalar (one shared position) or a (B,) vector of
    per-slot positions (continuous batching) — slot validity is computed
    per batch element either way.  With ``block_tables`` the caches are
    the (R, S_row, ...) paged pool: each slot's blocks are gathered into
    logical ring order first (:func:`paged_gather_kv`), so the math below
    — and therefore every output bit — is independent of the physical
    placement, sharing, or fragmentation of the table's blocks.

    Validity geometry is what makes multi-position speculative steps
    safe with NO extra masking here: the draft rounds and the verify
    pass (``DecodeModel.verify_fn``) leave stale draft-precision KV at
    ring slots AHEAD of a lane's committed position, but slot ``s`` is
    valid for a query at ``pos`` only when ``p_s <= pos`` (the ring-wrap
    residue above is <= pos by construction), so a query can never read
    a position it hasn't passed — and every caller that advances ``pos``
    through a drafted position rewrites that slot's KV in its own
    precision *before* the query reaches it (write-before-attend)."""
    b, hp, hd = q_all.shape
    rank = lax.axis_index(MODEL_AXIS)
    if block_tables is not None:
        bl_loc = block_size // cfg.tp
        k_cache = paged_gather_kv(k_cache, block_tables, bl_loc)
        v_cache = paged_gather_kv(v_cache, block_tables, bl_loc)
        s_glob = paged_s_glob(window, block_size, bl_loc)
    else:
        s_loc = k_cache.shape[1]
        s_glob = rank * s_loc + jnp.arange(s_loc)
    qr, k_cache, v_cache = _kv_major_q(q_all, k_cache, v_cache, cfg)

    # slot validity: slot s (global) holds position p_s = pos - ((pos-s) mod W)
    pos = jnp.asarray(pos)
    if pos.ndim == 0:
        pos = jnp.broadcast_to(pos, (b,))
    p_s = pos[:, None] - jnp.mod(pos[:, None] - s_glob[None, :], window)
    valid = (p_s >= 0) & slot_valid_mask(pos)[:, None]  # (B, S_loc)

    scale = 1.0 / math.sqrt(hd)
    s_ij = jnp.einsum("bkgd,bskd->bkgs", qr, k_cache.astype(qr.dtype),
                      preferred_element_type=jnp.float32) * scale
    s_ij = jnp.where(valid[:, None, None, :], s_ij, -jnp.inf)
    m = lax.pmax(jnp.max(s_ij, axis=-1), MODEL_AXIS)  # (B, K, G)
    m_safe = jnp.where(jnp.isinf(m), 0.0, m)
    p = jnp.exp(s_ij - m_safe[..., None])
    p = jnp.where(valid[:, None, None, :], p, 0.0)
    l = lax.psum(jnp.sum(p, axis=-1), MODEL_AXIS)
    o = lax.psum(
        jnp.einsum("bkgs,bskd->bkgd", p.astype(q_all.dtype),
                   v_cache.astype(q_all.dtype),
                   preferred_element_type=jnp.float32),
        MODEL_AXIS)
    o = o / jnp.maximum(l, 1e-30)[..., None]
    o = o.reshape(b, cfg.n_heads, hd)
    if hp > cfg.n_heads:  # padded heads contribute zero
        o = jnp.pad(o, ((0, 0), (0, hp - cfg.n_heads), (0, 0)))
    return o


def decode_out_proj(o: jax.Array, w: dict, cfg: AttnConfig, dtype) -> jax.Array:
    """(B, Hp, hd) f32 attention output -> (B, d) via the TP-local slice of
    the row-parallel wo + psum."""
    b = o.shape[0]
    rank = lax.axis_index(MODEL_AXIS)
    o_loc = lax.dynamic_slice(
        o, (0, rank * cfg.heads_local, 0), (b, cfg.heads_local, cfg.head_dim)
    ).astype(dtype)
    return lax.psum(o_loc.reshape(b, cfg.heads_local * cfg.head_dim) @ w["wo"],
                    MODEL_AXIS)


def decode_self_attention(
    x: jax.Array,  # (B, d) current token hidden
    w: dict,
    cfg: AttnConfig,
    k_cache: jax.Array,  # (B, S_loc, n_kv, hd) — this rank's seq chunk
    v_cache: jax.Array,
    pos: jax.Array,  # scalar int32 — index of the current token
    cos: jax.Array,  # (hd//2,) rope at `pos`
    sin: jax.Array,
    window: int,  # ring size == S_loc * tp
):
    """One-token decode with the cache slices held by the caller.  Returns
    (out (B,d), new_k_cache, new_v_cache) — the caller may instead use
    decode_new_kv/ring_slot/decode_attend to write a scan-carried stacked
    cache in place (models/decode.py does; see §Perf P2)."""
    b, _ = x.shape
    hd = cfg.head_dim
    s_loc = k_cache.shape[1]
    q_all, k1, v1 = decode_new_kv(x, w, cfg, cos, sin)
    idx, is_mine = ring_slot(pos, window, s_loc)
    mine = is_mine.astype(k_cache.dtype)
    old_k = lax.dynamic_slice(k_cache, (0, idx, 0, 0), (b, 1, cfg.n_kv, hd))[:, 0]
    old_v = lax.dynamic_slice(v_cache, (0, idx, 0, 0), (b, 1, cfg.n_kv, hd))[:, 0]
    k_cache = lax.dynamic_update_slice(
        k_cache, (mine * k1 + (1 - mine) * old_k)[:, None].astype(k_cache.dtype),
        (0, idx, 0, 0))
    v_cache = lax.dynamic_update_slice(
        v_cache, (mine * v1 + (1 - mine) * old_v)[:, None].astype(v_cache.dtype),
        (0, idx, 0, 0))
    o = decode_attend(q_all, k_cache, v_cache, cfg, pos, window)
    out = decode_out_proj(o, w, cfg, x.dtype)
    return out, k_cache, v_cache


def decode_cross_attention(
    x: jax.Array,  # (B, d)
    w: dict,
    cfg: AttnConfig,
    ck_cache: jax.Array,  # (B, S_enc_loc, n_kv, hd) precomputed encoder KV
    cv_cache: jax.Array,
    enc_len: jax.Array,  # scalar — valid encoder length
):
    b, _ = x.shape
    hd = cfg.head_dim
    s_loc = ck_cache.shape[1]
    rank = lax.axis_index(MODEL_AXIS)
    q = (x @ w["wq"]).reshape(b, cfg.heads_local, hd)
    q_all = lax.all_gather(q, MODEL_AXIS, axis=1, tiled=True)
    qr, ck_cache, cv_cache = _kv_major_q(q_all, ck_cache, cv_cache, cfg)
    valid = (rank * s_loc + jnp.arange(s_loc)) < enc_len
    scale = 1.0 / math.sqrt(hd)
    s_ij = jnp.einsum("bkgd,bskd->bkgs", qr, ck_cache.astype(qr.dtype),
                      preferred_element_type=jnp.float32) * scale
    s_ij = jnp.where(valid[None, None, None, :], s_ij, -jnp.inf)
    m = lax.pmax(jnp.max(s_ij, axis=-1), MODEL_AXIS)
    m_safe = jnp.where(jnp.isinf(m), 0.0, m)
    p = jnp.where(valid[None, None, None, :], jnp.exp(s_ij - m_safe[..., None]), 0.0)
    l = lax.psum(jnp.sum(p, axis=-1), MODEL_AXIS)
    o = lax.psum(
        jnp.einsum("bkgs,bskd->bkgd", p.astype(q_all.dtype),
                   cv_cache.astype(q_all.dtype),
                   preferred_element_type=jnp.float32), MODEL_AXIS)
    o = o / jnp.maximum(l, 1e-30)[..., None]
    o = o.reshape(b, cfg.n_heads, hd)
    if cfg.n_heads_padded > cfg.n_heads:
        o = jnp.pad(o, ((0, 0), (0, cfg.n_heads_padded - cfg.n_heads), (0, 0)))
    return decode_out_proj(o, w, cfg, x.dtype)
