"""Architecture configuration.

One `ModelConfig` describes any of the six supported family types:
dense / moe / ssm / hybrid / vlm / audio(enc-dec).  Instances for the ten
assigned architectures live in `repro.configs`.
"""
from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    arch_type: str  # "dense" | "moe" | "ssm" | "hybrid" | "vlm" | "audio"
    n_layers: int
    d_model: int
    vocab_size: int
    # attention (unused for pure ssm)
    n_heads: int = 0
    n_kv_heads: int = 0
    head_dim: int = 128
    qkv_bias: bool = False
    rope_theta: float = 1_000_000.0
    rope_mode: str = "1d"  # "1d" | "mrope"
    mrope_sections: tuple[int, ...] = (16, 24, 24)
    sliding_window: int = 0  # 0 = full attention (training/prefill)
    # mlp
    d_ff: int = 0
    # moe
    n_experts: int = 0
    moe_top_k: int = 0
    moe_d_ff: int = 0
    moe_capacity_factor: float = 1.25
    # load-balance aux loss weight; computed on each model rank's token
    # shard and averaged (standard EP practice — differs from global-batch
    # statistics by O(1/shard) noise)
    moe_aux_coef: float = 0.01
    # ssm / hybrid
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_chunk: int = 128
    hybrid_attn_every: int = 6  # hybrid: shared attn+mlp block cadence
    # enc-dec (audio)
    n_enc_layers: int = 0
    enc_frames_ratio: int = 2  # encoder frames = seq_len // ratio
    # misc
    norm_eps: float = 1e-5
    tie_embeddings: bool = True
    # long-context policy for the long_500k shape:
    #   "native"          — sub-quadratic arch, run as-is
    #   "sliding_window"  — dense arch served with a ring-buffer window cache
    long_context: str = "sliding_window"
    long_context_window: int = 8192
    # source citation for the assigned-architecture pool
    source: str = ""

    # ---- derived ----
    def padded_vocab(self, tp: int) -> int:
        return -(-self.vocab_size // tp) * tp

    @property
    def has_attention(self) -> bool:
        return self.arch_type != "ssm"

    @property
    def is_moe(self) -> bool:
        return self.arch_type == "moe"

    @property
    def is_encoder_decoder(self) -> bool:
        return self.arch_type == "audio"

    def n_params(self) -> int:
        """Approximate logical parameter count (for 6ND model-flops)."""
        d, v = self.d_model, self.vocab_size
        total = v * d  # embedding
        if not self.tie_embeddings:
            total += v * d
        per_attn = d * self.n_heads * self.head_dim + 2 * d * self.n_kv_heads * self.head_dim \
            + self.n_heads * self.head_dim * d if self.has_attention else 0
        per_dense_mlp = 3 * d * self.d_ff if self.d_ff else 0
        per_moe = self.n_experts * 3 * d * self.moe_d_ff if self.is_moe else 0
        d_in = self.ssm_expand * d
        n_h = d_in // self.ssm_head_dim if self.ssm_state else 0
        per_ssm = (2 * d * d_in + 2 * d * self.ssm_state + d * n_h + d_in * d) if self.ssm_state else 0
        if self.arch_type in ("dense", "vlm"):
            total += self.n_layers * (per_attn + per_dense_mlp)
        elif self.arch_type == "moe":
            total += self.n_layers * (per_attn + per_moe)
        elif self.arch_type == "ssm":
            total += self.n_layers * per_ssm
        elif self.arch_type == "hybrid":
            total += self.n_layers * per_ssm + (per_attn + per_dense_mlp)  # shared block
        elif self.arch_type == "audio":
            total += (self.n_layers + self.n_enc_layers) * (per_attn + per_dense_mlp)
            total += self.n_layers * per_attn  # cross attention
        return int(total)

    def n_active_params(self) -> int:
        """Active params per token (MoE: top-k experts only)."""
        if not self.is_moe:
            return self.n_params()
        d = self.d_model
        per_attn = d * self.n_heads * self.head_dim + 2 * d * self.n_kv_heads * self.head_dim \
            + self.n_heads * self.head_dim * d
        per_moe_active = self.moe_top_k * 3 * d * self.moe_d_ff
        total = self.vocab_size * d + self.n_layers * (per_attn + per_moe_active)
        return int(total)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One of the four assigned input shapes."""

    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"

    @property
    def is_decode(self) -> bool:
        return self.kind == "decode"


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}
