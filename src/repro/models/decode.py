"""Serving paths: prefill (build caches) and single-token decode for all six
architecture families, wired through the QSDP engine.

FSDP serving story (the paper's technique on the inference side): weights
stay fully sharded at rest and are re-gathered — *quantized* — layer by
layer inside every prefill/decode step.  Decode is therefore dominated by
weight all-gather bytes, exactly the regime where QSDP's wire compression
pays off most; the roofline benchmark quantifies this.

With ``DecodeSpec(rowquant_mlp=True)`` the dense-MLP weights skip the
dequant step entirely: the gathered wire codes are reshaped (K, N) with
their per-bucket affine as (K, N/bucket) segments and fed straight into
the fused ``kernels.ops.rowquant_matmul`` kernel (see
``QSDPEngine.gather_rowquant``).

Cache layouts (global shapes; per-device views inside shard_map):

  attention KV  (L, B, S, n_kv, hd)   P(None, batch?, "model", None, None)
                ring-buffered along S (full cache == ring that never wraps;
                sliding-window long-context == ring of window size)
  mamba conv    (L, B, K-1, tp * Cc)  P(None, batch?, None, "model")
                with Cc = d_inner_local + 2N (each rank stores its own
                slice; the 2N B/C section is per-rank replicated state)
  mamba ssm     (L, B, H, P, N)       P(None, batch?, "model", None, None)
  hybrid        mamba states (all layers) + per-group shared-block KV
                (G, B, S, n_kv, hd)
  audio         decoder self KV ring + static encoder cross KV
                (L, B, S_enc, n_kv, hd) + enc_len scalar

`batch?` is the FSDP axes when the global batch divides them (decode_32k),
or replicated for tiny batches (long_500k's B=1).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from ..core.quant import QuantizedParam
from . import attention as attn_mod
from . import layers as L
from . import mamba as mamba_mod
from . import moe as moe_mod
from .transformer import Model

Params = dict[str, jax.Array]
Cache = dict[str, Any]

# dense-MLP weights that may stay in wire-code form through swiglu_mlp
# (rowquant decode and serve.engine.prepare_wire_params share this list)
ROWQUANT_MLP = ("w_gate", "w_up", "w_down")

# families whose prompts may prefill chunk-at-a-time into the pool cache
# (pure attention stacks; modality/state caches still prefill whole-prompt)
CHUNKED_PREFILL_ARCHS = ("dense", "moe")


@dataclasses.dataclass(frozen=True)
class DecodeSpec:
    """Static decode-time configuration for one (arch, shape) pair."""

    cache_len: int  # ring size (== seq_len, or the sliding window)
    batch_global: int
    batch_sharded: bool  # shard batch over FSDP axes?
    enc_len: int = 0  # audio: encoder memory length (capped)
    # Decode the dense-MLP weights straight from their gathered wire codes
    # through kernels.ops.rowquant_matmul instead of materializing the dense
    # matrix (per-weight fallback when the wire layout doesn't tile rows —
    # see QSDPEngine.rowquant_eligible).
    rowquant_mlp: bool = False
    # Per-slot sampling: decode/prefill take a `sample` tree of per-slot
    # (temperature, top_k, PRNG key) arrays and sample the next token with
    # layers.sample_vocab_parallel instead of the pure greedy argmax.  Rows
    # with temp <= 0 or top_k == 1 still take the greedy path bit-exactly,
    # so a sampling engine at temp 0 matches a greedy engine token-for-token.
    sampling: bool = False
    # Paged KV block pool (serve/kv_pool.py).  kv_block_size > 0 switches
    # the attention cache from one private ring per slot to a shared pool
    # of fixed-size blocks addressed through per-slot block tables:
    # decode_fn / prefill_chunk_fn take a trailing `block_tables` (B, n_log)
    # int32 argument, and the cache kv leaves become (L, R, cache_len, ...)
    # with R pool rows instead of B lanes.  Requires cache_len %
    # kv_block_size == 0, kv_block_size % tp == 0, batch_sharded=False
    # (blocks may be shared across lanes, so the pool is batch-replicated),
    # and a CHUNKED_PREFILL_ARCHS architecture.
    kv_block_size: int = 0
    # Total physical blocks (0 = batch_global * cache_len // kv_block_size,
    # i.e. the same device bytes as the rings it replaces).  Rounded up to
    # whole pool rows of cache_len // kv_block_size blocks each.
    kv_pool_blocks: int = 0
    # Self-speculative decoding: a `draft_bits`-bit forward of the SAME
    # model (weights re-quantized from the resident wire codes) drafts up
    # to `draft_depth` tokens per slot per step, then the serving-precision
    # model scores all of them in ONE pooled `verify_fn` launch and commits
    # the longest prefix the verifier agrees with.  Greedy (and sampled)
    # streams are bit-identical to non-speculative decode by construction:
    # every committed token is produced by the verifier with math
    # elementwise identical to decode_fn.  draft_depth <= 1 disables
    # speculation (plain one-token decode).
    draft_bits: int = 0
    draft_depth: int = 0

    def batch_pspec(self, ms) -> tuple:
        return (ms.fsdp_axes,) if self.batch_sharded else (None,)

    @property
    def paged(self) -> bool:
        return self.kv_block_size > 0

    @property
    def speculative(self) -> bool:
        return self.draft_depth > 1 and self.draft_bits > 0

    @property
    def blocks_per_slot(self) -> int:
        """Logical blocks per slot ring (== physical blocks per pool row)."""
        return self.cache_len // self.kv_block_size

    def pool_rows(self) -> int:
        if not self.paged:
            return self.batch_global
        want = self.kv_pool_blocks or self.batch_global * self.blocks_per_slot
        return -(-want // self.blocks_per_slot)

    def pool_blocks(self) -> int:
        """Physical blocks actually materialized (whole rows)."""
        return self.pool_rows() * self.blocks_per_slot


def make_decode_spec(model: Model, shape, rowquant_mlp: bool = False) -> DecodeSpec:
    """Derive the decode configuration from a ShapeConfig."""
    cfg = model.cfg
    s = shape.seq_len
    if cfg.arch_type in ("ssm",):
        cache_len = 0  # state is O(1); no KV ring
    elif s > 65536 and cfg.long_context == "sliding_window":
        cache_len = cfg.long_context_window
    else:
        cache_len = s
    fsdp = model.ms.fsdp_size
    return DecodeSpec(
        cache_len=cache_len,
        batch_global=shape.global_batch,
        batch_sharded=shape.global_batch % fsdp == 0,
        enc_len=min(4096, s // cfg.enc_frames_ratio) if cfg.arch_type == "audio" else 0,
        rowquant_mlp=rowquant_mlp,
    )


class DecodeModel:
    """Per-device prefill / decode step functions for a bound Model."""

    def __init__(self, model: Model, spec: DecodeSpec):
        self.m = model
        self.spec = spec
        cfg = model.cfg
        ms = model.ms
        self.tp = ms.model_size
        if cfg.has_attention:
            assert spec.cache_len == 0 or spec.cache_len % self.tp == 0, (
                spec.cache_len, self.tp)
        if spec.paged:
            if cfg.arch_type not in CHUNKED_PREFILL_ARCHS:
                raise ValueError(
                    f"paged KV (kv_block_size={spec.kv_block_size}) supports "
                    f"{CHUNKED_PREFILL_ARCHS}, not {cfg.arch_type!r}")
            if spec.batch_sharded:
                raise ValueError(
                    "paged KV requires batch_sharded=False: block tables may "
                    "point any lane at any pool row, so the pool is "
                    "batch-replicated over the data axis")
            if spec.kv_block_size % self.tp:
                raise ValueError(
                    f"kv_block_size ({spec.kv_block_size}) must be a "
                    f"multiple of the model-axis size ({self.tp}) — every "
                    "block is sequence-sharded across all ranks")
            if spec.cache_len % spec.kv_block_size:
                raise ValueError(
                    f"cache_len ({spec.cache_len}) must be a multiple of "
                    f"kv_block_size ({spec.kv_block_size})")
        if spec.speculative and cfg.arch_type not in CHUNKED_PREFILL_ARCHS:
            raise ValueError(
                f"speculative decode (draft_depth={spec.draft_depth}) "
                f"supports {CHUNKED_PREFILL_ARCHS}, not {cfg.arch_type!r}")
        if spec.draft_bits and not 2 <= spec.draft_bits <= 8:
            raise ValueError(f"draft_bits must be in [2, 8], got "
                             f"{spec.draft_bits}")
        self.s_loc = spec.cache_len // self.tp if spec.cache_len else 0
        self.b_loc = (
            spec.batch_global // ms.fsdp_size if spec.batch_sharded else spec.batch_global
        )

    # ------------------------------------------------------------------
    # Cache shapes / pspecs (global views, for dryrun + init)
    # ------------------------------------------------------------------

    def cache_struct(self) -> tuple[Cache, Cache]:
        """Returns (ShapeDtypeStruct tree, PartitionSpec tree) — global."""
        m, cfg, sp = self.m, self.m.cfg, self.spec
        ms = m.ms
        bax = sp.batch_pspec(ms)[0]
        B = sp.batch_global
        structs: Cache = {}
        specs: Cache = {}

        def kv(prefix, layers, s):
            # paged: rows are pool storage, not lanes — never batch-sharded
            rows = sp.pool_rows() if sp.paged else B
            rax = None if sp.paged else bax
            shp = (layers, rows, s, m.acfg.n_kv, cfg.head_dim)
            structs[prefix + "k"] = jax.ShapeDtypeStruct(shp, jnp.bfloat16)
            structs[prefix + "v"] = jax.ShapeDtypeStruct(shp, jnp.bfloat16)
            specs[prefix + "k"] = P(None, rax, "model", None, None)
            specs[prefix + "v"] = P(None, rax, "model", None, None)

        if cfg.arch_type in ("dense", "vlm", "moe"):
            kv("", cfg.n_layers, sp.cache_len)
        elif cfg.arch_type == "ssm":
            self._mamba_struct(structs, specs, cfg.n_layers, B, bax)
        elif cfg.arch_type == "hybrid":
            self._mamba_struct(structs, specs, cfg.n_layers, B, bax)
            g = cfg.n_layers // cfg.hybrid_attn_every
            kv("shared_", g, sp.cache_len)
        elif cfg.arch_type == "audio":
            kv("", cfg.n_layers, sp.cache_len)
            shp = (cfg.n_layers, B, sp.enc_len, m.acfg.n_kv, cfg.head_dim)
            structs["ck"] = jax.ShapeDtypeStruct(shp, jnp.bfloat16)
            structs["cv"] = jax.ShapeDtypeStruct(shp, jnp.bfloat16)
            specs["ck"] = P(None, bax, "model", None, None)
            specs["cv"] = P(None, bax, "model", None, None)
        else:
            raise ValueError(cfg.arch_type)
        return structs, specs

    def _mamba_struct(self, structs, specs, layers, B, bax):
        mc = self.m.mcfg
        cc = mc.d_inner_local + 2 * mc.d_state
        structs["conv"] = jax.ShapeDtypeStruct(
            (layers, B, mc.conv_k - 1, self.tp * cc), jnp.float32)
        specs["conv"] = P(None, bax, None, "model")
        structs["ssm"] = jax.ShapeDtypeStruct(
            (layers, B, mc.n_heads, mc.head_dim, mc.d_state), jnp.float32)
        specs["ssm"] = P(None, bax, "model", None, None)

    def init_cache_local(self) -> Cache:
        """Per-device zero cache (inside shard_map) — used by tests."""
        structs, _ = self.cache_struct()
        ms = self.m.ms
        out = {}
        for k, st in structs.items():
            shp = list(st.shape)
            # paged kv rows are pool storage (already final in the struct);
            # every other cache leaf's dim 1 is the (possibly sharded) batch
            if not (self.spec.paged and k not in ("conv", "ssm")):
                shp[1] = self.b_loc
            if k in ("conv",):
                shp[3] //= self.tp
            elif k in ("ssm",):
                shp[2] //= self.tp
            else:  # kv
                shp[2] //= self.tp
            out[k] = jnp.zeros(shp, st.dtype)
        return out

    # ------------------------------------------------------------------
    # Decode (one token)
    # ------------------------------------------------------------------

    def decode_fn(self, params: Params, cache: Cache, tokens: jax.Array,
                  pos: jax.Array, key: jax.Array,
                  sample: Optional[dict] = None,
                  block_tables: Optional[jax.Array] = None
                  ) -> tuple[jax.Array, Cache]:
        """tokens (B_loc,) int32 current input; pos () or (B_loc,) int32 its
        position — a vector gives every batch slot its own sequence position
        (continuous batching).  pos[b] < 0 marks a DEAD lane: its KV write
        is masked out (ring bytes frozen), every cached slot fails the
        validity test (zero attention output), and schedulers pair it with
        temp<=0 so the row burns no Gumbel draws.  Returns
        (next_tokens (B_loc,), new_cache).

        sample (present iff ``spec.sampling``): per-slot sampling state —
        {"temp": (B_loc,) f32, "top_k": (B_loc,) i32, "key": (B_loc, 2) u32}.
        The per-token sampling key is fold_in(slot key, pos + 1) — a pure
        function of the REQUEST's own key and position, so sampled output
        is reproducible across runs and across batch compositions."""
        m, cfg = self.m, self.m.cfg
        pos = jnp.asarray(pos, jnp.int32)
        if pos.ndim == 0:
            pos = jnp.broadcast_to(pos, tokens.shape)
        if self.spec.paged and block_tables is None:
            raise ValueError("paged DecodeSpec: decode_fn needs block_tables")
        emb = m.engine.gather("embed", params["embed"], key)
        x = L.embed_vocab_parallel(tokens[:, None], emb)[:, 0]  # (B, d)

        cos, sin = self._decode_rope(pos)

        if cfg.arch_type in ("dense", "vlm"):
            x, cache = self._decode_attn_stack(params, "layers", x, cache, pos, cos, sin, key,
                                               mlp="dense", block_tables=block_tables)
        elif cfg.arch_type == "moe":
            x, cache = self._decode_attn_stack(params, "layers", x, cache, pos, cos, sin, key,
                                               mlp="moe", block_tables=block_tables)
        elif cfg.arch_type == "ssm":
            x, cache = self._decode_mamba_stack(params, x, cache, key)
        elif cfg.arch_type == "hybrid":
            x, cache = self._decode_hybrid(params, x, cache, pos, cos, sin, key)
        elif cfg.arch_type == "audio":
            x, cache = self._decode_audio(params, x, cache, pos, cos, sin, key)
        else:
            raise ValueError(cfg.arch_type)

        fn = m.engine.gather("final_norm", params["final_norm"], key)
        x = L.rms_norm(x, fn, cfg.norm_eps)
        head = emb if cfg.tie_embeddings else m.engine.gather("lm_head", params["lm_head"], key)
        logits = L.vocab_parallel_logits(x, head)
        nxt = self._sample(logits, head.shape[0], sample, pos + 1,
                           valid=attn_mod.slot_valid_mask(pos))
        return nxt.astype(jnp.int32), cache

    def _sample(self, logits, v_local, sample, n_consumed, valid=None):
        """Next-token selection: greedy argmax, or per-slot sampling keyed by
        fold_in(request key, tokens consumed so far) when `sample` is given.
        n_consumed (B,) is the model-visible prefix length, i.e. the global
        position of the token being produced — identical for a request
        whether it runs solo or interleaved, which is what pins sampled
        streams across batch compositions.

        `valid` (B,) bool — dead lanes (attention.slot_valid_mask: the ONE
        sentinel test) are clamped to temp 0 / top-k 1 in the DEVICE step
        itself, so they take the draw-free greedy reduction no matter what
        the host mirrors hold (schedulers also clear them host-side; this
        makes the Gumbel skip a property of the sentinel, not of scheduler
        discipline)."""
        if sample is None:
            return L.greedy_sample_vocab_parallel(logits, v_local)
        temp, top_k = sample["temp"], sample["top_k"]
        if valid is not None:
            temp = jnp.where(valid, temp, 0.0)
            top_k = jnp.where(valid, jnp.asarray(top_k), 1)
        skeys = jax.vmap(jax.random.fold_in)(sample["key"], n_consumed)
        return L.sample_vocab_parallel(logits, v_local, temp, top_k, skeys)

    def _decode_rope(self, pos):
        """pos () or (B,) -> cos/sin broadcastable for decode_new_kv
        ((hd//2,) shared, or (B, hd//2) per-slot)."""
        cfg = self.m.cfg
        if not cfg.has_attention:
            return None, None
        if cfg.rope_mode == "mrope":
            pos3 = jnp.broadcast_to(pos, (3,) + jnp.shape(pos))
            return L.mrope_cos_sin(pos3, cfg.head_dim, cfg.rope_theta, cfg.mrope_sections)
        return L.rope_cos_sin(pos, cfg.head_dim, cfg.rope_theta)

    def _write_token_kv(self, kc_all, vc_all, layer, k1, v1, pos,
                        block_tables=None):
        """Write this token's KV into the scan-carried stacked cache
        (L, B, S_loc, n_kv, hd) at (layer, b, ring slot of pos[b]) — a
        token-sized gather + scatter per layer (~KB) instead of re-emitting
        the whole cache as scan ys (§Perf P2-1).  pos is (B,): each batch
        slot writes its OWN ring slot, so interleaved requests at different
        positions never touch each other's cache lines.

        pos[b] < 0 is the DEAD-LANE sentinel (retired / never-filled /
        mid-chunked-prefill slots; ``attention.slot_valid_mask`` is the one
        place that owns the test): the lane's write is masked out entirely,
        so a dead lane's ring bytes are frozen — required by the chunked
        prefill path, which fills a lane's ring incrementally and cannot
        rely on a full-ring splice to wipe garbage writes.

        With ``block_tables`` the cache is the (L, R, S_row, ...) paged
        pool: the ring offset maps through the lane's table to a (pool row,
        row seq index) target instead (``attention.paged_slot``), and
        masked-out lanes redirect to the out-of-range row R and are DROPPED
        — same determinism argument as the chunk path below."""
        b = k1.shape[0]
        if block_tables is not None:
            bl_loc = self.spec.kv_block_size // self.tp
            row, seq, is_mine = attn_mod.paged_slot(
                pos, self.spec.cache_len, self.spec.kv_block_size, bl_loc,
                block_tables)
            mask = is_mine & attn_mod.slot_valid_mask(pos)
            row = jnp.where(mask, row, kc_all.shape[1])  # OOB row => dropped
            kc_all = kc_all.at[layer, row, seq].set(
                k1.astype(kc_all.dtype), mode="drop")
            vc_all = vc_all.at[layer, row, seq].set(
                v1.astype(vc_all.dtype), mode="drop")
            return kc_all, vc_all
        s_loc = kc_all.shape[2]
        idx, is_mine = attn_mod.ring_slot(pos, self.spec.cache_len, s_loc)
        bi = jnp.arange(b)
        mine = (is_mine & attn_mod.slot_valid_mask(pos))[:, None, None]
        new_k = jnp.where(mine, k1.astype(kc_all.dtype), kc_all[layer, bi, idx])
        new_v = jnp.where(mine, v1.astype(vc_all.dtype), vc_all[layer, bi, idx])
        kc_all = kc_all.at[layer, bi, idx].set(new_k)
        vc_all = vc_all.at[layer, bi, idx].set(new_v)
        return kc_all, vc_all

    def _decode_attn_layer(self, x, w, kc_all, vc_all, layer, pos, cos, sin, mlp,
                           block_tables=None):
        m, cfg = self.m, self.m.cfg
        h = L.rms_norm(x, w["attn_norm"], cfg.norm_eps)
        q_all, k1, v1 = attn_mod.decode_new_kv(h, w, m.acfg, cos, sin)
        kc_all, vc_all = self._write_token_kv(kc_all, vc_all, layer, k1, v1, pos,
                                              block_tables=block_tables)
        kc = lax.dynamic_index_in_dim(kc_all, layer, 0, keepdims=False)
        vc = lax.dynamic_index_in_dim(vc_all, layer, 0, keepdims=False)
        o = attn_mod.decode_attend(q_all, kc, vc, m.acfg, pos, self.spec.cache_len,
                                   block_tables=block_tables,
                                   block_size=self.spec.kv_block_size)
        a = attn_mod.decode_out_proj(o, w, m.acfg, x.dtype)
        x = x + a
        h = L.rms_norm(x, w["mlp_norm"], cfg.norm_eps)
        if mlp == "dense":
            x = x + L.swiglu_mlp(h, w["w_gate"], w["w_up"], w["w_down"])
        else:  # moe — drop-free dispatch: dead/other lanes must never evict
            # a live lane's expert slot (slot isolation; bit-neutral while
            # B * top_k fits the capacity floor, where nothing ever drops)
            y, _ = moe_mod.moe_layer(h, {k: w[k] for k in ("router", "w_gate", "w_up", "w_down")},
                                     m.ecfg, no_drop=True)
            x = x + y
        return x, kc_all, vc_all

    _ROWQUANT_MLP = ROWQUANT_MLP

    def _gather_layer_w(self, prefix, names, lw, lkey, mlp=None):
        """Gather one layer's weights — one coalesced collective for the
        dense/dequantized ones (see QSDPEngine.gather_layer); when rowquant
        decode is enabled the dense-MLP matmul weights come back as
        RowQuantWeights (wire codes + per-bucket affine) gathered separately
        and stay in code form through swiglu_mlp.

        Leaves that arrive as QuantizedParam (quantized train state /
        checkpoint-v2 serving, or a low-bit self-speculative draft built by
        ``serve.engine.make_draft_params``) are all-gathered straight from
        their stored codes — zero re-quantization: dense-MLP matmul weights
        whose buckets tile their rows stay in code form
        (QSDPEngine.gather_rowquant_wire -> rowquant_matmul), everything
        else dequantizes densely through the bits 2-8 kernels
        (QSDPEngine.gather_wire_dequant)."""
        m = self.m
        wire = [n for n in names if isinstance(lw[n], QuantizedParam)]
        rq = [n for n in names
              if n not in wire
              and self.spec.rowquant_mlp and mlp == "dense" and n in self._ROWQUANT_MLP]
        out = m.engine.gather_layer(
            f"{prefix}/", {n: lw[n] for n in names if n not in rq and n not in wire},
            lkey)
        for n in wire:
            if (n in self._ROWQUANT_MLP
                    and m.engine.rowquant_wire_eligible(f"{prefix}/{n}", lw[n])):
                out[n] = m.engine.gather_rowquant_wire(f"{prefix}/{n}", lw[n])
            else:
                out[n] = m.engine.gather_wire_dequant(f"{prefix}/{n}", lw[n])
        for n in rq:
            out[n] = m.engine.gather_rowquant(f"{prefix}/{n}", lw[n], lkey)
        return out

    def _decode_attn_stack(self, params, prefix, x, cache, pos, cos, sin, key, mlp,
                           block_tables=None):
        m = self.m
        grp = m._group(params, prefix)
        names = list(grp.keys())

        def body(carry, inp):
            x, kc_all, vc_all = carry
            idx, lw = inp
            lkey = jax.random.fold_in(key, idx)
            w = self._gather_layer_w(prefix, names, lw, lkey, mlp=mlp)
            x, kc_all, vc_all = self._decode_attn_layer(
                x, w, kc_all, vc_all, idx, pos, cos, sin, mlp,
                block_tables=block_tables)
            return (x, kc_all, vc_all), None

        nl = jax.tree.leaves(grp)[0].shape[0]
        (x, k_new, v_new), _ = lax.scan(
            body, (x, cache["k"], cache["v"]), (jnp.arange(nl), grp))
        cache = dict(cache, k=k_new, v=v_new)
        return x, cache

    # ------------------------------------------------------------------
    # Speculative verify (score k draft tokens in one launch)
    # ------------------------------------------------------------------

    def verify_fn(self, params: Params, cache: Cache, tokens: jax.Array,
                  pos: jax.Array, n_spec: jax.Array, key: jax.Array,
                  sample: Optional[dict] = None,
                  block_tables: Optional[jax.Array] = None
                  ) -> tuple[jax.Array, Cache]:
        """Serving-precision batch-verify of up to K drafted tokens per slot.

        tokens (B_loc, K): token j of slot b is the token fed at position
        pos[b] + j — row [t0, g1, .., g_{K-1}] where t0 is the slot's
        current feed token and g_j its draft chain.  pos (B_loc,) is each
        slot's feed position (< 0 = dead lane); n_spec (B_loc,) how many of
        the K tokens the slot actually runs this step (token j >= n_spec[b]
        is masked to the dead sentinel: no KV write, garbage output).

        Returns (out (B_loc, K), cache): out[b, j] is the model's next
        token after the prefix ..tokens[b, :j+1] — out[b, 0] is exactly
        what decode_fn would emit this step, and out[b, j] is valid
        whenever tokens[b, 1:j+1] were all accepted (each equals the
        verifier's previous output).  Every token's KV is (re)written at
        its own position in serving precision — draft-precision KV left by
        the draft rounds is overwritten — so after committing the accepted
        prefix the cache is bit-identical to sequential decode's.

        BIT-IDENTITY: the per-token math is `_decode_attn_layer` /
        `_sample` on the same (B, .) shapes as decode_fn — layers scan
        outside, the K token positions scan inside (write-before-attend
        per token, so token j attends the serving-precision KV of tokens
        < j), and the final norm/logits/sample stage also runs per token —
        so a committed token is bit-for-bit the token the equivalent
        sequence of decode_fn calls would produce (same gather key, same
        per-layer fold_in, same matmul shapes, same sampling fold)."""
        m, cfg = self.m, self.m.cfg
        if cfg.arch_type not in CHUNKED_PREFILL_ARCHS:
            raise NotImplementedError(
                f"speculative verify supports {CHUNKED_PREFILL_ARCHS}, "
                f"not {cfg.arch_type!r}")
        if self.spec.paged and block_tables is None:
            raise ValueError("paged DecodeSpec: verify_fn needs block_tables")
        b, kmax = tokens.shape
        pos = jnp.asarray(pos, jnp.int32)
        n_spec = jnp.asarray(n_spec, jnp.int32)
        mlp = "moe" if cfg.is_moe else "dense"

        emb = m.engine.gather("embed", params["embed"], key)
        # (K, B, d): embed is an elementwise take + psum, so embedding all
        # K tokens at once is bit-identical to decode_fn's per-token embed
        xs = jnp.moveaxis(L.embed_vocab_parallel(tokens, emb), 1, 0)
        # per-token positions with the dead sentinel beyond each slot's
        # depth: (K, B); attention.slot_valid_mask owns the < 0 test
        js = jnp.arange(kmax, dtype=jnp.int32)
        pjs = jnp.where((js[:, None] < n_spec[None, :])
                        & attn_mod.slot_valid_mask(pos)[None, :],
                        pos[None, :] + js[:, None], -1)

        grp = m._group(params, "layers")
        names = list(grp.keys())

        def layer_body(carry, inp):
            xs, kc_all, vc_all = carry
            idx, lw = inp
            lkey = jax.random.fold_in(key, idx)
            w = self._gather_layer_w("layers", names, lw, lkey, mlp=mlp)

            def token_body(tc, inp2):
                kc_all, vc_all = tc
                x, pj = inp2
                cos, sin = self._decode_rope(pj)
                x, kc_all, vc_all = self._decode_attn_layer(
                    x, w, kc_all, vc_all, idx, pj, cos, sin, mlp,
                    block_tables=block_tables)
                return (kc_all, vc_all), x

            (kc_all, vc_all), xs = lax.scan(token_body, (kc_all, vc_all),
                                            (xs, pjs))
            return (xs, kc_all, vc_all), None

        nl = jax.tree.leaves(grp)[0].shape[0]
        (xs, k_new, v_new), _ = lax.scan(
            layer_body, (xs, cache["k"], cache["v"]), (jnp.arange(nl), grp))
        cache = dict(cache, k=k_new, v=v_new)

        fn = m.engine.gather("final_norm", params["final_norm"], key)
        head = emb if cfg.tie_embeddings else m.engine.gather(
            "lm_head", params["lm_head"], key)

        def out_body(_, inp2):
            x, pj = inp2
            h = L.rms_norm(x, fn, cfg.norm_eps)
            logits = L.vocab_parallel_logits(h, head)
            nxt = self._sample(logits, head.shape[0], sample, pj + 1,
                               valid=attn_mod.slot_valid_mask(pj))
            return None, nxt

        _, outs = lax.scan(out_body, None, (xs, pjs))  # (K, B)
        return jnp.moveaxis(outs, 0, 1).astype(jnp.int32), cache

    # ------------------------------------------------------------------
    # Chunked prefill (one prompt chunk per slot, fused into the pool)
    # ------------------------------------------------------------------

    def _write_chunk_kv(self, kc_all, vc_all, layer, k1, v1, pos, n_valid,
                        block_tables=None):
        """Write one chunk's KV into the stacked pool cache at each slot's
        own ring offsets.  k1/v1 (B, Lq, n_kv, hd); pos (B, Lq) global
        positions; n_valid (B,) valid tokens per slot (0 = lane not
        prefilling).

        ``ring_slot`` indices are LOCAL (slot - owner * s_loc), so two
        tokens of one padded chunk can alias the same local index whenever
        the chunk spans more global slots than one rank holds (Lq > s_loc
        — e.g. padding tokens folding onto a valid token's slot).  Every
        non-owned or invalid token is therefore redirected to the
        out-of-range index s_loc and DROPPED, leaving exactly one scatter
        target per written slot: deterministic, and nothing is written for
        padded tokens or non-prefilling lanes, so live decode slots' (and
        dead lanes') ring bytes are untouched."""
        b, lq = pos.shape
        tok_valid = jnp.arange(lq)[None, :] < n_valid[:, None]
        if block_tables is not None:
            # paged: targets map through the lane's table to (pool row, row
            # seq index); the drop redirect goes to the out-of-range row R.
            # Same single-writer argument: a chunk's positions are distinct
            # mod the window, and the scheduler never hands two lanes the
            # same writable physical block.
            bl_loc = self.spec.kv_block_size // self.tp
            row, seq, is_mine = attn_mod.paged_slot(
                pos, self.spec.cache_len, self.spec.kv_block_size, bl_loc,
                block_tables)
            row = jnp.where(is_mine & tok_valid, row, kc_all.shape[1])
            kc_all = kc_all.at[layer, row, seq].set(k1.astype(kc_all.dtype),
                                                    mode="drop")
            vc_all = vc_all.at[layer, row, seq].set(v1.astype(vc_all.dtype),
                                                    mode="drop")
            return kc_all, vc_all
        s_loc = kc_all.shape[2]
        idx, is_mine = attn_mod.ring_slot(pos, self.spec.cache_len, s_loc)
        bi = jnp.broadcast_to(jnp.arange(b)[:, None], (b, lq))
        idx = jnp.where(is_mine & tok_valid, idx, s_loc)  # s_loc => dropped
        kc_all = kc_all.at[layer, bi, idx].set(k1.astype(kc_all.dtype),
                                               mode="drop")
        vc_all = vc_all.at[layer, bi, idx].set(v1.astype(vc_all.dtype),
                                               mode="drop")
        return kc_all, vc_all

    def _chunk_attn_layer(self, x, w, kc_all, vc_all, layer, pos, n_valid,
                          cos, sin, mlp, block_tables=None):
        """One attention layer over a (B, Lq, d) chunk: write the chunk's KV
        into the ring first, then attend the full ring (the chunk sees its
        own earlier tokens AND every previously-prefilled chunk through the
        cache, exactly like decode sees the prefix)."""
        m, cfg = self.m, self.m.cfg
        b, lq, _ = x.shape
        h = L.rms_norm(x, w["attn_norm"], cfg.norm_eps)
        q_all, k1, v1 = attn_mod.chunk_new_kv(h, w, m.acfg, cos, sin)
        kc_all, vc_all = self._write_chunk_kv(kc_all, vc_all, layer, k1, v1,
                                              pos, n_valid,
                                              block_tables=block_tables)
        kc = lax.dynamic_index_in_dim(kc_all, layer, 0, keepdims=False)
        vc = lax.dynamic_index_in_dim(vc_all, layer, 0, keepdims=False)
        o = attn_mod.chunk_attend(q_all, kc, vc, m.acfg, pos, self.spec.cache_len,
                                  block_tables=block_tables,
                                  block_size=self.spec.kv_block_size)
        hp = o.shape[2]
        a = attn_mod.decode_out_proj(o.reshape(b * lq, hp, cfg.head_dim), w,
                                     m.acfg, x.dtype)
        x = x + a.reshape(b, lq, -1)
        h = L.rms_norm(x, w["mlp_norm"], cfg.norm_eps)
        if mlp == "dense":
            x = x + L.swiglu_mlp(h, w["w_gate"], w["w_up"], w["w_down"])
        else:  # moe — no_drop: expert capacity must never let padding or
            # co-resident lanes evict a valid token's expert slot (slot
            # isolation), so the chunk path dispatches drop-free
            y, _ = moe_mod.moe_layer(
                h.reshape(b * lq, -1),
                {k: w[k] for k in ("router", "w_gate", "w_up", "w_down")},
                m.ecfg, no_drop=True)
            x = x + y.reshape(b, lq, -1)
        return x, kc_all, vc_all

    def _chunk_attn_stack(self, params, prefix, x, cache, pos, n_valid, cos,
                          sin, key, mlp, block_tables=None):
        m = self.m
        grp = m._group(params, prefix)
        names = list(grp.keys())

        def body(carry, inp):
            x, kc_all, vc_all = carry
            idx, lw = inp
            lkey = jax.random.fold_in(key, idx)
            # mlp=None: same gather routing as whole-prompt prefill, so the
            # dequantized weights are bit-identical between the two paths.
            w = self._gather_layer_w(prefix, names, lw, lkey, mlp=None)
            x, kc_all, vc_all = self._chunk_attn_layer(
                x, w, kc_all, vc_all, idx, pos, n_valid, cos, sin, mlp,
                block_tables=block_tables)
            return (x, kc_all, vc_all), None

        nl = jax.tree.leaves(grp)[0].shape[0]
        (x, k_new, v_new), _ = lax.scan(
            body, (x, cache["k"], cache["v"]), (jnp.arange(nl), grp))
        return x, dict(cache, k=k_new, v=v_new)

    def prefill_chunk_fn(self, params: Params, cache: Cache,
                         tokens: jax.Array, offset: jax.Array,
                         n_valid: jax.Array, key: jax.Array,
                         sample: Optional[dict] = None,
                         block_tables: Optional[jax.Array] = None
                         ) -> tuple[jax.Array, Cache]:
        """Offset-aware chunked prefill fused over the WHOLE slot pool.

        tokens (B_loc, Lb): one right-padded prompt chunk per slot, Lb the
        bucket length (the scheduler pads chunks into a bounded bucket set
        so the jit cache holds at most n_buckets traces).  offset (B_loc,)
        is each slot's chunk start position, n_valid (B_loc,) its real
        chunk length (0 = lane not prefilling this step: nothing is read
        from or written to that lane).  Writes each chunk's KV into the
        slot's ring at its offsets and returns (next_tokens (B_loc,),
        cache) — next_tokens is meaningful only for lanes whose chunk ends
        the prompt (sampled from the last valid position with
        n_consumed = offset + n_valid, identical to whole-prompt prefill's
        keying), garbage elsewhere.

        Same gather key / per-layer fold_in as prefill_fn and decode_fn, so
        the dequantized weights are bit-identical to the whole-prompt path.
        Supported for CHUNKED_PREFILL_ARCHS (pure attention stacks)."""
        m, cfg = self.m, self.m.cfg
        if cfg.arch_type not in CHUNKED_PREFILL_ARCHS:
            raise NotImplementedError(
                f"chunked prefill supports {CHUNKED_PREFILL_ARCHS}, "
                f"not {cfg.arch_type!r}")
        if self.spec.paged and block_tables is None:
            raise ValueError("paged DecodeSpec requires block_tables")
        b, lq = tokens.shape
        offset = jnp.asarray(offset, jnp.int32)
        n_valid = jnp.asarray(n_valid, jnp.int32)
        emb = m.engine.gather("embed", params["embed"], key)
        x = L.embed_vocab_parallel(tokens, emb)  # (B, Lq, d)
        pos = offset[:, None] + jnp.arange(lq, dtype=jnp.int32)[None, :]
        cos, sin = L.rope_cos_sin(pos, cfg.head_dim, cfg.rope_theta)
        x, cache = self._chunk_attn_stack(
            params, "layers", x, cache, pos, n_valid, cos, sin, key,
            mlp="moe" if cfg.is_moe else "dense", block_tables=block_tables)
        fn = m.engine.gather("final_norm", params["final_norm"], key)
        last = jnp.clip(n_valid - 1, 0, lq - 1)
        h = L.rms_norm(x[jnp.arange(b), last], fn, cfg.norm_eps)
        head = emb if cfg.tie_embeddings else m.engine.gather(
            "lm_head", params["lm_head"], key)
        logits = L.vocab_parallel_logits(h, head)
        nxt = self._sample(logits, head.shape[0], sample, offset + n_valid,
                           valid=n_valid > 0)
        return nxt.astype(jnp.int32), cache

    def _decode_mamba_layer(self, x, w, conv, ssm):
        m, cfg = self.m, self.m.cfg
        h = L.rms_norm(x, w["pre_norm"], cfg.norm_eps)
        mw = {k: v for k, v in w.items() if k != "pre_norm"}
        y, conv, ssm = mamba_mod.mamba2_decode(h, mw, m.mcfg, conv, ssm)
        return x + y, conv, ssm

    def _decode_mamba_stack(self, params, x, cache, key, prefix="layers",
                            grp=None, conv=None, ssm=None, key_base=0,
                            layer_offset=0):
        """Scan mamba layers with the stacked (conv, ssm) state as CARRY,
        updating each layer's slice in place (same rationale as the
        attention cache — §Perf P2-1)."""
        m = self.m
        grp = grp if grp is not None else m._group(params, prefix)
        names = list(grp.keys())
        external = conv is not None
        conv = conv if external else cache["conv"]
        ssm = ssm if external else cache["ssm"]

        def body(carry, inp):
            x, conv_all, ssm_all = carry
            idx, lw = inp
            lkey = jax.random.fold_in(key, key_base + idx)
            w = m.engine.gather_layer(f"{prefix}/", {n: lw[n] for n in names}, lkey)
            li = layer_offset + idx
            cv = lax.dynamic_index_in_dim(conv_all, li, 0, keepdims=False)
            st = lax.dynamic_index_in_dim(ssm_all, li, 0, keepdims=False)
            x, cv, st = self._decode_mamba_layer(x, w, cv, st)
            conv_all = lax.dynamic_update_slice_in_dim(
                conv_all, cv[None].astype(conv_all.dtype), li, 0)
            ssm_all = lax.dynamic_update_slice_in_dim(
                ssm_all, st[None].astype(ssm_all.dtype), li, 0)
            return (x, conv_all, ssm_all), None

        nl = grp[names[0]].shape[0]
        (x, conv_new, ssm_new), _ = lax.scan(
            body, (x, conv, ssm), (jnp.arange(nl), grp))
        if not external:
            return x, dict(cache, conv=conv_new, ssm=ssm_new)
        return x, conv_new, ssm_new

    def _decode_hybrid(self, params, x, cache, pos, cos, sin, key):
        m, cfg = self.m, self.m.cfg
        every = cfg.hybrid_attn_every
        n_groups, rem = divmod(cfg.n_layers, every)
        grp = m._group(params, "layers")
        main = {k: v[: n_groups * every].reshape(n_groups, every, *v.shape[1:])
                for k, v in grp.items()}
        tail = {k: v[n_groups * every:] for k, v in grp.items()}

        shared_names = [n for n in
                        ["attn_norm", "wq", "wk", "wv", "wo", "bq", "bk", "bv",
                         "mlp_norm", "w_gate", "w_up", "w_down"]
                        if f"shared/{n}" in params]
        mamba_names = list(grp.keys())

        def group_body(carry, inp):
            x, conv_all, ssm_all, kc_all, vc_all = carry
            gidx, gw = inp
            gkey = jax.random.fold_in(key, 1000 + gidx)

            def layer_body(inner, inp2):
                x, conv_all, ssm_all = inner
                li_in_g, lw = inp2
                lkey = jax.random.fold_in(gkey, li_in_g)
                w = m.engine.gather_layer(
                    "layers/", {n: lw[n] for n in mamba_names}, lkey)
                li = gidx * every + li_in_g
                cv = lax.dynamic_index_in_dim(conv_all, li, 0, keepdims=False)
                st = lax.dynamic_index_in_dim(ssm_all, li, 0, keepdims=False)
                x, cv, st = self._decode_mamba_layer(x, w, cv, st)
                conv_all = lax.dynamic_update_slice_in_dim(
                    conv_all, cv[None].astype(conv_all.dtype), li, 0)
                ssm_all = lax.dynamic_update_slice_in_dim(
                    ssm_all, st[None].astype(ssm_all.dtype), li, 0)
                return (x, conv_all, ssm_all), None

            (x, conv_all, ssm_all), _ = lax.scan(
                layer_body, (x, conv_all, ssm_all), (jnp.arange(every), gw))
            skey = jax.random.fold_in(key, 5000 + gidx)
            w = self._gather_layer_w(
                "shared", shared_names,
                {n: params[f"shared/{n}"] for n in shared_names}, skey,
                mlp="dense")
            x, kc_all, vc_all = self._decode_attn_layer(
                x, w, kc_all, vc_all, gidx, pos, cos, sin, "dense")
            return (x, conv_all, ssm_all, kc_all, vc_all), None

        (x, conv_new, ssm_new, k_new, v_new), _ = lax.scan(
            group_body,
            (x, cache["conv"], cache["ssm"], cache["shared_k"], cache["shared_v"]),
            (jnp.arange(n_groups), main))
        if rem:
            x, conv_new, ssm_new = self._decode_mamba_stack(
                params, x, None, jax.random.fold_in(key, 2000), grp=tail,
                conv=conv_new, ssm=ssm_new, layer_offset=n_groups * every)
        return x, dict(cache, conv=conv_new, ssm=ssm_new,
                       shared_k=k_new, shared_v=v_new)

    def _decode_audio(self, params, x, cache, pos, cos, sin, key):
        m, cfg = self.m, self.m.cfg
        grp = m._group(params, "dec")
        names = list(grp.keys())
        enc_len = jnp.asarray(self.spec.enc_len, jnp.int32)

        def body(carry, inp):
            x, kc_all, vc_all = carry
            idx, lw, ck, cv = inp
            lkey = jax.random.fold_in(key, idx)
            w = self._gather_layer_w("dec", names, lw, lkey, mlp="dense")
            h = L.rms_norm(x, w["attn_norm"], cfg.norm_eps)
            q_all, k1, v1 = attn_mod.decode_new_kv(h, w, m.acfg, cos, sin)
            kc_all, vc_all = self._write_token_kv(kc_all, vc_all, idx, k1, v1, pos)
            kc = lax.dynamic_index_in_dim(kc_all, idx, 0, keepdims=False)
            vc = lax.dynamic_index_in_dim(vc_all, idx, 0, keepdims=False)
            o = attn_mod.decode_attend(q_all, kc, vc, m.acfg, pos, self.spec.cache_len)
            x = x + attn_mod.decode_out_proj(o, w, m.acfg, x.dtype)
            h = L.rms_norm(x, w["xattn_norm"], cfg.norm_eps)
            xw = {"wq": w["xwq"], "wk": w["xwk"], "wv": w["xwv"], "wo": w["xwo"]}
            x = x + attn_mod.decode_cross_attention(h, xw, m.acfg, ck, cv, enc_len)
            h = L.rms_norm(x, w["mlp_norm"], cfg.norm_eps)
            x = x + L.swiglu_mlp(h, w["w_gate"], w["w_up"], w["w_down"])
            return (x, kc_all, vc_all), None

        nl = grp[names[0]].shape[0]
        (x, k_new, v_new), _ = lax.scan(
            body, (x, cache["k"], cache["v"]),
            (jnp.arange(nl), grp, cache["ck"], cache["cv"]))
        return x, dict(cache, k=k_new, v=v_new)

    # ------------------------------------------------------------------
    # Prefill (build caches from a full prompt)
    # ------------------------------------------------------------------

    def prefill_fn(self, params: Params, batch: dict, key: jax.Array,
                   sample: Optional[dict] = None) -> tuple[jax.Array, Cache]:
        """batch: same leaves as training minus labels.  Returns
        (next_tokens (B_loc,) from the last position, cache).

        sample: optional per-slot sampling state (see decode_fn); the first
        generated token is keyed by fold_in(slot key, prompt length)."""
        m, cfg = self.m, self.m.cfg
        if self.spec.paged:
            raise NotImplementedError(
                "whole-prompt prefill is ring-only; paged specs must use "
                "chunked prefill (prefill_chunk_fn)")
        tokens = batch["tokens"]
        b, s = tokens.shape
        if self.m.cfg.has_attention:
            assert self.spec.cache_len >= s, "prefill prompt exceeds the cache ring"
        emb = m.engine.gather("embed", params["embed"], key)
        x = L.embed_vocab_parallel(tokens, emb)
        if cfg.arch_type == "vlm":
            x = jnp.where(batch["vision_mask"][..., None],
                          batch["vision_embeds"].astype(x.dtype), x)
        positions = jnp.arange(s)
        cos, sin = m._rope(batch, s)

        cache: Cache = {}
        if cfg.arch_type in ("dense", "vlm", "moe"):
            x, cache = self._prefill_attn_stack(params, "layers", x, key, cos, sin, positions,
                                                mlp="moe" if cfg.is_moe else "dense")
        elif cfg.arch_type == "ssm":
            x, conv, ssm = self._prefill_mamba_stack(params, x, key)
            cache = {"conv": conv, "ssm": ssm}
        elif cfg.arch_type == "hybrid":
            x, cache = self._prefill_hybrid(params, x, key, cos, sin, positions)
        elif cfg.arch_type == "audio":
            x, cache = self._prefill_audio(params, batch, x, key, cos, sin, positions)
        else:
            raise ValueError(cfg.arch_type)

        fn = m.engine.gather("final_norm", params["final_norm"], key)
        h = L.rms_norm(x[:, -1], fn, cfg.norm_eps)
        head = emb if cfg.tie_embeddings else m.engine.gather("lm_head", params["lm_head"], key)
        logits = L.vocab_parallel_logits(h, head)
        nxt = self._sample(logits, head.shape[0], sample,
                           jnp.full((b,), s, jnp.int32))
        return nxt.astype(jnp.int32), cache

    def _slice_seq(self, kv: jax.Array) -> jax.Array:
        """(B, S, n_kv, hd) full-seq KV -> this rank's S_loc ring chunk
        (zero-padded when the prompt is shorter than the ring)."""
        rank = lax.axis_index("model")
        b, s, nk, hd = kv.shape
        pad = self.spec.cache_len - s
        if pad:
            kv = jnp.pad(kv, ((0, 0), (0, pad), (0, 0), (0, 0)))
        return lax.dynamic_slice(kv, (0, rank * self.s_loc, 0, 0),
                                 (b, self.s_loc, nk, hd))

    def _prefill_attn_layer(self, x, w, cos, sin, positions, mlp):
        m, cfg = self.m, self.m.cfg
        h = L.rms_norm(x, w["attn_norm"], cfg.norm_eps)
        a, (kf, vf) = attn_mod.self_attention(h, w, m.acfg, cos, sin, positions,
                                              cache_slice=True)
        x = x + a
        h = L.rms_norm(x, w["mlp_norm"], cfg.norm_eps)
        if mlp == "dense":
            x = x + L.swiglu_mlp(h, w["w_gate"], w["w_up"], w["w_down"])
        else:
            bb, ss, d = h.shape
            y, _ = moe_mod.moe_layer(h.reshape(bb * ss, d),
                                     {k: w[k] for k in ("router", "w_gate", "w_up", "w_down")},
                                     m.ecfg)
            x = x + y.reshape(bb, ss, d)
        kc = self._slice_seq(kf).astype(jnp.bfloat16)
        vc = self._slice_seq(vf).astype(jnp.bfloat16)
        return x, kc, vc

    def _prefill_attn_stack(self, params, prefix, x, key, cos, sin, positions, mlp):
        m = self.m
        grp = m._group(params, prefix)
        names = list(grp.keys())

        def body(x, inp):
            idx, lw = inp
            lkey = jax.random.fold_in(key, idx)
            # mlp=None: rowquant stays a decode-only optimization in prefill,
            # but wire-form (QuantizedParam) leaves still route to their
            # code-form gather.
            w = self._gather_layer_w(prefix, names, lw, lkey, mlp=None)
            x, kc, vc = self._prefill_attn_layer(x, w, cos, sin, positions, mlp)
            return x, (kc, vc)

        nl = jax.tree.leaves(grp)[0].shape[0]
        x, (k, v) = lax.scan(jax.checkpoint(body), x, (jnp.arange(nl), grp))
        return x, {"k": k, "v": v}

    def _prefill_mamba_stack(self, params, x, key, prefix="layers", grp=None, key_base=0):
        m, cfg = self.m, self.m.cfg
        grp = grp if grp is not None else m._group(params, prefix)
        names = list(grp.keys())

        def body(x, inp):
            idx, lw = inp
            lkey = jax.random.fold_in(key, key_base + idx)
            w = m.engine.gather_layer(f"{prefix}/", {n: lw[n] for n in names}, lkey)
            h = L.rms_norm(x, w["pre_norm"], cfg.norm_eps)
            mw = {k: v for k, v in w.items() if k != "pre_norm"}
            y, (cx, cbc, hf) = mamba_mod.mamba2_block(h, mw, m.mcfg, return_state=True)
            conv = jnp.concatenate([cx, cbc.astype(cx.dtype)], axis=-1).astype(jnp.float32)
            return x + y, (conv, hf.astype(jnp.float32))

        nl = grp[names[0]].shape[0]
        x, (conv, ssm) = lax.scan(jax.checkpoint(body), x, (jnp.arange(nl), grp))
        return x, conv, ssm

    def _prefill_hybrid(self, params, x, key, cos, sin, positions):
        m, cfg = self.m, self.m.cfg
        every = cfg.hybrid_attn_every
        n_groups, rem = divmod(cfg.n_layers, every)
        grp = m._group(params, "layers")
        main = {k: v[: n_groups * every].reshape(n_groups, every, *v.shape[1:])
                for k, v in grp.items()}
        tail = {k: v[n_groups * every:] for k, v in grp.items()}
        shared_names = [n for n in
                        ["attn_norm", "wq", "wk", "wv", "wo", "bq", "bk", "bv",
                         "mlp_norm", "w_gate", "w_up", "w_down"]
                        if f"shared/{n}" in params]

        def group_body(x, inp):
            gidx, gw = inp
            gkey = jax.random.fold_in(key, 1000 + gidx)
            x, conv, ssm = self._prefill_mamba_stack(params, x, gkey, grp=gw)
            skey = jax.random.fold_in(key, 5000 + gidx)
            w = m.engine.gather_layer(
                "shared/", {n: params[f"shared/{n}"] for n in shared_names}, skey)
            x, kc, vc = self._prefill_attn_layer(x, w, cos, sin, positions, "dense")
            return x, (conv, ssm, kc, vc)

        x, (cm, sm, k, v) = lax.scan(jax.checkpoint(group_body), x, (jnp.arange(n_groups), main))
        conv = cm.reshape(n_groups * every, *cm.shape[2:])
        ssm = sm.reshape(n_groups * every, *sm.shape[2:])
        if rem:
            x, ct, st = self._prefill_mamba_stack(
                params, x, jax.random.fold_in(key, 2000), grp=tail)
            conv = jnp.concatenate([conv, ct], axis=0)
            ssm = jnp.concatenate([ssm, st], axis=0)
        return x, {"conv": conv, "ssm": ssm, "shared_k": k, "shared_v": v}

    def _prefill_audio(self, params, batch, x, key, cos, sin, positions):
        m, cfg = self.m, self.m.cfg
        audio = batch["audio_embeds"].astype(m.compute_dtype)
        b, s_enc, _ = audio.shape
        cos_e, sin_e = L.rope_cos_sin(jnp.arange(s_enc), cfg.head_dim, cfg.rope_theta)
        # offset-3000 encoder key scope — must match Model._loss_encdec
        # (qlint QK201: enc/dec layers share short names; a shared parent
        # key would correlate their quantization noise)
        mem = m._scan_layers(params, "enc", audio,
                             jax.random.fold_in(key, 3000), cos_e, sin_e,
                             jnp.arange(s_enc), m._enc_layer)
        efn = m.engine.gather("enc_final_norm", params["enc_final_norm"], key)
        mem = L.rms_norm(mem, efn, cfg.norm_eps)

        grp = m._group(params, "dec")
        names = list(grp.keys())
        dec = m._dec_layer_factory(mem)

        def body(x, inp):
            idx, lw = inp
            lkey = jax.random.fold_in(key, idx)
            w = m.engine.gather_layer("dec/", {n: lw[n] for n in names}, lkey)
            # self-attn with cache slice
            h = L.rms_norm(x, w["attn_norm"], cfg.norm_eps)
            a, (kf, vf) = attn_mod.self_attention(h, w, m.acfg, cos, sin, positions,
                                                  cache_slice=True)
            x = x + a
            h = L.rms_norm(x, w["xattn_norm"], cfg.norm_eps)
            xw = {"wq": w["xwq"], "wk": w["xwk"], "wv": w["xwv"], "wo": w["xwo"]}
            x = x + attn_mod.cross_attention(h, mem, xw, m.acfg)
            h = L.rms_norm(x, w["mlp_norm"], cfg.norm_eps)
            x = x + L.swiglu_mlp(h, w["w_gate"], w["w_up"], w["w_down"])
            # cross-KV cache: computed from memory with this layer's weights
            mi = mem
            ck = (mi @ w["xwk"]).reshape(b, s_enc, m.acfg.kv_local, cfg.head_dim)
            cvv = (mi @ w["xwv"]).reshape(b, s_enc, m.acfg.kv_local, cfg.head_dim)
            if m.acfg.kv_mode == "tp":
                ck = lax.all_gather(ck, "model", axis=2, tiled=True)
                cvv = lax.all_gather(cvv, "model", axis=2, tiled=True)
            rank = lax.axis_index("model")
            e_loc = self.spec.enc_len // self.tp
            ck = lax.dynamic_slice(ck, (0, rank * e_loc, 0, 0),
                                   (b, e_loc, m.acfg.n_kv, cfg.head_dim))
            cvv = lax.dynamic_slice(cvv, (0, rank * e_loc, 0, 0),
                                    (b, e_loc, m.acfg.n_kv, cfg.head_dim))
            kc = self._slice_seq(kf).astype(jnp.bfloat16)
            vc = self._slice_seq(vf).astype(jnp.bfloat16)
            return x, (kc, vc, ck.astype(jnp.bfloat16), cvv.astype(jnp.bfloat16))

        nl = grp[names[0]].shape[0]
        x, (k, v, ck, cv) = lax.scan(jax.checkpoint(body), x, (jnp.arange(nl), grp))
        return x, {"k": k, "v": v, "ck": ck, "cv": cv}
