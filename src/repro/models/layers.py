"""Shared model building blocks (per-device code, shard_map-native).

Conventions (see core/tp.py):
  * activations entering TP-sharded compute pass through tp_copy;
  * row-parallel outputs pass through tp_reduce;
  * everything here consumes *gathered, TP-local* weights (the QSDP engine
    materializes them per layer inside the step).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax

from ..core.tp import tp_copy, tp_reduce
from ..kernels.ops import RowQuantWeight, rowquant_matmul_dispatch

# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def rms_norm(x: jax.Array, w: jax.Array, eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    return (x * lax.rsqrt(var + eps)).astype(dt) * w.astype(dt)


def layer_norm(x: jax.Array, w: jax.Array, b: jax.Array, eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mu) ** 2, axis=-1, keepdims=True)
    y = (x - mu) * lax.rsqrt(var + eps)
    return y.astype(dt) * w.astype(dt) + b.astype(dt)


# ---------------------------------------------------------------------------
# RoPE (1-D and multimodal M-RoPE)
# ---------------------------------------------------------------------------


def rope_cos_sin(positions: jax.Array, head_dim: int, theta: float) -> tuple[jax.Array, jax.Array]:
    """positions (...,) -> cos/sin of shape (..., head_dim//2), f32."""
    half = head_dim // 2
    freqs = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.cos(ang), jnp.sin(ang)


def mrope_cos_sin(
    positions: jax.Array, head_dim: int, theta: float, sections: tuple[int, ...]
) -> tuple[jax.Array, jax.Array]:
    """Qwen2-VL multimodal RoPE.

    positions: (3, ...) — temporal / height / width position streams.
    The head_dim//2 rotary frequencies are partitioned into `sections`
    (sum(sections) == head_dim//2); section s rotates with positions[s].
    """
    half = head_dim // 2
    assert sum(sections) == half, (sections, half)
    freqs = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    ang_parts = []
    start = 0
    for s, sec in enumerate(sections):
        ang_parts.append(positions[s].astype(jnp.float32)[..., None] * freqs[start : start + sec])
        start += sec
    ang = jnp.concatenate(ang_parts, axis=-1)
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """x: (..., n_heads, head_dim); cos/sin: broadcastable (..., head_dim//2).

    Uses the interleaved-halves convention (rotate_half), matching
    Llama/Qwen-family checkpoints.
    """
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    c = cos[..., None, :].astype(x.dtype)
    s = sin[..., None, :].astype(x.dtype)
    return jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s], axis=-1)


# ---------------------------------------------------------------------------
# Vocab-parallel embedding + fused cross-entropy
# ---------------------------------------------------------------------------


def embed_vocab_parallel(tokens: jax.Array, emb_local: jax.Array) -> jax.Array:
    """tokens (B, S) int32; emb_local (V_local, d) — this rank's vocab shard.

    Out-of-shard ids contribute zero; tp_reduce combines the shards.
    Output (B, S, d), replicated over the model axis.
    """
    v_local = emb_local.shape[0]
    rank = lax.axis_index("model")
    ids = tokens - rank * v_local
    in_range = (ids >= 0) & (ids < v_local)
    ids = jnp.clip(ids, 0, v_local - 1)
    out = jnp.take(emb_local, ids, axis=0)
    out = jnp.where(in_range[..., None], out, 0)
    return tp_reduce(out)


@jax.custom_vjp
def vocab_parallel_xent(h: jax.Array, w_local: jax.Array, labels: jax.Array) -> jax.Array:
    """Mean token cross-entropy with vocab-parallel logits.

    h: (T, d) final hidden states (replicated over model)
    w_local: (V_local, d) — this rank's shard of the (tied/untied) LM head
    labels: (T,) int32 global ids; negative labels are masked out.

    Never materializes full-vocab logits on one device; the backward
    recomputes the local logits (remat) and returns exact gradients.
    The result is replicated over the model axis; h's cotangent is the
    full (model-summed) one, as required by the tp_copy convention.
    """
    loss, _, _ = _xent_fwd_math(h, w_local, labels)
    return loss


def _xent_fwd_math(h, w_local, labels):
    v_local = w_local.shape[0]
    rank = lax.axis_index("model")
    logits = (h.astype(jnp.float32)) @ (w_local.astype(jnp.float32)).T  # (T, V_local)
    m_loc = jnp.max(logits, axis=-1)
    m = lax.pmax(m_loc, "model")  # fwd-only (custom_vjp controls AD)
    se_loc = jnp.sum(jnp.exp(logits - m[:, None]), axis=-1)
    se = lax.psum(se_loc, "model")
    lse = jnp.log(se) + m  # (T,)
    ids = labels - rank * v_local
    in_range = (ids >= 0) & (ids < v_local)
    ids_c = jnp.clip(ids, 0, v_local - 1)
    picked = jnp.take_along_axis(logits, ids_c[:, None], axis=1)[:, 0]
    tgt = lax.psum(jnp.where(in_range, picked, 0.0), "model")  # (T,)
    mask = (labels >= 0).astype(jnp.float32)
    n = jnp.maximum(jnp.sum(mask), 1.0)
    loss = jnp.sum((lse - tgt) * mask) / n
    return loss, (m, se, n), (ids_c, in_range, mask)


def _xent_fwd(h, w_local, labels):
    loss, (m, se, n), _ = _xent_fwd_math(h, w_local, labels)
    return loss, (h, w_local, labels, m, se, n)


def _xent_bwd(res, ct):
    h, w_local, labels, m, se, n = res
    v_local = w_local.shape[0]
    rank = lax.axis_index("model")
    hf = h.astype(jnp.float32)
    wf = w_local.astype(jnp.float32)
    logits = hf @ wf.T  # recompute (remat)
    p = jnp.exp(logits - m[:, None]) / se[:, None]  # local softmax slice
    ids = labels - rank * v_local
    in_range = (ids >= 0) & (ids < v_local)
    ids_c = jnp.clip(ids, 0, v_local - 1)
    onehot = (
        jax.nn.one_hot(ids_c, v_local, dtype=jnp.float32) * in_range[:, None].astype(jnp.float32)
    )
    mask = (labels >= 0).astype(jnp.float32)
    dlogits = (p - onehot) * (mask * ct / n)[:, None]  # (T, V_local)
    # h is replicated over model; its true cotangent sums every rank's path.
    dh = lax.psum(dlogits @ wf, "model").astype(h.dtype)
    dw = (dlogits.T @ hf).astype(w_local.dtype)
    return dh, dw, None


vocab_parallel_xent.defvjp(_xent_fwd, _xent_bwd)


def vocab_parallel_logits(h: jax.Array, w_local: jax.Array) -> jax.Array:
    """(T, d) -> (T, V_local) local logit shard (decode path, no grad)."""
    return (h.astype(jnp.float32)) @ (w_local.astype(jnp.float32)).T


def greedy_sample_vocab_parallel(logits_local: jax.Array, v_local: int) -> jax.Array:
    """Argmax over the full (model-sharded) vocab.  logits_local (T, V_local)
    -> (T,) global token ids."""
    rank = lax.axis_index("model")
    m_loc = jnp.max(logits_local, axis=-1)
    a_loc = jnp.argmax(logits_local, axis=-1) + rank * v_local
    m = lax.pmax(m_loc, "model")
    # break ties by smallest id: psum of candidates at the max
    cand = jnp.where(m_loc >= m, a_loc, jnp.iinfo(jnp.int32).max)
    return lax.pmin(cand, "model")


def sample_vocab_parallel(
    logits_local: jax.Array,  # (T, V_local) f32 local logit shard
    v_local: int,
    temp: jax.Array,  # (T,) f32 per-row temperature; <= 0 -> greedy
    top_k: jax.Array,  # (T,) int32 per-row top-k; <= 0 -> full vocab, 1 -> greedy
    key: jax.Array,  # (T, 2) uint32 per-row PRNG keys
) -> jax.Array:
    """Per-row temperature / top-k sampling over model-sharded vocab logits.

    Rows with ``temp <= 0`` or ``top_k == 1`` take the greedy argmax path
    BIT-EXACTLY (same reduction as :func:`greedy_sample_vocab_parallel`), so
    a greedy request under a sampling engine matches a pure-greedy engine.
    Sampling uses the Gumbel-max trick seeded per row, so a row's token
    depends only on its own (logits, temp, top_k, key) — never on what else
    is in the batch — which is what makes continuous-batching runs
    reproducible and slot-isolated.

    The full-vocab logits are re-assembled with one all-gather over the
    model axis; every rank then draws the SAME per-row Gumbel noise and
    takes the same argmax, so the result is model-replicated like the
    greedy path.
    """
    greedy = greedy_sample_vocab_parallel(logits_local, v_local)
    full = lax.all_gather(logits_local, "model", axis=1, tiled=True)  # (T, V)

    def row(lg, t, k, kk):
        v = lg.shape[0]
        # top-k mask: keep logits >= the k-th largest (dynamic per-row k)
        kth = jnp.take(jnp.sort(lg), v - jnp.clip(k, 1, v))
        keep = (k <= 0) | (lg >= kth)
        z = lg / jnp.maximum(t, 1e-6) + jax.random.gumbel(kk, (v,), jnp.float32)
        z = jnp.where(keep, z, -jnp.inf)
        return jnp.argmax(z).astype(jnp.int32)

    sampled = jax.vmap(row)(full, temp, top_k, key)
    use_greedy = (temp <= 0.0) | (top_k == 1)
    return jnp.where(use_greedy, greedy, sampled)


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------


def qmatmul(x: jax.Array, w) -> jax.Array:
    """x @ w where w is either a dense array or a :class:`RowQuantWeight`
    (a gathered weight still in QSDP wire-code form — consumed by the fused
    dequant-matmul kernel without materializing the dense matrix).
    Handles arbitrary leading batch dims on x."""
    if isinstance(w, RowQuantWeight):
        lead = x.shape[:-1]
        y = rowquant_matmul_dispatch(x.reshape(-1, x.shape[-1]), w)
        return y.reshape(*lead, w.codes.shape[1])
    return x @ w


def swiglu_mlp(x: jax.Array, w_gate, w_up, w_down) -> jax.Array:
    """Column-parallel gate/up, row-parallel down.  Weights may be dense
    arrays (training) or RowQuantWeights (quantized-weight decode)."""
    xi = tp_copy(x)
    g = qmatmul(xi, w_gate)
    u = qmatmul(xi, w_up)
    return tp_reduce(qmatmul(jax.nn.silu(g) * u, w_down))


def gelu_mlp(x: jax.Array, w_up: jax.Array, b_up, w_down: jax.Array, b_down) -> jax.Array:
    """Classic enc-dec FFN (GELU), column->row parallel; biases optional."""
    xi = tp_copy(x)
    u = xi @ w_up
    if b_up is not None:
        u = u + b_up.astype(u.dtype)
    y = tp_reduce(jax.nn.gelu(u) @ w_down)
    if b_down is not None:
        y = y + b_down.astype(y.dtype)
    return y
