"""Mamba2 (state-space duality) mixer — chunked SSD scan for training and
O(1)-state recurrence for decode.

TP layout: the inner dimension (d_inner = expand * d_model, heads of size
head_dim) is sharded over the model axis; B/C projections (n_groups = 1,
shared across heads) and their convs are model-replicated.  The recurrent
state never crosses devices — the paper's QSDP technique applies unchanged
to the projection weights (DESIGN.md §5), while the scan itself is local.

The chunked SSD algorithm follows Dao & Gu (2024), Listing 1:
  y_t = C_t^T h_t,  h_t = exp(dt_t A) h_{t-1} + dt_t B_t x_t^T
split into intra-chunk (quadratic within a chunk, via the 1-semiseparable
mask L) and inter-chunk (state recurrence over chunk summaries).
The gated RMSNorm is applied per-head (group-norm style) so normalization
never needs a cross-rank reduction; this is noted as a deviation from the
reference implementation's full-width norm in DESIGN.md.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
from jax import lax

from ..core.tp import tp_copy, tp_reduce

MODEL_AXIS = "model"


@dataclasses.dataclass(frozen=True)
class MambaConfig:
    d_model: int
    d_state: int  # N
    head_dim: int  # P
    expand: int
    conv_k: int
    chunk: int
    tp: int

    @property
    def d_inner(self) -> int:
        return self.expand * self.d_model

    @property
    def n_heads(self) -> int:
        assert self.d_inner % self.head_dim == 0
        return self.d_inner // self.head_dim

    @property
    def heads_local(self) -> int:
        assert self.n_heads % self.tp == 0, (self.n_heads, self.tp)
        return self.n_heads // self.tp

    @property
    def d_inner_local(self) -> int:
        return self.heads_local * self.head_dim


def _causal_conv(x: jax.Array, w: jax.Array) -> jax.Array:
    """Depthwise causal conv. x: (B, S, C); w: (C, K)."""
    b, s, c = x.shape
    k = w.shape[1]
    y = lax.conv_general_dilated(
        x,
        w[:, None, :].transpose(2, 1, 0),  # (K, 1, C) -> spec below
        window_strides=(1,),
        padding=[(k - 1, 0)],
        dimension_numbers=("NWC", "WIO", "NWC"),
        feature_group_count=c,
    )
    return y


def segsum_decay(da_cs: jax.Array) -> jax.Array:
    """L[..., i, j, h] = exp(cumsum_i - cumsum_j) masked to j <= i."""
    q = da_cs.shape[2]
    diff = da_cs[:, :, :, None, :] - da_cs[:, :, None, :, :]
    mask = jnp.tril(jnp.ones((q, q), bool))
    return jnp.where(mask[None, None, :, :, None], jnp.exp(diff), 0.0)


def ssd_chunked(
    x: jax.Array,  # (B, S, H, P) f32
    dt: jax.Array,  # (B, S, H) f32, post-softplus (>= 0)
    a: jax.Array,  # (H,) f32, negative
    bmat: jax.Array,  # (B, S, N)
    cmat: jax.Array,  # (B, S, N)
    chunk: int,
    h0: jax.Array | None = None,  # (B, H, P, N) initial state
) -> tuple[jax.Array, jax.Array]:
    """Returns (y (B,S,H,P), final_state (B,H,P,N))."""
    b, s, h, p = x.shape
    n = bmat.shape[-1]
    q = min(chunk, s)
    pad = (-s) % q
    if pad:
        # pad to a chunk multiple with dt=0 steps: decay exp(0·a)=1 and the
        # contribution dt·B·x = 0, so the final state is exactly preserved.
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        bmat = jnp.pad(bmat, ((0, 0), (0, pad), (0, 0)))
        cmat = jnp.pad(cmat, ((0, 0), (0, pad), (0, 0)))
        s_orig = s
        s = s + pad
    else:
        s_orig = s
    l = s // q
    xc = x.reshape(b, l, q, h, p)
    dtc = dt.reshape(b, l, q, h)
    bc = bmat.reshape(b, l, q, n)
    cc = cmat.reshape(b, l, q, n)

    da = dtc * a  # (b,l,q,h)
    da_cs = jnp.cumsum(da, axis=2)

    # intra-chunk (quadratic, chunk-local)
    decay = segsum_decay(da_cs)  # (b,l,q,q,h)
    scores = jnp.einsum("blin,bljn->blij", cc, bc)
    att = scores[..., None] * decay * dtc[:, :, None, :, :]
    y = jnp.einsum("blijh,bljhp->blihp", att, xc)

    # chunk summary states
    decay_to_end = jnp.exp(da_cs[:, :, -1:, :] - da_cs)  # (b,l,q,h)
    s_chunk = jnp.einsum("bljn,bljh,bljhp->blhpn", bc, dtc * decay_to_end, xc)

    # inter-chunk recurrence
    chunk_decay = jnp.exp(da_cs[:, :, -1, :])  # (b,l,h)

    def step(hprev, inp):
        s_c, dec = inp
        return hprev * dec[:, :, None, None] + s_c, hprev

    init = jnp.zeros((b, h, p, n), x.dtype) if h0 is None else h0
    hfinal, hprevs = lax.scan(
        step,
        init,
        (s_chunk.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(1, 0, 2)),
    )
    hprevs = hprevs.transpose(1, 0, 2, 3, 4)  # (b,l,h,p,n)
    y = y + jnp.einsum("blin,blih,blhpn->blihp", cc, jnp.exp(da_cs), hprevs)
    return y.reshape(b, s, h, p)[:, :s_orig], hfinal


def _gated_headnorm(y: jax.Array, z: jax.Array, w: jax.Array, eps: float = 1e-5) -> jax.Array:
    """Per-head RMSNorm of y * silu(z).  y/z: (B, S, H, P); w: (H*P,) local."""
    b, s, h, p = y.shape
    g = y * jax.nn.silu(z)
    var = jnp.mean(g.astype(jnp.float32) ** 2, axis=-1, keepdims=True)
    g = (g.astype(jnp.float32) * lax.rsqrt(var + eps)).astype(y.dtype)
    return g.reshape(b, s, h * p) * w.astype(y.dtype)


def mamba2_block(
    x: jax.Array,  # (B, S, d) replicated over model
    w: dict,
    cfg: MambaConfig,
    return_state: bool = False,
):
    """Train/prefill forward.  Weight dict (gathered, TP-local):
    w_z, w_x: (d, d_inner_local); w_bc: (d, 2N); w_dt: (d, H_local);
    conv_x: (d_inner_local, K); conv_bc: (2N, K); a_log, dt_bias, d_skip:
    (H_local,); norm: (d_inner_local,); w_out: (d_inner_local, d).
    """
    b, s, _ = x.shape
    hl, p, n = cfg.heads_local, cfg.head_dim, cfg.d_state
    xi = tp_copy(x)
    z = xi @ w["w_z"]  # (B,S,d_il)
    xin = xi @ w["w_x"]
    # B/C weights are model-replicated but their outputs feed rank-LOCAL
    # heads (rank-specific consumption), so the path goes through tp_copy
    # and w_bc/conv_bc carry grad_sync_model=True in their ParamSpecs.
    bc = (xi @ w["w_bc"]).astype(jnp.float32)  # (B,S,2N)
    dt_raw = xi @ w["w_dt"]  # (B,S,H_local)

    xin_raw, bc_raw = xin, bc  # pre-conv inputs (decode conv-state seeds)
    xin = _causal_conv(xin, w["conv_x"].astype(xin.dtype))
    xin = jax.nn.silu(xin)
    bc = _causal_conv(bc, w["conv_bc"].astype(bc.dtype))
    bc = jax.nn.silu(bc)
    bmat, cmat = bc[..., :n], bc[..., n:]

    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + w["dt_bias"].astype(jnp.float32))
    a = -jnp.exp(w["a_log"].astype(jnp.float32))  # (H_local,)

    xh = xin.reshape(b, s, hl, p).astype(jnp.float32)
    y, h_final = ssd_chunked(xh, dt, a, bmat, cmat, cfg.chunk)
    y = y + xh * w["d_skip"].astype(jnp.float32)[None, None, :, None]
    y = y.astype(x.dtype)

    g = _gated_headnorm(y, z.reshape(b, s, hl, p), w["norm"])
    out = tp_reduce(g @ w["w_out"])
    if not return_state:
        return out
    k = cfg.conv_k
    conv_x_state = xin_raw[:, s - (k - 1):, :]  # (B, K-1, d_il)
    conv_bc_state = bc_raw[:, s - (k - 1):, :].astype(x.dtype)  # (B, K-1, 2N)
    return out, (conv_x_state, conv_bc_state, h_final)


def mamba2_decode(
    x: jax.Array,  # (B, d)
    w: dict,
    cfg: MambaConfig,
    conv_state: jax.Array,  # (B, K-1, d_inner_local + 2N)
    ssm_state: jax.Array,  # (B, H_local, P, N) f32
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Single-token recurrent step.  Returns (out, conv_state, ssm_state)."""
    b, _ = x.shape
    hl, p, n = cfg.heads_local, cfg.head_dim, cfg.d_state
    z = x @ w["w_z"]
    xin = x @ w["w_x"]
    bc = x @ w["w_bc"]
    dt_raw = x @ w["w_dt"]

    # conv over the ring of the last K-1 inputs + current
    cat = jnp.concatenate([xin, bc.astype(xin.dtype)], axis=-1)  # (B, C)
    hist = jnp.concatenate([conv_state, cat[:, None]], axis=1)  # (B, K, C)
    conv_w = jnp.concatenate(
        [w["conv_x"], w["conv_bc"].astype(w["conv_x"].dtype)], axis=0
    )  # (C, K)
    conv_out = jnp.einsum("bkc,ck->bc", hist.astype(jnp.float32), conv_w.astype(jnp.float32))
    conv_out = jax.nn.silu(conv_out)
    new_conv_state = hist[:, 1:]

    d_il = cfg.d_inner_local
    xin_c = conv_out[:, :d_il]
    bmat = conv_out[:, d_il : d_il + n]
    cmat = conv_out[:, d_il + n :]

    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + w["dt_bias"].astype(jnp.float32))  # (B,Hl)
    a = -jnp.exp(w["a_log"].astype(jnp.float32))
    da = jnp.exp(dt * a)  # (B,Hl)
    xh = xin_c.reshape(b, hl, p)
    new_state = ssm_state * da[:, :, None, None] + jnp.einsum(
        "bh,bn,bhp->bhpn", dt, bmat, xh
    )
    y = jnp.einsum("bn,bhpn->bhp", cmat, new_state)
    y = y + xh * w["d_skip"].astype(jnp.float32)[None, :, None]

    g = _gated_headnorm(
        y[:, None].astype(x.dtype), z.reshape(b, 1, hl, p), w["norm"]
    )[:, 0]
    out = lax.psum(g @ w["w_out"], MODEL_AXIS)
    return out, new_conv_state, new_state
