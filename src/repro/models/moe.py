"""Mixture-of-Experts layer with expert parallelism over the model axis.

Dispatch is sort-based (no (T, E, C) one-hot tensors): assignments are
sorted by expert id, positions within each expert computed by searchsorted,
tokens over capacity dropped (standard capacity-factor semantics), and the
(E, C, d) buffer exchanged with a single ``all_to_all`` so each rank runs
only its E/tp local experts.  The return path is the inverse all_to_all and
a weighted scatter-add combine.

Gradient notes: all_to_all's builtin transpose is its inverse all_to_all
(verified exact), scatter/gather transposes are gather/scatter — the whole
layer is exactly differentiable.  Router weights are model-replicated and
compute identically on every model rank, so their gradients agree across
replicas without extra collectives.

Expert weights are TP'd on the *expert* axis (tp_axis=0) and QSDP-gathered —
in MoE models they dominate communication volume, which is exactly where the
paper's quantized gathers pay off most (DESIGN.md §5).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
from jax import lax

from ..core.tp import tp_merge_tokens, tp_reduce, tp_split_tokens

MODEL_AXIS = "model"


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_model: int
    d_ff: int  # per-expert hidden
    tp: int
    capacity_factor: float = 1.25
    normalize_weights: bool = True  # Qwen3/OLMoE normalize top-k probs
    aux_coef: float = 0.01

    @property
    def experts_local(self) -> int:
        assert self.n_experts % self.tp == 0, (self.n_experts, self.tp)
        return self.n_experts // self.tp

    def capacity(self, n_tokens: int) -> int:
        c = int(n_tokens * self.top_k * self.capacity_factor / self.n_experts)
        return max(8, -(-c // 8) * 8)  # round up to 8 for tiling


def moe_layer(
    x: jax.Array,  # (T, d) tokens, replicated over model
    w: dict,  # router (d, E) replicated; w_gate/w_up (E_loc, d, ff); w_down (E_loc, ff, d)
    cfg: MoEConfig,
    no_drop: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """Returns (out (T, d) replicated, aux_loss scalar identical on every
    model rank).

    no_drop=True sizes the dispatch buffer to the worst case (every
    assignment to one expert) so capacity NEVER drops a token.  Standard
    capacity drops make one token's output depend on which OTHER tokens
    share the batch (they compete for expert slots) — fine for training,
    but the chunked-prefill serve path flattens every pool lane plus
    right-padding into one token batch, and slot isolation requires a
    lane's tokens to be independent of co-resident lanes and padding.
    COST: the (E, C, d) buffer, its all_to_alls, and the expert matmuls
    grow to n_experts x the balanced-load size (dense rows are zero and
    wasted) — cheap at decode/chunk token counts, but a large-E,
    long-chunk deployment should replace this with a ragged/segment
    dispatch rather than widen the dense buffer further.

    Token parallelism over the model axis: the replicated token set is
    SPLIT 1/tp per rank before routing (tp_split_tokens) so each token is
    dispatched exactly once — without this every rank would route the same
    tokens and expert FLOPs/all-to-all bytes would be duplicated tp x (a
    16x waste at TP=16; caught by the roofline's useful-flops ratio).
    Outputs are re-replicated with tp_merge_tokens (one all-gather, the
    sequence-parallel pattern).  Router gradients flow from rank-specific
    token slices, so the router ParamSpec must set grad_sync_model=True.
    """
    t_full, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    if cfg.tp > 1:
        pad_t = (-t_full) % cfg.tp
        if pad_t:
            x = jnp.pad(x, ((0, pad_t), (0, 0)))
        x = tp_split_tokens(x, 0)
    t = x.shape[0]
    c = (max(8, -(-t * k // 8) * 8) if no_drop  # worst case: zero drops
         else cfg.capacity(t))

    logits = x.astype(jnp.float32) @ w["router"].astype(jnp.float32)  # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    topw, tope = lax.top_k(probs, k)  # (T, k)
    if cfg.normalize_weights:
        topw = topw / jnp.maximum(jnp.sum(topw, axis=-1, keepdims=True), 1e-9)

    # Switch-style load-balance auxiliary loss, averaged over the token
    # slices of all model ranks (tp_reduce keeps it identical per rank; its
    # identity-backward matches the rank-specific slice convention).
    me = jnp.mean(probs, axis=0)  # (E,)
    ce = jnp.mean(
        jnp.sum(jax.nn.one_hot(tope, e, dtype=jnp.float32), axis=1), axis=0
    )  # fraction routed per expert
    aux = cfg.aux_coef * e * jnp.sum(me * ce)
    if cfg.tp > 1:
        aux = tp_reduce(aux) / cfg.tp

    # ---- sort-based dispatch ----
    tk = t * k
    flat_e = tope.reshape(tk)
    flat_w = topw.reshape(tk)
    tok_idx = jnp.repeat(jnp.arange(t), k)
    perm = jnp.argsort(flat_e, stable=True)
    se = flat_e[perm]
    sw = flat_w[perm]
    st = tok_idx[perm]
    starts = jnp.searchsorted(se, se, side="left")
    pos = jnp.arange(tk) - starts
    keep = pos < c
    pos_c = jnp.where(keep, pos, 0)

    vals = jnp.take(x, st, axis=0) * keep[:, None].astype(x.dtype)
    buf = jnp.zeros((e, c, d), x.dtype).at[se, pos_c].add(vals)

    # ---- expert-parallel exchange ----
    recv = lax.all_to_all(buf, MODEL_AXIS, split_axis=0, concat_axis=0, tiled=True)
    # (E,C,d) rows grouped as (src_rank, E_loc): regroup to (E_loc, src*C, d)
    recv = recv.reshape(cfg.tp, cfg.experts_local, c, d).transpose(1, 0, 2, 3)
    recv = recv.reshape(cfg.experts_local, cfg.tp * c, d)

    # ---- expert FFN (SwiGLU) ----
    h_g = jnp.einsum("ecd,edf->ecf", recv, w["w_gate"].astype(x.dtype))
    h_u = jnp.einsum("ecd,edf->ecf", recv, w["w_up"].astype(x.dtype))
    h = jax.nn.silu(h_g) * h_u
    y = jnp.einsum("ecf,efd->ecd", h, w["w_down"].astype(x.dtype))

    # ---- return path ----
    y = y.reshape(cfg.experts_local, cfg.tp, c, d).transpose(1, 0, 2, 3)
    y = y.reshape(cfg.n_experts, c, d)
    back = lax.all_to_all(y, MODEL_AXIS, split_axis=0, concat_axis=0, tiled=True)

    # ---- combine ----
    gathered = back[se, pos_c]  # (Tk, d)
    gathered = gathered * (sw * keep.astype(jnp.float32)).astype(x.dtype)[:, None]
    out = jnp.zeros((t, d), x.dtype).at[st].add(gathered)

    # ---- re-replicate the token outputs over the model axis ----
    if cfg.tp > 1:
        out = tp_merge_tokens(out, 0)
        if pad_t:
            out = out[:t_full]
    return out, aux
