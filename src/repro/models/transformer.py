"""Model composition: spec building + per-device forward functions for all
six architecture families, wired through the QSDP engine.

Everything in this file is *per-device* code executed inside shard_map.
Parameters arrive in the engine's rest layout ((L?, 1, 1, n_local) local
views) and are materialized per layer with quantized all-gathers inside the
(rematerialized) scan over layers — reproducing FSDP's gather -> compute ->
discard -> re-gather-in-backward schedule, with 2 AllGathers + 1
ReduceScatter per layer per step.  Each layer's params ride ONE coalesced
u8 collective (QSDPConfig.coalesce), and with QSDPConfig.prefetch the scan
is double-buffered so layer i+1's gather overlaps layer i's compute (see
_scan_layers).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import PartitionSpec as P

from ..core.qsdp import MeshSpec, ParamSpec, QSDPConfig, QSDPEngine
from . import attention as attn_mod
from . import layers as L
from . import mamba as mamba_mod
from . import moe as moe_mod
from .attention import AttnConfig
from .config import ModelConfig, ShapeConfig
from .mamba import MambaConfig
from .moe import MoEConfig

Params = dict[str, jax.Array]


# ---------------------------------------------------------------------------
# Spec building
# ---------------------------------------------------------------------------


def _attn_specs(d: int, a: AttnConfig, stack: Optional[int], bias: bool, out_scale: float) -> dict[str, ParamSpec]:
    hp = a.n_heads_padded * a.head_dim
    kvd = a.n_kv * a.head_dim
    kv_tp = a.kv_mode == "tp"
    s: dict[str, ParamSpec] = {
        "wq": ParamSpec((d, hp), tp_axis=1, stack=stack, init="scaled_normal", init_scale=1.0),
        "wk": ParamSpec((d, kvd), tp_axis=1 if kv_tp else None, stack=stack,
                        init="scaled_normal", init_scale=1.0, grad_sync_model=not kv_tp),
        "wv": ParamSpec((d, kvd), tp_axis=1 if kv_tp else None, stack=stack,
                        init="scaled_normal", init_scale=1.0, grad_sync_model=not kv_tp),
        "wo": ParamSpec((hp, d), tp_axis=0, stack=stack, init="scaled_normal", init_scale=out_scale),
    }
    if bias:
        s["bq"] = ParamSpec((hp,), tp_axis=0, stack=stack, init="zeros", quantize=False)
        s["bk"] = ParamSpec((kvd,), tp_axis=0 if kv_tp else None, stack=stack, init="zeros",
                            quantize=False, grad_sync_model=not kv_tp)
        s["bv"] = ParamSpec((kvd,), tp_axis=0 if kv_tp else None, stack=stack, init="zeros",
                            quantize=False, grad_sync_model=not kv_tp)
    return s


def _mlp_specs(d: int, ff: int, stack: Optional[int], out_scale: float) -> dict[str, ParamSpec]:
    return {
        "w_gate": ParamSpec((d, ff), tp_axis=1, stack=stack, init="scaled_normal", init_scale=1.0),
        "w_up": ParamSpec((d, ff), tp_axis=1, stack=stack, init="scaled_normal", init_scale=1.0),
        "w_down": ParamSpec((ff, d), tp_axis=0, stack=stack, init="scaled_normal", init_scale=out_scale),
    }


def _moe_specs(d: int, e: int, ffe: int, stack: Optional[int], out_scale: float) -> dict[str, ParamSpec]:
    return {
        # router consumes rank-specific token slices (token-parallel MoE
        # dispatch) -> per-rank grads are partial sums over its slice
        "router": ParamSpec((d, e), tp_axis=None, stack=stack, init="scaled_normal",
                            init_scale=1.0, quantize=False, grad_sync_model=True),
        "w_gate": ParamSpec((e, d, ffe), tp_axis=0, stack=stack, init="scaled_normal", init_scale=1.0),
        "w_up": ParamSpec((e, d, ffe), tp_axis=0, stack=stack, init="scaled_normal", init_scale=1.0),
        "w_down": ParamSpec((e, ffe, d), tp_axis=0, stack=stack, init="scaled_normal", init_scale=out_scale),
    }


def _mamba_specs(m: MambaConfig, stack: Optional[int], out_scale: float) -> dict[str, ParamSpec]:
    d, din, h, n, k = m.d_model, m.d_inner, m.n_heads, m.d_state, m.conv_k
    return {
        "w_z": ParamSpec((d, din), tp_axis=1, stack=stack, init="scaled_normal", init_scale=1.0),
        "w_x": ParamSpec((d, din), tp_axis=1, stack=stack, init="scaled_normal", init_scale=1.0),
        "w_bc": ParamSpec((d, 2 * n), tp_axis=None, stack=stack, init="scaled_normal",
                          init_scale=1.0, grad_sync_model=True),
        "w_dt": ParamSpec((d, h), tp_axis=1, stack=stack, init="scaled_normal", init_scale=1.0),
        "conv_x": ParamSpec((din, k), tp_axis=0, stack=stack, init="normal", init_scale=0.3,
                            quantize=False),
        "conv_bc": ParamSpec((2 * n, k), tp_axis=None, stack=stack, init="normal", init_scale=0.3,
                             quantize=False, grad_sync_model=True),
        "a_log": ParamSpec((h,), tp_axis=0, stack=stack, init="constant", init_scale=0.5,
                           quantize=False),
        "dt_bias": ParamSpec((h,), tp_axis=0, stack=stack, init="constant", init_scale=-4.0,
                             quantize=False),
        "d_skip": ParamSpec((h,), tp_axis=0, stack=stack, init="ones", quantize=False),
        "norm": ParamSpec((din,), tp_axis=0, stack=stack, init="ones", quantize=False),
        "w_out": ParamSpec((din, d), tp_axis=0, stack=stack, init="scaled_normal", init_scale=out_scale),
    }


def _norm_spec(d: int, stack: Optional[int]) -> ParamSpec:
    return ParamSpec((d,), tp_axis=None, stack=stack, init="ones", quantize=False)


# ---------------------------------------------------------------------------
# Model
# ---------------------------------------------------------------------------


class Model:
    """Binds ModelConfig + MeshSpec + QSDPConfig into per-device step
    functions and the parameter/cache layout."""

    def __init__(self, cfg: ModelConfig, ms: MeshSpec, qcfg: QSDPConfig):
        self.cfg = cfg
        self.ms = ms
        self.qcfg = qcfg
        tp = ms.model_size
        if cfg.has_attention:
            self.acfg = AttnConfig(
                n_heads=cfg.n_heads, n_kv=cfg.n_kv_heads, head_dim=cfg.head_dim,
                tp=tp, causal=True, sliding_window=cfg.sliding_window,
                mxu_bf16=getattr(qcfg, "attn_bf16", False),
            )
        if cfg.arch_type in ("ssm", "hybrid"):
            self.mcfg = MambaConfig(
                d_model=cfg.d_model, d_state=cfg.ssm_state, head_dim=cfg.ssm_head_dim,
                expand=cfg.ssm_expand, conv_k=cfg.ssm_conv, chunk=cfg.ssm_chunk, tp=tp,
            )
        if cfg.is_moe:
            self.ecfg = MoEConfig(
                n_experts=cfg.n_experts, top_k=cfg.moe_top_k, d_model=cfg.d_model,
                d_ff=cfg.moe_d_ff, tp=tp, capacity_factor=cfg.moe_capacity_factor,
                aux_coef=cfg.moe_aux_coef,
            )
        self.vp = cfg.padded_vocab(tp)
        self.specs = self._build_specs()
        self.engine = QSDPEngine(ms, qcfg, self.specs)
        self.compute_dtype = self.engine.compute_dtype
        if qcfg.remat_policy == "dots":
            self.remat = partial(
                jax.checkpoint,
                policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
        else:
            self.remat = jax.checkpoint

    # -- specs ---------------------------------------------------------------

    def _build_specs(self) -> dict[str, ParamSpec]:
        cfg = self.cfg
        d = cfg.d_model
        nl = cfg.n_layers
        out_scale = 1.0 / np.sqrt(2 * max(nl, 1))
        s: dict[str, ParamSpec] = {
            "embed": ParamSpec((self.vp, d), tp_axis=0, init="normal", init_scale=0.02),
            "final_norm": _norm_spec(d, None),
        }
        if not cfg.tie_embeddings:
            s["lm_head"] = ParamSpec((self.vp, d), tp_axis=0, init="normal", init_scale=0.02)

        def add(prefix: str, block: dict[str, ParamSpec]):
            for k, v in block.items():
                s[f"{prefix}/{k}"] = v

        if cfg.arch_type in ("dense", "vlm"):
            add("layers", _attn_specs(d, self.acfg, nl, cfg.qkv_bias, out_scale))
            add("layers", _mlp_specs(d, cfg.d_ff, nl, out_scale))
            s["layers/attn_norm"] = _norm_spec(d, nl)
            s["layers/mlp_norm"] = _norm_spec(d, nl)
        elif cfg.arch_type == "moe":
            add("layers", _attn_specs(d, self.acfg, nl, cfg.qkv_bias, out_scale))
            add("layers", _moe_specs(d, cfg.n_experts, cfg.moe_d_ff, nl, out_scale))
            s["layers/attn_norm"] = _norm_spec(d, nl)
            s["layers/mlp_norm"] = _norm_spec(d, nl)
        elif cfg.arch_type == "ssm":
            add("layers", _mamba_specs(self.mcfg, nl, out_scale))
            s["layers/pre_norm"] = _norm_spec(d, nl)
        elif cfg.arch_type == "hybrid":
            add("layers", _mamba_specs(self.mcfg, nl, out_scale))
            s["layers/pre_norm"] = _norm_spec(d, nl)
            # the shared transformer block, re-gathered at every invocation
            add("shared", _attn_specs(d, self.acfg, None, cfg.qkv_bias, out_scale))
            add("shared", _mlp_specs(d, cfg.d_ff, None, out_scale))
            s["shared/attn_norm"] = _norm_spec(d, None)
            s["shared/mlp_norm"] = _norm_spec(d, None)
        elif cfg.arch_type == "audio":
            ne = cfg.n_enc_layers
            add("enc", _attn_specs(d, self.acfg, ne, cfg.qkv_bias, out_scale))
            add("enc", _mlp_specs(d, cfg.d_ff, ne, out_scale))
            s["enc/attn_norm"] = _norm_spec(d, ne)
            s["enc/mlp_norm"] = _norm_spec(d, ne)
            s["enc_final_norm"] = _norm_spec(d, None)
            add("dec", _attn_specs(d, self.acfg, nl, cfg.qkv_bias, out_scale))
            add("dec", _mlp_specs(d, cfg.d_ff, nl, out_scale))
            for k, v in _attn_specs(d, self.acfg, nl, cfg.qkv_bias, out_scale).items():
                s[f"dec/x{k}"] = v  # cross-attention projections
            s["dec/attn_norm"] = _norm_spec(d, nl)
            s["dec/xattn_norm"] = _norm_spec(d, nl)
            s["dec/mlp_norm"] = _norm_spec(d, nl)
        else:
            raise ValueError(cfg.arch_type)
        return s

    # -- param / input plumbing ----------------------------------------------

    def init_params(self, key: jax.Array) -> Params:
        return self.engine.init_params(key)

    def param_pspecs(self) -> dict[str, P]:
        return self.engine.in_specs()

    def _group(self, params: Params, prefix: str) -> Params:
        pl = len(prefix) + 1
        return {k[pl:]: v for k, v in params.items() if k.startswith(prefix + "/")}

    def _gather_block(self, params: Params, prefix: str, names: list[str], key: jax.Array) -> dict:
        leaves = {n: params[f"{prefix}/{n}"] for n in names if f"{prefix}/{n}" in params}
        return self.engine.gather_layer(f"{prefix}/", leaves, key)

    # ======================================================================
    # Training
    # ======================================================================

    def loss_fn(self, params: Params, batch: dict, key: jax.Array) -> jax.Array:
        """Per-device local-mean loss for one microbatch (see core/tp.py for
        the gradient conventions)."""
        cfg = self.cfg
        if cfg.arch_type == "audio":
            return self._loss_encdec(params, batch, key)
        tokens = batch["tokens"]  # (B, S)
        b, s = tokens.shape
        emb = self.engine.gather("embed", params["embed"], key)
        x = L.embed_vocab_parallel(tokens, emb)
        if cfg.arch_type == "vlm":
            x = jnp.where(batch["vision_mask"][..., None], batch["vision_embeds"].astype(x.dtype), x)
        positions = jnp.arange(s)
        cos, sin = self._rope(batch, s)

        x = self._run_stack(params, x, key, cos, sin, positions)

        fn = self.engine.gather("final_norm", params["final_norm"], key)
        x = L.rms_norm(x, fn, cfg.norm_eps)
        head = emb if cfg.tie_embeddings else self.engine.gather("lm_head", params["lm_head"], key)
        loss = L.vocab_parallel_xent(
            x.reshape(b * s, -1), head, batch["labels"].reshape(b * s)
        )
        if cfg.is_moe:
            loss = loss + self._aux.astype(loss.dtype)
        return loss

    def _rope(self, batch: dict, s: int):
        cfg = self.cfg
        if not cfg.has_attention:
            return None, None
        if cfg.rope_mode == "mrope":
            pos3 = batch["positions"]  # (3, B, S)
            return L.mrope_cos_sin(pos3, cfg.head_dim, cfg.rope_theta, cfg.mrope_sections)
        return L.rope_cos_sin(jnp.arange(s), cfg.head_dim, cfg.rope_theta)

    # -- layer stacks ----------------------------------------------------------

    def _run_stack(self, params, x, key, cos, sin, positions):
        cfg = self.cfg
        if cfg.arch_type in ("dense", "vlm"):
            return self._scan_layers(params, "layers", x, key, cos, sin, positions,
                                     self._dense_layer)
        if cfg.arch_type == "moe":
            self._aux = jnp.zeros((), jnp.float32)
            return self._scan_layers(params, "layers", x, key, cos, sin, positions,
                                     self._moe_layer, carry_aux=True)
        if cfg.arch_type == "ssm":
            return self._scan_layers(params, "layers", x, key, cos, sin, positions,
                                     self._mamba_layer)
        if cfg.arch_type == "hybrid":
            return self._hybrid_stack(params, x, key, cos, sin, positions)
        raise ValueError(cfg.arch_type)

    def _scan_layers(self, params, prefix, x, key, cos, sin, positions, layer_fn,
                     carry_aux=False, group=None):
        """Scan over a stacked layer group, gathering each layer's params
        inside the (rematerialized) body.

        Under ``qcfg.coalesce`` each layer's params ride ONE collective
        (see QSDPEngine.gather_layer).  Under ``qcfg.prefetch`` the scan is
        additionally software-pipelined (double-buffered): iteration i
        decodes the wire buffer gathered during iteration i-1 and *issues*
        the coalesced gather for layer i+1 before computing layer i, so the
        next layer's collective overlaps this layer's compute — in the
        forward and, because the remat backward replays the same body, in
        the backward too.  The u8 wire buffer is the scan carry; a prologue
        gather feeds layer 0 and the final (wrapped-around) gather's result
        is discarded.
        """
        eng = self.engine
        grp = group if group is not None else self._group(params, prefix)
        names = list(grp.keys())
        stack = grp[names[0]].shape[0]
        pfx = f"{prefix}/"
        init = (x, jnp.zeros((), jnp.float32)) if carry_aux else x

        # prefetch rides the coalesced wire buffer through the scan carry, so
        # it only applies when the per-layer policy actually coalesces this
        # group (coalesce_max_bytes may veto it on small meshes).
        pipelined = (self.qcfg.prefetch and stack > 1
                     and eng.layer_coalesced(tuple(f"{pfx}{n}" for n in sorted(names))))
        if not pipelined:
            def body(carry, inp):
                idx, lw = inp
                lkey = jax.random.fold_in(key, idx)
                w = eng.gather_layer(pfx, {n: lw[n] for n in names}, lkey)
                return layer_fn(carry, w, cos, sin, positions), None

            out, _ = lax.scan(self.remat(body), init, (jnp.arange(stack), grp))
        else:
            wire0 = eng.gather_layer_start(
                pfx, {k: v[0] for k, v in grp.items()}, jax.random.fold_in(key, 0))

            def body(carry, inp):
                core, wire = carry
                idx, lw = inp
                lkey = jax.random.fold_in(key, idx)
                w = eng.gather_layer_finish(pfx, {n: lw[n] for n in names}, wire, lkey)
                # next layer's shards read straight from the (scan-invariant)
                # closed-over stack — no rolled copy of the params; the wrap
                # to layer 0 on the last step is the discarded epilogue gather
                nxt = jnp.mod(idx + 1, stack)
                lw_next = {n: lax.dynamic_index_in_dim(grp[n], nxt, 0, keepdims=False)
                           for n in names}
                wire_next = eng.gather_layer_start(
                    pfx, lw_next, jax.random.fold_in(key, idx + 1))
                return (layer_fn(core, w, cos, sin, positions), wire_next), None

            (out, _), _ = lax.scan(self.remat(body), (init, wire0),
                                   (jnp.arange(stack), grp))
        if carry_aux:
            x, self._aux = out
            return x
        return out

    def _dense_layer(self, x, w, cos, sin, positions):
        cfg = self.cfg
        h = L.rms_norm(x, w["attn_norm"], cfg.norm_eps)
        a, _ = attn_mod.self_attention(h, w, self.acfg, cos, sin, positions)
        x = x + a
        h = L.rms_norm(x, w["mlp_norm"], cfg.norm_eps)
        return x + L.swiglu_mlp(h, w["w_gate"], w["w_up"], w["w_down"])

    def _moe_layer(self, carry, w, cos, sin, positions):
        x, aux = carry
        cfg = self.cfg
        h = L.rms_norm(x, w["attn_norm"], cfg.norm_eps)
        a, _ = attn_mod.self_attention(h, w, self.acfg, cos, sin, positions)
        x = x + a
        h = L.rms_norm(x, w["mlp_norm"], cfg.norm_eps)
        b, s, d = h.shape
        moe_w = {k: w[k] for k in ("router", "w_gate", "w_up", "w_down")}
        y, a_l = moe_mod.moe_layer(h.reshape(b * s, d), moe_w, self.ecfg)
        return (x + y.reshape(b, s, d), aux + a_l)

    def _mamba_layer(self, x, w, cos, sin, positions):
        h = L.rms_norm(x, w["pre_norm"], self.cfg.norm_eps)
        mw = {k: v for k, v in w.items() if k != "pre_norm"}
        return x + mamba_mod.mamba2_block(h, mw, self.mcfg)

    def _shared_block(self, params, x, key, cos, sin, positions):
        w = self._gather_block(
            params, "shared",
            ["attn_norm", "wq", "wk", "wv", "wo", "bq", "bk", "bv",
             "mlp_norm", "w_gate", "w_up", "w_down"], key)
        return self._dense_layer(x, w, cos, sin, positions)

    def _hybrid_stack(self, params, x, key, cos, sin, positions):
        cfg = self.cfg
        every = cfg.hybrid_attn_every
        n_groups, rem = divmod(cfg.n_layers, every)
        grp = self._group(params, "layers")
        main = {k: v[: n_groups * every].reshape(n_groups, every, *v.shape[1:]) for k, v in grp.items()}
        tail = {k: v[n_groups * every :] for k, v in grp.items()}

        def group_body(x, inp):
            gidx, gw = inp
            gkey = jax.random.fold_in(key, 1000 + gidx)
            x = self._scan_layers(params, "layers", x, gkey, cos, sin, positions,
                                  self._mamba_layer, group=gw)
            x = self._shared_block(params, x, gkey, cos, sin, positions)
            return x, None

        x, _ = lax.scan(self.remat(group_body), x, (jnp.arange(n_groups), main))
        if rem:
            x = self._scan_layers(params, "layers", x, jax.random.fold_in(key, 2000),
                                  cos, sin, positions, self._mamba_layer, group=tail)
        return x

    # -- encoder-decoder -------------------------------------------------------

    def _enc_layer(self, x, w, cos, sin, positions):
        cfg = self.cfg
        acfg = dataclasses.replace(self.acfg, causal=False)
        h = L.rms_norm(x, w["attn_norm"], cfg.norm_eps)
        a, _ = attn_mod.self_attention(h, w, acfg, cos, sin, positions)
        x = x + a
        h = L.rms_norm(x, w["mlp_norm"], cfg.norm_eps)
        return x + L.swiglu_mlp(h, w["w_gate"], w["w_up"], w["w_down"])

    def _dec_layer_factory(self, memory):
        cfg = self.cfg

        def f(x, w, cos, sin, positions):
            h = L.rms_norm(x, w["attn_norm"], cfg.norm_eps)
            a, _ = attn_mod.self_attention(h, w, self.acfg, cos, sin, positions)
            x = x + a
            h = L.rms_norm(x, w["xattn_norm"], cfg.norm_eps)
            xw = {"wq": w["xwq"], "wk": w["xwk"], "wv": w["xwv"], "wo": w["xwo"]}
            x = x + attn_mod.cross_attention(h, memory, xw, self.acfg)
            h = L.rms_norm(x, w["mlp_norm"], cfg.norm_eps)
            return x + L.swiglu_mlp(h, w["w_gate"], w["w_up"], w["w_down"])

        return f

    def _loss_encdec(self, params, batch, key):
        cfg = self.cfg
        audio = batch["audio_embeds"].astype(self.compute_dtype)  # (B, S_enc, d)
        tokens = batch["tokens"]  # (B, S_dec)
        b, s_dec = tokens.shape
        s_enc = audio.shape[1]
        cos_e, sin_e = L.rope_cos_sin(jnp.arange(s_enc), cfg.head_dim, cfg.rope_theta)
        # the encoder stack gets its own key scope (offset 3000, same family
        # as the hybrid group offsets): enc and dec layers share short
        # names, so scanning both under `key` would derive IDENTICAL
        # quantization keys for enc[i]/wq and dec[i]/wq — correlated
        # shift-rounding noise across tensors (qlint QK201)
        mem = self._scan_layers(params, "enc", audio,
                                jax.random.fold_in(key, 3000), cos_e, sin_e,
                                jnp.arange(s_enc), self._enc_layer)
        efn = self.engine.gather("enc_final_norm", params["enc_final_norm"], key)
        mem = L.rms_norm(mem, efn, cfg.norm_eps)

        emb = self.engine.gather("embed", params["embed"], key)
        x = L.embed_vocab_parallel(tokens, emb)
        cos_d, sin_d = L.rope_cos_sin(jnp.arange(s_dec), cfg.head_dim, cfg.rope_theta)
        x = self._scan_layers(params, "dec", x, key, cos_d, sin_d,
                              jnp.arange(s_dec), self._dec_layer_factory(mem))
        fn = self.engine.gather("final_norm", params["final_norm"], key)
        x = L.rms_norm(x, fn, cfg.norm_eps)
        head = emb if cfg.tie_embeddings else self.engine.gather("lm_head", params["lm_head"], key)
        return L.vocab_parallel_xent(x.reshape(b * s_dec, -1), head,
                                     batch["labels"].reshape(b * s_dec))
