from .optimizers import (  # noqa: F401
    AdamWConfig,
    Optimizer,
    OptState,
    SGDConfig,
    cosine_schedule,
    make_adamw,
    make_sgd,
)
