"""Optimizers over QSDP rest-layout parameters.

Parameters live in the engine's rest layout — per-device flat f32 shards
(ZeRO-3): every optimizer state tensor (Adam m/v, momentum) is sharded
exactly like its parameter, so optimizer memory scales 1/(FSDP*TP) per
device.  Updates are purely elementwise, hence trivially shard_map-safe
(no collectives on the optimizer path).

The paper trains GPT with AdamW (Table 4: lr 6e-4/3e-4/2e-4, betas
(0.9, 0.95), eps 1e-8) and analyses plain SGD (Theorem 2); both are here.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

from ..core.quant import QuantConfig, QuantizedParam, qparam_decode, qparam_encode

Params = dict[str, jax.Array]


class OptState(NamedTuple):
    step: jax.Array  # () int32
    mu: Any  # first moment / momentum (pytree like params) or ()
    nu: Any  # second moment or ()


@dataclasses.dataclass(frozen=True)
class Optimizer:
    """(init, update) pair. update returns (new_params, new_state)."""

    init: Callable[[Params], OptState]
    update: Callable[[Params, Params, OptState], tuple[Params, OptState]]
    # True when this optimizer stores its mu/nu moments as QuantizedParam
    # wire codes (AdamWConfig.moment_bits) — state_pspecs keys off it.
    quantized_moments: bool = False


def cosine_schedule(
    base_lr: float, warmup_steps: int, total_steps: int, min_ratio: float = 0.1
) -> Callable[[jax.Array], jax.Array]:
    """Linear warmup then cosine decay to min_ratio * base_lr (the MosaicML
    LLM recipe the paper trains with)."""

    def lr(step: jax.Array) -> jax.Array:
        step = step.astype(jnp.float32)
        warm = base_lr * step / max(warmup_steps, 1)
        t = jnp.clip(
            (step - warmup_steps) / max(total_steps - warmup_steps, 1), 0.0, 1.0
        )
        cos = base_lr * (min_ratio + (1 - min_ratio) * 0.5 * (1 + jnp.cos(math.pi * t)))
        return jnp.where(step < warmup_steps, warm, cos)

    return lr


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 6e-4
    b1: float = 0.9
    b2: float = 0.95  # paper Table 4
    eps: float = 1e-8
    weight_decay: float = 0.0
    grad_clip: float = 1.0  # global-norm clip; 0 disables
    schedule: Optional[Callable[[jax.Array], jax.Array]] = None
    # Store mu/nu as packed wire codes (QuantizedParam) of this width, in
    # the SDP4Bit quantized-optimizer-state direction: each step decodes
    # the moment shard, applies the f32 Adam math, and re-quantizes with
    # deterministic nearest rounding (bucketed min-max keeps nu >= 0).
    # None (default) keeps exact f32 moments.
    moment_bits: Optional[int] = None
    moment_bucket_size: int = 1024


def _global_norm(tree) -> jax.Array:
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree))
    )


def _clip_by_global_norm(grads, max_norm: float):
    # NOTE: params are fully sharded (each element lives on exactly one
    # device in the (model, fsdp) grid), but each *device* only sees its
    # shard, so the true global norm needs a psum over every mesh axis.
    # The caller (train step) runs inside shard_map — use psum there via
    # the axis_names argument.
    raise NotImplementedError("use clipped_update inside the train step")


def make_adamw(cfg: AdamWConfig) -> Optimizer:
    # Optional quantized moments: nearest rounding is deterministic (no key
    # threading through the update) and the bucketed min-max affine maps
    # zeros to exact zeros, so a fresh init is represented losslessly.
    mq = (QuantConfig(bits=cfg.moment_bits, bucket_size=cfg.moment_bucket_size,
                      mode="nearest")
          if cfg.moment_bits else None)

    def _enc(m):
        return qparam_encode(m, mq) if mq is not None else m

    def _dec(m):
        return qparam_decode(m) if isinstance(m, QuantizedParam) else m

    def init(params: Params) -> OptState:
        zeros = jax.tree.map(lambda p: jnp.zeros_like(p, dtype=jnp.float32), params)
        return OptState(step=jnp.zeros((), jnp.int32),
                        mu={k: _enc(v) for k, v in zeros.items()},
                        nu={k: _enc(jnp.copy(v)) for k, v in zeros.items()})

    def update(params: Params, grads: Params, st: OptState, grad_scale: jax.Array = 1.0):
        step = st.step + 1
        lr = cfg.schedule(step) if cfg.schedule is not None else cfg.lr
        b1, b2 = cfg.b1, cfg.b2
        c1 = 1.0 - b1 ** step.astype(jnp.float32)
        c2 = 1.0 - b2 ** step.astype(jnp.float32)

        def upd(p, g, m, v):
            g = g.astype(jnp.float32) * grad_scale
            m = b1 * _dec(m) + (1 - b1) * g
            v = b2 * _dec(v) + (1 - b2) * g * g
            mh = m / c1
            vh = v / c2
            step_dir = mh / (jnp.sqrt(vh) + cfg.eps)
            if cfg.weight_decay:
                step_dir = step_dir + cfg.weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * step_dir).astype(p.dtype), _enc(m), _enc(v)

        out = {
            k: upd(params[k], grads[k], st.mu[k], st.nu[k]) for k in params
        }
        new_p = {k: o[0] for k, o in out.items()}
        new_m = {k: o[1] for k, o in out.items()}
        new_v = {k: o[2] for k, o in out.items()}
        return new_p, OptState(step=step, mu=new_m, nu=new_v)

    return Optimizer(init=init, update=update, quantized_moments=mq is not None)


@dataclasses.dataclass(frozen=True)
class SGDConfig:
    lr: float = 0.1
    momentum: float = 0.0
    weight_decay: float = 0.0
    schedule: Optional[Callable[[jax.Array], jax.Array]] = None


def make_sgd(cfg: SGDConfig) -> Optimizer:
    def init(params: Params) -> OptState:
        mu = (
            jax.tree.map(lambda p: jnp.zeros_like(p, dtype=jnp.float32), params)
            if cfg.momentum
            else ()
        )
        return OptState(step=jnp.zeros((), jnp.int32), mu=mu, nu=())

    def update(params: Params, grads: Params, st: OptState, grad_scale: jax.Array = 1.0):
        step = st.step + 1
        lr = cfg.schedule(step) if cfg.schedule is not None else cfg.lr

        def upd(p, g, m):
            g = g.astype(jnp.float32) * grad_scale
            if cfg.weight_decay:
                g = g + cfg.weight_decay * p.astype(jnp.float32)
            if cfg.momentum:
                m = cfg.momentum * m + g
                d = m
            else:
                d = g
            return (p.astype(jnp.float32) - lr * d).astype(p.dtype), m

        if cfg.momentum:
            out = {k: upd(params[k], grads[k], st.mu[k]) for k in params}
            new_p = {k: o[0] for k, o in out.items()}
            new_m = {k: o[1] for k, o in out.items()}
        else:
            out = {k: upd(params[k], grads[k], None) for k in params}
            new_p = {k: o[0] for k, o in out.items()}
            new_m = ()
        return new_p, OptState(step=step, mu=new_m, nu=())

    return Optimizer(init=init, update=update)
