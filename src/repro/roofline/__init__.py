from .analysis import (  # noqa: F401
    HW_V5E,
    Hardware,
    RooflineReport,
    collective_bytes_from_hlo,
    roofline,
)
