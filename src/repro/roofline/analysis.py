"""Three-term roofline analysis from compiled dry-run artifacts.

    compute term    = HLO_FLOPs_per_device / peak_FLOP/s_per_chip
    memory term     = HLO_bytes_per_device / HBM_bw_per_chip
    collective term = collective_wire_bytes_per_device / link_bw

FLOPs/bytes come from ``compiled.cost_analysis()`` (the partitioned,
per-device module).  Collective bytes are NOT in cost_analysis: we parse the
compiled HLO text, take each collective op's *result* shape and its
replica-group size G, and convert to per-device wire bytes with the
standard ring/all-to-all formulas:

    all-gather        R * (G-1)/G      (R = result bytes = full gathered)
    reduce-scatter    R * (G-1)        (R = scattered result; input = R*G)
    all-reduce        2R * (G-1)/G
    all-to-all        R * (G-1)/G
    collective-permute R
"""
from __future__ import annotations

import dataclasses
import re
from typing import Optional

import numpy as np

# ---------------------------------------------------------------------------
# Hardware constants (TPU v5e, per assignment)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Hardware:
    name: str
    peak_flops: float  # FLOP/s per chip (bf16)
    hbm_bw: float  # B/s per chip
    ici_bw: float  # B/s per link


HW_V5E = Hardware(name="tpu-v5e", peak_flops=197e12, hbm_bw=819e9, ici_bw=50e9)


# ---------------------------------------------------------------------------
# HLO parsing
# ---------------------------------------------------------------------------

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "f16": 2, "bf16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16,
}

_COLL_RE = re.compile(
    r"=\s*(?:\(([^)]*)\)|(\w+\[[\d,]*\][^ ]*))\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(-start)?\(",
)
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_COMP_HEAD_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s+\(.*\)\s+->\s+.*\{")
_WHILE_RE = re.compile(r"\bwhile\(.*?body=%([\w.\-]+)")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_CALL_RE = re.compile(r"\bcall\(.*?to_apply=%([\w.\-]+)")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _group_size(line: str) -> int:
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return int(m.group(2))  # [num_groups, group_size]
    m = re.search(r"replica_groups=\{\{([^}]*)\}", line)
    if m:
        return len(m.group(1).split(","))
    return 1


def _wire_bytes(kind: str, rbytes: int, g: int) -> int:
    if kind == "all-gather":
        return rbytes * (g - 1) // g
    if kind == "reduce-scatter":
        return rbytes * (g - 1)
    if kind == "all-reduce":
        return 2 * rbytes * (g - 1) // g
    if kind == "all-to-all":
        return rbytes * (g - 1) // g
    return rbytes  # collective-permute


def _split_computations(hlo_text: str) -> dict[str, list[str]]:
    comps: dict[str, list[str]] = {}
    cur = None
    for line in hlo_text.splitlines():
        m = _COMP_HEAD_RE.match(line)
        if m:
            cur = m.group(1)
            comps[cur] = []
            if line.startswith("ENTRY") or " ENTRY " in line:
                comps["__entry__"] = comps[cur]
        elif cur is not None:
            comps[cur].append(line)
    return comps


def collective_bytes_from_hlo(hlo_text: str) -> dict:
    """Per-device wire bytes per *step execution*, by collective kind.

    Collectives inside ``while`` bodies (scan-over-layers, microbatch
    accumulation) appear once in the HLO text but execute trip_count times;
    we walk the computation graph and multiply by XLA's
    ``backend_config known_trip_count`` annotations (nested loops compose).
    """
    comps = _split_computations(hlo_text)
    kinds = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
             "collective-permute")

    def analyze(name: str, seen: frozenset) -> tuple[dict, dict]:
        if name in seen or name not in comps:
            return dict.fromkeys(kinds, 0), dict.fromkeys(kinds, 0)
        byts = dict.fromkeys(kinds, 0)
        cnts = dict.fromkeys(kinds, 0)
        for line in comps[name]:
            cm = _COLL_RE.search(line)
            if cm:
                tuple_part, single, kind, is_start = cm.groups()
                type_str = tuple_part if tuple_part else single
                if is_start and tuple_part:
                    # async start result = (operand, result): use the last part
                    type_str = tuple_part.split(",")[-1]
                rbytes = _shape_bytes(type_str)
                g = _group_size(line)
                if g > 1:
                    byts[kind] += _wire_bytes(kind, rbytes, g)
                    cnts[kind] += 1
                continue
            wm = _WHILE_RE.search(line)
            if wm:
                tm = _TRIP_RE.search(line)
                trips = int(tm.group(1)) if tm else 1
                b, c = analyze(wm.group(1), seen | {name})
                for k in kinds:
                    byts[k] += trips * b[k]
                    cnts[k] += trips * c[k]
                continue
            lm = _CALL_RE.search(line)
            if lm:
                b, c = analyze(lm.group(1), seen | {name})
                for k in kinds:
                    byts[k] += b[k]
                    cnts[k] += c[k]
        return byts, cnts

    byts, cnts = analyze("__entry__", frozenset())
    out: dict = dict(byts)
    out["total"] = sum(byts.values())
    out["counts"] = cnts
    return out


# ---------------------------------------------------------------------------
# Roofline report
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    flops_per_device: float
    bytes_per_device: float
    collective_bytes: float
    collectives: dict
    t_compute: float
    t_memory: float  # upper bound (every CPU-fusion boundary hits HBM)
    t_collective: float
    bottleneck: str
    model_flops: float
    useful_flops_ratio: float
    peak_memory_bytes: Optional[float] = None
    bytes_min_per_device: float = 0.0
    t_memory_min: float = 0.0  # lower bound (perfect elementwise fusion)

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    def summary(self) -> str:
        return (
            f"{self.arch:24s} {self.shape:12s} {self.mesh:10s} "
            f"Tc={self.t_compute*1e3:9.3f}ms Tm={self.t_memory*1e3:9.3f}ms "
            f"Tcoll={self.t_collective*1e3:9.3f}ms -> {self.bottleneck:10s} "
            f"useful={self.useful_flops_ratio:6.3f}"
        )


def roofline(
    arch: str,
    shape: str,
    mesh: str,
    cost: dict,
    hlo_text: str,
    n_chips: int,
    model_flops_global: float,
    hw: Hardware = HW_V5E,
    peak_memory: Optional[float] = None,
) -> RooflineReport:
    """Three-term roofline from the compiled HLO text (trip-count aware —
    see hlo_analyzer; raw cost_analysis() counts while bodies once, which
    undercounts scan-over-layers models by ~n_layers x).  `cost` (the raw
    cost_analysis dict) is accepted for reference but the terms are derived
    from the analyzer."""
    from .hlo_analyzer import analyze_hlo

    a = analyze_hlo(hlo_text)
    flops = float(a["flops"])
    byts = float(a["traffic_bytes"])
    byts_min = float(a.get("traffic_min_bytes", byts))
    coll = a["collectives"]
    t_c = flops / hw.peak_flops
    t_m = byts / hw.hbm_bw
    t_m_min = byts_min / hw.hbm_bw
    t_x = coll["total"] / hw.ici_bw
    # bottleneck decided with the OPTIMISTIC memory bound: if even the
    # perfectly-fused traffic dominates, the step is genuinely memory-bound
    # on the target; the pessimistic bound only brackets fusion quality.
    bn = max((("compute", t_c), ("memory", t_m_min), ("collective", t_x)),
             key=lambda kv: kv[1])[0]
    mf_per_dev = model_flops_global / n_chips
    return RooflineReport(
        arch=arch, shape=shape, mesh=mesh,
        flops_per_device=flops, bytes_per_device=byts,
        collective_bytes=float(coll["total"]), collectives=coll,
        t_compute=t_c, t_memory=t_m, t_collective=t_x, bottleneck=bn,
        model_flops=mf_per_dev,
        useful_flops_ratio=(mf_per_dev / flops) if flops else 0.0,
        peak_memory_bytes=peak_memory,
        bytes_min_per_device=byts_min, t_memory_min=t_m_min,
    )
