"""Trip-count-aware HLO analyzer.

XLA's ``compiled.cost_analysis()`` reports a single execution of each
computation — collectives *and* FLOPs inside ``while`` bodies (i.e. every
scan-over-layers / microbatch loop) are counted once instead of
trip_count times (verified empirically: a scan of 7 matmuls reports the
FLOPs of one).  For a framework whose every model is a scan over layers
that is off by 50-100x, so we analyze the HLO text ourselves:

  * flops:   2*M*N*K for every ``dot`` (operand shapes resolved through the
             instruction symbol table); convolutions counted analogously.
  * traffic: bytes written + bytes read per materialized instruction
             (fusions are single instructions = XLA's materialization
             boundaries; access-only ops — tuple/gte/parameter/bitcast —
             are skipped).  This is the HBM-traffic proxy for the memory
             roofline term.
  * wire:    per-kind collective bytes with ring / all-to-all formulas.

All three are multiplied through ``while`` trip counts (XLA annotates
``backend_config known_trip_count``) and ``call`` edges.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Optional

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "f16": 2, "bf16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "token": 0, "s2": 1, "u2": 1,
    "f8e4m3fn": 1, "f8e5m2": 1,
}

_COMP_HEAD_RE = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s+\(.*\)\s+->\s+.*\{")
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s+=\s+((?:\([^)]*\))|(?:[\w\[\]{},:*\s]+?))\s+"
    r"([\w\-]+)\("
)
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")

_SKIP_TRAFFIC = {
    "tuple", "get-tuple-element", "parameter", "constant", "bitcast",
    "after-all", "partition-id", "replica-id", "iota", "copy-start",
    "copy-done", "while", "call", "conditional",
}

# Pure elementwise / layout ops that a TPU backend fuses into their
# producers/consumers: counting their results as HBM traffic models the
# CPU backend's materialization choices, not the target's.  The memory
# roofline term assumes perfect elementwise fusion and charges traffic only
# at genuine materialization points (fusions, dots, reductions, data
# movement, collectives, RNG).
_ELEMENTWISE_FUSED = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum",
    "exponential", "exponential-minus-one", "log", "log-plus-one", "tanh",
    "logistic", "rsqrt", "sqrt", "cbrt", "power", "negate", "abs",
    "compare", "select", "and", "or", "not", "xor", "convert", "broadcast",
    "reshape", "floor", "ceil", "round-nearest-afz", "round-nearest-even",
    "clamp", "sign", "shift-left", "shift-right-logical",
    "shift-right-arithmetic", "reduce-precision", "sine", "cosine", "atan2",
    "is-finite", "remainder", "map", "slice", "rem", "real", "imag",
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")


def _parse_dims(type_str: str) -> list[tuple[str, list[int]]]:
    out = []
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt in _DTYPE_BYTES:
            out.append((dt, [int(d) for d in dims.split(",") if d]))
    return out


def _type_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _parse_dims(type_str):
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES[dt]
    return total


def _group_size(line: str) -> int:
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return int(m.group(2))
    m = re.search(r"replica_groups=\{\{([^}]*)\}", line)
    if m:
        return len(m.group(1).split(","))
    if "source_target_pairs={{" in line:
        # collective-permute carries pairs, not groups; any pair means the
        # payload crosses the wire (the formula charges full result bytes).
        return 2
    return 1


def _wire_bytes(kind: str, rbytes: int, g: int) -> int:
    if kind == "all-gather":
        return rbytes * (g - 1) // g
    if kind == "reduce-scatter":
        return rbytes * (g - 1)
    if kind == "all-reduce":
        return 2 * rbytes * (g - 1) // g
    if kind == "all-to-all":
        return rbytes * (g - 1) // g
    return rbytes  # collective-permute


@dataclasses.dataclass
class Instr:
    name: str
    type_str: str
    op: str
    line: str


@dataclasses.dataclass
class CompStats:
    flops: float = 0.0
    traffic: float = 0.0  # upper bound: every CPU-fusion boundary is HBM
    traffic_min: float = 0.0  # lower bound: perfect fusion (dots, reduces,
    # data movement, collectives, RNG only)
    wire: Optional[dict] = None
    counts: Optional[dict] = None
    # launch counts per "<kind>:<dtype>" (e.g. "all-gather:u8") — separates
    # quantized-payload launches from fp metadata/fallback launches, which
    # is how the coalesced-wire regression tests assert 1 launch per layer.
    counts_dt: Optional[dict] = None


def _split(hlo_text: str) -> tuple[dict[str, list[Instr]], Optional[str]]:
    comps: dict[str, list[Instr]] = {}
    entry = None
    cur: Optional[list[Instr]] = None
    for line in hlo_text.splitlines():
        h = _COMP_HEAD_RE.match(line)
        if h:
            cur = comps.setdefault(h.group(2), [])
            if h.group(1):
                entry = h.group(2)
            continue
        if cur is None:
            continue
        m = _INSTR_RE.match(line)
        if m:
            cur.append(Instr(m.group(1), m.group(2).strip(), m.group(3), line))
    return comps, entry


def _dot_flops(instr: Instr, types: dict[str, str]) -> float:
    # result elems * 2 * contraction size
    res = _parse_dims(instr.type_str)
    if not res:
        return 0.0
    r_elems = 1
    for d in res[0][1]:
        r_elems *= d
    ops = _OPERAND_RE.findall(instr.line.split("(", 1)[1])
    lhs_type = types.get(ops[0]) if ops else None
    if lhs_type is None:
        return 0.0
    lhs_dims = _parse_dims(lhs_type)
    if not lhs_dims:
        return 0.0
    m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", instr.line)
    k = 1
    if m:
        for idx in m.group(1).split(","):
            if idx:
                k *= lhs_dims[0][1][int(idx)]
    return 2.0 * r_elems * k


def _conv_flops(instr: Instr, types: dict[str, str]) -> float:
    res = _parse_dims(instr.type_str)
    if not res:
        return 0.0
    r_elems = 1
    for d in res[0][1]:
        r_elems *= d
    ops = _OPERAND_RE.findall(instr.line.split("(", 1)[1])
    if len(ops) < 2:
        return 0.0
    ker = _parse_dims(types.get(ops[1], ""))
    if not ker:
        return 0.0
    k_elems = 1
    for d in ker[0][1]:
        k_elems *= d
    # per output element: 2 * (kernel elems / output features)
    out_feat = res[0][1][-1] if res[0][1] else 1
    return 2.0 * r_elems * (k_elems / max(out_feat, 1))


def analyze_hlo(hlo_text: str) -> dict:
    comps, entry = _split(hlo_text)
    kinds = _COLLECTIVES
    memo: dict[str, CompStats] = {}

    def run(name: str, stack: frozenset) -> CompStats:
        if name in memo:
            return memo[name]
        st = CompStats(wire=dict.fromkeys(kinds, 0), counts=dict.fromkeys(kinds, 0),
                       counts_dt={})
        if name in stack or name not in comps:
            return st
        types = {i.name: i.type_str for i in comps[name]}
        for i in comps[name]:
            if i.op == "dot":
                st.flops += _dot_flops(i, types)
            elif i.op == "convolution":
                st.flops += _conv_flops(i, types)
            elif i.op == "fusion":
                # flops of fused dots live inside the called computation
                m = re.search(r"calls=%([\w.\-]+)", i.line)
                if m:
                    sub = run(m.group(1), stack | {name})
                    st.flops += sub.flops
            elif i.op == "while":
                m = re.search(r"body=%([\w.\-]+)", i.line)
                tm = _TRIP_RE.search(i.line)
                trips = int(tm.group(1)) if tm else 1
                if m:
                    sub = run(m.group(1), stack | {name})
                    st.flops += trips * sub.flops
                    st.traffic += trips * sub.traffic
                    st.traffic_min += trips * sub.traffic_min
                    for k in kinds:
                        st.wire[k] += trips * sub.wire[k]
                        st.counts[k] += trips * sub.counts[k]
                    for k2, v in sub.counts_dt.items():
                        st.counts_dt[k2] = st.counts_dt.get(k2, 0) + trips * v
            elif i.op == "call":
                m = re.search(r"to_apply=%([\w.\-]+)", i.line)
                if m:
                    sub = run(m.group(1), stack | {name})
                    st.flops += sub.flops
                    st.traffic += sub.traffic
                    st.traffic_min += sub.traffic_min
                    for k in kinds:
                        st.wire[k] += sub.wire[k]
                        st.counts[k] += sub.counts[k]
                    for k2, v in sub.counts_dt.items():
                        st.counts_dt[k2] = st.counts_dt.get(k2, 0) + v
            elif i.op == "conditional":
                for m in re.finditer(r"(?:true_computation|false_computation|branch_computations=\{)[^,)]*%([\w.\-]+)", i.line):
                    sub = run(m.group(1), stack | {name})
                    st.flops += sub.flops
                    st.traffic += sub.traffic
                    st.traffic_min += sub.traffic_min

            base = i.op.replace("-start", "")
            if base in kinds and not i.op.endswith("-done"):
                dims = _parse_dims(i.type_str)
                if i.op.endswith("-start") and i.type_str.startswith("("):
                    # async form: (operand, result[, ...]) tuple type — the
                    # RESULT buffer is the last shape (naive comma-splitting
                    # breaks on the commas inside shapes like u8[8,32])
                    dims = dims[-1:]
                rbytes = 0
                for dt, d in dims:
                    n_el = 1
                    for x in d:
                        n_el *= x
                    rbytes += n_el * _DTYPE_BYTES[dt]
                g = _group_size(i.line)
                if g > 1:
                    st.wire[base] += _wire_bytes(base, rbytes, g)
                    st.counts[base] += 1
                    dt = dims[0][0] if dims else "?"
                    k2 = f"{base}:{dt}"
                    st.counts_dt[k2] = st.counts_dt.get(k2, 0) + 1

            if (i.op not in _SKIP_TRAFFIC and i.op not in _ELEMENTWISE_FUSED
                    and not i.op.endswith("-done")):
                w = _type_bytes(i.type_str)
                tail = i.line.split("(", 1)[1]
                tail = tail.split("metadata=")[0]
                opnames = _OPERAND_RE.findall(tail)
                # essential ops contribute to the perfect-fusion lower bound.
                # A fusion counts as essential only if its body computes
                # (holds a dot/reduce) — pure elementwise kLoop fusions are
                # assumed to merge into their neighbours on TPU.
                essential = i.op in (
                    "dot", "convolution", "reduce", "reduce-window",
                    "dynamic-slice", "dynamic-update-slice", "gather",
                    "scatter", "concatenate", "pad", "copy", "sort",
                    "transpose", "rng", "rng-bit-generator",
                    "select-and-scatter",
                ) or i.op.replace("-start", "") in kinds
                dus_update_bytes = None
                slice_fusion = False
                if i.op == "fusion":
                    m = re.search(r"calls=%([\w.\-]+)", i.line)
                    sub = run(m.group(1), stack | {name}) if m else None
                    head = i.line.split("metadata=")[0]
                    essential = (sub is not None and sub.flops > 0) or any(
                        t in head for t in ("reduce", "dynamic", "scatter",
                                            "gather", "concat", "transpose"))
                    # in-place DUS fusions: XLA aliases the big buffer
                    # (input-output aliasing), so the physical traffic is
                    # the update slice, not the whole buffer.  Detect a
                    # fused computation whose root is a dynamic-update-slice
                    # of a parameter-sized buffer and charge update bytes.
                    if m and m.group(1) in comps:
                        body = comps[m.group(1)]
                        btypes = {j.name: j.type_str for j in body}
                        dus = [j for j in body if j.op == "dynamic-update-slice"]
                        if dus and _type_bytes(i.type_str) == max(
                                (_type_bytes(j.type_str) for j in body),
                                default=0):
                            ub = 0
                            for j in dus:
                                ops_j = _OPERAND_RE.findall(
                                    j.line.split("(", 1)[1].split("metadata=")[0])
                                if len(ops_j) > 1 and ops_j[1] in btypes:
                                    ub += _type_bytes(btypes[ops_j[1]])
                                else:
                                    ub = None
                                    break
                            if ub is not None and ub < _type_bytes(i.type_str):
                                dus_update_bytes = ub
                        # slice-consuming fusions: a fusion whose body
                        # dynamic-slices a much larger operand reads only
                        # the addressed slice on real hardware (the CPU
                        # backend sometimes hoists dtype converts over the
                        # whole buffer — a backend artifact, not traffic).
                        if dus_update_bytes is None:
                            has_ds = any(j.op == "dynamic-slice" for j in body)
                            tailf = i.line.split("(", 1)[1].split("metadata=")[0]
                            opsf = [_type_bytes(types[o]) for o in
                                    _OPERAND_RE.findall(tailf) if o in types]
                            if (has_ds and opsf
                                    and _type_bytes(i.type_str) <= max(opsf) // 4):
                                slice_fusion = True
                if dus_update_bytes is not None:
                    st.traffic += 2 * dus_update_bytes
                    st.traffic_min += 2 * dus_update_bytes
                elif slice_fusion:
                    w2 = _type_bytes(i.type_str)
                    small_ops = sum(
                        _type_bytes(types[o]) for o in opnames
                        if o in types and _type_bytes(types[o]) <= 4 * w2)
                    st.traffic += 2 * w2 + small_ops
                    if essential:
                        st.traffic_min += 2 * w2 + small_ops
                elif i.op in ("dynamic-slice", "gather"):
                    # reads only the addressed slice (~ result bytes)
                    st.traffic += 2 * w
                    if essential:
                        st.traffic_min += 2 * w
                elif i.op in ("dynamic-update-slice", "scatter"):
                    # in-place buffer update: reads+writes only the update
                    upd = types.get(opnames[1]) if len(opnames) > 1 else None
                    ub = _type_bytes(upd) if upd else w
                    st.traffic += 2 * min(ub, w)
                    if essential:
                        st.traffic_min += 2 * min(ub, w)
                else:
                    r = 0
                    for opname in opnames:
                        t = types.get(opname)
                        if t is not None:
                            r += _type_bytes(t)
                    st.traffic += w + r
                    if essential:
                        st.traffic_min += w + r
        memo[name] = st
        return st

    st = run(entry or "__missing__", frozenset())
    wire = dict(st.wire)
    wire["total"] = sum(st.wire.values())
    wire["counts"] = st.counts
    wire["counts_by_dtype"] = st.counts_dt
    return {
        "flops": st.flops,
        "traffic_bytes": st.traffic,
        "traffic_min_bytes": st.traffic_min,
        "collectives": wire,
    }
