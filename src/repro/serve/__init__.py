from .common import (ServeSetup, build_serve_setup, decode_cache_len,  # noqa: F401
                     make_prompt_batch, make_serve_spec,
                     scheduler_batch_builder)
from .engine import (ServeEngine, greedy_sample_params,  # noqa: F401
                     make_sample_params)
from .scheduler import (CompletedRequest, ContinuousScheduler, Request,  # noqa: F401
                        TokenEvent)
