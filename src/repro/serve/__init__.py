from .common import (ServeSetup, build_serve_setup, decode_cache_len,  # noqa: F401
                     make_prompt_batch, make_scheduler, make_serve_spec,
                     scheduler_batch_builder)
from .engine import (ServeEngine, greedy_sample_params,  # noqa: F401
                     make_sample_params, prefill_bucket_for,
                     prefill_bucket_sizes)
from .scheduler import (CompletedRequest, ContinuousScheduler, Request,  # noqa: F401
                        TokenEvent)
