"""Shared serve-engine setup.

launch/serve.py, examples/serve_batched.py and benchmarks/bench_serve.py
all build the same stack — mesh, MeshSpec, model config (registry name or
an explicit ModelConfig), QSDP engine, ring-sized DecodeSpec, ServeEngine,
and a (tokens + modality stubs) prompt batch.  This module is the ONE place
that does it, so every entry point serves the exact same engine.  (The
scripts/check_*.py sanity scripts deliberately hand-build engine-level
variations — batch-sharded pools, solo references — that are the thing
under test.)
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from .. import configs
from ..core.qsdp import MeshSpec, QSDPConfig, step_comm_bytes
from ..models.config import ModelConfig
from ..models.decode import DecodeSpec
from ..models.transformer import Model
from .engine import ServeEngine


def decode_cache_len(cfg: ModelConfig, prompt_len: int, gen: int, tp: int) -> int:
    """KV ring size for serving `prompt_len + gen` tokens: 0 for pure-SSM
    stacks, else the total rounded up to a multiple of the model-axis size
    (the ring is sequence-sharded over it)."""
    if cfg.arch_type == "ssm":
        return 0
    ring = prompt_len + gen
    return ring + (-ring) % tp


def make_serve_spec(cfg: ModelConfig, ms: MeshSpec, batch: int,
                    prompt_len: int, gen: int, *, sampling: bool = False,
                    rowquant_mlp: bool = False,
                    batch_sharded: Optional[bool] = None,
                    kv_block_size: int = 0,
                    kv_pool_blocks: int = 0,
                    draft_bits: int = 0,
                    draft_depth: int = 0) -> DecodeSpec:
    """The DecodeSpec every serve entry point derives from (arch, shape).

    kv_block_size > 0 turns on the paged KV pool (block-table addressed;
    requires chunked prefill and an unsharded batch axis — block tables can
    point any lane at any pool row); kv_pool_blocks sizes the pool
    (0 = one full logical window per slot).  draft_bits + draft_depth > 1
    turn on self-speculative decoding (a draft_bits rowquant forward drafts
    up to draft_depth tokens per slot per step, batch-verified by the
    serving-precision model in one launch)."""
    if batch_sharded is None:
        batch_sharded = batch % ms.fsdp_size == 0 and not kv_block_size
    cache_len = decode_cache_len(cfg, prompt_len, gen, ms.model_size)
    if kv_block_size and cache_len:
        # the logical window must tile into whole blocks, and each block
        # must split evenly across the seq-sharded model axis
        kv_block_size += (-kv_block_size) % ms.model_size
        cache_len += (-cache_len) % kv_block_size
    return DecodeSpec(
        cache_len=cache_len,
        batch_global=batch,
        batch_sharded=batch_sharded,
        enc_len=max(prompt_len // cfg.enc_frames_ratio, ms.model_size)
        if cfg.arch_type == "audio" else 0,
        sampling=sampling,
        rowquant_mlp=rowquant_mlp,
        kv_block_size=kv_block_size if cache_len else 0,
        kv_pool_blocks=kv_pool_blocks,
        draft_bits=draft_bits,
        draft_depth=draft_depth,
    )


@dataclasses.dataclass
class ServeSetup:
    """Everything a serve driver needs, built identically everywhere."""
    cfg: ModelConfig
    model: Model
    params: dict
    mesh: object
    ms: MeshSpec
    spec: DecodeSpec
    engine: ServeEngine

    def decode_gather_bytes(self) -> int:
        """Analytic per-device weight-gather wire bytes of ONE decode step
        (FSDP serving re-gathers every param once per step)."""
        return step_comm_bytes(self.model.engine, gathers_per_param=1,
                               reduces_per_param=0)["weight_gather"]


def build_serve_setup(arch, *, data_par: int = 1, model_par: int = 1,
                      smoke: bool = True, qsdp: Optional[QSDPConfig] = None,
                      batch: int = 8, prompt_len: int = 32, gen: int = 16,
                      seed: int = 0, sampling: bool = False,
                      rowquant_mlp: bool = False,
                      batch_sharded: Optional[bool] = None,
                      kv_block_size: int = 0,
                      kv_pool_blocks: int = 0,
                      draft_bits: int = 0,
                      draft_depth: int = 0) -> ServeSetup:
    """Build (mesh, model, params, DecodeSpec, ServeEngine) for serving.
    `arch` is a registry name (resolved smoke/full) or a ModelConfig."""
    mesh = jax.make_mesh((data_par, model_par), ("data", "model"))
    ms = MeshSpec(axes=("data", "model"), shape=(data_par, model_par))
    if isinstance(arch, ModelConfig):
        cfg = arch
    else:
        cfg = configs.get_smoke(arch) if smoke else configs.get_config(arch)
    qsdp = qsdp if qsdp is not None else QSDPConfig()
    model = Model(cfg, ms, qsdp)
    params = model.init_params(jax.random.PRNGKey(seed))
    spec = make_serve_spec(cfg, ms, batch, prompt_len, gen, sampling=sampling,
                           rowquant_mlp=rowquant_mlp,
                           batch_sharded=batch_sharded,
                           kv_block_size=kv_block_size,
                           kv_pool_blocks=kv_pool_blocks,
                           draft_bits=draft_bits,
                           draft_depth=draft_depth)
    engine = ServeEngine(model, mesh, spec)
    return ServeSetup(cfg=cfg, model=model, params=params, mesh=mesh, ms=ms,
                      spec=spec, engine=engine)


def make_prompt_batch(cfg: ModelConfig, spec: DecodeSpec, ms: MeshSpec,
                      tokens: jax.Array, *, seed: int = 1):
    """(tokens (B, S) [+ modality stubs], matching pspecs) for prefill."""
    tokens = jnp.asarray(tokens, jnp.int32)
    b, s = tokens.shape
    bax = ms.fsdp_axes if spec.batch_sharded else None
    batch = {"tokens": tokens}
    pspecs = {"tokens": P(bax)}
    if cfg.arch_type == "vlm":
        batch.update(vision_embeds=jnp.zeros((b, s, cfg.d_model), jnp.bfloat16),
                     vision_mask=jnp.zeros((b, s), bool),
                     positions=jnp.broadcast_to(jnp.arange(s), (3, b, s)))
        pspecs.update(vision_embeds=P(bax), vision_mask=P(bax),
                      positions=P(None, bax))
    if cfg.arch_type == "audio":
        batch["audio_embeds"] = 0.1 * jax.random.normal(
            jax.random.PRNGKey(seed), (b, spec.enc_len, cfg.d_model),
            jnp.bfloat16)
        pspecs["audio_embeds"] = P(bax)
    return batch, pspecs


def scheduler_batch_builder(cfg: ModelConfig, spec: DecodeSpec, ms: MeshSpec):
    """A ContinuousScheduler `batch_builder` for any architecture family:
    builds the batch-of-1 prefill batch (tokens + modality stubs)."""
    pf_spec = dataclasses.replace(spec, batch_global=1, batch_sharded=False)

    def build(tokens):
        return make_prompt_batch(cfg, pf_spec, ms, tokens)

    return build


def make_scheduler(setup: ServeSetup, *, gather_key=None,
                   prefill_chunk: int = 0, prefill_buckets: int = 4,
                   prefill_interleave: int = 1,
                   kv_quant_bits: int = 0, kv_quant_horizon: int = 0,
                   kv_prefix_share: bool = True):
    """The ContinuousScheduler every serve entry point builds from a
    ServeSetup: launcher, bench, and examples get the same batch_builder
    (modality stubs included) and the same chunked-admission knobs.  The
    kv_quant_* knobs configure the paged pool's quantized cold tier (paged
    setups only)."""
    from .scheduler import ContinuousScheduler
    return ContinuousScheduler(
        setup.model, setup.mesh, setup.spec, setup.params,
        gather_key=gather_key,
        batch_builder=scheduler_batch_builder(setup.cfg, setup.spec, setup.ms),
        prefill_chunk=prefill_chunk, prefill_buckets=prefill_buckets,
        prefill_interleave=prefill_interleave,
        kv_quant_bits=kv_quant_bits, kv_quant_horizon=kv_quant_horizon,
        kv_prefix_share=kv_prefix_share)
