"""Batched serving engine: jit-compiled prefill + decode steps over the mesh.

The engine owns the shard_map plumbing; `DecodeModel` owns the per-device
math.  Decoding re-gathers quantized weights layer-by-layer every step —
FSDP-style serving — so step latency is collective-bound and QSDP's wire
compression directly reduces it (see benchmarks/fig4_bandwidth_model.py).

With ``DecodeSpec(rowquant_mlp=True)`` the dense-MLP weights additionally
*stay in wire-code form* after the gather: the fused
``kernels.ops.rowquant_matmul`` Pallas kernel consumes the gathered u8
codes + per-bucket affine directly, so the dequantized matrix is never
written to HBM (falls back to the dense path per weight when the wire
buckets don't tile its rows — see ``QSDPEngine.rowquant_eligible``).

Quantized-domain checkpoints (format v2, ``quantized_state=True`` training)
serve with ZERO conversion: :func:`prepare_wire_params` keeps the eligible
dense-MLP weights as their stored wire codes — sliced per layer so the
scan-over-layers can carry them — and the per-step gather ships those
exact bytes into a RowQuantWeight (``QSDPEngine.gather_rowquant_wire``);
everything else is decoded once, host-side, to its exact f32 values.
"""
from __future__ import annotations

import math
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from ..compat import shard_map
from ..core.quant import (QuantConfig, QuantizedParam, qparam_decode,
                          qparam_encode, qparam_split_stack)
from ..models.decode import ROWQUANT_MLP, DecodeModel, DecodeSpec, make_decode_spec
from ..models.transformer import Model
from .kv_pool import PoolExhausted, decode_block, encode_block


def prepare_wire_params(model: Model, params: dict) -> dict:
    """Host-side: adapt a (possibly quantized-domain) train-state params
    dict for serving.

    QuantizedParam leaves that are rowquant-eligible dense-MLP weights of a
    dense/VLM stack keep their wire codes — stacked leaves are re-sliced
    per layer (``qparam_split_stack``) so ``lax.scan`` can carry them — and
    are consumed by ``QSDPEngine.gather_rowquant_wire`` with no
    re-quantization.  Every other QuantizedParam is decoded to its exact
    f32 rest-layout values (deterministic)."""
    out = {}
    eng = model.engine
    for name, v in params.items():
        if not isinstance(v, QuantizedParam):
            out[name] = v
            continue
        base = name.rsplit("/", 1)[-1]
        if (model.cfg.arch_type in ("dense", "vlm")
                and name.startswith("layers/")
                and base in ROWQUANT_MLP
                and eng.rowquant_wire_eligible(name, v)):
            out[name] = qparam_split_stack(v) if v.stacked else v
        else:
            out[name] = qparam_decode(v)
    return out


# layer weights the self-speculative draft re-quantizes to `draft_bits`
# (the large matmuls; norms / biases / router / embed / head stay shared
# with the serving params so the two models agree everywhere quantization
# wouldn't pay)
DRAFT_WEIGHTS = ROWQUANT_MLP + ("wq", "wk", "wv", "wo")


def _draft_bucket(n_local: int, bits: int) -> int:
    """Per-leaf draft bucket: the largest power-of-2 divisor of the shard
    size, capped at 256 — small enough that low-bit min-max buckets track
    the weight distribution (draft fidelity is what buys acceptance), and a
    divisor so `qparam_split_stack` stays bucket-aligned."""
    cpb = 8 // bits if 8 % bits == 0 else 1
    b = math.gcd(n_local, 256)
    return b if b % cpb == 0 else 0


def make_draft_params(model: Model, params: dict, draft_bits: int) -> dict:
    """Host-side: the self-speculative DRAFT parameter set — the serving
    params with every large `layers/*` matmul weight replaced by its
    `draft_bits`-bit wire codes (deterministic nearest rounding, so the
    draft — and therefore the acceptance rate — is a pure function of the
    served weights).  Leaves that already ARE wire codes (quantized
    checkpoints / train state) are reused as-is: the draft reads the codes
    already resident for QSDP, no second copy and no re-encode.  Everything
    else (embed, head, norms, biases, router) is the SAME array object as
    the serving params — zero extra bytes.

    The draft engine's per-step gather then ships the low-bit codes and
    consumes them through the bits 2-8 kernels: rowquant matmul where the
    buckets tile the rows, dense dequant otherwise (see
    ``DecodeModel._gather_layer_w``)."""
    if not 2 <= draft_bits <= 8:
        raise ValueError(f"draft_bits must be in [2, 8], got {draft_bits}")
    out = {}
    for name, v in params.items():
        base = name.rsplit("/", 1)[-1]
        if (not name.startswith("layers/") or base not in DRAFT_WEIGHTS
                or isinstance(v, QuantizedParam)):
            out[name] = v  # shared with (or already wire in) the serving set
            continue
        bucket = _draft_bucket(v.shape[-1], draft_bits)
        if not bucket or v.ndim not in (3, 4):
            out[name] = v
            continue
        cfg = QuantConfig(bits=draft_bits, bucket_size=bucket, mode="nearest")
        qp = qparam_encode(v, cfg)
        out[name] = qparam_split_stack(qp) if qp.stacked else qp
    return out


def wire_param_pspecs(model: Model, params: dict) -> dict:
    """Per-leaf PartitionSpecs for a params dict that may mix dense rest
    leaves and (possibly stack-split) QuantizedParam wire leaves."""
    out = {}
    base = ("model", model.ms.fsdp_axes, None)
    for name, v in params.items():
        if isinstance(v, QuantizedParam):
            out[name] = P(None, *base) if v.wire.ndim == 4 else P(*base)
        else:
            out[name] = model.specs[name].rest_pspec(model.ms)
    return out


def prefill_bucket_sizes(chunk: int, n_buckets: int, cache_len: int
                         ) -> tuple[int, ...]:
    """The bounded set of padded chunk lengths for chunked prefill:
    `n_buckets` evenly spaced sizes up to the chunk size (clamped to the KV
    ring so a chunk's ring targets stay collision-free), deduped ascending.
    Every chunk right-pads to the smallest bucket that fits, so the jit
    cache compiles at most `len(buckets)` prefill traces no matter how many
    distinct prompt lengths a trace contains.  A valid token's numerics are
    INDEPENDENT of the bucket it rides in (padded rows add query rows, they
    never enter another row's reductions), so bucketing cannot perturb
    tokens — only trace counts."""
    if chunk <= 0:
        return ()
    top = min(chunk, cache_len) if cache_len else chunk
    n = max(1, min(n_buckets, top))
    return tuple(sorted({max(1, round(top * i / n)) for i in range(1, n + 1)}
                        | {top}))


def prefill_bucket_for(length: int, buckets: tuple[int, ...]) -> int:
    """Smallest bucket that holds a chunk of `length` tokens."""
    for b in buckets:
        if length <= b:
            return b
    raise ValueError(f"chunk of {length} tokens exceeds every bucket "
                     f"{buckets}")


def make_sample_params(temperature: float = 0.0, top_k: int = 0,
                       seed: int = 0, b: int = 1) -> dict:
    """The `sample` tree consumed by DecodeModel.decode_fn/prefill_fn when
    ``DecodeSpec.sampling`` — ONE request's sampling state broadcast over b
    slots.  This is the only place that owns its shape contract."""
    return {
        "temp": jnp.full((b,), temperature, jnp.float32),
        "top_k": jnp.full((b,), top_k, jnp.int32),
        "key": jnp.broadcast_to(jax.random.PRNGKey(seed), (b, 2)),
    }


def greedy_sample_params(b: int) -> dict:
    """Per-slot sampling state that reduces every row to the greedy path
    bit-exactly (temp 0)."""
    return make_sample_params(b=b)


class ServeEngine:
    def __init__(self, model: Model, mesh, spec: DecodeSpec,
                 params: Optional[dict] = None):
        """`params` (optional) is only inspected for its leaf FORMS: pass it
        when serving wire-form (QuantizedParam) leaves so the shard_map
        pspecs match — see :func:`prepare_wire_params`."""
        self.model = model
        self.mesh = mesh
        self.spec = spec
        self.dm = DecodeModel(model, spec)
        ms = model.ms
        self.bax = ms.fsdp_axes if spec.batch_sharded else None
        self._pspecs = (wire_param_pspecs(model, params) if params is not None
                        else model.param_pspecs())
        _, self.cache_pspecs = self.dm.cache_struct()
        self._decode = None
        self._prefill = None
        # chunked prefill: one compiled step per BUCKET length — the
        # continuous scheduler right-pads prompt chunks into a bounded
        # bucket set, so this cache holds at most n_buckets entries.
        self._chunk_steps: dict[int, object] = {}
        # speculative verify: one compiled step per draft depth K actually
        # launched (bounded by spec.draft_depth distinct values)
        self._verify_steps: dict[int, object] = {}
        self._block_ops = None

    # -- jitted steps ---------------------------------------------------------

    def sample_pspecs(self) -> dict:
        """PartitionSpecs for the per-slot `sample` tree (batch-axis arrays)."""
        return {"temp": P(self.bax), "top_k": P(self.bax), "key": P(self.bax)}

    def decode_step(self):
        """jit'd decode: (params, cache, tokens (B,), pos (B,), key
        [, sample]) -> (next_tokens, cache).  pos is PER-SLOT — every batch
        slot advances at its own sequence position, which is what lets the
        continuous-batching scheduler interleave requests mid-decode.  The
        trailing `sample` arg exists iff ``spec.sampling``.

        Paged specs take a block-table arg after pos: (params, cache,
        tokens, pos, block_tables (B, blocks_per_slot) i32, key
        [, sample]) — the table is replicated (every rank resolves the
        same logical->physical block mapping; blocks are seq-sharded, so
        each rank's gather stays rank-local)."""
        if self._decode is None:
            in_specs = [self._pspecs, self.cache_pspecs, P(self.bax),
                        P(self.bax), P()]
            raw = self.dm.decode_fn
            if self.spec.paged:
                in_specs.insert(4, P(None, None))

                def raw(params, cache, tokens, pos, bt, key, *extra):
                    return self.dm.decode_fn(params, cache, tokens, pos,
                                             key, *extra, block_tables=bt)
            if self.spec.sampling:
                in_specs.append(self.sample_pspecs())
            fn = shard_map(
                raw, mesh=self.mesh,
                in_specs=tuple(in_specs),
                out_specs=(P(self.bax), self.cache_pspecs),
                check_vma=False,
            )
            self._decode = jax.jit(fn, donate_argnums=(1,))
        return self._decode

    def prefill_step(self, batch_pspecs: dict):
        if self.spec.paged:
            raise NotImplementedError(
                "whole-prompt prefill is ring-only; paged engines must use "
                "chunked prefill (prefill_chunk_step / generate(prefill_chunk=...))")
        if self._prefill is None:
            in_specs = [self._pspecs, batch_pspecs, P()]
            if self.spec.sampling:
                in_specs.append(self.sample_pspecs())
            fn = shard_map(
                self.dm.prefill_fn, mesh=self.mesh,
                in_specs=tuple(in_specs),
                out_specs=(P(self.bax), self.cache_pspecs),
                check_vma=False,
            )
            self._prefill = jax.jit(fn)
        return self._prefill

    def prefill_chunk_step(self, bucket_len: int):
        """jit'd chunked prefill over the whole slot pool: (params, cache,
        tokens (B, Lb), offset (B,), n_valid (B,), key [, sample]) ->
        (next_tokens (B,), cache).  Compiled once per bucket length Lb;
        writes each prefilling slot's chunk KV into its ring lane in place
        (non-prefilling lanes pass n_valid 0 and are untouched), so it runs
        back-to-back with decode_step over the same donated cache."""
        if bucket_len not in self._chunk_steps:
            in_specs = [self._pspecs, self.cache_pspecs, P(self.bax),
                        P(self.bax), P(self.bax), P()]
            raw = self.dm.prefill_chunk_fn
            if self.spec.paged:
                # paged call shape: (params, cache, tokens, offset, n_valid,
                # block_tables, key [, sample])
                in_specs.insert(5, P(None, None))

                def raw(params, cache, tokens, offset, n_valid, bt, key,
                        *extra):
                    return self.dm.prefill_chunk_fn(
                        params, cache, tokens, offset, n_valid, key, *extra,
                        block_tables=bt)
            if self.spec.sampling:
                in_specs.append(self.sample_pspecs())
            fn = shard_map(
                raw, mesh=self.mesh,
                in_specs=tuple(in_specs),
                out_specs=(P(self.bax), self.cache_pspecs),
                check_vma=False,
            )
            self._chunk_steps[bucket_len] = jax.jit(fn, donate_argnums=(1,))
        return self._chunk_steps[bucket_len]

    def verify_step(self, k: int):
        """jit'd speculative verify over the whole slot pool: (params,
        cache, tokens (B, K), pos (B,), n_spec (B,), key [, sample]) ->
        (out (B, K), cache) — ``DecodeModel.verify_fn`` scores all K
        drafted tokens per slot in ONE pooled launch and (re)writes their
        KV in serving precision.  Paged call shape inserts block_tables
        (B, blocks_per_slot) after n_spec.  Compiled once per draft depth
        K."""
        if k not in self._verify_steps:
            in_specs = [self._pspecs, self.cache_pspecs, P(self.bax),
                        P(self.bax), P(self.bax), P()]
            raw = self.dm.verify_fn
            if self.spec.paged:
                in_specs.insert(5, P(None, None))

                def raw(params, cache, tokens, pos, n_spec, bt, key, *extra):
                    return self.dm.verify_fn(params, cache, tokens, pos,
                                             n_spec, key, *extra,
                                             block_tables=bt)
            if self.spec.sampling:
                in_specs.append(self.sample_pspecs())
            fn = shard_map(
                raw, mesh=self.mesh,
                in_specs=tuple(in_specs),
                out_specs=(P(self.bax), self.cache_pspecs),
                check_vma=False,
            )
            self._verify_steps[k] = jax.jit(fn, donate_argnums=(1,))
        return self._verify_steps[k]

    # -- convenience ------------------------------------------------------------

    def init_cache(self):
        structs, specs = self.dm.cache_struct()
        return {
            k: jax.device_put(jnp.zeros(s.shape, s.dtype), NamedSharding(self.mesh, specs[k]))
            for k, s in structs.items()
        }

    # -- paged block ops (cold tier + copy-on-write) -----------------------------

    def kv_block_ops(self):
        """jit'd (extract, load, copy) over a paged cache's global k/v
        arrays, addressing ONE physical block by id.

        A block's bytes live strided across the model axis (each rank holds
        its block_loc-token slice of every block), so in the global arrays
        block `bid` = row ``bid // bpr``, seq positions
        ``rank * s_loc + (bid % bpr) * block_loc + [0, block_loc)`` per
        rank — token order inside the (L, block_size, n_kv, hd) view is the
        natural position order.  These run OUTSIDE shard_map between steps
        (cold-tier demote/rehydrate, COW forks); they are off the decode
        hot path."""
        if self._block_ops is None:
            sp, tp = self.spec, self.dm.tp
            bs = sp.kv_block_size
            bpr = sp.cache_len // bs
            bl = bs // tp
            s_loc = sp.cache_len // tp
            i = jnp.arange(bs)

            def seq_of(idx):
                return (i // bl) * s_loc + idx * bl + i % bl

            def extract(cache, bid):
                row, seq = bid // bpr, seq_of(bid % bpr)
                return cache["k"][:, row, seq], cache["v"][:, row, seq]

            def load(cache, bid, kblk, vblk):
                row, seq = bid // bpr, seq_of(bid % bpr)
                return dict(
                    cache,
                    k=cache["k"].at[:, row, seq].set(
                        kblk.astype(cache["k"].dtype)),
                    v=cache["v"].at[:, row, seq].set(
                        vblk.astype(cache["v"].dtype)))

            def copy(cache, src, dst):
                kb, vb = extract(cache, src)
                return load(cache, dst, kb, vb)

            self._block_ops = (jax.jit(extract),
                               jax.jit(load, donate_argnums=(0,)),
                               jax.jit(copy, donate_argnums=(0,)))
        return self._block_ops

    def demote_cold_blocks(self, cache, pool, now: int) -> int:
        """Quantized cold tier: re-encode cached (refcount-0 prefix) blocks
        idle past the pool's quant horizon into the `core.quant` wire format
        (host-resident packed codes + per-bucket meta) and free their hot
        blocks.  Returns the number of blocks demoted.  Values seen by
        attention are unchanged until a block is rehydrated — and demotion
        only ever touches blocks no live request references."""
        ids = pool.demotable(now)
        if not ids:
            return 0
        extract, _, _ = self.kv_block_ops()
        for bid in ids:
            kb, vb = extract(cache, jnp.int32(bid))
            cold = encode_block(jax.device_get(kb), jax.device_get(vb),
                                pool.quant_cfg)
            pool.demote(bid, cold, now)
        return len(ids)

    def rehydrate_block(self, cache, pool, key, now: int):
        """Bring a cold prefix block back hot: alloc a block, decode the
        wire codes (bit-exact `core.quant` QDQ values), scatter them in.
        Returns (bid, cache)."""
        bid, cold = pool.rehydrate(key, now)
        _, load, _ = self.kv_block_ops()
        kb, vb = decode_block(cold)
        return bid, load(cache, jnp.int32(bid), kb, vb)

    def generate(self, params, prompt_batch: dict, batch_pspecs: dict,
                 n_tokens: int, key: Optional[jax.Array] = None,
                 sample: Optional[dict] = None, fold_step_keys: bool = True,
                 prefill_chunk: int = 0, prefill_buckets: int = 4):
        """Prefill the prompt then decode n_tokens (greedy unless a `sample`
        tree is given on a ``spec.sampling`` engine).

        fold_step_keys=False reuses ONE gather key for prefill and every
        decode step, i.e. serves a FIXED quantized model: with the paper's
        stochastic-shift weight quantizer the dequantized weights depend on
        the step key, and a fixed key is what makes a request's tokens
        bit-identical between this solo path and the continuous-batching
        scheduler (which interleaves requests at different step indices, so
        no per-step key schedule could line up).

        prefill_chunk=C > 0 prefills through ``prefill_chunk_step`` in
        C-token chunks instead of one whole-prompt launch — the SAME
        computation the chunked continuous scheduler runs, which is what
        makes this the bit-exact solo reference for chunked serving.  (The
        two prefill styles are distinct float paths: chunked attention
        reads earlier chunks back from the bf16 KV ring, whole-prompt flash
        attention never rounds through the cache — each is deterministic
        and composition-independent, but their greedy tokens may differ.)"""
        key = key if key is not None else jax.random.PRNGKey(0)
        b, s = prompt_batch["tokens"].shape
        if sample is not None and not self.spec.sampling:
            raise ValueError(
                "generate() got a sample tree but this engine was built with "
                "DecodeSpec(sampling=False)")
        if self.spec.sampling and sample is None:
            sample = greedy_sample_params(b)
        extra = (sample,) if self.spec.sampling else ()
        bt = ()
        if self.spec.paged:
            if not prefill_chunk:
                raise ValueError(
                    "paged DecodeSpec serves through chunked prefill only; "
                    "pass prefill_chunk=...")
            # solo path: each lane owns its full logical window, laid out as
            # the identity block table — the pool must hold b * bps blocks.
            bps = self.spec.blocks_per_slot
            need, have = b * bps, self.spec.pool_blocks()
            if need > have:
                raise PoolExhausted(
                    f"KV pool exhausted: {b} lanes need {need} blocks but "
                    f"the pool holds {have}; raise --kv-pool-blocks (or "
                    "lower the batch)")
            bt = (jnp.arange(b * bps, dtype=jnp.int32).reshape(b, bps),)
        if prefill_chunk:
            if fold_step_keys:
                raise ValueError(
                    "chunked prefill serves a fixed quantized model; pass "
                    "fold_step_keys=False")
            if self.spec.cache_len and s > self.spec.cache_len:
                # the scheduler rejects these at submit(); enforce the same
                # bound here — a chunk at offset >= cache_len would
                # overwrite ring slots still holding LIVE earlier positions
                # before they are attended (non-causal reads)
                raise ValueError(
                    f"prompt ({s}) exceeds the KV ring "
                    f"({self.spec.cache_len}); chunked prefill cannot "
                    "stream a prompt through a smaller sliding window")
            buckets = prefill_bucket_sizes(prefill_chunk, prefill_buckets,
                                           self.spec.cache_len)
            tokens = prompt_batch["tokens"]
            cache = self.init_cache()
            for o in range(0, s, prefill_chunk):
                clen = min(prefill_chunk, s - o)
                bucket = prefill_bucket_for(clen, buckets)
                chunk = jnp.zeros((b, bucket), jnp.int32)
                chunk = chunk.at[:, :clen].set(tokens[:, o:o + clen])
                nxt, cache = self.prefill_chunk_step(bucket)(
                    params, cache, chunk, jnp.full((b,), o, jnp.int32),
                    jnp.full((b,), clen, jnp.int32), *bt, key, *extra)
        else:
            nxt, cache = self.prefill_step(batch_pspecs)(
                params, prompt_batch, key, *extra)
        out = [nxt]
        dec = self.decode_step()
        for i in range(n_tokens - 1):
            pos = jnp.full((b,), s + i, jnp.int32)
            k = jax.random.fold_in(key, i) if fold_step_keys else key
            nxt, cache = dec(params, cache, nxt, pos, *bt, k, *extra)
            out.append(nxt)
        return jnp.stack(out, axis=1)  # (B, n_tokens)
