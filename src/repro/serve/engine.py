"""Batched serving engine: jit-compiled prefill + decode steps over the mesh.

The engine owns the shard_map plumbing; `DecodeModel` owns the per-device
math.  Decoding re-gathers quantized weights layer-by-layer every step —
FSDP-style serving — so step latency is collective-bound and QSDP's wire
compression directly reduces it (see benchmarks/fig4_bandwidth_model.py).

With ``DecodeSpec(rowquant_mlp=True)`` the dense-MLP weights additionally
*stay in wire-code form* after the gather: the fused
``kernels.ops.rowquant_matmul`` Pallas kernel consumes the gathered u8
codes + per-bucket affine directly, so the dequantized matrix is never
written to HBM (falls back to the dense path per weight when the wire
buckets don't tile its rows — see ``QSDPEngine.rowquant_eligible``).
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from ..compat import shard_map
from ..models.decode import DecodeModel, DecodeSpec, make_decode_spec
from ..models.transformer import Model


class ServeEngine:
    def __init__(self, model: Model, mesh, spec: DecodeSpec):
        self.model = model
        self.mesh = mesh
        self.spec = spec
        self.dm = DecodeModel(model, spec)
        ms = model.ms
        self.bax = ms.fsdp_axes if spec.batch_sharded else None
        self._pspecs = model.param_pspecs()
        _, self.cache_pspecs = self.dm.cache_struct()
        self._decode = None
        self._prefill = None

    # -- jitted steps ---------------------------------------------------------

    def decode_step(self):
        if self._decode is None:
            fn = shard_map(
                self.dm.decode_fn, mesh=self.mesh,
                in_specs=(self._pspecs, self.cache_pspecs, P(self.bax), P(), P()),
                out_specs=(P(self.bax), self.cache_pspecs),
                check_vma=False,
            )
            self._decode = jax.jit(fn, donate_argnums=(1,))
        return self._decode

    def prefill_step(self, batch_pspecs: dict):
        if self._prefill is None:
            fn = shard_map(
                self.dm.prefill_fn, mesh=self.mesh,
                in_specs=(self._pspecs, batch_pspecs, P()),
                out_specs=(P(self.bax), self.cache_pspecs),
                check_vma=False,
            )
            self._prefill = jax.jit(fn)
        return self._prefill

    # -- convenience ------------------------------------------------------------

    def init_cache(self):
        structs, specs = self.dm.cache_struct()
        return {
            k: jax.device_put(jnp.zeros(s.shape, s.dtype), NamedSharding(self.mesh, specs[k]))
            for k, s in structs.items()
        }

    def generate(self, params, prompt_batch: dict, batch_pspecs: dict,
                 n_tokens: int, key: Optional[jax.Array] = None):
        """Greedy generation: prefill the prompt then decode n_tokens."""
        key = key if key is not None else jax.random.PRNGKey(0)
        s = prompt_batch["tokens"].shape[1]
        nxt, cache = self.prefill_step(batch_pspecs)(params, prompt_batch, key)
        out = [nxt]
        dec = self.decode_step()
        for i in range(n_tokens - 1):
            pos = jnp.asarray(s + i, jnp.int32)
            nxt, cache = dec(params, cache, nxt, pos, jax.random.fold_in(key, i))
            out.append(nxt)
        return jnp.stack(out, axis=1)  # (B, n_tokens)
