"""Batched serving engine: jit-compiled prefill + decode steps over the mesh.

The engine owns the shard_map plumbing; `DecodeModel` owns the per-device
math.  Decoding re-gathers quantized weights layer-by-layer every step —
FSDP-style serving — so step latency is collective-bound and QSDP's wire
compression directly reduces it (see benchmarks/fig4_bandwidth_model.py).

With ``DecodeSpec(rowquant_mlp=True)`` the dense-MLP weights additionally
*stay in wire-code form* after the gather: the fused
``kernels.ops.rowquant_matmul`` Pallas kernel consumes the gathered u8
codes + per-bucket affine directly, so the dequantized matrix is never
written to HBM (falls back to the dense path per weight when the wire
buckets don't tile its rows — see ``QSDPEngine.rowquant_eligible``).

Quantized-domain checkpoints (format v2, ``quantized_state=True`` training)
serve with ZERO conversion: :func:`prepare_wire_params` keeps the eligible
dense-MLP weights as their stored wire codes — sliced per layer so the
scan-over-layers can carry them — and the per-step gather ships those
exact bytes into a RowQuantWeight (``QSDPEngine.gather_rowquant_wire``);
everything else is decoded once, host-side, to its exact f32 values.
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from ..compat import shard_map
from ..core.quant import QuantizedParam, qparam_decode, qparam_split_stack
from ..models.decode import ROWQUANT_MLP, DecodeModel, DecodeSpec, make_decode_spec
from ..models.transformer import Model


def prepare_wire_params(model: Model, params: dict) -> dict:
    """Host-side: adapt a (possibly quantized-domain) train-state params
    dict for serving.

    QuantizedParam leaves that are rowquant-eligible dense-MLP weights of a
    dense/VLM stack keep their wire codes — stacked leaves are re-sliced
    per layer (``qparam_split_stack``) so ``lax.scan`` can carry them — and
    are consumed by ``QSDPEngine.gather_rowquant_wire`` with no
    re-quantization.  Every other QuantizedParam is decoded to its exact
    f32 rest-layout values (deterministic)."""
    out = {}
    eng = model.engine
    for name, v in params.items():
        if not isinstance(v, QuantizedParam):
            out[name] = v
            continue
        base = name.rsplit("/", 1)[-1]
        if (model.cfg.arch_type in ("dense", "vlm")
                and name.startswith("layers/")
                and base in ROWQUANT_MLP
                and eng.rowquant_wire_eligible(name, v)):
            out[name] = qparam_split_stack(v) if v.stacked else v
        else:
            out[name] = qparam_decode(v)
    return out


def wire_param_pspecs(model: Model, params: dict) -> dict:
    """Per-leaf PartitionSpecs for a params dict that may mix dense rest
    leaves and (possibly stack-split) QuantizedParam wire leaves."""
    out = {}
    base = ("model", model.ms.fsdp_axes, None)
    for name, v in params.items():
        if isinstance(v, QuantizedParam):
            out[name] = P(None, *base) if v.wire.ndim == 4 else P(*base)
        else:
            out[name] = model.specs[name].rest_pspec(model.ms)
    return out


def make_sample_params(temperature: float = 0.0, top_k: int = 0,
                       seed: int = 0, b: int = 1) -> dict:
    """The `sample` tree consumed by DecodeModel.decode_fn/prefill_fn when
    ``DecodeSpec.sampling`` — ONE request's sampling state broadcast over b
    slots.  This is the only place that owns its shape contract."""
    return {
        "temp": jnp.full((b,), temperature, jnp.float32),
        "top_k": jnp.full((b,), top_k, jnp.int32),
        "key": jnp.broadcast_to(jax.random.PRNGKey(seed), (b, 2)),
    }


def greedy_sample_params(b: int) -> dict:
    """Per-slot sampling state that reduces every row to the greedy path
    bit-exactly (temp 0)."""
    return make_sample_params(b=b)


class ServeEngine:
    def __init__(self, model: Model, mesh, spec: DecodeSpec,
                 params: Optional[dict] = None):
        """`params` (optional) is only inspected for its leaf FORMS: pass it
        when serving wire-form (QuantizedParam) leaves so the shard_map
        pspecs match — see :func:`prepare_wire_params`."""
        self.model = model
        self.mesh = mesh
        self.spec = spec
        self.dm = DecodeModel(model, spec)
        ms = model.ms
        self.bax = ms.fsdp_axes if spec.batch_sharded else None
        self._pspecs = (wire_param_pspecs(model, params) if params is not None
                        else model.param_pspecs())
        _, self.cache_pspecs = self.dm.cache_struct()
        self._decode = None
        self._prefill = None

    # -- jitted steps ---------------------------------------------------------

    def sample_pspecs(self) -> dict:
        """PartitionSpecs for the per-slot `sample` tree (batch-axis arrays)."""
        return {"temp": P(self.bax), "top_k": P(self.bax), "key": P(self.bax)}

    def decode_step(self):
        """jit'd decode: (params, cache, tokens (B,), pos (B,), key
        [, sample]) -> (next_tokens, cache).  pos is PER-SLOT — every batch
        slot advances at its own sequence position, which is what lets the
        continuous-batching scheduler interleave requests mid-decode.  The
        trailing `sample` arg exists iff ``spec.sampling``."""
        if self._decode is None:
            in_specs = [self._pspecs, self.cache_pspecs, P(self.bax),
                        P(self.bax), P()]
            if self.spec.sampling:
                in_specs.append(self.sample_pspecs())
            fn = shard_map(
                self.dm.decode_fn, mesh=self.mesh,
                in_specs=tuple(in_specs),
                out_specs=(P(self.bax), self.cache_pspecs),
                check_vma=False,
            )
            self._decode = jax.jit(fn, donate_argnums=(1,))
        return self._decode

    def prefill_step(self, batch_pspecs: dict):
        if self._prefill is None:
            in_specs = [self._pspecs, batch_pspecs, P()]
            if self.spec.sampling:
                in_specs.append(self.sample_pspecs())
            fn = shard_map(
                self.dm.prefill_fn, mesh=self.mesh,
                in_specs=tuple(in_specs),
                out_specs=(P(self.bax), self.cache_pspecs),
                check_vma=False,
            )
            self._prefill = jax.jit(fn)
        return self._prefill

    # -- convenience ------------------------------------------------------------

    def init_cache(self):
        structs, specs = self.dm.cache_struct()
        return {
            k: jax.device_put(jnp.zeros(s.shape, s.dtype), NamedSharding(self.mesh, specs[k]))
            for k, s in structs.items()
        }

    def generate(self, params, prompt_batch: dict, batch_pspecs: dict,
                 n_tokens: int, key: Optional[jax.Array] = None,
                 sample: Optional[dict] = None, fold_step_keys: bool = True):
        """Prefill the prompt then decode n_tokens (greedy unless a `sample`
        tree is given on a ``spec.sampling`` engine).

        fold_step_keys=False reuses ONE gather key for prefill and every
        decode step, i.e. serves a FIXED quantized model: with the paper's
        stochastic-shift weight quantizer the dequantized weights depend on
        the step key, and a fixed key is what makes a request's tokens
        bit-identical between this solo path and the continuous-batching
        scheduler (which interleaves requests at different step indices, so
        no per-step key schedule could line up)."""
        key = key if key is not None else jax.random.PRNGKey(0)
        b, s = prompt_batch["tokens"].shape
        if sample is not None and not self.spec.sampling:
            raise ValueError(
                "generate() got a sample tree but this engine was built with "
                "DecodeSpec(sampling=False)")
        if self.spec.sampling and sample is None:
            sample = greedy_sample_params(b)
        extra = (sample,) if self.spec.sampling else ()
        nxt, cache = self.prefill_step(batch_pspecs)(
            params, prompt_batch, key, *extra)
        out = [nxt]
        dec = self.decode_step()
        for i in range(n_tokens - 1):
            pos = jnp.full((b,), s + i, jnp.int32)
            k = jax.random.fold_in(key, i) if fold_step_keys else key
            nxt, cache = dec(params, cache, nxt, pos, k, *extra)
            out.append(nxt)
        return jnp.stack(out, axis=1)  # (B, n_tokens)
