"""Paged KV block pool: host-side bookkeeping for vLLM-style paged serving.

The serve stack's KV cache is a pool of fixed-size blocks instead of one
private ring per slot.  Device tensors keep the ring's exact layout — the
pool cache is ``(L, R, cache_len, n_kv, hd)`` sequence-sharded over the
model axis, reinterpreted per rank as ``R * blocks_per_row`` physical
blocks of ``block_size // tp`` tokens each (see
``models.attention.paged_gather_kv``) — so a physical block is addressed
by one int32 id and per-slot *block tables* map logical block index ->
physical id.  Everything jit-side is a gather (attend) or a drop-mode
scatter (KV write) through those tables; everything stateful lives HERE,
in plain Python on the host:

* **alloc / free / refcount** — a free list plus per-block refcounts.
  Blocks shared by several requests (prefix hits) carry ref > 1 and are
  read-only; a writer must copy-on-write first (``cow_fork``).
* **prefix table** — full prompt blocks are registered under a *chained
  structural key* (the previous block's key + this block's token tuple),
  so lookups can never alias distinct prefixes: equality is on the token
  contents themselves, not a digest.  A new request walks its prompt's
  chain and shares every hit read-only, skipping that prefix's prefill.
* **deferred reclaim** — a retired request's registered blocks drop to
  ref 0 but stay resident in an LRU of *cached* blocks; they are evicted
  only when the allocator actually needs a free block (or demoted, below).
  Unregistered blocks (generated tokens) free immediately.
* **quantized cold tier** — cached blocks idle past a horizon are
  re-encoded into the ``core.quant`` wire format (packed codes +
  per-bucket scale/zero, deterministic "nearest" rounding) and their hot
  block is returned to the free list: a cold prefix costs
  ``wire_bytes``/token instead of bf16 bytes (~4x fewer at 4-bit), which
  is what multiplies how many prefixes stay resident.  A prefix hit on a
  cold block re-hydrates it through the same bit-exact decode dispatch
  (``encode_block`` / ``decode_block`` round-trip equals the
  ``quantize_dequantize`` reference bit-for-bit — property-tested).

The pool never touches device memory itself: the scheduler/engine own the
cache arrays and ask the pool *which* block ids to read, write, copy or
drop.  That keeps every invariant (no double-free, no leak, no aliasing)
a pure host-side property the hypothesis suite can hammer.
"""
from __future__ import annotations

import dataclasses
from collections import OrderedDict, deque
from typing import Optional, Sequence

import jax.numpy as jnp
import numpy as np

from ..core.quant import (QuantConfig, dequantize, quantize, wire_bytes,
                          wire_pack, wire_unpack)


class PoolExhausted(RuntimeError):
    """Raised when an allocation cannot be satisfied even after evicting
    every reclaimable (ref-0 cached) block."""


# ---------------------------------------------------------------------------
# Prefix keys — chained structural keys, alias-free by construction
# ---------------------------------------------------------------------------


def prefix_keys(prompt: Sequence[int], block_size: int) -> list:
    """Chained keys for every FULL block of `prompt`.

    ``key_j = (key_{j-1}, tuple(block_j tokens))`` — structural equality on
    the actual token contents, so two distinct prefixes can never collide
    (a digest could; nested tuples cannot).  Partial trailing blocks get no
    key: only full blocks are sharable."""
    keys = []
    prev = None
    for j in range(len(prompt) // block_size):
        prev = (prev, tuple(int(t) for t in
                            prompt[j * block_size:(j + 1) * block_size]))
        keys.append(prev)
    return keys


# ---------------------------------------------------------------------------
# Quantized cold-tier codec (wraps core.quant, deterministic)
# ---------------------------------------------------------------------------


def kv_quant_config(bits: int, bucket_size: int = 128) -> QuantConfig:
    """The cold-tier codec: deterministic nearest rounding (no key — a cold
    block must decode to the same bytes every time it is re-hydrated) with
    f32 wire metadata, so the wire round-trip is the identity on the
    quantized representation and encode/decode matches the plain
    quantize_dequantize reference bit-for-bit (bf16 meta would re-round the
    scales on the wire and break that property)."""
    return QuantConfig(bits=bits, bucket_size=bucket_size, mode="nearest",
                       backend="jnp", meta_dtype="float32")


@dataclasses.dataclass
class ColdBlock:
    """One demoted block: wire bytes for k and v + enough to decode."""
    k_wire: np.ndarray  # (wire_bytes,) u8
    v_wire: np.ndarray
    shape: tuple  # (L, block_size, n_kv, hd) — the hot bf16 shape
    cfg: QuantConfig

    @property
    def nbytes(self) -> int:
        return self.k_wire.nbytes + self.v_wire.nbytes


def encode_block(k: np.ndarray, v: np.ndarray, cfg: QuantConfig) -> ColdBlock:
    """(L, bs, n_kv, hd) bf16/f32 block pair -> wire-format ColdBlock."""
    shape = tuple(k.shape)
    kw = np.asarray(wire_pack(quantize(jnp.asarray(k, jnp.float32), cfg)))
    vw = np.asarray(wire_pack(quantize(jnp.asarray(v, jnp.float32), cfg)))
    return ColdBlock(k_wire=kw, v_wire=vw, shape=shape, cfg=cfg)


def decode_block(cold: ColdBlock, dtype=jnp.bfloat16):
    """ColdBlock -> (k, v) device arrays of `cold.shape` — the existing
    bit-exact wire decode dispatch (wire_unpack + dequantize)."""
    n = int(np.prod(cold.shape))
    k = dequantize(wire_unpack(jnp.asarray(cold.k_wire), n, cold.cfg,
                               cold.shape), dtype)
    v = dequantize(wire_unpack(jnp.asarray(cold.v_wire), n, cold.cfg,
                               cold.shape), dtype)
    return k, v


def block_qdq_reference(x: np.ndarray, cfg: QuantConfig) -> np.ndarray:
    """The quantize_dequantize reference the cold-tier round-trip must match
    bit-exactly (property suite)."""
    from ..core.quant import quantize_dequantize
    return np.asarray(quantize_dequantize(jnp.asarray(x, jnp.float32), cfg))


# ---------------------------------------------------------------------------
# The block pool
# ---------------------------------------------------------------------------


class BlockPool:
    """Host-side allocator + prefix cache + cold tier over `n_blocks`
    physical KV blocks of `block_size` (global) tokens each.

    `hot_block_bytes` (optional) is the device bytes of one resident block
    (all layers, k+v) — only used for the capacity stats."""

    def __init__(self, n_blocks: int, block_size: int, *,
                 quant_bits: int = 0, quant_horizon: int = 0,
                 quant_bucket: int = 128, hot_block_bytes: int = 0):
        if n_blocks < 1:
            raise ValueError(f"n_blocks must be >= 1, got {n_blocks}")
        if block_size < 1:
            raise ValueError(f"block_size must be >= 1, got {block_size}")
        self.n_blocks = n_blocks
        self.block_size = block_size
        self.quant_bits = int(quant_bits)
        self.quant_horizon = int(quant_horizon)
        self.quant_cfg = (kv_quant_config(quant_bits, quant_bucket)
                          if quant_bits else None)
        self.hot_block_bytes = hot_block_bytes
        self._free: deque[int] = deque(range(n_blocks))
        self._ref = np.zeros(n_blocks, np.int64)
        self._key_of: dict[int, object] = {}  # bid -> prefix key
        self._bid_of: dict[object, int] = {}  # prefix key -> bid
        self._cached: OrderedDict[int, int] = OrderedDict()  # bid -> last-use
        self._cold: "OrderedDict[object, ColdBlock]" = OrderedDict()
        self._cold_idle: dict[object, int] = {}  # key -> last-use step
        self.stats = dict(allocs=0, frees=0, prefix_hits=0, prefix_misses=0,
                          cow_forks=0, evictions=0, demotions=0,
                          rehydrations=0, cold_evictions=0)

    # -- invariant probes (the property suite leans on these) ---------------

    @property
    def free_blocks(self) -> int:
        """Blocks an alloc() can obtain right now (free + reclaimable)."""
        return len(self._free) + len(self._cached)

    @property
    def blocks_in_use(self) -> int:
        """Blocks pinned by a live reference (ref > 0)."""
        return int((self._ref > 0).sum())

    @property
    def blocks_cached(self) -> int:
        return len(self._cached)

    @property
    def cold_blocks(self) -> int:
        return len(self._cold)

    def cold_bytes(self) -> int:
        return sum(c.nbytes for c in self._cold.values())

    def check_invariants(self) -> None:
        """Every block is in exactly one of {free, cached (ref 0), ref>0};
        the prefix table maps are mutually inverse."""
        free = set(self._free)
        cached = set(self._cached)
        live = {int(b) for b in np.nonzero(self._ref > 0)[0]}
        assert not (free & cached), (free & cached)
        assert not (free & live), (free & live)
        assert not (cached & live), (cached & live)
        assert free | cached | live == set(range(self.n_blocks)), (
            free, cached, live)
        assert (self._ref >= 0).all(), self._ref
        for bid, key in self._key_of.items():
            assert self._bid_of.get(key) == bid, (bid, key)
        assert len(self._bid_of) == len(self._key_of)
        for bid in cached:
            assert bid in self._key_of, bid  # only registered blocks cache

    # -- alloc / free / refcount --------------------------------------------

    def alloc(self, now: int = 0) -> int:
        """One free block id (ref = 1).  Evicts the LRU cached block when
        the free list is empty (demoting it to the cold tier first when the
        tier is on); raises PoolExhausted when nothing is reclaimable."""
        if not self._free:
            self._evict_one(now)
        if not self._free:
            raise PoolExhausted(
                f"KV block pool exhausted: all {self.n_blocks} blocks are "
                "referenced by live requests (no cached block to reclaim); "
                "raise --kv-pool-blocks or retire requests first")
        bid = self._free.popleft()
        self._ref[bid] = 1
        self.stats["allocs"] += 1
        return bid

    def _evict_one(self, now: int) -> None:
        if not self._cached:
            return
        bid, _ = self._cached.popitem(last=False)  # LRU
        key = self._key_of.pop(bid)
        del self._bid_of[key]
        self._free.append(bid)
        self.stats["evictions"] += 1

    def incref(self, bid: int) -> None:
        if self._ref[bid] < 1:
            raise RuntimeError(f"incref of unreferenced block {bid}")
        self._ref[bid] += 1

    def decref(self, bid: int, now: int = 0) -> None:
        """Drop one reference.  ref 0 + registered -> deferred reclaim (LRU
        cache); ref 0 unregistered -> freed immediately.  A decref below
        zero is a double-free and raises."""
        if self._ref[bid] < 1:
            raise RuntimeError(f"double free of block {bid}")
        self._ref[bid] -= 1
        if self._ref[bid] == 0:
            if bid in self._key_of:
                self._cached[bid] = now
                self._cached.move_to_end(bid)
            else:
                self._free.append(bid)
            self.stats["frees"] += 1

    def ref(self, bid: int) -> int:
        return int(self._ref[bid])

    # -- prefix table --------------------------------------------------------

    def register(self, key, bid: int) -> None:
        """Publish a (ref > 0) block under a prefix key.  First writer wins:
        re-registering an existing key is a no-op (the two blocks hold
        byte-identical content by construction — same tokens, same fixed
        model, same chunk decomposition)."""
        if key in self._bid_of:
            return
        if self._ref[bid] < 1:
            raise RuntimeError(f"register of unreferenced block {bid}")
        if bid in self._key_of:  # one key per block
            return
        self._key_of[bid] = key
        self._bid_of[key] = bid
        # content under `key` now resident hot: a stale cold copy (possible
        # after eviction raced a re-prefill) would just waste bytes
        self._cold.pop(key, None)
        self._cold_idle.pop(key, None)

    def is_registered(self, bid: int) -> bool:
        return bid in self._key_of

    def unregister(self, bid: int) -> None:
        """Withdraw a block from the prefix table (its content is about to
        change — ring wrap overwrite — or its request chain broke)."""
        key = self._key_of.pop(bid, None)
        if key is not None:
            del self._bid_of[key]
        self._cached.pop(bid, None)
        if key is not None and self._ref[bid] == 0:
            # was cached (ref 0): nothing references it and it is no longer
            # findable — straight back to the free list
            self._free.append(bid)

    def lookup(self, key, now: int = 0) -> Optional[int]:
        """Prefix hit: return the hot block id for `key` with a NEW
        reference taken (un-caching it if it was in deferred reclaim), or
        None.  Cold blocks do NOT hit here — use lookup_cold + rehydrate."""
        bid = self._bid_of.get(key)
        if bid is None:
            self.stats["prefix_misses"] += 1
            return None
        if self._ref[bid] == 0:
            self._cached.pop(bid, None)
            self._ref[bid] = 1
        else:
            self._ref[bid] += 1
        self.stats["prefix_hits"] += 1
        return bid

    def touch(self, bid: int, now: int) -> None:
        if bid in self._cached:
            self._cached[bid] = now
            self._cached.move_to_end(bid)

    # -- copy-on-write -------------------------------------------------------

    def cow_fork(self, bid: int, now: int = 0) -> int:
        """A writer holding one reference to shared block `bid` wants a
        private copy: allocate a fresh block, drop the writer's reference to
        the shared one.  The CALLER must device-copy bid's bytes into the
        returned id before writing (that copy is what preserves the other
        readers' view).  Returns the new private block id."""
        new = self.alloc(now)
        self.decref(bid, now)
        self.stats["cow_forks"] += 1
        return new

    # -- quantized cold tier -------------------------------------------------

    def demotable(self, now: int) -> list[int]:
        """Cached block ids idle for >= quant_horizon steps (oldest first).
        Empty when the tier is off."""
        if not self.quant_cfg or self.quant_horizon <= 0:
            return []
        return [bid for bid, last in self._cached.items()
                if now - last >= self.quant_horizon]

    def demote(self, bid: int, cold: ColdBlock, now: int = 0) -> None:
        """Move a cached block to the cold store (caller already encoded its
        bytes): the hot block returns to the free list; the prefix key now
        resolves through lookup_cold."""
        if bid not in self._cached:
            raise RuntimeError(f"demote of non-cached block {bid}")
        key = self._key_of.pop(bid)
        del self._bid_of[key]
        del self._cached[bid]
        self._free.append(bid)
        self._cold[key] = cold
        self._cold_idle[key] = now
        self.stats["demotions"] += 1

    def lookup_cold(self, key) -> Optional[ColdBlock]:
        return self._cold.get(key)

    def rehydrate(self, key, now: int = 0) -> tuple[int, ColdBlock]:
        """Cold hit: allocate a hot block for `key`'s content and re-register
        it.  The CALLER decodes the returned ColdBlock into the returned
        block id (bit-exact wire decode).  The cold copy is dropped."""
        cold = self._cold.pop(key)
        self._cold_idle.pop(key, None)
        bid = self.alloc(now)
        self._key_of[bid] = key
        self._bid_of[key] = bid
        self.stats["rehydrations"] += 1
        return bid, cold

    # -- capacity accounting -------------------------------------------------

    def capacity_stats(self) -> dict:
        """The bench columns: hot occupancy, prefix-cache effectiveness and
        the cold tier's capacity multiplier."""
        hits = self.stats["prefix_hits"]
        misses = self.stats["prefix_misses"]
        hot_b = self.hot_block_bytes
        cold_per_block = (self.block_kv_wire_bytes()
                          if self.quant_cfg else 0)
        # bytes multiplier of the cold representation, and total context
        # blocks resident (hot capacity + every demoted block's context,
        # each held at 1/compression of a hot block's bytes)
        compression = hot_b / cold_per_block if hot_b and cold_per_block else 1.0
        eff = self.n_blocks + len(self._cold)
        return dict(
            blocks_total=self.n_blocks,
            blocks_in_use=self.blocks_in_use,
            blocks_cached=self.blocks_cached,
            blocks_free=len(self._free),
            cold_blocks=len(self._cold),
            cold_bytes=self.cold_bytes(),
            hot_block_bytes=hot_b,
            prefix_hit_rate=hits / (hits + misses) if hits + misses else 0.0,
            cold_compression=compression,
            effective_capacity=float(eff),
            **self.stats,
        )

    def block_kv_wire_bytes(self) -> int:
        """Cold bytes of one block (k + v) — needs hot_block_bytes to infer
        the element count (bf16: 2 bytes/elem)."""
        if not (self.quant_cfg and self.hot_block_bytes):
            return 0
        n = self.hot_block_bytes // 2 // 2  # elems per tensor (k or v)
        return 2 * wire_bytes(n, self.quant_cfg)
