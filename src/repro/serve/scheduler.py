"""Continuous-batching request scheduler over the jit-compiled ServeEngine.

The scheduler owns a fixed pool of ``B = spec.batch_global`` decode slots.
Queued requests are admitted into freed slots MID-DECODE: admission runs a
batch-of-1 prefill that writes the prompt's KV (the slot's entire ring /
state, so nothing stale survives from the previous occupant) and the
resulting single-slot cache is spliced into the pool cache with a
token-addressed ``dynamic_update_slice`` along the batch axis — live slots
are never touched.  Every decode step then advances ALL slots at their own
per-slot positions (``DecodeModel.decode_fn`` with ``pos: (B,)``), streams
each slot's token back to its request, retires slots on EOS / length, and
refills them from the queue.

Invariants this module is built around (enforced by
tests/test_serve_scheduler.py and scripts/check_serve_sched.py):

* **Slot isolation** — with greedy decoding, a request's output tokens are
  bit-identical whether it runs alone in a batch-of-1 engine
  (``ServeEngine.generate(..., fold_step_keys=False)``) or interleaved with
  arbitrary other requests here.  Nothing a slot computes reads another
  slot's cache, position, or sampling state.
* **Fixed served model** — the paper's stochastic-shift weight quantizer
  makes the dequantized weights a function of the gather key, so the
  scheduler uses ONE ``gather_key`` for every prefill and decode step.
  Interleaved requests sit at different global step indices; any per-step
  key schedule would decode them against different weights than a solo run.
* **Reproducible sampling** — per-request sampling streams are keyed by
  ``fold_in(PRNGKey(request.seed), position)``, a pure function of the
  request itself, so temperature/top-k outputs are identical across runs
  and across batch compositions.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from functools import partial
from typing import Callable, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import PartitionSpec as P

from ..models.decode import DecodeSpec
from ..models.transformer import Model
from .engine import ServeEngine, make_sample_params


@dataclasses.dataclass(frozen=True)
class Request:
    """One generation request.

    temperature <= 0 (or top_k == 1) decodes greedily — bit-exact with the
    pure-greedy engine path.  top_k <= 0 means no top-k restriction.
    """
    rid: str
    prompt: Sequence[int]
    max_new_tokens: int
    temperature: float = 0.0
    top_k: int = 0
    seed: int = 0
    eos_id: Optional[int] = None

    @property
    def needs_sampling(self) -> bool:
        return self.temperature > 0.0 and self.top_k != 1


@dataclasses.dataclass(frozen=True)
class TokenEvent:
    """One streamed token: request `rid` produced its `index`-th token."""
    rid: str
    token: int
    index: int
    done: bool


@dataclasses.dataclass
class CompletedRequest:
    rid: str
    tokens: np.ndarray  # (n_generated,) int32, includes the EOS if hit
    submit_step: int  # scheduler decode-step count at submit()
    admit_step: int  # ... when the prompt was prefilled into a slot
    finish_step: int  # ... when the last token was produced
    submit_time: float
    finish_time: float


@dataclasses.dataclass
class _Slot:
    req: Request
    n_out: int  # tokens generated so far (incl. the prefill token)


@partial(jax.jit, donate_argnums=(0,))
def _splice_slot(pool: dict, one: dict, slot: jax.Array) -> dict:
    """Write a batch-of-1 prefill cache into pool slot `slot` (batch axis 1
    on every cache leaf).  A dynamic_update_slice touches ONLY that slot's
    lane, so live slots keep decoding over unchanged bytes."""
    return {
        k: lax.dynamic_update_slice_in_dim(v, one[k].astype(v.dtype), slot, axis=1)
        for k, v in pool.items()
    }


class ContinuousScheduler:
    """Fixed-slot continuous batching over one model / parameter set.

    Parameters
    ----------
    model, mesh, spec, params:
        as for :class:`ServeEngine`; ``spec.batch_global`` is the slot-pool
        size B.  Set ``spec.sampling=True`` to serve temperature/top-k
        requests (greedy requests still take the bit-exact greedy path).
    gather_key:
        the ONE weight-gather key used for every prefill and decode step
        (see module docstring).  Defaults to PRNGKey(0).
    batch_builder:
        ``tokens (1, s) -> (batch dict, batch pspecs)`` for architectures
        whose prefill needs modality stubs (vlm/audio); defaults to a
        tokens-only batch.
    """

    def __init__(self, model: Model, mesh, spec: DecodeSpec, params: dict,
                 gather_key: Optional[jax.Array] = None,
                 batch_builder: Optional[Callable] = None):
        self.model = model
        self.mesh = mesh
        self.spec = spec
        self.params = params
        self.B = spec.batch_global
        self.gather_key = (gather_key if gather_key is not None
                           else jax.random.PRNGKey(0))
        self.batch_builder = batch_builder or self._default_batch
        self.engine = ServeEngine(model, mesh, spec, params=params)
        # batch-of-1 prefill engine: prompts prefill at their exact length
        # (one retrace per distinct length), into the same ring layout
        self._pf_spec = dataclasses.replace(spec, batch_global=1,
                                            batch_sharded=False)
        self.prefill_engine = ServeEngine(model, mesh, self._pf_spec,
                                          params=params)

        self.cache = self.engine.init_cache()
        self.queue: deque[Request] = deque()
        self.slots: list[Optional[_Slot]] = [None] * self.B
        # per-slot device-step state (host mirrors; assembled each step)
        self.tok = np.zeros(self.B, np.int32)
        self.pos = np.zeros(self.B, np.int32)
        self.temp = np.zeros(self.B, np.float32)
        self.top_k = np.zeros(self.B, np.int32)
        self.keys = np.zeros((self.B, 2), np.uint32)
        self._submit_meta: dict[str, tuple[int, float]] = {}
        self._admit_step: dict[str, int] = {}
        self._out: dict[str, list[int]] = {}
        self.finished: dict[str, CompletedRequest] = {}
        # stats
        self.step_count = 0
        self.prefill_count = 0
        self.occupancy_sum = 0
        self.tokens_generated = 0

    # -- request intake ------------------------------------------------------

    def submit(self, req: Request) -> None:
        if req.rid in self._out or req.rid in self.finished:
            raise ValueError(f"duplicate request id {req.rid!r}")
        if req.needs_sampling and not self.spec.sampling:
            raise ValueError(
                f"request {req.rid!r} needs sampling but the engine was built "
                "with DecodeSpec(sampling=False)")
        if len(req.prompt) < 1:
            raise ValueError(f"request {req.rid!r}: prompt must be non-empty")
        if self.spec.cache_len and len(req.prompt) > self.spec.cache_len:
            raise ValueError(
                f"request {req.rid!r}: prompt ({len(req.prompt)}) exceeds the "
                f"KV ring ({self.spec.cache_len})")
        if req.max_new_tokens < 1:
            raise ValueError(f"request {req.rid!r}: max_new_tokens must be >= 1")
        self._submit_meta[req.rid] = (self.step_count, time.perf_counter())
        self._out[req.rid] = []
        self.queue.append(req)

    # -- internals -----------------------------------------------------------

    @staticmethod
    def _default_batch(tokens: np.ndarray):
        return {"tokens": jnp.asarray(tokens)}, {"tokens": P(None)}

    def _free_slots(self) -> list[int]:
        return [i for i, s in enumerate(self.slots) if s is None]

    def n_active(self) -> int:
        return sum(s is not None for s in self.slots)

    def _emit(self, events: list, slot_i: int, token: int) -> None:
        """Record one generated token for the slot's request; retire the
        slot when the request is done."""
        st = self.slots[slot_i]
        req = st.req
        self._out[req.rid].append(token)
        st.n_out += 1
        self.tokens_generated += 1
        done = (st.n_out >= req.max_new_tokens
                or (req.eos_id is not None and token == req.eos_id))
        events.append(TokenEvent(req.rid, token, st.n_out - 1, done))
        if done:
            submit_step, submit_time = self._submit_meta.pop(req.rid)
            self.finished[req.rid] = CompletedRequest(
                rid=req.rid,
                tokens=np.asarray(self._out.pop(req.rid), np.int32),
                submit_step=submit_step,
                admit_step=self._admit_step.pop(req.rid),
                finish_step=self.step_count,
                submit_time=submit_time,
                finish_time=time.perf_counter(),
            )
            self.slots[slot_i] = None
            self.temp[slot_i] = 0.0
            self.top_k[slot_i] = 0
        else:
            self.tok[slot_i] = token

    def _admit(self, events: list) -> None:
        """Prefill queued requests into free slots (batch-of-1 prefill, then
        splice the slot cache lane in place)."""
        for slot_i in self._free_slots():
            if not self.queue:
                return
            req = self.queue.popleft()
            s = len(req.prompt)
            tokens = np.asarray(req.prompt, np.int32)[None, :]
            batch, pspecs = self.batch_builder(tokens)
            key_data = np.asarray(jax.random.PRNGKey(req.seed), np.uint32)
            extra = ()
            if self.spec.sampling:
                extra = (make_sample_params(req.temperature, req.top_k,
                                            req.seed),)
            nxt1, cache1 = self.prefill_engine.prefill_step(pspecs)(
                self.params, batch, self.gather_key, *extra)
            self.prefill_count += 1
            self.cache = _splice_slot(self.cache, cache1,
                                      jnp.asarray(slot_i, jnp.int32))
            self.slots[slot_i] = _Slot(req=req, n_out=0)
            self._admit_step[req.rid] = self.step_count
            # slot decode state: the prefill token is fed at position s
            self.pos[slot_i] = s
            self.temp[slot_i] = req.temperature
            self.top_k[slot_i] = req.top_k
            self.keys[slot_i] = key_data
            self._emit(events, slot_i, int(jax.device_get(nxt1)[0]))

    # -- the scheduler loop --------------------------------------------------

    def step(self) -> list[TokenEvent]:
        """Admit pending requests into free slots, then run ONE pooled decode
        step.  Returns the tokens streamed this step (admission may also
        stream each admitted request's first, prefill-produced token)."""
        events: list[TokenEvent] = []
        self._admit(events)
        active = [i for i, s in enumerate(self.slots) if s is not None]
        if not active:
            return events
        extra = ()
        if self.spec.sampling:
            extra = ({"temp": jnp.asarray(self.temp),
                      "top_k": jnp.asarray(self.top_k),
                      "key": jnp.asarray(self.keys)},)
        nxt, self.cache = self.engine.decode_step()(
            self.params, self.cache, jnp.asarray(self.tok),
            jnp.asarray(self.pos), self.gather_key, *extra)
        nxt = np.asarray(jax.device_get(nxt))
        self.step_count += 1
        self.occupancy_sum += len(active)
        for slot_i in active:
            self.pos[slot_i] += 1
            self._emit(events, slot_i, int(nxt[slot_i]))
        return events

    def run(self, max_steps: Optional[int] = None,
            on_token: Optional[Callable[[TokenEvent], None]] = None
            ) -> dict[str, CompletedRequest]:
        """Drain the queue: step until every submitted request finished (or
        max_steps decode steps ran).  Returns {rid: CompletedRequest}."""
        steps = 0
        while self.queue or self.n_active():
            if max_steps is not None and steps >= max_steps:
                break
            for ev in self.step():
                if on_token is not None:
                    on_token(ev)
            steps += 1
        return self.finished

    def stats(self) -> dict:
        return {
            "decode_steps": self.step_count,
            "prefills": self.prefill_count,
            "tokens_generated": self.tokens_generated,
            "slots": self.B,
            "mean_occupancy": (self.occupancy_sum / self.step_count
                               if self.step_count else 0.0),
        }
