"""Continuous-batching request scheduler over the jit-compiled ServeEngine.

The scheduler owns a fixed pool of ``B = spec.batch_global`` decode slots.
Queued requests are admitted into freed slots MID-DECODE, by one of two
admission paths:

* **Blocking (default, ``prefill_chunk=0``)** — admission runs a batch-of-1
  prefill that writes the prompt's KV (the slot's entire ring, so nothing
  stale survives from the previous occupant) and the resulting single-slot
  cache is spliced into the pool cache with a token-addressed
  ``dynamic_update_slice`` along the batch axis.  Every queued prompt
  stalls the pooled decode for its full length, and each distinct prompt
  length costs one jit retrace.

* **Chunked (``prefill_chunk=C``)** — prompts prefill C tokens at a time
  through ``ServeEngine.prefill_chunk_step``: each scheduler step advances
  every *prefilling* slot by at most one chunk (one pooled launch, chunks
  from concurrently-admitting slots ride it together), written straight
  into the slot's KV ring at its chunk offset, alongside the normal pooled
  decode — live slots never wait more than one chunk's latency for a new
  arrival, however long its prompt.  Chunks are right-padded into a small
  set of length buckets (``serve.common.prefill_bucket_sizes``) so the jit
  cache is bounded at n_buckets traces instead of one per distinct prompt
  length.  ``prefill_interleave`` is the fairness knob: chunk launches per
  scheduler step (1 = maximally decode-fair, higher drains the queue
  faster at the cost of longer steps).  Supported for the pure-attention
  families (``models.decode.CHUNKED_PREFILL_ARCHS``).

Dead lanes (never filled, retired, or mid-chunked-prefill) carry the
sentinel ``pos = -1``: the decode step masks their KV-ring write entirely
(bytes frozen), their attention sees zero valid slots, and their sampling
row is clamped to temp 0 / top-k 1 so it takes the draw-free greedy
reduction.  Nothing a dead lane computes can reach a live lane, and the
conformance suite asserts its cache bytes never change.

Invariants this module is built around (enforced by
tests/test_serve_scheduler.py, tests/test_chunked_prefill.py and
scripts/check_serve_sched.py):

* **Slot isolation** — with greedy decoding, a request's output tokens are
  bit-identical whether it runs alone in a batch-of-1 engine
  (``ServeEngine.generate(..., fold_step_keys=False)``, with the MATCHING
  ``prefill_chunk`` so the solo run performs the same chunk decomposition)
  or interleaved with arbitrary other requests here.  Nothing a slot
  computes reads another slot's cache, position, or sampling state, and a
  chunk's numerics are independent of the bucket it is padded into.
  (Chunked and whole-prompt prefill are distinct float paths — chunked
  attention reads earlier chunks back from the bf16 KV ring, flash prefill
  never rounds through the cache — so each admission path is compared
  against its own solo form.)
* **Fixed served model** — the paper's stochastic-shift weight quantizer
  makes the dequantized weights a function of the gather key, so the
  scheduler uses ONE ``gather_key`` for every prefill chunk and decode
  step.  Chunked prefill folds the same per-layer keys as whole-prompt
  prefill, so both paths dequantize bit-identical weights.
* **Reproducible sampling** — per-request sampling streams are keyed by
  ``fold_in(PRNGKey(request.seed), position)``, a pure function of the
  request itself, so temperature/top-k outputs are identical across runs
  and across batch compositions.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from functools import partial
from typing import Callable, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import PartitionSpec as P

from ..models.decode import CHUNKED_PREFILL_ARCHS, DecodeSpec
from ..models.transformer import Model
from .engine import (ServeEngine, make_draft_params, make_sample_params,
                     prefill_bucket_for, prefill_bucket_sizes)
from .kv_pool import BlockPool, PoolExhausted, prefix_keys


@dataclasses.dataclass(frozen=True)
class Request:
    """One generation request.

    temperature <= 0 (or top_k == 1) decodes greedily — bit-exact with the
    pure-greedy engine path.  top_k <= 0 means no top-k restriction.
    """
    rid: str
    prompt: Sequence[int]
    max_new_tokens: int
    temperature: float = 0.0
    top_k: int = 0
    seed: int = 0
    eos_id: Optional[int] = None

    @property
    def needs_sampling(self) -> bool:
        return self.temperature > 0.0 and self.top_k != 1


@dataclasses.dataclass(frozen=True)
class TokenEvent:
    """One streamed token: request `rid` produced its `index`-th token."""
    rid: str
    token: int
    index: int
    done: bool


@dataclasses.dataclass
class CompletedRequest:
    rid: str
    tokens: np.ndarray  # (n_generated,) int32, includes the EOS if hit
    submit_step: int  # scheduler decode-step count at submit()
    admit_step: int  # ... when the request entered a slot (chunked: when
    # assignment started; blocking: when the prompt was prefilled)
    finish_step: int  # ... when the last token was produced
    submit_time: float
    finish_time: float
    first_token_step: int = 0  # ... when token 0 (the prefill token) landed
    first_token_time: float = 0.0  # wall clock of token 0 (TTFT source)


@dataclasses.dataclass
class _Slot:
    req: Request
    n_out: int  # tokens generated so far (incl. the prefill token)
    pf_off: int = 0  # prompt tokens already prefilled (chunked admission)
    prefilling: bool = False  # True until the last chunk lands
    # paged-pool bookkeeping (spec.paged only)
    pkeys: Optional[list] = None  # chained prefix keys of the full prompt blocks
    n_registered: int = 0  # prompt blocks already published to the prefix table
    reserve: int = 0  # worst-case future block allocs still owed to this lane


@partial(jax.jit, donate_argnums=(0,))
def _splice_slot(pool: dict, one: dict, slot: jax.Array) -> dict:
    """Write a batch-of-1 prefill cache into pool slot `slot` (batch axis 1
    on every cache leaf).  A dynamic_update_slice touches ONLY that slot's
    lane, so live slots keep decoding over unchanged bytes."""
    return {
        k: lax.dynamic_update_slice_in_dim(v, one[k].astype(v.dtype), slot, axis=1)
        for k, v in pool.items()
    }


class ContinuousScheduler:
    """Fixed-slot continuous batching over one model / parameter set.

    Parameters
    ----------
    model, mesh, spec, params:
        as for :class:`ServeEngine`; ``spec.batch_global`` is the slot-pool
        size B.  Set ``spec.sampling=True`` to serve temperature/top-k
        requests (greedy requests still take the bit-exact greedy path).
    gather_key:
        the ONE weight-gather key used for every prefill and decode step
        (see module docstring).  Defaults to PRNGKey(0).
    batch_builder:
        ``tokens (1, s) -> (batch dict, batch pspecs)`` for architectures
        whose prefill needs modality stubs (vlm/audio); defaults to a
        tokens-only batch.  Blocking admission only.
    prefill_chunk:
        0 (default) = blocking batch-of-1 admission; C > 0 = chunked
        admission, at most C prompt tokens prefilled per scheduler step per
        slot (see module docstring).
    prefill_buckets:
        bucket count for chunk right-padding (bounds the chunked jit cache).
    prefill_interleave:
        chunk launches per scheduler step (fairness knob; default 1).
    """

    def __init__(self, model: Model, mesh, spec: DecodeSpec, params: dict,
                 gather_key: Optional[jax.Array] = None,
                 batch_builder: Optional[Callable] = None,
                 prefill_chunk: int = 0, prefill_buckets: int = 4,
                 prefill_interleave: int = 1,
                 kv_quant_bits: int = 0, kv_quant_horizon: int = 0,
                 kv_prefix_share: bool = True):
        self.model = model
        self.mesh = mesh
        self.spec = spec
        self.params = params
        self.B = spec.batch_global
        self.gather_key = (gather_key if gather_key is not None
                           else jax.random.PRNGKey(0))
        self.batch_builder = batch_builder or self._default_batch
        self.engine = ServeEngine(model, mesh, spec, params=params)
        self.prefill_chunk = int(prefill_chunk)
        if self.prefill_chunk < 0:
            raise ValueError(
                f"prefill_chunk must be >= 0 (0 = blocking admission), "
                f"got {prefill_chunk}")
        if spec.paged and not self.prefill_chunk:
            raise ValueError(
                "paged DecodeSpec(kv_block_size > 0) requires chunked "
                "admission; pass prefill_chunk > 0")
        self.prefill_interleave = max(int(prefill_interleave), 1)
        if self.prefill_chunk:
            if model.cfg.arch_type not in CHUNKED_PREFILL_ARCHS:
                raise ValueError(
                    f"prefill_chunk requires an arch in "
                    f"{CHUNKED_PREFILL_ARCHS}; {model.cfg.arch_type!r} "
                    "prefills whole-prompt (prefill_chunk=0)")
            self.buckets = prefill_bucket_sizes(
                self.prefill_chunk, prefill_buckets, spec.cache_len)
        else:
            self.buckets = ()
        # batch-of-1 prefill engine (blocking admission): prompts prefill at
        # their exact length (one retrace per distinct length), into the
        # same ring layout
        self._pf_spec = dataclasses.replace(spec, batch_global=1,
                                            batch_sharded=False)
        self.prefill_engine = ServeEngine(model, mesh, self._pf_spec,
                                          params=params)

        # self-speculative decoding (spec.draft_depth > 1): a low-bit draft
        # engine shares THIS scheduler's cache and reads the wire codes
        # already resident for QSDP (make_draft_params re-encodes only raw
        # leaves, once, host-side); the serving-precision engine verifies
        # every drafted token before it is committed, so streams stay
        # bit-identical to non-speculative decode
        self.draft_engine: Optional[ServeEngine] = None
        self.draft_params: Optional[dict] = None
        if spec.speculative:
            self.draft_params = make_draft_params(model, params,
                                                  spec.draft_bits)
            self.draft_engine = ServeEngine(model, mesh, spec,
                                            params=self.draft_params)

        # paged pool (spec.paged): block tables map each lane's logical
        # block index -> physical pool block; every valid table entry holds
        # exactly one pool reference (alloc = 1, prefix lookup = +1)
        self.pool: Optional[BlockPool] = None
        self.block_tables: Optional[np.ndarray] = None
        self._reserved = 0  # sum of live lanes' worst-case future allocs
        self.prefix_share = bool(kv_prefix_share)  # A/B knob (bench)
        if spec.paged:
            structs, _ = self.engine.dm.cache_struct()
            ks = structs["k"]
            hot = (int(np.prod((ks.shape[0], spec.kv_block_size)
                               + tuple(ks.shape[3:])))
                   * jnp.dtype(ks.dtype).itemsize * 2)
            self.pool = BlockPool(
                spec.pool_blocks(), spec.kv_block_size,
                quant_bits=kv_quant_bits, quant_horizon=kv_quant_horizon,
                hot_block_bytes=hot)
            self.block_tables = np.full(
                (self.B, spec.blocks_per_slot), -1, np.int32)

        self.cache = self.engine.init_cache()
        self.queue: deque[Request] = deque()
        self.slots: list[Optional[_Slot]] = [None] * self.B
        # per-slot device-step state (host mirrors; assembled each step);
        # every lane starts at the dead sentinel
        self.tok = np.zeros(self.B, np.int32)
        self.pos = np.full(self.B, -1, np.int32)
        self.temp = np.zeros(self.B, np.float32)
        self.top_k = np.ones(self.B, np.int32)
        self.keys = np.zeros((self.B, 2), np.uint32)
        self._submit_meta: dict[str, tuple[int, float]] = {}
        self._admit_step: dict[str, int] = {}
        self._first_token: dict[str, tuple[int, float]] = {}
        self._out: dict[str, list[int]] = {}
        self.finished: dict[str, CompletedRequest] = {}
        # stats
        self.step_count = 0
        self.prefill_count = 0
        self.prefill_chunk_count = 0
        self._pf_shapes: set[int] = set()  # distinct compiled prefill shapes
        self._max_pf_tokens = 0  # longest single prefill launch (seq tokens)
        self.occupancy_sum = 0
        self.tokens_generated = 0
        # speculative-decoding stats: a "lane step" is one lane's
        # participation in one pooled launch; accepted_per_launch is
        # committed tokens per verify lane step (non-speculative decode is
        # exactly 1 by construction, anything above 1 is bought latency)
        self.decode_launches = 0
        self.draft_launches = 0
        self.draft_lane_steps = 0
        self.verify_launches = 0
        self.spec_tokens = 0
        self.spec_lane_steps = 0

    # -- request intake ------------------------------------------------------

    def submit(self, req: Request) -> None:
        if req.rid in self._out or req.rid in self.finished:
            raise ValueError(f"duplicate request id {req.rid!r}")
        if req.needs_sampling and not self.spec.sampling:
            raise ValueError(
                f"request {req.rid!r} needs sampling but the engine was built "
                "with DecodeSpec(sampling=False)")
        if len(req.prompt) < 1:
            raise ValueError(f"request {req.rid!r}: prompt must be non-empty")
        if self.spec.cache_len and len(req.prompt) > self.spec.cache_len:
            raise ValueError(
                f"request {req.rid!r}: prompt ({len(req.prompt)}) exceeds the "
                f"logical KV window ({self.spec.cache_len})")
        if req.max_new_tokens < 1:
            raise ValueError(f"request {req.rid!r}: max_new_tokens must be >= 1")
        if self.pool is not None and self._lane_need(req) > self.pool.n_blocks:
            # paged admission queues on transient pool pressure, but a
            # request whose worst case exceeds the WHOLE pool can never run
            raise ValueError(
                f"request {req.rid!r}: needs up to {self._lane_need(req)} KV "
                f"blocks but the pool holds {self.pool.n_blocks}; raise "
                "--kv-pool-blocks")
        self._submit_meta[req.rid] = (self.step_count, time.perf_counter())
        self._out[req.rid] = []
        self.queue.append(req)

    # -- internals -----------------------------------------------------------

    @staticmethod
    def _default_batch(tokens: np.ndarray):
        return {"tokens": jnp.asarray(tokens)}, {"tokens": P(None)}

    def _free_slots(self) -> list[int]:
        return [i for i, s in enumerate(self.slots) if s is None]

    def n_active(self) -> int:
        return sum(s is not None for s in self.slots)

    def _clear_lane(self, slot_i: int) -> None:
        """Dead-lane sentinel: pos -1 masks the lane's KV write and zeroes
        its attention; temp 0 / top-k 1 take the draw-free greedy path."""
        self.tok[slot_i] = 0
        self.pos[slot_i] = -1
        self.temp[slot_i] = 0.0
        self.top_k[slot_i] = 1
        self.keys[slot_i] = 0

    def _arm_lane(self, slot_i: int, req: Request, first_pos: int) -> None:
        """Slot enters the decoding phase at position `first_pos`."""
        self.pos[slot_i] = first_pos
        self.temp[slot_i] = req.temperature
        self.top_k[slot_i] = req.top_k
        self.keys[slot_i] = np.asarray(jax.random.PRNGKey(req.seed), np.uint32)

    # -- paged-pool bookkeeping (spec.paged) ---------------------------------

    def _lane_need(self, req: Request) -> int:
        """Worst-case number of pool blocks a lane running `req` ever
        allocates: its whole footprint when it fits the window, the full
        per-slot table otherwise (a wrapping lane COW-forks every shared
        block, so sharing buys it nothing in the worst case)."""
        bs, bps = self.spec.kv_block_size, self.spec.blocks_per_slot
        total = len(req.prompt) + req.max_new_tokens
        return bps if total > self.spec.cache_len else -(-total // bs)

    def _lane_alloc(self, st: _Slot) -> int:
        bid = self.pool.alloc(self.step_count)
        if st.reserve > 0:
            st.reserve -= 1
            self._reserved -= 1
        return bid

    def _prefix_attach(self, slot_i: int, st: _Slot) -> None:
        """Walk the prompt's prefix-key chain and share every consecutive
        hit (hot or cold-rehydrated) read-only; the lane skips prefilling
        the shared tokens.  Sharing is truncated so the skipped span is a
        whole number of prefill chunks AND at least one prompt token
        remains — the lane's remaining chunks then land at the same offsets
        as its solo chunk decomposition, which is what keeps shared-prefix
        streams bit-identical to solo runs."""
        req, C, bs = st.req, self.prefill_chunk, self.spec.kv_block_size
        taken: list[int] = []  # bids, one NEW reference each
        for key in st.pkeys:
            bid = self.pool.lookup(key, self.step_count)
            if bid is None and self.pool.lookup_cold(key) is not None:
                try:
                    bid, self.cache = self.engine.rehydrate_block(
                        self.cache, self.pool, key, self.step_count)
                except PoolExhausted:
                    bid = None
            if bid is None:
                break
            taken.append(bid)
        n = len(taken)
        while n and not ((n * bs) % C == 0 and n * bs < len(req.prompt)):
            n -= 1
        for bid in taken[n:]:
            self.pool.decref(bid, self.step_count)
        for j, bid in enumerate(taken[:n]):
            self.block_tables[slot_i, j] = bid
        st.pf_off = n * bs
        st.n_registered = n

    def _release_lane_blocks(self, slot_i: int) -> None:
        """Retirement: drop the lane's reference on every table entry —
        registered prompt blocks fall into deferred reclaim (LRU cache),
        generated-token blocks free immediately — and return its unused
        reservation."""
        st = self.slots[slot_i]
        if st is not None and st.reserve:
            self._reserved -= st.reserve
            st.reserve = 0
        for b in self.block_tables[slot_i]:
            if b >= 0:
                self.pool.decref(int(b), self.step_count)
        self.block_tables[slot_i] = -1

    def _prepare_decode_block(self, slot_i: int) -> None:
        """Before a lane's next decode write at pos p: make sure the target
        logical block has a writable private physical block.  Fresh logical
        blocks allocate; on ring wrap into a SHARED block (ref > 1) the lane
        COW-forks and device-copies the bytes first (other readers keep the
        original); wrapping a block it registered itself withdraws it from
        the prefix table (its content is about to change)."""
        st = self.slots[slot_i]
        p = int(self.pos[slot_i])
        w, bs = self.spec.cache_len, self.spec.kv_block_size
        j = (p % w) // bs
        b = int(self.block_tables[slot_i, j])
        if b < 0:
            self.block_tables[slot_i, j] = self._lane_alloc(st)
        elif p >= w and p % bs == 0:
            if self.pool.ref(b) > 1:
                new = self.pool.cow_fork(b, self.step_count)
                if st.reserve > 0:
                    st.reserve -= 1
                    self._reserved -= 1
                _, _, copyb = self.engine.kv_block_ops()
                self.cache = copyb(self.cache, jnp.int32(b), jnp.int32(new))
                self.block_tables[slot_i, j] = new
            elif self.pool.is_registered(b):
                self.pool.unregister(b)

    def _prepare_decode_blocks(self, slot_i: int, k: int) -> None:
        """Speculative variant of :meth:`_prepare_decode_block`: the lane may
        write up to `k` positions (p .. p+k-1) this step, so every logical
        block that span touches needs a physical block up front.  The
        scheduler only drafts k > 1 under the no-wrap gate (p + k <= window),
        where all written positions are past the prompt: the lane is the sole
        owner of every target block, so the COW / unregister branches can
        never fire — fresh allocation is the only case."""
        if k <= 1:
            self._prepare_decode_block(slot_i)
            return
        st = self.slots[slot_i]
        p = int(self.pos[slot_i])
        bs = self.spec.kv_block_size
        for j in range(p // bs, (p + k - 1) // bs + 1):
            if self.block_tables[slot_i, j] < 0:
                self.block_tables[slot_i, j] = self._lane_alloc(st)

    def _bt_device(self) -> jax.Array:
        # -1 (unallocated) entries are safe to ship raw: gathers clip them
        # and the position-validity math masks those logical slots, writes
        # only ever target allocated blocks
        return jnp.asarray(self.block_tables)

    def _emit(self, events: list, slot_i: int, token: int) -> None:
        """Record one generated token for the slot's request; retire the
        slot when the request is done."""
        st = self.slots[slot_i]
        req = st.req
        self._out[req.rid].append(token)
        st.n_out += 1
        self.tokens_generated += 1
        if st.n_out == 1:
            self._first_token[req.rid] = (self.step_count, time.perf_counter())
        done = (st.n_out >= req.max_new_tokens
                or (req.eos_id is not None and token == req.eos_id))
        events.append(TokenEvent(req.rid, token, st.n_out - 1, done))
        if done:
            submit_step, submit_time = self._submit_meta.pop(req.rid)
            ft_step, ft_time = self._first_token.pop(req.rid)
            self.finished[req.rid] = CompletedRequest(
                rid=req.rid,
                tokens=np.asarray(self._out.pop(req.rid), np.int32),
                submit_step=submit_step,
                admit_step=self._admit_step.pop(req.rid),
                finish_step=self.step_count,
                submit_time=submit_time,
                finish_time=time.perf_counter(),
                first_token_step=ft_step,
                first_token_time=ft_time,
            )
            if self.pool is not None:
                self._release_lane_blocks(slot_i)
            self.slots[slot_i] = None
            self._clear_lane(slot_i)
        else:
            self.tok[slot_i] = token

    # -- blocking admission (prefill_chunk == 0) -----------------------------

    def _admit_blocking(self, events: list) -> None:
        """Prefill queued requests into free slots (batch-of-1 prefill, then
        splice the slot cache lane in place).  Each pass dispatches every
        free slot's prefill asynchronously and host-syncs the produced
        tokens ONCE; the outer loop re-scans for slots freed by their own
        prefill token (max_new_tokens == 1 / instant EOS) so a retirement
        never leaves a lane idle while the queue is non-empty."""
        while self.queue:
            free = self._free_slots()
            if not free:
                return
            admitted: list[tuple[int, jax.Array]] = []
            for slot_i in free:
                if not self.queue:
                    break
                req = self.queue.popleft()
                s = len(req.prompt)
                tokens = np.asarray(req.prompt, np.int32)[None, :]
                batch, pspecs = self.batch_builder(tokens)
                extra = ()
                if self.spec.sampling:
                    extra = (make_sample_params(req.temperature, req.top_k,
                                                req.seed),)
                nxt1, cache1 = self.prefill_engine.prefill_step(pspecs)(
                    self.params, batch, self.gather_key, *extra)
                self.prefill_count += 1
                self._pf_shapes.add(s)
                self._max_pf_tokens = max(self._max_pf_tokens, s)
                self.cache = _splice_slot(self.cache, cache1,
                                          jnp.asarray(slot_i, jnp.int32))
                self.slots[slot_i] = _Slot(req=req, n_out=0)
                self._admit_step[req.rid] = self.step_count
                # slot decode state: the prefill token is fed at position s
                self._arm_lane(slot_i, req, s)
                admitted.append((slot_i, nxt1))
            if not admitted:
                return
            # ONE host sync for the whole pass (the prefills above were all
            # dispatched without a device round-trip between them)
            toks = jax.device_get([t for _, t in admitted])
            for (slot_i, _), t in zip(admitted, toks):
                self._emit(events, slot_i, int(np.asarray(t)[0]))

    # -- chunked admission (prefill_chunk > 0) -------------------------------

    def _assign_slots(self) -> None:
        """Move queued requests into free slots as `prefilling` occupants;
        no model work happens here — chunks run in :meth:`_chunk_pass`.

        Paged: admission is additionally gated on pool headroom — a request
        only enters a slot when the pool's reclaimable blocks cover its
        worst-case footprint on top of what already-admitted lanes may
        still claim (so no lane can deadlock mid-flight on an empty pool);
        otherwise it QUEUES, however long its prompt.  Admission then walks
        the prompt's prefix chain and shares every cached block read-only,
        skipping that span's prefill entirely."""
        for slot_i in self._free_slots():
            if not self.queue:
                return
            req = self.queue[0]
            if self.pool is not None:
                need = self._lane_need(req)
                if self.pool.free_blocks - self._reserved < need:
                    return  # pool pressure: keep queued (FIFO, no skip-ahead)
            self.queue.popleft()
            st = _Slot(req=req, n_out=0, prefilling=True)
            self.slots[slot_i] = st
            self._admit_step[req.rid] = self.step_count
            if self.pool is not None:
                st.pkeys = prefix_keys(req.prompt, self.spec.kv_block_size)
                st.reserve = need
                self._reserved += need
                if self.prefix_share:
                    self._prefix_attach(slot_i, st)
                wraps = (len(req.prompt) + req.max_new_tokens
                         > self.spec.cache_len)
                if st.pf_off and not wraps:
                    # shared blocks the lane will never allocate (a wrapping
                    # lane keeps the full reservation: it may COW-fork them)
                    n_shared = st.pf_off // self.spec.kv_block_size
                    st.reserve -= n_shared
                    self._reserved -= n_shared
            # the lane keeps the dead sentinel until its last chunk lands

    def _chunk_pass(self, events: list) -> None:
        """Advance every prefilling slot by one chunk per launch, at most
        ``prefill_interleave`` launches this step.  All concurrently
        prefilling slots' chunks ride ONE pooled launch, right-padded to
        the smallest shared bucket; lanes whose chunk completes the prompt
        stream their prefill token (one batched host sync) and start
        decoding this very step."""
        for _ in range(self.prefill_interleave):
            lanes = [i for i, s in enumerate(self.slots)
                     if s is not None and s.prefilling]
            if not lanes:
                return
            clen = {i: min(self.prefill_chunk,
                           len(self.slots[i].req.prompt) - self.slots[i].pf_off)
                    for i in lanes}
            bucket = prefill_bucket_for(max(clen.values()), self.buckets)
            tokens = np.zeros((self.B, bucket), np.int32)
            offset = np.zeros(self.B, np.int32)
            n_valid = np.zeros(self.B, np.int32)
            temp = np.zeros(self.B, np.float32)
            top_k = np.ones(self.B, np.int32)
            keys = np.zeros((self.B, 2), np.uint32)
            for i in lanes:
                st = self.slots[i]
                tokens[i, :clen[i]] = st.req.prompt[st.pf_off:st.pf_off + clen[i]]
                offset[i] = st.pf_off
                n_valid[i] = clen[i]
                temp[i] = st.req.temperature
                top_k[i] = st.req.top_k
                keys[i] = np.asarray(jax.random.PRNGKey(st.req.seed), np.uint32)
            bt = ()
            if self.pool is not None:
                bs = self.spec.kv_block_size
                for i in lanes:
                    st = self.slots[i]
                    for j in range(st.pf_off // bs,
                                   -(-(st.pf_off + clen[i]) // bs)):
                        if self.block_tables[i, j] < 0:
                            self.block_tables[i, j] = self._lane_alloc(st)
                bt = (self._bt_device(),)
            extra = ()
            if self.spec.sampling:
                extra = ({"temp": jnp.asarray(temp),
                          "top_k": jnp.asarray(top_k),
                          "key": jnp.asarray(keys)},)
            nxt, self.cache = self.engine.prefill_chunk_step(bucket)(
                self.params, self.cache, jnp.asarray(tokens),
                jnp.asarray(offset), jnp.asarray(n_valid), *bt,
                self.gather_key, *extra)
            self.prefill_chunk_count += 1
            self._pf_shapes.add(bucket)
            self._max_pf_tokens = max(self._max_pf_tokens, bucket)
            finishing = []
            for i in lanes:
                st = self.slots[i]
                st.pf_off += clen[i]
                if self.pool is not None and self.prefix_share:
                    # publish every prompt block this chunk completed (all
                    # chunk offsets are multiples of the chunk size from 0,
                    # so the block bytes are the canonical decomposition's)
                    full = min(st.pf_off // self.spec.kv_block_size,
                               len(st.pkeys))
                    for j in range(st.n_registered, full):
                        self.pool.register(st.pkeys[j],
                                           int(self.block_tables[i, j]))
                    st.n_registered = max(st.n_registered, full)
                if st.pf_off >= len(st.req.prompt):
                    finishing.append(i)
            if finishing:
                toks = np.asarray(jax.device_get(nxt))  # one sync per launch
                for i in finishing:
                    st = self.slots[i]
                    st.prefilling = False
                    self.prefill_count += 1
                    self._arm_lane(i, st.req, len(st.req.prompt))
                    self._emit(events, i, int(toks[i]))
                # a prefill token may retire its request instantly; refill
                # the freed lanes so they start prefilling next launch
                self._assign_slots()

    # -- the scheduler loop --------------------------------------------------

    def step(self) -> list[TokenEvent]:
        """Admit pending requests, then run ONE pooled decode step.  Under
        chunked admission the admit phase runs at most `prefill_interleave`
        chunk launches; under blocking admission it prefills whole prompts
        into every free slot.  Returns the tokens streamed this step
        (admission may also stream admitted requests' first tokens)."""
        events: list[TokenEvent] = []
        if self.prefill_chunk:
            self._assign_slots()
            self._chunk_pass(events)
        else:
            self._admit_blocking(events)
        active = [i for i, s in enumerate(self.slots)
                  if s is not None and not s.prefilling]
        if not active:
            return events
        # per-slot draft depth this step: capped by the remaining token
        # budget and the no-wrap gate — a lane whose window would wrap
        # inside the draft span (pos + k > cache_len) decodes plainly
        # (k = 1) through the COW-aware single-block path, so speculative
        # writes are always sole-owner, never rollback/COW
        n_spec = np.ones(self.B, np.int32)
        if self.spec.speculative:
            for i in active:
                st = self.slots[i]
                k_i = min(self.spec.draft_depth,
                          st.req.max_new_tokens - st.n_out,
                          self.spec.cache_len - int(self.pos[i]))
                n_spec[i] = max(1, k_i)
        kmax = max(int(n_spec[i]) for i in active)
        bt = ()
        if self.pool is not None:
            for i in active:
                self._prepare_decode_blocks(i, int(n_spec[i]))
            if self.pool.quant_horizon > 0 and self.pool.quant_cfg:
                # quantized cold tier: idle cached prefix blocks re-encode
                # into the core.quant wire format, freeing their hot block
                self.engine.demote_cold_blocks(self.cache, self.pool,
                                               self.step_count)
            bt = (self._bt_device(),)
        extra = ()
        if self.spec.sampling:
            extra = ({"temp": jnp.asarray(self.temp),
                      "top_k": jnp.asarray(self.top_k),
                      "key": jnp.asarray(self.keys)},)
        if kmax == 1:
            nxt, self.cache = self.engine.decode_step()(
                self.params, self.cache, jnp.asarray(self.tok),
                jnp.asarray(self.pos), *bt, self.gather_key, *extra)
            nxt = np.asarray(jax.device_get(nxt))
            self.decode_launches += 1
            self.step_count += 1
            self.occupancy_sum += len(active)
            for slot_i in active:
                self.pos[slot_i] += 1
                self._emit(events, slot_i, int(nxt[slot_i]))
            return events
        return self._step_speculative(events, active, n_spec, kmax, bt, extra)

    def _step_speculative(self, events: list, active: list[int],
                          n_spec: np.ndarray, kmax: int,
                          bt: tuple, extra: tuple) -> list[TokenEvent]:
        """Draft up to kmax-1 tokens per lane on the low-bit engine, then
        score the whole window in ONE pooled serving-precision launch.

        Round r of the draft feeds the previous round's token at position
        pos + r (lanes whose depth is exhausted ride along dead, pos -1);
        the drafts write draft-precision KV into the shared cache, every
        slot of which the verifier then overwrites with serving-precision
        KV before any future query can attend to it.  Verification scores
        [tok, d1, .., d_{k-1}] with the exact per-token decode math (same
        weights, same fold_in-keyed sampling streams), commits the longest
        prefix of drafts the serving model agrees with plus the one token
        it produces itself — so every committed token, greedy or sampled,
        is bit-identical to non-speculative decode by construction."""
        rows = [jnp.asarray(self.tok)]
        cur = rows[0]
        dstep = self.draft_engine.decode_step()
        for r in range(kmax - 1):
            live = (self.pos >= 0) & (n_spec - 1 > r)
            pos_r = np.where(live, self.pos + r, -1).astype(np.int32)
            cur, self.cache = dstep(
                self.draft_params, self.cache, cur, jnp.asarray(pos_r),
                *bt, self.gather_key, *extra)
            rows.append(cur)
            self.draft_launches += 1
            self.draft_lane_steps += int(live.sum())
        tok_mat = jnp.stack(rows, axis=1)  # (B, kmax) drafted window
        outs, self.cache = self.engine.verify_step(kmax)(
            self.params, self.cache, tok_mat, jnp.asarray(self.pos),
            jnp.asarray(n_spec), *bt, self.gather_key, *extra)
        self.verify_launches += 1
        self.spec_lane_steps += len(active)
        tok_host, out_host = jax.device_get((tok_mat, outs))
        tok_host = np.asarray(tok_host)
        out_host = np.asarray(out_host)
        self.step_count += 1
        self.occupancy_sum += len(active)
        for i in active:
            k_i = int(n_spec[i])
            a = 0  # accepted drafts: longest prefix the verifier agrees on
            while (a < k_i - 1
                   and int(out_host[i, a]) == int(tok_host[i, a + 1])):
                a += 1
            for j in range(a + 1):
                self.pos[i] += 1
                self.spec_tokens += 1
                self._emit(events, i, int(out_host[i, j]))
                if self.slots[i] is None:
                    break  # EOS / budget retirement mid-window
        return events

    def run(self, max_steps: Optional[int] = None,
            on_token: Optional[Callable[[TokenEvent], None]] = None
            ) -> dict[str, CompletedRequest]:
        """Drain the queue: step until every submitted request finished (or
        max_steps decode steps ran).  Returns {rid: CompletedRequest}."""
        steps = 0
        while self.queue or self.n_active():
            if max_steps is not None and steps >= max_steps:
                break
            for ev in self.step():
                if on_token is not None:
                    on_token(ev)
            steps += 1
        return self.finished

    def stats(self) -> dict:
        d = self.pool.capacity_stats() if self.pool is not None else {}
        return d | {
            "decode_steps": self.step_count,
            "prefills": self.prefill_count,
            "prefill_chunks": self.prefill_chunk_count,
            # distinct prefill shapes this scheduler compiled: bucket
            # lengths when chunked (bounded by len(self.buckets)), distinct
            # prompt lengths when blocking (unbounded — the bug chunking
            # fixes); bench_serve asserts on it in CI
            "prefill_traces": len(self._pf_shapes),
            # longest prompt-token stretch a single prefill launch processed
            # while live slots waited: the whole prompt under blocking
            # admission, at most one (padded) chunk under chunked admission
            "max_prefill_launch_tokens": self._max_pf_tokens,
            "tokens_generated": self.tokens_generated,
            "slots": self.B,
            "mean_occupancy": (self.occupancy_sum / self.step_count
                               if self.step_count else 0.0),
            # serving-precision launch accounting, normalized per lane so
            # the numbers are batch-composition independent:
            # launches_per_token = serving-precision lane-steps per decoded
            # token — exactly 1.0 for non-speculative decode (every active
            # lane in every pooled launch emits one token), < 1.0 iff
            # speculation commits more than one token per verify
            "decode_launches": self.decode_launches,
            "draft_launches": self.draft_launches,
            "draft_lane_steps": self.draft_lane_steps,
            "verify_launches": self.verify_launches,
            "spec_tokens": self.spec_tokens,
            "spec_lane_steps": self.spec_lane_steps,
            "lane_steps": self.occupancy_sum,
            "accepted_per_launch": (self.spec_tokens / self.spec_lane_steps
                                    if self.spec_lane_steps else 0.0),
            "launches_per_token": (
                self.occupancy_sum
                / max(1, self.tokens_generated - self.prefill_count)),
            # draft cost per committed token (low-bit lane-steps; the
            # speculative win is real when accepted_per_launch beats
            # 1 + draft_overhead * cost_ratio of the draft forward)
            "draft_overhead": (
                self.draft_lane_steps
                / max(1, self.tokens_generated - self.prefill_count)),
        }
