from .step import TrainState, build_train_step  # noqa: F401
from .checkpoint import load_checkpoint, save_checkpoint  # noqa: F401
