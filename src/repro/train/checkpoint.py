"""Sharded checkpointing: npz payloads + a validated JSON manifest.

Two on-disk formats:

``qsdp-ckpt-v1`` (legacy, still loads)
    Every leaf is an f32 (or int) ndarray in the rest (ZeRO-3) layout of
    the mesh it was saved on; the manifest records shapes/dtypes only.
    Loading requires the same mesh layout.

``qsdp-ckpt-v2`` (default)
    Same npz container, but :class:`~repro.core.quant.QuantizedParam`
    leaves (quantized-domain train state: packed master weights, 8-bit
    Adam moments) are written AS THEIR WIRE BYTES — u8 codes + per-bucket
    (scale, zero) — at ~bits/32 of the f32 payload, plus a manifest that
    records per-leaf kind ("dense" | "quantized"), the quantizer config,
    and the (model_size, fsdp_size) the state was saved under.  On load:

      * same mesh layout, quantized leaf  -> byte-identical QuantizedParam
        (resume is bit-exact; serve can feed the codes straight to
        ``QSDPEngine.gather_rowquant_wire`` with zero conversion);
      * different mesh layout             -> dense leaves are resharded
        through their logical form (bit-identical values); quantized
        leaves are decoded (deterministic, bit-identical f32 values) and
        resharded — pass ``dequantize=True`` to opt in, since the result
        is an f32 leaf, and re-enter quantized form with
        ``quantize_train_state`` if desired (fresh bucket boundaries).

    Both the manifest ``format`` field and every leaf's shape/dtype are
    validated against the npz payload on load; unknown formats and
    corrupted/mismatched manifests fail loudly.

Saves the rest-layout (ZeRO-3) state: each leaf is fetched to host in its
distributed layout and written whole (single-host container); on a real
multi-host pod each host would write only its addressable shards with the
same manifest format.  Loading re-places leaves with the model's pspecs.
"""
from __future__ import annotations

import json
import os
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from ..core.qsdp import MeshSpec, from_rest, to_rest
from ..core.quant import QuantConfig, QuantizedParam, qparam_decode
from ..optim import OptState
from .step import TrainState

FORMAT_V1 = "qsdp-ckpt-v1"
FORMAT_V2 = "qsdp-ckpt-v2"
_KNOWN_FORMATS = (FORMAT_V1, FORMAT_V2)


def _state_items(state: TrainState):
    """Yield (npz key, leaf) for every leaf of the state."""
    for k, v in state.params.items():
        yield f"params/{k}", v
    yield "opt/step", state.opt.step
    for name, tree in (("mu", state.opt.mu), ("nu", state.opt.nu)):
        if tree == ():
            continue
        for k, v in tree.items():
            yield f"opt/{name}/{k}", v


def _flatten(state: TrainState) -> tuple[dict[str, np.ndarray], dict[str, dict]]:
    """Host arrays + per-leaf manifest entries."""
    flat, leaves = {}, {}
    for key, v in _state_items(state):
        if isinstance(v, QuantizedParam):
            arr = np.asarray(jax.device_get(v.wire))
            leaves[key] = {
                "kind": "quantized",
                "shape": list(arr.shape),
                "dtype": str(arr.dtype),
                "cell_shape": list(v.cell_shape),
                "bits": v.cfg.bits,
                "bucket_size": v.cfg.bucket_size,
                "mode": v.cfg.mode,
                "meta_dtype": v.cfg.meta_dtype,
            }
        else:
            arr = np.asarray(jax.device_get(v))
            leaves[key] = {"kind": "dense", "shape": list(arr.shape),
                           "dtype": str(arr.dtype)}
        flat[key] = arr
    return flat, leaves


def _mesh_sizes(state: TrainState) -> tuple[int, int]:
    """(model_size, fsdp_size) read off the rest layout of the params."""
    for _, v in state.params.items():
        if isinstance(v, QuantizedParam):
            return int(v.wire.shape[-3]), int(v.wire.shape[-2])
        return int(v.shape[-3]), int(v.shape[-2])
    raise ValueError("empty state")


def save_checkpoint(path: str, state: TrainState, meta: dict[str, Any] | None = None,
                    format_version: int = 2) -> None:
    os.makedirs(path, exist_ok=True)
    flat, leaves = _flatten(state)
    if format_version == 1:
        if any(e["kind"] == "quantized" for e in leaves.values()):
            raise ValueError("qsdp-ckpt-v1 cannot hold QuantizedParam leaves; "
                             "save with format_version=2")
        manifest = {
            "format": FORMAT_V1,
            "leaves": {k: {"shape": e["shape"], "dtype": e["dtype"]}
                       for k, e in leaves.items()},
            "meta": meta or {},
        }
    elif format_version == 2:
        model_size, fsdp_size = _mesh_sizes(state)
        manifest = {
            "format": FORMAT_V2,
            "mesh": {"model_size": model_size, "fsdp_size": fsdp_size},
            "leaves": leaves,
            "meta": meta or {},
        }
    else:
        raise ValueError(f"unknown checkpoint format_version: {format_version}")
    np.savez(os.path.join(path, "state.npz"), **flat)
    with open(os.path.join(path, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)


def _read_manifest(path: str) -> dict:
    mpath = os.path.join(path, "manifest.json")
    if not os.path.exists(mpath):
        raise FileNotFoundError(f"checkpoint manifest missing: {mpath}")
    with open(mpath) as f:
        manifest = json.load(f)
    fmt = manifest.get("format")
    if fmt not in _KNOWN_FORMATS:
        raise ValueError(
            f"unknown checkpoint format {fmt!r} in {mpath}; "
            f"this build reads {list(_KNOWN_FORMATS)}")
    return manifest


def _validate_leaves(manifest: dict, data: dict[str, np.ndarray], path: str) -> None:
    leaves = manifest.get("leaves")
    if not isinstance(leaves, dict) or set(leaves) != set(data):
        raise ValueError(
            f"corrupted checkpoint manifest in {path}: leaf set mismatch "
            f"(manifest has {len(leaves or {})}, payload has {len(data)})")
    for k, e in leaves.items():
        if list(data[k].shape) != list(e["shape"]) or str(data[k].dtype) != e["dtype"]:
            raise ValueError(
                f"corrupted checkpoint manifest in {path}: leaf {k!r} is "
                f"{data[k].shape}/{data[k].dtype} on disk but "
                f"{tuple(e['shape'])}/{e['dtype']} in the manifest")


def _leaf_qcfg(e: dict) -> QuantConfig:
    return QuantConfig(bits=e["bits"], bucket_size=e["bucket_size"],
                       mode=e["mode"], meta_dtype=e.get("meta_dtype", "float32"))


def load_checkpoint(path: str, mesh, pspecs: TrainState,
                    model=None, dequantize: bool = False) -> TrainState:
    """Load a checkpoint onto `mesh`, placing leaves per `pspecs`.

    v2 checkpoints saved on a different (model_size, fsdp_size) layout are
    resharded through the logical parameter form — bit-identical values —
    which requires `model` (for the ParamSpecs).  Quantized leaves survive
    a same-layout load byte-for-byte; across layouts (or with
    ``dequantize=True``) they are decoded to their exact f32 values.
    """
    manifest = _read_manifest(path)
    with np.load(os.path.join(path, "state.npz")) as z:
        data = {k: z[k] for k in z.files}
    _validate_leaves(manifest, data, path)

    def put(arr, ps):
        return jax.device_put(jnp.asarray(arr), NamedSharding(mesh, ps))

    if manifest["format"] == FORMAT_V1:
        leaves = {k: {"kind": "dense"} for k in data}
        src_sizes = tgt_sizes = None
    else:
        leaves = manifest["leaves"]
        src_sizes = (manifest["mesh"]["model_size"], manifest["mesh"]["fsdp_size"])
        axes = dict(zip(mesh.axis_names, np.shape(mesh.devices)))
        tgt_sizes = (axes.get("model", 1),
                     axes.get("data", 1) * axes.get("pod", 1))
    same_layout = src_sizes is None or src_sizes == tgt_sizes
    if not same_layout and model is None:
        raise ValueError(
            f"checkpoint was saved on (model={src_sizes[0]}, fsdp={src_sizes[1]}) "
            f"but the target mesh is (model={tgt_sizes[0]}, fsdp={tgt_sizes[1]}); "
            "resharding needs the `model` argument")
    ms_src = (MeshSpec(axes=("data", "model"), shape=(src_sizes[1], src_sizes[0]))
              if src_sizes else None)

    def param_name(key: str) -> Optional[str]:
        for pre in ("params/", "opt/mu/", "opt/nu/"):
            if key.startswith(pre):
                return key[len(pre):]
        return None

    def load_leaf(key: str, ps):
        e = leaves[key]
        arr = data[key]
        name = param_name(key)
        if e.get("kind") == "quantized":
            qcfg = _leaf_qcfg(e)
            cell_shape = tuple(e["cell_shape"])
            if same_layout and not dequantize:
                return QuantizedParam(put(arr, ps), cell_shape, qcfg)
            if not same_layout and not dequantize:
                raise ValueError(
                    f"quantized leaf {key!r} cannot be resharded in wire form "
                    "(bucket boundaries are layout-dependent); load with "
                    "dequantize=True — the decoded values are bit-exact — and "
                    "re-enter wire form with quantize_train_state if desired")
            # exact decode to the source rest layout, then fall through to
            # the dense handling (caller's pspecs govern placement; the
            # reshard branch below re-derives them from the model)
            arr = np.asarray(qparam_decode(
                QuantizedParam(jnp.asarray(arr), cell_shape, qcfg)))
        if not same_layout:
            spec = model.specs[name]
            arr = to_rest(from_rest(jnp.asarray(arr), spec, ms_src), spec, model.ms)
            ps = spec.rest_pspec(model.ms)
        return put(arr, ps)

    params = {
        k[len("params/"):]: load_leaf(k, pspecs.params[k[len("params/"):]])
        for k in data
        if k.startswith("params/")
    }
    mu = {} if pspecs.opt.mu != () else ()
    nu = {} if pspecs.opt.nu != () else ()
    for k in data:
        if k.startswith("opt/mu/") and mu != ():
            name = k[len("opt/mu/"):]
            mu[name] = load_leaf(k, pspecs.opt.mu[name])
        elif k.startswith("opt/nu/") and nu != ():
            name = k[len("opt/nu/"):]
            nu[name] = load_leaf(k, pspecs.opt.nu[name])
    step = put(data["opt/step"], pspecs.opt.step)
    return TrainState(params=params, opt=OptState(step=step, mu=mu, nu=nu))


def checkpoint_payload_bytes(path: str) -> dict[str, int]:
    """Per-leaf payload bytes of a saved checkpoint (exact npz array bytes,
    excluding zip container overhead) — benchmarks and tests use this to
    track the quantized-state memory win."""
    with np.load(os.path.join(path, "state.npz")) as z:
        return {k: int(z[k].nbytes) for k in z.files}
