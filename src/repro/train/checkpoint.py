"""Sharded checkpointing: npz payloads + a JSON manifest.

Saves the rest-layout (ZeRO-3) state: each leaf is fetched to host in its
distributed layout and written whole (single-host container); on a real
multi-host pod each host would write only its addressable shards with the
same manifest format.  Loading re-places leaves with the model's pspecs.
"""
from __future__ import annotations

import json
import os
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding

from ..optim import OptState
from .step import TrainState


def _flatten(state: TrainState) -> dict[str, np.ndarray]:
    out = {}
    for k, v in state.params.items():
        out[f"params/{k}"] = np.asarray(jax.device_get(v))
    out["opt/step"] = np.asarray(jax.device_get(state.opt.step))
    for name, tree in (("mu", state.opt.mu), ("nu", state.opt.nu)):
        if tree == ():
            continue
        for k, v in tree.items():
            out[f"opt/{name}/{k}"] = np.asarray(jax.device_get(v))
    return out


def save_checkpoint(path: str, state: TrainState, meta: dict[str, Any] | None = None) -> None:
    os.makedirs(path, exist_ok=True)
    flat = _flatten(state)
    np.savez(os.path.join(path, "state.npz"), **flat)
    manifest = {
        "format": "qsdp-ckpt-v1",
        "leaves": {k: {"shape": list(v.shape), "dtype": str(v.dtype)} for k, v in flat.items()},
        "meta": meta or {},
    }
    with open(os.path.join(path, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)


def load_checkpoint(path: str, mesh, pspecs: TrainState) -> TrainState:
    with np.load(os.path.join(path, "state.npz")) as z:
        data = {k: z[k] for k in z.files}

    def put(arr, ps):
        return jax.device_put(jnp.asarray(arr), NamedSharding(mesh, ps))

    params = {
        k[len("params/"):]: put(v, pspecs.params[k[len("params/"):]])
        for k, v in data.items()
        if k.startswith("params/")
    }
    mu = {} if pspecs.opt.mu != () else ()
    nu = {} if pspecs.opt.nu != () else ()
    for k, v in data.items():
        if k.startswith("opt/mu/") and mu != ():
            name = k[len("opt/mu/"):]
            mu[name] = put(v, pspecs.opt.mu[name])
        elif k.startswith("opt/nu/") and nu != ():
            name = k[len("opt/nu/"):]
            nu[name] = put(v, pspecs.opt.nu[name])
    step = put(data["opt/step"], pspecs.opt.step)
    return TrainState(params=params, opt=OptState(step=step, mu=mu, nu=nu))
