"""Train-step builder: gradient accumulation + QSDP-wired backward +
sharded optimizer update, all inside one shard_map.

Schedule per optimizer step (paper Figure 5 + Appendix A):

  for each of n_micro microbatches:             (scan, rematerialized)
      for each layer:  quantized AllGather(w)   -> forward
      for each layer:  quantized AllGather(w)   -> backward
                       quantized ReduceScatter(g)
  grads averaged over microbatches              (local, sharded)
  AdamW update on the f32 master shards         (local, sharded)
  [optional] Q^w re-quantization of the master  (theory-faithful mode)

Under ``QSDPConfig.coalesce`` every per-layer AllGather / ReduceScatter
above is ONE u8 collective launch carrying the whole layer's coalesced wire
buffer (codes + metadata + filtered-fp payloads) instead of 3 x n_params
launches — same bytes, same decoded values, ~20x fewer launches (see
core/qsdp.py).  Under ``QSDPConfig.prefetch`` the scan-over-layers inside
``Model.loss_fn`` is additionally double-buffered: layer i+1's gather is
in flight while layer i computes, in the forward and the rematerialized
backward alike (``benchmarks/bench_step.py`` measures all three schedules).

Quantized-domain train state (``quantized_state=True``)
-------------------------------------------------------
The paper's Theorem 2 maintains ONLY quantized weights.  The historical
``quantize_master=True`` mode emulated that with f32 leaves round-tripped
through quantize->dequantize each step; ``quantized_state=True`` makes the
state itself quantized: every master-eligible parameter rests as a
:class:`~repro.core.quant.QuantizedParam` (packed u8 wire codes +
per-bucket affine, ~bits/32 of the f32 bytes).  Per step, each device
dequantizes its shard locally, runs the identical schedule above, and
re-quantizes the updated shard under the SAME per-step keys the QDQ master
uses (``fold_in(key, 0x3A57E9)`` then ``_h(name)``) — so the loss/param
trajectory is bit-exact with ``quantize_master=True`` started from the
same (quantization-grid) initial state; see ``quantize_train_state`` /
``dequantize_train_state``.

Gradient semantics: `Model.loss_fn` returns the per-device local-batch mean
with no collectives on the loss path; the engine's reduce-scatter backward
divides by the FSDP size, so accumulated grads are exact global-batch means.
Global-norm clipping needs one extra psum over all mesh axes (each element
of the sharded param grid lives on exactly one device).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import NamedSharding, PartitionSpec as P

from ..compat import shard_map
from ..core.quant import (
    QuantConfig,
    QuantizedParam,
    qparam_decode,
    qparam_encode,
    quantize_dequantize,
)
from ..models.transformer import Model
from ..optim import Optimizer, OptState


class TrainState(NamedTuple):
    params: dict[str, Any]  # f32 rest-layout leaves and/or QuantizedParam
    opt: OptState


def init_train_state(model: Model, optimizer: Optimizer, key: jax.Array) -> TrainState:
    params = model.init_params(key)
    return TrainState(params=params, opt=optimizer.init(params))


# -- master quantization policy (shared by quantize_master / quantized_state) --

_MASTER_SALT = 0x3A57E9


def master_quant_config(model: Model, master_bits: int = 8) -> QuantConfig:
    """The Q^w the master weights are re-quantized with (paper Theorem 2:
    random-shift rounding at the engine's bucket granularity)."""
    return QuantConfig(bits=master_bits, bucket_size=model.qcfg.bucket_size,
                       mode="shift")


def master_eligible(model: Model, name: str) -> bool:
    """Params the master quantization applies to — the same filter the wire
    quantization uses (norms / biases / tiny tensors stay full precision)."""
    spec = model.specs[name]
    return bool(
        spec.quantize
        and spec.n_logical_local(model.ms.model_size) >= model.qcfg.min_quant_size
    )


def quantize_train_state(state: TrainState, model: Model, key: jax.Array,
                         master_bits: int = 8) -> TrainState:
    """Convert an f32 TrainState into quantized-domain form: every
    master-eligible param leaf becomes a :class:`QuantizedParam` holding its
    packed wire codes, quantized under the same key schedule a train step
    with this `key` would use.  Host-side helper (global rest arrays);
    optimizer moments are left as the optimizer built them."""
    qc = master_quant_config(model, master_bits)
    mkey = jax.random.fold_in(key, _MASTER_SALT)
    params = {}
    for name, p in state.params.items():
        if master_eligible(model, name) and not isinstance(p, QuantizedParam):
            params[name] = qparam_encode(p, qc, jax.random.fold_in(mkey, _h(name)))
        else:
            params[name] = p
    return TrainState(params=params, opt=state.opt)


def dequantize_train_state(state: TrainState) -> TrainState:
    """Decode every QuantizedParam leaf (params AND optimizer moments) back
    to dense f32 rest layout.  Decoding is deterministic, so this yields
    exactly the values a `quantize_master=True` QDQ step would have stored."""
    def dec(leaf):
        return qparam_decode(leaf) if isinstance(leaf, QuantizedParam) else leaf

    params = {k: dec(v) for k, v in state.params.items()}
    mu = state.opt.mu if state.opt.mu == () else {k: dec(v) for k, v in state.opt.mu.items()}
    nu = state.opt.nu if state.opt.nu == () else {k: dec(v) for k, v in state.opt.nu.items()}
    return TrainState(params=params, opt=OptState(step=state.opt.step, mu=mu, nu=nu))


def state_pspecs(model: Model, optimizer_has_mu: bool = True, has_nu: bool = True,
                 quantized_state: bool = False, quantized_moments: bool = False):
    """PartitionSpec tree for a TrainState.  QuantizedParam leaves hold a
    rank-3 (MODEL, FSDP, nbytes) wire array whatever the stack, so their
    spec is always the flat wire spec (shard_map prefix-broadcasts the P
    over the QuantizedParam subtree)."""
    wire_p = P("model", model.ms.fsdp_axes, None)
    pspec = {}
    for name, spec in model.specs.items():
        if quantized_state and master_eligible(model, name):
            pspec[name] = wire_p
        else:
            pspec[name] = spec.rest_pspec(model.ms)
    base = model.param_pspecs()
    mom = {name: wire_p for name in base} if quantized_moments else base
    mu = mom if optimizer_has_mu else ()
    nu = mom if has_nu else ()
    return TrainState(
        params=pspec,
        opt=OptState(step=P(), mu=mu, nu=nu),
    )


def build_train_step(
    model: Model,
    optimizer: Optimizer,
    n_micro: int = 1,
    grad_clip: float = 1.0,
    quantize_master: bool = False,
    master_bits: int = 8,
    quantized_state: bool = False,
):
    """Returns the per-device step_fn to be wrapped in shard_map by the
    caller (launch.train / dryrun).  Buffer donation is owned by that
    caller's jit (see ``make_jitted_train_step``'s `donate`).

    quantize_master:  f32 state, round-tripped through Q^w each step (QDQ).
    quantized_state:  the state's master-eligible leaves ARE the wire codes
                      (QuantizedParam): decode shard-locally at step entry,
                      re-quantize at step exit under the same keys — bit-
                      exact with the QDQ path (see module docstring).
    """
    ms = model.ms
    all_axes = tuple(ms.axes)

    def step_fn(state: TrainState, batch: dict, key: jax.Array) -> tuple[TrainState, dict]:
        if quantized_state:
            params = {k: qparam_decode(v) if isinstance(v, QuantizedParam) else v
                      for k, v in state.params.items()}
        else:
            params = state.params

        # ---- microbatch split along the batch axis of every batch leaf ----
        # (axis 0 for everything except the M-RoPE "positions" stream, whose
        # leading axis is the 3 temporal/height/width channels)
        def split(name, x):
            ax = 1 if name == "positions" else 0
            b = x.shape[ax]
            assert b % n_micro == 0, (name, b, n_micro)
            x = jnp.moveaxis(x, ax, 0)
            x = x.reshape(n_micro, b // n_micro, *x.shape[1:])
            return jnp.moveaxis(x, 1, ax + 1)

        micro = {k: split(k, v) for k, v in batch.items()}

        def micro_step(carry, inp):
            acc, i = carry
            mb = inp
            mkey = jax.random.fold_in(key, i)
            loss, grads = jax.value_and_grad(model.loss_fn)(params, mb, mkey)
            acc = jax.tree.map(lambda a, g: a + g.astype(jnp.float32), acc, grads)
            return (acc, i + 1), loss

        zero = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        (gsum, _), losses = lax.scan(micro_step, (zero, jnp.zeros((), jnp.int32)), micro)
        grads = jax.tree.map(lambda g: g / n_micro, gsum)
        loss = jnp.mean(losses)

        # ---- global-norm clip (elements are disjoint across the mesh) ----
        sq = sum(jnp.sum(jnp.square(g)) for g in jax.tree.leaves(grads))
        gnorm = jnp.sqrt(lax.psum(sq, all_axes))
        if grad_clip:
            scale = jnp.minimum(1.0, grad_clip / jnp.maximum(gnorm, 1e-12))
        else:
            scale = jnp.ones(())

        new_params, new_opt = optimizer.update(params, grads, state.opt, grad_scale=scale)

        # ---- theory-faithful master quantization (Theorem 2) -------------
        if quantize_master or quantized_state:
            qc = master_quant_config(model, master_bits)
            mkey = jax.random.fold_in(key, _MASTER_SALT)

            def qmaster(name, p):
                if not master_eligible(model, name):
                    return p
                pkey = jax.random.fold_in(mkey, _h(name))
                if quantized_state:
                    return qparam_encode(p, qc, pkey)
                return quantize_dequantize(p, qc, pkey).astype(p.dtype)

            new_params = {k: qmaster(k, v) for k, v in new_params.items()}

        metrics = {
            "loss": lax.pmean(loss, all_axes),
            "grad_norm": gnorm,
            "step": new_opt.step,
        }
        return TrainState(params=new_params, opt=new_opt), metrics

    return step_fn


def _h(s: str) -> int:
    h = 2166136261
    for ch in s.encode():
        h = (h ^ ch) * 16777619 & 0xFFFFFFFF
    return h


def make_jitted_train_step(model: Model, optimizer: Optimizer, mesh, n_micro: int = 1,
                           batch_pspec: Optional[dict] = None, donate: bool = True,
                           quantized_state: bool = False, **kw):
    """Convenience: shard_map + jit the per-device step over `mesh`."""
    step = build_train_step(model, optimizer, n_micro=n_micro,
                            quantized_state=quantized_state, **kw)
    sspec = state_pspecs(
        model,
        quantized_state=quantized_state,
        quantized_moments=getattr(optimizer, "quantized_moments", False),
    )
    if batch_pspec is None:
        batch_pspec = {"tokens": P(model.ms.fsdp_axes), "labels": P(model.ms.fsdp_axes)}
    mapped = shard_map(
        step, mesh=mesh,
        in_specs=(sspec, batch_pspec, P()),
        out_specs=(sspec, {"loss": P(), "grad_norm": P(), "step": P()}),
        check_vma=False,
    )
    return jax.jit(mapped, donate_argnums=(0,) if donate else ())
