"""Train-step builder: gradient accumulation + QSDP-wired backward +
sharded optimizer update, all inside one shard_map.

Schedule per optimizer step (paper Figure 5 + Appendix A):

  for each of n_micro microbatches:             (scan, rematerialized)
      for each layer:  quantized AllGather(w)   -> forward
      for each layer:  quantized AllGather(w)   -> backward
                       quantized ReduceScatter(g)
  grads averaged over microbatches              (local, sharded)
  AdamW update on the f32 master shards         (local, sharded)
  [optional] Q^w re-quantization of the master  (theory-faithful mode)

Under ``QSDPConfig.coalesce`` every per-layer AllGather / ReduceScatter
above is ONE u8 collective launch carrying the whole layer's coalesced wire
buffer (codes + metadata + filtered-fp payloads) instead of 3 x n_params
launches — same bytes, same decoded values, ~20x fewer launches (see
core/qsdp.py).  Under ``QSDPConfig.prefetch`` the scan-over-layers inside
``Model.loss_fn`` is additionally double-buffered: layer i+1's gather is
in flight while layer i computes, in the forward and the rematerialized
backward alike (``benchmarks/bench_step.py`` measures all three schedules).

Gradient semantics: `Model.loss_fn` returns the per-device local-batch mean
with no collectives on the loss path; the engine's reduce-scatter backward
divides by the FSDP size, so accumulated grads are exact global-batch means.
Global-norm clipping needs one extra psum over all mesh axes (each element
of the sharded param grid lives on exactly one device).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import NamedSharding, PartitionSpec as P

from ..compat import shard_map
from ..core.quant import QuantConfig, quantize_dequantize
from ..models.transformer import Model
from ..optim import Optimizer, OptState


class TrainState(NamedTuple):
    params: dict[str, jax.Array]
    opt: OptState


def init_train_state(model: Model, optimizer: Optimizer, key: jax.Array) -> TrainState:
    params = model.init_params(key)
    return TrainState(params=params, opt=optimizer.init(params))


def state_pspecs(model: Model, optimizer_has_mu: bool = True, has_nu: bool = True):
    pspec = model.param_pspecs()
    mu = pspec if optimizer_has_mu else ()
    nu = pspec if has_nu else ()
    return TrainState(
        params=pspec,
        opt=OptState(step=P(), mu=mu, nu=nu),
    )


def build_train_step(
    model: Model,
    optimizer: Optimizer,
    n_micro: int = 1,
    grad_clip: float = 1.0,
    quantize_master: bool = False,
    master_bits: int = 8,
    donate: bool = True,
):
    """Returns (step_fn, in_specs, out_specs).  step_fn is per-device code
    to be wrapped in shard_map by the caller (launch.train / dryrun)."""
    ms = model.ms
    all_axes = tuple(ms.axes)

    def step_fn(state: TrainState, batch: dict, key: jax.Array) -> tuple[TrainState, dict]:
        params = state.params

        # ---- microbatch split along the batch axis of every batch leaf ----
        # (axis 0 for everything except the M-RoPE "positions" stream, whose
        # leading axis is the 3 temporal/height/width channels)
        def split(name, x):
            ax = 1 if name == "positions" else 0
            b = x.shape[ax]
            assert b % n_micro == 0, (name, b, n_micro)
            x = jnp.moveaxis(x, ax, 0)
            x = x.reshape(n_micro, b // n_micro, *x.shape[1:])
            return jnp.moveaxis(x, 1, ax + 1)

        micro = {k: split(k, v) for k, v in batch.items()}

        def micro_step(carry, inp):
            acc, i = carry
            mb = inp
            mkey = jax.random.fold_in(key, i)
            loss, grads = jax.value_and_grad(model.loss_fn)(params, mb, mkey)
            acc = jax.tree.map(lambda a, g: a + g.astype(jnp.float32), acc, grads)
            return (acc, i + 1), loss

        zero = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        (gsum, _), losses = lax.scan(micro_step, (zero, jnp.zeros((), jnp.int32)), micro)
        grads = jax.tree.map(lambda g: g / n_micro, gsum)
        loss = jnp.mean(losses)

        # ---- global-norm clip (elements are disjoint across the mesh) ----
        if grad_clip:
            sq = sum(jnp.sum(jnp.square(g)) for g in jax.tree.leaves(grads))
            gnorm = jnp.sqrt(lax.psum(sq, all_axes))
            scale = jnp.minimum(1.0, grad_clip / jnp.maximum(gnorm, 1e-12))
        else:
            sq = sum(jnp.sum(jnp.square(g)) for g in jax.tree.leaves(grads))
            gnorm = jnp.sqrt(lax.psum(sq, all_axes))
            scale = jnp.ones(())

        new_params, new_opt = optimizer.update(params, grads, state.opt, grad_scale=scale)

        # ---- optional theory-faithful master quantization (Theorem 2) ----
        if quantize_master:
            qc = QuantConfig(bits=master_bits, bucket_size=model.qcfg.bucket_size, mode="shift")
            mkey = jax.random.fold_in(key, 0x3A57E9)

            def qmaster(name, p):
                spec = model.specs[name]
                if not spec.quantize or spec.n_logical_local(ms.model_size) < model.qcfg.min_quant_size:
                    return p
                return quantize_dequantize(p, qc, jax.random.fold_in(mkey, _h(name))).astype(p.dtype)

            new_params = {k: qmaster(k, v) for k, v in new_params.items()}

        metrics = {
            "loss": lax.pmean(loss, all_axes),
            "grad_norm": gnorm,
            "step": new_opt.step,
        }
        return TrainState(params=new_params, opt=new_opt), metrics

    return step_fn


def _h(s: str) -> int:
    h = 2166136261
    for ch in s.encode():
        h = (h ^ ch) * 16777619 & 0xFFFFFFFF
    return h


def make_jitted_train_step(model: Model, optimizer: Optimizer, mesh, n_micro: int = 1,
                           batch_pspec: Optional[dict] = None, donate: bool = True,
                           **kw):
    """Convenience: shard_map + jit the per-device step over `mesh`."""
    step = build_train_step(model, optimizer, n_micro=n_micro, **kw)
    sspec = state_pspecs(model)
    if batch_pspec is None:
        batch_pspec = {"tokens": P(model.ms.fsdp_axes), "labels": P(model.ms.fsdp_axes)}
    mapped = shard_map(
        step, mesh=mesh,
        in_specs=(sspec, batch_pspec, P()),
        out_specs=(sspec, {"loss": P(), "grad_norm": P(), "step": P()}),
        check_vma=False,
    )
    return jax.jit(mapped, donate_argnums=(0,) if donate else ())
