"""Offline deployment-plan autotuner (fpgaHART idiom).

The quant-and-schedule design space — bits x rounding x bucket x meta dtype
x coalesce/prefetch x prefill chunk/buckets x slots — is searched offline
with per-layer analytic cost models (launch counts, wire bytes, roofline
times), the shortlist is measured with the real train step, and the winner
is emitted as a versioned :class:`DeploymentPlan` JSON that
``launch/train.py`` and ``launch/serve.py`` consume instead of flag soup.

    PYTHONPATH=src python -m repro.tune.autotune --smoke --out PLAN.json
"""
from .cost_model import (CostParams, GatherCost, HW_PRESETS, crossover_bytes,
                         layer_gather_cost, plan_layer_policies,
                         predict_hlo_gather_counts, predict_step_time)
from .plan import PLAN_VERSION, DeploymentPlan, LayerPolicy
from .space import Candidate, enumerate_space
from .search import exhaustive_search, simulated_annealing

__all__ = [
    "PLAN_VERSION", "DeploymentPlan", "LayerPolicy",
    "CostParams", "GatherCost", "HW_PRESETS", "crossover_bytes",
    "layer_gather_cost", "plan_layer_policies", "predict_hlo_gather_counts",
    "predict_step_time",
    "Candidate", "enumerate_space",
    "exhaustive_search", "simulated_annealing",
]
