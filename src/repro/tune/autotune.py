"""Offline deployment-plan autotuner CLI.

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \\
    PYTHONPATH=src python -m repro.tune.autotune --arch gpt-125m --smoke \\
        --data-par 4 --model-par 2 --hw cpu-smoke --out PLAN.json

Pipeline (fpgaHART idiom):
  1. cost-model every candidate of the composed design space (per-layer
     launch counts + wire bytes + serialization terms -> predicted step
     time), including the coalesce byte-threshold cut points the model's
     crossover suggests;
  2. measure the shortlist with the real jitted train step;
  3. derive the per-layer coalesce policy (the headline bugfix: small-mesh
     deployments fall back to per-tensor gathers where the coalesced
     buffer's serialization cost outweighs the launch savings);
  4. emit a versioned DeploymentPlan JSON for launch/train.py --plan and
     launch/serve.py --plan.

``--assert-choice per-tensor`` makes CI fail loudly if the planner stops
selecting per-tensor gathers on the tiny CPU mesh (regression guard: the
fix must stay load-bearing).
"""
import os

if "XLA_FLAGS" not in os.environ:
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import argparse
import dataclasses

import jax

from .. import configs
from ..core.qsdp import MeshSpec, QSDPConfig
from ..data import SyntheticLM
from ..models.transformer import Model
from .cost_model import (HW_PRESETS, crossover_bytes, layer_gather_cost,
                         layer_groups, plan_layer_policies, predict_step_time)
from .measure import measure_train_step
from .plan import PLAN_VERSION, DeploymentPlan
from .search import exhaustive_search, simulated_annealing
from .space import Candidate, enumerate_space


def parse_args(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gpt-125m")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--data-par", type=int, default=4)
    ap.add_argument("--model-par", type=int, default=2)
    ap.add_argument("--hw", default="cpu-smoke", choices=sorted(HW_PRESETS),
                    help="cost-model hardware preset")
    ap.add_argument("--out", default="PLAN.json")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=32)
    ap.add_argument("--n-micro", type=int, default=1)
    ap.add_argument("--min-quant-size", type=int, default=2048)
    ap.add_argument("--steps", type=int, default=3,
                    help="timed steps per measured shortlist candidate")
    ap.add_argument("--measure-top", type=int, default=3,
                    help="measure this many cost-model leaders (0 = trust "
                         "the model, skip measurement)")
    ap.add_argument("--full-space", action="store_true",
                    help="also search the quality-affecting axes (bits / "
                         "bucket / meta dtype)")
    ap.add_argument("--search", default="auto",
                    choices=("auto", "exhaustive", "anneal"))
    ap.add_argument("--anneal-iters", type=int, default=200)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--slots", type=int, default=8,
                    help="serve section: decode slot pool size")
    ap.add_argument("--prefill-chunk", type=int, default=0)
    ap.add_argument("--prefill-buckets", type=int, default=4)
    ap.add_argument("--assert-choice", default="any",
                    choices=("any", "per-tensor", "coalesced"),
                    help="fail unless the plan's policy for the stacked "
                         "layer group matches (CI regression guard)")
    return ap.parse_args(argv)


def _engine_for(mcfg, ms: MeshSpec, qcfg: QSDPConfig):
    return Model(mcfg, ms, qcfg).engine


def main(argv=None):
    args = parse_args(argv)
    cp = HW_PRESETS[args.hw]
    ms = MeshSpec(axes=("data", "model"),
                  shape=(args.data_par, args.model_par))
    nd = args.data_par * args.model_par
    if len(jax.devices()) < nd:
        raise SystemExit(
            f"mesh ({args.data_par},{args.model_par}) needs {nd} devices, "
            f"have {len(jax.devices())} — set XLA_FLAGS="
            f"--xla_force_host_platform_device_count={nd}")
    mcfg = (configs.get_smoke(args.arch) if args.smoke
            else configs.get_config(args.arch))
    base_qsdp = QSDPConfig(min_quant_size=args.min_quant_size)
    base_cand = Candidate(slots=args.slots, prefill_chunk=args.prefill_chunk,
                          prefill_buckets=args.prefill_buckets)

    # -- 1. candidate space, seeded with the model's crossover threshold ----
    probe = _engine_for(mcfg, ms, dataclasses.replace(
        base_qsdp, coalesce=True, coalesce_max_bytes=None))
    groups = layer_groups(probe)
    stacked = [(g, ns) for g, ns, stack in groups if stack > 1]
    main_group, main_names = (stacked[0] if stacked
                              else (groups[0][0], groups[0][1]))
    xover = crossover_bytes(probe, main_names, cp)
    # a threshold of 0 compiles to the same program as per-tensor — no
    # point measuring it twice
    ths = (None, xover) if xover > 0 else (None,)
    cands = list(enumerate_space(thresholds=ths, full_space=args.full_space,
                                 base=base_cand))

    def cost_fn(cand: Candidate) -> float:
        eng = _engine_for(mcfg, ms, cand.to_qsdp(base_qsdp))
        t = predict_step_time(eng, cp, n_micro=args.n_micro)
        if cand.prefetch:
            # the pipeline's wrapped-around epilogue gather is pure overhead
            # (one extra coalesced layer gather per traversal, fwd + bwd)
            for g, ns, stack in layer_groups(eng):
                if stack > 1 and eng.layer_coalesced(tuple(ns)):
                    t += 2 * args.n_micro * layer_gather_cost(
                        eng, ns, True).time_s(cp)
        return t

    n_eval = len(cands)
    use_anneal = (args.search == "anneal"
                  or (args.search == "auto" and n_eval > 512))
    if use_anneal:
        ranked = simulated_annealing(cands, cost_fn, seed=args.seed,
                                     iters=args.anneal_iters)
    else:
        ranked = exhaustive_search(cands, cost_fn)
    print(f"# cost model ({cp.name}): {n_eval} candidates, "
          f"crossover buffer {xover} B "
          f"({'anneal' if use_anneal else 'exhaustive'})")
    for t, c in ranked[:5]:
        print(f"#   {t * 1e3:9.3f} ms  {c.label()}")

    # -- 2. measure the shortlist ------------------------------------------
    measured = {}
    winner_cost, winner = ranked[0]
    if args.measure_top > 0:
        data = SyntheticLM(vocab_size=mcfg.vocab_size, seq_len=args.seq,
                           global_batch=args.batch, seed=args.seed + 1)
        tokens, labels = data.sample(0)
        batch = {"tokens": tokens, "labels": labels}
        # equal predicted cost => same compiled program (the model is a
        # function of the induced policy); measure each program once
        shortlist, seen = [], set()
        for t, c in ranked:
            if t not in seen:
                shortlist.append((t, c))
                seen.add(t)
            if len(shortlist) == args.measure_top:
                break
        best_ms = None
        for t, c in shortlist:
            r = measure_train_step(mcfg, ms, c.to_qsdp(base_qsdp), batch,
                                   n_micro=args.n_micro, steps=args.steps,
                                   seed=args.seed)
            measured[c.label()] = {**r, "predicted_ms": t * 1e3}
            print(f"# measured {r['step_ms_median']:9.3f} ms "
                  f"(predicted {t * 1e3:9.3f})  {c.label()}")
            if best_ms is None or r["step_ms_median"] < best_ms:
                best_ms, winner, winner_cost = r["step_ms_median"], c, t

    # -- 3. per-layer coalesce policy for the winner ------------------------
    policy_eng = _engine_for(mcfg, ms, dataclasses.replace(
        winner.to_qsdp(base_qsdp), coalesce=True, coalesce_max_bytes=None))
    policies, model_thresh = plan_layer_policies(policy_eng, cp)
    if not winner.coalesce:
        # measurement vetoed coalescing outright: the thresholded policy
        # must not coalesce anything (threshold 0 if the model disagreed)
        if any(p.coalesce for p in policies):
            model_thresh = 0
            policies = [dataclasses.replace(p, coalesce=False)
                        for p in policies]
    final_qsdp = dataclasses.replace(
        winner.to_qsdp(base_qsdp), coalesce=True,
        coalesce_max_bytes=model_thresh,
        prefetch=winner.prefetch and any(
            p.coalesce for p in policies if p.group == main_group))
    final_eng = _engine_for(mcfg, ms, final_qsdp)

    # -- 4. emit ------------------------------------------------------------
    plan = DeploymentPlan(
        version=PLAN_VERSION,
        arch=mcfg.name,
        mesh_axes=ms.axes,
        mesh_shape=ms.shape,
        hw=cp.name,
        qsdp={
            "quantize_weights": final_qsdp.quantize_weights,
            "quantize_grads": final_qsdp.quantize_grads,
            "weight_bits": final_qsdp.weight_bits,
            "grad_bits": final_qsdp.grad_bits,
            "bucket_size": final_qsdp.bucket_size,
            "weight_mode": final_qsdp.weight_mode,
            "grad_mode": final_qsdp.grad_mode,
            "min_quant_size": final_qsdp.min_quant_size,
            "meta_wire_dtype": final_qsdp.meta_wire_dtype,
            "hierarchical": final_qsdp.hierarchical,
            "coalesce": final_qsdp.coalesce,
            "prefetch": final_qsdp.prefetch,
            "coalesce_max_bytes": final_qsdp.coalesce_max_bytes,
        },
        serve={
            "slots": winner.slots,
            "prefill_chunk": winner.prefill_chunk,
            "prefill_buckets": winner.prefill_buckets,
            "draft_bits": winner.draft_bits,
            "draft_depth": winner.draft_depth,
        },
        layers=tuple(policies),
        predicted={
            "step_ms": winner_cost * 1e3,
            "crossover_buffer_bytes": xover,
            "candidates_evaluated": n_eval,
            "search": "anneal" if use_anneal else "exhaustive",
        },
        measured=measured,
    )
    plan.save(args.out)
    main_co = final_eng.layer_coalesced(tuple(main_names))
    choice = "coalesced" if main_co else "per-tensor"
    print(f"# plan: {choice} gathers for group '{main_group}' "
          f"(buffer {final_eng.layer_wire_bytes(tuple(main_names))} B, "
          f"coalesce_max_bytes={final_qsdp.coalesce_max_bytes}), "
          f"prefetch={final_qsdp.prefetch} -> {args.out}")
    if args.assert_choice != "any" and choice != args.assert_choice:
        raise SystemExit(
            f"--assert-choice {args.assert_choice} failed: planner chose "
            f"{choice} gathers for '{main_group}' on mesh "
            f"({args.data_par},{args.model_par})")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
