"""Per-layer analytic cost models for the deployment-plan autotuner.

fpgaHART idiom: each schedulable unit (here: a layer group's gather and its
gradient reduce-scatter) gets a closed-form cost composed from a small set
of hardware constants, the search scores whole candidates by summing the
per-layer terms, and only the shortlist is measured.

The model that explains (and fixes) the coalesced small-scale regression:

    t_per_tensor = L_pt * t_launch + wire / link_bw
    t_coalesced  = L_co * t_launch + wire / link_bw + buf / ser_bw

Coalescing leaves the wire bytes untouched (same codes, same metadata) and
collapses L_pt = 3*n_quant + n_fp launches into L_co = 1, but it adds
serialization passes over the ONE gathered buffer of ``buf = P * nbytes``
bytes — segment concat, f32<->u8 bitcasts of the fp payloads, the vmap'd
per-shard decode.  Equating the two sides gives the crossover

    buf* = (L_pt - L_co) * t_launch * ser_bw

below which coalescing wins.  On a TPU-class part t_launch ~ microseconds
and the serialization passes run at HBM bandwidth, so buf* is tens of MB
and whole-layer coalescing is right; on the tiny emulated CPU mesh the
per-byte cost of those extra passes is enormous (interpreted op overhead on
small buffers) while launches are nearly free, so buf* is sub-KB and
per-tensor gathers win — which is exactly what BENCH_step measured
(qsdp-coalesced 370 ms vs plain qsdp 204 ms median).  The autotuner turns
this model into ``QSDPConfig.coalesce_max_bytes``.

``ser_bw`` is an *effective* rate: on CPU it absorbs the interpreter's
per-op overhead (which scales with the number of buckets/segments, i.e.
with bytes), on TPU it is the fused pack/unpack passes' HBM bandwidth.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

from ..core import collectives as coll
from ..core.qsdp import QSDPEngine
from ..core.quant import fp_segment_bytes, wire_segment_bytes
from ..roofline.analysis import HW_V5E, Hardware

# ---------------------------------------------------------------------------
# Hardware presets
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class CostParams:
    """The constants the per-layer models compose over."""

    hw: Hardware          # roofline part (peak flops / hbm / link bw)
    t_launch_s: float     # fixed dispatch+sync overhead per collective launch
    ser_bw: float         # effective B/s of the coalesce serialize/decode passes

    @property
    def name(self) -> str:
        return self.hw.name


# cpu-smoke: calibrated against BENCH_step's emulated 8-device CPU mesh —
# the coalesced variants pay ~166 ms/step over per-tensor for ~0.7 MB of
# coalesced buffer traffic (ser_bw ~ 4 MB/s effective: interpreted per-op
# overhead, not memcpy), while 32 extra launches cost well under a ms.
HW_CPU_SMOKE = Hardware(name="cpu-smoke", peak_flops=5e10, hbm_bw=2e10,
                        ici_bw=2e9)
CPU_SMOKE = CostParams(hw=HW_CPU_SMOKE, t_launch_s=5e-6, ser_bw=4e6)

# tpu-v5e: launches are ~2 us of dispatch, serialization is two fused
# HBM passes (read + write) over the buffer.
TPU_V5E = CostParams(hw=HW_V5E, t_launch_s=2e-6, ser_bw=HW_V5E.hbm_bw / 2)

HW_PRESETS: dict[str, CostParams] = {
    "cpu-smoke": CPU_SMOKE,
    "tpu-v5e": TPU_V5E,
}


# ---------------------------------------------------------------------------
# Per-layer gather / reduce-scatter costs
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class GatherCost:
    """One collective event (a layer gather or its grad reduce-scatter)."""

    launches: int     # collective launches
    wire_bytes: int   # per-device bytes on the wire (policy-invariant)
    ser_bytes: int    # coalesced-buffer bytes serialized (0 when per-tensor)

    def time_s(self, cp: CostParams) -> float:
        return (self.launches * cp.t_launch_s
                + self.wire_bytes / cp.hw.ici_bw
                + self.ser_bytes / cp.ser_bw)


def _levels(engine: QSDPEngine) -> int:
    return 2 if engine.cfg.hierarchical and engine.ms.multi_pod else 1


def _per_tensor_launches(engine: QSDPEngine, names: list[str]) -> int:
    lv = _levels(engine)
    return sum(3 * lv if engine._is_quantized(engine.specs[n]) else 1
               for n in names)


def layer_gather_cost(engine: QSDPEngine, names: list[str],
                      coalesced: bool) -> GatherCost:
    """Cost of ONE all-gather of `names` under a forced coalesce policy."""
    ms, cfg = engine.ms, engine.cfg
    p = ms.fsdp_size
    wfp = 4 if cfg.weight_wire_dtype == "float32" else 2
    wire = sum(coll.gather_wire_bytes(
        engine.specs[n].n_local(ms), p,
        cfg.wcfg() if engine._is_quantized(engine.specs[n]) else None, wfp)
        for n in names)
    if coalesced:
        return GatherCost(launches=_levels(engine), wire_bytes=wire,
                          ser_bytes=engine.layer_wire_bytes(tuple(names)))
    return GatherCost(launches=_per_tensor_launches(engine, names),
                      wire_bytes=wire, ser_bytes=0)


def layer_rs_cost(engine: QSDPEngine, names: list[str],
                  coalesced: bool) -> GatherCost:
    """Cost of ONE gradient reduce-scatter of `names` (same structure: the
    coalesced form ships one chunked u8 buffer of ~P * per-chunk bytes)."""
    ms, cfg = engine.ms, engine.cfg
    p = ms.fsdp_size
    gfp = 4 if cfg.grad_wire_dtype == "float32" else 2
    wire = buf = 0
    for n in names:
        spec = engine.specs[n]
        n_local = spec.n_local(ms)
        gq = cfg.gcfg() if engine._is_grad_quantized(spec) else None
        wire += coll.reduce_scatter_wire_bytes(n_local * p, p, gq, gfp)
        # coalesced RS buffer: P chunk-rows, each one shard's worth
        buf += p * (wire_segment_bytes(n_local, gq) if gq is not None
                    else fp_segment_bytes(n_local, cfg.grad_wire_dtype))
    if coalesced:
        return GatherCost(launches=_levels(engine), wire_bytes=wire,
                          ser_bytes=buf)
    return GatherCost(launches=_per_tensor_launches(engine, names),
                      wire_bytes=wire, ser_bytes=0)


def crossover_bytes(engine: QSDPEngine, names: list[str],
                    cp: CostParams) -> int:
    """Gathered-buffer size at which coalescing `names` stops paying:
    buf* = (L_pt - L_co) * t_launch * ser_bw."""
    saved = _per_tensor_launches(engine, names) - _levels(engine)
    return max(int(saved * cp.t_launch_s * cp.ser_bw), 0)


# ---------------------------------------------------------------------------
# HLO-visible launch prediction (conformance against roofline.hlo_analyzer)
# ---------------------------------------------------------------------------


def predict_hlo_gather_counts(engine: QSDPEngine, names: list[str],
                              coalesced: Optional[bool] = None) -> int:
    """All-gather launch count the *compiled HLO* shows for ONE gather of
    `names` (what ``analyze_hlo(...)["collectives"]["counts"]`` reports).

    Differs from the analytic :func:`repro.core.qsdp.layer_gather_launches`
    in exactly one way: the analyzer only counts collectives whose replica
    group is larger than 1, so levels of size 1 — e.g. the whole FSDP axis
    on a (1,1) mesh — are invisible (XLA compiles them away).  `coalesced`
    forces the policy; None uses ``engine.layer_coalesced``.
    """
    ms = engine.ms
    if coalesced is None:
        coalesced = engine.layer_coalesced(tuple(names))
    sizes = dict(zip(ms.axes, ms.shape))
    hier = engine.cfg.hierarchical and ms.multi_pod
    if hier:
        levels = [sizes.get("pod", 1), sizes["data"]]
    else:
        levels = [ms.fsdp_size]
    visible = [sz for sz in levels if sz > 1]
    if coalesced:
        return len(visible)
    total = 0
    for n in names:
        if engine._is_quantized(engine.specs[n]):
            # 3 per visible level hierarchically, else 3 over the joint axis
            total += 3 * (len(visible) if hier else (1 if ms.fsdp_size > 1 else 0))
        else:
            # fp payloads ride ONE all-gather over the joint FSDP axes
            total += 1 if ms.fsdp_size > 1 else 0
    return total


# ---------------------------------------------------------------------------
# Step-level composition
# ---------------------------------------------------------------------------


def layer_groups(engine: QSDPEngine) -> list[tuple[str, list[str], int]]:
    """(group name, param names, gathers per stack traversal) — stacked
    specs grouped by their prefix (the scan gathers each slice once per
    traversal), non-stacked params as singleton groups (what
    ``Model.loss_fn`` gathers via ``engine.gather``)."""
    grouped: dict[str, list[str]] = {}
    singles: list[tuple[str, list[str], int]] = []
    stacks: dict[str, int] = {}
    for name, spec in sorted(engine.specs.items()):
        if spec.stack is not None and "/" in name:
            g = name.split("/", 1)[0]
            grouped.setdefault(g, []).append(name)
            stacks[g] = spec.stack
        else:
            singles.append((name, [name], 1))
    out = [(g, ns, stacks[g]) for g, ns in sorted(grouped.items())]
    return out + singles


def predict_step_time(engine: QSDPEngine, cp: CostParams, *,
                      n_micro: int = 1,
                      coalesced_groups: Optional[dict[str, bool]] = None,
                      t_compute_s: float = 0.0) -> float:
    """Predicted seconds per train step: compute floor (optional, from a
    roofline report) + the comm terms of the FSDP schedule — per microbatch
    each layer is gathered twice (forward + remat backward) and
    reduce-scattered once."""
    total = t_compute_s
    for group, names, stack in layer_groups(engine):
        if coalesced_groups is not None:
            co = coalesced_groups[group]
        else:
            co = engine.layer_coalesced(tuple(names))
        g = layer_gather_cost(engine, names, co)
        r = layer_rs_cost(engine, names, co)
        total += n_micro * stack * (2 * g.time_s(cp) + r.time_s(cp))
    return total


def plan_layer_policies(engine: QSDPEngine, cp: CostParams):
    """Per-group coalesce decisions + the single ``coalesce_max_bytes``
    threshold that realizes them.

    The engine expresses the policy as ONE byte threshold on the gathered
    buffer (coalesce iff buffer <= threshold), so the search is over the
    expressible cuts: 0 plus each group's buffer size.  For every cut, sum
    each group's predicted gather+RS time under the decision that cut
    induces, and keep the cheapest (weighting by the stack depth — a scan
    group pays its cost once per layer).  This matters because the
    unconstrained per-group optimum need not be byte-monotone: a singleton
    group (launch savings = 0, e.g. ``final_norm``) never profits from
    coalescing, while the big stacked groups do — the scan then correctly
    sacrifices the singleton's nanoseconds instead of the layers' win.

    Returns (policies, coalesce_max_bytes); coalesce_max_bytes is None when
    the best cut coalesces every group (no threshold needed).
    """
    from .plan import LayerPolicy

    infos = []
    for group, names, stack in layer_groups(engine):
        tco = (layer_gather_cost(engine, names, True).time_s(cp)
               + layer_rs_cost(engine, names, True).time_s(cp))
        tpt = (layer_gather_cost(engine, names, False).time_s(cp)
               + layer_rs_cost(engine, names, False).time_s(cp))
        infos.append((group, names, stack,
                      engine.layer_wire_bytes(tuple(names)), tco, tpt))
    cuts = sorted({0} | {buf for _, _, _, buf, _, _ in infos})
    best_cut, best_total = 0, None
    for t in cuts:
        total = sum(stack * (tco if buf <= t else tpt)
                    for _, _, stack, buf, tco, tpt in infos)
        if best_total is None or total < best_total:
            best_cut, best_total = t, total
    policies = [LayerPolicy(
        group=group,
        coalesce=buf <= best_cut,
        wire_buffer_bytes=buf,
        launches_per_tensor=_per_tensor_launches(engine, names),
        launches_coalesced=_levels(engine),
    ) for group, names, _stack, buf, _tco, _tpt in infos]
    if all(p.coalesce for p in policies):
        return policies, None
    return policies, best_cut
