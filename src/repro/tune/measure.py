"""Shortlist measurement: run the real jitted train step for a handful of
timed steps per candidate (the same harness benchmarks/bench_step.py uses),
so the plan's final ranking rests on measured medians, not only on the
analytic model."""
from __future__ import annotations

import time

import jax
import numpy as np

from ..core.qsdp import MeshSpec, QSDPConfig, layer_gather_launches
from ..models.transformer import Model
from ..optim import AdamWConfig, make_adamw
from ..train.step import init_train_state, make_jitted_train_step


def measure_train_step(mcfg, ms: MeshSpec, qcfg: QSDPConfig, batch: dict,
                       *, n_micro: int = 1, steps: int = 3,
                       seed: int = 0) -> dict:
    """Median per-step wall ms of `qcfg` on the given mesh/model/batch
    (compile + 1 warmup excluded), plus the analytic launch count so the
    plan records what the measurement exercised."""
    mesh = jax.make_mesh(ms.shape, ms.axes)
    model = Model(mcfg, ms, qcfg)
    opt = make_adamw(AdamWConfig(lr=1e-3))
    state = init_train_state(model, opt, jax.random.PRNGKey(seed))
    step = make_jitted_train_step(model, opt, mesh, n_micro=n_micro)
    key = jax.random.PRNGKey(seed + 7)
    times = []
    with mesh:
        t0 = time.perf_counter()
        state, metrics = step(state, batch, key)  # compile
        float(metrics["loss"])
        compile_s = time.perf_counter() - t0
        # one more untimed step so the timed loop sees the steady state
        # (device-resident donated buffers, no sharding-driven recompile)
        state, metrics = step(state, batch, jax.random.fold_in(key, -1))
        float(metrics["loss"])
        for i in range(steps):
            t0 = time.perf_counter()
            state, metrics = step(state, batch, jax.random.fold_in(key, i))
            float(metrics["loss"])
            times.append(1e3 * (time.perf_counter() - t0))
    layer_names = [n for n in model.specs if n.startswith("layers/")]
    return {
        "step_ms_median": float(np.median(times)),
        "step_ms_all": [float(t) for t in times],
        "compile_s": float(compile_s),
        "loss_final": float(metrics["loss"]),
        "layer_gather_launches": layer_gather_launches(model.engine,
                                                       layer_names),
    }
