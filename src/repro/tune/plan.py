"""Versioned deployment plan: the autotuner's output, the launchers' input.

A :class:`DeploymentPlan` pins one point of the quant-and-schedule design
space — the QSDPConfig comm policy (bits, bucket, rounding, meta dtype,
coalesce/prefetch + the per-layer ``coalesce_max_bytes`` threshold) and the
serve-side scheduler knobs — together with the mesh it was tuned for, the
per-layer-group policy decisions that justify it, and the cost-model /
measurement evidence.  ``launch/train.py --plan`` and ``launch/serve.py
--plan`` consume it instead of a dozen individual flags.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Optional

from ..core.qsdp import QSDPConfig

PLAN_VERSION = 1

# QSDPConfig fields a plan may override (everything that shapes the wire /
# schedule; deliberately NOT compute_dtype / remat_policy, which belong to
# the launcher).
_QSDP_FIELDS = (
    "quantize_weights", "quantize_grads", "weight_bits", "grad_bits",
    "bucket_size", "weight_mode", "grad_mode", "min_quant_size",
    "meta_wire_dtype", "hierarchical", "coalesce", "prefetch",
    "coalesce_max_bytes",
)

_SERVE_FIELDS = (
    "slots", "prefill_chunk", "prefill_buckets", "prefill_interleave",
    "kv_block_size", "kv_pool_blocks", "kv_quant_bits", "kv_quant_horizon",
    "draft_bits", "draft_depth",
)


def _round_floats(obj, ndigits: int = 4):
    """Round every float in a JSON-able tree (stable artifact diffs)."""
    if isinstance(obj, float):
        return round(obj, ndigits)
    if isinstance(obj, dict):
        return {k: _round_floats(v, ndigits) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        return [_round_floats(v, ndigits) for v in obj]
    return obj


@dataclasses.dataclass(frozen=True)
class LayerPolicy:
    """Per-layer-group decision record (diagnostic + what the threshold in
    the qsdp section encodes)."""

    group: str               # layer-group prefix ("layers") or single param
    coalesce: bool           # does the plan's policy coalesce this group?
    wire_buffer_bytes: int   # per-device gathered wire buffer (P * nbytes)
    launches_per_tensor: int  # one gather of the group, per-tensor
    launches_coalesced: int   # one gather of the group, coalesced

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


@dataclasses.dataclass(frozen=True)
class DeploymentPlan:
    version: int
    arch: str
    mesh_axes: tuple[str, ...]
    mesh_shape: tuple[int, ...]
    hw: str                       # cost-model hardware preset name
    qsdp: dict                    # QSDPConfig overrides (subset of _QSDP_FIELDS)
    serve: dict                   # serve knobs (subset of _SERVE_FIELDS)
    layers: tuple[LayerPolicy, ...] = ()
    predicted: dict = dataclasses.field(default_factory=dict)
    measured: dict = dataclasses.field(default_factory=dict)

    # -- QSDPConfig round-trip -------------------------------------------------

    def to_qsdp_config(self, base: Optional[QSDPConfig] = None) -> QSDPConfig:
        base = base if base is not None else QSDPConfig()
        bad = set(self.qsdp) - set(_QSDP_FIELDS)
        if bad:
            raise ValueError(f"plan qsdp section has unknown fields: {sorted(bad)}")
        return dataclasses.replace(base, **self.qsdp)

    def serve_knobs(self) -> dict:
        bad = set(self.serve) - set(_SERVE_FIELDS)
        if bad:
            raise ValueError(f"plan serve section has unknown fields: {sorted(bad)}")
        return dict(self.serve)

    def validate_mesh(self, axes: tuple[str, ...], shape: tuple[int, ...]) -> None:
        """A plan is tuned FOR a mesh; refuse to drive a different one (the
        cost crossover and the per-layer byte threshold both scale with the
        FSDP size)."""
        if tuple(axes) != self.mesh_axes or tuple(shape) != self.mesh_shape:
            raise ValueError(
                f"plan was tuned for mesh {self.mesh_axes}={self.mesh_shape}, "
                f"launcher requested {tuple(axes)}={tuple(shape)} — re-run "
                f"repro.tune.autotune for this mesh")

    # -- (de)serialization ----------------------------------------------------

    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["mesh_axes"] = list(self.mesh_axes)
        d["mesh_shape"] = list(self.mesh_shape)
        d["layers"] = [lp.to_dict() for lp in self.layers]
        return d

    def save(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(_round_floats(self.to_dict()), f, indent=1, sort_keys=True)
            f.write("\n")

    @classmethod
    def from_dict(cls, d: dict) -> "DeploymentPlan":
        if d.get("version") != PLAN_VERSION:
            raise ValueError(
                f"deployment plan version {d.get('version')!r} != supported "
                f"{PLAN_VERSION} — regenerate with repro.tune.autotune")
        layers = tuple(LayerPolicy(**lp) for lp in d.get("layers", ()))
        return cls(
            version=PLAN_VERSION,
            arch=d["arch"],
            mesh_axes=tuple(d["mesh_axes"]),
            mesh_shape=tuple(int(x) for x in d["mesh_shape"]),
            hw=d.get("hw", ""),
            qsdp=dict(d.get("qsdp", {})),
            serve=dict(d.get("serve", {})),
            layers=layers,
            predicted=dict(d.get("predicted", {})),
            measured=dict(d.get("measured", {})),
        )

    @classmethod
    def load(cls, path: str) -> "DeploymentPlan":
        with open(path) as f:
            return cls.from_dict(json.load(f))
