"""Search drivers over the design space (fpgaHART idiom: brute force for
small composed spaces, seeded simulated annealing when the space explodes).

Both are deterministic: exhaustive by construction, annealing via an
explicit ``np.random.default_rng(seed)`` with fixed iteration count —
CI reruns pick the same plan.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, Sequence

import numpy as np

from .space import Candidate


def exhaustive_search(candidates: Sequence[Candidate],
                      cost_fn: Callable[[Candidate], float]):
    """Score every candidate; return [(cost, candidate)] best-first with a
    stable tiebreak (candidate label) so equal-cost reruns agree."""
    scored = [(float(cost_fn(c)), c) for c in candidates]
    scored.sort(key=lambda t: (t[0], t[1].label()))
    return scored


def simulated_annealing(candidates: Sequence[Candidate],
                        cost_fn: Callable[[Candidate], float],
                        *, seed: int = 0, iters: int = 200,
                        t0: float = 1.0, t1: float = 1e-3):
    """Anneal over the candidate list by single-axis mutation: propose a
    candidate agreeing with the current one on all but one knob.  Costs are
    memoized, so for spaces near-exhaustively covered this converges to the
    brute-force answer at a fraction of the evaluations.  Returns the same
    best-first [(cost, candidate)] shape as exhaustive_search (evaluated
    subset only)."""
    rng = np.random.default_rng(seed)
    pool = list(candidates)
    if not pool:
        return []
    cache: dict[Candidate, float] = {}

    def cost(c: Candidate) -> float:
        if c not in cache:
            cache[c] = float(cost_fn(c))
        return cache[c]

    fields = [f.name for f in dataclasses.fields(Candidate)]
    cur = pool[int(rng.integers(len(pool)))]
    cur_cost = cost(cur)
    for i in range(iters):
        t = t0 * (t1 / t0) ** (i / max(iters - 1, 1))
        ax = fields[int(rng.integers(len(fields)))]
        neighbors = [c for c in pool
                     if getattr(c, ax) != getattr(cur, ax)
                     and all(getattr(c, f) == getattr(cur, f)
                             for f in fields if f != ax)]
        if not neighbors:
            continue
        nxt = neighbors[int(rng.integers(len(neighbors)))]
        nxt_cost = cost(nxt)
        if (nxt_cost <= cur_cost
                or rng.random() < math.exp((cur_cost - nxt_cost) / max(t, 1e-12))):
            cur, cur_cost = nxt, nxt_cost
    return sorted(((cost, cand) for cand, cost in cache.items()),
                  key=lambda t: (t[0], t[1].label()))
