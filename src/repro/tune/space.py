"""The composed quant-and-schedule design space the autotuner searches.

A :class:`Candidate` is one point: the QSDP comm policy knobs plus the
serve-side scheduler knobs.  ``enumerate_space`` yields only *valid*
combinations (prefetch requires coalesce, draft bits pair with draft depth,
...) — the same constraints the launchers now validate at parse time.

Two tiers:
  * quality-neutral (default): coalesce / prefetch / the per-layer byte
    threshold — these permute launches, not values; gradients stay
    bit-exact, so the tuner may flip them freely.
  * quality-affecting (--full-space): bits / bucket / rounding / meta
    dtype — these change the quantization error, so they only enter the
    search when explicitly asked for (and the plan records them for the
    convergence harness to sign off).
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Iterator, Optional

from ..core.qsdp import QSDPConfig


@dataclasses.dataclass(frozen=True)
class Candidate:
    # comm policy (train + serve weight gathers)
    coalesce: bool = True
    prefetch: bool = False
    coalesce_max_bytes: Optional[int] = None
    weight_bits: int = 8
    grad_bits: int = 8
    bucket_size: int = 1024
    weight_mode: str = "shift"
    grad_mode: str = "stochastic"
    meta_wire_dtype: str = "float32"
    # serve schedule
    slots: int = 8
    prefill_chunk: int = 0
    prefill_buckets: int = 4
    draft_bits: int = 0
    draft_depth: int = 0

    def label(self) -> str:
        co = ("coalesced" if self.coalesce_max_bytes is None else
              f"coalesce<={self.coalesce_max_bytes}B") if self.coalesce else "per-tensor"
        tag = f"W{self.weight_bits}G{self.grad_bits} b{self.bucket_size} {co}"
        if self.prefetch:
            tag += "+prefetch"
        if self.meta_wire_dtype != "float32":
            tag += f" meta={self.meta_wire_dtype}"
        return tag

    def valid(self) -> bool:
        return (
            not (self.prefetch and not self.coalesce)
            and 2 <= self.weight_bits <= 8
            and 2 <= self.grad_bits <= 8
            and self.bucket_size > 0
            and (self.draft_bits > 0) == (self.draft_depth > 1)
            and (self.draft_bits == 0 or 2 <= self.draft_bits <= 8)
        )

    def to_qsdp(self, base: QSDPConfig) -> QSDPConfig:
        return dataclasses.replace(
            base, coalesce=self.coalesce, prefetch=self.prefetch,
            coalesce_max_bytes=self.coalesce_max_bytes,
            weight_bits=self.weight_bits, grad_bits=self.grad_bits,
            bucket_size=self.bucket_size, weight_mode=self.weight_mode,
            grad_mode=self.grad_mode, meta_wire_dtype=self.meta_wire_dtype)

    def axes_dict(self) -> dict:
        return dataclasses.asdict(self)


def enumerate_space(*, thresholds: tuple[Optional[int], ...] = (None,),
                    full_space: bool = False,
                    serve_slots: tuple[int, ...] = (8,),
                    base: Optional[Candidate] = None) -> Iterator[Candidate]:
    """Yield every valid candidate.  `thresholds` injects cost-model-derived
    ``coalesce_max_bytes`` cut points (the crossover) next to None."""
    base = base or Candidate()
    schedule = []
    for co, pf in ((False, False), (True, False), (True, True)):
        ths = thresholds if co else (None,)
        for th in ths:
            schedule.append((co, pf, th))
    if full_space:
        quant = itertools.product((4, 6, 8), (4, 8), (256, 1024),
                                  ("float32", "bfloat16"))
    else:
        quant = [(base.weight_bits, base.grad_bits, base.bucket_size,
                  base.meta_wire_dtype)]
    for (co, pf, th), (wb, gb, bsz, meta), slots in itertools.product(
            schedule, quant, serve_slots):
        cand = dataclasses.replace(
            base, coalesce=co, prefetch=pf, coalesce_max_bytes=th,
            weight_bits=wb, grad_bits=gb, bucket_size=bsz,
            meta_wire_dtype=meta, slots=slots)
        if cand.valid():
            yield cand
