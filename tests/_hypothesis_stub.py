"""Deterministic fallback for the `hypothesis` API surface this suite uses.

The real dependency is declared in pyproject.toml (`pip install -e .[test]`);
this stub exists so the property tests still *run* — as seeded, fixed-count
example sweeps — in minimal environments where hypothesis is not installed
(e.g. hermetic CI images).  conftest.py installs it into sys.modules only
when `import hypothesis` fails, so a real installation always wins.

Supported subset: `@given(**kwargs)` with keyword strategies,
`@settings(max_examples=..., deadline=...)`, `strategies.integers(lo, hi)`,
`strategies.sampled_from(seq)`.  Examples are drawn from a PRNG seeded per
test name, so runs are reproducible (no shrinking, no failure database).
"""
from __future__ import annotations

import functools
import inspect
import random
import sys
import types

_DEFAULT_MAX_EXAMPLES = 100


class _Strategy:
    def __init__(self, draw):
        self._draw = draw

    def example(self, rng: random.Random):
        return self._draw(rng)


def integers(min_value: int, max_value: int) -> _Strategy:
    return _Strategy(lambda rng: rng.randint(min_value, max_value))


def sampled_from(elements) -> _Strategy:
    elements = list(elements)
    return _Strategy(lambda rng: rng.choice(elements))


def settings(max_examples: int = _DEFAULT_MAX_EXAMPLES, deadline=None, **_ignored):
    def deco(fn):
        fn._stub_max_examples = max_examples
        return fn

    return deco


def given(**strategies_by_name):
    def deco(fn):
        @functools.wraps(fn)
        def runner(*args, **kwargs):
            # read at call time, from the runner first: @settings above
            # @given decorates the runner, below @given decorates fn —
            # real hypothesis accepts both orders
            n = getattr(runner, "_stub_max_examples",
                        getattr(fn, "_stub_max_examples", _DEFAULT_MAX_EXAMPLES))
            rng = random.Random(fn.__qualname__)
            for _ in range(n):
                drawn = {k: s.example(rng) for k, s in strategies_by_name.items()}
                fn(*args, **drawn, **kwargs)

        # hide the strategy-driven params from pytest's fixture resolution
        sig = inspect.signature(fn)
        runner.__signature__ = sig.replace(parameters=[
            p for name, p in sig.parameters.items()
            if name not in strategies_by_name
        ])
        return runner

    return deco


def install() -> types.ModuleType:
    """Register this stub as `hypothesis` / `hypothesis.strategies`."""
    mod = types.ModuleType("hypothesis")
    mod.given = given
    mod.settings = settings
    st = types.ModuleType("hypothesis.strategies")
    st.integers = integers
    st.sampled_from = sampled_from
    mod.strategies = st
    mod.__stub__ = True
    sys.modules["hypothesis"] = mod
    sys.modules["hypothesis.strategies"] = st
    return mod
