"""Shared fixtures.  NOTE: no XLA_FLAGS here on purpose — unit/smoke tests
run on the real single CPU device; distributed behaviour is covered by
subprocess tests (test_distributed.py) that set their own device count,
and by the dry-run (launch/dryrun.py) which forces 512 in-process.
"""
import jax
import pytest

try:
    import hypothesis  # noqa: F401  (preferred: pip install -e .[test])
except ImportError:  # hermetic environment — run properties as seeded sweeps
    from _hypothesis_stub import install as _install_hypothesis_stub

    _install_hypothesis_stub()


@pytest.fixture(scope="session")
def mesh11():
    """Trivial (data=1, model=1) mesh — exercises the full shard_map code
    path (collectives degenerate to identity) on one device."""
    return jax.make_mesh((1, 1), ("data", "model"))


@pytest.fixture(scope="session")
def key():
    return jax.random.PRNGKey(0)
