"""Schema contract for the BENCH_step.json / BENCH_serve.json artifacts.

The bench writers validate their output against benchmarks/bench_schema.py
before writing; these tests pin the validator itself (dropped columns,
wrong types, and version mismatches must fail loudly) and check the
artifacts checked in at the repo root still conform.
"""
import json
import sys
from pathlib import Path

import pytest

ROOT = Path(__file__).resolve().parents[1]
sys.path.insert(0, str(ROOT / "benchmarks"))

import bench_schema  # noqa: E402
from bench_schema import (  # noqa: E402
    BENCH_SCHEMA_VERSION, BenchSchemaError, validate_bench_serve,
    validate_bench_step)

_FILL = {"num": 1.5, "int": 1, "bool": True, "str": "x", "dict": {},
         "list": [], "numlist": [1.0, 2.0]}


def _row(spec):
    return {k: _FILL[t] for k, t in spec.items()}


def _step_doc():
    return {
        "schema_version": BENCH_SCHEMA_VERSION,
        "config": _row(bench_schema.STEP_CONFIG),
        "variants": {"qsdp": _row(bench_schema.STEP_VARIANT),
                     "qsdp-coalesced": _row(bench_schema.STEP_VARIANT)},
        "summary": _row(bench_schema.STEP_SUMMARY),
    }


def _serve_doc():
    return {
        "schema_version": BENCH_SCHEMA_VERSION,
        "config": _row(bench_schema.SERVE_CONFIG),
        "variants": {"qsdp": _row(bench_schema.SERVE_VARIANT)},
        "summary": _row(bench_schema.SERVE_SUMMARY),
    }


def test_minimal_docs_validate():
    validate_bench_step(_step_doc())
    validate_bench_serve(_serve_doc())


def test_extra_columns_allowed():
    doc = _step_doc()
    doc["variants"]["qsdp"]["novel_metric"] = 42
    doc["summary"]["extra_ratio"] = 0.5
    validate_bench_step(doc)


def test_dropped_variant_column_fails():
    doc = _step_doc()
    del doc["variants"]["qsdp"]["step_ms_median"]
    with pytest.raises(BenchSchemaError, match="step_ms_median"):
        validate_bench_step(doc)


def test_dropped_summary_column_fails():
    doc = _serve_doc()
    del doc["summary"]["gather_bytes_ratio_qsdp_vs_baseline"]
    with pytest.raises(BenchSchemaError,
                       match="gather_bytes_ratio_qsdp_vs_baseline"):
        validate_bench_serve(doc)


def test_wrong_type_fails():
    doc = _step_doc()
    doc["config"]["smoke"] = "yes"  # str where bool required
    with pytest.raises(BenchSchemaError, match="smoke"):
        validate_bench_step(doc)
    doc = _step_doc()
    doc["variants"]["qsdp"]["compile_s"] = True  # bool is not a num
    with pytest.raises(BenchSchemaError, match="compile_s"):
        validate_bench_step(doc)


def test_version_mismatch_fails():
    doc = _step_doc()
    doc["schema_version"] = BENCH_SCHEMA_VERSION + 98
    with pytest.raises(BenchSchemaError, match="schema_version"):
        validate_bench_step(doc)


def test_legacy_doc_without_version_validates():
    doc = _step_doc()
    del doc["schema_version"]
    validate_bench_step(doc)


def test_empty_variants_fails():
    doc = _serve_doc()
    doc["variants"] = {}
    with pytest.raises(BenchSchemaError, match="variants"):
        validate_bench_serve(doc)


def test_stamp_sets_current_version():
    doc = _step_doc()
    del doc["schema_version"]
    assert bench_schema.stamp(doc)["schema_version"] == BENCH_SCHEMA_VERSION


@pytest.mark.parametrize("fname,validate", [
    ("BENCH_step.json", validate_bench_step),
    ("BENCH_serve.json", validate_bench_serve),
])
def test_checked_in_artifacts_conform(fname, validate):
    path = ROOT / fname
    if not path.exists():
        pytest.skip(f"{fname} not present at repo root")
    validate(json.loads(path.read_text()))
