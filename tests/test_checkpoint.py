"""Checkpoint format v1/v2 round-trips on the trivial mesh: quantized-state
payloads, manifest validation, resume bit-exactness, and the quantized
payload-size bound.  Cross-mesh resharding ((1,1) <-> (2,4)) runs under 8
emulated devices in scripts/check_quantized_state.py (test_distributed.py)."""
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.core.qsdp import MeshSpec, QSDPConfig
from repro.core.quant import QuantizedParam
from repro.models.transformer import Model
from repro.optim import AdamWConfig, make_adamw
from repro.train import load_checkpoint, save_checkpoint
from repro.train.checkpoint import checkpoint_payload_bytes
from repro.train.step import (
    dequantize_train_state,
    init_train_state,
    make_jitted_train_step,
    master_eligible,
    quantize_train_state,
    state_pspecs,
)

from test_quantized_state import run_steps, tiny_batch, tiny_model


def test_checkpoint_roundtrip(tmp_path, mesh11):
    cfg = configs.get_smoke("gpt_125m")
    ms = MeshSpec(axes=("data", "model"), shape=(1, 1))
    model = Model(cfg, ms, QSDPConfig(min_quant_size=256))
    opt = make_adamw(AdamWConfig())
    state = init_train_state(model, opt, jax.random.PRNGKey(0))
    path = str(tmp_path / "ckpt")
    save_checkpoint(path, state, meta={"arch": cfg.name})
    loaded = load_checkpoint(path, mesh11, state_pspecs(model))
    for k in state.params:
        np.testing.assert_array_equal(np.asarray(state.params[k]),
                                      np.asarray(loaded.params[k]))
    for k in state.opt.mu:
        np.testing.assert_array_equal(np.asarray(state.opt.mu[k]),
                                      np.asarray(loaded.opt.mu[k]))
    assert int(loaded.opt.step) == int(state.opt.step)

    with open(os.path.join(path, "manifest.json")) as f:
        man = json.load(f)
    assert man["meta"]["arch"] == cfg.name
    assert man["format"] == "qsdp-ckpt-v2"
    assert man["mesh"] == {"model_size": 1, "fsdp_size": 1}


def test_checkpoint_v1_still_loads(tmp_path, mesh11):
    model = tiny_model()
    opt = make_adamw(AdamWConfig())
    state = init_train_state(model, opt, jax.random.PRNGKey(0))
    path = str(tmp_path / "ckpt_v1")
    save_checkpoint(path, state, format_version=1)
    with open(os.path.join(path, "manifest.json")) as f:
        assert json.load(f)["format"] == "qsdp-ckpt-v1"
    loaded = load_checkpoint(path, mesh11, state_pspecs(model))
    for k in state.params:
        np.testing.assert_array_equal(np.asarray(state.params[k]),
                                      np.asarray(loaded.params[k]))


def test_v1_refuses_quantized_state(tmp_path):
    model = tiny_model()
    opt = make_adamw(AdamWConfig())
    state = quantize_train_state(
        init_train_state(model, opt, jax.random.PRNGKey(0)),
        model, jax.random.PRNGKey(1))
    with pytest.raises(ValueError, match="v1"):
        save_checkpoint(str(tmp_path / "x"), state, format_version=1)


def test_quantized_checkpoint_roundtrip_and_bytes(tmp_path, mesh11):
    """v2 stores quantized leaves as their exact wire bytes; loading them
    back is byte-identical, and the payload obeys the bits/32 bound of the
    acceptance criterion."""
    model = tiny_model()
    opt = make_adamw(AdamWConfig(moment_bits=8))
    state = quantize_train_state(
        init_train_state(model, opt, jax.random.PRNGKey(0)),
        model, jax.random.PRNGKey(1))
    path = str(tmp_path / "qckpt")
    save_checkpoint(path, state)
    sp = state_pspecs(model, quantized_state=True, quantized_moments=True)
    loaded = load_checkpoint(path, mesh11, sp)

    f32_path = str(tmp_path / "fckpt")
    save_checkpoint(f32_path, dequantize_train_state(state))
    qbytes = checkpoint_payload_bytes(path)
    fbytes = checkpoint_payload_bytes(f32_path)

    for name, leaf in state.params.items():
        l2 = loaded.params[name]
        if isinstance(leaf, QuantizedParam):
            assert isinstance(l2, QuantizedParam)
            np.testing.assert_array_equal(np.asarray(leaf.wire), np.asarray(l2.wire))
            assert l2.cell_shape == leaf.cell_shape and l2.cfg == leaf.cfg
            # payload bound: bits/32 of the f32 payload + bucket metadata
            cfg = leaf.cfg
            n = leaf.n
            nb = -(-n // cfg.bucket_size)
            key = f"params/{name}"
            bound = (fbytes[key] * cfg.bits / 32
                     + 2 * cfg.meta_bytes * nb          # per-bucket (scale, zero)
                     + cfg.bucket_size * cfg.bits / 8)  # tail-bucket padding
            assert qbytes[key] <= bound, (name, qbytes[key], bound)
        else:
            np.testing.assert_array_equal(np.asarray(leaf), np.asarray(l2))
    assert any(isinstance(v, QuantizedParam) for v in loaded.params.values())
    assert all(isinstance(v, QuantizedParam) for v in loaded.opt.mu.values())
    # whole-checkpoint win
    assert sum(qbytes.values()) < 0.45 * sum(fbytes.values())


def test_quantized_checkpoint_dequantize_load(tmp_path, mesh11):
    """dequantize=True loads a quantized v2 checkpoint as exact f32 values."""
    model = tiny_model()
    opt = make_adamw(AdamWConfig())
    state = quantize_train_state(
        init_train_state(model, opt, jax.random.PRNGKey(0)),
        model, jax.random.PRNGKey(1))
    path = str(tmp_path / "qckpt")
    save_checkpoint(path, state)
    loaded = load_checkpoint(path, mesh11, state_pspecs(model), dequantize=True)
    ref = dequantize_train_state(state)
    for k in ref.params:
        assert not isinstance(loaded.params[k], QuantizedParam)
        np.testing.assert_array_equal(np.asarray(ref.params[k]),
                                      np.asarray(loaded.params[k]), err_msg=k)


def test_resume_bitexact(tmp_path, mesh11):
    """train 5 -> save -> load -> train 5 more == train 10 straight, in the
    quantized-state domain (wire bytes survive the checkpoint untouched)."""
    model = tiny_model()
    opt = make_adamw(AdamWConfig(lr=1e-3))
    batch = tiny_batch()
    qs0 = quantize_train_state(
        init_train_state(model, opt, jax.random.PRNGKey(0)),
        model, jax.random.PRNGKey(9))
    step = make_jitted_train_step(model, opt, mesh11, quantized_state=True,
                                  donate=False)
    path = str(tmp_path / "resume")
    with mesh11:
        s5, l5 = run_steps(step, qs0, batch, 5)
        save_checkpoint(path, s5)
        sp = state_pspecs(model, quantized_state=True)
        s5b = load_checkpoint(path, mesh11, sp)
        s10_resumed, l10b = run_steps(step, s5b, batch, 5, start=5)
        s10_straight, _ = run_steps(step, s5, batch, 5, start=5)
    dq_a = dequantize_train_state(s10_resumed)
    dq_b = dequantize_train_state(s10_straight)
    for k in dq_a.params:
        np.testing.assert_array_equal(np.asarray(dq_a.params[k]),
                                      np.asarray(dq_b.params[k]), err_msg=k)
    for k in dq_a.opt.mu:
        np.testing.assert_array_equal(np.asarray(dq_a.opt.mu[k]),
                                      np.asarray(dq_b.opt.mu[k]), err_msg=k)
    assert int(dq_a.opt.step) == int(dq_b.opt.step) == 10


# ---------------------------------------------------------------------------
# manifest validation: corrupted / unknown manifests fail loudly
# ---------------------------------------------------------------------------


def _saved_tiny(tmp_path):
    model = tiny_model()
    opt = make_adamw(AdamWConfig())
    state = init_train_state(model, opt, jax.random.PRNGKey(0))
    path = str(tmp_path / "ckpt")
    save_checkpoint(path, state)
    return model, path


def _edit_manifest(path, fn):
    mp = os.path.join(path, "manifest.json")
    with open(mp) as f:
        man = json.load(f)
    fn(man)
    with open(mp, "w") as f:
        json.dump(man, f)


def test_unknown_format_fails(tmp_path, mesh11):
    model, path = _saved_tiny(tmp_path)
    _edit_manifest(path, lambda m: m.update(format="qsdp-ckpt-v9"))
    with pytest.raises(ValueError, match="unknown checkpoint format"):
        load_checkpoint(path, mesh11, state_pspecs(model))


def test_missing_format_fails(tmp_path, mesh11):
    model, path = _saved_tiny(tmp_path)
    _edit_manifest(path, lambda m: m.pop("format"))
    with pytest.raises(ValueError, match="unknown checkpoint format"):
        load_checkpoint(path, mesh11, state_pspecs(model))


def test_mismatched_leaf_shape_fails(tmp_path, mesh11):
    model, path = _saved_tiny(tmp_path)

    def corrupt(m):
        k = next(iter(m["leaves"]))
        m["leaves"][k]["shape"] = [1, 2, 3]

    _edit_manifest(path, corrupt)
    with pytest.raises(ValueError, match="corrupted checkpoint manifest"):
        load_checkpoint(path, mesh11, state_pspecs(model))


def test_missing_leaf_entry_fails(tmp_path, mesh11):
    model, path = _saved_tiny(tmp_path)

    def drop(m):
        m["leaves"].pop(next(iter(m["leaves"])))

    _edit_manifest(path, drop)
    with pytest.raises(ValueError, match="leaf set mismatch"):
        load_checkpoint(path, mesh11, state_pspecs(model))


def test_missing_manifest_fails(tmp_path, mesh11):
    model, path = _saved_tiny(tmp_path)
    os.remove(os.path.join(path, "manifest.json"))
    with pytest.raises(FileNotFoundError):
        load_checkpoint(path, mesh11, state_pspecs(model))
