"""Checkpoint save/load roundtrip on the trivial mesh."""
import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.core.qsdp import MeshSpec, QSDPConfig
from repro.models.transformer import Model
from repro.optim import AdamWConfig, make_adamw
from repro.train import load_checkpoint, save_checkpoint
from repro.train.step import init_train_state, state_pspecs


def test_checkpoint_roundtrip(tmp_path, mesh11):
    cfg = configs.get_smoke("gpt_125m")
    ms = MeshSpec(axes=("data", "model"), shape=(1, 1))
    model = Model(cfg, ms, QSDPConfig(min_quant_size=256))
    opt = make_adamw(AdamWConfig())
    state = init_train_state(model, opt, jax.random.PRNGKey(0))
    path = str(tmp_path / "ckpt")
    save_checkpoint(path, state, meta={"arch": cfg.name})
    loaded = load_checkpoint(path, mesh11, state_pspecs(model))
    for k in state.params:
        np.testing.assert_array_equal(np.asarray(state.params[k]),
                                      np.asarray(loaded.params[k]))
    for k in state.opt.mu:
        np.testing.assert_array_equal(np.asarray(state.opt.mu[k]),
                                      np.asarray(loaded.opt.mu[k]))
    assert int(loaded.opt.step) == int(state.opt.step)

    import json, os
    with open(os.path.join(path, "manifest.json")) as f:
        man = json.load(f)
    assert man["meta"]["arch"] == cfg.name
    assert man["format"].startswith("qsdp-ckpt")
