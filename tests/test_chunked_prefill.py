"""Property/conformance suite for chunked, length-bucketed prefill
(serve/scheduler.py ``prefill_chunk > 0`` + models/decode.py
``prefill_chunk_fn``).

The load-bearing invariant carries over from the blocking admission path:
with greedy decoding, a request's output tokens are BIT-IDENTICAL whether
it runs alone in a batch-of-1 engine
(``ServeEngine.generate(..., fold_step_keys=False, prefill_chunk=C)`` —
the solo reference runs the SAME chunk decomposition) or interleaved
under the chunked scheduler — across chunk sizes {1, 7, 64}, prompt
lengths straddling bucket boundaries, mid-prefill retirements of *other*
slots, and KV-ring wrap.  A request's stream depends only on (prompt,
weights, chunk size): never on bucket padding (asserted directly), nor on
co-resident traffic, admission timing, or pool dirtiness.  (Chunked and
whole-prompt prefill are distinct float paths — chunked attention reads
earlier chunks back from the bf16 KV ring, flash prefill never rounds
through the cache — so each admission path is compared against ITS solo
form, exactly as any chunked-prefill serving system must.)  Plus the
bounded-retrace guarantee (at most n_buckets compiled prefill shapes for
arbitrarily many distinct prompt lengths) and the dead-lane contract (a
retired or never-filled lane's cache bytes are frozen — the pos = -1
sentinel masks its ring write).

Engines and schedulers are cached at module scope (compiles dominate);
reusing one scheduler across tests is deliberate — chunked admission never
wipes a lane's ring, so a dirty pool is exactly the state the validity
masking must survive.
"""
import itertools

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st
from jax.sharding import PartitionSpec as P

from repro.core.qsdp import MeshSpec, QSDPConfig
from repro.models.config import ModelConfig
from repro.models.decode import DecodeSpec
from repro.models.transformer import Model
from repro.serve import (ContinuousScheduler, Request, ServeEngine,
                         make_sample_params, prefill_bucket_for,
                         prefill_bucket_sizes)

MS = MeshSpec(axes=("data", "model"), shape=(1, 1))
MESH = jax.make_mesh((1, 1), ("data", "model"))
GATHER_KEY = jax.random.PRNGKey(7)
RING = 32
VOCAB = 256
CHUNKS = (1, 7, 64)
_RID = itertools.count()


def _cfg(family: str) -> ModelConfig:
    base = dict(name=f"chunk-{family}", arch_type=family, n_layers=2,
                d_model=64, vocab_size=VOCAB, n_heads=4, n_kv_heads=2,
                head_dim=16, d_ff=128)
    if family == "moe":
        base.update(n_experts=4, moe_top_k=2)
    return ModelConfig(**base)


_models: dict = {}
_scheds: dict = {}
_solo: dict = {}
_solo_out: dict = {}


def model_and_params(family):
    if family not in _models:
        m = Model(_cfg(family), MS, QSDPConfig(min_quant_size=256))
        _models[family] = (m, m.init_params(jax.random.PRNGKey(0)))
    return _models[family]


def scheduler(family, slots, chunk, buckets=4, interleave=1
              ) -> ContinuousScheduler:
    key = (family, slots, chunk, buckets, interleave)
    if key not in _scheds:
        m, params = model_and_params(family)
        spec = DecodeSpec(cache_len=RING, batch_global=slots,
                          batch_sharded=False, sampling=True)
        _scheds[key] = ContinuousScheduler(
            m, MESH, spec, params, gather_key=GATHER_KEY,
            prefill_chunk=chunk, prefill_buckets=buckets,
            prefill_interleave=interleave)
    return _scheds[key]


def solo_tokens(family, prompt, gen, chunk, temperature=0.0, top_k=0, seed=0):
    """Reference: the request alone in a batch-of-1 engine running the SAME
    chunk decomposition (chunk=0 = whole-prompt prefill), fixed gather key
    (memoized across scenarios)."""
    key = (family, tuple(prompt), gen, chunk, temperature, top_k, seed)
    if key in _solo_out:
        return _solo_out[key]
    if family not in _solo:
        m, _ = model_and_params(family)
        spec = DecodeSpec(cache_len=RING, batch_global=1,
                          batch_sharded=False, sampling=True)
        _solo[family] = ServeEngine(m, MESH, spec)
    _, params = model_and_params(family)
    sample = make_sample_params(temperature, top_k, seed)
    out = _solo[family].generate(
        params, {"tokens": jnp.asarray(np.asarray(prompt, np.int32)[None])},
        {"tokens": P(None)}, n_tokens=gen, key=GATHER_KEY, sample=sample,
        fold_step_keys=False, prefill_chunk=chunk)
    _solo_out[key] = np.asarray(jax.device_get(out))[0]
    return _solo_out[key]


def run_scheduler(sched, reqs):
    for r in reqs:
        sched.submit(r)
    done = sched.run()
    return [done[r.rid].tokens for r in reqs]


def make_requests(rng, n, max_gen=5, min_plen=1, max_plen=10):
    """Prompt lengths drawn uniformly over [min_plen, max_plen] — for every
    chunk size under test that range straddles bucket boundaries (and for
    chunk 7 it crosses the multi-chunk threshold)."""
    return [Request(rid=f"c{next(_RID)}",
                    prompt=rng.integers(0, VOCAB,
                                        size=int(rng.integers(
                                            min_plen, max_plen + 1))).tolist(),
                    max_new_tokens=int(rng.integers(1, max_gen + 1)))
            for _ in range(n)]


# ---------------------------------------------------------------------------
# Bucket policy (pure host-side properties)
# ---------------------------------------------------------------------------


@settings(max_examples=50, deadline=None)
@given(chunk=st.integers(1, 256), n=st.integers(1, 8),
       ring=st.integers(1, 512))
def test_bucket_policy_properties(chunk, n, ring):
    """Buckets are ascending, at most n (+dedup slack never exceeds n),
    capped at min(chunk, ring); every chunk length <= the cap lands in a
    bucket >= it."""
    buckets = prefill_bucket_sizes(chunk, n, ring)
    top = min(chunk, ring)
    assert buckets == tuple(sorted(set(buckets)))
    assert len(buckets) <= n
    assert buckets[-1] == top
    for length in range(1, top + 1):
        b = prefill_bucket_for(length, buckets)
        assert length <= b <= top


def test_bucket_policy_rejects_oversized_chunk():
    with pytest.raises(ValueError, match="exceeds"):
        prefill_bucket_for(9, (4, 8))


# ---------------------------------------------------------------------------
# Tentpole invariant: chunked-interleaved greedy == solo same-chunk batch-of-1
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("family,chunk",
                         [("dense", 1), ("dense", 7), ("dense", 64),
                          ("moe", 7)])
@settings(max_examples=3, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_chunked_greedy_matches_solo(family, chunk, seed):
    """Random prompt lengths (straddling every bucket boundary) and
    generation lengths, admitted mid-decode through the chunked scheduler:
    every greedy request's tokens match its solo batch-of-1 run (same chunk
    decomposition) token-for-token, for chunk sizes 1 (token-at-a-time), 7
    (multi-chunk with ragged tails), and 64 (single chunk > every prompt)."""
    rng = np.random.default_rng(seed)
    sched = scheduler(family, 2, chunk)
    reqs = make_requests(rng, int(rng.integers(3, 6)))
    outs = run_scheduler(sched, reqs)
    for r, got in zip(reqs, outs):
        ref = solo_tokens(family, r.prompt, r.max_new_tokens, chunk)
        np.testing.assert_array_equal(got, ref,
                                      err_msg=f"{family} chunk={chunk} {r.rid}")


def test_chunked_sampled_requests_reproducible():
    """Sampled requests admitted through chunked prefill match their solo
    sampled run (the final chunk keys its draw by fold_in(seed, prompt_len),
    identical to whole-prompt prefill) and replay identically."""
    sched = scheduler("dense", 2, 4)
    rng = np.random.default_rng(17)
    reqs = [Request(rid=f"c{next(_RID)}",
                    prompt=rng.integers(0, VOCAB, size=pl).tolist(),
                    max_new_tokens=g, temperature=t, top_k=k, seed=s)
            for pl, g, t, k, s in [(9, 4, 1.1, 4, 3), (5, 3, 0.0, 0, 0),
                                   (7, 4, 0.8, 0, 9)]]
    outs = run_scheduler(sched, reqs)
    for r, got in zip(reqs, outs):
        np.testing.assert_array_equal(
            got, solo_tokens("dense", r.prompt, r.max_new_tokens, 4,
                             r.temperature, r.top_k, r.seed))
    renamed = [Request(rid=f"c{next(_RID)}", prompt=r.prompt,
                       max_new_tokens=r.max_new_tokens,
                       temperature=r.temperature, top_k=r.top_k, seed=r.seed)
               for r in reqs]
    for a, b in zip(outs, run_scheduler(sched, renamed)):
        np.testing.assert_array_equal(a, b)


def test_mid_prefill_retirement_of_other_slots():
    """A slot retired by its own prefill token (max_new_tokens == 1) while a
    neighbour is mid-prefill: the neighbour's remaining chunks, and the
    request refilled into the freed lane, are unaffected."""
    rng = np.random.default_rng(23)
    sched = scheduler("dense", 2, 2)
    reqs = [Request(rid=f"c{next(_RID)}",
                    prompt=rng.integers(0, VOCAB, size=4).tolist(),
                    max_new_tokens=1),  # retires off its prefill token
            Request(rid=f"c{next(_RID)}",
                    prompt=rng.integers(0, VOCAB, size=9).tolist(),
                    max_new_tokens=4),  # 5 chunks: mid-prefill at retirement
            Request(rid=f"c{next(_RID)}",
                    prompt=rng.integers(0, VOCAB, size=6).tolist(),
                    max_new_tokens=3)]  # refills the freed lane
    outs = run_scheduler(sched, reqs)
    for r, got in zip(reqs, outs):
        np.testing.assert_array_equal(
            got, solo_tokens("dense", r.prompt, r.max_new_tokens, 2),
            err_msg=r.rid)


def test_ring_wrap_composes_with_chunked_prefill():
    """Sliding-window model: chunked prefill into a ring the generation then
    wraps, through slots that are freed and reused — must match the solo
    run (which wraps the same ring)."""
    cfg = ModelConfig(name="chunk-wrap", arch_type="dense", n_layers=2,
                      d_model=64, vocab_size=VOCAB, n_heads=4, n_kv_heads=2,
                      head_dim=16, d_ff=128, sliding_window=0,
                      long_context="sliding_window", long_context_window=16)
    m = Model(cfg, MS, QSDPConfig(min_quant_size=256))
    params = m.init_params(jax.random.PRNGKey(0))
    spec = DecodeSpec(cache_len=16, batch_global=2, batch_sharded=False,
                      sampling=True)
    sched = ContinuousScheduler(m, MESH, spec, params, gather_key=GATHER_KEY,
                                prefill_chunk=3)
    solo = ServeEngine(
        m, MESH, DecodeSpec(cache_len=16, batch_global=1, batch_sharded=False,
                            sampling=True))
    rng = np.random.default_rng(3)
    # gen 14 from prompt 8: positions reach 21 > ring 16 — wraps; 3 requests
    # on 2 slots forces reuse after a wrapped generation
    reqs = [Request(rid=f"c{next(_RID)}",
                    prompt=rng.integers(0, VOCAB, size=8).tolist(),
                    max_new_tokens=g) for g in (14, 6, 14)]
    outs = run_scheduler(sched, reqs)
    for r, got in zip(reqs, outs):
        ref = solo.generate(
            params, {"tokens": jnp.asarray(np.asarray(r.prompt, np.int32)[None])},
            {"tokens": P(None)}, n_tokens=r.max_new_tokens, key=GATHER_KEY,
            fold_step_keys=False, prefill_chunk=3)
        np.testing.assert_array_equal(got, np.asarray(jax.device_get(ref))[0])


def test_tokens_independent_of_bucket_padding():
    """A valid chunk token's numerics never depend on the bucket it is
    padded into: the same request through bucket sets {C} (every chunk
    padded to C) and the default graded set yields bit-identical tokens —
    padding adds query rows, it cannot enter another row's reductions."""
    m, params = model_and_params("dense")
    spec = DecodeSpec(cache_len=RING, batch_global=1, batch_sharded=False,
                      sampling=True)
    eng = ServeEngine(m, MESH, spec)
    rng = np.random.default_rng(43)
    prompt = rng.integers(0, VOCAB, size=9).tolist()
    tb = {"tokens": jnp.asarray(np.asarray(prompt, np.int32)[None])}
    outs = [np.asarray(jax.device_get(eng.generate(
        params, tb, {"tokens": P(None)}, n_tokens=4, key=GATHER_KEY,
        fold_step_keys=False, prefill_chunk=4, prefill_buckets=nb)))[0]
        for nb in (1, 4)]
    np.testing.assert_array_equal(outs[0], outs[1])


# ---------------------------------------------------------------------------
# Bounded retraces
# ---------------------------------------------------------------------------


def test_trace_count_bounded_by_buckets():
    """>= 8 distinct prompt lengths compile at most n_buckets chunked
    prefill traces (the blocking path compiles one per distinct length —
    the retrace bug chunking fixes)."""
    m, params = model_and_params("dense")
    rng = np.random.default_rng(29)
    plens = list(range(1, 10))  # 9 distinct lengths
    reqs = [Request(rid=f"c{next(_RID)}",
                    prompt=rng.integers(0, VOCAB, size=pl).tolist(),
                    max_new_tokens=2) for pl in plens]
    sched = scheduler("dense", 2, 8, buckets=4)
    base = sched.stats()
    run_scheduler(sched, reqs)
    st_ = sched.stats()
    assert st_["prefill_traces"] <= 4, st_
    # the REAL jit cache (one compiled fn per bucket) obeys the same bound
    assert len(sched.engine._chunk_steps) <= 4
    assert st_["prefills"] - base["prefills"] == len(plens)
    assert st_["prefill_chunks"] > base["prefill_chunks"]

    blocking = scheduler("dense", 2, 0)
    run_scheduler(blocking, [
        Request(rid=f"c{next(_RID)}", prompt=r.prompt,
                max_new_tokens=r.max_new_tokens) for r in reqs])
    assert blocking.stats()["prefill_traces"] == len(plens)


def test_chunked_validation_and_interleave():
    """prefill_chunk rejects non-attention stacks; prefill_interleave > 1
    drains multi-chunk prompts in fewer scheduler steps, same tokens."""
    mcfg = ModelConfig(name="chunk-ssm", arch_type="ssm", n_layers=2,
                       d_model=64, vocab_size=VOCAB, ssm_state=16,
                       ssm_head_dim=16, ssm_chunk=8)
    m = Model(mcfg, MS, QSDPConfig(min_quant_size=256))
    spec = DecodeSpec(cache_len=0, batch_global=2, batch_sharded=False)
    with pytest.raises(ValueError, match="prefill_chunk"):
        ContinuousScheduler(m, MESH, spec, m.init_params(jax.random.PRNGKey(0)),
                            prefill_chunk=4)

    rng = np.random.default_rng(31)
    prompt = rng.integers(0, VOCAB, size=10).tolist()
    fair = scheduler("dense", 2, 2)
    eager = scheduler("dense", 2, 2, interleave=4)
    a = run_scheduler(fair, [Request(rid=f"c{next(_RID)}", prompt=prompt,
                                     max_new_tokens=4)])[0]
    b = run_scheduler(eager, [Request(rid=f"c{next(_RID)}", prompt=prompt,
                                      max_new_tokens=4)])[0]
    np.testing.assert_array_equal(a, b)
    np.testing.assert_array_equal(a, solo_tokens("dense", prompt, 4, 2))


def test_moe_no_drop_isolates_tokens():
    """moe_layer(no_drop=True): a token's output is independent of every
    other token in the batch — capacity can never evict it.  The standard
    capacity path demonstrably leaks (earlier tokens' routing decides which
    later assignments are dropped), which is why the chunked-prefill and
    pooled-decode serve paths dispatch drop-free."""
    from repro.compat import shard_map
    from repro.models.moe import MoEConfig, moe_layer

    cfg = MoEConfig(n_experts=4, top_k=2, d_model=16, d_ff=32, tp=1,
                    capacity_factor=0.25)  # overflows at t=32 (c floors at 8)
    rng = np.random.default_rng(7)
    w = {"router": jnp.asarray(rng.normal(size=(16, 4)), jnp.float32),
         "w_gate": jnp.asarray(0.1 * rng.normal(size=(4, 16, 32)), jnp.float32),
         "w_up": jnp.asarray(0.1 * rng.normal(size=(4, 16, 32)), jnp.float32),
         "w_down": jnp.asarray(0.1 * rng.normal(size=(4, 32, 16)), jnp.float32)}
    x1 = rng.normal(size=(32, 16)).astype(np.float32)
    x2 = x1.copy()
    x2[:16] = rng.normal(size=(16, 16))  # perturb the OTHER (earlier) tokens

    def run(no_drop, x):
        fn = shard_map(lambda xx: moe_layer(xx, w, cfg, no_drop=no_drop)[0],
                       mesh=MESH, in_specs=(P(),), out_specs=P(),
                       check_vma=False)
        return np.asarray(jax.device_get(jax.jit(fn)(jnp.asarray(x))))

    np.testing.assert_array_equal(run(True, x1)[16:], run(True, x2)[16:])
    assert not np.array_equal(run(False, x1)[16:], run(False, x2)[16:]), \
        "expected capacity drops to leak across tokens at this overflow"


# ---------------------------------------------------------------------------
# Dead-lane contract
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("chunk", [0, 4])
def test_dead_lane_bytes_frozen(chunk):
    """A retired lane's cache bytes never change while other lanes decode:
    the pos = -1 sentinel masks the dead lane's ring write under BOTH
    admission paths — the direct form of 'a dead lane's bytes never
    influence a live lane' (plus the live lane still matches solo)."""
    sched = scheduler("dense", 2, chunk)
    rng = np.random.default_rng(37)
    # dirty both lanes, then retire everything
    run_scheduler(sched, make_requests(rng, 3, max_gen=3))
    assert sched.n_active() == 0
    snap = {k: np.asarray(jax.device_get(v))[:, 1].copy()
            for k, v in sched.cache.items()}
    # one request -> lane 0; lane 1 stays dead (dirty) for the whole run
    req = Request(rid=f"c{next(_RID)}",
                  prompt=rng.integers(0, VOCAB, size=6).tolist(),
                  max_new_tokens=4)
    out = run_scheduler(sched, [req])[0]
    np.testing.assert_array_equal(out,
                                  solo_tokens("dense", req.prompt,
                                              req.max_new_tokens, chunk))
    for k, v in sched.cache.items():
        np.testing.assert_array_equal(
            np.asarray(jax.device_get(v))[:, 1], snap[k],
            err_msg=f"dead lane {k} bytes changed (chunk={chunk})")


def test_immediate_retire_refills_same_admission_pass():
    """Blocking admission: a slot retired by its own prefill token
    (max_new_tokens == 1) is re-scanned and refilled within the SAME
    admission pass — three 1-token requests through one slot finish with
    ZERO pooled decode steps."""
    sched = scheduler("dense", 1, 0)
    base = sched.stats()
    rng = np.random.default_rng(41)
    reqs = [Request(rid=f"c{next(_RID)}",
                    prompt=rng.integers(0, VOCAB, size=5).tolist(),
                    max_new_tokens=1) for _ in range(3)]
    outs = run_scheduler(sched, reqs)
    st_ = sched.stats()
    assert st_["decode_steps"] - base["decode_steps"] == 0, st_
    assert st_["prefills"] - base["prefills"] == 3
    for r, got in zip(reqs, outs):
        np.testing.assert_array_equal(got, solo_tokens("dense", r.prompt, 1, 0))


# ---------------------------------------------------------------------------
# Paged KV block pool (kv_block_size > 0): the same isolation invariant must
# hold with the per-slot rings replaced by block-table indirection into a
# shared pool — across placements, prefix-cache hits, pool fragmentation
# after churn, wrap-driven copy-on-write, and co-resident traffic.  On one
# device the paged gather/scatter visits the same logical addresses as the
# ring, so the RING solo engine doubles as the reference: these tests also
# pin paged == ring at tp=1 (the (2,4) form lives in check_serve_sched.py).
# ---------------------------------------------------------------------------

PAGED_BS = 8  # block size == chunk size keeps shared prefixes chunk-aligned


def paged_scheduler(slots=3, pool_blocks=0, share=True) -> ContinuousScheduler:
    key = ("paged", slots, pool_blocks, share)
    if key not in _scheds:
        m, params = model_and_params("dense")
        spec = DecodeSpec(cache_len=RING, batch_global=slots,
                          batch_sharded=False, sampling=True,
                          kv_block_size=PAGED_BS, kv_pool_blocks=pool_blocks)
        _scheds[key] = ContinuousScheduler(
            m, MESH, spec, params, gather_key=GATHER_KEY,
            prefill_chunk=PAGED_BS, prefill_buckets=3, kv_prefix_share=share)
    return _scheds[key]


@settings(max_examples=3, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_paged_interleaved_matches_solo(seed):
    """Random requests through the paged scheduler: every greedy stream
    matches the solo batch-of-1 run (same chunk decomposition) bit-for-bit,
    wherever the allocator happened to place each block."""
    rng = np.random.default_rng(seed)
    sched = paged_scheduler()
    reqs = make_requests(rng, int(rng.integers(3, 6)))
    outs = run_scheduler(sched, reqs)
    for r, got in zip(reqs, outs):
        np.testing.assert_array_equal(
            got, solo_tokens("dense", r.prompt, r.max_new_tokens, PAGED_BS),
            err_msg=f"paged {r.rid}")
    sched.pool.check_invariants()


def test_paged_sampled_requests_match_solo():
    """Sampled requests under the paged pool reproduce their solo sampled
    runs — block indirection must not perturb the per-request keying."""
    sched = paged_scheduler()
    rng = np.random.default_rng(19)
    reqs = [Request(rid=f"c{next(_RID)}",
                    prompt=rng.integers(0, VOCAB, size=pl).tolist(),
                    max_new_tokens=g, temperature=t, top_k=k, seed=s)
            for pl, g, t, k, s in [(9, 4, 1.1, 4, 3), (5, 3, 0.0, 0, 0),
                                   (7, 4, 0.8, 0, 9)]]
    outs = run_scheduler(sched, reqs)
    for r, got in zip(reqs, outs):
        np.testing.assert_array_equal(
            got, solo_tokens("dense", r.prompt, r.max_new_tokens, PAGED_BS,
                             r.temperature, r.top_k, r.seed))


def test_paged_prefix_sharing_bit_exact():
    """Requests sharing a 2-block system prompt: sharing engages
    (prefix_hits > 0, shared blocks skip their prefill chunks) and every
    stream still matches BOTH its solo run and the same trace through a
    sharing-disabled scheduler — the prefix cache is invisible in tokens."""
    rng = np.random.default_rng(23)
    system = rng.integers(0, VOCAB, size=2 * PAGED_BS).tolist()
    mk = lambda: Request(  # noqa: E731
        rid=f"c{next(_RID)}",
        prompt=system + rng.integers(
            0, VOCAB, size=int(rng.integers(1, 5))).tolist(),
        max_new_tokens=int(rng.integers(2, 5)))
    reqs = [mk() for _ in range(5)]
    sched = paged_scheduler()
    base_hits = sched.pool.stats["prefix_hits"]
    base_chunks = sched.stats()["prefill_chunks"]
    outs = run_scheduler(sched, reqs)
    hits = sched.pool.stats["prefix_hits"] - base_hits
    assert hits > 0, sched.pool.stats
    # shared blocks skip whole chunks: 5 requests x 3 chunks would be 15
    launches = sched.stats()["prefill_chunks"] - base_chunks
    assert launches < 3 * len(reqs), launches
    for r, got in zip(reqs, outs):
        np.testing.assert_array_equal(
            got, solo_tokens("dense", r.prompt, r.max_new_tokens, PAGED_BS),
            err_msg=r.rid)
    noshare = paged_scheduler(share=False)
    renamed = [Request(rid=f"c{next(_RID)}", prompt=r.prompt,
                       max_new_tokens=r.max_new_tokens) for r in reqs]
    for a, b in zip(outs, run_scheduler(noshare, renamed)):
        np.testing.assert_array_equal(a, b)
    sched.pool.check_invariants()


def test_paged_fragmentation_churn():
    """Waves of mixed-length requests fragment the free list (retirements
    interleave with admissions, cached prefix blocks evict on demand);
    tokens stay placement-independent and the pool neither leaks nor
    double-frees."""
    sched = paged_scheduler()
    rng = np.random.default_rng(29)
    for wave in range(3):
        reqs = make_requests(rng, 5, max_gen=4)
        outs = run_scheduler(sched, reqs)
        for r, got in zip(reqs, outs):
            np.testing.assert_array_equal(
                got, solo_tokens("dense", r.prompt, r.max_new_tokens,
                                 PAGED_BS), err_msg=f"wave {wave} {r.rid}")
        sched.pool.check_invariants()
    assert sched.pool.blocks_in_use == 0  # every retirement released blocks


def test_paged_wrap_cow_preserves_shared_blocks():
    """Sliding-window wrap into a SHARED prefix block: the wrapping writer
    must copy-on-write (readers keep the original bytes) or unregister (sole
    owner), and every wrapped stream still matches its solo run."""
    cfg = ModelConfig(name="paged-wrap", arch_type="dense", n_layers=2,
                      d_model=64, vocab_size=VOCAB, n_heads=4, n_kv_heads=2,
                      head_dim=16, d_ff=128, sliding_window=0,
                      long_context="sliding_window", long_context_window=16)
    m = Model(cfg, MS, QSDPConfig(min_quant_size=256))
    params = m.init_params(jax.random.PRNGKey(0))
    # 6 blocks (not the default 4): with only 4, r1's admission pins the
    # shared block out of the cached tier and reserves its full wrap
    # footprint, so r2 would queue and only ever see an UNREGISTERED block
    # (r1 wraps as sole owner).  6 lets both admit concurrently, which is
    # the scenario under test: the first wrapping writer must COW-fork
    # because the other lane still holds a reference.
    spec = DecodeSpec(cache_len=16, batch_global=2, batch_sharded=False,
                      sampling=True, kv_block_size=PAGED_BS, kv_pool_blocks=6)
    sched = ContinuousScheduler(m, MESH, spec, params, gather_key=GATHER_KEY,
                                prefill_chunk=PAGED_BS, prefill_buckets=2)
    solo = ServeEngine(
        m, MESH, DecodeSpec(cache_len=16, batch_global=1, batch_sharded=False,
                            sampling=True))
    rng = np.random.default_rng(13)
    system = rng.integers(0, VOCAB, size=PAGED_BS).tolist()
    mk = lambda g: Request(  # noqa: E731
        rid=f"c{next(_RID)}",
        prompt=system + rng.integers(0, VOCAB, size=2).tolist(),
        max_new_tokens=g)
    r0 = mk(2)  # registers the system block, retires (block cached)
    outs = run_scheduler(sched, [r0])
    r1, r2 = mk(10), mk(10)  # 10 + 10 = 20 > window 16: both wrap back
    outs += run_scheduler(sched, [r1, r2])  # into the SHARED logical block 0
    assert sched.pool.stats["prefix_hits"] >= 2, sched.pool.stats
    assert sched.pool.stats["cow_forks"] >= 1, sched.pool.stats
    for r, got in zip([r0, r1, r2], outs):
        ref = solo.generate(
            params, {"tokens": jnp.asarray(np.asarray(r.prompt, np.int32)[None])},
            {"tokens": P(None)}, n_tokens=r.max_new_tokens, key=GATHER_KEY,
            fold_step_keys=False, prefill_chunk=PAGED_BS)
        np.testing.assert_array_equal(got, np.asarray(jax.device_get(ref))[0],
                                      err_msg=r.rid)
    sched.pool.check_invariants()


def test_paged_pool_exhaustion_queues():
    """Satellite: admission is bounded by FREE BLOCKS, not free slots — two
    4-block requests over a 4-block pool run one at a time (the second
    queues despite an idle slot) and both finish with solo-exact tokens."""
    sched = paged_scheduler(slots=2, pool_blocks=4)  # one row: 4 blocks
    rng = np.random.default_rng(31)
    reqs = [Request(rid=f"c{next(_RID)}",
                    prompt=rng.integers(0, VOCAB, size=20).tolist(),
                    max_new_tokens=6)  # ceil(26 / 8) = 4 blocks
            for _ in range(2)]
    for r in reqs:
        sched.submit(r)
    while sched.queue or sched.n_active():
        assert sched.n_active() <= 1, "pool-exhausted admission did not queue"
        sched.step()
    for r in reqs:
        np.testing.assert_array_equal(
            sched.finished[r.rid].tokens,
            solo_tokens("dense", r.prompt, r.max_new_tokens, PAGED_BS),
            err_msg=r.rid)
    sched.pool.check_invariants()
