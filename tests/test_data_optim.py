"""Synthetic data pipeline + optimizer unit tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data import SyntheticLM
from repro.optim import AdamWConfig, SGDConfig, cosine_schedule, make_adamw, make_sgd


def test_data_deterministic_and_shaped():
    d = SyntheticLM(vocab_size=256, seq_len=32, global_batch=4, seed=7)
    t1, l1 = d.sample(3)
    t2, l2 = d.sample(3)
    np.testing.assert_array_equal(t1, t2)
    assert t1.shape == (4, 32) and l1.shape == (4, 32)
    assert t1.dtype == jnp.int32
    t3, _ = d.sample(4)
    assert not np.array_equal(np.asarray(t1), np.asarray(t3))


def test_data_is_markov_consistent():
    """labels[t] is a valid successor of tokens[t] under the fixed table."""
    d = SyntheticLM(vocab_size=64, seq_len=16, global_batch=2, seed=1)
    tab = d._table()
    t, l = map(np.asarray, d.sample(0))
    for b in range(2):
        for i in range(16):
            assert l[b, i] in tab[t[b, i]]


def test_data_learnable_entropy_floor():
    d = SyntheticLM(vocab_size=512, seq_len=8, global_batch=2, seed=0, branching=4)
    h = d.bigram_entropy()
    assert h <= np.log(4) + 1e-6  # at most log(branching)
    assert h < np.log(512)  # strictly below the unigram/uniform floor


def test_adamw_matches_reference():
    cfg = AdamWConfig(lr=0.1, b1=0.9, b2=0.99, eps=1e-8, weight_decay=0.0)
    opt = make_adamw(cfg)
    p = {"w": jnp.array([1.0, 2.0])}
    g = {"w": jnp.array([0.5, -1.0])}
    st = opt.init(p)
    p1, st1 = opt.update(p, g, st)
    # step 1: mhat = g, vhat = g^2 -> update = lr * g/(|g| + eps) = lr*sign
    np.testing.assert_allclose(p1["w"], p["w"] - 0.1 * np.sign([0.5, -1.0]),
                               rtol=1e-5)
    assert int(st1.step) == 1
    # states sharded like params
    assert st1.mu["w"].shape == p["w"].shape


def test_adamw_weight_decay_decoupled():
    opt = make_adamw(AdamWConfig(lr=0.1, weight_decay=0.5))
    p = {"w": jnp.array([2.0])}
    g = {"w": jnp.array([0.0])}
    p1, _ = opt.update(p, g, opt.init(p))
    np.testing.assert_allclose(p1["w"], [2.0 - 0.1 * 0.5 * 2.0], rtol=1e-6)


def test_sgd_momentum():
    opt = make_sgd(SGDConfig(lr=1.0, momentum=0.9))
    p = {"w": jnp.zeros(1)}
    g = {"w": jnp.ones(1)}
    st = opt.init(p)
    p1, st1 = opt.update(p, g, st)
    p2, _ = opt.update(p1, g, st1)
    np.testing.assert_allclose(p1["w"], [-1.0])
    np.testing.assert_allclose(p2["w"], [-1.0 - 1.9])


def test_cosine_schedule():
    s = cosine_schedule(1.0, warmup_steps=10, total_steps=110, min_ratio=0.1)
    assert float(s(jnp.asarray(0))) == 0.0
    assert float(s(jnp.asarray(5))) == pytest.approx(0.5)
    assert float(s(jnp.asarray(10))) == pytest.approx(1.0)
    assert float(s(jnp.asarray(110))) == pytest.approx(0.1)
    assert float(s(jnp.asarray(60))) == pytest.approx(0.55, abs=1e-6)
