"""Multi-device integration tests.

jax's device count is fixed at first init, so in-process tests here would
see this process's single CPU device; the real distributed coverage runs in
subprocesses with XLA_FLAGS=--xla_force_host_platform_device_count=8:

  * scripts/check_distributed.py — numerical correctness of the quantized
    collectives, hierarchical variants, engine gathers, TP gradients vs a
    single-device replica, and decode==prefill consistency.
  * scripts/check_coalesced.py — bit-exactness of the coalesced wire format
    vs. the per-tensor collectives (all bits/modes/backends, hierarchical,
    bf16 metadata, engine + prefetch pipeline) and the HLO regression that
    a coalesced layer gather is exactly ONE u8 all-gather launch.
  * scripts/check_quantized_state.py — quantized-domain train state on the
    (2,4) mesh: 10-step bit-exactness vs the f32 QDQ master path, and
    checkpoint-v2 save-on-one-mesh/load-on-another resharding
    ((1,1) <-> (2,4), f32 and quantized states).
  * scripts/check_serve_sched.py — continuous-batching scheduler on the
    (2,4) mesh: greedy slot-isolation (interleaved == solo batch-of-1,
    bit-exact, batch-sharded slot pool) and sampled-request replay
    determinism, dense + moe.
  * scripts/check_tune_costmodel.py — the deployment-plan autotuner's
    predicted HLO all-gather launch counts vs actually-compiled programs
    on the (2,4) and (2,2,2) pod meshes: per-tensor / coalesced /
    threshold-vetoed / mixed per-layer policies and hierarchical gathers.

These also run in the CI `distributed` job (pytest -m slow) so they cannot
silently rot.
"""
import os
import subprocess
import sys

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(script, timeout=900):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    env.pop("XLA_FLAGS", None)  # script sets its own device count
    return subprocess.run(
        [sys.executable, os.path.join(ROOT, "scripts", script)],
        capture_output=True, text=True, timeout=timeout, env=env,
    )


@pytest.mark.slow
def test_distributed_numerics():
    r = _run("check_distributed.py")
    assert r.returncode == 0, r.stdout[-4000:] + r.stderr[-4000:]
    assert "ALL-OK" in r.stdout
    assert "FAIL " not in r.stdout


@pytest.mark.slow
def test_coalesced_wire_format():
    r = _run("check_coalesced.py")
    assert r.returncode == 0, r.stdout[-4000:] + r.stderr[-4000:]
    assert "ALL-OK" in r.stdout
    assert "FAIL " not in r.stdout


@pytest.mark.slow
def test_quantized_state_distributed():
    r = _run("check_quantized_state.py")
    assert r.returncode == 0, r.stdout[-4000:] + r.stderr[-4000:]
    assert "ALL-OK" in r.stdout
    assert "FAIL " not in r.stdout


@pytest.mark.slow
def test_serve_scheduler_distributed():
    r = _run("check_serve_sched.py")
    assert r.returncode == 0, r.stdout[-4000:] + r.stderr[-4000:]
    assert "ALL-OK" in r.stdout
    assert "FAIL " not in r.stdout


@pytest.mark.slow
def test_tune_costmodel_conformance():
    r = _run("check_tune_costmodel.py")
    assert r.returncode == 0, r.stdout[-4000:] + r.stderr[-4000:]
    assert "ALL-OK" in r.stdout
    assert "FAIL " not in r.stdout
