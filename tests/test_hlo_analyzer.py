"""Unit tests for roofline/hlo_analyzer.py on hand-written HLO text.

The analyzer is the ground truth for every compiled-collective assertion in
the repo (check_tune_costmodel, check_coalesced, repro.analysis collective
audit), so its parsing of the HLO text forms — sync and async collectives,
iota vs explicit replica groups, while-loop trip counts — is pinned here
against tiny hand-written modules with known byte counts.
"""
from repro.roofline.hlo_analyzer import analyze_hlo

SUM = """\
%sum (a: f32[], b: f32[]) -> f32[] {
  %a = f32[] parameter(0)
  %b = f32[] parameter(1)
  ROOT %r = f32[] add(%a, %b)
}
"""


def test_all_gather_count_and_wire():
    hlo = """\
HloModule m

ENTRY %main (p0: f32[8,32]) -> f32[32,32] {
  %p0 = f32[8,32]{1,0} parameter(0)
  ROOT %ag = f32[32,32]{1,0} all-gather(%p0), channel_id=1, replica_groups=[1,4]<=[4], dimensions={0}, use_global_device_ids=true
}
"""
    coll = analyze_hlo(hlo)["collectives"]
    assert coll["counts"]["all-gather"] == 1
    # ring all-gather: result_bytes * (g-1)/g = 32*32*4 * 3/4
    assert coll["all-gather"] == 3072
    assert coll["total"] == 3072


def test_counts_by_dtype_separates_quantized_payload():
    # one u8 wire-code gather + one f32 metadata gather: the per-dtype
    # launch counts are what the coalesced-wire regressions key on.
    hlo = """\
HloModule m

ENTRY %main (p0: u8[4,64], p1: f32[4,64]) -> f32[16,64] {
  %p0 = u8[4,64]{1,0} parameter(0)
  %p1 = f32[4,64]{1,0} parameter(1)
  %agu = u8[16,64]{1,0} all-gather(%p0), replica_groups=[1,4]<=[4], dimensions={0}
  %agf = f32[16,64]{1,0} all-gather(%p1), replica_groups=[1,4]<=[4], dimensions={0}
  %c = f32[16,64]{1,0} convert(%agu)
  ROOT %r = f32[16,64]{1,0} add(%c, %agf)
}
"""
    coll = analyze_hlo(hlo)["collectives"]
    assert coll["counts"]["all-gather"] == 2
    assert coll["counts_by_dtype"] == {"all-gather:u8": 1, "all-gather:f32": 1}
    # u8: 1024*3/4 = 768; f32: 4096*3/4 = 3072
    assert coll["all-gather"] == 768 + 3072


def test_collective_classification_and_wire_formulas():
    hlo = SUM + """\

ENTRY %main (p0: f32[4,8], p1: f32[8,8]) -> f32[8,8] {
  %p0 = f32[4,8]{1,0} parameter(0)
  %ag = f32[16,8]{1,0} all-gather(%p0), replica_groups=[1,4]<=[4], dimensions={0}
  %rs = f32[4,8]{1,0} reduce-scatter(%ag), replica_groups=[1,4]<=[4], dimensions={0}, to_apply=%sum
  %p1 = f32[8,8]{1,0} parameter(1)
  %ar = f32[8,8]{1,0} all-reduce(%p1), replica_groups=[1,4]<=[4], to_apply=%sum
  %a2a = f32[8,8]{1,0} all-to-all(%ar), replica_groups=[1,4]<=[4], dimensions={0}
  ROOT %cp = f32[8,8]{1,0} collective-permute(%a2a), source_target_pairs={{0,1},{1,2},{2,3},{3,0}}
}
"""
    coll = analyze_hlo(hlo)["collectives"]
    for kind in ("all-gather", "reduce-scatter", "all-reduce", "all-to-all",
                 "collective-permute"):
        assert coll["counts"][kind] == 1, kind
    assert coll["all-gather"] == 512 * 3 // 4          # 384
    assert coll["reduce-scatter"] == 128 * 3           # 384
    assert coll["all-reduce"] == 2 * 256 * 3 // 4      # 384
    assert coll["all-to-all"] == 256 * 3 // 4          # 192
    assert coll["collective-permute"] == 256           # full result bytes
    assert coll["total"] == 384 * 3 + 192 + 256


def test_async_start_done_counted_once():
    # async form: the -start op carries a (operand, result) tuple type; the
    # result buffer is the LAST shape and the -done must not double-count.
    hlo = """\
HloModule m

ENTRY %main (p0: u8[8,32]) -> u8[32,32] {
  %p0 = u8[8,32]{1,0} parameter(0)
  %ags = (u8[8,32]{1,0}, u8[32,32]{1,0}) all-gather-start(%p0), channel_id=1, replica_groups=[1,4]<=[4], dimensions={0}
  ROOT %agd = u8[32,32]{1,0} all-gather-done(%ags)
}
"""
    coll = analyze_hlo(hlo)["collectives"]
    assert coll["counts"]["all-gather"] == 1
    assert coll["counts_by_dtype"] == {"all-gather:u8": 1}
    assert coll["all-gather"] == 1024 * 3 // 4


def test_while_trip_count_multiplies_collectives():
    hlo = SUM + """\

%cond (carg: (s32[], f32[8,32])) -> pred[] {
  %carg = (s32[], f32[8,32]) parameter(0)
  %ci = s32[] get-tuple-element(%carg), index=0
  %n = s32[] constant(7)
  ROOT %lt = pred[] compare(%ci, %n), direction=LT
}

%body (arg: (s32[], f32[8,32])) -> (s32[], f32[8,32]) {
  %arg = (s32[], f32[8,32]) parameter(0)
  %i = s32[] get-tuple-element(%arg), index=0
  %x = f32[8,32]{1,0} get-tuple-element(%arg), index=1
  %ar = f32[8,32]{1,0} all-reduce(%x), replica_groups=[1,8]<=[8], to_apply=%sum
  %one = s32[] constant(1)
  %ip = s32[] add(%i, %one)
  ROOT %out = (s32[], f32[8,32]) tuple(%ip, %ar)
}

ENTRY %main (p0: f32[8,32]) -> (s32[], f32[8,32]) {
  %p0 = f32[8,32]{1,0} parameter(0)
  %z = s32[] constant(0)
  %init = (s32[], f32[8,32]) tuple(%z, %p0)
  ROOT %w = (s32[], f32[8,32]) while(%init), condition=%cond, body=%body, backend_config={"known_trip_count":{"n":"7"}}
}
"""
    coll = analyze_hlo(hlo)["collectives"]
    assert coll["counts"]["all-reduce"] == 7
    assert coll["counts_by_dtype"] == {"all-reduce:f32": 7}
    per_iter = 2 * (8 * 32 * 4) * 7 // 8
    assert coll["all-reduce"] == 7 * per_iter


def test_multi_mesh_group_forms_and_degenerate_axis():
    # same program gathering over two mesh axes: iota form [groups,size],
    # explicit {{...}} form, and a size-1 axis that must NOT count.
    hlo = """\
HloModule m

ENTRY %main (p0: f32[8,16], p1: f32[4,16]) -> f32[16,16] {
  %p0 = f32[8,16]{1,0} parameter(0)
  %p1 = f32[4,16]{1,0} parameter(1)
  %ag_model = f32[16,16]{1,0} all-gather(%p0), replica_groups=[4,2]<=[8], dimensions={0}
  %ag_data = f32[16,16]{1,0} all-gather(%p1), replica_groups={{0,1,2,3},{4,5,6,7}}, dimensions={0}
  %ag_degenerate = f32[8,16]{1,0} all-gather(%p0), replica_groups=[8,1]<=[8], dimensions={0}
  ROOT %r = f32[16,16]{1,0} add(%ag_model, %ag_data)
}
"""
    coll = analyze_hlo(hlo)["collectives"]
    assert coll["counts"]["all-gather"] == 2  # degenerate axis excluded
    # g=2: 1024*1/2 = 512; g=4: 1024*3/4 = 768
    assert coll["all-gather"] == 512 + 768


def test_dot_flops_through_while():
    hlo = """\
HloModule m

%cond (carg: (s32[], f32[8,16])) -> pred[] {
  %carg = (s32[], f32[8,16]) parameter(0)
  %ci = s32[] get-tuple-element(%carg), index=0
  %n = s32[] constant(3)
  ROOT %lt = pred[] compare(%ci, %n), direction=LT
}

%body (arg: (s32[], f32[8,16])) -> (s32[], f32[8,16]) {
  %arg = (s32[], f32[8,16]) parameter(0)
  %i = s32[] get-tuple-element(%arg), index=0
  %x = f32[8,16]{1,0} get-tuple-element(%arg), index=1
  %w = f32[16,16]{1,0} constant({...})
  %y = f32[8,16]{1,0} dot(%x, %w), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %one = s32[] constant(1)
  %ip = s32[] add(%i, %one)
  ROOT %out = (s32[], f32[8,16]) tuple(%ip, %y)
}

ENTRY %main (p0: f32[8,16]) -> (s32[], f32[8,16]) {
  %p0 = f32[8,16]{1,0} parameter(0)
  %z = s32[] constant(0)
  %init = (s32[], f32[8,16]) tuple(%z, %p0)
  ROOT %w = (s32[], f32[8,16]) while(%init), condition=%cond, body=%body, backend_config={"known_trip_count":{"n":"3"}}
}
"""
    out = analyze_hlo(hlo)
    # 2*M*N*K per dot = 2*8*16*16 = 4096, times 3 trips
    assert out["flops"] == 3 * 2 * 8 * 16 * 16
