"""All 40 (architecture x input shape) pairs produce coherent input specs
and parameter layouts for the production meshes — pure shape math, no
devices (the compile proof lives in the dry-run sweep)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.configs.inputs import input_specs
from repro.core.qsdp import MeshSpec, QSDPConfig
from repro.models.config import SHAPES
from repro.models.decode import make_decode_spec
from repro.models.transformer import Model

MS = MeshSpec(axes=("data", "model"), shape=(16, 16))
MS_POD = MeshSpec(axes=("pod", "data", "model"), shape=(2, 16, 16))


@pytest.fixture(scope="module")
def models():
    return {a: Model(configs.get_config(a), MS, QSDPConfig())
            for a in configs.ASSIGNED}


@pytest.mark.parametrize("shape_name", list(SHAPES))
def test_input_specs_all_archs(models, shape_name):
    shape = SHAPES[shape_name]
    for arch, model in models.items():
        kind, structs, specs = input_specs(model, shape)
        assert kind == {"train": "train", "prefill": "prefill",
                        "decode": "decode"}[shape.kind]
        flat_structs = jax.tree.leaves(structs)
        flat_specs = jax.tree.leaves(specs, is_leaf=lambda x: x is None or hasattr(x, "index"))
        assert all(isinstance(s, jax.ShapeDtypeStruct) for s in flat_structs)
        if kind == "train":
            batch, _ = structs
            assert batch["tokens"].shape == (shape.global_batch, shape.seq_len)
        elif kind == "decode":
            cache, tok, pos, _ = structs
            assert tok.shape == (shape.global_batch,)
            # seq-sharded cache dims divide the model axis
            for k, st in cache.items():
                if k in ("k", "v", "shared_k", "shared_v", "ck", "cv"):
                    assert st.shape[2] % 16 == 0, (arch, k, st.shape)


def test_param_layouts_production_mesh(models):
    """Every parameter's rest layout divides both meshes exactly."""
    for arch, model in models.items():
        for name, spec in model.specs.items():
            shp = spec.rest_shape(MS)
            assert shp[-2] == MS.fsdp_size, (arch, name)
            # TP divisibility was already asserted in tp_local_shape
            spec.tp_local_shape(MS.model_size)
            shp_pod = spec.rest_shape(MS_POD)
            assert shp_pod[-2] == MS_POD.fsdp_size, (arch, name)


def test_decode_spec_policies(models):
    # dense archs use the sliding window for long_500k
    d = make_decode_spec(models["yi_34b"], SHAPES["long_500k"])
    assert d.cache_len == configs.get_config("yi_34b").long_context_window
    # ssm is O(1)-state
    d = make_decode_spec(models["mamba2_370m"], SHAPES["long_500k"])
    assert d.cache_len == 0
    # decode_32k keeps the full ring
    d = make_decode_spec(models["yi_34b"], SHAPES["decode_32k"])
    assert d.cache_len == 32_768 and d.batch_sharded
    # long_500k batch=1 cannot shard over 16 data ranks
    d = make_decode_spec(models["qwen2_vl_72b"], SHAPES["long_500k"])
    assert not d.batch_sharded


def test_model_flops_accounting(models):
    """6ND sanity: the headline parameter counts match the model cards."""
    expect = {
        "qwen2_5_3b": (2.5e9, 4.0e9), "yi_6b": (5.5e9, 7.0e9),
        "yi_34b": (32e9, 37e9), "qwen2_vl_72b": (68e9, 75e9),
        "mamba2_370m": (0.3e9, 0.5e9), "olmoe_1b_7b": (6.0e9, 8.0e9),
        "qwen3_moe_235b_a22b": (200e9, 260e9),
    }
    for arch, (lo, hi) in expect.items():
        n = configs.get_config(arch).n_params()
        assert lo <= n <= hi, (arch, n / 1e9)
    # MoE active < total
    c = configs.get_config("qwen3_moe_235b_a22b")
    assert c.n_active_params() < 0.2 * c.n_params()
