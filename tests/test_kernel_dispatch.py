"""Backend dispatch: fused Pallas quantize→pack / unpack→dequantize kernels
vs the jnp reference in core.quant — bit-exact wire bytes — plus the
code-form (rowquant) serve path."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.core.quant import QuantConfig, dequantize, quantize
from repro.kernels import ops, ref

KEY = jax.random.PRNGKey(0)


# ---------------------------------------------------------------------------
# Fused kernels vs jnp reference: identical wire bytes
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("mode", ["nearest", "stochastic", "shift"])
@pytest.mark.parametrize("bits", [2, 4, 8])
def test_fused_quantize_pack_bit_exact(bits, mode):
    """pallas-interpret and jnp produce byte-identical (codes, scale, zero)
    for every packed width and every rounding mode."""
    cfg = dict(bits=bits, bucket_size=256, mode=mode)
    x = jax.random.normal(KEY, (3000,)) * 2.0
    k = jax.random.PRNGKey(3)
    qj = quantize(x, QuantConfig(**cfg, backend="jnp"), k)
    qp = quantize(x, QuantConfig(**cfg, backend="pallas"), k)
    np.testing.assert_array_equal(np.asarray(qj.codes), np.asarray(qp.codes))
    np.testing.assert_array_equal(np.asarray(qj.scale), np.asarray(qp.scale))
    np.testing.assert_array_equal(np.asarray(qj.zero), np.asarray(qp.zero))


@pytest.mark.parametrize("rand_bits", [16, 32])
def test_fused_quantize_pack_stochastic_rand_bits(rand_bits):
    """Both stochastic-rounding threshold widths draw the same PRNG stream
    in both backends."""
    x = jax.random.normal(KEY, (2048,))
    k = jax.random.PRNGKey(5)
    mk = lambda b: QuantConfig(bits=4, bucket_size=512, mode="stochastic",
                               rand_bits=rand_bits, backend=b)
    qj, qp = quantize(x, mk("jnp"), k), quantize(x, mk("pallas"), k)
    np.testing.assert_array_equal(np.asarray(qj.codes), np.asarray(qp.codes))


@pytest.mark.parametrize("bits", [2, 3, 4, 8])
def test_fused_unpack_dequantize_bit_exact(bits):
    """Under jit (the production context — every step is a jitted shard_map)
    the fused unpack→dequantize kernel matches the jnp decode bitwise.
    Eager jnp differs by <=1 ULP only through XLA's FMA fusion."""
    cfg = QuantConfig(bits=bits, bucket_size=256, mode="shift")
    x = jax.random.normal(KEY, (3000,)) * 1.7
    q = quantize(x, cfg, jax.random.PRNGKey(1), backend="jnp")
    dj = jax.jit(lambda q: dequantize(q, backend="jnp"))(q)
    dp = dequantize(q, backend="pallas")
    np.testing.assert_array_equal(np.asarray(dj), np.asarray(dp))
    d_eager = dequantize(q, backend="jnp")
    np.testing.assert_allclose(np.asarray(d_eager), np.asarray(dp),
                               rtol=1e-6, atol=1e-6)


def test_dispatch_identical_wire_bytes_end_to_end():
    """The satellite acceptance check: core.quant produces identical wire
    bytes whichever backend is selected, including shapes after padding."""
    for n in (100, 1024, 4097):
        for bits in (2, 4, 8):
            cfg = dict(bits=bits, bucket_size=1024, mode="shift")
            x = jax.random.normal(jax.random.PRNGKey(n), (n,))
            k = jax.random.PRNGKey(9)
            qj = quantize(x, QuantConfig(**cfg, backend="jnp"), k)
            qp = quantize(x, QuantConfig(**cfg, backend="pallas"), k)
            assert qj.codes.shape == qp.codes.shape
            assert qj.wire_bytes == qp.wire_bytes
            np.testing.assert_array_equal(np.asarray(qj.codes), np.asarray(qp.codes))
            np.testing.assert_array_equal(np.asarray(qj.scale), np.asarray(qp.scale))
            np.testing.assert_array_equal(np.asarray(qj.zero), np.asarray(qp.zero))


def test_resolve_backend_env(monkeypatch):
    monkeypatch.delenv("REPRO_QUANT_BACKEND", raising=False)
    monkeypatch.delenv("REPRO_PALLAS_INTERPRET", raising=False)
    # auto on CPU -> jnp; forcing interpret opts into the kernels
    assert ops.resolve_backend() in ("jnp", "pallas")
    if jax.default_backend() != "tpu":
        assert ops.resolve_backend() == "jnp"
        monkeypatch.setenv("REPRO_PALLAS_INTERPRET", "1")
        assert ops.resolve_backend() == "pallas"
    monkeypatch.setenv("REPRO_QUANT_BACKEND", "jnp")
    assert ops.resolve_backend() == "jnp"
    # a cfg-level "auto" (the QuantConfig default) must defer to the env,
    # so the documented env knob works through core.quant.quantize
    assert ops.resolve_backend("auto") == "jnp"
    monkeypatch.setenv("REPRO_QUANT_BACKEND", "pallas")
    assert ops.resolve_backend("auto") == "pallas"
    assert ops.resolve_backend("jnp") == "jnp"  # per-call override wins
    monkeypatch.setenv("REPRO_QUANT_BACKEND", "bogus")
    with pytest.raises(AssertionError):
        ops.resolve_backend()


def test_quantized_collectives_backend_agnostic():
    """all_gather / reduce-scatter wire payloads are backend-independent
    (quantize is vmapped over per-peer chunks inside the collectives)."""
    from repro.core import collectives as coll
    from repro.compat import shard_map

    mesh = jax.make_mesh((1,), ("data",))
    x = jax.random.normal(KEY, (2048,))
    outs = {}
    for b in ("jnp", "pallas"):
        cfg = QuantConfig(bits=4, bucket_size=512, mode="stochastic", backend=b)

        def f(x):
            g = coll.all_gather_quantized(x, ("data",), cfg, jax.random.PRNGKey(2))
            r = coll.reduce_scatter_quantized(x, ("data",), cfg, jax.random.PRNGKey(3))
            return g, r

        outs[b] = shard_map(f, mesh=mesh, in_specs=P("data"),
                            out_specs=(P("data"), P("data")), check_vma=False)(x)
    for a, b in zip(jax.tree.leaves(outs["jnp"]), jax.tree.leaves(outs["pallas"])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# Segment-affine rowquant matmul (wire-code consumption)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n_seg", [1, 2, 4])
def test_rowquant_matmul_segment_affine(n_seg):
    k, n = 128, 512
    w = jax.random.normal(KEY, (k, n))
    codes = jax.random.randint(jax.random.PRNGKey(1), (k, n), 0, 256).astype(jnp.uint8)
    scale = jax.random.uniform(jax.random.PRNGKey(2), (k, n_seg)) * 0.1 + 0.01
    zero = jax.random.normal(jax.random.PRNGKey(3), (k, n_seg)) * 0.1
    x = jax.random.normal(jax.random.PRNGKey(4), (32, k))
    y = ops.rowquant_matmul(x, codes, scale, zero, block_n=128)
    y_ref = ref.rowquant_matmul_ref(x, codes, scale, zero)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), rtol=2e-4, atol=1e-2)


# ---------------------------------------------------------------------------
# Serve path: gathered weights stay in code form through the matmul
# ---------------------------------------------------------------------------


def _tiny_dense_model():
    from repro.core.qsdp import MeshSpec, QSDPConfig
    from repro.models.config import ModelConfig
    from repro.models.transformer import Model

    ms = MeshSpec(axes=("data", "model"), shape=(1, 1))
    qs = QSDPConfig(min_quant_size=256, bucket_size=128)
    cfg = ModelConfig(name="tiny_rowquant", arch_type="dense", n_layers=2,
                      d_model=128, vocab_size=512, n_heads=8, n_kv_heads=4,
                      head_dim=16, d_ff=256)
    return Model(cfg, ms, qs)


def test_gather_rowquant_eligibility():
    model = _tiny_dense_model()
    eng = model.engine
    # MLP weights: 2D, rows a multiple of the bucket -> code form
    assert eng.rowquant_eligible("layers/w_gate")
    assert eng.rowquant_eligible("layers/w_down")
    # norms are excluded from quantization entirely
    assert not eng.rowquant_eligible("layers/attn_norm")


def test_gather_rowquant_matches_dense_gather():
    """dequant(RowQuantWeight) == the dense gather's weight (same codes)."""
    from repro.compat import shard_map
    from repro.kernels.ops import RowQuantWeight

    model = _tiny_dense_model()
    eng = model.engine
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    params = model.init_params(jax.random.PRNGKey(0))
    name = "layers/w_gate"
    local = params[name][0]  # layer 0 slice

    def f(local):
        k = jax.random.PRNGKey(11)
        dense = eng.gather(name, local, k)
        rq = eng.gather_rowquant(name, local, k)
        return dense, rq

    dense, rq = shard_map(
        f, mesh=mesh,
        in_specs=P("model", ("data",), None),
        out_specs=(P(), RowQuantWeight(P(), P(), P())), check_vma=False)(local)
    assert isinstance(rq, RowQuantWeight)
    n_seg = rq.scale.shape[1]
    seg = rq.codes.shape[1] // n_seg
    w = (rq.codes.astype(jnp.float32)
         * jnp.repeat(rq.scale, seg, axis=1) + jnp.repeat(rq.zero, seg, axis=1))
    np.testing.assert_allclose(np.asarray(w, np.float32),
                               np.asarray(dense, np.float32),
                               rtol=1e-2, atol=1e-2)  # dense is bf16


@pytest.mark.parametrize("arch", ["seamless_m4t_large_v2", "zamba2_7b"])
def test_serve_rowquant_decode_audio_hybrid(arch):
    """The audio decoder and the hybrid shared-attention stack also route
    their MLP gathers through the code-form path."""
    from repro import configs
    from repro.core.qsdp import MeshSpec, QSDPConfig
    from repro.models.decode import DecodeSpec
    from repro.models.transformer import Model
    from repro.serve.engine import ServeEngine

    cfg = configs.get_smoke(arch)
    model = Model(cfg, MeshSpec(axes=("data", "model"), shape=(1, 1)),
                  QSDPConfig(min_quant_size=256, bucket_size=128))
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    params = model.init_params(jax.random.PRNGKey(0))
    B, S = 2, 16
    batch = {"tokens": jnp.ones((B, S), jnp.int32)}
    bspecs = {"tokens": P(("data",))}
    enc_len = 0
    if cfg.arch_type == "audio":
        enc_len = S // cfg.enc_frames_ratio
        batch["audio_embeds"] = 0.1 * jax.random.normal(
            jax.random.PRNGKey(5), (B, enc_len, cfg.d_model))
        bspecs["audio_embeds"] = P(("data",))
    prefix = "dec/" if cfg.arch_type == "audio" else "shared/"
    assert model.engine.rowquant_eligible(prefix + "w_gate")
    spec = DecodeSpec(cache_len=64, batch_global=B, batch_sharded=False,
                      enc_len=enc_len)
    dense = ServeEngine(model, mesh, spec).generate(params, batch, bspecs, 5)
    rq = ServeEngine(
        model, mesh, dataclasses.replace(spec, rowquant_mlp=True)
    ).generate(params, batch, bspecs, 5)
    np.testing.assert_array_equal(np.asarray(dense), np.asarray(rq))


@pytest.mark.parametrize("backend", ["jnp", "pallas"])
def test_serve_rowquant_decode_matches_dense(backend, monkeypatch):
    """End-to-end: greedy decode through the code-form MLP path produces the
    same tokens as the dense-dequant path, with both matmul backends."""
    monkeypatch.setenv("REPRO_QUANT_BACKEND", backend)
    from repro.models.decode import DecodeSpec
    from repro.serve.engine import ServeEngine

    model = _tiny_dense_model()
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    params = model.init_params(jax.random.PRNGKey(0))
    batch = {"tokens": jnp.ones((2, 16), jnp.int32)}
    bspecs = {"tokens": P(("data",))}
    spec = DecodeSpec(cache_len=64, batch_global=2, batch_sharded=False)
    out = ServeEngine(model, mesh, spec).generate(params, batch, bspecs, 6)
    out_rq = ServeEngine(
        model, mesh, dataclasses.replace(spec, rowquant_mlp=True)
    ).generate(params, batch, bspecs, 6)
    assert out.shape == out_rq.shape == (2, 6)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(out_rq))
