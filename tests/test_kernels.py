"""Pallas kernels vs pure-jnp oracles (interpret mode on CPU): shape/dtype
sweeps + property tests, per the kernel contract in kernels/EXAMPLE.md."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels import ops, ref

KEY = jax.random.PRNGKey(0)


@pytest.mark.parametrize("nb", [1, 7, 8, 17])
@pytest.mark.parametrize("bucket", [256, 1024])
@pytest.mark.parametrize("stochastic", [True, False])
def test_quantize_kernel_matches_ref(nb, bucket, stochastic):
    x = jax.random.normal(KEY, (nb, bucket)) * 2.0
    rand = jax.random.uniform(jax.random.PRNGKey(1), x.shape)
    c, s, z = ops.quantize_buckets(x, rand, 255, stochastic)
    c2, s2, z2 = ref.quantize_ref(x, rand, 255, stochastic)
    # codes: exact up to 1-ULP reduction-order ties at rounding boundaries
    # (kernel reduces min/max over an (8, bucket) VMEM tile; XLA's tree
    # differs) — require <=1 level difference and >=99.9% exact.
    ca, cb = np.asarray(c, np.int32), np.asarray(c2, np.int32)
    assert np.max(np.abs(ca - cb)) <= 1
    assert np.mean(ca == cb) >= 0.999
    np.testing.assert_allclose(s, s2, rtol=1e-6)
    np.testing.assert_allclose(z, z2, rtol=1e-6)


@pytest.mark.parametrize("levels", [3, 15, 63, 255])
def test_quantize_kernel_levels_sweep(levels):
    x = jax.random.normal(KEY, (4, 512))
    rand = jax.random.uniform(jax.random.PRNGKey(2), x.shape)
    c, s, z = ops.quantize_buckets(x, rand, levels, True)
    c2, s2, z2 = ref.quantize_ref(x, rand, levels, True)
    ca, cb = np.asarray(c, np.int32), np.asarray(c2, np.int32)
    assert np.max(np.abs(ca - cb)) <= 1 and np.mean(ca == cb) >= 0.999
    assert int(jnp.max(c)) <= levels


@pytest.mark.parametrize("nb", [1, 5, 16])
def test_dequantize_kernel_matches_ref(nb):
    codes = jax.random.randint(KEY, (nb, 512), 0, 256).astype(jnp.uint8)
    scale = jax.random.uniform(jax.random.PRNGKey(3), (nb, 1)) + 0.01
    zero = jax.random.normal(jax.random.PRNGKey(4), (nb, 1))
    out = ops.dequantize_buckets(codes, scale, zero)
    np.testing.assert_allclose(out, ref.dequantize_ref(codes, scale, zero),
                               rtol=1e-5, atol=1e-6)  # fma reassociation


def test_quant_dequant_kernel_roundtrip():
    x = jax.random.normal(KEY, (8, 1024))
    rand = jax.random.uniform(jax.random.PRNGKey(5), x.shape)
    c, s, z = ops.quantize_buckets(x, rand, 255, False)
    y = ops.dequantize_buckets(c, s, z)
    assert float(jnp.max(jnp.abs(y - x))) <= 0.5 * float(jnp.max(s)) + 1e-6


@pytest.mark.parametrize("m,k,n", [(8, 64, 32), (64, 256, 192), (128, 512, 256),
                                   (33, 100, 77)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rowquant_matmul_shape_dtype_sweep(m, k, n, dtype):
    w = jax.random.normal(KEY, (k, n))
    codes, scale, zero = ref.quantize_rowwise_ref(w, 255)
    x = jax.random.normal(jax.random.PRNGKey(6), (m, k)).astype(dtype)
    y = ops.rowquant_matmul(x, codes, scale, zero)
    y_ref = ref.rowquant_matmul_ref(x, codes, scale, zero)
    assert y.dtype == dtype
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-4
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(y_ref, np.float32),
                               rtol=tol, atol=tol * k)


@pytest.mark.parametrize("blocks", [(32, 64, 64), (128, 256, 512)])
def test_rowquant_matmul_block_shapes(blocks):
    bm, bn, bk = blocks
    w = jax.random.normal(KEY, (512, 256))
    codes, scale, zero = ref.quantize_rowwise_ref(w, 255)
    x = jax.random.normal(jax.random.PRNGKey(7), (64, 512))
    y = ops.rowquant_matmul(x, codes, scale, zero, block_m=bm, block_n=bn, block_k=bk)
    y_ref = ref.rowquant_matmul_ref(x, codes, scale, zero)
    np.testing.assert_allclose(y, y_ref, rtol=2e-4, atol=1e-2)


@given(m=st.integers(1, 40), k=st.integers(8, 128), n=st.integers(1, 48))
@settings(max_examples=15, deadline=None)
def test_rowquant_matmul_property_any_shape(m, k, n):
    w = jax.random.normal(jax.random.PRNGKey(k), (k, n))
    codes, scale, zero = ref.quantize_rowwise_ref(w, 255)
    x = jax.random.normal(jax.random.PRNGKey(m), (m, k))
    y = ops.rowquant_matmul(x, codes, scale, zero, block_m=32, block_n=32, block_k=64)
    np.testing.assert_allclose(y, ref.rowquant_matmul_ref(x, codes, scale, zero),
                               rtol=1e-3, atol=1e-2)


def test_rowquant_matmul_is_close_to_unquantized():
    """The fused kernel on 8-bit codes approximates the f32 matmul."""
    w = jax.random.normal(KEY, (256, 128)) * 0.05
    x = jax.random.normal(jax.random.PRNGKey(8), (32, 256))
    codes, scale, zero = ops.quantize_weight_rowwise(w, bits=8)
    y = ops.rowquant_matmul(x, codes, scale, zero)
    rel = float(jnp.linalg.norm(y - x @ w) / jnp.linalg.norm(x @ w))
    assert rel < 2e-2, rel
