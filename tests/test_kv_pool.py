"""Property suite for the paged KV block pool (serve.kv_pool): random
alloc/free/incref/register op sequences never double-free or leak a block,
chained prefix keys never alias distinct prefixes, copy-on-write preserves
every other reader's reference, and the quantized cold tier's
encode_block/decode_block round-trip matches the core.quant
quantize_dequantize reference bit-for-bit.

Runs with real `hypothesis` when installed, or with the deterministic
seeded-sweep stub in tests/_hypothesis_stub.py (installed by conftest.py).
"""
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

import jax.numpy as jnp

from repro.serve.kv_pool import (
    BlockPool,
    PoolExhausted,
    block_qdq_reference,
    decode_block,
    encode_block,
    kv_quant_config,
    prefix_keys,
)


# ---------------------------------------------------------------------------
# alloc / free / refcount: no double-free, no leak, exact conservation
# ---------------------------------------------------------------------------


@settings(max_examples=40, deadline=None)
@given(seed=st.integers(0, 10_000), n_blocks=st.integers(2, 12),
       n_ops=st.integers(1, 120))
def test_pool_never_leaks_or_double_frees(seed, n_blocks, n_ops):
    rng = np.random.default_rng(seed)
    pool = BlockPool(n_blocks, 8)
    held = []  # one entry per live reference we hold
    registered = 0
    for step in range(n_ops):
        op = int(rng.integers(0, 4))
        if op == 0:
            try:
                held.append(pool.alloc(step))
            except PoolExhausted:
                assert pool.free_blocks == 0
        elif op == 1 and held:
            pool.decref(held.pop(int(rng.integers(len(held)))), step)
        elif op == 2 and held:
            bid = held[int(rng.integers(len(held)))]
            pool.incref(bid)
            held.append(bid)
        elif op == 3 and held:
            pool.register(("k", registered),
                          held[int(rng.integers(len(held)))])
            registered += 1
        pool.check_invariants()
        # conservation: every block is exactly one of free / cached / live
        assert (len(pool._free) + pool.blocks_cached + pool.blocks_in_use
                == n_blocks)
        # the pool's refcounts mirror our reference model exactly
        assert sorted(set(held)) == [int(b) for b in
                                     np.nonzero(pool._ref > 0)[0]]
        for bid in set(held):
            assert pool.ref(bid) == held.count(bid)
    # drain: every held reference releases cleanly, nothing leaks
    for bid in list(held):
        pool.decref(bid, n_ops)
    pool.check_invariants()
    assert pool.free_blocks == n_blocks
    if held:  # one decref past zero is a double free and must raise
        with pytest.raises(RuntimeError, match="double free"):
            pool.decref(held[0], n_ops)


def test_alloc_exhaustion_raises():
    pool = BlockPool(1, 4)
    pool.alloc()
    with pytest.raises(PoolExhausted):
        pool.alloc()


def test_alloc_evicts_lru_cached():
    pool = BlockPool(2, 4)
    a = pool.alloc(0)
    pool.register(("a",), a)
    pool.decref(a, 0)
    b = pool.alloc(1)
    pool.register(("b",), b)
    pool.decref(b, 1)
    # both retired into deferred reclaim; a new alloc evicts the LRU first
    assert pool.alloc(2) == a
    assert pool.lookup(("a",)) is None  # evicted key no longer resolves
    assert pool.lookup(("b",), 2) == b  # MRU survives and re-pins
    pool.check_invariants()


# ---------------------------------------------------------------------------
# prefix keys: chained structural keys are alias-free by construction
# ---------------------------------------------------------------------------


@settings(max_examples=60, deadline=None)
@given(seed=st.integers(0, 10_000), bs=st.sampled_from([2, 4, 8]),
       n=st.integers(1, 6))
def test_prefix_keys_never_alias(seed, bs, n):
    rng = np.random.default_rng(seed)
    # tiny vocab on purpose: per-block token collisions are common, so a
    # digest-style key WOULD alias here — chained keys must not
    a = rng.integers(0, 4, size=n * bs).tolist()
    b = rng.integers(0, 4, size=n * bs).tolist()
    ka, kb = prefix_keys(a, bs), prefix_keys(b, bs)
    assert len(ka) == len(kb) == n
    for j in range(n):
        assert (ka[j] == kb[j]) == (a[:(j + 1) * bs] == b[:(j + 1) * bs])
    # keys within one prompt are all distinct (chain depth differs)
    assert len(set(ka)) == n
    # a partial trailing block never gets a key
    assert len(prefix_keys(a + [1], bs)) == n


# ---------------------------------------------------------------------------
# copy-on-write: the fork moves ONLY the writer's reference
# ---------------------------------------------------------------------------


@settings(max_examples=40, deadline=None)
@given(readers=st.integers(1, 5))
def test_cow_preserves_shared_refs(readers):
    pool = BlockPool(8, 4)
    bid = pool.alloc()
    pool.register(("sys",), bid)
    for _ in range(readers):
        assert pool.lookup(("sys",)) == bid
    new = pool.cow_fork(bid)  # the original writer goes private
    assert new != bid
    assert pool.ref(bid) == readers  # every reader's reference intact
    assert pool.ref(new) == 1
    assert pool.lookup(("sys",)) == bid  # registry still serves the shared id
    assert pool.stats["cow_forks"] == 1
    pool.decref(bid)
    pool.check_invariants()


# ---------------------------------------------------------------------------
# quantized cold tier: wire round-trip == quantize_dequantize, bit-exact
# ---------------------------------------------------------------------------


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 10_000), bits=st.sampled_from([2, 4, 8]),
       bucket=st.sampled_from([32, 128]), bs=st.sampled_from([4, 8]),
       nl=st.integers(1, 3))
def test_cold_tier_roundtrip_bit_exact(seed, bits, bucket, bs, nl):
    rng = np.random.default_rng(seed)
    cfg = kv_quant_config(bits, bucket)
    shape = (nl, bs, 2, 16)
    k = (rng.standard_normal(shape) * 3).astype(np.float32)
    v = rng.standard_normal(shape).astype(np.float32)
    cold = encode_block(k, v, cfg)
    kd, vd = decode_block(cold, dtype=jnp.float32)
    assert np.array_equal(np.asarray(kd), block_qdq_reference(k, cfg))
    assert np.array_equal(np.asarray(vd), block_qdq_reference(v, cfg))
    # and encoding is deterministic: same bytes every time (nearest mode)
    again = encode_block(k, v, cfg)
    assert np.array_equal(cold.k_wire, again.k_wire)
    assert np.array_equal(cold.v_wire, again.v_wire)


@settings(max_examples=30, deadline=None)
@given(horizon=st.integers(1, 4), idle=st.integers(0, 8))
def test_demote_rehydrate_state_machine(horizon, idle):
    pool = BlockPool(4, 4, quant_bits=4, quant_horizon=horizon,
                     hot_block_bytes=1024)
    bid = pool.alloc(0)
    pool.register(("p",), bid)
    pool.decref(bid, 0)  # retire into deferred reclaim
    assert pool.blocks_cached == 1
    dem = pool.demotable(idle)
    assert (bid in dem) == (idle >= horizon)
    if dem:
        cold = encode_block(np.ones((1, 4, 1, 8), np.float32),
                            np.ones((1, 4, 1, 8), np.float32), pool.quant_cfg)
        pool.demote(bid, cold, idle)
        pool.check_invariants()
        assert pool.cold_blocks == 1 and pool.blocks_cached == 0
        assert pool.lookup(("p",)) is None  # cold never hits the hot path
        assert pool.lookup_cold(("p",)) is cold
        nbid, got = pool.rehydrate(("p",), idle + 1)
        assert got is cold and pool.cold_blocks == 0
        assert pool.ref(nbid) == 1 and pool.is_registered(nbid)
        pool.check_invariants()
        pool.decref(nbid, idle + 1)
    pool.check_invariants()
