"""Parse-time flag validation in both launchers: inconsistent combos die
with a one-line argparse error (exit code 2) instead of an unreadable
tracing failure minutes later, and every valid combo still parses."""
import pytest

from repro.launch import serve, train
from repro.tune.plan import PLAN_VERSION, DeploymentPlan


@pytest.fixture(scope="module")
def plan_path(tmp_path_factory):
    p = tmp_path_factory.mktemp("plan") / "plan.json"
    DeploymentPlan(
        version=PLAN_VERSION, arch="t", mesh_axes=("data", "model"),
        mesh_shape=(1, 1), hw="cpu-smoke",
        qsdp={"coalesce": True, "coalesce_max_bytes": 0},
        serve={"slots": 4, "prefill_chunk": 0, "prefill_buckets": 2,
               "draft_bits": 0, "draft_depth": 0},
    ).save(str(p))
    return str(p)


# ---------------------------------------------------------------------------
# train
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("argv", [
    ["--prefetch", "--no-coalesce"],
    ["--wbits", "1"],
    ["--wbits", "9"],
    ["--gbits", "0"],
    ["--master-bits", "12"],
    ["--moment-bits", "11"],
    ["--bucket", "0"],
    ["--coalesce-max-bytes", "-1"],
    ["--data-par", "0"],
    ["--model-par", "0"],
    ["--quantize-master", "--quantized-state"],
], ids=lambda a: " ".join(a))
def test_train_rejects(argv, capsys):
    with pytest.raises(SystemExit) as e:
        train.parse_args(argv)
    assert e.value.code == 2
    assert "error" in capsys.readouterr().err


@pytest.mark.parametrize("extra", [
    ["--prefetch"], ["--baseline"], ["--hierarchical"],
    ["--no-coalesce"], ["--coalesce-max-bytes", "0"],
], ids=lambda a: " ".join(a))
def test_train_rejects_plan_plus_policy_flags(plan_path, extra, capsys):
    with pytest.raises(SystemExit) as e:
        train.parse_args(["--plan", plan_path] + extra)
    assert e.value.code == 2
    assert "--plan pins the comm policy" in capsys.readouterr().err


@pytest.mark.parametrize("argv", [
    [],
    ["--prefetch"],                      # coalesce defaults on
    ["--coalesce-max-bytes", "0"],
    ["--wbits", "2", "--gbits", "8", "--moment-bits", "8"],
    ["--quantized-state", "--master-bits", "4"],
], ids=lambda a: " ".join(a) or "<defaults>")
def test_train_accepts(argv):
    args = train.parse_args(argv)
    assert args.data_par >= 1


def test_train_accepts_plan_flag(plan_path):
    args = train.parse_args(["--plan", plan_path])
    assert args.plan == plan_path
    qsdp = train.build_qsdp(args)
    assert qsdp.coalesce and qsdp.coalesce_max_bytes == 0


def test_train_missing_plan_file_is_clean_error(tmp_path):
    args = train.parse_args(["--plan", str(tmp_path / "nope.json")])
    with pytest.raises(SystemExit) as e:
        train.build_qsdp(args)
    assert "nope.json" in str(e.value)


# ---------------------------------------------------------------------------
# serve
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("argv", [
    ["--wbits", "1"],
    ["--wbits", "9"],
    ["--draft-bits", "1", "--draft-depth", "4", "--continuous"],
    ["--draft-bits", "9", "--draft-depth", "4", "--continuous"],
    ["--draft-bits", "4", "--continuous"],            # missing depth
    ["--draft-depth", "4", "--continuous"],           # missing bits
    ["--draft-bits", "4", "--draft-depth", "4"],      # missing --continuous
    ["--kv-block-size", "16"],                        # without prefill chunk
    ["--kv-quant-bits", "8", "--prefill-chunk", "8"],  # without block size
    ["--kv-quant-bits", "1", "--prefill-chunk", "8", "--kv-block-size", "8"],
    ["--prefill-buckets", "0"],
    ["--prefill-chunk", "-1"],
    ["--prefill-interleave", "0"],
], ids=lambda a: " ".join(a))
def test_serve_rejects(argv, capsys):
    with pytest.raises(SystemExit) as e:
        serve.parse_args(argv)
    assert e.value.code == 2
    assert "error" in capsys.readouterr().err


def test_serve_rejects_plan_plus_baseline(plan_path, capsys):
    with pytest.raises(SystemExit) as e:
        serve.parse_args(["--plan", plan_path, "--baseline"])
    assert e.value.code == 2


def test_serve_rejects_missing_plan_file(tmp_path, capsys):
    with pytest.raises(SystemExit) as e:
        serve.parse_args(["--plan", str(tmp_path / "nope.json")])
    assert e.value.code == 2


@pytest.mark.parametrize("argv", [
    [],
    ["--continuous", "--prefill-chunk", "16"],
    ["--continuous", "--prefill-chunk", "16", "--kv-block-size", "8",
     "--kv-quant-bits", "4"],
    ["--continuous", "--draft-bits", "4", "--draft-depth", "4"],
], ids=lambda a: " ".join(a) or "<defaults>")
def test_serve_accepts(argv):
    args = serve.parse_args(argv)
    assert args.plan_obj is None


def test_serve_plan_sets_defaults_but_flags_win(plan_path):
    # plan's serve knobs become the defaults
    args = serve.parse_args(["--plan", plan_path])
    assert args.plan_obj is not None
    assert args.batch == 4 and args.prefill_buckets == 2
    # an explicitly typed flag still overrides the plan's knob
    args = serve.parse_args(["--plan", plan_path, "--batch", "16"])
    assert args.batch == 16 and args.prefill_buckets == 2
