"""Learned quantization levels (paper Section 5.2 / Algorithm 2)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core.levels import (
    LevelsConfig, compression_error, dequantize_levels,
    learn_levels_for_tensor, learn_levels_minibatch, learn_levels_sequential,
    quantize_levels, uniform_levels,
)
from repro.core.quant import QuantConfig, quantize_dequantize

KEY = jax.random.PRNGKey(0)


def _skewed(n=8192):
    """Heavy-tailed values where a uniform grid wastes levels (the paper's
    motivation for learned levels)."""
    g = jax.random.normal(KEY, (n,))
    return jnp.sign(g) * jnp.abs(g) ** 3


def test_sequential_rule_matches_paper_update():
    """One value pulls its nearest level by lr*(q - v) (Figure 2, line 6)."""
    levels = jnp.array([0.0, 1.0])
    out = learn_levels_sequential(jnp.array([0.8]), levels, lr=0.1)
    np.testing.assert_allclose(out, [0.0, 1.0 - 0.1 * (1.0 - 0.8)], atol=1e-7)


def test_minibatch_matches_sequential_single_level():
    """Closed-form batch rate equals the sequential loop when all values in
    the batch share a level."""
    levels = jnp.array([0.0, 10.0])
    vals = jnp.full((16,), 0.5)
    seq = learn_levels_sequential(vals, levels, lr=0.05)
    mb = learn_levels_minibatch(vals, levels, lr=0.05, batch_size=16)
    np.testing.assert_allclose(seq, mb, rtol=1e-5)


def test_learned_levels_reduce_error_low_bits():
    """Paper Tables 3/6 + Figures 7/8: learned levels beat the uniform grid
    at <=4 bits on non-uniform data."""
    x = _skewed()
    cfg = LevelsConfig(bits=4, bucket_size=1024, epochs=2)
    levels = learn_levels_for_tensor(x, cfg)
    q_learned = quantize_levels(x, levels, bucket_size=1024)
    err_learned = float(compression_error(x, dequantize_levels(q_learned, levels)))
    q_uniform = quantize_levels(x, uniform_levels(4), bucket_size=1024)
    err_uniform = float(compression_error(x, dequantize_levels(q_uniform, uniform_levels(4))))
    assert err_learned < err_uniform, (err_learned, err_uniform)


def test_learned_no_worse_at_high_bits():
    """Paper: 'no effect for bit-widths higher than 6 bits'."""
    x = _skewed()
    cfg = LevelsConfig(bits=8, bucket_size=1024)
    levels = learn_levels_for_tensor(x, cfg)
    ql = quantize_levels(x, levels)
    qu = quantize_levels(x, uniform_levels(8))
    el = float(compression_error(x, dequantize_levels(ql, levels)))
    eu = float(compression_error(x, dequantize_levels(qu, uniform_levels(8))))
    assert el < eu * 1.25  # parity or better


def test_levels_roundtrip_and_wire_format():
    x = jax.random.normal(KEY, (3000,))
    levels = uniform_levels(4)
    q = quantize_levels(x, levels, bucket_size=512)
    y = dequantize_levels(q, levels)
    assert y.shape == x.shape
    # uniform table reproduces the plain nearest wire quantizer
    cfg = QuantConfig(bits=4, bucket_size=512, mode="nearest")
    np.testing.assert_allclose(y, quantize_dequantize(x, cfg), atol=1e-6)


def test_stochastic_levels_unbiased_within_hull():
    x = jnp.linspace(0.05, 0.95, 64)  # already in [0,1]
    levels = jnp.sort(jax.random.uniform(KEY, (8,)))
    levels = jnp.concatenate([jnp.zeros(1), levels[1:-1], jnp.ones(1)])
    keys = jax.random.split(KEY, 3000)

    def one(k):
        q = quantize_levels(x, levels, bucket_size=64, key=k)
        return dequantize_levels(q, levels)

    ys = jax.vmap(one)(keys)
    # bucket min-max normalization is affine; unbiasedness holds within it
    np.testing.assert_allclose(jnp.mean(ys, axis=0), x, atol=2e-2)
