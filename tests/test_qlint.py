"""Regression tests for the repro.analysis qlint subsystem.

Each pass must (a) fire on a seeded violation and (b) stay quiet on the
equivalent clean program; the repo at HEAD must be clean modulo the
checked-in qlint_baseline.json.  The seeded programs here are the
acceptance set: an injected key collision, a redundant quantize round-trip,
a u8 wire buffer widened before its collective, a cost-model count
mismatch, and a host sync in the scheduler loop.
"""
import json
import os
import subprocess
import sys
import textwrap
from functools import partial
from pathlib import Path

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.analysis import key_audit, source_lint
from repro.analysis.collective_audit import (diff_gather_counts,
                                             diff_wire_bytes)
from repro.analysis.findings import load_baseline, partition_findings
from repro.analysis.jaxpr_audit import audit_jaxpr
from repro.analysis.key_audit import MASTER_SALT, KeyUse, check_key_uses
from repro.compat import shard_map
from repro.core.quant import QuantConfig, dequantize, quantize

ROOT = Path(__file__).resolve().parents[1]
CFG = QuantConfig(bits=8, bucket_size=64, mode="nearest")


def _rules(findings):
    return {f.rule for f in findings}


# ---------------------------------------------------------------------------
# source lint (QS4xx)
# ---------------------------------------------------------------------------


def _seed_tree(tmp_path):
    files = {
        "serve/scheduler.py": """\
            import jax

            class ContinuousScheduler:
                def __init__(self):
                    self.n = jax.device_get(0)  # exempt: setup, not the loop

                def step(self, tokens):
                    done = jax.device_get(tokens)
                    return float(tokens.item())
            """,
        "core/lib.py": """\
            import jax

            def default_key():
                return jax.random.PRNGKey(0)
            """,
        "train/bad_import.py": """\
            from repro.kernels.quantize import quantize_kernel
            """,
    }
    for rel, body in files.items():
        p = tmp_path / rel
        p.parent.mkdir(parents=True, exist_ok=True)
        p.write_text(textwrap.dedent(body))
    return tmp_path


def test_lint_fires_on_seeded_tree(tmp_path):
    findings = source_lint.lint_source(_seed_tree(tmp_path))
    assert _rules(findings) == {"QS401", "QS402", "QS403"}
    qs401 = [f for f in findings if f.rule == "QS401"]
    # device_get + .item() inside step(); the __init__ sync is exempt
    assert len(qs401) == 2
    assert all("ContinuousScheduler.step" in f.site for f in qs401)


def test_lint_head_clean_modulo_baseline():
    findings = source_lint.run()
    baseline = load_baseline(str(ROOT / "qlint_baseline.json"))
    new, suppressed, unused = partition_findings(findings, baseline)
    assert new == [], [str(f) for f in new]
    assert unused == [], unused  # every suppression still earns its keep
    assert len(suppressed) == len(baseline)


# ---------------------------------------------------------------------------
# key audit (QK2xx)
# ---------------------------------------------------------------------------


def test_key_audit_fires_on_injected_collision():
    uses = [KeyUse("loss", 7, "layers.0.wq", "scan", False),
            KeyUse("loss", 7, "layers.1.wq", "scan", False)]
    assert _rules(check_key_uses(uses)) == {"QK201"}


def test_key_audit_fires_on_hash_collision():
    uses = [KeyUse("master", 0xDEAD, "wq", "_h(name)", True),
            KeyUse("master", 0xDEAD, "wk", "_h(name)", True)]
    assert _rules(check_key_uses(uses)) == {"QK202"}


def test_key_audit_flags_reserved_salt_overlap():
    uses = [KeyUse("step", MASTER_SALT, "master-requant", "salt", False),
            KeyUse("step", MASTER_SALT, "micro[3824617]", "index", False)]
    assert "QK203" in _rules(check_key_uses(uses))


def test_key_audit_distinct_constants_clean():
    uses = [KeyUse("loss", 7, "layers.0.wq", "scan", False),
            KeyUse("loss", 8, "layers.1.wq", "scan", False)]
    assert check_key_uses(uses) == []


def test_key_audit_head_clean():
    # full param trees: the dense family plus the enc/dec audio family whose
    # shared-short-name collision this subsystem originally caught
    findings = key_audit.run(archs=["gpt-125m", "seamless-m4t-large-v2"])
    assert findings == [], [str(f) for f in findings]


# ---------------------------------------------------------------------------
# jaxpr audit (QJ1xx)
# ---------------------------------------------------------------------------


def test_jaxpr_audit_fires_on_redundant_roundtrip():
    def seeded(x):
        d = dequantize(quantize(x, CFG))
        return quantize(d.reshape(-1), CFG)  # re-quantizing decoded values

    closed = jax.make_jaxpr(seeded)(jnp.ones((256,), jnp.float32))
    findings = audit_jaxpr(closed, "seeded")
    assert "QJ101" in _rules(findings)


def test_jaxpr_audit_clean_when_values_change():
    def clean(x):
        d = dequantize(quantize(x, CFG))
        return quantize(d * 1.5 + 1.0, CFG)  # real compute between the two

    closed = jax.make_jaxpr(clean)(jnp.ones((256,), jnp.float32))
    assert audit_jaxpr(closed, "clean") == []


def test_jaxpr_audit_fires_on_u8_widening_before_collective():
    mesh = jax.make_mesh((1,), ("x",))

    @partial(shard_map, mesh=mesh, in_specs=P(), out_specs=P(),
             check_vma=False)
    def seeded(x):
        q = quantize(x, CFG)
        wide = q.codes.astype(jnp.float32)  # 4x the wire bytes
        return jax.lax.all_gather(wide, "x")

    closed = jax.make_jaxpr(seeded)(jnp.ones((256,), jnp.float32))
    findings = audit_jaxpr(closed, "seeded")
    assert "QJ102" in _rules(findings)


def test_jaxpr_audit_clean_when_gathering_u8():
    mesh = jax.make_mesh((1,), ("x",))

    @partial(shard_map, mesh=mesh, in_specs=P(), out_specs=P(),
             check_vma=False)
    def clean(x):
        q = quantize(x, CFG)
        gathered = jax.lax.all_gather(q.codes, "x")  # u8 on the wire
        return gathered.astype(jnp.float32)

    closed = jax.make_jaxpr(clean)(jnp.ones((256,), jnp.float32))
    assert audit_jaxpr(closed, "clean") == []


# ---------------------------------------------------------------------------
# collective audit (QC3xx)
# ---------------------------------------------------------------------------


def test_collective_audit_fires_on_extra_gather():
    findings = diff_gather_counts({"all-gather": 2}, 1, "t")
    assert _rules(findings) == {"QC301"}


def test_collective_audit_fires_on_unexpected_kind():
    findings = diff_gather_counts({"all-gather": 1, "all-to-all": 1}, 1, "t")
    assert _rules(findings) == {"QC301"}
    assert any("all-to-all" in f.site for f in findings)


def test_collective_audit_matching_counts_clean():
    assert diff_gather_counts({"all-gather": 1, "reduce-scatter": 2}, 1,
                              "t") == []


def test_collective_audit_wire_budget():
    assert _rules(diff_wire_bytes(2_000_000, 1_000_000, "t")) == {"QC302"}
    assert diff_wire_bytes(1_000_000, 1_000_000, "t") == []  # within slack


# ---------------------------------------------------------------------------
# CLI end-to-end
# ---------------------------------------------------------------------------


def _run_cli(args, cwd=None):
    env = {**os.environ,
           "PYTHONPATH": str(ROOT / "src") + os.pathsep
           + os.environ.get("PYTHONPATH", "")}
    return subprocess.run(
        [sys.executable, "-m", "repro.analysis.qlint", *args],
        cwd=cwd or ROOT, env=env, capture_output=True, text=True,
        timeout=300)


def test_cli_head_exits_zero_with_checked_in_baseline():
    r = _run_cli(["--passes", "lint",
                  "--baseline", str(ROOT / "qlint_baseline.json")])
    assert r.returncode == 0, r.stdout + r.stderr
    assert "new=0" in r.stdout


def test_cli_seeded_tree_exits_nonzero_then_baselines(tmp_path):
    tree = _seed_tree(tmp_path / "tree")
    baseline = tmp_path / "baseline.json"
    baseline.write_text(json.dumps({"version": 1, "suppressions": []}))

    r = _run_cli(["--passes", "lint", "--root", str(tree),
                  "--baseline", str(baseline),
                  "--report", str(tmp_path / "report.json")])
    assert r.returncode == 1, r.stdout + r.stderr
    assert "NEW QS401" in r.stdout
    report = json.loads((tmp_path / "report.json").read_text())
    assert report["ok"] is False
    assert {f["rule"] for f in report["new"]} == {"QS401", "QS402", "QS403"}

    r = _run_cli(["--passes", "lint", "--root", str(tree),
                  "--baseline", str(baseline), "--update-baseline"])
    assert r.returncode == 1  # still new THIS run; baseline now records them
    r = _run_cli(["--passes", "lint", "--root", str(tree),
                  "--baseline", str(baseline)])
    assert r.returncode == 0, r.stdout + r.stderr
