"""QSDP engine layout algebra: rest-layout round trips, comm accounting."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.qsdp import (
    MeshSpec, ParamSpec, QSDPConfig, QSDPEngine, from_rest, init_param,
    step_comm_bytes, to_rest,
)

MS = MeshSpec(axes=("data", "model"), shape=(4, 2))
MS_POD = MeshSpec(axes=("pod", "data", "model"), shape=(2, 4, 2))


def test_mesh_spec_properties():
    assert MS.fsdp_size == 4 and MS.model_size == 2
    assert MS.fsdp_axes == ("data",)
    assert MS_POD.fsdp_size == 8
    assert MS_POD.fsdp_axes == ("data", "pod")
    assert MS_POD.multi_pod


@pytest.mark.parametrize("spec", [
    ParamSpec((16, 8)),                       # replicated
    ParamSpec((16, 8), tp_axis=1),            # column-parallel
    ParamSpec((16, 8), tp_axis=0),            # row-parallel
    ParamSpec((16, 8), tp_axis=1, stack=3),   # scanned stack
    ParamSpec((10, 7), tp_axis=None, stack=2),  # padding path (70 % 4 != 0)
    ParamSpec((5,),),
])
def test_to_from_rest_roundtrip(spec):
    n = spec.logical_size
    shape = ((spec.stack,) if spec.stack else ()) + spec.shape
    full = jnp.arange(n, dtype=jnp.float32).reshape(shape)
    rest = to_rest(full, spec, MS)
    assert rest.shape == spec.rest_shape(MS)
    back = from_rest(rest, spec, MS)
    np.testing.assert_array_equal(back, full)


@given(d0=st.integers(1, 12), d1=st.integers(1, 12),
       tp=st.sampled_from([None, 0, 1]), stack=st.sampled_from([None, 2]))
@settings(max_examples=40, deadline=None)
def test_to_from_rest_property(d0, d1, tp, stack):
    if tp is not None:
        dims = [d0, d1]
        dims[tp] *= MS.model_size  # make divisible
        d0, d1 = dims
    spec = ParamSpec((d0, d1), tp_axis=tp, stack=stack)
    shape = ((stack,) if stack else ()) + (d0, d1)
    full = jnp.arange(np.prod(shape), dtype=jnp.float32).reshape(shape)
    back = from_rest(to_rest(full, spec, MS), spec, MS)
    np.testing.assert_array_equal(back, full)


def test_init_param_shapes_and_kinds():
    for kind, check in [("zeros", lambda x: np.all(x == 0)),
                        ("ones", lambda x: True),
                        ("normal", lambda x: np.std(x) > 0)]:
        spec = ParamSpec((8, 8), tp_axis=1, init=kind)
        p = init_param(jax.random.PRNGKey(0), spec, MS)
        assert p.shape == spec.rest_shape(MS)
        # ones/zeros roundtrip exactly
        if kind != "normal":
            back = from_rest(p, spec, MS)
            assert check(np.asarray(back))


def test_step_comm_bytes_formulas():
    """2 gathers + 1 reduce-scatter per param per step; quantization cuts
    weight bytes ~4x (8-bit codes + metadata vs fp32)."""
    specs = {"w": ParamSpec((1024, 1024), tp_axis=1)}
    q = QSDPEngine(MS, QSDPConfig(min_quant_size=1), specs)
    fp = QSDPEngine(MS, QSDPConfig.baseline(), specs)
    bq = step_comm_bytes(q)
    bf = step_comm_bytes(fp)
    assert bq["total"] < bf["total"]
    n_local_shard = specs["w"].n_local(MS)  # 1024*512/4
    # fp32 gather: (P-1) * n_local * 4 bytes, twice
    assert bf["weight_gather"] == 2 * 3 * n_local_shard * 4
    # grad (bf16 wire): (P-1) * (n/P) * 2
    assert bf["grad_reduce"] == 3 * n_local_shard * 2
    # quantized weights ~ 1 byte/val + bucket metadata
    assert bq["weight_gather"] < bf["weight_gather"] / 3.5
    ratio = bf["total"] / bq["total"]
    assert 2.0 < ratio < 5.0, ratio


def test_min_quant_size_filtering():
    """Small tensors (norms, biases) travel in full precision (paper §5)."""
    specs = {
        "norm": ParamSpec((64,), quantize=False),
        "small": ParamSpec((100,)),
        "big": ParamSpec((4096, 64), tp_axis=0),
    }
    eng = QSDPEngine(MS, QSDPConfig(min_quant_size=2048), specs)
    assert not eng._is_quantized(specs["norm"])
    assert not eng._is_quantized(specs["small"])
    assert eng._is_quantized(specs["big"])


def test_engine_init_and_pspecs():
    specs = {"w": ParamSpec((16, 8), tp_axis=1, stack=2), "b": ParamSpec((8,))}
    eng = QSDPEngine(MS, QSDPConfig(), specs)
    params = eng.init_params(jax.random.PRNGKey(0))
    assert set(params) == {"w", "b"}
    ps = eng.in_specs()
    assert ps["w"] == specs["w"].rest_pspec(MS)
    assert params["w"].shape == specs["w"].rest_shape(MS)
