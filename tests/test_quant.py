"""Unit + property tests for the paper's quantizers (core/quant.py)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.quant import (
    QuantConfig, Quantized, dequantize, pack_codes, q_coinflip, q_nearest,
    q_shift, quantize, quantize_dequantize, quantized_shapes, unpack_codes,
    wire_bytes,
)

KEY = jax.random.PRNGKey(0)


# ---------------------------------------------------------------------------
# Lattice quantizers (Definitions 1 and 12, Lemma 5 / Lemma 15)
# ---------------------------------------------------------------------------


def test_q_nearest_grid():
    x = jnp.array([0.2, -0.7, 1.49, 2.51])
    y = q_nearest(x, 1.0)
    np.testing.assert_allclose(y, [0.0, -1.0, 1.0, 3.0])


def test_q_shift_unbiased_dithered_variance():
    """Definition 1 (shift r re-added at decode) is unbiased with the classic
    dithered-quantization error law: err ~ Unif(-d/2, d/2], var = d^2/12 for
    EVERY x.  (The paper's Lemma-5 variance formula d^2 {x/d}(1-{x/d})
    describes the variant that does NOT re-add r — its proof drops the '+r'
    term of Definition 1.  Both variants are unbiased and both satisfy the
    Lemma 4 contraction, which test_theory checks on the actual operator.)"""
    delta = 0.25
    x = jnp.array([0.1, 0.33, -0.6, 1.01])
    keys = jax.random.split(KEY, 20000)
    ys = jax.vmap(lambda k: q_shift(x, delta, k))(keys)
    mean = jnp.mean(ys, axis=0)
    var = jnp.mean((ys - x) ** 2, axis=0)
    np.testing.assert_allclose(mean, x, atol=3e-3)
    np.testing.assert_allclose(var, jnp.full(4, delta**2 / 12), rtol=0.08)


def test_q_shift_shared_shift_dependence():
    """Definition 1: ONE shift for all coordinates -> outputs lie on a
    common shifted lattice (pairwise differences are multiples of delta)."""
    delta = 0.5
    x = jax.random.normal(KEY, (64,))
    y = q_shift(x, delta, jax.random.PRNGKey(3))
    d = (y - y[0]) / delta
    np.testing.assert_allclose(d, jnp.round(d), atol=1e-5)


def test_q_coinflip_unbiased():
    delta = 0.3
    x = jnp.array([0.07, -0.22, 0.9])
    keys = jax.random.split(KEY, 20000)
    ys = jax.vmap(lambda k: q_coinflip(x, delta, k))(keys)
    np.testing.assert_allclose(jnp.mean(ys, axis=0), x, atol=4e-3)
    # every sample is on the un-shifted lattice
    np.testing.assert_allclose(ys / delta, jnp.round(ys / delta), atol=1e-4)


# ---------------------------------------------------------------------------
# Bit packing
# ---------------------------------------------------------------------------


@given(bits=st.sampled_from([1, 2, 4, 8]), n=st.integers(1, 64))
@settings(max_examples=30, deadline=None)
def test_pack_unpack_roundtrip(bits, n):
    k = 8 // bits
    codes = np.random.default_rng(n).integers(0, 1 << bits, size=(3, n * k)).astype(np.uint8)
    packed = pack_codes(jnp.asarray(codes), bits)
    assert packed.shape == (3, n)
    out = unpack_codes(packed, bits)
    np.testing.assert_array_equal(np.asarray(out), codes)


def test_pack_passthrough_odd_bits():
    codes = jnp.arange(8, dtype=jnp.uint8)[None]
    for bits in (3, 5, 6, 7):
        assert pack_codes(codes, bits) is codes


# ---------------------------------------------------------------------------
# Wire quantizer (Section 5: bucketed min-max)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("mode", ["shift", "stochastic", "nearest"])
@pytest.mark.parametrize("bits", [2, 4, 8])
def test_wire_roundtrip_error_bound(mode, bits):
    cfg = QuantConfig(bits=bits, bucket_size=256, mode=mode)
    x = jax.random.normal(KEY, (1000,)) * 3.0
    xq = quantize_dequantize(x, cfg, jax.random.PRNGKey(1))
    # per-bucket scale = (max-min)/levels; error <= scale for stochastic,
    # <= scale/2 + shift for the others -> bound by 1.5 * max scale
    q = quantize(x, cfg, jax.random.PRNGKey(1))
    bound = 1.5 * float(jnp.max(q.scale))
    assert float(jnp.max(jnp.abs(xq - x))) <= bound + 1e-6
    assert xq.shape == x.shape and xq.dtype == x.dtype


def test_wire_nearest_is_optimal_grid():
    cfg = QuantConfig(bits=8, bucket_size=128, mode="nearest")
    x = jax.random.normal(KEY, (128,))
    xq = quantize_dequantize(x, cfg)
    q = quantize(x, cfg)
    assert float(jnp.max(jnp.abs(xq - x))) <= 0.5 * float(jnp.max(q.scale)) + 1e-6


def test_wire_stochastic_unbiased():
    cfg = QuantConfig(bits=4, bucket_size=64, mode="stochastic")
    x = jax.random.normal(KEY, (64,))
    keys = jax.random.split(KEY, 4000)
    ys = jax.vmap(lambda k: quantize_dequantize(x, cfg, k))(keys)
    err = jnp.mean(ys, axis=0) - x
    scale = float(jnp.max(quantize(x, cfg, KEY).scale))
    assert float(jnp.max(jnp.abs(err))) < 0.1 * scale


def test_bucket_padding_and_shapes():
    cfg = QuantConfig(bits=8, bucket_size=1024, mode="nearest")
    x = jax.random.normal(KEY, (3, 700))  # 2100 elements -> 3 buckets padded
    q = quantize(x, cfg)
    s = quantized_shapes(x.size, cfg)
    assert q.codes.shape == s["codes"] == (3, 1024)
    assert q.scale.shape == s["scale"] == (3,)
    assert dequantize(q).shape == x.shape
    np.testing.assert_allclose(dequantize(q), x, atol=float(jnp.max(q.scale)))


def test_wire_bytes_accounting():
    cfg = QuantConfig(bits=8, bucket_size=1024)
    # n=4096 -> 4 buckets: 4096 code bytes + 4*(4+4) scale/zero bytes
    assert wire_bytes(4096, cfg) == 4096 + 32
    cfg4 = QuantConfig(bits=4, bucket_size=1024)
    assert wire_bytes(4096, cfg4) == 2048 + 32


@given(n=st.integers(1, 5000), bits=st.sampled_from([2, 4, 8]))
@settings(max_examples=25, deadline=None)
def test_quantize_any_size_roundtrips(n, bits):
    cfg = QuantConfig(bits=bits, bucket_size=512, mode="nearest")
    x = jax.random.normal(jax.random.PRNGKey(n), (n,))
    q = quantize(x, cfg)
    y = dequantize(q)
    assert y.shape == x.shape
    assert bool(jnp.all(jnp.isfinite(y)))
    assert float(jnp.max(jnp.abs(y - x))) <= 0.51 * float(jnp.max(q.scale)) + 1e-6


def test_constant_bucket_zero_scale():
    cfg = QuantConfig(bits=8, bucket_size=64, mode="nearest")
    x = jnp.full((64,), 3.14159)
    y = quantize_dequantize(x, cfg)
    np.testing.assert_allclose(y, x, atol=1e-5)
