"""Property-based tests for the whole quant stack: quantize -> pack ->
unpack -> dequantize round-trip invariants over bits 2-8 x all 3 rounding
modes x odd shapes / bucket remainders / non-divisible tails, plus the
wire_pack/wire_unpack byte-length formulas and the QuantizedParam
(quantized-domain train state) encode/decode layer on top.

Runs with real `hypothesis` when installed, or with the deterministic
seeded-sweep stub in tests/_hypothesis_stub.py (installed by conftest.py)
in hermetic environments — only `integers` / `sampled_from` strategies are
used so both back ends accept every test.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.quant import (
    QuantConfig,
    QuantizedParam,
    dequantize,
    fp_pack,
    fp_unpack,
    pack_codes,
    qparam_decode,
    qparam_encode,
    qparam_split_stack,
    quantize,
    quantize_dequantize,
    quantized_shapes,
    unpack_codes,
    wire_bytes,
    wire_pack,
    wire_segment_bytes,
    wire_unpack,
)

MODES = ("shift", "stochastic", "nearest")


def _key(*ints):
    k = jax.random.PRNGKey(ints[0])
    for i in ints[1:]:
        k = jax.random.fold_in(k, i)
    return k


def _data(n, seed, scale=3.0):
    return jax.random.normal(_key(seed), (n,)) * scale


# ---------------------------------------------------------------------------
# quantize -> dequantize: shape/dtype restoration + per-bucket error bound
# ---------------------------------------------------------------------------


@settings(max_examples=60, deadline=None)
@given(bits=st.integers(2, 8), mode=st.sampled_from(MODES),
       n=st.integers(1, 4000), bucket=st.sampled_from([64, 96, 128, 1024]),
       seed=st.integers(0, 2**16))
def test_roundtrip_error_bound(bits, mode, n, bucket, seed):
    cfg = QuantConfig(bits=bits, bucket_size=bucket, mode=mode)
    x = _data(n, seed)
    q = quantize(x, cfg, _key(seed, 1))
    y = dequantize(q)
    assert y.shape == x.shape and y.dtype == x.dtype
    # each bucket's decode error is bounded by one step of its grid
    pad = (-n) % bucket
    xb = jnp.pad(x, (0, pad)).reshape(-1, bucket)
    yb = jnp.pad(y, (0, pad)).reshape(-1, bucket)
    err = jnp.max(jnp.abs(xb - yb), axis=1)
    bound = q.scale * (1 + 1e-5) + 1e-7
    assert bool(jnp.all(err <= bound)), (float(jnp.max(err - bound)), bits, mode)


@settings(max_examples=30, deadline=None)
@given(bits=st.integers(2, 8), mode=st.sampled_from(MODES),
       d0=st.integers(1, 7), d1=st.integers(1, 11), d2=st.integers(1, 13),
       seed=st.integers(0, 2**16))
def test_roundtrip_odd_shapes(bits, mode, d0, d1, d2, seed):
    """Odd multi-dim shapes with non-divisible tails restore exactly."""
    cfg = QuantConfig(bits=bits, bucket_size=64, mode=mode)
    x = _data(d0 * d1 * d2, seed).reshape(d0, d1, d2)
    y = quantize_dequantize(x, cfg, _key(seed, 2))
    assert y.shape == x.shape and y.dtype == x.dtype
    assert bool(jnp.all(jnp.isfinite(y)))


# ---------------------------------------------------------------------------
# code packing: exact inverses + byte-length formulas
# ---------------------------------------------------------------------------


@settings(max_examples=40, deadline=None)
@given(bits=st.integers(1, 8), n_codes=st.integers(1, 64), seed=st.integers(0, 2**16))
def test_pack_unpack_codes_inverse(bits, n_codes, seed):
    k = 8 // bits if 8 % bits == 0 else 1
    n = n_codes * k  # pack requires a whole number of bytes
    codes = jax.random.randint(_key(seed), (n,), 0, (1 << bits)).astype(jnp.uint8)
    packed = pack_codes(codes, bits)
    assert packed.shape[-1] == n // k
    assert bool(jnp.all(unpack_codes(packed, bits) == codes))


@settings(max_examples=60, deadline=None)
@given(bits=st.integers(2, 8), n=st.integers(1, 5000),
       bucket=st.sampled_from([64, 128, 1024]),
       meta=st.sampled_from(["float32", "bfloat16"]))
def test_wire_byte_length_formulas(bits, n, bucket, meta):
    cfg = QuantConfig(bits=bits, bucket_size=bucket, meta_dtype=meta)
    nb = -(-n // bucket)
    s = quantized_shapes(n, cfg)
    assert s["scale"] == (nb,) and s["zero"] == (nb,)
    assert s["codes"] == (nb, bucket // cfg.codes_per_byte)
    expect = nb * (bucket // cfg.codes_per_byte) + 2 * cfg.meta_bytes * nb
    assert wire_bytes(n, cfg) == wire_segment_bytes(n, cfg) == expect
    # packed widths: 1/2/4/8-bit codes occupy exactly bits/8 bytes each,
    # others one byte per value
    if 8 % bits == 0:
        assert s["codes"][1] * 8 == bucket * bits


@settings(max_examples=50, deadline=None)
@given(bits=st.integers(2, 8), mode=st.sampled_from(MODES),
       n=st.integers(1, 4000), seed=st.integers(0, 2**16))
def test_wire_pack_unpack_bitexact(bits, mode, n, seed):
    """wire_pack -> wire_unpack reproduces codes/scale/zero bit-for-bit and
    the buffer length matches the static formula."""
    cfg = QuantConfig(bits=bits, bucket_size=128, mode=mode)
    x = _data(n, seed)
    q = quantize(x, cfg, _key(seed, 3))
    buf = wire_pack(q)
    assert buf.dtype == jnp.uint8
    assert buf.shape == (wire_segment_bytes(n, cfg),)
    q2 = wire_unpack(buf, n, cfg, shape=q.shape)
    assert bool(jnp.all(q2.codes == q.codes))
    assert bool(jnp.all(q2.scale == q.scale))
    assert bool(jnp.all(q2.zero == q.zero))
    assert bool(jnp.all(dequantize(q2) == dequantize(q)))


@settings(max_examples=30, deadline=None)
@given(n=st.integers(1, 2000), seed=st.integers(0, 2**16),
       dt=st.sampled_from(["float32", "bfloat16", "float16"]))
def test_fp_pack_unpack_roundtrip(n, seed, dt):
    x = _data(n, seed).astype(getattr(jnp, dt)).astype(jnp.float32)
    buf = fp_pack(x, dt)
    assert buf.shape == (n * jnp.dtype(getattr(jnp, dt)).itemsize,)
    assert bool(jnp.all(fp_unpack(buf, n, dt) == x))


# ---------------------------------------------------------------------------
# QuantizedParam: the quantized-domain train-state layer
# ---------------------------------------------------------------------------


@settings(max_examples=30, deadline=None)
@given(bits=st.integers(2, 8), mode=st.sampled_from(MODES),
       stack=st.integers(1, 4), n_local=st.integers(1, 700),
       seed=st.integers(0, 2**16))
def test_qparam_matches_qdq_master(bits, mode, stack, n_local, seed):
    """Decoding a QuantizedParam is bit-identical to the f32 QDQ master
    path applied to the same rest-layout leaf with the same key — the
    invariant the quantized-domain train state rests on."""
    cfg = QuantConfig(bits=bits, bucket_size=256, mode=mode)
    x = _data(stack * n_local, seed).reshape(stack, 1, 1, n_local)
    key = _key(seed, 4)
    qp = qparam_encode(x, cfg, key)
    assert qp.wire.shape == (1, 1, wire_segment_bytes(stack * n_local, cfg))
    dec = qparam_decode(qp)
    ref = quantize_dequantize(x, cfg, key)
    assert dec.shape == x.shape
    assert bool(jnp.all(dec == ref))


@settings(max_examples=20, deadline=None)
@given(bits=st.integers(2, 8), model=st.integers(1, 3), fsdp=st.integers(1, 3),
       n_local=st.integers(1, 300), seed=st.integers(0, 2**16))
def test_qparam_multicell_matches_per_cell(bits, model, fsdp, n_local, seed):
    """Host-side (vmapped, multi-cell) encode/decode agrees bit-for-bit with
    the per-device single-cell path for every (model, fsdp) cell."""
    cfg = QuantConfig(bits=bits, bucket_size=128, mode="shift")
    x = _data(model * fsdp * n_local, seed).reshape(model, fsdp, n_local)
    key = _key(seed, 5)
    dec = qparam_decode(qparam_encode(x, cfg, key))
    for m in range(model):
        for f in range(fsdp):
            cell = x[m:m + 1, f:f + 1, :]
            ref = qparam_decode(qparam_encode(cell, cfg, key))
            assert bool(jnp.all(dec[m:m + 1, f:f + 1, :] == ref)), (m, f)


@settings(max_examples=20, deadline=None)
@given(stack=st.integers(1, 5), nb_s=st.integers(1, 4), seed=st.integers(0, 2**16))
def test_qparam_split_stack_exact(stack, nb_s, seed):
    """Per-layer wire slices of a bucket-aligned stack decode to exactly
    the corresponding slices of the full decode (the serve scan layout)."""
    bucket = 64
    n_local = nb_s * bucket
    cfg = QuantConfig(bits=8, bucket_size=bucket, mode="shift")
    x = _data(stack * n_local, seed).reshape(stack, 1, 1, n_local)
    qp = qparam_encode(x, cfg, _key(seed, 6))
    sp = qparam_split_stack(qp)
    assert sp.wire.shape == (stack, 1, 1, wire_segment_bytes(n_local, cfg))
    assert sp.cell_shape == (n_local,)
    full = qparam_decode(qp)
    assert bool(jnp.all(qparam_decode(sp) == full))
    # each slice is a self-contained wire segment
    for s in range(stack):
        one = QuantizedParam(sp.wire[s], (n_local,), cfg)
        assert bool(jnp.all(qparam_decode(one)[0, 0] == full[s, 0, 0]))


def test_qparam_rejects_bad_rank():
    cfg = QuantConfig(bits=8, bucket_size=64)
    with pytest.raises(ValueError):
        qparam_encode(jnp.zeros((4, 4)), cfg, _key(0))


@settings(max_examples=20, deadline=None)
@given(bits=st.sampled_from([2, 4, 8]), n_local=st.integers(1, 1000),
       seed=st.integers(0, 2**16))
def test_qparam_compression_ratio(bits, n_local, seed):
    """The wire holds <= bits/32 of the f32 bytes + per-bucket metadata —
    the memory-win bound the checkpoint-v2 tests also assert."""
    cfg = QuantConfig(bits=bits, bucket_size=1024)
    x = _data(n_local, seed).reshape(1, 1, n_local)
    qp = qparam_encode(x, cfg, _key(seed, 7))
    nb = -(-n_local // cfg.bucket_size)
    f32_bytes = 4 * n_local
    meta_overhead = 2 * cfg.meta_bytes * nb
    # bits/8 bytes per value (padded up to a whole bucket) + metadata
    assert qp.wire.nbytes <= (n_local + cfg.bucket_size) * bits / 8 + meta_overhead
    if n_local >= cfg.bucket_size:  # amortized: the acceptance-criterion bound
        assert qp.wire.nbytes <= f32_bytes * bits / 32 + meta_overhead + cfg.bucket_size
