"""Quantized-domain train state: bit-exactness vs the f32 QDQ master path,
quantized Adam moments, grad-clip metric semantics, and state-bytes
accounting — all on the trivial (1,1) mesh (the (2,4) mesh runs the same
checks in scripts/check_quantized_state.py via test_distributed.py)."""
import inspect

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.qsdp import MeshSpec, QSDPConfig
from repro.core.quant import QuantizedParam
from repro.models.config import ModelConfig
from repro.models.transformer import Model
from repro.optim import AdamWConfig, make_adamw
from repro.train.step import (
    build_train_step,
    dequantize_train_state,
    init_train_state,
    make_jitted_train_step,
    master_eligible,
    quantize_train_state,
    state_pspecs,
)


def tiny_model(ms=None, **qkw):
    ms = ms or MeshSpec(axes=("data", "model"), shape=(1, 1))
    cfg = ModelConfig(name="tiny", arch_type="dense", n_layers=2, d_model=64,
                      vocab_size=128, n_heads=4, n_kv_heads=2, head_dim=16,
                      d_ff=128)
    qkw.setdefault("min_quant_size", 256)
    return Model(cfg, ms, QSDPConfig(**qkw))


def tiny_batch(b=4, s=32, vocab=128, seed=3):
    tokens = jax.random.randint(jax.random.PRNGKey(seed), (b, s), 0, vocab)
    return {"tokens": tokens, "labels": tokens}


def run_steps(step, state, batch, n, start=0, seed=7):
    losses = []
    for i in range(start, start + n):
        state, m = step(state, batch, jax.random.fold_in(jax.random.PRNGKey(seed), i))
        losses.append(float(m["loss"]))
    return state, losses


@pytest.fixture(scope="module")
def qdq_vs_qstate(mesh11):
    """Run 10 steps of the f32 QDQ master path and of the quantized-domain
    state path from the same (grid-representable) initial state."""
    model = tiny_model()
    opt = make_adamw(AdamWConfig(lr=1e-3))
    s0 = init_train_state(model, opt, jax.random.PRNGKey(0))
    qs0 = quantize_train_state(s0, model, jax.random.PRNGKey(9))
    fs0 = dequantize_train_state(qs0)

    batch = tiny_batch()
    step_q = make_jitted_train_step(model, opt, mesh11, quantized_state=True,
                                    donate=False)
    step_f = make_jitted_train_step(model, opt, mesh11, quantize_master=True,
                                    donate=False)
    with mesh11:
        sq, lq = run_steps(step_q, qs0, batch, 10)
        sf, lf = run_steps(step_f, fs0, batch, 10)
    return model, sq, lq, sf, lf


def test_quantized_state_bitexact_loss(qdq_vs_qstate):
    _, _, lq, _, lf = qdq_vs_qstate
    assert lq == lf  # float-exact, all 10 steps


def test_quantized_state_bitexact_params_and_moments(qdq_vs_qstate):
    model, sq, _, sf, _ = qdq_vs_qstate
    dq = dequantize_train_state(sq)
    for k in sf.params:
        np.testing.assert_array_equal(np.asarray(dq.params[k]),
                                      np.asarray(sf.params[k]), err_msg=k)
    for k in sf.opt.mu:
        np.testing.assert_array_equal(np.asarray(dq.opt.mu[k]),
                                      np.asarray(sf.opt.mu[k]), err_msg=k)
        np.testing.assert_array_equal(np.asarray(dq.opt.nu[k]),
                                      np.asarray(sf.opt.nu[k]), err_msg=k)


def test_quantized_state_leaf_forms(qdq_vs_qstate):
    """Eligible leaves rest as QuantizedParam wire codes; filtered leaves
    (norms, small tensors) stay f32 — and the wire is ~bits/32 the size."""
    model, sq, _, _, _ = qdq_vs_qstate
    n_wire = 0
    for name, leaf in sq.params.items():
        if master_eligible(model, name):
            assert isinstance(leaf, QuantizedParam), name
            assert leaf.wire.dtype == jnp.uint8
            spec = model.specs[name]
            f32_bytes = int(np.prod(spec.rest_shape(model.ms))) * 4
            assert leaf.wire.nbytes < 0.3 * f32_bytes, name  # 8-bit + meta
            n_wire += 1
        else:
            assert not isinstance(leaf, QuantizedParam), name
    assert n_wire > 0


def test_quantized_moments_run_and_compress(mesh11):
    model = tiny_model()
    opt = make_adamw(AdamWConfig(lr=1e-3, moment_bits=8, moment_bucket_size=256))
    assert opt.quantized_moments
    state = init_train_state(model, opt, jax.random.PRNGKey(0))
    for tree in (state.opt.mu, state.opt.nu):
        for k, v in tree.items():
            assert isinstance(v, QuantizedParam), k
            # freshly-initialized moments are exact zeros after decode
            from repro.core.quant import qparam_decode
            assert bool(jnp.all(qparam_decode(v) == 0.0)), k
    step = make_jitted_train_step(model, opt, mesh11, donate=False)
    with mesh11:
        state, losses = run_steps(step, state, tiny_batch(), 3)
    assert all(np.isfinite(losses))
    # moments stayed in wire form through the update
    assert all(isinstance(v, QuantizedParam) for v in state.opt.mu.values())
    f32_bytes = sum(int(np.prod(s.rest_shape(model.ms))) * 4
                    for s in model.specs.values())
    mu_bytes = sum(v.wire.nbytes for v in state.opt.mu.values())
    assert mu_bytes < 0.3 * f32_bytes


def test_quantized_moments_track_f32_moments(mesh11):
    """8-bit moments follow the f32-moment trajectory closely over a few
    steps (they are a lossy, documented approximation — not bit-exact)."""
    model = tiny_model()
    batch = tiny_batch()
    states = {}
    for bits in (None, 8):
        opt = make_adamw(AdamWConfig(lr=1e-3, moment_bits=bits))
        state = init_train_state(model, opt, jax.random.PRNGKey(0))
        step = make_jitted_train_step(model, opt, mesh11, donate=False)
        with mesh11:
            states[bits], losses = run_steps(step, state, batch, 3)
        assert all(np.isfinite(losses))
    # lossy by design: early-training nu is tiny, so 8-bit moment error is
    # amplified through 1/sqrt(nu) — bound the drift at a few lr-sized steps
    for k in states[None].params:
        a = np.asarray(states[None].params[k])
        b = np.asarray(states[8].params[k])
        np.testing.assert_allclose(a, b, atol=5e-2, err_msg=k)


def test_grad_clip_zero_same_gnorm_scale_one(mesh11):
    """grad_clip=0 must report the SAME grad_norm metric as a clipped run
    (the norm is computed once, in one arm) and apply scale == 1 — i.e. the
    same update as an effectively-unbinding clip threshold."""
    model = tiny_model()
    opt = make_adamw(AdamWConfig(lr=1e-3))
    batch = tiny_batch()
    results = {}
    for clip in (0.0, 1.0, 1e9):
        state = init_train_state(model, opt, jax.random.PRNGKey(0))
        step = make_jitted_train_step(model, opt, mesh11, grad_clip=clip,
                                      donate=False)
        with mesh11:
            state, m = step(state, batch, jax.random.PRNGKey(7))
        results[clip] = (state, float(m["grad_norm"]), float(m["loss"]))
    # same grad_norm metric whether or not clipping is enabled
    assert results[0.0][1] == results[1.0][1] == results[1e9][1]
    assert results[0.0][2] == results[1.0][2]
    # scale == 1: grad_clip=0 takes the identical step as a huge threshold
    s0, shuge = results[0.0][0], results[1e9][0]
    for k in s0.params:
        np.testing.assert_array_equal(np.asarray(s0.params[k]),
                                      np.asarray(shuge.params[k]), err_msg=k)


def test_build_train_step_donate_removed():
    """The dead `donate` parameter is gone from build_train_step (donation
    is owned by make_jitted_train_step's jit)."""
    sig = inspect.signature(build_train_step)
    assert "donate" not in sig.parameters
    assert "donate" in inspect.signature(make_jitted_train_step).parameters


def test_make_jitted_donate_false_keeps_input_state(mesh11):
    model = tiny_model()
    opt = make_adamw(AdamWConfig(lr=1e-3))
    state = init_train_state(model, opt, jax.random.PRNGKey(0))
    step = make_jitted_train_step(model, opt, mesh11, donate=False)
    with mesh11:
        step(state, tiny_batch(), jax.random.PRNGKey(1))
    # input buffers not donated: still readable
    _ = [np.asarray(v) for v in state.params.values()]


def test_state_pspecs_quantized_forms():
    model = tiny_model()
    sp = state_pspecs(model, quantized_state=True, quantized_moments=True)
    from jax.sharding import PartitionSpec as P
    wire = P("model", model.ms.fsdp_axes, None)
    for name in model.specs:
        if master_eligible(model, name):
            assert sp.params[name] == wire, name
        else:
            assert sp.params[name] == model.specs[name].rest_pspec(model.ms), name
    assert all(v == wire for v in sp.opt.mu.values())
