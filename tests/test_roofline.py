"""HLO analyzer + roofline term tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.roofline import HW_V5E, collective_bytes_from_hlo, roofline
from repro.roofline.hlo_analyzer import analyze_hlo

SYNTH = """
HloModule test

%body (p: (s32[], f32[8,8])) -> (s32[], f32[8,8]) {
  %p = (s32[], f32[8,8]{1,0}) parameter(0)
  %g0 = s32[] get-tuple-element(%p), index=0
  %g1 = f32[8,8]{1,0} get-tuple-element(%p), index=1
  %ag = f32[8,8]{1,0} all-gather(%g1), replica_groups={{0,1,2,3},{4,5,6,7}}, dimensions={0}
  %d = f32[8,8]{1,0} dot(%ag, %g1), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  ROOT %t = (s32[], f32[8,8]{1,0}) tuple(%g0, %d)
}

%cond (p: (s32[], f32[8,8])) -> pred[] {
  %p = (s32[], f32[8,8]{1,0}) parameter(0)
  ROOT %c = pred[] constant(true)
}

ENTRY %main (a: f32[8,8]) -> f32[8,8] {
  %a = f32[8,8]{1,0} parameter(0)
  %ar = f32[8,8]{1,0} all-reduce(%a), replica_groups={{0,1}}, to_apply=%cond
  %t0 = (s32[], f32[8,8]{1,0}) tuple(%ar, %a)
  %w = (s32[], f32[8,8]{1,0}) while(%t0), condition=%cond, body=%body, backend_config={"known_trip_count":{"n":"10"}}
  ROOT %r = f32[8,8]{1,0} get-tuple-element(%w), index=1
}
"""


def test_analyzer_trip_count_multiplication():
    r = analyze_hlo(SYNTH)
    # dot: 2*8*8*8 flops, x10 trips
    assert r["flops"] == 10 * 2 * 8 * 8 * 8
    c = r["collectives"]
    # all-gather in body: result 256B, g=4 -> 192B wire, x10
    assert c["all-gather"] == 10 * (256 * 3 // 4)
    # top-level all-reduce: 2*256*(2-1)/2 = 256
    assert c["all-reduce"] == 256
    assert c["counts"]["all-gather"] == 10


def test_collective_bytes_public_api():
    c = collective_bytes_from_hlo(SYNTH)
    assert c["total"] == c["all-gather"] + c["all-reduce"]


def test_analyzer_against_real_lowering():
    """Known matmul chain: scan(5) of 64x64 matmuls = 5*2*64^3 flops."""
    a = jnp.zeros((64, 64), jnp.float32)

    def f(x):
        def body(c, _):
            return c @ x, None
        y, _ = jax.lax.scan(body, x, None, length=5)
        return y

    txt = jax.jit(f).lower(a).compile().as_text()
    r = analyze_hlo(txt)
    assert r["flops"] == 5 * 2 * 64**3
    assert r["collectives"]["total"] == 0
    assert r["traffic_bytes"] > 5 * 64 * 64 * 4  # at least the carries


def test_roofline_terms_and_bottleneck():
    rep = roofline("a", "s", "m", cost={}, hlo_text=SYNTH, n_chips=256,
                   model_flops_global=256 * 5000.0, hw=HW_V5E)
    assert rep.t_compute == pytest.approx(10 * 1024 / HW_V5E.peak_flops)
    assert rep.bottleneck in ("compute", "memory", "collective")
    assert rep.model_flops == pytest.approx(5000.0)
    d = rep.to_dict()
    assert {"t_compute", "t_memory", "t_collective", "bottleneck"} <= set(d)


def test_wire_formulas():
    from repro.roofline.hlo_analyzer import _wire_bytes
    assert _wire_bytes("all-gather", 1024, 4) == 768
    assert _wire_bytes("reduce-scatter", 256, 4) == 768
    assert _wire_bytes("all-reduce", 1024, 4) == 1536
    assert _wire_bytes("all-to-all", 1024, 4) == 768
    assert _wire_bytes("collective-permute", 1024, 4) == 1024
