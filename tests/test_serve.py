"""Serving path unit tests on the trivial mesh: cache structs, prefill ->
decode flow, ring-buffer semantics."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro import configs
from repro.core.qsdp import MeshSpec, QSDPConfig
from repro.models.config import ModelConfig
from repro.models.decode import DecodeModel, DecodeSpec
from repro.models.transformer import Model
from repro.serve import ServeEngine

MS = MeshSpec(axes=("data", "model"), shape=(1, 1))
QS = QSDPConfig.baseline()


def _model(arch_type="dense", **kw):
    base = dict(name="t", arch_type=arch_type, n_layers=2, d_model=64,
                vocab_size=256)
    if arch_type in ("dense", "vlm", "moe", "audio", "hybrid"):
        base.update(n_heads=4, n_kv_heads=2, head_dim=16, d_ff=128)
    if arch_type in ("ssm", "hybrid"):
        base.update(ssm_state=16, ssm_head_dim=16, ssm_chunk=8)
    base.update(kw)
    return Model(ModelConfig(**base), MS, QS)


def test_cache_struct_shapes():
    m = _model()
    dm = DecodeModel(m, DecodeSpec(cache_len=32, batch_global=4, batch_sharded=True))
    structs, specs = dm.cache_struct()
    assert structs["k"].shape == (2, 4, 32, 2, 16)
    assert structs["k"].dtype == jnp.bfloat16
    assert set(structs) == set(specs) == {"k", "v"}


def test_cache_struct_ssm():
    m = _model("ssm")
    dm = DecodeModel(m, DecodeSpec(cache_len=0, batch_global=4, batch_sharded=True))
    structs, _ = dm.cache_struct()
    # conv: (L, B, K-1, d_inner + 2N); ssm: (L, B, H, P, N)
    assert structs["conv"].shape == (2, 4, 3, 128 + 32)
    assert structs["ssm"].shape == (2, 4, 8, 16, 16)


def test_cache_struct_hybrid_groups():
    m = _model("hybrid", n_layers=5, hybrid_attn_every=2)
    dm = DecodeModel(m, DecodeSpec(cache_len=32, batch_global=2, batch_sharded=True))
    structs, _ = dm.cache_struct()
    assert structs["shared_k"].shape[0] == 2  # 5 // 2 groups
    assert structs["conv"].shape[0] == 5


def test_generate_then_extend_consistency(mesh11):
    """Greedy generate(k) tokens == generate(k+2)'s first k tokens (the
    decode chain is deterministic in the fp path)."""
    m = _model()
    params = m.init_params(jax.random.PRNGKey(0))
    spec = DecodeSpec(cache_len=32, batch_global=4, batch_sharded=True)
    prompt = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (4, 16), 0, 256)}
    ps = {"tokens": P(("data",))}
    with mesh11:
        e1 = ServeEngine(m, mesh11, spec)
        t1 = np.asarray(jax.device_get(e1.generate(params, prompt, ps, n_tokens=4)))
        e2 = ServeEngine(m, mesh11, spec)
        t2 = np.asarray(jax.device_get(e2.generate(params, prompt, ps, n_tokens=6)))
    np.testing.assert_array_equal(t1, t2[:, :4])


def test_sliding_window_ring_wraps(mesh11):
    """Decode past the window size keeps working (ring overwrite) and only
    attends to the last `window` positions."""
    m = _model(sliding_window=0, long_context="sliding_window",
               long_context_window=16)
    params = m.init_params(jax.random.PRNGKey(0))
    spec = DecodeSpec(cache_len=16, batch_global=2, batch_sharded=True)
    eng = ServeEngine(m, mesh11, spec)
    prompt = {"tokens": jax.random.randint(jax.random.PRNGKey(2), (2, 12), 0, 256)}
    with mesh11:
        out = eng.generate(params, prompt, {"tokens": P(("data",))}, n_tokens=10)
    out = np.asarray(jax.device_get(out))
    assert out.shape == (2, 10)
    assert ((out >= 0) & (out < 256)).all()


@pytest.mark.parametrize("arch", ["qwen2_5_3b", "olmoe_1b_7b", "mamba2_370m",
                                  "zamba2_7b", "seamless_m4t_large_v2",
                                  "qwen2_vl_72b"])
def test_smoke_serve_all_families(arch, mesh11):
    """One prefill + one decode step per family's smoke config."""
    cfg = configs.get_smoke(arch)
    m = Model(cfg, MS, QSDPConfig(min_quant_size=256))
    params = m.init_params(jax.random.PRNGKey(0))
    B, S = 2, 16
    spec = DecodeSpec(cache_len=0 if cfg.arch_type == "ssm" else 32,
                      batch_global=B, batch_sharded=True,
                      enc_len=8 if cfg.arch_type == "audio" else 0)
    eng = ServeEngine(m, mesh11, spec)
    prompt = {"tokens": jnp.ones((B, S), jnp.int32)}
    ps = {"tokens": P(("data",))}
    if cfg.arch_type == "vlm":
        prompt.update(vision_embeds=jnp.zeros((B, S, cfg.d_model), jnp.bfloat16),
                      vision_mask=jnp.zeros((B, S), bool),
                      positions=jnp.broadcast_to(jnp.arange(S), (3, B, S)))
        ps.update(vision_embeds=P(("data",)), vision_mask=P(("data",)),
                  positions=P(None, ("data",)))
    if cfg.arch_type == "audio":
        prompt["audio_embeds"] = 0.1 * jax.random.normal(
            jax.random.PRNGKey(1), (B, 8, cfg.d_model), jnp.bfloat16)
        ps["audio_embeds"] = P(("data",))
    with mesh11:
        out = eng.generate(params, prompt, ps, n_tokens=2)
    out = np.asarray(jax.device_get(out))
    assert out.shape == (B, 2)
    assert ((out >= 0) & (out < cfg.vocab_size)).all()
