"""Serving conformance & property suite for the continuous-batching
scheduler (serve/scheduler.py).

The load-bearing invariant: with greedy decoding, a request's output tokens
are BIT-IDENTICAL whether it runs alone in a batch-of-1 engine
(``ServeEngine.generate(..., fold_step_keys=False)``) or interleaved with
arbitrary other requests under the scheduler — random arrival orders,
prompt/generation lengths, and slot counts, on the dense and moe families.
Plus: cache hygiene on slot reuse (no stale KV; ring wrap composes with
reuse), and per-request sampling that is reproducible across runs and
batch compositions and reduces to the greedy path bit-exactly at
temperature 0 / top-k 1.

Engines and schedulers are cached at module scope (compiles dominate);
reusing one scheduler across tests is deliberate — every admission must
fully overwrite the slot it lands in, so a dirty pool is exactly the state
the hygiene invariant covers.
"""
import itertools

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st
from jax.sharding import PartitionSpec as P

from repro.core.qsdp import MeshSpec, QSDPConfig
from repro.models.config import ModelConfig
from repro.models.decode import DecodeSpec
from repro.models.transformer import Model
from repro.serve import (ContinuousScheduler, Request, ServeEngine,
                         make_sample_params)

MS = MeshSpec(axes=("data", "model"), shape=(1, 1))
MESH = jax.make_mesh((1, 1), ("data", "model"))
# ONE gather key for every prefill/decode step — the served model is a fixed
# function (see scheduler module docstring); solo references use the same key
GATHER_KEY = jax.random.PRNGKey(7)
RING = 32
VOCAB = 256
PROMPT_LENS = (4, 6)  # bounded so prefill retraces stay cheap
_RID = itertools.count()


def _cfg(family: str) -> ModelConfig:
    base = dict(name=f"sched-{family}", arch_type=family, n_layers=2,
                d_model=64, vocab_size=VOCAB, n_heads=4, n_kv_heads=2,
                head_dim=16, d_ff=128)
    if family == "moe":
        base.update(n_experts=4, moe_top_k=2)
    return ModelConfig(**base)


_models: dict = {}
_scheds: dict = {}
_solo: dict = {}
_solo_out: dict = {}


def model_and_params(family):
    if family not in _models:
        m = Model(_cfg(family), MS, QSDPConfig(min_quant_size=256))
        _models[family] = (m, m.init_params(jax.random.PRNGKey(0)))
    return _models[family]


def scheduler(family, slots) -> ContinuousScheduler:
    if (family, slots) not in _scheds:
        m, params = model_and_params(family)
        spec = DecodeSpec(cache_len=RING, batch_global=slots,
                          batch_sharded=False, sampling=True)
        _scheds[(family, slots)] = ContinuousScheduler(
            m, MESH, spec, params, gather_key=GATHER_KEY)
    return _scheds[(family, slots)]


def solo_tokens(family, prompt, gen, temperature=0.0, top_k=0, seed=0):
    """Reference: the request alone in a batch-of-1 engine, fixed gather
    key (memoized — many scheduler scenarios share solo requests)."""
    key = (family, tuple(prompt), gen, temperature, top_k, seed)
    if key in _solo_out:
        return _solo_out[key]
    if family not in _solo:
        m, _ = model_and_params(family)
        spec = DecodeSpec(cache_len=RING, batch_global=1,
                          batch_sharded=False, sampling=True)
        _solo[family] = ServeEngine(m, MESH, spec)
    _, params = model_and_params(family)
    sample = make_sample_params(temperature, top_k, seed)
    out = _solo[family].generate(
        params, {"tokens": jnp.asarray(np.asarray(prompt, np.int32)[None])},
        {"tokens": P(None)}, n_tokens=gen, key=GATHER_KEY, sample=sample,
        fold_step_keys=False)
    _solo_out[key] = np.asarray(jax.device_get(out))[0]
    return _solo_out[key]


def make_requests(rng, n, max_gen=5, sampled=False):
    reqs = []
    for _ in range(n):
        plen = int(rng.choice(PROMPT_LENS))
        reqs.append(Request(
            rid=f"t{next(_RID)}",
            prompt=rng.integers(0, VOCAB, size=plen).tolist(),
            max_new_tokens=int(rng.integers(1, max_gen + 1)),
            temperature=float(rng.choice([0.0, 0.7, 1.3])) if sampled else 0.0,
            top_k=int(rng.choice([0, 1, 3])) if sampled else 0,
            seed=int(rng.integers(0, 100))))
    return reqs


def run_scheduler(sched, reqs):
    for r in reqs:
        sched.submit(r)
    done = sched.run()
    return [done[r.rid].tokens for r in reqs]


# ---------------------------------------------------------------------------
# Tentpole invariant: interleaved greedy == solo batch-of-1, property-driven
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("family", ["dense", "moe"])
@settings(max_examples=4, deadline=None)
@given(seed=st.integers(0, 10_000), slots=st.sampled_from([2, 3]))
def test_interleaved_greedy_matches_solo(family, seed, slots):
    """Random arrival orders / prompt lengths / generation lengths / slot
    counts: every greedy request's tokens match its solo batch-of-1 run
    token-for-token."""
    rng = np.random.default_rng(seed)
    sched = scheduler(family, slots)
    reqs = make_requests(rng, int(rng.integers(3, 6)))
    outs = run_scheduler(sched, reqs)
    for r, got in zip(reqs, outs):
        ref = solo_tokens(family, r.prompt, r.max_new_tokens)[: r.max_new_tokens]
        np.testing.assert_array_equal(got, ref, err_msg=f"{family} {r.rid}")


@pytest.mark.parametrize("family", ["dense", "moe"])
def test_interleaved_insensitive_to_arrival_order(family):
    """The same request set, submitted in different orders (hence decoded
    against different slot neighbours), yields identical per-request
    streams."""
    rng = np.random.default_rng(99)
    reqs = make_requests(rng, 5)
    sched = scheduler(family, 3)
    a = dict(zip((r.rid for r in reqs), run_scheduler(sched, reqs)))
    perm = [reqs[i] for i in [3, 0, 4, 2, 1]]
    renamed = [Request(rid=f"t{next(_RID)}", prompt=r.prompt,
                       max_new_tokens=r.max_new_tokens, seed=r.seed)
               for r in perm]
    b = run_scheduler(sched, renamed)
    for orig, got in zip(perm, b):
        np.testing.assert_array_equal(got, a[orig.rid])


# ---------------------------------------------------------------------------
# Cache hygiene: slot reuse must look like a fresh engine
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("family", ["dense", "moe"])
def test_slot_reuse_no_stale_kv(family):
    """More requests than slots forces freed-slot reuse mid-decode; every
    reused slot's request must match the fresh batch-of-1 engine, and a
    second pass over the same prompts (pool now dirty with the first pass's
    KV) must reproduce it."""
    rng = np.random.default_rng(5)
    sched = scheduler(family, 2)
    reqs = make_requests(rng, 5, max_gen=4)
    first = run_scheduler(sched, reqs)
    for r, got in zip(reqs, first):
        np.testing.assert_array_equal(
            got, solo_tokens(family, r.prompt, r.max_new_tokens))
    again = [Request(rid=f"t{next(_RID)}", prompt=r.prompt,
                     max_new_tokens=r.max_new_tokens) for r in reqs]
    second = run_scheduler(sched, again)
    for a, b in zip(first, second):
        np.testing.assert_array_equal(a, b)


def test_ring_wrap_composes_with_slot_reuse():
    """Sliding-window model: generation long enough to wrap the KV ring,
    through slots that are freed and reused — wrap + reuse must still match
    the solo run (which wraps the same ring)."""
    cfg = ModelConfig(name="wrap", arch_type="dense", n_layers=2, d_model=64,
                      vocab_size=VOCAB, n_heads=4, n_kv_heads=2, head_dim=16,
                      d_ff=128, sliding_window=0, long_context="sliding_window",
                      long_context_window=16)
    m = Model(cfg, MS, QSDPConfig(min_quant_size=256))
    params = m.init_params(jax.random.PRNGKey(0))
    spec = DecodeSpec(cache_len=16, batch_global=2, batch_sharded=False,
                      sampling=True)
    sched = ContinuousScheduler(m, MESH, spec, params, gather_key=GATHER_KEY)
    solo = ServeEngine(
        m, MESH, DecodeSpec(cache_len=16, batch_global=1, batch_sharded=False,
                            sampling=True))
    rng = np.random.default_rng(3)
    # gen 14 from prompt 8: positions reach 21 > ring 16 — wraps; 3 requests
    # on 2 slots forces reuse after a wrapped generation
    reqs = [Request(rid=f"t{next(_RID)}",
                    prompt=rng.integers(0, VOCAB, size=8).tolist(),
                    max_new_tokens=g) for g in (14, 6, 14)]
    outs = run_scheduler(sched, reqs)
    for r, got in zip(reqs, outs):
        ref = solo.generate(
            params, {"tokens": jnp.asarray(np.asarray(r.prompt, np.int32)[None])},
            {"tokens": P(None)}, n_tokens=r.max_new_tokens, key=GATHER_KEY,
            fold_step_keys=False)
        np.testing.assert_array_equal(got, np.asarray(jax.device_get(ref))[0])


# ---------------------------------------------------------------------------
# Sampling determinism
# ---------------------------------------------------------------------------


def test_sampling_reproducible_across_runs_and_compositions():
    """temperature/top-k requests with fixed per-request seeds reproduce
    exactly across scheduler runs AND across different batch compositions
    (different co-resident requests)."""
    rng = np.random.default_rng(11)
    sched = scheduler("dense", 3)
    reqs = make_requests(rng, 4, sampled=True)
    a = run_scheduler(sched, reqs)
    # same requests again (new rids), plus extra greedy traffic interleaved
    renamed = [Request(rid=f"t{next(_RID)}", prompt=r.prompt,
                       max_new_tokens=r.max_new_tokens,
                       temperature=r.temperature, top_k=r.top_k, seed=r.seed)
               for r in reqs]
    fillers = make_requests(rng, 3)
    order = [renamed[1], fillers[0], renamed[0], fillers[1], renamed[3],
             fillers[2], renamed[2]]
    done = dict(zip((r.rid for r in order), run_scheduler(sched, order)))
    for orig, ren in zip(reqs, renamed):
        np.testing.assert_array_equal(done[ren.rid],
                                      a[reqs.index(orig)])
    # and each sampled stream matches its solo batch-of-1 run
    for r, got in zip(reqs, a):
        np.testing.assert_array_equal(
            got, solo_tokens("dense", r.prompt, r.max_new_tokens,
                             r.temperature, r.top_k, r.seed))


def test_temp0_topk1_reduce_to_greedy_bit_exactly():
    """temperature=0 and top_k=1 rows of the sampling path must equal the
    pure-greedy engine (DecodeSpec(sampling=False)) token-for-token."""
    m, params = model_and_params("dense")
    prompt = np.arange(1, 7, dtype=np.int32)
    greedy_eng = ServeEngine(
        m, MESH, DecodeSpec(cache_len=RING, batch_global=1,
                            batch_sharded=False, sampling=False))
    ref = np.asarray(jax.device_get(greedy_eng.generate(
        params, {"tokens": jnp.asarray(prompt[None])}, {"tokens": P(None)},
        n_tokens=5, key=GATHER_KEY, fold_step_keys=False)))[0]
    for temperature, top_k in ((0.0, 0), (0.0, 3), (1.3, 1)):
        got = solo_tokens("dense", prompt.tolist(), 5, temperature, top_k,
                          seed=42)
        np.testing.assert_array_equal(got, ref, err_msg=f"{temperature}/{top_k}")


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 10_000), k=st.sampled_from([1, 2, 3, 8]))
def test_sampled_tokens_stay_in_topk(seed, k):
    """sample_vocab_parallel property: a sampled token is always inside the
    row's top-k logit set, and temp<=0 rows equal the argmax."""
    from repro.compat import shard_map
    from repro.models.layers import sample_vocab_parallel

    rng = np.random.default_rng(seed)
    t, v = 4, 16
    logits = jnp.asarray(rng.normal(size=(t, v)).astype(np.float32))
    temp = jnp.asarray(rng.choice([0.0, 0.5, 1.0], size=t).astype(np.float32))
    top_k = jnp.full((t,), k, jnp.int32)
    keys = jnp.asarray(
        np.stack([np.asarray(jax.random.PRNGKey(int(s)))
                  for s in rng.integers(0, 1 << 30, size=t)]))

    fn = shard_map(
        lambda lg, tp, tk, kk: sample_vocab_parallel(lg, v, tp, tk, kk),
        mesh=MESH, in_specs=(P(), P(), P(), P()), out_specs=P(),
        check_vma=False)
    toks = np.asarray(jax.device_get(jax.jit(fn)(logits, temp, top_k, keys)))
    lg = np.asarray(logits)
    for i in range(t):
        topk_ids = np.argsort(lg[i])[::-1][:k]
        kth = lg[i][topk_ids[-1]]
        assert lg[i][toks[i]] >= kth, (i, toks[i], k)
        if temp[i] <= 0 or k == 1:
            assert toks[i] == int(np.argmax(lg[i]))


# ---------------------------------------------------------------------------
# Scheduler surface: streaming events, stats, validation
# ---------------------------------------------------------------------------


def test_streaming_events_are_contiguous_per_request():
    sched = scheduler("dense", 2)
    rng = np.random.default_rng(21)
    reqs = make_requests(rng, 4, max_gen=4)
    for r in reqs:
        sched.submit(r)
    events = []
    done = sched.run(on_token=events.append)
    seen: dict = {}
    for ev in events:
        assert ev.index == seen.get(ev.rid, -1) + 1, "gap in streamed tokens"
        seen[ev.rid] = ev.index
    for r in reqs:
        toks = [ev.token for ev in events if ev.rid == r.rid]
        np.testing.assert_array_equal(np.asarray(toks, np.int32),
                                      done[r.rid].tokens)
        dones = [ev.done for ev in events if ev.rid == r.rid]
        assert dones[-1] and not any(dones[:-1])


def test_scheduler_stats_and_occupancy():
    sched = scheduler("dense", 2)
    base = sched.stats()
    rng = np.random.default_rng(31)
    reqs = make_requests(rng, 3, max_gen=3)
    run_scheduler(sched, reqs)
    st_ = sched.stats()
    assert st_["prefills"] - base["prefills"] == 3
    assert st_["tokens_generated"] - base["tokens_generated"] == sum(
        r.max_new_tokens for r in reqs)
    assert 0 < st_["mean_occupancy"] <= 2


def test_scheduler_validation_errors():
    m, params = model_and_params("dense")
    spec = DecodeSpec(cache_len=RING, batch_global=2, batch_sharded=False,
                      sampling=False)
    sched = ContinuousScheduler(m, MESH, spec, params)
    with pytest.raises(ValueError, match="sampling"):
        sched.submit(Request(rid="s", prompt=[1, 2], max_new_tokens=2,
                             temperature=0.9))
    with pytest.raises(ValueError, match="exceeds"):
        sched.submit(Request(rid="long", prompt=list(range(RING + 1)),
                             max_new_tokens=1))
    with pytest.raises(ValueError, match="non-empty"):
        sched.submit(Request(rid="empty", prompt=[], max_new_tokens=1))
    with pytest.raises(ValueError, match="max_new_tokens"):
        sched.submit(Request(rid="zero", prompt=[1], max_new_tokens=0))
    sched.submit(Request(rid="dup", prompt=[1, 2], max_new_tokens=1))
    with pytest.raises(ValueError, match="duplicate"):
        sched.submit(Request(rid="dup", prompt=[1, 2], max_new_tokens=1))


def test_eos_frees_slot_early():
    """A request that hits its eos_id stops (eos included in the stream) and
    its slot is reused; remaining requests are unaffected."""
    m, params = model_and_params("dense")
    rng = np.random.default_rng(41)
    sched = scheduler("dense", 2)
    prompt = rng.integers(0, VOCAB, size=4).tolist()
    free_run = solo_tokens("dense", prompt, 8)
    eos = int(free_run[2])  # stop at the 3rd token the model would emit
    reqs = [Request(rid=f"t{next(_RID)}", prompt=prompt, max_new_tokens=8,
                    eos_id=eos),
            make_requests(rng, 1, max_gen=4)[0],
            make_requests(rng, 1, max_gen=4)[0]]
    outs = run_scheduler(sched, reqs)
    np.testing.assert_array_equal(outs[0], free_run[:3])
    for r, got in zip(reqs[1:], outs[1:]):
        np.testing.assert_array_equal(
            got, solo_tokens("dense", r.prompt, r.max_new_tokens))


def test_paged_pool_capacity_validation():
    """Satellite: with the paged pool the submit-time bound is pool blocks,
    not ring length — the solo path raises a clear PoolExhausted when the
    lanes cannot all fit, and both entry points insist on chunked prefill
    (paged serving has no whole-prompt float path)."""
    from repro.serve.kv_pool import PoolExhausted

    m, params = model_and_params("dense")
    # batch 2 x 4 blocks/slot = 8 blocks needed; the pool holds one row (4)
    pspec = DecodeSpec(cache_len=RING, batch_global=2, batch_sharded=False,
                       sampling=True, kv_block_size=8, kv_pool_blocks=4)
    with pytest.raises(ValueError, match="chunked admission"):
        ContinuousScheduler(m, MESH, pspec, params, gather_key=GATHER_KEY)
    eng = ServeEngine(m, MESH, pspec)
    prompt = {"tokens": jnp.ones((2, 4), jnp.int32)}
    with pytest.raises(ValueError, match="chunked prefill"):
        eng.generate(params, prompt, {"tokens": P(None)}, n_tokens=2,
                     key=GATHER_KEY, fold_step_keys=False)
    with pytest.raises(PoolExhausted, match="kv-pool-blocks"):
        eng.generate(params, prompt, {"tokens": P(None)}, n_tokens=2,
                     key=GATHER_KEY, fold_step_keys=False, prefill_chunk=4)
