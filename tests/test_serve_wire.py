"""Serving a quantized-domain checkpoint with zero conversion: the stored
wire codes feed QSDPEngine.gather_rowquant_wire / rowquant_matmul directly,
never passing through a quantize or dequantize of the dense matrix."""
import jax
import jax.numpy as jnp
import numpy as np
from functools import partial
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map
from repro.core.qsdp import MeshSpec, QSDPConfig
from repro.core.quant import QuantizedParam, qparam_decode
from repro.models.config import ModelConfig
from repro.models.decode import DecodeSpec
from repro.models.transformer import Model
from repro.optim import AdamWConfig, make_adamw
from repro.serve import ServeEngine
from repro.serve.engine import prepare_wire_params, wire_param_pspecs
from repro.train import load_checkpoint, save_checkpoint
from repro.train.step import (
    dequantize_train_state,
    init_train_state,
    quantize_train_state,
    state_pspecs,
)

MS = MeshSpec(axes=("data", "model"), shape=(1, 1))
# full-precision collectives + f32 compute so the ONLY difference between
# wire-serve and f32-serve is the MLP matmul route (codes vs dense) — which
# decodes to identical values
QS = QSDPConfig(quantize_weights=False, quantize_grads=False, coalesce=True,
                bucket_size=64, min_quant_size=256, compute_dtype="float32")


def _model():
    cfg = ModelConfig(name="wq", arch_type="dense", n_layers=2, d_model=64,
                      vocab_size=128, n_heads=4, n_kv_heads=2, head_dim=16,
                      d_ff=128)
    return Model(cfg, MS, QS)


def _quantized_state(model):
    opt = make_adamw(AdamWConfig(lr=1e-3))
    state = init_train_state(model, opt, jax.random.PRNGKey(0))
    return quantize_train_state(state, model, jax.random.PRNGKey(5))


def test_gather_rowquant_wire_is_zero_conversion(mesh11):
    """RowQuantWeight built from stored codes carries the checkpoint BYTES
    (codes + per-bucket affine) untouched, and its affine decode equals the
    dequantized parameter to within one fp rounding (the mul+add may or may
    not be FMA-contracted depending on the surrounding program)."""
    model = _model()
    state = _quantized_state(model)
    prepared = prepare_wire_params(model, state.params)
    name = "layers/w_gate"
    qp = prepared[name]
    assert isinstance(qp, QuantizedParam) and qp.wire.ndim == 4
    dense = qparam_decode(state.params[name])  # (L, 1, 1, n_local)
    spec = model.specs[name]
    k_dim, n_dim = spec.tp_local_shape(1)
    eng = model.engine
    bucket = qp.cfg.bucket_size

    @partial(shard_map, mesh=mesh11,
             in_specs=(P(None, "model", ("data",), None),),
             out_specs=(P(), P(), P()), check_vma=False)
    def gather_layer0(wire):
        qp0 = QuantizedParam(wire[0], qp.cell_shape, qp.cfg)
        rw = eng.gather_rowquant_wire(name, qp0)
        return rw.codes[None], rw.scale[None], rw.zero[None]

    with mesh11:
        codes, scale, zero = (x[0] for x in gather_layer0(qp.wire))
    # byte-identity with the stored wire segment of layer 0
    from repro.core.quant import wire_unpack
    q0 = wire_unpack(qp.wire[0].reshape(-1), qp.n, qp.cfg)
    np.testing.assert_array_equal(np.asarray(codes).reshape(-1, bucket),
                                  np.asarray(q0.codes))
    np.testing.assert_array_equal(np.asarray(scale).reshape(-1), np.asarray(q0.scale))
    np.testing.assert_array_equal(np.asarray(zero).reshape(-1), np.asarray(q0.zero))
    # value-identity up to one fp rounding of the affine
    seg = n_dim // scale.shape[1]
    w = (np.asarray(codes, np.float32) * np.repeat(np.asarray(scale), seg, axis=1)
         + np.repeat(np.asarray(zero), seg, axis=1))
    ref = np.asarray(dense[0]).reshape(k_dim, n_dim)
    np.testing.assert_allclose(w, ref, rtol=0, atol=1.2e-7)


def test_prepare_wire_params_forms():
    model = _model()
    state = _quantized_state(model)
    prepared = prepare_wire_params(model, state.params)
    for base in ("w_gate", "w_up", "w_down"):
        v = prepared[f"layers/{base}"]
        assert isinstance(v, QuantizedParam)
        assert v.wire.ndim == 4 and v.wire.shape[0] == 2  # per-layer slices
    # everything else decoded to dense f32 rest leaves
    for name, v in prepared.items():
        if name.split("/")[-1] not in ("w_gate", "w_up", "w_down"):
            assert not isinstance(v, QuantizedParam), name
            ref = state.params[name]
            if isinstance(ref, QuantizedParam):
                np.testing.assert_array_equal(np.asarray(v),
                                              np.asarray(qparam_decode(ref)))
    # pspecs: wire leaves get the stacked wire spec
    ps = wire_param_pspecs(model, prepared)
    assert ps["layers/w_gate"] == P(None, "model", ("data",), None)
    assert ps["layers/attn_norm"] == model.specs["layers/attn_norm"].rest_pspec(MS)


def test_serve_from_wire_matches_f32_serve(tmp_path, mesh11):
    """generate() from a v2 quantized checkpoint (codes straight into the
    rowquant matmul) == generate() from the dequantized f32 params."""
    model = _model()
    state = _quantized_state(model)
    path = str(tmp_path / "qckpt")
    save_checkpoint(path, state)
    loaded = load_checkpoint(path, mesh11,
                             state_pspecs(model, quantized_state=True),
                             model=model)
    prepared = prepare_wire_params(model, loaded.params)
    f32_params = dequantize_train_state(state).params

    spec = DecodeSpec(cache_len=32, batch_global=2, batch_sharded=False)
    prompt = {"tokens": jax.random.randint(jax.random.PRNGKey(2), (2, 8), 0, 128)}
    ps = {"tokens": P(None)}
    with mesh11:
        eng_w = ServeEngine(model, mesh11, spec, params=prepared)
        toks_w = np.asarray(jax.device_get(
            eng_w.generate(prepared, prompt, ps, n_tokens=4)))
        eng_f = ServeEngine(model, mesh11, spec)
        toks_f = np.asarray(jax.device_get(
            eng_f.generate(f32_params, prompt, ps, n_tokens=4)))
    np.testing.assert_array_equal(toks_w, toks_f)
