"""Per-architecture smoke tests (deliverable f): a REDUCED variant of each
assigned family runs one forward/train step on CPU — output shapes + no
NaNs.  Runs on the trivial (1,1) mesh so it works on a single device."""
import jax
import jax.numpy as jnp
import pytest
from functools import partial
from jax.sharding import PartitionSpec as P

from repro import configs
from repro.compat import shard_map
from repro.core.qsdp import MeshSpec, QSDPConfig
from repro.models.transformer import Model

MS = MeshSpec(axes=("data", "model"), shape=(1, 1))
QS = QSDPConfig(min_quant_size=256)
B, S = 2, 64


def _batch(cfg):
    batch = {
        "tokens": jnp.ones((B, S), jnp.int32),
        "labels": jnp.ones((B, S), jnp.int32),
    }
    specs = {"tokens": P(("data",)), "labels": P(("data",))}
    if cfg.arch_type == "vlm":
        batch["vision_embeds"] = jnp.zeros((B, S, cfg.d_model), jnp.bfloat16)
        batch["vision_mask"] = jnp.zeros((B, S), bool)
        batch["positions"] = jnp.broadcast_to(jnp.arange(S), (3, B, S))
        specs.update(vision_embeds=P(("data",)), vision_mask=P(("data",)),
                     positions=P(None, ("data",)))
    if cfg.arch_type == "audio":
        batch["audio_embeds"] = 0.1 * jax.random.normal(
            jax.random.PRNGKey(5), (B, S // cfg.enc_frames_ratio, cfg.d_model))
        specs["audio_embeds"] = P(("data",))
    return batch, specs


@pytest.mark.parametrize("arch", configs.list_archs())
def test_smoke_train_step(arch, mesh11):
    cfg = configs.get_smoke(arch)
    assert cfg.n_layers <= 4 and cfg.d_model <= 512
    assert cfg.n_experts <= 4
    model = Model(cfg, MS, QS)
    params = model.init_params(jax.random.PRNGKey(0))
    batch, bspecs = _batch(cfg)

    @partial(shard_map, mesh=mesh11,
             in_specs=(model.param_pspecs(), bspecs, P()),
             out_specs=(P(), model.param_pspecs()), check_vma=False)
    def step(p, b, k):
        loss, grads = jax.value_and_grad(model.loss_fn)(p, b, k)
        return loss, grads

    with mesh11:
        loss, grads = jax.jit(step)(params, batch, jax.random.PRNGKey(1))
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss)), float(loss)
    for name, g in grads.items():
        assert g.shape == params[name].shape, name
        assert bool(jnp.all(jnp.isfinite(g))), name


@pytest.mark.parametrize("arch", configs.ASSIGNED)
def test_full_config_matches_assignment(arch):
    """The full (non-smoke) configs carry the exact assigned hyperparams."""
    cfg = configs.get_config(arch)
    expected = {
        "qwen2_5_3b": (36, 2048, 16, 2, 11008, 151936),
        "yi_6b": (32, 4096, 32, 4, 11008, 64000),
        "seamless_m4t_large_v2": (24, 1024, 16, 16, 8192, 256206),
        "qwen1_5_32b": (64, 5120, 40, 40, 27392, 152064),
        "olmoe_1b_7b": (16, 2048, 16, 16, None, 50304),
        "yi_34b": (60, 7168, 56, 8, 20480, 64000),
        "zamba2_7b": (81, 3584, 32, 32, 14336, 32000),
        "qwen2_vl_72b": (80, 8192, 64, 8, 29568, 152064),
        "qwen3_moe_235b_a22b": (94, 4096, 64, 4, None, 151936),
        "mamba2_370m": (48, 1024, 0, 0, None, 50280),
    }[arch]
    L, d, h, kv, ff, v = expected
    assert cfg.n_layers == L and cfg.d_model == d and cfg.vocab_size == v
    assert cfg.n_heads == h and cfg.n_kv_heads == kv
    if ff is not None:
        assert cfg.d_ff == ff
    assert cfg.source  # pool citation present


def test_moe_configs_expert_counts():
    assert configs.get_config("olmoe_1b_7b").n_experts == 64
    assert configs.get_config("olmoe_1b_7b").moe_top_k == 8
    c = configs.get_config("qwen3_moe_235b_a22b")
    assert c.n_experts == 128 and c.moe_top_k == 8 and c.moe_d_ff == 1536


def test_ssm_config_state():
    assert configs.get_config("mamba2_370m").ssm_state == 128
    assert configs.get_config("zamba2_7b").ssm_state == 64
