"""Self-speculative decoding suite: the low-bit wire codes draft, the
serving-precision model verifies.

Load-bearing invariants:

* Committed tokens are BIT-IDENTICAL to non-speculative decode — greedy
  streams match the solo batch-of-1 reference
  (``ServeEngine.generate(..., fold_step_keys=False)``) and sampled
  streams match the non-speculative scheduler, on the ring AND paged KV
  paths.  Speculation is a pure launch-count optimization; it may never
  change a token.
* The 2/3/4-bit rowquant re-quantization of the serving weights agrees
  with the serving-precision greedy argmax often enough to be a useful
  draft: acceptance per verify launch stays above a fixed per-bit-width
  threshold on the toy model (teacher-forced by construction — every
  rejected draft token is replaced by the verifier's own output).
* Acceptance is DETERMINISTIC: identical across runs, and each request's
  committed stream (and launch count) is independent of what else shares
  the batch — per-slot draft depth depends only on that slot's own budget
  and position.

Property tests run with real ``hypothesis`` when installed or the seeded
sweep stub in tests/_hypothesis_stub.py (conftest.py installs it).
Schedulers/engines are cached at module scope; compiles dominate, and a
dirty slot pool is exactly what the hygiene invariants elsewhere cover.
"""
import itertools

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st
from jax.sharding import PartitionSpec as P

from repro.core.qsdp import MeshSpec, QSDPConfig
from repro.core.quant import QuantizedParam
from repro.models.config import ModelConfig
from repro.models.decode import DecodeSpec
from repro.models.transformer import Model
from repro.serve import ContinuousScheduler, Request, ServeEngine
from repro.serve.engine import make_draft_params, make_sample_params

MS = MeshSpec(axes=("data", "model"), shape=(1, 1))
MESH = jax.make_mesh((1, 1), ("data", "model"))
GATHER_KEY = jax.random.PRNGKey(7)
RING = 32
VOCAB = 256
CFG = ModelConfig(name="spec-toy", arch_type="dense", n_layers=2, d_model=64,
                  vocab_size=VOCAB, n_heads=4, n_kv_heads=2, head_dim=16,
                  d_ff=128)

# acceptance-per-verify-launch floors on the toy model (1.0 = the verifier
# alone; anything above it means the draft's argmax agreed at least
# sometimes).  Coarser drafts agree less — on these RANDOM weights the
# near-uniform logits flip under 2-bit noise often enough that some
# compositions accept nothing, so 2-bit gets a fixed composition (below)
# instead of a sweep floor.
ACCEPT_FLOOR = {2: 1.05, 3: 1.1, 4: 1.5}

_state: dict = {}


def model_and_params():
    if "model" not in _state:
        m = Model(CFG, MS, QSDPConfig(min_quant_size=256))
        _state["model"] = (m, m.init_params(jax.random.PRNGKey(0)))
    return _state["model"]


def _spec(slots, *, paged=False, bits=0, depth=0):
    return DecodeSpec(cache_len=RING, batch_global=slots,
                      batch_sharded=False, sampling=True,
                      kv_block_size=8 if paged else 0,
                      draft_bits=bits, draft_depth=depth)


def scheduler(bits, depth, *, paged=False, slots=4) -> ContinuousScheduler:
    key = ("sched", bits, depth, paged, slots)
    if key not in _state:
        m, params = model_and_params()
        kw = dict(prefill_chunk=8, prefill_buckets=3) if paged else {}
        _state[key] = ContinuousScheduler(
            m, MESH, _spec(slots, paged=paged, bits=bits, depth=depth),
            params, gather_key=GATHER_KEY, **kw)
    return _state[key]


def solo_tokens(prompt, gen, *, paged=False, temperature=0.0, top_k=0,
                seed=0):
    """NON-speculative solo batch-of-1 reference with the fixed gather
    key — the stream speculation must reproduce bit-for-bit."""
    key = ("solo", paged)
    if key not in _state:
        m, params = model_and_params()
        _state[key] = (ServeEngine(m, MESH, _spec(1, paged=paged)), params)
    eng, params = _state[key]
    kw = dict(prefill_chunk=8, prefill_buckets=3) if paged else {}
    out = eng.generate(
        params, {"tokens": jnp.asarray(np.asarray(prompt, np.int32)[None])},
        {"tokens": P(None)}, n_tokens=gen, key=GATHER_KEY,
        sample=make_sample_params(temperature, top_k, seed),
        fold_step_keys=False, **kw)
    return np.asarray(jax.device_get(out))[0]


_RID = itertools.count()


def make_requests(rng, n, tag, max_gen=6, sampled=False):
    reqs = []
    for i in range(n):
        t, k = 0.0, 0
        if sampled and i % 2:
            t, k = float(rng.uniform(0.5, 1.2)), int(rng.integers(0, 6))
        reqs.append(Request(
            rid=f"{tag}{i}.{next(_RID)}",
            prompt=rng.integers(0, VOCAB,
                                size=int(rng.integers(3, 10))).tolist(),
            max_new_tokens=int(rng.integers(1, max_gen + 1)),
            temperature=t, top_k=k, seed=1000 + i))
    return reqs


def run_sched(sched, reqs):
    base = sched.stats()
    for r in reqs:
        sched.submit(Request(rid=r.rid, prompt=r.prompt,
                             max_new_tokens=r.max_new_tokens,
                             temperature=r.temperature, top_k=r.top_k,
                             seed=r.seed))
    done = sched.run(max_steps=2000)
    st = sched.stats()
    delta = {k: st[k] - base[k]
             for k in ("spec_tokens", "spec_lane_steps", "draft_launches",
                       "verify_launches", "decode_launches",
                       "tokens_generated")}
    return done, delta


# ---------------------------------------------------------------------------
# draft parameter construction
# ---------------------------------------------------------------------------


def test_make_draft_params_quantizes_layer_matmuls_shares_rest():
    m, params = model_and_params()
    draft = make_draft_params(m, params, 4)
    assert set(draft) == set(params)
    quantized = [n for n, v in draft.items()
                 if isinstance(v, QuantizedParam)
                 and not isinstance(params[n], QuantizedParam)]
    assert quantized, "no layer weight was re-quantized for the draft"
    for n in quantized:
        assert n.startswith("layers/"), n
        assert draft[n].cfg.bits == 4
        assert draft[n].cfg.mode == "nearest"  # deterministic draft
    # everything else is the SAME array object — zero extra bytes
    for n, v in draft.items():
        if n not in quantized:
            assert v is params[n], n


@pytest.mark.parametrize("bits", [1, 9])
def test_make_draft_params_rejects_bad_bits(bits):
    m, params = model_and_params()
    with pytest.raises(ValueError):
        make_draft_params(m, params, bits)


def test_decode_spec_speculative_property():
    assert _spec(4, bits=4, depth=4).speculative
    assert not _spec(4).speculative
    assert not _spec(4, bits=4, depth=1).speculative  # depth 1 = plain
    assert not _spec(4, bits=0, depth=4).speculative


# ---------------------------------------------------------------------------
# bit-identity: speculative == non-speculative, ring and paged
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("bits,depth", [(4, 4), (2, 3)])
def test_greedy_speculative_matches_solo_ring(bits, depth):
    rng = np.random.default_rng(10 * bits + depth)
    reqs = make_requests(rng, 5, f"g{bits}")
    done, _ = run_sched(scheduler(bits, depth), reqs)
    for r in reqs:
        ref = solo_tokens(r.prompt, r.max_new_tokens)
        assert np.array_equal(done[r.rid].tokens, ref), \
            (r.rid, done[r.rid].tokens.tolist(), ref.tolist())


def test_greedy_speculative_matches_solo_paged():
    rng = np.random.default_rng(3)
    reqs = make_requests(rng, 5, "p")
    done, delta = run_sched(scheduler(4, 4, paged=True), reqs)
    for r in reqs:
        ref = solo_tokens(r.prompt, r.max_new_tokens, paged=True)
        assert np.array_equal(done[r.rid].tokens, ref), \
            (r.rid, done[r.rid].tokens.tolist(), ref.tolist())
    assert delta["verify_launches"] > 0  # speculation actually engaged


def test_sampled_speculative_matches_plain_scheduler():
    """Sampled streams too: committed tokens always come from the verifier
    and the draft shares the per-slot sampling streams, so the speculative
    scheduler reproduces the non-speculative one bit-for-bit."""
    rng = np.random.default_rng(4)
    reqs = make_requests(rng, 6, "s", sampled=True)
    done_spec, delta = run_sched(scheduler(4, 4), reqs)
    done_plain, _ = run_sched(scheduler(0, 0), reqs)
    for r in reqs:
        assert np.array_equal(done_spec[r.rid].tokens,
                              done_plain[r.rid].tokens), r.rid
    assert delta["spec_tokens"] > 0


# ---------------------------------------------------------------------------
# draft quality: acceptance above a fixed per-bit-width floor
# ---------------------------------------------------------------------------


@settings(max_examples=6, deadline=None)
@given(bits=st.sampled_from([3, 4]), seed=st.integers(0, 3))
def test_draft_acceptance_above_floor(bits, seed):
    """The low-bit draft agrees with the serving-precision greedy argmax
    at a useful rate: tokens committed per verify launch stay above the
    per-bit floor (1.0 would mean the draft never helped), while the
    committed stream stays bit-identical to the solo reference."""
    rng = np.random.default_rng(100 + seed)
    reqs = make_requests(rng, 4, f"a{bits}_{seed}", max_gen=8)
    done, delta = run_sched(scheduler(bits, 4), reqs)
    for r in reqs:
        ref = solo_tokens(r.prompt, r.max_new_tokens)
        assert np.array_equal(done[r.rid].tokens, ref), r.rid
    assert delta["spec_lane_steps"] > 0
    rate = delta["spec_tokens"] / delta["spec_lane_steps"]
    assert rate >= ACCEPT_FLOOR[bits], (bits, rate)


def test_draft_acceptance_2bit_fixed_composition():
    """Even the 2-bit draft clears its floor on a fixed composition (and
    acceptance there is deterministic, so this is a stable threshold, not
    a flaky sample)."""
    rng = np.random.default_rng(100)
    reqs = make_requests(rng, 4, "a2fix", max_gen=8)
    done, delta = run_sched(scheduler(2, 4), reqs)
    for r in reqs:
        assert np.array_equal(done[r.rid].tokens,
                              solo_tokens(r.prompt, r.max_new_tokens)), r.rid
    rate = delta["spec_tokens"] / max(delta["spec_lane_steps"], 1)
    assert rate >= ACCEPT_FLOOR[2], rate


# ---------------------------------------------------------------------------
# determinism: across runs and batch compositions
# ---------------------------------------------------------------------------


def test_acceptance_deterministic_across_runs():
    rng = np.random.default_rng(5)
    reqs = make_requests(rng, 5, "d", sampled=True)
    s1 = ContinuousScheduler(*_fresh_args(), gather_key=GATHER_KEY)
    s2 = ContinuousScheduler(*_fresh_args(), gather_key=GATHER_KEY)
    done1, delta1 = run_sched(s1, reqs)
    done2, delta2 = run_sched(s2, reqs)
    for r in reqs:
        assert np.array_equal(done1[r.rid].tokens, done2[r.rid].tokens), r.rid
    assert delta1 == delta2, (delta1, delta2)  # identical launch accounting


def _fresh_args():
    m, params = model_and_params()
    return m, MESH, _spec(4, bits=4, depth=4), params


def test_acceptance_independent_of_batch_composition():
    """Each request's committed stream is a function of the request alone:
    per-slot draft depth depends only on that slot's own budget/position,
    dead lanes never enter live lanes' reductions.  Resubmitting the same
    requests in a different arrival order, mixed with fillers (including a
    gen-1 request that forces a k=1 lane inside deeper launches), must
    reproduce every stream."""
    rng = np.random.default_rng(6)
    base = make_requests(rng, 3, "b", sampled=True)
    fillers = make_requests(rng, 3, "f", sampled=True)
    fillers[0] = Request(rid=fillers[0].rid, prompt=fillers[0].prompt,
                         max_new_tokens=1, seed=fillers[0].seed)
    # same requests under fresh rids — a stream is a function of the
    # request's content and seed, never its id or arrival order
    redo = {r.rid: Request(rid=f"{r.rid}.redo", prompt=r.prompt,
                           max_new_tokens=r.max_new_tokens,
                           temperature=r.temperature, top_k=r.top_k,
                           seed=r.seed)
            for r in base}
    done_a, _ = run_sched(scheduler(4, 4), base)
    done_b, _ = run_sched(scheduler(4, 4),
                          [fillers[0], redo[base[2].rid], fillers[1],
                           redo[base[0].rid], fillers[2], redo[base[1].rid]])
    for r in base:
        assert np.array_equal(done_a[r.rid].tokens,
                              done_b[f"{r.rid}.redo"].tokens), \
            (r.rid, done_a[r.rid].tokens.tolist(),
             done_b[f"{r.rid}.redo"].tokens.tolist())


# ---------------------------------------------------------------------------
# validation
# ---------------------------------------------------------------------------


def test_speculative_spec_validation():
    m, _ = model_and_params()
    with pytest.raises(ValueError):
        ServeEngine(m, MESH, _spec(2, bits=1, depth=4))  # bits out of range
