"""Theorem 2 / Corollary 3 / Lemma 4-5 validation (core/theory.py).

These are the paper's own claims, checked against its own parameter
choices on well-conditioned quadratic PL objectives."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.quant import q_coinflip, q_nearest, q_shift
from repro.core.theory import (
    Quadratic, make_quadratic, run_qsgd, theorem2_params,
)

KEY = jax.random.PRNGKey(0)


def _setup(kappa=4.0, n=64, delta_star=0.5, eps=1e-3, sigma=0.0):
    obj = make_quadratic(KEY, n=n, kappa=kappa)
    params = theorem2_params(obj.alpha, obj.beta, delta_star, eps, sigma,
                             f0_gap=float(obj.f(jnp.zeros(n))))
    bench = obj.lattice_opt_value(delta_star, jax.random.PRNGKey(7))
    return obj, params, bench


def test_theorem2_deterministic_convergence():
    """Exact gradients: E f(x_T) <= E f(x*_{r,d*}) + eps (Theorem 2)."""
    obj, params, bench = _setup()
    # average over quantization randomness
    finals = []
    for s in range(8):
        xT, _ = run_qsgd(obj, jnp.zeros(64), params, jax.random.PRNGKey(s))
        finals.append(float(obj.f(xT)))
    assert np.mean(finals) <= bench + 1e-3 + 1e-6, (np.mean(finals), bench)


def test_theorem2_stochastic_convergence():
    obj, params, bench = _setup(sigma=0.5, eps=0.05)
    finals = []
    for s in range(8):
        xT, _ = run_qsgd(obj, jnp.zeros(64), params, jax.random.PRNGKey(s), sigma=0.5)
        finals.append(float(obj.f(xT)))
    assert np.mean(finals) <= bench + 0.05 + 1e-6


def test_theorem2_linear_contraction_rate():
    """Error contracts at least as fast as (1 - eta*alpha/(2 beta)) per step
    in the deterministic case (Lemma 9/10)."""
    obj, params, bench = _setup()
    _, fs = run_qsgd(obj, jnp.zeros(64), params, jax.random.PRNGKey(1))
    gaps = np.maximum(np.asarray(fs) - bench, 1e-12)
    # only the transient matters: once the gap hits the quantization floor
    # the ratio is ~1 by construction.  Use steps with gap > 100x the floor.
    floor = max(gaps[-1], 1e-9)
    live = np.nonzero(gaps > 100 * floor)[0]
    assert len(live) >= 3, (gaps[:5], floor)
    idx = live[: max(3, len(live) // 2)]
    ratios = gaps[idx[1:]] / gaps[idx[:-1]]
    rate = 1.0 - 0.5 * params.eta * obj.alpha / obj.beta
    assert np.median(ratios) <= rate + 0.05


def test_naive_rtn_breaks_convergence():
    """The paper's motivating failure: round-to-nearest (no random shift)
    stalls far above the lattice optimum when the step is small relative to
    the grid (Section 6: 'straightforward round-to-nearest ... does not
    converge')."""
    obj, params, bench = _setup()
    import dataclasses
    # coarse grid + RTN: iterates freeze as soon as steps < delta/2
    coarse = dataclasses.replace(params, delta=0.5)
    x_rtn, _ = run_qsgd(obj, jnp.zeros(64), coarse, KEY, weight_q="nearest")
    x_shift_runs = [run_qsgd(obj, jnp.zeros(64), coarse, jax.random.PRNGKey(s),
                             weight_q="shift")[0] for s in range(6)]
    f_rtn = float(obj.f(x_rtn))
    f_shift = np.mean([float(obj.f(x)) for x in x_shift_runs])
    assert f_shift < f_rtn, (f_shift, f_rtn)


def test_corollary3_gradient_quantization():
    """Adding an unbiased gradient quantizer preserves convergence
    (Corollary 3) with the adjusted eta."""
    obj = make_quadratic(KEY, n=64, kappa=4.0)
    delta_star, eps = 0.5, 0.05
    g_delta = 0.05
    # sigma_grad^2 <= delta_g * G_l1 (paper bound); use observed G_l1 at x0
    g_l1 = float(jnp.sum(jnp.abs(obj.grad(jnp.zeros(64)))))
    sigma_q = np.sqrt(g_delta * g_l1)
    params = theorem2_params(obj.alpha, obj.beta, delta_star, eps, 0.0,
                             f0_gap=float(obj.f(jnp.zeros(64))), sigma_q=sigma_q)
    bench = obj.lattice_opt_value(delta_star, jax.random.PRNGKey(7))
    finals = [float(obj.f(run_qsgd(obj, jnp.zeros(64), params,
                                   jax.random.PRNGKey(s), grad_q_delta=g_delta)[0]))
              for s in range(8)]
    assert np.mean(finals) <= bench + eps + 1e-6


def test_lemma4_variance_contraction():
    """E||Q_d(x) - x||^2 <= (d/d*) E_r ||x*_{r,d*} - x||^2 with the RHS over
    nearest lattice points (Lemma 4), checked by Monte Carlo."""
    delta_star = 1.0
    delta = delta_star / 8
    x = jax.random.normal(KEY, (128,)) * 2.3
    keys = jax.random.split(KEY, 4000)
    lhs = jnp.mean(jax.vmap(
        lambda k: jnp.sum((q_shift(x, delta, k) - x) ** 2))(keys))
    rs = jax.random.uniform(jax.random.PRNGKey(5), (4000,), minval=-0.5, maxval=0.5)

    def nearest_on(r):
        y = delta_star * jnp.round((x - r * delta_star) / delta_star) + r * delta_star
        return jnp.sum((y - x) ** 2)

    rhs = jnp.mean(jax.vmap(nearest_on)(rs))
    assert float(lhs) <= (delta / delta_star) * float(rhs) * 1.05


def test_lemma6_scalar_inequality():
    """(1-{y}){y} <= k (1-{y/k}) {y/k} for integer k."""
    ys = np.linspace(0, 7, 1401)
    for k in (2, 3, 8):
        f = lambda v: (v - np.floor(v))
        lhs = (1 - f(ys)) * f(ys)
        rhs = k * (1 - f(ys / k)) * f(ys / k)
        assert np.all(lhs <= rhs + 1e-9)


def test_theorem2_params_formulas():
    p = theorem2_params(alpha=1.0, beta=2.0, delta_star=1.0, eps=0.1,
                        sigma=1.0, f0_gap=10.0)
    assert p.eta == pytest.approx(min(0.3 * 0.1 * 1.0 / 1.0, 1.0))
    assert p.delta == pytest.approx(p.eta / np.ceil(16 * 4))
    assert p.lr == pytest.approx(p.eta / 2.0)
