"""repro.tune — deployment-plan autotuner tier-1 coverage.

  * DeploymentPlan save/load round-trip, versioning, mesh validation,
    QSDPConfig round-trip (unknown-field rejection)
  * the per-layer coalesce byte-threshold policy in the QSDP engine
    (the coalesced small-scale regression fix) + bit-exactness of a
    MIXED threshold policy against the per-tensor path
  * cost-model conformance: predicted HLO all-gather counts vs the
    compiled train step on the (1,1) mesh (multi-device counts are pinned
    analytically here and against real compiled HLO by
    scripts/check_tune_costmodel.py via test_distributed.py)
  * search determinism (exhaustive + simulated annealing) and candidate
    space validity
  * the emitted plan round-trips through BOTH launchers (autotune ->
    train --plan / serve --plan)
"""
import dataclasses
from functools import partial

import jax
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map
from repro.core.qsdp import MeshSpec, QSDPConfig, layer_gather_launches
from repro.models.config import ModelConfig
from repro.models.transformer import Model
from repro.roofline.hlo_analyzer import analyze_hlo
from repro.tune import (
    PLAN_VERSION,
    Candidate,
    DeploymentPlan,
    HW_PRESETS,
    LayerPolicy,
    crossover_bytes,
    enumerate_space,
    exhaustive_search,
    plan_layer_policies,
    predict_hlo_gather_counts,
    predict_step_time,
    simulated_annealing,
)
from repro.tune.cost_model import CPU_SMOKE, TPU_V5E, layer_groups

MCFG = ModelConfig(name="t", arch_type="dense", n_layers=3, d_model=64,
                   vocab_size=256, n_heads=4, n_kv_heads=2, head_dim=16,
                   d_ff=128)
MS11 = MeshSpec(axes=("data", "model"), shape=(1, 1))
MS42 = MeshSpec(axes=("data", "model"), shape=(4, 2))


def _engine(ms=MS11, **qkw):
    qkw.setdefault("min_quant_size", 128)
    return Model(MCFG, ms, QSDPConfig(**qkw)).engine


def _layer_names(engine):
    return tuple(n for n in sorted(engine.specs) if n.startswith("layers/"))


# ---------------------------------------------------------------------------
# DeploymentPlan
# ---------------------------------------------------------------------------


def _mk_plan(**over):
    base = dict(
        version=PLAN_VERSION, arch="t", mesh_axes=("data", "model"),
        mesh_shape=(4, 2), hw="cpu-smoke",
        qsdp={"weight_bits": 4, "grad_bits": 8, "coalesce": True,
              "coalesce_max_bytes": 1024, "min_quant_size": 128,
              "prefetch": False},
        serve={"slots": 4, "prefill_chunk": 8, "prefill_buckets": 2},
        layers=(LayerPolicy(group="layers", coalesce=False,
                            wire_buffer_bytes=4096, launches_per_tensor=23,
                            launches_coalesced=1),),
        predicted={"step_ms": 1.23456789}, measured={},
    )
    base.update(over)
    return DeploymentPlan(**base)


def test_plan_save_load_roundtrip(tmp_path):
    p = str(tmp_path / "plan.json")
    plan = _mk_plan()
    plan.save(p)
    loaded = DeploymentPlan.load(p)
    assert loaded.version == PLAN_VERSION
    assert loaded.mesh_axes == ("data", "model")
    assert loaded.mesh_shape == (4, 2)
    assert loaded.qsdp == plan.qsdp
    assert loaded.serve == plan.serve
    assert loaded.layers == plan.layers
    # floats are rounded to 4 decimals on disk (stable artifact diffs)
    assert loaded.predicted["step_ms"] == 1.2346


def test_plan_version_mismatch(tmp_path):
    d = _mk_plan().to_dict()
    d["version"] = PLAN_VERSION + 1
    with pytest.raises(ValueError, match="regenerate"):
        DeploymentPlan.from_dict(d)


def test_plan_validate_mesh():
    plan = _mk_plan()
    plan.validate_mesh(("data", "model"), (4, 2))  # tuned mesh: fine
    with pytest.raises(ValueError, match="re-run repro.tune.autotune"):
        plan.validate_mesh(("data", "model"), (1, 1))
    with pytest.raises(ValueError):
        plan.validate_mesh(("pod", "data", "model"), (1, 4, 2))


def test_plan_to_qsdp_config():
    qsdp = _mk_plan().to_qsdp_config(QSDPConfig())
    assert qsdp.weight_bits == 4 and qsdp.grad_bits == 8
    assert qsdp.coalesce and qsdp.coalesce_max_bytes == 1024
    assert qsdp.min_quant_size == 128
    with pytest.raises(ValueError, match="unknown fields"):
        _mk_plan(qsdp={"bogus_knob": 1}).to_qsdp_config(QSDPConfig())


# ---------------------------------------------------------------------------
# Engine threshold policy (the regression fix mechanism)
# ---------------------------------------------------------------------------


def test_layer_coalesced_threshold():
    eng = _engine(MS42, coalesce=True)
    names = _layer_names(eng)
    buf = eng.layer_wire_bytes(names)
    assert buf > 0
    assert eng.layer_coalesced(names)  # no threshold = always coalesce
    at = _engine(MS42, coalesce=True, coalesce_max_bytes=buf)
    below = _engine(MS42, coalesce=True, coalesce_max_bytes=buf - 1)
    never = _engine(MS42, coalesce=True, coalesce_max_bytes=0)
    off = _engine(MS42, coalesce=False, coalesce_max_bytes=10 ** 9)
    assert at.layer_coalesced(names)
    assert not below.layer_coalesced(names)
    assert not never.layer_coalesced(names)
    assert not off.layer_coalesced(names)  # coalesce=False wins


def test_layer_gather_launches_respects_threshold():
    names = list(_layer_names(_engine()))
    per_tensor = layer_gather_launches(_engine(coalesce=False), names)
    assert per_tensor == 23  # 7 quantized x 3 + 2 fp norms
    assert layer_gather_launches(
        _engine(coalesce=True, coalesce_max_bytes=0), names) == per_tensor
    assert layer_gather_launches(
        _engine(coalesce=True, coalesce_max_bytes=10 ** 9), names) == 1


def _loss_and_grads(mesh11, qcfg):
    model = Model(MCFG, MS11, qcfg)
    params = model.init_params(jax.random.PRNGKey(1))
    tokens = jax.random.randint(jax.random.PRNGKey(2), (2, 16), 0, 256)
    batch = {"tokens": tokens, "labels": tokens}

    @partial(shard_map, mesh=mesh11,
             in_specs=(model.param_pspecs(),
                       {"tokens": P(("data",)), "labels": P(("data",))}, P()),
             out_specs=(P(), model.param_pspecs()), check_vma=False)
    def f(p, b, k):
        loss, g = jax.value_and_grad(model.loss_fn)(p, b, k)
        return jax.lax.pmean(loss, ("data", "model")), g

    loss, g = jax.jit(f)(params, batch, jax.random.PRNGKey(3))
    return float(loss), jax.device_get(g)


def test_mixed_threshold_policy_bitexact(mesh11):
    """A threshold that coalesces SOME groups and not others must still be
    bit-exact vs the per-tensor path (same per-tensor quantization keys)."""
    eng = _engine(coalesce=True)
    bufs = sorted(eng.layer_wire_bytes(tuple(ns))
                  for _, ns, _ in layer_groups(eng))
    mid = bufs[len(bufs) // 2]  # between the smallest and largest group
    assert bufs[0] <= mid < bufs[-1]
    l0, g0 = _loss_and_grads(mesh11, QSDPConfig(min_quant_size=128,
                                                coalesce=False))
    l1, g1 = _loss_and_grads(mesh11, QSDPConfig(
        min_quant_size=128, coalesce=True, coalesce_max_bytes=mid))
    assert l0 == l1
    for k in g0:
        assert (np.asarray(g0[k]) == np.asarray(g1[k])).all(), k


# ---------------------------------------------------------------------------
# Cost model
# ---------------------------------------------------------------------------


def test_cost_model_explains_the_regression():
    """On the tiny CPU mesh the model must veto coalescing (the headline
    bugfix); on the TPU preset it must keep it."""
    eng = _engine(MS42, coalesce=True)
    names = list(_layer_names(eng))
    assert crossover_bytes(eng, names, CPU_SMOKE) < \
        eng.layer_wire_bytes(tuple(names))
    assert crossover_bytes(eng, names, TPU_V5E) > \
        eng.layer_wire_bytes(tuple(names))
    # step-time ordering flips between the presets
    pt = _engine(MS42, coalesce=False)
    co = _engine(MS42, coalesce=True)
    assert predict_step_time(pt, CPU_SMOKE) < predict_step_time(co, CPU_SMOKE)
    assert predict_step_time(co, TPU_V5E) < predict_step_time(pt, TPU_V5E)


def test_plan_layer_policies_thresholds():
    eng = _engine(MS42, coalesce=True)
    cpu_pol, cpu_thresh = plan_layer_policies(eng, CPU_SMOKE)
    assert cpu_pol and not any(p.coalesce for p in cpu_pol)
    assert cpu_thresh is not None
    assert cpu_thresh < min(p.wire_buffer_bytes for p in cpu_pol)
    tpu_pol, tpu_thresh = plan_layer_policies(eng, TPU_V5E)
    assert all(p.coalesce for p in tpu_pol)
    assert tpu_thresh is None  # everything coalesces: no threshold needed
    # the threshold reproduces the decisions through the engine predicate
    cut = _engine(MS42, coalesce=True, coalesce_max_bytes=cpu_thresh)
    for _, ns, _ in layer_groups(cut):
        assert not cut.layer_coalesced(tuple(ns))


def test_predict_hlo_counts_analytic_multidevice():
    """Launch counts the compiled HLO will show on real multi-device meshes
    (conformance against actual compiled HLO runs in the slow subprocess
    check; these pin the closed forms)."""
    names = list(_layer_names(_engine(MS42)))
    pt = _engine(MS42, coalesce=False)
    assert predict_hlo_gather_counts(pt, names, coalesced=False) == 23
    assert predict_hlo_gather_counts(pt, names, coalesced=True) == 1
    ms_pod = MeshSpec(axes=("pod", "data", "model"), shape=(2, 2, 2))
    hier = _engine(ms_pod, coalesce=True, hierarchical=True)
    assert predict_hlo_gather_counts(hier, names, coalesced=True) == 2
    assert predict_hlo_gather_counts(hier, names, coalesced=False) == \
        3 * 7 * 2 + 2  # 3 per quantized tensor per level + 1 per fp payload


def _hlo_counts(mesh11, qcfg):
    model = Model(MCFG, MS11, qcfg)
    params = model.init_params(jax.random.PRNGKey(1))
    tokens = jax.random.randint(jax.random.PRNGKey(2), (2, 16), 0, 256)
    batch = {"tokens": tokens, "labels": tokens}

    @partial(shard_map, mesh=mesh11,
             in_specs=(model.param_pspecs(),
                       {"tokens": P(("data",)), "labels": P(("data",))}, P()),
             out_specs=(P(), model.param_pspecs()), check_vma=False)
    def f(p, b, k):
        loss, g = jax.value_and_grad(model.loss_fn)(p, b, k)
        return jax.lax.pmean(loss, ("data", "model")), g

    compiled = jax.jit(f).lower(params, batch, jax.random.PRNGKey(3)).compile()
    return analyze_hlo(compiled.as_text())["collectives"]["counts"], model


@pytest.mark.parametrize("qkw", [
    dict(coalesce=False),
    dict(coalesce=True),
    dict(coalesce=True, coalesce_max_bytes=2048),
], ids=["per-tensor", "coalesced", "thresholded"])
def test_hlo_conformance_trivial_mesh(mesh11, qkw):
    """(1,1) conformance: the analyzer only counts collectives with replica
    groups > 1, so every gather is invisible on the trivial mesh — and the
    predictor agrees (returns 0 for each group)."""
    counts, model = _hlo_counts(mesh11, QSDPConfig(min_quant_size=128, **qkw))
    predicted = sum(predict_hlo_gather_counts(model.engine, ns)
                    for _, ns, _ in layer_groups(model.engine))
    assert predicted == 0
    assert counts["all-gather"] == predicted
    assert counts["reduce-scatter"] == 0


# ---------------------------------------------------------------------------
# Candidate space + search
# ---------------------------------------------------------------------------


def _toy_cost(c: Candidate) -> float:
    return (1.0 * c.coalesce + 0.25 * c.prefetch + 0.01 * c.weight_bits
            + (0.001 if c.coalesce_max_bytes else 0.0))


def test_enumerate_space_valid_and_unique():
    cands = list(enumerate_space(thresholds=(None, 4096)))
    assert len(cands) == len(set(cands))
    assert all(c.valid() for c in cands)
    assert any(not c.coalesce for c in cands)
    assert any(c.coalesce and c.coalesce_max_bytes == 4096 for c in cands)
    full = list(enumerate_space(thresholds=(None,), full_space=True))
    assert len(full) > len(list(enumerate_space(thresholds=(None,))))
    assert all(c.valid() for c in full)


def test_exhaustive_search_deterministic():
    cands = list(enumerate_space(thresholds=(None, 4096), full_space=True))
    r1 = exhaustive_search(cands, _toy_cost)
    r2 = exhaustive_search(cands, _toy_cost)
    assert r1 == r2
    assert [t for t, _ in r1] == sorted(t for t, _ in r1)
    assert not r1[0][1].coalesce  # toy cost: per-tensor wins


def test_annealing_deterministic_and_finds_optimum():
    cands = list(enumerate_space(thresholds=(None, 4096), full_space=True))
    r1 = simulated_annealing(cands, _toy_cost, seed=0, iters=300)
    r2 = simulated_annealing(cands, _toy_cost, seed=0, iters=300)
    assert r1 == r2
    best = exhaustive_search(cands, _toy_cost)[0]
    assert r1[0][0] == best[0]


# ---------------------------------------------------------------------------
# End-to-end: autotune -> plan -> both launchers (acceptance round-trip)
# ---------------------------------------------------------------------------


def test_autotune_plan_roundtrips_through_launchers(tmp_path, capsys):
    from repro.launch import serve as serve_mod
    from repro.launch import train as train_mod
    from repro.tune import autotune

    out = str(tmp_path / "plan.json")
    rc = autotune.main(["--smoke", "--data-par", "1", "--model-par", "1",
                        "--measure-top", "0", "--min-quant-size", "256",
                        "--out", out, "--assert-choice", "per-tensor"])
    assert rc == 0
    plan = DeploymentPlan.load(out)
    # normalized policy: always thresholded coalesce (0 = never coalesce)
    assert plan.qsdp["coalesce"] is True
    assert plan.qsdp["coalesce_max_bytes"] == 0
    assert plan.layers and not any(lp.coalesce for lp in plan.layers)

    assert train_mod.main(["--plan", out, "--smoke", "--steps", "1",
                           "--batch", "2", "--seq", "16",
                           "--log-every", "1"]) == 0
    assert serve_mod.main(["--plan", out, "--smoke", "--batch", "2",
                           "--prompt-len", "8", "--gen", "2"]) == 0
    assert "QSDP plan" in capsys.readouterr().out
