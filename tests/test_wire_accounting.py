"""Wire-byte accounting regression: analytic bytes == actual buffer length.

The analytic communication model (wire_segment_bytes / gather_wire_bytes /
reduce_scatter_wire_bytes) feeds the roofline, the bench bytes columns and
the repro.tune cost model, so it must pin the REAL packed wire format for
every code width — including the sub-byte widths where codes_per_byte > 1
(2/4/8 bit-pack exactly) and the awkward widths 3/5/6/7 that occupy one
byte per code on the emulated wire.
"""
import dataclasses

import jax
import pytest

from repro.core import collectives as coll
from repro.core.quant import (
    QuantConfig,
    fp_pack,
    fp_segment_bytes,
    quantize,
    wire_pack,
)

BUCKET = 64


def _cfg(bits, meta="float32"):
    return QuantConfig(bits=bits, bucket_size=BUCKET, mode="shift",
                       backend="jnp", meta_dtype=meta)


def _packed_nbytes(n, cfg):
    """Length of the ACTUAL packed wire buffer for an n-element tensor."""
    x = jax.random.normal(jax.random.PRNGKey(8 * n + cfg.bits), (n,))
    buf = wire_pack(quantize(x, cfg, jax.random.PRNGKey(1)))
    assert buf.dtype == jax.numpy.uint8 and buf.ndim == 1
    return int(buf.shape[0])


@pytest.mark.parametrize("bits", range(2, 9))
@pytest.mark.parametrize("meta", ["float32", "bfloat16"])
@pytest.mark.parametrize("n", [7, BUCKET, 3 * BUCKET, 1000])
def test_segment_bytes_pin_packed_buffer(bits, meta, n):
    cfg = _cfg(bits, meta)
    assert coll.WireSegment(n, cfg).nbytes == _packed_nbytes(n, cfg)


@pytest.mark.parametrize("dtype", ["float32", "bfloat16"])
def test_fp_segment_bytes_pin_packed_buffer(dtype):
    x = jax.random.normal(jax.random.PRNGKey(0), (100,))
    assert fp_segment_bytes(100, dtype) == int(fp_pack(x, dtype).shape[0])
    assert coll.WireSegment(100, None, dtype).nbytes == \
        fp_segment_bytes(100, dtype)


def test_layout_nbytes_pin_encoded_buffer():
    """The whole coalesced layout: mixed quant widths + fp payloads."""
    segs = (coll.WireSegment(300, _cfg(4)),
            coll.WireSegment(50, None, "float32"),
            coll.WireSegment(BUCKET, _cfg(3, "bfloat16")),
            coll.WireSegment(10, None, "bfloat16"),
            coll.WireSegment(200, _cfg(8)))
    layout = coll.WireLayout(segs)
    key = jax.random.PRNGKey(2)
    xs = [jax.random.normal(jax.random.fold_in(key, i), (s.n,))
          for i, s in enumerate(segs)]
    keys = [jax.random.fold_in(key, 100 + i) if s.cfg is not None else None
            for i, s in enumerate(segs)]
    buf = coll.encode_wire(xs, layout, keys)
    assert int(buf.shape[0]) == layout.nbytes
    assert layout.offsets()[-1] + segs[-1].nbytes == layout.nbytes


@pytest.mark.parametrize("bits", range(2, 9))
@pytest.mark.parametrize("p", [2, 8])
def test_gather_wire_bytes_pin_packed_shards(bits, p):
    """Ring all-gather moves (P-1) shards; each shard IS the packed buffer."""
    cfg = _cfg(bits)
    for n_local in (BUCKET, 1000):
        assert coll.gather_wire_bytes(n_local, p, cfg) == \
            (p - 1) * _packed_nbytes(n_local, cfg)
    # fp payload: raw dtype bytes per element
    assert coll.gather_wire_bytes(96, p, None, fp_bytes=4) == (p - 1) * 96 * 4
    assert coll.gather_wire_bytes(96, p, None, fp_bytes=2) == (p - 1) * 96 * 2


@pytest.mark.parametrize("bits", range(2, 9))
@pytest.mark.parametrize("p", [2, 8])
def test_reduce_scatter_wire_bytes_pin_packed_chunks(bits, p):
    """Ring RS moves (P-1) chunks of n//p elements, each a packed buffer."""
    cfg = _cfg(bits)
    for n in (p * BUCKET, p * 500):
        assert coll.reduce_scatter_wire_bytes(n, p, cfg) == \
            (p - 1) * _packed_nbytes(n // p, cfg)
    assert coll.reduce_scatter_wire_bytes(p * 96, p, None) == (p - 1) * 96 * 4


def test_meta_dtype_halves_metadata_only():
    cfg32 = _cfg(8)
    cfg16 = dataclasses.replace(cfg32, meta_dtype="bfloat16")
    n = 5 * BUCKET
    # bf16 metadata saves exactly 2 bytes per (scale, zero) pair per bucket
    assert _packed_nbytes(n, cfg32) - _packed_nbytes(n, cfg16) == 2 * 2 * 5
    assert coll.WireSegment(n, cfg32).nbytes - \
        coll.WireSegment(n, cfg16).nbytes == 2 * 2 * 5
